"""L1 Pallas kernel: the Nekbone-style spectral-element operator `ax`.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's compute
kernels are HIP on MI250X, where each element's tensor contractions run on
a wavefront with LDS staging. On TPU the same contractions are batched
small matmuls — ideal MXU work. We tile the element batch with the Pallas
grid so each block's operands stay inside VMEM:

  * block = EBLK elements of (Q,Q,Q) f32 -> EBLK*Q^3*4 bytes
    (EBLK=8, Q=8: 16 KiB in + 16 KiB out + D 256 B, far below 16 MiB VMEM;
    larger EBLK amortizes grid overhead, see EXPERIMENTS.md §Perf);
  * contractions are expressed as dot_general-shaped matmuls on (Q, Q^2)
    and (Q^2, Q) operands so the MXU systolic array does all FLOPs;
  * the kernel runs with interpret=True here (CPU PJRT cannot execute
    Mosaic custom-calls); TPU perf is estimated from VMEM footprint + MXU
    utilization in EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block size in elements. Q is fixed by the artifact shape.
EBLK = 8


def _ax_kernel(u_ref, d_ref, o_ref):
    """One grid step: apply the operator to an [EBLK, Q, Q, Q] block."""
    u = u_ref[...]
    d = d_ref[...]
    e, q = u.shape[0], u.shape[1]

    # Axis-0 contraction: for every element, D @ U with U = (Q, Q^2).
    u_r = u.reshape(e, q, q * q)
    ur = jnp.einsum("am,emk->eak", d, u_r).reshape(e, q, q, q)
    # Axis-1: move axis 1 to front of the trailing matrix.
    u_s = u.transpose(0, 2, 1, 3).reshape(e, q, q * q)
    us = (
        jnp.einsum("bm,emk->ebk", d, u_s)
        .reshape(e, q, q, q)
        .transpose(0, 2, 1, 3)
    )
    # Axis-2: (Q^2, Q) @ D^T.
    u_t = u.reshape(e, q * q, q)
    ut = jnp.einsum("cm,ekm->ekc", d, u_t).reshape(e, q, q, q)

    # Second application (transposed), summed over the three axes.
    w = (
        jnp.einsum("ma,emk->eak", d, ur.reshape(e, q, q * q)).reshape(e, q, q, q)
        + jnp.einsum("mb,emk->ebk", d, us.transpose(0, 2, 1, 3).reshape(e, q, q * q))
        .reshape(e, q, q, q)
        .transpose(0, 2, 1, 3)
        + jnp.einsum("mc,ekm->ekc", d, ut.reshape(e, q * q, q)).reshape(e, q, q, q)
    )
    o_ref[...] = w


@functools.partial(jax.jit, static_argnames=("eblk",))
def ax(u: jnp.ndarray, d: jnp.ndarray, eblk: int = EBLK) -> jnp.ndarray:
    """Apply the spectral operator to `u` [E, Q, Q, Q] with matrix `d` [Q, Q]."""
    e, q = u.shape[0], u.shape[1]
    # Largest divisor of e not exceeding the requested block size, so any
    # element count tiles cleanly.
    eblk = max(b for b in range(1, min(eblk, e) + 1) if e % b == 0)
    return pl.pallas_call(
        _ax_kernel,
        grid=(e // eblk,),
        in_specs=[
            pl.BlockSpec((eblk, q, q, q), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((q, q), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((eblk, q, q, q), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, q, q, q), jnp.float32),
        interpret=True,
    )(u, d)


def _ax_grid_kernel(u_ref, d_ref, o_ref):
    """One grid step: the operator on a single (Q,Q,Q) element tile."""
    u = u_ref[...]
    d = d_ref[...]
    q = u.shape[0]
    ur = jnp.einsum("am,mbc->abc", d, u)
    us = jnp.einsum("bm,amc->abc", d, u)
    ut = jnp.einsum("cm,abm->abc", d, u)
    o_ref[...] = (
        jnp.einsum("ma,mbc->abc", d, ur)
        + jnp.einsum("mb,amc->abc", d, us)
        + jnp.einsum("mc,abm->abc", d, ut)
    )


@jax.jit
def ax_grid(u: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """Apply the spectral operator to a [G,G,G] block laid out as a grid
    of (Q,Q,Q) spectral elements, tiling the elements directly with a 3-D
    Pallas grid (no grid<->element transpose on the HBM side — each
    BlockSpec step *is* one element, which is also the natural VMEM
    tiling on TPU)."""
    g = u.shape[0]
    q = d.shape[0]
    assert g % q == 0, f"grid edge {g} must be a multiple of Q={q}"
    n = g // q
    return pl.pallas_call(
        _ax_grid_kernel,
        grid=(n, n, n),
        in_specs=[
            pl.BlockSpec((q, q, q), lambda i, j, k: (i, j, k)),
            pl.BlockSpec((q, q), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((q, q, q), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((g, g, g), jnp.float32),
        interpret=True,
    )(u, d)


def ax_flops(e: int, q: int) -> int:
    """FLOPs of one application: 6 contractions x 2*Q^4 per element."""
    return e * 12 * q**4


def ax_bytes(e: int, q: int) -> int:
    """HBM traffic: read u, write w (D is negligible)."""
    return e * q**3 * 4 * 2
