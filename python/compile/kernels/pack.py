"""L1 Pallas kernels: surface pack / unpack-add for the Faces exchange.

These are the bandwidth-bound kernels the Faces benchmark launches around
its MPI phase ("copy into contiguous MPI buffers from faces, edges, and
corners of the local block" / "add the received messages back", paper
§V-A). On TPU the [G,G,G] block fits VMEM whole for the sizes we ship
(G=32: 128 KiB), so both kernels run as a single grid step; the packed
faces/edges/corners layout keeps the outgoing MPI buffers contiguous in
HBM, the TPU analogue of the coalesced-write HIP packing kernels.

Both kernels run with interpret=True (see ax.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(u_ref, f_ref, e_ref, c_ref):
    u = u_ref[...]
    g = u.shape[0]
    f_ref[0, :, :] = u[0, :, :]
    f_ref[1, :, :] = u[g - 1, :, :]
    f_ref[2, :, :] = u[:, 0, :]
    f_ref[3, :, :] = u[:, g - 1, :]
    f_ref[4, :, :] = u[:, :, 0]
    f_ref[5, :, :] = u[:, :, g - 1]

    e_ref[0, :] = u[0, 0, :]
    e_ref[1, :] = u[0, g - 1, :]
    e_ref[2, :] = u[g - 1, 0, :]
    e_ref[3, :] = u[g - 1, g - 1, :]
    e_ref[4, :] = u[0, :, 0]
    e_ref[5, :] = u[0, :, g - 1]
    e_ref[6, :] = u[g - 1, :, 0]
    e_ref[7, :] = u[g - 1, :, g - 1]
    e_ref[8, :] = u[:, 0, 0]
    e_ref[9, :] = u[:, 0, g - 1]
    e_ref[10, :] = u[:, g - 1, 0]
    e_ref[11, :] = u[:, g - 1, g - 1]

    c_ref[0] = u[0, 0, 0]
    c_ref[1] = u[0, 0, g - 1]
    c_ref[2] = u[0, g - 1, 0]
    c_ref[3] = u[0, g - 1, g - 1]
    c_ref[4] = u[g - 1, 0, 0]
    c_ref[5] = u[g - 1, 0, g - 1]
    c_ref[6] = u[g - 1, g - 1, 0]
    c_ref[7] = u[g - 1, g - 1, g - 1]


@jax.jit
def pack(u: jnp.ndarray):
    """Extract surface regions of `u` [G,G,G] -> (faces [6,G,G], edges
    [12,G], corners [8]). Region order documented in ref.pack_ref."""
    g = u.shape[0]
    return pl.pallas_call(
        _pack_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((6, g, g), jnp.float32),
            jax.ShapeDtypeStruct((12, g), jnp.float32),
            jax.ShapeDtypeStruct((8,), jnp.float32),
        ],
        interpret=True,
    )(u)


def _unpack_add_kernel(u_ref, f_ref, e_ref, c_ref, o_ref):
    u = u_ref[...]
    f = f_ref[...]
    e = e_ref[...]
    c = c_ref[...]
    g = u.shape[0]
    u = u.at[0, :, :].add(f[0]).at[g - 1, :, :].add(f[1])
    u = u.at[:, 0, :].add(f[2]).at[:, g - 1, :].add(f[3])
    u = u.at[:, :, 0].add(f[4]).at[:, :, g - 1].add(f[5])

    u = u.at[0, 0, :].add(e[0]).at[0, g - 1, :].add(e[1])
    u = u.at[g - 1, 0, :].add(e[2]).at[g - 1, g - 1, :].add(e[3])
    u = u.at[0, :, 0].add(e[4]).at[0, :, g - 1].add(e[5])
    u = u.at[g - 1, :, 0].add(e[6]).at[g - 1, :, g - 1].add(e[7])
    u = u.at[:, 0, 0].add(e[8]).at[:, 0, g - 1].add(e[9])
    u = u.at[:, g - 1, 0].add(e[10]).at[:, g - 1, g - 1].add(e[11])

    u = u.at[0, 0, 0].add(c[0]).at[0, 0, g - 1].add(c[1])
    u = u.at[0, g - 1, 0].add(c[2]).at[0, g - 1, g - 1].add(c[3])
    u = u.at[g - 1, 0, 0].add(c[4]).at[g - 1, 0, g - 1].add(c[5])
    u = u.at[g - 1, g - 1, 0].add(c[6]).at[g - 1, g - 1, g - 1].add(c[7])
    o_ref[...] = u


@jax.jit
def unpack_add(u: jnp.ndarray, faces: jnp.ndarray, edges: jnp.ndarray, corners: jnp.ndarray):
    """Add received boundary contributions into `u`'s surface."""
    g = u.shape[0]
    return pl.pallas_call(
        _unpack_add_kernel,
        out_shape=jax.ShapeDtypeStruct((g, g, g), jnp.float32),
        interpret=True,
    )(u, faces, edges, corners)


def pack_bytes(g: int) -> int:
    """HBM traffic of pack: read the block surface, write the buffers."""
    surface = 6 * g * g + 12 * g + 8
    return 2 * surface * 4


def unpack_bytes(g: int) -> int:
    """HBM traffic of unpack_add: read+write the whole block plus buffers."""
    return (2 * g**3 + 6 * g * g + 12 * g + 8) * 4
