"""Pure-jnp oracle implementations for the L1 Pallas kernels.

These are the correctness references: pytest checks every Pallas kernel
against these functions, and the rust side's CPU reference (used by the
Faces benchmark's self-check) implements the same math.
"""

import jax.numpy as jnp
import numpy as np


def deriv_matrix(q: int) -> np.ndarray:
    """A fixed, well-conditioned QxQ 'spectral derivative'-like matrix.

    Nekbone uses the Gauss-Lobatto-Legendre differentiation matrix; any
    fixed dense matrix exercises the same tensor-contraction structure.
    We use a deterministic, integer-friendly construction so rust can
    reproduce it bit-for-bit in f32 (see rust/src/faces/reference.rs).
    """
    d = np.zeros((q, q), dtype=np.float32)
    for a in range(q):
        for m in range(q):
            # Small magnitudes, exactly representable in f32.
            d[a, m] = ((a - m) % q - (q - 1) / 2.0) / q
    return d


def ax_ref(u: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """Spectral-element local operator (Nekbone's `ax` hot loop).

    u: [E, Q, Q, Q] per-element nodal values; d: [Q, Q].
    w = sum over the three axes of D^T (D u) applied along that axis.
    """
    ur = jnp.einsum("am,embc->eabc", d, u)
    us = jnp.einsum("bm,eamc->eabc", d, u)
    ut = jnp.einsum("cm,eabm->eabc", d, u)
    w = (
        jnp.einsum("ma,embc->eabc", d, ur)
        + jnp.einsum("mb,eamc->eabc", d, us)
        + jnp.einsum("mc,eabm->eabc", d, ut)
    )
    return w


def pack_ref(u: jnp.ndarray):
    """Extract the 6 faces, 12 edges, and 8 corners of a [G,G,G] block.

    Order matches `rust/src/faces/neighbors.rs` (documented there):
    faces:  -x, +x, -y, +y, -z, +z              -> [6, G, G]
    edges:  (xy) --, -+, +-, ++  then (xz) --, -+, +-, ++
            then (yz) --, -+, +-, ++            -> [12, G]
    corners: (-,-,-) .. (+,+,+) lexicographic   -> [8]
    """
    g = u.shape[0]
    faces = jnp.stack(
        [u[0, :, :], u[g - 1, :, :], u[:, 0, :], u[:, g - 1, :], u[:, :, 0], u[:, :, g - 1]]
    )
    edges = jnp.stack(
        [
            u[0, 0, :], u[0, g - 1, :], u[g - 1, 0, :], u[g - 1, g - 1, :],
            u[0, :, 0], u[0, :, g - 1], u[g - 1, :, 0], u[g - 1, :, g - 1],
            u[:, 0, 0], u[:, 0, g - 1], u[:, g - 1, 0], u[:, g - 1, g - 1],
        ]
    )
    corners = jnp.stack(
        [
            u[0, 0, 0], u[0, 0, g - 1], u[0, g - 1, 0], u[0, g - 1, g - 1],
            u[g - 1, 0, 0], u[g - 1, 0, g - 1], u[g - 1, g - 1, 0], u[g - 1, g - 1, g - 1],
        ]
    )
    return faces, edges, corners


def unpack_add_ref(u, faces, edges, corners):
    """Add received boundary contributions back into the block surface.

    Mirror of `pack_ref`: the face received from the -x neighbor is added
    onto this block's -x face, etc.
    """
    g = u.shape[0]
    u = u.at[0, :, :].add(faces[0]).at[g - 1, :, :].add(faces[1])
    u = u.at[:, 0, :].add(faces[2]).at[:, g - 1, :].add(faces[3])
    u = u.at[:, :, 0].add(faces[4]).at[:, :, g - 1].add(faces[5])

    u = u.at[0, 0, :].add(edges[0]).at[0, g - 1, :].add(edges[1])
    u = u.at[g - 1, 0, :].add(edges[2]).at[g - 1, g - 1, :].add(edges[3])
    u = u.at[0, :, 0].add(edges[4]).at[0, :, g - 1].add(edges[5])
    u = u.at[g - 1, :, 0].add(edges[6]).at[g - 1, :, g - 1].add(edges[7])
    u = u.at[:, 0, 0].add(edges[8]).at[:, 0, g - 1].add(edges[9])
    u = u.at[:, g - 1, 0].add(edges[10]).at[:, g - 1, g - 1].add(edges[11])

    u = u.at[0, 0, 0].add(corners[0]).at[0, 0, g - 1].add(corners[1])
    u = u.at[0, g - 1, 0].add(corners[2]).at[0, g - 1, g - 1].add(corners[3])
    u = u.at[g - 1, 0, 0].add(corners[4]).at[g - 1, 0, g - 1].add(corners[5])
    u = u.at[g - 1, g - 1, 0].add(corners[6]).at[g - 1, g - 1, g - 1].add(corners[7])
    return u
