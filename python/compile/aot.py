"""AOT compile path: lower every L2 entry point to HLO text + manifest.

HLO *text* (never `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (behind the rust `xla`
crate) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Faces grid sizes to ship (rust picks by config; tests use 16).
FACES_GRIDS = [16, 32]


def to_hlo_text(fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def shape_str(shapes) -> str:
    if not shapes:
        return "-"
    return ",".join("x".join(str(d) for d in s.shape) for s in shapes)


def entries():
    """(name, fn, input_specs, output_shapes) for every artifact."""
    out = []
    for g in FACES_GRIDS:
        out.append(
            (f"faces_pack_g{g}", model.faces_pack, [f32(g, g, g)],
             [f32(6, g, g), f32(12, g), f32(8)])
        )
        out.append(
            (
                f"faces_ax_g{g}",
                model.faces_ax,
                [f32(g, g, g), f32(model.Q, model.Q)],
                [f32(g, g, g)],
            )
        )
        out.append(
            (
                f"faces_unpack_g{g}",
                model.faces_unpack_add,
                [f32(g, g, g), f32(6, g, g), f32(12, g), f32(8)],
                [f32(g, g, g)],
            )
        )
    n = model.param_count()
    bs1 = (model.BATCH, model.SEQ + 1)
    out.append(("train_init", model.init_params, [], [f32(n)]))
    out.append(
        ("train_grad", model.train_grad, [f32(n), f32(*bs1)], [f32(1), f32(n)])
    )
    out.append(("sgd_apply", model.sgd_apply, [f32(n), f32(n)], [f32(n)]))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest_lines = ["# AOT artifact manifest: name, HLO file, f32 arg/result shapes"]
    for name, fn, specs, outs in entries():
        if only and name not in only:
            continue
        text = to_hlo_text(fn, *specs)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        line = f"name={name} file={fname} in={shape_str(specs)} out={shape_str(outs)}"
        manifest_lines.append(line)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
