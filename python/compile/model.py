"""L2: JAX entry points lowered to the AOT artifacts the rust side runs.

Two groups:

* **Faces** — the compute kernels of the Faces microbenchmark (paper
  §V-A): `faces_pack` (surface -> contiguous MPI buffers), `faces_ax`
  (interior spectral-element operator while communication is in flight),
  `faces_unpack_add` (add received contributions). Each calls the L1
  Pallas kernels in `kernels/`.

* **Trainer** — a small causal language model used by the
  `st_allreduce_train` example: data-parallel ranks each run
  `train_grad`, allreduce the flat gradient through the ST collective,
  then run `sgd_apply`. Parameters travel as ONE flat f32 vector so the
  rust collective layer treats them as a single buffer.

Everything here is shape-static; `aot.py` lowers one artifact per
configured size.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ax as ax_kernel
from .kernels import pack as pack_kernel
from .kernels.ref import deriv_matrix

Q = 8  # spectral element order + 1 (points per dimension)


# ---------------------------------------------------------------------
# Faces entries
# ---------------------------------------------------------------------

def faces_pack(u):
    """[G,G,G] -> (faces [6,G,G], edges [12,G], corners [8])."""
    f, e, c = pack_kernel.pack(u)
    return f, e, c


def faces_ax(u, d):
    """Interior compute: the spectral operator applied to every (Q,Q,Q)
    element tile of the [G,G,G] block (the Pallas grid tiles elements
    directly; see kernels/ax.py::ax_grid).

    `d` is a runtime argument, NOT a baked constant: xla_extension 0.5.1
    (the version behind the rust `xla` crate) miscompiles constant
    operands of gridded pallas_calls to zeros — see DESIGN.md §Gotchas.
    """
    return (ax_kernel.ax_grid(u, d),)


def faces_unpack_add(u, faces, edges, corners):
    """Add received boundary contributions into the block surface."""
    return (pack_kernel.unpack_add(u, faces, edges, corners),)


# ---------------------------------------------------------------------
# Trainer entries (data-parallel LM for the ST-allreduce example)
# ---------------------------------------------------------------------

# Model dimensions (small enough to train a few hundred steps on CPU).
VOCAB = 32
SEQ = 16
BATCH = 8
DIM = 64
HIDDEN = 4 * DIM
LR = 0.5


def _param_shapes():
    return [
        ("embed", (VOCAB, DIM)),
        ("wq", (DIM, DIM)),
        ("wk", (DIM, DIM)),
        ("wv", (DIM, DIM)),
        ("wo", (DIM, DIM)),
        ("w1", (DIM, HIDDEN)),
        ("w2", (HIDDEN, DIM)),
        ("head", (DIM, VOCAB)),
    ]


def param_count() -> int:
    return sum(int(np.prod(s)) for _, s in _param_shapes())


def _unflatten(flat):
    out = {}
    off = 0
    for name, shape in _param_shapes():
        n = int(np.prod(shape))
        out[name] = flat[off : off + n].reshape(shape)
        off += n
    return out


def init_params():
    """Deterministic initialization, emitted as a zero-input artifact."""
    key = jax.random.PRNGKey(0)
    parts = []
    for name, shape in _param_shapes():
        key, sub = jax.random.split(key)
        scale = 0.02 if name == "embed" else (1.0 / np.sqrt(shape[0]))
        parts.append((jax.random.normal(sub, shape, jnp.float32) * scale).reshape(-1))
    return (jnp.concatenate(parts),)


def _forward(p, tokens):
    """Single-block causal transformer; tokens int32 [B, S]."""
    x = p["embed"][tokens]  # [B, S, D]
    # Causal single-head attention.
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    att = jnp.einsum("bsd,btd->bst", q, k) / np.sqrt(DIM).astype(np.float32)
    mask = jnp.tril(jnp.ones((SEQ, SEQ), jnp.float32))
    att = jnp.where(mask == 1.0, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    x = x + (att @ v) @ p["wo"]
    # MLP.
    x = x + jax.nn.gelu(x @ p["w1"]) @ p["w2"]
    return x @ p["head"]  # [B, S, V]


def _loss(flat, tokens_f):
    tokens = tokens_f.astype(jnp.int32)  # [B, S+1] as f32 on the wire
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = _forward(_unflatten(flat), inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
    return jnp.mean(nll)


def train_grad(flat, tokens_f):
    """-> (loss [1], grads [N]): each rank computes its local gradient."""
    loss, g = jax.value_and_grad(_loss)(flat, tokens_f)
    return loss.reshape(1), g


def sgd_apply(flat, grads):
    """Apply the (allreduce-averaged) gradient."""
    return (flat - LR * grads,)
