"""L2 model tests: faces entries and the trainer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import ax_ref, deriv_matrix, pack_ref, unpack_add_ref

Q = model.Q


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


@pytest.mark.parametrize("g", [8, 16])
def test_faces_ax_equals_elementwise_ref(g):
    """The grid<->element reshape in faces_ax must be exact."""
    u = rand((g, g, g), 1)
    (w,) = model.faces_ax(u, jnp.asarray(deriv_matrix(Q)))
    n = g // Q
    ue = (
        u.reshape(n, Q, n, Q, n, Q).transpose(0, 2, 4, 1, 3, 5).reshape(n**3, Q, Q, Q)
    )
    we = ax_ref(ue, jnp.asarray(deriv_matrix(Q)))
    want = (
        we.reshape(n, n, n, Q, Q, Q).transpose(0, 3, 1, 4, 2, 5).reshape(g, g, g)
    )
    np.testing.assert_allclose(w, want, rtol=1e-5, atol=1e-5)


def test_faces_pack_and_unpack_against_ref():
    g = 16
    u = rand((g, g, g), 2)
    f, e, c = model.faces_pack(u)
    rf, re, rc = pack_ref(u)
    np.testing.assert_array_equal(f, rf)
    np.testing.assert_array_equal(e, re)
    np.testing.assert_array_equal(c, rc)
    (u2,) = model.faces_unpack_add(u, f, e, c)
    np.testing.assert_allclose(u2, unpack_add_ref(u, rf, re, rc), rtol=1e-6)


def test_param_count_matches_layout():
    (flat,) = model.init_params()
    assert flat.shape == (model.param_count(),)
    assert flat.dtype == jnp.float32
    # Deterministic init.
    (flat2,) = model.init_params()
    np.testing.assert_array_equal(flat, flat2)


def make_tokens(seed):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, model.VOCAB, size=(model.BATCH, model.SEQ + 1))
    return jnp.asarray(toks, jnp.float32)


def test_train_grad_shapes_and_finite():
    (flat,) = model.init_params()
    loss, g = model.train_grad(flat, make_tokens(0))
    assert loss.shape == (1,)
    assert g.shape == flat.shape
    assert np.isfinite(float(loss[0]))
    assert np.isfinite(np.asarray(g)).all()


def test_loss_decreases_under_sgd():
    (flat,) = model.init_params()
    toks = make_tokens(1)
    losses = []
    for _ in range(100):
        loss, g = model.train_grad(flat, toks)
        losses.append(float(loss[0]))
        (flat,) = model.sgd_apply(flat, g)
    assert losses[-1] < losses[0] * 0.5, f"no learning: {losses[0]} -> {losses[-1]}"


def test_initial_loss_near_uniform():
    (flat,) = model.init_params()
    loss, _ = model.train_grad(flat, make_tokens(2))
    assert abs(float(loss[0]) - np.log(model.VOCAB)) < 0.5


def test_sgd_apply_is_descent_step():
    (flat,) = model.init_params()
    g = jnp.ones_like(flat)
    (out,) = model.sgd_apply(flat, g)
    np.testing.assert_allclose(out, flat - model.LR, rtol=1e-6)
