"""Pallas kernels vs pure-jnp oracles — the core numerics signal.

hypothesis sweeps shapes and value distributions; every property asserts
allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ax as axk
from compile.kernels import pack as packk
from compile.kernels.ref import ax_ref, deriv_matrix, pack_ref, unpack_add_ref

Q = 8


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------------
# ax kernel
# ---------------------------------------------------------------------

@pytest.mark.parametrize("e", [1, 8, 27, 64])
def test_ax_matches_ref(e):
    u = rand((e, Q, Q, Q), e)
    d = jnp.asarray(deriv_matrix(Q))
    got = axk.ax(u, d)
    want = ax_ref(u, d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("eblk", [1, 2, 4, 8])
def test_ax_block_size_invariant(eblk):
    """Result must not depend on the Pallas grid tiling."""
    u = rand((16, Q, Q, Q), 3)
    d = jnp.asarray(deriv_matrix(Q))
    base = axk.ax(u, d, eblk=8)
    np.testing.assert_allclose(axk.ax(u, d, eblk=eblk), base, rtol=1e-6)


def test_ax_linearity():
    u = rand((8, Q, Q, Q), 5)
    v = rand((8, Q, Q, Q), 6)
    d = jnp.asarray(deriv_matrix(Q))
    lhs = axk.ax(u + 2.0 * v, d)
    rhs = axk.ax(u, d) + 2.0 * axk.ax(v, d)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    e=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 100.0),
)
def test_ax_property_sweep(e, seed, scale):
    u = rand((e, Q, Q, Q), seed) * scale
    d = jnp.asarray(deriv_matrix(Q))
    got = axk.ax(u, d)
    want = ax_ref(u, d)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale)


def test_ax_zero_input_gives_zero():
    u = jnp.zeros((4, Q, Q, Q), jnp.float32)
    d = jnp.asarray(deriv_matrix(Q))
    assert float(jnp.abs(axk.ax(u, d)).max()) == 0.0


def test_deriv_matrix_deterministic():
    a = deriv_matrix(Q)
    b = deriv_matrix(Q)
    np.testing.assert_array_equal(a, b)
    # Matches the closed form rust reimplements (faces/reference.rs).
    assert a[0, 0] == pytest.approx((0 - (Q - 1) / 2.0) / Q)


# ---------------------------------------------------------------------
# pack / unpack kernels
# ---------------------------------------------------------------------

@pytest.mark.parametrize("g", [8, 16, 32])
def test_pack_matches_ref(g):
    u = rand((g, g, g), g)
    f, e, c = packk.pack(u)
    rf, re, rc = pack_ref(u)
    np.testing.assert_array_equal(f, rf)
    np.testing.assert_array_equal(e, re)
    np.testing.assert_array_equal(c, rc)


@pytest.mark.parametrize("g", [8, 16, 32])
def test_unpack_add_matches_ref(g):
    u = rand((g, g, g), g + 1)
    f = rand((6, g, g), g + 2)
    e = rand((12, g), g + 3)
    c = rand((8,), g + 4)
    got = packk.unpack_add(u, f, e, c)
    want = unpack_add_ref(u, f, e, c)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_pack_unpack_roundtrip_adds_surface():
    """unpack_add(pack(u)) doubles faces, with edge/corner multiplicity."""
    g = 16
    u = jnp.ones((g, g, g), jnp.float32)
    f, e, c = packk.pack(u)
    out = packk.unpack_add(u, f, e, c)
    # interior untouched
    assert float(out[g // 2, g // 2, g // 2]) == 1.0
    # face-interior point: u + face = 2
    assert float(out[0, g // 2, g // 2]) == 2.0
    # edge point: u + 2 faces + edge = 4
    assert float(out[0, 0, g // 2]) == 4.0
    # corner point: u + 3 faces + 3 edges + corner = 8
    assert float(out[0, 0, 0]) == 8.0


@settings(max_examples=15, deadline=None)
@given(g=st.sampled_from([8, 16]), seed=st.integers(0, 2**31 - 1))
def test_pack_property_sweep(g, seed):
    u = rand((g, g, g), seed)
    f, e, c = packk.pack(u)
    rf, re, rc = pack_ref(u)
    np.testing.assert_array_equal(f, rf)
    np.testing.assert_array_equal(e, re)
    np.testing.assert_array_equal(c, rc)


def test_pack_output_dtypes_and_shapes():
    g = 8
    f, e, c = packk.pack(jnp.zeros((g, g, g), jnp.float32))
    assert f.shape == (6, g, g) and f.dtype == jnp.float32
    assert e.shape == (12, g) and e.dtype == jnp.float32
    assert c.shape == (8,) and c.dtype == jnp.float32
