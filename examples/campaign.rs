//! Workload-engine campaign: run the scenario cross-product on the
//! parallel sweep executor and emit the comparative JSON + Markdown
//! report (written to CAMPAIGN_report.{json,md} in the working dir).
//!
//! Two parts:
//! 1. the CI smoke campaign (2 workloads × 4 variants each — host, ST,
//!    KT, and GI — tiny sizes) with hard assertions: validation passes,
//!    the JSON report parses, and a rerun is byte-identical;
//! 2. the full default campaign — all nine registered workloads × every
//!    variant × 2 sizes × 2 topologies × {1, 2} queues per rank × 2
//!    seeds — which produces the report artifact CI uploads (including
//!    the multi-queue cells and the achieved-overlap / critical-path
//!    columns).
//!
//! Deterministic at any `STMPI_SWEEP_THREADS`.
//!
//! Run: `cargo run --release --example campaign`

use stmpi::workloads::campaign::{json_parses, run_campaign, CampaignSpec};

fn main() {
    // Part 1: smoke campaign with report assertions.
    let t0 = std::time::Instant::now();
    let smoke = CampaignSpec::smoke();
    let a = run_campaign(&smoke).expect("smoke campaign");
    assert!(a.all_ok(), "smoke campaign validation failed:\n{}", a.to_markdown());
    assert!(json_parses(&a.to_json()), "smoke JSON report must parse");
    let b = run_campaign(&smoke).expect("smoke campaign rerun");
    assert_eq!(a.to_json(), b.to_json(), "smoke report must be byte-identical across reruns");
    assert_eq!(a.to_markdown(), b.to_markdown());
    println!(
        "smoke campaign OK: {} cells ran, JSON parses, rerun byte-identical (wall {:.1}s)\n",
        a.ran_cells(),
        t0.elapsed().as_secs_f64()
    );

    // Part 2: the full campaign — every registered workload and variant,
    // including the multi-queue axis (q=2 cells; workloads that drive a
    // single queue appear as skipped rows there).
    let t1 = std::time::Instant::now();
    let spec = CampaignSpec {
        elems: vec![64, 1024],
        topos: vec![(2, 1), (4, 1)],
        queues: vec![1, 2],
        seeds: vec![11, 23],
        iters: 2,
        ..CampaignSpec::default()
    };
    let report = run_campaign(&spec).expect("full campaign");
    println!("{}", report.to_markdown());
    assert!(report.all_ok(), "campaign validation failed (see report above)");
    assert!(
        report.workloads_covered() >= 9,
        "expected >= 9 workloads, got {}",
        report.workloads_covered()
    );
    assert!(
        report.cells.iter().any(|c| c.queues_per_rank == 2 && c.summary.is_some()),
        "the multi-queue axis must contribute ran cells"
    );
    assert!(
        report
            .cells
            .iter()
            .any(|c| c.variant.contains("gi") && c.summary.is_some() && c.gi_posts > 0),
        "the GPU-initiated axis must contribute ran cells that post through the command ring"
    );
    assert!(
        report
            .cells
            .iter()
            .filter(|c| c.summary.is_some())
            .all(|c| c.overlap_pct.is_some() && c.crit.is_some()),
        "every ran cell must carry achieved-overlap and critical-path columns"
    );
    assert!(report.to_markdown().contains("overlap %"));
    assert!(json_parses(&report.to_json()), "full JSON report must parse");
    std::fs::write("CAMPAIGN_report.json", report.to_json()).expect("write CAMPAIGN_report.json");
    std::fs::write("CAMPAIGN_report.md", report.to_markdown()).expect("write CAMPAIGN_report.md");
    println!(
        "wrote CAMPAIGN_report.json and CAMPAIGN_report.md ({} cells, wall {:.1}s)",
        report.cells.len(),
        t1.elapsed().as_secs_f64()
    );
}
