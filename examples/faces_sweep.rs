//! Regenerate every evaluation figure of the paper in one run
//! (Figs 8-12; see DESIGN.md §Experiment index and EXPERIMENTS.md for
//! the paper-vs-measured record).
//!
//! Each figure's (variant x seed) grid runs in parallel on the
//! `sim::sweep` executor; per-run seeds keep the report byte-identical
//! regardless of thread count. Set `STMPI_SWEEP_THREADS` to override the
//! worker count.
//!
//! Run: `cargo run --release --example faces_sweep`

use stmpi::faces::figures::{all_figures, run_figure, Loops, FIGURE_G, SEEDS};
use stmpi::sim::sweep;

fn main() {
    println!(
        "Faces figure sweep: 5 seeds per variant, G={FIGURE_G}, Modeled compute, {} sweep threads\n",
        sweep::default_threads()
    );
    let t_all = std::time::Instant::now();
    for spec in all_figures() {
        let t0 = std::time::Instant::now();
        let report = run_figure(&spec, &SEEDS, Loops::default(), FIGURE_G);
        println!("{}", report.render());
        println!("(wall {:.1}s)\n", t0.elapsed().as_secs_f64());
    }
    println!("total wall {:.1}s", t_all.elapsed().as_secs_f64());
}
