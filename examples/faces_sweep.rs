//! Regenerate every evaluation figure of the paper in one run
//! (Figs 8-12 plus the ST-vs-KT figure and message-size sweep; see
//! DESIGN.md §Experiment index and EXPERIMENTS.md for the
//! paper-vs-measured record).
//!
//! Each figure's (variant x seed) grid runs in parallel on the
//! `sim::sweep` executor; per-run seeds keep the report byte-identical
//! regardless of thread count. Set `STMPI_SWEEP_THREADS` to override the
//! worker count.
//!
//! Run: `cargo run --release --example faces_sweep`

use stmpi::faces::figures::{
    all_figures, render_kt_compare, run_figure, run_kt_compare, Loops, FIGURE_G, KT_COMPARE_GS,
    SEEDS,
};
use stmpi::sim::sweep;

fn main() {
    println!(
        "Faces figure sweep: 5 seeds per variant, G={FIGURE_G}, Modeled compute, {} sweep threads\n",
        sweep::default_threads()
    );
    let t_all = std::time::Instant::now();
    for spec in all_figures() {
        let t0 = std::time::Instant::now();
        let report = run_figure(&spec, &SEEDS, Loops::default(), FIGURE_G);
        println!("{}", report.render());
        println!("(wall {:.1}s)\n", t0.elapsed().as_secs_f64());
    }
    // The ST-vs-KT message-size sweep (arXiv 2306.15773 Fig-6-style gap).
    let t0 = std::time::Instant::now();
    let rows = run_kt_compare(&KT_COMPARE_GS, &SEEDS, Loops::default());
    println!("{}", render_kt_compare(&rows));
    println!("(wall {:.1}s)\n", t0.elapsed().as_secs_f64());
    for r in &rows {
        assert!(
            r.kt.avg <= r.st.avg,
            "KT must be <= ST at G={}: {:.3} vs {:.3} ms",
            r.g,
            r.kt.avg,
            r.st.avg
        );
    }
    println!("total wall {:.1}s", t_all.elapsed().as_secs_f64());
}
