//! Data-parallel training over the ST stack: each of 4 ranks runs the
//! AOT-compiled causal-LM train step (JAX fwd/bwd lowered to HLO), the
//! flat gradient is summed with the stream-triggered ring allreduce
//! (every ring step = MPIX enqueue_send/recv + one batched start), and
//! SGD applies the averaged gradient — all on the simulated cluster, with
//! real numerics. The loss curve is printed and recorded in
//! EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example st_allreduce_train`

use stmpi::costmodel::{presets, MemOpFlavor};
use stmpi::train::{train, TrainConfig};

fn main() {
    let cfg = TrainConfig {
        nodes: 4,
        ranks_per_node: 1,
        steps: 200,
        seed: 3,
        cost: presets::frontier_like(),
        flavor: MemOpFlavor::Hip,
    };
    println!(
        "ST-allreduce data-parallel training: {} ranks x {} steps (causal LM, real XLA numerics)\n",
        cfg.nodes * cfg.ranks_per_node,
        cfg.steps
    );
    let t0 = std::time::Instant::now();
    let r = train(&cfg).expect("training failed");
    println!("step   loss");
    for (i, l) in r.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == r.losses.len() {
            println!("{i:>4}   {l:.4}");
        }
    }
    let first = r.losses[0];
    let last = *r.losses.last().unwrap();
    println!(
        "\nloss {first:.4} -> {last:.4} ({:.1}% reduction) | virtual {:.3} ms | wall {:.1}s",
        (1.0 - last / first) * 100.0,
        r.time_ns as f64 / 1e6,
        t0.elapsed().as_secs_f64()
    );
    assert!(last < first * 0.8, "training must reduce loss substantially");
}
