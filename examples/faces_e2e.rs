//! End-to-end Faces driver with REAL numerics — the full-system proof:
//!
//! * L1/L2: every kernel (pack / spectral-element ax / unpack-add) is the
//!   AOT-compiled XLA artifact authored in JAX+Pallas;
//! * L3: the simulated cluster (8 Frontier-like nodes, Slingshot-11-style
//!   NICs with triggered ops, GPU streams + control processors, the MPI
//!   matching layer and progress threads) moves the actual bytes;
//! * every variant's final fields are checked against the sequential CPU
//!   reference (the paper's own methodology, §V-A), and the headline
//!   baseline-vs-ST comparison is reported.
//!
//! Run: `make artifacts && cargo run --release --example faces_e2e`

use stmpi::coordinator::report::pct_delta;
use stmpi::faces::{run_faces, FacesConfig, Variant};
use stmpi::world::ComputeMode;

fn main() {
    let base = FacesConfig {
        dist: (2, 2, 2),
        nodes: 8,
        ranks_per_node: 1,
        g: 32,
        outer: 1,
        middle: 2,
        inner: 10,
        variant: Variant::Host,
        compute: ComputeMode::Real,
        check: true,
        seed: 11,
        cost: stmpi::costmodel::presets::frontier_like(),
    };
    println!(
        "Faces end-to-end: {}x{}x{} ranks on {} nodes, G={} ({} inner iters, real XLA numerics)\n",
        base.dist.0, base.dist.1, base.dist.2, base.nodes, base.g, base.inner
    );

    let mut rows = Vec::new();
    for variant in [Variant::Host, Variant::StreamTriggered, Variant::StreamTriggeredShader] {
        let cfg = FacesConfig { variant, ..base.clone() };
        let t0 = std::time::Instant::now();
        let r = run_faces(&cfg).expect("faces run failed");
        let err = r.max_err.expect("check enabled");
        println!(
            "{:<10} virtual {:>9.3} ms | max|field-reference| = {:.2e} {} | {} wire B, {} ipc B, {} kernels (wall {:.1}s)",
            variant.name(),
            r.time_ns as f64 / 1e6,
            err,
            if err < 1e-3 { "OK" } else { "FAIL" },
            r.metrics.bytes_wire,
            r.metrics.bytes_ipc,
            r.metrics.kernels_launched,
            t0.elapsed().as_secs_f64(),
        );
        assert!(err < 1e-3, "{} diverged from the CPU reference", variant.name());
        rows.push((variant, r.time_ns as f64 / 1e6));
    }

    let baseline = rows[0].1;
    println!("\nheadline (paper §V): execution time vs baseline");
    for (v, t) in &rows[1..] {
        println!("  {:<10} {:+.1}%", v.name(), pct_delta(baseline, *t));
    }
    println!("\nall variants validated against the CPU-only reference — recorded in EXPERIMENTS.md");
}
