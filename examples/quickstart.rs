//! Quickstart: the paper's Fig. 7 usage example, line for line.
//!
//! Rank 0 launches a device compute kernel, enqueues four batched ST
//! sends, one start, one wait; rank 1 enqueues the matching receives and
//! consumes them in a device kernel. The host never blocks on
//! communication — only on the final `hipStreamSynchronize`.
//!
//! Run: `cargo run --release --example quickstart`

use stmpi::coordinator::{build_world, run_cluster};
use stmpi::costmodel::presets;
use stmpi::gpu::{self, host_enqueue, stream_synchronize, KernelPayload, KernelSpec, StreamOp};
use stmpi::mpi::COMM_WORLD_DUP;
use stmpi::nic::BufSlice;
use stmpi::stx::{Queue, Variant};
use stmpi::world::{BufId, Topology};

const SIZE: usize = 256;

fn main() {
    // Two ranks on two nodes, like a minimal multi-node job.
    let mut world = build_world(presets::frontier_like(), Topology::new(2, 1));
    let src: Vec<BufId> = (0..4).map(|_| world.bufs.alloc(SIZE)).collect();
    let dst: Vec<BufId> = (0..4).map(|_| world.bufs.alloc(SIZE)).collect();
    let tags = [123, 126, 125, 124]; // the figure's (deliberately shuffled) tags

    let src2 = src.clone();
    let dst2 = dst.clone();
    let out = run_cluster(world, 7, move |my_rank, ctx| {
        // hipStreamCreateWithFlags + MPIX_Create_queue (stx v2: a typed
        // Queue handle owning the NIC counters it maps).
        let stream = ctx.with(move |w, core| gpu::create_stream(w, core, my_rank));
        let queue = Queue::create(ctx, my_rank, stream, Variant::StreamTriggered)
            .expect("NIC counter pool exhausted");

        if my_rank == 0 {
            // launch_device_compute_kernel(src_buf1..4, stream)
            let bufs = src2.clone();
            host_enqueue(
                ctx,
                stream,
                StreamOp::Kernel(KernelSpec {
                    name: "compute".into(),
                    flops: 4 * SIZE as u64,
                    bytes: 4 * 4 * SIZE as u64,
                    payload: KernelPayload::Fn(Box::new(move |w, _| {
                        for (i, b) in bufs.iter().enumerate() {
                            w.bufs.get_mut(*b).fill(i as f32 + 1.0);
                        }
                    })),
                }),
            );
            for (i, b) in src2.iter().enumerate() {
                queue.send(ctx, 1, BufSlice::whole(*b, SIZE), tags[i], COMM_WORLD_DUP).unwrap();
            }
            // Enqueue_start enables triggering of all prior send ops.
            queue.start(ctx).unwrap();
            // wait blocks only the current GPU stream.
            queue.wait(ctx).unwrap();
            println!(
                "[rank 0] four sends enqueued + started at t={} ns (host not blocked)",
                ctx.now()
            );
        } else {
            for (i, b) in dst2.iter().enumerate() {
                queue.recv(ctx, 0, BufSlice::whole(*b, SIZE), tags[i], COMM_WORLD_DUP).unwrap();
            }
            queue.start(ctx).unwrap();
            queue.wait(ctx).unwrap();
            // launch_device_compute_kernel(dst_buf1..4, stream): ordered
            // after the waitValue64, so it sees the received data.
            let bufs = dst2.clone();
            host_enqueue(
                ctx,
                stream,
                StreamOp::Kernel(KernelSpec {
                    name: "consume".into(),
                    flops: 4 * SIZE as u64,
                    bytes: 4 * 4 * SIZE as u64,
                    payload: KernelPayload::Fn(Box::new(move |w, _| {
                        for (i, b) in bufs.iter().enumerate() {
                            assert!(
                                w.bufs.get(*b).iter().all(|&x| x == i as f32 + 1.0),
                                "buffer {i} does not contain the sent payload"
                            );
                        }
                        println!("[rank 1] device kernel verified all four received buffers");
                    })),
                }),
            );
            println!(
                "[rank 1] four recvs enqueued at t={} ns (host not blocked)",
                ctx.now()
            );
        }
        // hipStreamSynchronize(stream)
        stream_synchronize(ctx, stream);
        // MPIX_Free_queue(queue): returns its counters to the NIC pool.
        queue.free(ctx).unwrap();
    })
    .expect("quickstart run failed");

    println!("\ndone in {} ns of virtual time", out.makespan);
    println!(
        "DWQ-triggered sends: {} | progress-thread emulated ops: {} | stream memops: {}",
        out.world.metrics.dwq_triggered,
        out.world.metrics.progress_ops,
        out.world.metrics.memops_executed
    );
}
