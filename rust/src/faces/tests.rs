//! Faces benchmark tests (Modeled compute; Real-compute correctness runs
//! live in rust/tests/ since they need the AOT artifacts).

use super::*;

fn zero_jitter(mut cfg: FacesConfig) -> FacesConfig {
    cfg.cost.jitter_sigma = 0.0;
    cfg
}

#[test]
fn baseline_1d_runs_and_exchanges() {
    let cfg = zero_jitter(FacesConfig::smoke(2, 1, (2, 1, 1)));
    let r = run_faces(&cfg).unwrap();
    assert!(r.time_ns > 0);
    // 2 ranks x 1 neighbor x 3 iterations = 6 messages, all inter-node.
    assert_eq!(r.metrics.eager_sends, 6);
    assert_eq!(r.metrics.intra_sends, 0);
    // 3 kernels per iteration per rank (+ none at init).
    assert_eq!(r.metrics.kernels_launched, 2 * 3 * 3);
}

#[test]
fn st_1d_uses_dwq_offload() {
    let mut cfg = zero_jitter(FacesConfig::smoke(2, 1, (2, 1, 1)));
    cfg.variant = Variant::StreamTriggered;
    let r = run_faces(&cfg).unwrap();
    assert_eq!(r.metrics.dwq_triggered, 6, "every inter-node ST send via DWQ");
    // Baseline syncs after pack each iteration; ST only drains at middle
    // end: exactly 2 ranks x 1 sync.
    assert_eq!(r.metrics.stream_syncs, 2);
}

#[test]
fn baseline_syncs_every_iteration() {
    let cfg = zero_jitter(FacesConfig::smoke(2, 1, (2, 1, 1)));
    let r = run_faces(&cfg).unwrap();
    // per rank: 3 inner syncs + 1 drain.
    assert_eq!(r.metrics.stream_syncs, 2 * (3 + 1));
}

#[test]
fn intra_node_st_runs_through_progress_thread() {
    let mut cfg = zero_jitter(FacesConfig::smoke(1, 2, (2, 1, 1)));
    cfg.variant = Variant::StreamTriggered;
    let r = run_faces(&cfg).unwrap();
    assert_eq!(r.metrics.dwq_triggered, 0);
    assert!(r.metrics.progress_ops >= 6, "intra ST sends emulated in software");
    assert_eq!(r.metrics.intra_sends, 6);
}

#[test]
fn dist_must_match_world_size() {
    let cfg = FacesConfig::smoke(2, 1, (4, 1, 1));
    assert!(run_faces(&cfg).is_err());
}

#[test]
fn three_d_has_seven_neighbors_per_rank() {
    let cfg = zero_jitter(FacesConfig::smoke(8, 1, (2, 2, 2)));
    let r = run_faces(&cfg).unwrap();
    // 8 ranks x 7 neighbors x 3 iters sends.
    let total = r.metrics.eager_sends + r.metrics.rendezvous_sends + r.metrics.intra_sends;
    assert_eq!(total, 8 * 7 * 3);
}

#[test]
fn deterministic_given_seed() {
    let cfg = zero_jitter(FacesConfig::smoke(2, 2, (4, 1, 1)));
    let a = run_faces(&cfg).unwrap();
    let b = run_faces(&cfg).unwrap();
    assert_eq!(a.time_ns, b.time_ns);
    assert_eq!(a.rank_time, b.rank_time);
}

#[test]
fn jitter_varies_by_seed() {
    let mut cfg = FacesConfig::smoke(2, 1, (2, 1, 1));
    cfg.cost.jitter_sigma = 0.05;
    let a = run_faces(&cfg).unwrap();
    cfg.seed = 999;
    let b = run_faces(&cfg).unwrap();
    assert_ne!(a.time_ns, b.time_ns, "different seeds must jitter timings");
}

#[test]
fn loop_counts_scale_messages() {
    let mut cfg = zero_jitter(FacesConfig::smoke(2, 1, (2, 1, 1)));
    cfg.outer = 2;
    cfg.middle = 2;
    cfg.inner = 2;
    let r = run_faces(&cfg).unwrap();
    assert_eq!(r.metrics.eager_sends, 2 * 2 * 2 * 2); // ranks x o x m x i
}

#[test]
fn shader_variant_beats_hip_variant_inter_node() {
    let mut cfg = zero_jitter(FacesConfig::smoke(8, 1, (2, 2, 2)));
    cfg.inner = 6;
    cfg.variant = Variant::StreamTriggered;
    let hip = run_faces(&cfg).unwrap();
    cfg.variant = Variant::StreamTriggeredShader;
    let shader = run_faces(&cfg).unwrap();
    assert!(
        shader.time_ns < hip.time_ns,
        "shader memops must win: {} vs {}",
        shader.time_ns,
        hip.time_ns
    );
}

#[test]
fn rank_time_is_positive_for_all_ranks() {
    let cfg = zero_jitter(FacesConfig::smoke(4, 2, (8, 1, 1)));
    let r = run_faces(&cfg).unwrap();
    assert_eq!(r.rank_time.len(), 8);
    assert!(r.rank_time.iter().all(|&t| t > 0));
    assert_eq!(r.time_ns, *r.rank_time.iter().max().unwrap());
}

#[test]
fn kt_1d_uses_dwq_offload_without_memops() {
    let mut cfg = zero_jitter(FacesConfig::smoke(2, 1, (2, 1, 1)));
    cfg.variant = Variant::KernelTriggered;
    let r = run_faces(&cfg).unwrap();
    assert_eq!(r.metrics.dwq_triggered, 6, "every inter-node KT send via DWQ");
    assert_eq!(r.metrics.kt_triggers, 6, "one mid-kernel trigger per iteration per rank");
    assert_eq!(r.metrics.memops_executed, 0, "KT executes no stream memops at all");
    // Like ST, KT only drains at middle end: 2 ranks x 1 sync.
    assert_eq!(r.metrics.stream_syncs, 2);
}

#[test]
fn kt_beats_st_inter_node() {
    let mut cfg = zero_jitter(FacesConfig::smoke(8, 1, (2, 2, 2)));
    cfg.inner = 6;
    cfg.variant = Variant::StreamTriggered;
    let st = run_faces(&cfg).unwrap();
    cfg.variant = Variant::KernelTriggered;
    let kt = run_faces(&cfg).unwrap();
    assert!(
        kt.time_ns <= st.time_ns,
        "KT must not be slower than ST: {} vs {}",
        kt.time_ns,
        st.time_ns
    );
}

#[test]
fn kt_intra_node_runs_through_progress_thread() {
    let mut cfg = zero_jitter(FacesConfig::smoke(1, 2, (2, 1, 1)));
    cfg.variant = Variant::KernelTriggered;
    let r = run_faces(&cfg).unwrap();
    assert_eq!(r.metrics.dwq_triggered, 0);
    assert_eq!(r.metrics.intra_sends, 6, "intra KT sends emulated in software");
    assert_eq!(r.metrics.kt_triggers, 6, "triggers still fire from inside kernels");
}
