//! The Faces microbenchmark (paper §V): nearest-neighbor halo exchange
//! from CORAL-2 Nekbone, in baseline (GPU-aware MPI) and stream-triggered
//! variants.
//!
//! Per inner iteration each rank (paper §V-A):
//!  1. pre-posts non-blocking receives from up to 26 neighbors
//!     (double-buffered, so iteration k+1's receives never race the
//!     in-flight unpack of iteration k);
//!  2. launches the pack kernel (surface -> contiguous MPI buffers);
//!  3. initiates sends to all neighbors
//!     — **baseline**: `hipStreamSynchronize` then `MPI_Isend` per
//!       neighbor + `MPI_Waitall` on the sends (host drives the control
//!       path; Fig 1);
//!     — **ST**: `MPIX_Enqueue_send` per neighbor + one
//!       `MPIX_Enqueue_start`; the GPU CP triggers the NIC after pack
//!       completes in stream order, and `MPIX_Enqueue_wait` replaces the
//!       host-side send waitall (Fig 2);
//!     — **KT**: the trigger fires from *inside* the last pack kernel
//!       and the completion wait rides the next iteration's pack
//!       prologue — no stream memory ops at all (the follow-on design
//!       of arXiv 2306.15773);
//!     — **GI**: the last pack kernel builds the neighbor sends as
//!       command-ring descriptors itself; the NIC drains the ring with
//!       no trigger counters and no pre-armed DWQ slots (the
//!       GPU-initiated design of arXiv 2503.24230);
//!
//! All three send protocols run through one per-rank
//! [`stx::CommPlan`] built once before the timed region (`iteration` in
//! this module) — the loop body contains no enqueue calls.
//!  4. launches the interior spectral-element kernel (overlapped with
//!     communication);
//!  5. waits for the receives;
//!  6. launches the unpack-add kernel.
//!
//! Loop nest: outer (buffer alloc) x middle (field re-init) x inner
//! (timed communication steps). Correctness is checked against the
//! sequential CPU reference ([`reference::exchange_reference`]), exactly
//! as the paper's Faces does.

pub mod domain;
pub mod figures;
pub mod reference;

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::{lease_world, run_cluster, stash_world};
use crate::costmodel::CostModel;
use crate::gpu::{self, host_enqueue, stream_synchronize, KernelPayload, KernelSpec, StreamOp};
use crate::mpi::{self, SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::runtime::Runtime;
use crate::sim::{HostCtx, SimStats};
use crate::stx;
use crate::world::{BufId, ComputeMode, Metrics, Topology, World};

use domain::{region_of, ProcGrid, Region};
use reference::Q;

/// Which Faces implementation to run (paper §V-B, §V-F, plus the KT
/// follow-on): the crate-wide communication-variant axis, defined in
/// [`crate::stx`].
pub use crate::stx::Variant;

/// Full configuration of one Faces run.
#[derive(Debug, Clone)]
pub struct FacesConfig {
    /// Process distribution (px, py, pz); px*py*pz == nodes*rpn.
    pub dist: (usize, usize, usize),
    pub nodes: usize,
    pub ranks_per_node: usize,
    /// Local block edge in grid points (multiple of Q=8 for Real compute).
    pub g: usize,
    pub outer: usize,
    pub middle: usize,
    pub inner: usize,
    pub variant: Variant,
    pub compute: ComputeMode,
    /// Verify final fields against the CPU reference (Real compute only).
    pub check: bool,
    pub seed: u64,
    pub cost: CostModel,
    /// Fault-injection plan for this run (`None` = no chaos; see
    /// [`crate::fault`]). The decision stream is keyed by a fingerprint
    /// of the run parameters, so chaos runs replay byte-identically.
    pub faults: Option<crate::fault::FaultSpec>,
}

impl FacesConfig {
    /// Small smoke configuration used by tests.
    pub fn smoke(nodes: usize, rpn: usize, dist: (usize, usize, usize)) -> Self {
        Self {
            dist,
            nodes,
            ranks_per_node: rpn,
            g: 16,
            outer: 1,
            middle: 1,
            inner: 3,
            variant: Variant::Host,
            compute: ComputeMode::Modeled,
            check: false,
            seed: 1,
            cost: crate::costmodel::presets::frontier_like(),
            faults: None,
        }
    }

    pub fn world_size(&self) -> usize {
        self.nodes * self.ranks_per_node
    }
}

/// Outcome of one Faces run.
#[derive(Debug)]
pub struct FacesResult {
    /// Accumulated inner-loop wall time per rank (virtual ns).
    pub rank_time: Vec<u64>,
    /// The reported figure-of-merit: max over ranks (the paper's overall
    /// execution time of the timed region).
    pub time_ns: u64,
    pub metrics: Metrics,
    /// Engine statistics of the run (event/microtask/host-switch counts);
    /// byte-identical across runs with the same config+seed — the
    /// determinism tests assert on this.
    pub stats: SimStats,
    /// Max relative error vs the CPU reference when checking was enabled
    /// (max |field - reference| / max |reference| over ranks).
    pub max_err: Option<f32>,
    /// Achieved communication/computation overlap from the run's trace
    /// (`None` when tracing is off — `STMPI_TRACE=0`).
    pub overlap: Option<crate::obs::Overlap>,
    /// Critical-path attribution for the last-finishing rank (`None`
    /// when tracing is off).
    pub crit: Option<crate::obs::CritPath>,
    /// The raw event trace, for Chrome-trace export.
    pub trace: Option<crate::obs::TraceBuf>,
}

impl FacesResult {
    pub fn time_s(&self) -> f64 {
        self.time_ns as f64 / 1e9
    }
}

/// One neighbor's message schedule for a rank.
#[derive(Debug, Clone)]
struct MsgPlan {
    nbr: usize,
    tag_send: i32,
    tag_recv: i32,
    /// Where the outgoing payload lives in the packed buffers.
    send: BufSlice,
    /// Where the incoming payload lands, per receive-buffer parity.
    recv: [BufSlice; 2],
}

/// Per-rank execution plan: buffers + message schedule.
#[derive(Debug, Clone)]
struct RankPlan {
    /// The shared QxQ derivative matrix (runtime argument to faces_ax —
    /// xla_extension 0.5.1 miscompiles it if baked as a constant).
    d: BufId,
    u: BufId,
    w: BufId,
    pf: BufId,
    pe: BufId,
    pc: BufId,
    rf: [BufId; 2],
    re: [BufId; 2],
    rc: [BufId; 2],
    msgs: Vec<MsgPlan>,
}

fn build_plans(w: &mut World, grid: &ProcGrid, g: usize) -> Vec<RankPlan> {
    let g3 = g * g * g;
    let d = w.bufs.alloc_init(reference::deriv_matrix(Q));
    (0..grid.size())
        .map(|rank| {
            let u = w.alloc_device(g3);
            let ww = w.alloc_device(g3);
            let pf = w.alloc_device(6 * g * g);
            let pe = w.alloc_device(12 * g);
            let pc = w.alloc_device(8);
            let rf = [w.alloc_device(6 * g * g), w.alloc_device(6 * g * g)];
            let re = [w.alloc_device(12 * g), w.alloc_device(12 * g)];
            let rc = [w.alloc_device(8), w.alloc_device(8)];
            let msgs = grid
                .neighbors(rank)
                .into_iter()
                .map(|(d, nbr)| {
                    let mine = region_of(d);
                    let send_buf = match mine {
                        Region::Face(_) => pf,
                        Region::Edge(_) => pe,
                        Region::Corner(_) => pc,
                    };
                    let recv_bufs = match mine {
                        Region::Face(_) => rf,
                        Region::Edge(_) => re,
                        Region::Corner(_) => rc,
                    };
                    MsgPlan {
                        nbr,
                        // We send toward d; the receiver matches on the
                        // direction as computed from *its* side (-d).
                        tag_send: d.tag(),
                        tag_recv: d.opposite().tag(),
                        send: BufSlice::new(send_buf, mine.offset(g), mine.elems(g)),
                        recv: [
                            BufSlice::new(recv_bufs[0], mine.offset(g), mine.elems(g)),
                            BufSlice::new(recv_bufs[1], mine.offset(g), mine.elems(g)),
                        ],
                    }
                })
                .collect();
            RankPlan { d, u, w: ww, pf, pe, pc, rf, re, rc, msgs }
        })
        .collect()
}

// --------------------------------------------------------------------
// Kernel construction
// --------------------------------------------------------------------

fn ax_flops(g: usize) -> u64 {
    let e = (g / Q).pow(3) as u64;
    e * 12 * (Q as u64).pow(4)
}

/// The pack phase launches ONE KERNEL PER NEIGHBOR REGION, like the real
/// Faces ("launch kernels to copy into contiguous MPI buffers from faces,
/// edges, and corners", §V-A — plural). For Real compute the first kernel
/// carries the fused HLO payload (numerics of all regions at once); the
/// rest model the per-region launch + copy cost.
fn pack_kernels(plan: &RankPlan, g: usize, real: bool) -> Vec<KernelSpec> {
    plan.msgs
        .iter()
        .enumerate()
        .map(|(i, m)| KernelSpec {
            name: format!("faces_pack[{i}]"),
            flops: 0,
            bytes: 2 * 4 * m.send.elems as u64,
            payload: if real && i == 0 {
                KernelPayload::Hlo {
                    entry: format!("faces_pack_g{g}"),
                    inputs: vec![plan.u],
                    outputs: vec![plan.pf, plan.pe, plan.pc],
                }
            } else {
                KernelPayload::None
            },
        })
        .collect()
}

fn ax_kernel(plan: &RankPlan, g: usize, real: bool) -> KernelSpec {
    KernelSpec {
        name: "faces_ax".into(),
        flops: ax_flops(g),
        bytes: 2 * 4 * (g * g * g) as u64,
        payload: if real {
            KernelPayload::Hlo {
                entry: format!("faces_ax_g{g}"),
                inputs: vec![plan.u, plan.d],
                outputs: vec![plan.w],
            }
        } else {
            KernelPayload::None
        },
    }
}

/// Unpack likewise launches one add-kernel per received region ("launch
/// kernels to add the received messages", §V-A); the first carries the
/// fused HLO payload.
fn unpack_kernels(plan: &RankPlan, g: usize, parity: usize, real: bool) -> Vec<KernelSpec> {
    plan.msgs
        .iter()
        .enumerate()
        .map(|(i, m)| KernelSpec {
            name: format!("faces_unpack[{i}]"),
            flops: m.recv[parity].elems as u64,
            bytes: 3 * 4 * m.recv[parity].elems as u64,
            payload: if real && i == 0 {
                KernelPayload::Hlo {
                    entry: format!("faces_unpack_g{g}"),
                    inputs: vec![plan.w, plan.rf[parity], plan.re[parity], plan.rc[parity]],
                    outputs: vec![plan.u],
                }
            } else {
                KernelPayload::None
            },
        })
        .collect()
}

// --------------------------------------------------------------------
// The benchmark driver
// --------------------------------------------------------------------

/// Run one Faces configuration to completion.
pub fn run_faces(cfg: &FacesConfig) -> Result<FacesResult> {
    let (px, py, pz) = cfg.dist;
    let grid = ProcGrid::new(px, py, pz);
    if grid.size() != cfg.world_size() {
        bail!(
            "distribution {px}x{py}x{pz} ({} ranks) != nodes*rpn ({})",
            grid.size(),
            cfg.world_size()
        );
    }
    let real = cfg.compute == ComputeMode::Real;
    if real && cfg.g % Q != 0 {
        bail!("grid edge {} must be a multiple of Q={Q} for Real compute", cfg.g);
    }

    let topo = Topology::new(cfg.nodes, cfg.ranks_per_node);
    // World-reuse key (see `coordinator::lease_world`): everything that
    // shapes world structure or lease-time setup — the grid edge decides
    // buffer sizes, the compute mode decides whether a Runtime is loaded.
    // Seed and faults are per-run state, reinstalled below on every lease.
    let reuse = format!(
        "faces/{}/{}x{}/g{}/{:?}/{:?}",
        cfg.variant.name(),
        cfg.nodes,
        cfg.ranks_per_node,
        cfg.g,
        cfg.compute,
        cfg.cost
    );
    let mut world = lease_world(&reuse, cfg.cost.clone(), topo);
    world.compute = cfg.compute;
    if real {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let rt = Runtime::load(&dir).context("loading AOT artifacts for Real compute")?;
        for entry in ["faces_pack", "faces_ax", "faces_unpack"] {
            let name = format!("{entry}_g{}", cfg.g);
            if !rt.has_entry(&name) {
                bail!("artifact '{name}' not found; add G={} to aot.py FACES_GRIDS", cfg.g);
            }
        }
        world.runtime = Some(Arc::new(rt));
    }

    if let Some(spec) = &cfg.faults {
        let label = format!(
            "faces/{}/{}x{}/g{}/s{}",
            cfg.variant.name(),
            cfg.nodes,
            cfg.ranks_per_node,
            cfg.g,
            cfg.seed
        );
        let fp = crate::fault::fingerprint(spec.seed, &label);
        world.fault = Some(crate::fault::FaultState::new(crate::fault::FaultPlan::new(
            spec.clone(),
            fp,
            grid.size(),
        )));
    }

    let plans = Arc::new(build_plans(&mut world, &grid, cfg.g));
    let times: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(vec![0; grid.size()]));

    let cfg2 = cfg.clone();
    let plans2 = plans.clone();
    let times2 = times.clone();
    // `context` (not a reformatting anyhow!) so callers — the campaign's
    // stalled-cell aggregation in particular — can still downcast to the
    // engine's `SimError` and pull the structured StallReport out.
    let mut out = run_cluster(world, cfg.seed, move |rank, ctx| {
        rank_program(&cfg2, &plans2[rank], rank, ctx, &times2);
    })
    .context("faces run failed")?;

    let rank_time = times.lock().unwrap().clone();
    let time_ns = rank_time.iter().copied().max().unwrap_or(0);

    let max_err = if cfg.check && real {
        // Relative error: the ax+add iteration grows field magnitudes
        // geometrically, so absolute tolerances are meaningless after a
        // few steps.
        let reference = reference::exchange_reference(&grid, cfg.g, cfg.inner);
        let mut err = 0.0f32;
        for r in 0..grid.size() {
            let got = out.world.bufs.get(plans[r].u);
            let scale = reference[r]
                .iter()
                .fold(0.0f32, |m, x| m.max(x.abs()))
                .max(1e-12);
            err = err.max(reference::max_abs_diff(got, &reference[r]) / scale);
        }
        Some(err)
    } else {
        None
    };

    let a = out.take_analytics();
    let result = FacesResult {
        rank_time,
        time_ns,
        metrics: out.world.metrics.clone(),
        stats: out.stats,
        max_err,
        overlap: a.overlap,
        crit: a.crit,
        trace: a.trace,
    };
    // Clean runs park the world for the next same-shape cell; error paths
    // return early above, so a stalled world is dropped, never pooled.
    stash_world(&reuse, out.world);
    Ok(result)
}

/// The per-rank host program (what the application process runs).
fn rank_program(
    cfg: &FacesConfig,
    plan: &RankPlan,
    rank: usize,
    ctx: &mut HostCtx<World>,
    times: &Arc<Mutex<Vec<u64>>>,
) {
    let real = cfg.compute == ComputeMode::Real;
    let g = cfg.g;
    // Stream + (for queue-using variants) queue setup, then the
    // build-once communication plan — all outside the timed region. The
    // plan records every neighbor send plus the double-buffered posted
    // receives; iterations only re-arm it.
    let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
    let queues: Vec<stx::Queue> = if cfg.variant.uses_queue() {
        vec![stx::Queue::create(ctx, rank, sid, cfg.variant).expect("NIC counter pool exhausted")]
    } else {
        Vec::new()
    };
    let mut b = stx::CommPlan::builder(rank, sid, cfg.variant, &queues);
    for m in &plan.msgs {
        b.send(m.nbr, m.send, m.tag_send, COMM_WORLD);
        b.recv_db(SrcSel::Rank(m.nbr), TagSel::Tag(m.tag_recv), COMM_WORLD, m.recv);
    }
    let cplan = b.build(ctx).expect("faces plan build");

    let mut acc: u64 = 0;
    for _outer in 0..cfg.outer {
        // Outer loop: "allocate MPI buffers" — modeled as a fixed host
        // cost (allocation is not on the timed path).
        ctx.advance(20_000);
        for _middle in 0..cfg.middle {
            // Field (re-)initialization.
            let (u, w_, rf, re, rc) = (plan.u, plan.w, plan.rf, plan.re, plan.rc);
            ctx.with(move |w, _| {
                if w.is_real() {
                    *w.bufs.get_mut(u) = reference::init_field(rank, g);
                    w.bufs.get_mut(w_).fill(0.0);
                    for p in 0..2 {
                        w.bufs.get_mut(rf[p]).fill(0.0);
                        w.bufs.get_mut(re[p]).fill(0.0);
                        w.bufs.get_mut(rc[p]).fill(0.0);
                    }
                }
            });
            ctx.advance(30_000); // init kernel cost (untimed region)

            let t0 = ctx.now();
            for inner in 0..cfg.inner {
                iteration(cfg, plan, ctx, sid, &cplan, inner % 2, real);
            }
            // Drain the device before stopping the clock (every variant
            // ends the timed region fully synchronized). KT and GI
            // additionally drain their send completions here — ST
            // already waited for them via the stream wait — so the
            // figures of merit compare like for like.
            if matches!(cfg.variant, Variant::KernelTriggered | Variant::GpuInitiated) {
                cplan.drain(ctx).expect("KT/GI queue drain");
            }
            stream_synchronize(ctx, sid);
            acc += ctx.now() - t0;
        }
    }
    for q in queues {
        q.free(ctx).expect("ST queue must be idle at teardown");
    }
    times.lock().unwrap()[rank] = acc;
}

/// One Faces iteration, all variants: the plan's round carries the
/// per-variant send protocol —
///
/// * **baseline**: pack kernels, `hipStreamSynchronize`, `MPI_Isend` per
///   neighbor (Fig 1); the send waitall runs after the receive waitall.
/// * **ST**: pack kernels, deferred sends + one CP trigger; the *stream*
///   waits for completion after the interior compute is enqueued
///   (Fig 2).
/// * **KT** (arXiv 2306.15773): the trigger fires from *inside* the last
///   pack kernel ([`stx::KT_TRIGGER_FRAC`] of its window) and the
///   completion wait for the previous iteration's sends rides the first
///   pack kernel's prologue — no `writeValue64`, no `waitValue64`, no
///   stream stall between operations.
/// * **GI** (arXiv 2503.24230): like KT for waits, but the last pack
///   kernel *builds* the neighbor-send descriptors into its
///   per-thread-block command ring (`cost.gi_descr_build_ns` per
///   descriptor, one per [`crate::gpu::GI_CHUNK_BYTES`] of payload) and
///   the NIC consumes them directly — no trigger counters, no DWQ
///   slots.
fn iteration(
    cfg: &FacesConfig,
    plan: &RankPlan,
    ctx: &mut HostCtx<World>,
    sid: gpu::StreamId,
    cplan: &stx::CommPlan,
    parity: usize,
    real: bool,
) {
    // 1. Pre-post receives (standard MPI_Irecv + double buffering — the
    //    paper's deliberate choice while the NIC lacks triggered
    //    receives, §V-B).
    let rreqs = cplan.post_recvs(ctx, parity);
    // 2+3. Pack kernels (one per region) + this iteration's sends, under
    //      the plan's variant protocol.
    let round = cplan.round(ctx, pack_kernels(plan, cfg.g, real)).expect("faces round");
    // 4. Interior compute (overlaps communication in every variant).
    host_enqueue(ctx, sid, StreamOp::Kernel(ax_kernel(plan, cfg.g, real)));
    // ST's completion wait is enqueued here — after the ax kernel, so
    // the stream overlaps compute with the triggered sends, and the
    // packed buffers are protected from the next iteration's pack. KT's
    // complete is a no-op (completion rides the next pack prologue).
    let round = match cfg.variant {
        Variant::Host => Some(round),
        _ => {
            cplan.complete(ctx, round).expect("faces send completion");
            None
        }
    };
    // 5. Wait for receives on the host; the baseline then performs its
    //    host-side send waitall (Fig 1's control path).
    mpi::waitall(ctx, &rreqs);
    if let Some(r) = round {
        cplan.complete(ctx, r).expect("faces host send waitall");
    }
    // 6. Unpack-add of received contributions (one kernel per region).
    for k in unpack_kernels(plan, cfg.g, parity, real) {
        host_enqueue(ctx, sid, StreamOp::Kernel(k));
    }
}

#[cfg(test)]
mod tests;
