//! CPU reference for Faces — the same math as python's `ref.py`, in rust.
//!
//! The Faces benchmark "confirms correct results by comparing against a
//! reference CPU-only implementation" (paper §V-A); this module is that
//! reference. It is also used by the runtime integration tests to check
//! the AOT artifacts' numerics end-to-end.

use super::domain::{region_of, ProcGrid, Region};

pub const Q: usize = 8;

/// The fixed QxQ 'derivative' matrix; must match ref.py::deriv_matrix.
pub fn deriv_matrix(q: usize) -> Vec<f32> {
    let mut d = vec![0.0f32; q * q];
    for a in 0..q {
        for m in 0..q {
            let modv = ((a as i64 - m as i64).rem_euclid(q as i64)) as f32;
            d[a * q + m] = (modv - (q as f32 - 1.0) / 2.0) / q as f32;
        }
    }
    d
}

#[inline]
fn idx(g: usize, x: usize, y: usize, z: usize) -> usize {
    (x * g + y) * g + z
}

/// Extract faces/edges/corners of a [G,G,G] block (layout as in ref.py).
pub fn pack_ref(u: &[f32], g: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(u.len(), g * g * g);
    let m = g - 1;
    let mut faces = vec![0.0f32; 6 * g * g];
    let mut edges = vec![0.0f32; 12 * g];
    let mut corners = vec![0.0f32; 8];
    for a in 0..g {
        for b in 0..g {
            faces[0 * g * g + a * g + b] = u[idx(g, 0, a, b)];
            faces[1 * g * g + a * g + b] = u[idx(g, m, a, b)];
            faces[2 * g * g + a * g + b] = u[idx(g, a, 0, b)];
            faces[3 * g * g + a * g + b] = u[idx(g, a, m, b)];
            faces[4 * g * g + a * g + b] = u[idx(g, a, b, 0)];
            faces[5 * g * g + a * g + b] = u[idx(g, a, b, m)];
        }
    }
    for a in 0..g {
        edges[0 * g + a] = u[idx(g, 0, 0, a)];
        edges[1 * g + a] = u[idx(g, 0, m, a)];
        edges[2 * g + a] = u[idx(g, m, 0, a)];
        edges[3 * g + a] = u[idx(g, m, m, a)];
        edges[4 * g + a] = u[idx(g, 0, a, 0)];
        edges[5 * g + a] = u[idx(g, 0, a, m)];
        edges[6 * g + a] = u[idx(g, m, a, 0)];
        edges[7 * g + a] = u[idx(g, m, a, m)];
        edges[8 * g + a] = u[idx(g, a, 0, 0)];
        edges[9 * g + a] = u[idx(g, a, 0, m)];
        edges[10 * g + a] = u[idx(g, a, m, 0)];
        edges[11 * g + a] = u[idx(g, a, m, m)];
    }
    corners[0] = u[idx(g, 0, 0, 0)];
    corners[1] = u[idx(g, 0, 0, m)];
    corners[2] = u[idx(g, 0, m, 0)];
    corners[3] = u[idx(g, 0, m, m)];
    corners[4] = u[idx(g, m, 0, 0)];
    corners[5] = u[idx(g, m, 0, m)];
    corners[6] = u[idx(g, m, m, 0)];
    corners[7] = u[idx(g, m, m, m)];
    (faces, edges, corners)
}

/// Add boundary contributions into the block surface (mirror of pack).
pub fn unpack_add_ref(u: &[f32], g: usize, faces: &[f32], edges: &[f32], corners: &[f32]) -> Vec<f32> {
    let mut out = u.to_vec();
    let m = g - 1;
    for a in 0..g {
        for b in 0..g {
            out[idx(g, 0, a, b)] += faces[0 * g * g + a * g + b];
            out[idx(g, m, a, b)] += faces[1 * g * g + a * g + b];
            out[idx(g, a, 0, b)] += faces[2 * g * g + a * g + b];
            out[idx(g, a, m, b)] += faces[3 * g * g + a * g + b];
            out[idx(g, a, b, 0)] += faces[4 * g * g + a * g + b];
            out[idx(g, a, b, m)] += faces[5 * g * g + a * g + b];
        }
    }
    for a in 0..g {
        out[idx(g, 0, 0, a)] += edges[0 * g + a];
        out[idx(g, 0, m, a)] += edges[1 * g + a];
        out[idx(g, m, 0, a)] += edges[2 * g + a];
        out[idx(g, m, m, a)] += edges[3 * g + a];
        out[idx(g, 0, a, 0)] += edges[4 * g + a];
        out[idx(g, 0, a, m)] += edges[5 * g + a];
        out[idx(g, m, a, 0)] += edges[6 * g + a];
        out[idx(g, m, a, m)] += edges[7 * g + a];
        out[idx(g, a, 0, 0)] += edges[8 * g + a];
        out[idx(g, a, 0, m)] += edges[9 * g + a];
        out[idx(g, a, m, 0)] += edges[10 * g + a];
        out[idx(g, a, m, m)] += edges[11 * g + a];
    }
    out[idx(g, 0, 0, 0)] += corners[0];
    out[idx(g, 0, 0, m)] += corners[1];
    out[idx(g, 0, m, 0)] += corners[2];
    out[idx(g, 0, m, m)] += corners[3];
    out[idx(g, m, 0, 0)] += corners[4];
    out[idx(g, m, 0, m)] += corners[5];
    out[idx(g, m, m, 0)] += corners[6];
    out[idx(g, m, m, m)] += corners[7];
    out
}

/// Spectral operator on the element view [E,Q,Q,Q].
pub fn ax_elements_ref(u: &[f32], e: usize, q: usize) -> Vec<f32> {
    let d = deriv_matrix(q);
    let q3 = q * q * q;
    let at = |el: usize, a: usize, b: usize, c: usize| el * q3 + (a * q + b) * q + c;
    let mut ur = vec![0.0f32; u.len()];
    let mut us = vec![0.0f32; u.len()];
    let mut ut = vec![0.0f32; u.len()];
    for el in 0..e {
        for a in 0..q {
            for b in 0..q {
                for c in 0..q {
                    let (mut sr, mut ss, mut st) = (0.0f32, 0.0, 0.0);
                    for m in 0..q {
                        sr += d[a * q + m] * u[at(el, m, b, c)];
                        ss += d[b * q + m] * u[at(el, a, m, c)];
                        st += d[c * q + m] * u[at(el, a, b, m)];
                    }
                    ur[at(el, a, b, c)] = sr;
                    us[at(el, a, b, c)] = ss;
                    ut[at(el, a, b, c)] = st;
                }
            }
        }
    }
    let mut w = vec![0.0f32; u.len()];
    for el in 0..e {
        for a in 0..q {
            for b in 0..q {
                for c in 0..q {
                    let mut s = 0.0f32;
                    for m in 0..q {
                        s += d[m * q + a] * ur[at(el, m, b, c)];
                        s += d[m * q + b] * us[at(el, a, m, c)];
                        s += d[m * q + c] * ut[at(el, a, b, m)];
                    }
                    w[at(el, a, b, c)] = s;
                }
            }
        }
    }
    w
}

/// Spectral operator on the grid view [G,G,G] (reshape to elements and
/// back exactly as model.py::faces_ax does).
pub fn ax_grid_ref(u: &[f32], g: usize) -> Vec<f32> {
    assert_eq!(u.len(), g * g * g);
    assert_eq!(g % Q, 0, "grid must be a multiple of Q={Q}");
    let n = g / Q;
    let e = n * n * n;
    // grid -> elements
    let mut ue = vec![0.0f32; u.len()];
    for ex in 0..n {
        for ey in 0..n {
            for ez in 0..n {
                let el = (ex * n + ey) * n + ez;
                for a in 0..Q {
                    for b in 0..Q {
                        for c in 0..Q {
                            ue[el * Q * Q * Q + (a * Q + b) * Q + c] =
                                u[idx(g, ex * Q + a, ey * Q + b, ez * Q + c)];
                        }
                    }
                }
            }
        }
    }
    let we = ax_elements_ref(&ue, e, Q);
    // elements -> grid
    let mut w = vec![0.0f32; u.len()];
    for ex in 0..n {
        for ey in 0..n {
            for ez in 0..n {
                let el = (ex * n + ey) * n + ez;
                for a in 0..Q {
                    for b in 0..Q {
                        for c in 0..Q {
                            w[idx(g, ex * Q + a, ey * Q + b, ez * Q + c)] =
                                we[el * Q * Q * Q + (a * Q + b) * Q + c];
                        }
                    }
                }
            }
        }
    }
    w
}

/// Deterministic per-rank initial field, shared with the benchmark.
pub fn init_field(rank: usize, g: usize) -> Vec<f32> {
    let n = g * g * g;
    (0..n)
        .map(|i| {
            let v = ((i as u64).wrapping_mul(2654435761).wrapping_add(rank as u64 * 97)) % 1024;
            (v as f32) / 1024.0 - 0.5
        })
        .collect()
}

/// Sequential whole-cluster reference: run `iters` Faces iterations over
/// every rank's block and return the final fields.
///
/// One iteration (identical to the distributed benchmark):
///   p_r = pack(u_r); w_r = ax(u_r);
///   u'_r = unpack_add(w_r, sum of neighbor contributions into the
///          facing regions; absent neighbors contribute zero).
pub fn exchange_reference(grid: &ProcGrid, g: usize, iters: usize) -> Vec<Vec<f32>> {
    let nranks = grid.size();
    let mut u: Vec<Vec<f32>> = (0..nranks).map(|r| init_field(r, g)).collect();
    for _ in 0..iters {
        let packs: Vec<_> = u.iter().map(|f| pack_ref(f, g)).collect();
        let axs: Vec<_> = u.iter().map(|f| ax_grid_ref(f, g)).collect();
        let mut next = Vec::with_capacity(nranks);
        for r in 0..nranks {
            // Assemble this rank's incoming boundary buffers.
            let mut rf = vec![0.0f32; 6 * g * g];
            let mut re = vec![0.0f32; 12 * g];
            let mut rc = vec![0.0f32; 8];
            for (d, nb) in grid.neighbors(r) {
                // Neighbor nb sends its region facing us: region_of(-d).
                let their = region_of(d.opposite());
                let mine = region_of(d);
                let elems = mine.elems(g);
                let (pf, pe, pc) = &packs[nb];
                let src: &[f32] = match their {
                    Region::Face(_) => pf,
                    Region::Edge(_) => pe,
                    Region::Corner(_) => pc,
                };
                let dst: &mut [f32] = match mine {
                    Region::Face(_) => &mut rf,
                    Region::Edge(_) => &mut re,
                    Region::Corner(_) => &mut rc,
                };
                let so = their.offset(g);
                let do_ = mine.offset(g);
                dst[do_..do_ + elems].copy_from_slice(&src[so..so + elems]);
            }
            next.push(unpack_add_ref(&axs[r], g, &rf, &re, &rc));
        }
        u = next;
    }
    u
}

/// Max |a-b| over two fields.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deriv_matrix_matches_python_formula() {
        let d = deriv_matrix(8);
        // d[a,m] = ((a - m) mod q - (q-1)/2) / q
        assert_eq!(d[0], (0.0 - 3.5) / 8.0);
        assert_eq!(d[1], (7.0 - 3.5) / 8.0); // a=0, m=1 -> (-1) mod 8 = 7
        assert_eq!(d[8], (1.0 - 3.5) / 8.0); // a=1, m=0
    }

    #[test]
    fn pack_unpack_roundtrip_multiplicities() {
        let g = 16;
        let u = vec![1.0f32; g * g * g];
        let (f, e, c) = pack_ref(&u, g);
        let out = unpack_add_ref(&u, g, &f, &e, &c);
        let mid = g / 2;
        assert_eq!(out[idx(g, mid, mid, mid)], 1.0); // interior untouched
        assert_eq!(out[idx(g, 0, mid, mid)], 2.0); // face
        assert_eq!(out[idx(g, 0, 0, mid)], 4.0); // edge: 2 faces + edge
        assert_eq!(out[idx(g, 0, 0, 0)], 8.0); // corner: 3f + 3e + c
    }

    #[test]
    fn ax_zero_is_zero() {
        let w = ax_elements_ref(&vec![0.0; 512], 1, 8);
        assert!(w.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn ax_linearity() {
        let g = 8; // one element
        let u: Vec<f32> = (0..512).map(|i| (i % 13) as f32 / 13.0).collect();
        let two_u: Vec<f32> = u.iter().map(|x| 2.0 * x).collect();
        let w1 = ax_grid_ref(&u, g);
        let w2 = ax_grid_ref(&two_u, g);
        for (a, b) in w1.iter().zip(&w2) {
            assert!((2.0 * a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn exchange_reference_isolated_rank_is_pure_ax() {
        let grid = ProcGrid::new(1, 1, 1);
        let g = 8;
        let u0 = init_field(0, g);
        let want = ax_grid_ref(&u0, g);
        let got = exchange_reference(&grid, g, 1);
        assert_eq!(got[0], want, "no neighbors => unpack adds zeros");
    }

    #[test]
    fn exchange_reference_two_ranks_share_faces() {
        let grid = ProcGrid::new(2, 1, 1);
        let g = 8;
        let got = exchange_reference(&grid, g, 1);
        // Rank 0's +x face must include rank 1's -x pack contribution.
        let u1 = init_field(1, g);
        let w0 = ax_grid_ref(&init_field(0, g), g);
        let m = g - 1;
        let expect = w0[idx(g, m, 3, 4)] + u1[idx(g, 0, 3, 4)];
        assert!((got[0][idx(g, m, 3, 4)] - expect).abs() < 1e-5);
        // And its -x face has no neighbor: pure ax result.
        assert_eq!(got[0][idx(g, 0, 3, 4)], w0[idx(g, 0, 3, 4)]);
    }

    #[test]
    fn init_field_is_deterministic_and_rank_dependent() {
        assert_eq!(init_field(3, 8), init_field(3, 8));
        assert_ne!(init_field(3, 8), init_field(4, 8));
    }
}
