//! Figure harness: regenerates every evaluation figure of the paper.
//!
//! Each figure is a [`FigureSpec`] naming the topology, distribution, and
//! variants; [`run_figure`] executes every variant over several seeds
//! (the paper averages 5 runs, §V-B) with the jittered cost preset and
//! produces the paper-style avg/min/max rows plus the baseline-relative
//! deltas. The benches under `benches/` are thin wrappers that print
//! these reports; `examples/faces_sweep.rs` runs them all, plus the
//! ST-vs-KT message-size sweep ([`run_kt_compare`]) and the KT-vs-GI
//! crossover sweep ([`run_gi_compare`], the `figgi` artifact).

use crate::coordinator::report::{pct_delta, render_table, Summary};
use crate::costmodel::presets;
use crate::sim::sweep;
use crate::world::ComputeMode;

use super::{run_faces, FacesConfig, Variant};

/// One evaluation figure from the paper.
#[derive(Debug, Clone)]
pub struct FigureSpec {
    pub id: &'static str,
    pub title: &'static str,
    pub nodes: usize,
    pub ranks_per_node: usize,
    pub dist: (usize, usize, usize),
    pub variants: &'static [Variant],
    /// The relation the paper reports (documented expectation; asserted
    /// by tests and printed with the report).
    pub paper_result: &'static str,
}

/// Loop counts for figure runs. The paper's 10x100x100 nest repeats an
/// identical (deterministic, in virtual time) iteration; we default to
/// a smaller nest that produces the same per-iteration averages.
#[derive(Debug, Clone, Copy)]
pub struct Loops {
    pub outer: usize,
    pub middle: usize,
    pub inner: usize,
}

impl Default for Loops {
    fn default() -> Self {
        Self { outer: 1, middle: 2, inner: 25 }
    }
}

pub fn fig8() -> FigureSpec {
    FigureSpec {
        id: "fig8",
        title: "Faces 64x1x1, 8 nodes x 8 ranks/node",
        nodes: 8,
        ranks_per_node: 8,
        dist: (64, 1, 1),
        variants: &[Variant::Host, Variant::StreamTriggered],
        paper_result: "ST ~10% slower (progress-thread emulation dominates intra-node)",
    }
}

pub fn fig9() -> FigureSpec {
    FigureSpec {
        id: "fig9",
        title: "Faces 8x1x1, 1 node x 8 ranks",
        nodes: 1,
        ranks_per_node: 8,
        dist: (8, 1, 1),
        variants: &[Variant::Host, Variant::StreamTriggered],
        paper_result: "ST ~4% slower (pure intra-node, progress-thread emulation)",
    }
}

pub fn fig10() -> FigureSpec {
    FigureSpec {
        id: "fig10",
        title: "Faces 8x1x1, 8 nodes x 1 rank/node",
        nodes: 8,
        ranks_per_node: 1,
        dist: (8, 1, 1),
        variants: &[Variant::Host, Variant::StreamTriggered],
        paper_result: "ST ~parity with baseline (pure inter-node, NIC offload)",
    }
}

pub fn fig11() -> FigureSpec {
    FigureSpec {
        id: "fig11",
        title: "Faces 2x2x2, 8 nodes x 1 rank/node",
        nodes: 8,
        ranks_per_node: 1,
        dist: (2, 2, 2),
        variants: &[Variant::Host, Variant::StreamTriggered],
        paper_result: "ST ~4% faster (NIC offload wins at higher message fan-out)",
    }
}

pub fn fig12() -> FigureSpec {
    FigureSpec {
        id: "fig12",
        title: "Faces 2x2x2, 8 nodes x 1 rank/node, memop flavors",
        nodes: 8,
        ranks_per_node: 1,
        dist: (2, 2, 2),
        variants: &[Variant::Host, Variant::StreamTriggered, Variant::StreamTriggeredShader],
        paper_result: "ST-shader ~8% faster than baseline (tuned stream memops)",
    }
}

/// ST-vs-KT on the paper's best inter-node topology (the qualitative
/// Fig-6 relation of the follow-on paper, arXiv 2306.15773): KT removes
/// the per-iteration CP/stream handshake ST still pays — one
/// `writeValue64` plus one `waitValue64`, each with its host-side
/// enqueue — and releases the NIC from inside the pack kernel.
pub fn figkt() -> FigureSpec {
    FigureSpec {
        id: "figkt",
        title: "Faces 2x2x2, 8 nodes x 1 rank/node, ST vs KT",
        nodes: 8,
        ranks_per_node: 1,
        dist: (2, 2, 2),
        variants: &[Variant::Host, Variant::StreamTriggered, Variant::KernelTriggered],
        paper_result: "KT <= ST: no per-iteration CP memop handshake (arXiv 2306.15773 Fig 6)",
    }
}

pub fn all_figures() -> Vec<FigureSpec> {
    vec![fig8(), fig9(), fig10(), fig11(), fig12(), figkt()]
}

/// Result rows of one figure.
#[derive(Debug)]
pub struct FigureReport {
    pub spec: FigureSpec,
    /// (variant, avg/min/max over seeds in virtual ms).
    pub rows: Vec<(Variant, Summary)>,
}

impl FigureReport {
    /// Average time of a variant (virtual ms).
    pub fn avg(&self, v: Variant) -> f64 {
        self.rows.iter().find(|(rv, _)| *rv == v).map(|(_, s)| s.avg).unwrap()
    }

    /// Delta of `v` vs the baseline variant, in percent (positive =
    /// slower than baseline).
    pub fn delta_vs_baseline(&self, v: Variant) -> f64 {
        pct_delta(self.avg(Variant::Host), self.avg(v))
    }

    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "variant".to_string(),
            "avg (ms)".to_string(),
            "min (ms)".to_string(),
            "max (ms)".to_string(),
            "vs baseline".to_string(),
        ]];
        for (v, s) in &self.rows {
            let delta = if *v == Variant::Host {
                "--".to_string()
            } else {
                format!("{:+.1}%", self.delta_vs_baseline(*v))
            };
            rows.push(vec![
                v.name().to_string(),
                format!("{:.3}", s.avg),
                format!("{:.3}", s.min),
                format!("{:.3}", s.max),
                delta,
            ]);
        }
        format!(
            "== {} — {} ==\npaper: {}\n{}",
            self.spec.id,
            self.spec.title,
            self.spec.paper_result,
            render_table(&rows)
        )
    }
}

/// Default block edge for figure runs: production-sized local domains
/// (the calibration regime — faces are 64 KiB rendezvous messages, the
/// interior operator takes ~14 us, matching Faces at realistic Nekbone
/// sizes).
pub const FIGURE_G: usize = 128;

/// Run one figure: every variant x `seeds`, Modeled compute (numerics are
/// validated separately by the Real-compute e2e tests). The (variant x
/// seed) grid runs in parallel on the [`sweep`] executor; every job draws
/// randomness only from its own seed, so the report is byte-identical
/// regardless of the worker-thread count (see `rust/tests/determinism.rs`).
pub fn run_figure(spec: &FigureSpec, seeds: &[u64], loops: Loops, g: usize) -> FigureReport {
    let jobs: Vec<FacesConfig> = spec
        .variants
        .iter()
        .flat_map(|&variant| {
            seeds.iter().map(move |&seed| FacesConfig {
                dist: spec.dist,
                nodes: spec.nodes,
                ranks_per_node: spec.ranks_per_node,
                g,
                outer: loops.outer,
                middle: loops.middle,
                inner: loops.inner,
                variant,
                compute: ComputeMode::Modeled,
                check: false,
                seed,
                cost: presets::frontier_like_jittered(),
                faults: None,
            })
        })
        .collect();
    let samples_ms = sweep::map_default(&jobs, |_, cfg| {
        run_faces(cfg).expect("figure run failed").time_ns as f64 / 1e6
    });
    let rows = spec
        .variants
        .iter()
        .enumerate()
        .map(|(vi, &variant)| {
            let s = &samples_ms[vi * seeds.len()..(vi + 1) * seeds.len()];
            (variant, Summary::of(s))
        })
        .collect();
    FigureReport { spec: spec.clone(), rows }
}

/// The standard seeds (5 runs, like the paper).
pub const SEEDS: [u64; 5] = [11, 23, 37, 53, 71];

// ---------------------------------------------------------------------
// ST-vs-KT message-size sweep
// ---------------------------------------------------------------------

/// One row of the ST-vs-KT message-size sweep.
#[derive(Debug)]
pub struct KtCompareRow {
    /// Faces block edge; the face payload is `4 * g * g` bytes.
    pub g: usize,
    pub st: Summary,
    pub kt: Summary,
}

impl KtCompareRow {
    /// KT delta vs ST in percent (negative = KT faster).
    pub fn delta_pct(&self) -> f64 {
        pct_delta(self.st.avg, self.kt.avg)
    }
}

/// Block edges swept by the ST-vs-KT comparison: face payloads from
/// 4 KiB (eager) to 144 KiB (rendezvous).
pub const KT_COMPARE_GS: [usize; 4] = [32, 64, 128, 192];

/// The ST-vs-KT latency/overlap comparison figure (the qualitative
/// Fig-6 gap of arXiv 2306.15773): for every block edge in `gs`, run
/// Faces on the inter-node 2x2x2 topology under ST and KT. KT removes
/// the per-iteration CPU/stream handshake ST still pays (the
/// `writeValue64` + `waitValue64` memop pair and their host enqueues)
/// and releases the NIC from *inside* the pack kernel, so its latency
/// is expected at or below ST at every message size (pinned by this
/// module's tests).
pub fn run_kt_compare(gs: &[usize], seeds: &[u64], loops: Loops) -> Vec<KtCompareRow> {
    let variants = [Variant::StreamTriggered, Variant::KernelTriggered];
    let jobs: Vec<FacesConfig> = gs
        .iter()
        .flat_map(|&g| {
            variants.iter().flat_map(move |&variant| {
                seeds.iter().map(move |&seed| FacesConfig {
                    dist: (2, 2, 2),
                    nodes: 8,
                    ranks_per_node: 1,
                    g,
                    outer: loops.outer,
                    middle: loops.middle,
                    inner: loops.inner,
                    variant,
                    compute: ComputeMode::Modeled,
                    check: false,
                    seed,
                    cost: presets::frontier_like_jittered(),
                    faults: None,
                })
            })
        })
        .collect();
    let ms = sweep::map_default(&jobs, |_, cfg| {
        run_faces(cfg).expect("kt-compare run failed").time_ns as f64 / 1e6
    });
    let per_g = variants.len() * seeds.len();
    gs.iter()
        .enumerate()
        .map(|(gi, &g)| {
            let base = gi * per_g;
            KtCompareRow {
                g,
                st: Summary::of(&ms[base..base + seeds.len()]),
                kt: Summary::of(&ms[base + seeds.len()..base + per_g]),
            }
        })
        .collect()
}

/// Render the ST-vs-KT sweep as a paper-style table.
pub fn render_kt_compare(rows: &[KtCompareRow]) -> String {
    let mut t = vec![vec![
        "G".to_string(),
        "face KiB".to_string(),
        "st avg (ms)".to_string(),
        "kt avg (ms)".to_string(),
        "kt vs st".to_string(),
    ]];
    for r in rows {
        t.push(vec![
            r.g.to_string(),
            format!("{:.0}", (4 * r.g * r.g) as f64 / 1024.0),
            format!("{:.3}", r.st.avg),
            format!("{:.3}", r.kt.avg),
            format!("{:+.1}%", r.delta_pct()),
        ]);
    }
    format!(
        "== figkt-sweep — ST vs KT across message sizes ==\n\
         expectation: KT <= ST at every size (arXiv 2306.15773 Fig 6)\n{}",
        render_table(&t)
    )
}

// ---------------------------------------------------------------------
// KT-vs-GI message-size sweep (the figgi crossover)
// ---------------------------------------------------------------------

/// One row of the KT-vs-GI message-size sweep.
#[derive(Debug)]
pub struct GiCompareRow {
    /// Faces block edge; the face payload is `4 * g * g` bytes.
    pub g: usize,
    pub kt: Summary,
    pub gi: Summary,
}

impl GiCompareRow {
    /// GI delta vs KT in percent (negative = GI faster).
    pub fn delta_pct(&self) -> f64 {
        pct_delta(self.kt.avg, self.gi.avg)
    }
}

/// Block edges swept by the KT-vs-GI comparison: face payloads from
/// 4 KiB (one command-ring descriptor) to 144 KiB (18 descriptors).
pub const GI_COMPARE_GS: [usize; 4] = [32, 64, 128, 192];

/// The KT-vs-GI crossover figure (`figgi`): for every block edge in
/// `gs`, run Faces on the inter-node 2x2x2 topology under KT and GI.
///
/// The two variants trade different overheads, so the sweep crosses
/// over with message size:
///
/// * **GI wins small messages** — KT still pays host arming per message
///   (trigger/DWQ bookkeeping) every iteration; GI ships the pattern as
///   kernel arguments and pays only one `gi_descr_build_ns` descriptor
///   per message inside the kernel window.
/// * **KT wins large messages** — GI's descriptor count grows with
///   payload (one per [`crate::gpu::GI_CHUNK_BYTES`]), built serially
///   at the kernel tail, while KT's pre-armed DWQ descriptors cost the
///   same regardless of size.
///
/// The crossover is pinned by this module's tests: GI faster at the
/// smallest edge, KT faster at the largest.
pub fn run_gi_compare(gs: &[usize], seeds: &[u64], loops: Loops) -> Vec<GiCompareRow> {
    let variants = [Variant::KernelTriggered, Variant::GpuInitiated];
    let jobs: Vec<FacesConfig> = gs
        .iter()
        .flat_map(|&g| {
            variants.iter().flat_map(move |&variant| {
                seeds.iter().map(move |&seed| FacesConfig {
                    dist: (2, 2, 2),
                    nodes: 8,
                    ranks_per_node: 1,
                    g,
                    outer: loops.outer,
                    middle: loops.middle,
                    inner: loops.inner,
                    variant,
                    compute: ComputeMode::Modeled,
                    check: false,
                    seed,
                    cost: presets::frontier_like_jittered(),
                    faults: None,
                })
            })
        })
        .collect();
    let ms = sweep::map_default(&jobs, |_, cfg| {
        run_faces(cfg).expect("gi-compare run failed").time_ns as f64 / 1e6
    });
    let per_g = variants.len() * seeds.len();
    gs.iter()
        .enumerate()
        .map(|(gi, &g)| {
            let base = gi * per_g;
            GiCompareRow {
                g,
                kt: Summary::of(&ms[base..base + seeds.len()]),
                gi: Summary::of(&ms[base + seeds.len()..base + per_g]),
            }
        })
        .collect()
}

/// Render the KT-vs-GI sweep as a paper-style table.
pub fn render_gi_compare(rows: &[GiCompareRow]) -> String {
    let mut t = vec![vec![
        "G".to_string(),
        "face KiB".to_string(),
        "kt avg (ms)".to_string(),
        "gi avg (ms)".to_string(),
        "gi vs kt".to_string(),
    ]];
    for r in rows {
        t.push(vec![
            r.g.to_string(),
            format!("{:.0}", (4 * r.g * r.g) as f64 / 1024.0),
            format!("{:.3}", r.kt.avg),
            format!("{:.3}", r.gi.avg),
            format!("{:+.1}%", r.delta_pct()),
        ]);
    }
    format!(
        "== figgi-sweep — KT vs GI across message sizes ==\n\
         expectation: GI wins the smallest sizes (no host arming), KT the largest\n\
         (GI descriptor build scales with payload; crossover in between)\n{}",
        render_table(&t)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(spec: &FigureSpec) -> FigureReport {
        run_figure(spec, &[11, 23], Loops { outer: 1, middle: 1, inner: 10 }, FIGURE_G)
    }

    #[test]
    fn fig9_st_slower_intra_node() {
        let r = quick(&fig9());
        let d = r.delta_vs_baseline(Variant::StreamTriggered);
        assert!(d > 0.0, "ST must be slower intra-node (paper fig 9), got {d:+.1}%");
    }

    #[test]
    fn fig11_st_faster_inter_node_3d() {
        let r = quick(&fig11());
        let d = r.delta_vs_baseline(Variant::StreamTriggered);
        assert!(d < 0.0, "ST must win the 3-D inter-node case (paper fig 11), got {d:+.1}%");
    }

    #[test]
    fn fig12_shader_beats_st_and_baseline() {
        let r = quick(&fig12());
        let st = r.delta_vs_baseline(Variant::StreamTriggered);
        let sh = r.delta_vs_baseline(Variant::StreamTriggeredShader);
        assert!(sh < st, "shader must beat plain ST: {sh:+.1}% vs {st:+.1}%");
        assert!(sh < 0.0, "shader must beat baseline (paper fig 12), got {sh:+.1}%");
    }

    #[test]
    fn figkt_kt_at_most_st() {
        let r = quick(&figkt());
        let st = r.avg(Variant::StreamTriggered);
        let kt = r.avg(Variant::KernelTriggered);
        assert!(kt <= st, "KT must not be slower than ST: {kt:.3} vs {st:.3} ms");
        assert!(
            r.delta_vs_baseline(Variant::KernelTriggered) < 0.0,
            "KT must beat the host baseline on the inter-node 3-D case"
        );
    }

    #[test]
    fn kt_compare_kt_never_slower_across_sizes() {
        let loops = Loops { outer: 1, middle: 1, inner: 8 };
        let rows = run_kt_compare(&[32, 128], &[11, 23], loops);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(
                r.kt.avg <= r.st.avg,
                "KT must be <= ST at G={}: {:.3} vs {:.3} ms",
                r.g,
                r.kt.avg,
                r.st.avg
            );
        }
        let text = render_kt_compare(&rows);
        assert!(text.contains("kt vs st"));
    }

    /// The figgi crossover, pinned: GI must beat KT at the smallest
    /// block edge (no host arming; one descriptor per message) and KT
    /// must beat GI at the largest (GI's serial descriptor build grows
    /// with payload — 18 chunks per 144 KiB face).
    #[test]
    fn gi_compare_crossover_pinned() {
        let loops = Loops { outer: 1, middle: 1, inner: 8 };
        let rows = run_gi_compare(&[32, 192], &[11, 23], loops);
        assert_eq!(rows.len(), 2);
        assert!(
            rows[0].gi.avg < rows[0].kt.avg,
            "GI must win at G=32: gi {:.3} vs kt {:.3} ms",
            rows[0].gi.avg,
            rows[0].kt.avg
        );
        assert!(
            rows[1].kt.avg < rows[1].gi.avg,
            "KT must win at G=192: kt {:.3} vs gi {:.3} ms",
            rows[1].kt.avg,
            rows[1].gi.avg
        );
        let text = render_gi_compare(&rows);
        assert!(text.contains("gi vs kt"));
    }

    #[test]
    fn report_renders_all_rows() {
        let r = quick(&fig10());
        let text = r.render();
        assert!(text.contains("baseline"));
        assert!(text.contains("st"));
        assert!(text.contains("fig10"));
    }
}
