//! Faces domain decomposition: the 3-D process grid and its 26-neighbor
//! halo-exchange schedule (CORAL-2 Nekbone nearest-neighbor pattern).

/// A neighbor direction: each component in {-1, 0, 1}, not all zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Dir(pub i32, pub i32, pub i32);

impl Dir {
    pub fn opposite(self) -> Dir {
        Dir(-self.0, -self.1, -self.2)
    }

    /// 1 = face, 2 = edge, 3 = corner.
    pub fn order(self) -> u32 {
        (self.0.abs() + self.1.abs() + self.2.abs()) as u32
    }

    /// Dense encoding 0..26 (skipping 13 == the zero direction) used as
    /// the MPI tag for this direction.
    pub fn tag(self) -> i32 {
        (self.0 + 1) * 9 + (self.1 + 1) * 3 + (self.2 + 1)
    }

    /// All 26 directions, in deterministic order.
    pub fn all() -> Vec<Dir> {
        let mut v = Vec::with_capacity(26);
        for dx in -1..=1 {
            for dy in -1..=1 {
                for dz in -1..=1 {
                    if dx != 0 || dy != 0 || dz != 0 {
                        v.push(Dir(dx, dy, dz));
                    }
                }
            }
        }
        v
    }
}

/// Region of the packed surface buffers a direction maps to.
///
/// Pack layout (matches python kernels/ref.py `pack_ref` and the rust
/// reference): faces `[6, G, G]`, edges `[12, G]`, corners `[8]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    /// Index into the 6-face table; payload G*G.
    Face(usize),
    /// Index into the 12-edge table; payload G.
    Edge(usize),
    /// Index into the 8-corner table; payload 1.
    Corner(usize),
}

impl Region {
    pub fn elems(self, g: usize) -> usize {
        match self {
            Region::Face(_) => g * g,
            Region::Edge(_) => g,
            Region::Corner(_) => 1,
        }
    }

    /// Flat offset of this region within its packed buffer.
    pub fn offset(self, g: usize) -> usize {
        match self {
            Region::Face(i) => i * g * g,
            Region::Edge(i) => i * g,
            Region::Corner(i) => i,
        }
    }
}

/// Map a direction to its surface region (the block's side facing that
/// direction).
///
/// Face order: -x, +x, -y, +y, -z, +z.
/// Edge order: xy-plane (dx,dy) in (-,-),(-,+),(+,-),(+,+); then xz; then yz.
/// Corner order: lexicographic over (dx,dy,dz) with - before +.
pub fn region_of(d: Dir) -> Region {
    match d.order() {
        1 => Region::Face(match d {
            Dir(-1, 0, 0) => 0,
            Dir(1, 0, 0) => 1,
            Dir(0, -1, 0) => 2,
            Dir(0, 1, 0) => 3,
            Dir(0, 0, -1) => 4,
            Dir(0, 0, 1) => 5,
            _ => unreachable!(),
        }),
        2 => Region::Edge(if d.2 == 0 {
            // xy edges 0..4
            (2 * ((d.0 + 1) / 2) + (d.1 + 1) / 2) as usize
        } else if d.1 == 0 {
            // xz edges 4..8
            4 + (2 * ((d.0 + 1) / 2) + (d.2 + 1) / 2) as usize
        } else {
            // yz edges 8..12
            8 + (2 * ((d.1 + 1) / 2) + (d.2 + 1) / 2) as usize
        }),
        3 => Region::Corner(
            (4 * ((d.0 + 1) / 2) + 2 * ((d.1 + 1) / 2) + (d.2 + 1) / 2) as usize,
        ),
        _ => unreachable!("zero direction has no region"),
    }
}

/// The 3-D process grid (px × py × pz ranks, non-periodic).
#[derive(Debug, Clone, Copy)]
pub struct ProcGrid {
    pub px: usize,
    pub py: usize,
    pub pz: usize,
}

impl ProcGrid {
    pub fn new(px: usize, py: usize, pz: usize) -> Self {
        Self { px, py, pz }
    }

    pub fn size(&self) -> usize {
        self.px * self.py * self.pz
    }

    /// Rank -> grid coordinates (x fastest, matching the paper's
    /// `64x1x1` 1-D layouts where consecutive ranks are x-neighbors).
    pub fn coords(&self, rank: usize) -> (usize, usize, usize) {
        let x = rank % self.px;
        let y = (rank / self.px) % self.py;
        let z = rank / (self.px * self.py);
        (x, y, z)
    }

    pub fn rank_of(&self, x: usize, y: usize, z: usize) -> usize {
        (z * self.py + y) * self.px + x
    }

    /// The neighbor rank in direction `d`, if inside the grid.
    pub fn neighbor(&self, rank: usize, d: Dir) -> Option<usize> {
        let (x, y, z) = self.coords(rank);
        let nx = x as i64 + d.0 as i64;
        let ny = y as i64 + d.1 as i64;
        let nz = z as i64 + d.2 as i64;
        if nx < 0 || ny < 0 || nz < 0 {
            return None;
        }
        let (nx, ny, nz) = (nx as usize, ny as usize, nz as usize);
        if nx >= self.px || ny >= self.py || nz >= self.pz {
            return None;
        }
        Some(self.rank_of(nx, ny, nz))
    }

    /// All (direction, neighbor-rank) pairs for `rank`.
    pub fn neighbors(&self, rank: usize) -> Vec<(Dir, usize)> {
        Dir::all()
            .into_iter()
            .filter_map(|d| self.neighbor(rank, d).map(|n| (d, n)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dir_tags_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for d in Dir::all() {
            assert!(seen.insert(d.tag()), "duplicate tag for {d:?}");
            assert!((0..27).contains(&d.tag()));
            assert_ne!(d.tag(), 13, "13 is the zero direction");
        }
        assert_eq!(seen.len(), 26);
    }

    #[test]
    fn regions_cover_exactly() {
        let mut faces = std::collections::HashSet::new();
        let mut edges = std::collections::HashSet::new();
        let mut corners = std::collections::HashSet::new();
        for d in Dir::all() {
            match region_of(d) {
                Region::Face(i) => {
                    assert!(faces.insert(i));
                }
                Region::Edge(i) => {
                    assert!(edges.insert(i));
                }
                Region::Corner(i) => {
                    assert!(corners.insert(i));
                }
            }
        }
        assert_eq!(faces.len(), 6);
        assert_eq!(edges.len(), 12);
        assert_eq!(corners.len(), 8);
    }

    #[test]
    fn region_matches_python_ordering() {
        // Spot-checks against ref.py's documented layout.
        assert_eq!(region_of(Dir(-1, 0, 0)), Region::Face(0));
        assert_eq!(region_of(Dir(0, 0, 1)), Region::Face(5));
        assert_eq!(region_of(Dir(-1, -1, 0)), Region::Edge(0));
        assert_eq!(region_of(Dir(1, 1, 0)), Region::Edge(3));
        assert_eq!(region_of(Dir(-1, 0, -1)), Region::Edge(4));
        assert_eq!(region_of(Dir(0, 1, 1)), Region::Edge(11));
        assert_eq!(region_of(Dir(-1, -1, -1)), Region::Corner(0));
        assert_eq!(region_of(Dir(1, 1, 1)), Region::Corner(7));
    }

    #[test]
    fn grid_1d_neighbors() {
        let g = ProcGrid::new(8, 1, 1);
        assert_eq!(g.neighbors(0).len(), 1);
        assert_eq!(g.neighbors(3).len(), 2);
        assert_eq!(g.neighbor(3, Dir(1, 0, 0)), Some(4));
        assert_eq!(g.neighbor(0, Dir(-1, 0, 0)), None);
    }

    #[test]
    fn grid_2x2x2_all_seven_neighbors() {
        let g = ProcGrid::new(2, 2, 2);
        for r in 0..8 {
            assert_eq!(g.neighbors(r).len(), 7, "rank {r}");
        }
        // rank 0 = (0,0,0); its (+,+,+) corner neighbor is rank 7.
        assert_eq!(g.neighbor(0, Dir(1, 1, 1)), Some(7));
    }

    #[test]
    fn grid_interior_rank_has_26_neighbors() {
        let g = ProcGrid::new(3, 3, 3);
        assert_eq!(g.neighbors(13).len(), 26); // center of 3x3x3
    }

    #[test]
    fn neighbor_relation_is_symmetric() {
        let g = ProcGrid::new(4, 3, 2);
        for r in 0..g.size() {
            for (d, n) in g.neighbors(r) {
                assert_eq!(g.neighbor(n, d.opposite()), Some(r));
            }
        }
    }

    #[test]
    fn coords_roundtrip() {
        let g = ProcGrid::new(4, 3, 2);
        for r in 0..g.size() {
            let (x, y, z) = g.coords(r);
            assert_eq!(g.rank_of(x, y, z), r);
        }
    }
}
