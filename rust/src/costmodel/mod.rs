//! Calibrated cost model for the simulated Frontier-like testbed.
//!
//! Every latency/bandwidth the simulation charges comes from this struct,
//! so experiments can sweep parameters (and the ablation benches do). The
//! defaults are calibrated from public numbers for the paper's hardware —
//! HPE Slingshot-11 (~2 µs end-to-end latency, 200 Gb/s), AMD MI250X-class
//! GPUs (HIP kernel launch ~6 µs, stream memory ops ~1-2 µs), AMD EPYC
//! hosts — plus the paper's own measured *deltas* which bound the
//! progress-thread emulation overheads (§V-D) and the HIP-vs-shader
//! stream-memop gap (§V-F).
//!
//! All times are in nanoseconds of virtual time; bandwidths in bytes/ns
//! (== GB/s · 10⁻⁹ · 10⁹, i.e. numerically GB/s ÷ 1).

pub mod presets;

use crate::sim::rng::SplitMix64;
use crate::sim::Time;

/// Which stream-memory-operation implementation the GPU control processor
/// uses (paper §V-F): the stock HIP `hipStreamWriteValue64` /
/// `hipStreamWaitValue64`, or the hand-coded shader kernels that the paper
/// shows are ~4 pp faster end-to-end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemOpFlavor {
    Hip,
    Shader,
}

/// Default [`CostModel::gi_descr_build_ns`]: building a fixed-size
/// work-queue element with device-scope stores is cheaper than one host
/// `MPIX_Enqueue_*` call (300 ns) but far from free — GICC-style
/// measurements put a per-WQE doorbell + descriptor write in the
/// ~100 ns range.
pub const GI_DESCR_BUILD_NS_DEFAULT: Time = 120;

/// All tunable costs of the simulated testbed.
#[derive(Debug, Clone)]
pub struct CostModel {
    // ---- host (application process on the CPU) ----
    /// Cost of posting a standard MPI operation (MPI_Isend/MPI_Irecv).
    pub host_mpi_call: Time,
    /// Cost of an MPIX enqueue operation (returns immediately; just
    /// descriptor creation + queueing).
    pub host_enqueue_call: Time,
    /// Host-side completion check / request bookkeeping (MPI_Wait fast path).
    pub host_wait_overhead: Time,

    // ---- GPU / streams ----
    /// Host-side cost of enqueueing a kernel or stream op onto a stream.
    pub kernel_enqueue: Time,
    /// GPU control-processor dispatch cost per stream operation
    /// (launch + teardown of a kernel, or starting a memop).
    pub cp_dispatch: Time,
    /// Latency of a host<->device synchronization (hipStreamSynchronize):
    /// the expensive kernel-boundary sync the paper's Fig. 1 shows.
    pub stream_sync: Time,
    /// Execution cost of hipStreamWriteValue64 / hipStreamWaitValue64 on
    /// the control processor (the untuned HIP path, paper §V-F).
    pub memop_hip: Time,
    /// Execution cost of the hand-coded shader replacement.
    pub memop_shader: Time,
    /// GPU compute throughput, f32 FLOPs per ns (MI250X GCD ~ 24 TF/s f32).
    pub gpu_flops_per_ns: f64,
    /// GPU memory bandwidth, bytes per ns (MI250X GCD ~ 1.6 TB/s).
    pub gpu_mem_bw: f64,
    /// Fixed per-kernel execution overhead (pipeline drain, etc.).
    pub kernel_fixed: Time,

    // ---- NIC (simulated Slingshot-11) ----
    /// Host cost of appending one command descriptor to the NIC command
    /// queue (libfabric DWQ post).
    pub nic_cmd_post: Time,
    /// NIC-side processing per command (doorbell to DMA start).
    pub nic_proc: Time,
    /// Hardware latency from a trigger-counter write reaching threshold to
    /// the deferred operation starting (triggered-op dispatch).
    pub nic_trigger_latency: Time,
    /// NIC hardware tag-matching cost per arriving message.
    pub nic_match: Time,
    /// NIC list-processing cost to append a *triggered-receive*
    /// descriptor to the posted-receive list when its trigger fires (the
    /// receive-side offload of the follow-on work, arXiv 2306.15773):
    /// the fired DWQ entry is handed to the matching engine without any
    /// host or progress-thread involvement.
    pub nic_recv_post: Time,
    /// NIC completion-counter update cost.
    pub nic_completion: Time,
    /// Device-side cost for a kernel's threads to build ONE command-ring
    /// descriptor on the GPU-initiated path ([`crate::gpu::GiCtx`]).
    /// Paid serially inside the kernel window — it extends the kernel —
    /// once per [`crate::gpu::GI_CHUNK_BYTES`] granule of send payload
    /// (receives are a single descriptor). The GI analogue of the host's
    /// `host_enqueue_call` arming cost on the ST/KT paths.
    ///
    /// Default [`GI_DESCR_BUILD_NS_DEFAULT`]. Deliberately NOT part of
    /// [`CostModel::fields`]: it folds into [`CostModel::stable_hash`]
    /// only when overridden, so pre-GI store fingerprints stay valid.
    pub gi_descr_build_ns: Time,
    /// One-way wire latency between any two nodes (Slingshot ~1.8 µs MPI).
    pub wire_latency: Time,
    /// Wire bandwidth in bytes/ns (200 Gb/s = 25 GB/s = 25 B/ns).
    pub wire_bw: f64,
    /// Eager/rendezvous protocol switch threshold in bytes.
    pub eager_threshold: usize,
    /// Extra control-message round-trip charged to a rendezvous transfer
    /// (RTS + CTS/Get issue), on top of the data movement.
    pub rendezvous_ctrl: Time,
    /// Host CPU time the *standard* (non-triggered) path spends
    /// progressing each rendezvous send (RTS/CTS handling inside
    /// MPI_Isend/MPI_Waitall). The ST path does not pay this: "the NIC
    /// handles the entire progression of the rendezvous protocol" (§V-E).
    pub host_rendezvous_progression: Time,

    // ---- intra-node (ROCr IPC / P2P DMA) ----
    /// Startup latency of an intra-node GPU peer-to-peer DMA (ROCr IPC).
    pub ipc_latency: Time,
    /// Intra-node P2P bandwidth, bytes/ns (xGMI ~ 50 GB/s).
    pub ipc_bw: f64,
    /// Latency of the non-temporal memcpy path used for small intra-node
    /// payloads (paper §V-D).
    pub memcpy_small: Time,
    /// Payload size below which the memcpy path is used intra-node.
    pub memcpy_threshold: usize,

    // ---- progress thread (emulation of missing triggered features) ----
    /// Latency for the async progress thread to observe a trigger-counter
    /// update and wake (the key intra-node ST penalty, paper §V-D).
    pub progress_wakeup: Time,
    /// Progress-thread software handling cost per emulated operation
    /// (message matching + descriptor post).
    pub progress_per_op: Time,
    /// Progress-thread cost to update a completion counter.
    pub progress_completion: Time,
    /// Extra progress-thread involvement per *inter-node rendezvous* ST
    /// send (completion-counter handling the NIC can't do alone, §V-E).
    pub progress_rendezvous_assist: Time,

    // ---- NIC resource pools (finite hardware, §II-C) ----
    /// Hardware trigger/completion counters per NIC. Every `MPIX_Queue`
    /// holds two for its lifetime; exhaustion fails queue creation.
    pub nic_counter_limit: usize,
    /// Deferred-work-queue descriptor slots per NIC. A triggered send
    /// occupies one from enqueue until its trigger fires; multiple queues
    /// on one rank (or node) contend for this pool.
    pub dwq_slots_per_nic: usize,

    // ---- stochastics ----
    /// Multiplicative lognormal jitter applied to charged costs (sigma).
    /// 0 disables jitter entirely.
    pub jitter_sigma: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        presets::frontier_like()
    }
}

impl CostModel {
    /// Kernel execution time from its roofline characteristics.
    pub fn kernel_time(&self, flops: u64, bytes: u64) -> Time {
        let compute = flops as f64 / self.gpu_flops_per_ns;
        let memory = bytes as f64 / self.gpu_mem_bw;
        self.kernel_fixed + compute.max(memory).round() as Time
    }

    /// Wire transfer time for an eager message of `bytes`.
    pub fn wire_time(&self, bytes: usize) -> Time {
        self.wire_latency + (bytes as f64 / self.wire_bw).round() as Time
    }

    /// Serialization time on one NIC port for `bytes`.
    pub fn wire_serialize(&self, bytes: usize) -> Time {
        (bytes as f64 / self.wire_bw).round() as Time
    }

    /// Intra-node data movement time for `bytes`.
    pub fn ipc_time(&self, bytes: usize) -> Time {
        if bytes <= self.memcpy_threshold {
            self.memcpy_small + (bytes as f64 / self.gpu_mem_bw).round() as Time
        } else {
            self.ipc_latency + (bytes as f64 / self.ipc_bw).round() as Time
        }
    }

    /// Stream memory op cost for a flavor.
    pub fn memop(&self, flavor: MemOpFlavor) -> Time {
        match flavor {
            MemOpFlavor::Hip => self.memop_hip,
            MemOpFlavor::Shader => self.memop_shader,
        }
    }

    /// Apply configured jitter to a mean cost.
    pub fn jittered(&self, mean: Time, rng: &mut SplitMix64) -> Time {
        rng.jitter(mean, self.jitter_sigma)
    }

    /// True if a message of `bytes` uses the rendezvous protocol.
    pub fn is_rendezvous(&self, bytes: usize) -> bool {
        bytes > self.eager_threshold
    }

    /// The full, ordered (name, value-as-f64) field list. Single source
    /// of truth for [`CostModel::stable_hash`] and
    /// [`CostModel::apply_override`]: adding a field to the struct and
    /// to this table automatically extends both.
    fn fields(&self) -> [(&'static str, f64); 33] {
        [
            ("host_mpi_call", self.host_mpi_call as f64),
            ("host_enqueue_call", self.host_enqueue_call as f64),
            ("host_wait_overhead", self.host_wait_overhead as f64),
            ("kernel_enqueue", self.kernel_enqueue as f64),
            ("cp_dispatch", self.cp_dispatch as f64),
            ("stream_sync", self.stream_sync as f64),
            ("memop_hip", self.memop_hip as f64),
            ("memop_shader", self.memop_shader as f64),
            ("gpu_flops_per_ns", self.gpu_flops_per_ns),
            ("gpu_mem_bw", self.gpu_mem_bw),
            ("kernel_fixed", self.kernel_fixed as f64),
            ("nic_cmd_post", self.nic_cmd_post as f64),
            ("nic_proc", self.nic_proc as f64),
            ("nic_trigger_latency", self.nic_trigger_latency as f64),
            ("nic_match", self.nic_match as f64),
            ("nic_recv_post", self.nic_recv_post as f64),
            ("nic_completion", self.nic_completion as f64),
            ("wire_latency", self.wire_latency as f64),
            ("wire_bw", self.wire_bw),
            ("eager_threshold", self.eager_threshold as f64),
            ("rendezvous_ctrl", self.rendezvous_ctrl as f64),
            ("host_rendezvous_progression", self.host_rendezvous_progression as f64),
            ("ipc_latency", self.ipc_latency as f64),
            ("ipc_bw", self.ipc_bw),
            ("memcpy_small", self.memcpy_small as f64),
            ("memcpy_threshold", self.memcpy_threshold as f64),
            ("progress_wakeup", self.progress_wakeup as f64),
            ("progress_per_op", self.progress_per_op as f64),
            ("progress_completion", self.progress_completion as f64),
            ("progress_rendezvous_assist", self.progress_rendezvous_assist as f64),
            ("nic_counter_limit", self.nic_counter_limit as f64),
            ("dwq_slots_per_nic", self.dwq_slots_per_nic as f64),
            ("jitter_sigma", self.jitter_sigma),
        ]
    }

    /// Stable FNV-1a fingerprint of every tunable cost, by field name
    /// and IEEE bit pattern. Any semantic change to the model — a preset
    /// tweak, a `--diff` override, a campaign jitter/dwq knob — changes
    /// this hash, which is exactly the invalidation rule the campaign
    /// store needs: cached cells keyed on it are re-simulated if and
    /// only if the model they were produced under changed.
    pub fn stable_hash(&self) -> u64 {
        let mut h = crate::sim::rng::Fnv64::new();
        for (name, value) in self.fields() {
            h.write_str(name).write_f64(value);
        }
        // Fields added after the store's schema was frozen fold in only
        // when they differ from their default: a model that never
        // touches them hashes exactly as it did before the field
        // existed, so pre-existing store cells stay valid (the
        // zero-invalidation contract for canon extensions).
        if self.gi_descr_build_ns != GI_DESCR_BUILD_NS_DEFAULT {
            h.write_str("gi_descr_build_ns").write_f64(self.gi_descr_build_ns as f64);
        }
        h.finish()
    }

    /// Set one field by name (cost-model diffing and the `stmpi diff`
    /// CLI). Integer fields round the given value; unknown names error
    /// with the full list of valid ones.
    pub fn apply_override(&mut self, field: &str, value: f64) -> anyhow::Result<()> {
        if !value.is_finite() || value < 0.0 {
            anyhow::bail!("cost override {field}={value}: value must be finite and >= 0");
        }
        let t = value.round() as Time;
        let u = value.round() as usize;
        match field {
            "host_mpi_call" => self.host_mpi_call = t,
            "host_enqueue_call" => self.host_enqueue_call = t,
            "host_wait_overhead" => self.host_wait_overhead = t,
            "kernel_enqueue" => self.kernel_enqueue = t,
            "cp_dispatch" => self.cp_dispatch = t,
            "stream_sync" => self.stream_sync = t,
            "memop_hip" => self.memop_hip = t,
            "memop_shader" => self.memop_shader = t,
            "gpu_flops_per_ns" => self.gpu_flops_per_ns = value,
            "gpu_mem_bw" => self.gpu_mem_bw = value,
            "kernel_fixed" => self.kernel_fixed = t,
            "nic_cmd_post" => self.nic_cmd_post = t,
            "nic_proc" => self.nic_proc = t,
            "nic_trigger_latency" => self.nic_trigger_latency = t,
            "nic_match" => self.nic_match = t,
            "nic_recv_post" => self.nic_recv_post = t,
            "nic_completion" => self.nic_completion = t,
            "wire_latency" => self.wire_latency = t,
            "wire_bw" => self.wire_bw = value,
            "eager_threshold" => self.eager_threshold = u,
            "rendezvous_ctrl" => self.rendezvous_ctrl = t,
            "host_rendezvous_progression" => self.host_rendezvous_progression = t,
            "ipc_latency" => self.ipc_latency = t,
            "ipc_bw" => self.ipc_bw = value,
            "memcpy_small" => self.memcpy_small = t,
            "memcpy_threshold" => self.memcpy_threshold = u,
            "progress_wakeup" => self.progress_wakeup = t,
            "progress_per_op" => self.progress_per_op = t,
            "progress_completion" => self.progress_completion = t,
            "progress_rendezvous_assist" => self.progress_rendezvous_assist = t,
            "nic_counter_limit" => self.nic_counter_limit = u,
            "dwq_slots_per_nic" => self.dwq_slots_per_nic = u,
            "jitter_sigma" => self.jitter_sigma = value,
            "gi_descr_build_ns" => self.gi_descr_build_ns = t,
            other => {
                let mut names: Vec<&str> = self.fields().iter().map(|(n, _)| *n).collect();
                // Conditionally-hashed fields live outside fields(); keep
                // them discoverable in the error message.
                names.push("gi_descr_build_ns");
                anyhow::bail!("unknown cost-model field {other:?}; valid: {}", names.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_time_is_roofline_max() {
        let mut cm = presets::frontier_like();
        cm.kernel_fixed = 0;
        cm.gpu_flops_per_ns = 10.0;
        cm.gpu_mem_bw = 1000.0;
        // compute-bound: 1e6 flops / 10 = 1e5 ns vs 1e3 bytes -> 1 ns
        assert_eq!(cm.kernel_time(1_000_000, 1_000), 100_000);
        // memory-bound
        assert_eq!(cm.kernel_time(1_000, 1_000_000), 1_000);
    }

    #[test]
    fn wire_time_includes_latency_and_bw() {
        let mut cm = presets::frontier_like();
        cm.wire_latency = 2000;
        cm.wire_bw = 25.0;
        assert_eq!(cm.wire_time(25_000), 2000 + 1000);
    }

    #[test]
    fn small_messages_use_memcpy_path() {
        let cm = presets::frontier_like();
        let small = cm.ipc_time(64);
        let large = cm.ipc_time(4 << 20);
        assert!(small < large);
    }

    #[test]
    fn memop_flavors_differ() {
        let cm = presets::frontier_like();
        assert!(
            cm.memop(MemOpFlavor::Shader) < cm.memop(MemOpFlavor::Hip),
            "tuned shader memops must be cheaper (paper §V-F)"
        );
    }

    #[test]
    fn rendezvous_threshold() {
        let cm = presets::frontier_like();
        assert!(!cm.is_rendezvous(cm.eager_threshold));
        assert!(cm.is_rendezvous(cm.eager_threshold + 1));
    }

    #[test]
    fn stable_hash_is_deterministic_and_field_sensitive() {
        let base = presets::frontier_like();
        assert_eq!(base.stable_hash(), presets::frontier_like().stable_hash());
        // Every overridable field must perturb the hash (the store's
        // invalidation rule depends on it).
        for (name, value) in base.fields() {
            let mut cm = presets::frontier_like();
            cm.apply_override(name, value + 1.0).unwrap();
            assert_ne!(cm.stable_hash(), base.stable_hash(), "field {name} must change the hash");
        }
    }

    #[test]
    fn gi_descr_build_hashes_only_when_overridden() {
        // The zero-invalidation contract: at its default the field must
        // NOT perturb the hash (pre-GI store cells stay valid) …
        let base = presets::frontier_like();
        assert_eq!(base.gi_descr_build_ns, GI_DESCR_BUILD_NS_DEFAULT);
        // … but any override must invalidate, like every other field.
        let mut cm = presets::frontier_like();
        cm.apply_override("gi_descr_build_ns", (GI_DESCR_BUILD_NS_DEFAULT + 1) as f64).unwrap();
        assert_ne!(cm.stable_hash(), base.stable_hash());
        // Round-tripping back to the default restores the exact hash.
        cm.apply_override("gi_descr_build_ns", GI_DESCR_BUILD_NS_DEFAULT as f64).unwrap();
        assert_eq!(cm.stable_hash(), base.stable_hash());
    }

    #[test]
    fn apply_override_sets_fields_and_rejects_unknown() {
        let mut cm = presets::frontier_like();
        cm.apply_override("wire_bw", 50.0).unwrap();
        assert_eq!(cm.wire_bw, 50.0);
        cm.apply_override("eager_threshold", 1024.0).unwrap();
        assert_eq!(cm.eager_threshold, 1024);
        cm.apply_override("wire_latency", 900.0).unwrap();
        assert_eq!(cm.wire_latency, 900);
        cm.apply_override("gi_descr_build_ns", 90.0).unwrap();
        assert_eq!(cm.gi_descr_build_ns, 90);
        let err = cm.apply_override("no_such_field", 1.0).unwrap_err().to_string();
        assert!(err.contains("no_such_field") && err.contains("wire_bw"), "{err}");
        assert!(err.contains("gi_descr_build_ns"), "{err}");
        assert!(cm.apply_override("wire_bw", f64::NAN).is_err());
        assert!(cm.apply_override("wire_bw", -1.0).is_err());
    }

    #[test]
    fn zero_jitter_is_exact() {
        let mut cm = presets::frontier_like();
        cm.jitter_sigma = 0.0;
        let mut rng = SplitMix64::new(5);
        assert_eq!(cm.jittered(12345, &mut rng), 12345);
    }
}
