//! Cost-model presets.

use super::CostModel;

/// Frontier-like heterogeneous node (paper §V-C): AMD EPYC host, 8 GPUs
/// per node, Slingshot-11 NIC co-located with the GPU module.
///
/// Sources for the magnitudes (absolute values are best-effort; the
/// experiments depend on the *relative* structure):
/// * Slingshot-11: ~1.8-2 µs small-message MPI latency, 25 GB/s/port
///   (De Sensi et al., SC'20).
/// * MI250X-class GCD: ~24 TF/s f32 (vector), ~1.6 TB/s HBM per GCD,
///   HIP kernel launch ≈ 5-9 µs host-side + CP dispatch a few µs.
/// * HIP stream memory ops: the paper (§V-F) shows they are measurably
///   slower than hand-coded shader equivalents; we model 1.6 µs vs 0.4 µs.
/// * Progress-thread emulation: wakeup + per-op software handling in the
///   µs range (§V-D shows it costs ~4% end-to-end intra-node).
pub fn frontier_like() -> CostModel {
    CostModel {
        // host
        host_mpi_call: 1_200,
        host_enqueue_call: 300,
        host_wait_overhead: 120,

        // gpu
        kernel_enqueue: 1_300,
        cp_dispatch: 1_500,
        stream_sync: 4_500,
        memop_hip: 2_400,
        memop_shader: 400,
        gpu_flops_per_ns: 24_000.0, // 24 TF/s = 24e12/1e9 ns = 24000 flops/ns
        gpu_mem_bw: 1_600.0,        // 1.6 TB/s = 1600 B/ns
        kernel_fixed: 1_800,

        // nic
        nic_cmd_post: 300,
        nic_proc: 250,
        nic_trigger_latency: 350,
        nic_match: 120,
        nic_recv_post: 280,
        nic_completion: 200,
        gi_descr_build_ns: super::GI_DESCR_BUILD_NS_DEFAULT,
        wire_latency: 1_800,
        wire_bw: 25.0, // 25 GB/s
        eager_threshold: 16 * 1024,
        rendezvous_ctrl: 1_200,
        host_rendezvous_progression: 600,

        // intra-node
        ipc_latency: 1_000,
        ipc_bw: 50.0, // xGMI-ish
        memcpy_small: 600,
        memcpy_threshold: 8 * 1024,

        // progress thread
        progress_wakeup: 3_000,
        progress_per_op: 3_300,
        progress_completion: 600,
        progress_rendezvous_assist: 500,

        // NIC resource pools: Cassini exposes counters/DWQ slots in the
        // low thousands; defaults are ample so contention only appears
        // when an experiment dials them down.
        nic_counter_limit: 2_048,
        dwq_slots_per_nic: 1_024,

        jitter_sigma: 0.0,
    }
}

/// Preset with mild stochastic jitter, used to produce the paper-style
/// avg/min/max across seeds.
pub fn frontier_like_jittered() -> CostModel {
    CostModel { jitter_sigma: 0.01, ..frontier_like() }
}

#[cfg(test)]
mod tests {
    #[test]
    fn preset_is_sane() {
        let cm = super::frontier_like();
        assert!(cm.wire_latency > 0);
        assert!(cm.gpu_flops_per_ns > 0.0);
        assert!(cm.memop_shader < cm.memop_hip);
        assert!(cm.progress_wakeup + cm.progress_per_op > cm.nic_trigger_latency);
    }
}
