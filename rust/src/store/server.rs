//! `stmpi serve`: the campaign store as a long-running query service.
//!
//! A deliberately thin front-end — one `std::net::TcpListener`, no
//! async, no threads per connection: connections are served
//! sequentially and every request/response is a single JSON line
//! (except `campaign`, which streams progress lines before its final
//! `done` line). The protocol is line-oriented so a shell client is
//! enough:
//!
//! ```text
//! $ printf '{"op":"query","workload":"halo3d"}\n' | nc 127.0.0.1 7878
//! ```
//!
//! Operations (field `op`):
//!
//! | op | request fields | response |
//! |---|---|---|
//! | `ping` | — | `{"ok":true,"pong":true}` |
//! | `stats` | — | store shape: records, segments, quarantined |
//! | `get` | `key` (16 hex digits) | `found` + the full record object |
//! | `query` | `workload`/`variant`/`elems` filters, `limit` | `rows` (capped, deterministic order) |
//! | `campaign` | `spec` (see [`spec_from_json`]) | progress lines, then `done` + the report JSON |
//! | `diff` | `spec` + `overrides` `[["field",v],…]` | joined per-cell delta table |
//! | `shutdown` | — | `{"ok":true,"bye":true}`, then the server exits |
//!
//! Any malformed request yields `{"ok":false,"error":"…"}` on that
//! line; the connection stays up. Submitted campaigns always run
//! against the server's store directory (a client cannot point the
//! server at foreign paths), so every run is incremental over the same
//! store the `get`/`query` ops read.
//!
//! Because connections are served sequentially, one client must never
//! be able to wedge the service for everyone else. Two guards enforce
//! that: every read carries a timeout ([`Server::set_read_timeout`];
//! an idle connection is dropped, releasing the accept loop), and
//! request lines are capped at [`MAX_LINE_BYTES`] (an oversized line
//! gets an error response and the connection is dropped — the unread
//! tail cannot be resynced to a line boundary).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::report::json_escape;
use crate::fault::FaultSpec;
use crate::workloads::campaign::{diff_cost_models, run_campaign_observed, CampaignSpec};

use super::{key_hex, parse_key_hex, Json, Store};

/// Default row cap for `query` responses (override per request with
/// `limit`, itself clamped to this value).
pub const MAX_QUERY_ROWS: usize = 256;

/// Request-line length cap. Generous for every real request (the
/// largest — a campaign spec — is a few hundred bytes) while keeping a
/// hostile or confused client from growing an unbounded buffer.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Default per-read idle timeout: how long a connected client may sit
/// silent before the (sequential) server drops it and accepts the next
/// connection.
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(5);

/// The campaign-store service. [`Server::bind`] then [`Server::serve`];
/// `serve` blocks until a client sends `{"op":"shutdown"}`.
pub struct Server {
    listener: TcpListener,
    store_dir: PathBuf,
    read_timeout: Duration,
}

impl Server {
    /// Bind the listener (use port 0 to let the OS pick — tests do).
    pub fn bind(addr: &str, store_dir: &Path) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("serve: binding {addr}"))?;
        Ok(Server {
            listener,
            store_dir: store_dir.to_path_buf(),
            read_timeout: DEFAULT_READ_TIMEOUT,
        })
    }

    /// Override the per-read idle timeout (tests shorten it so an idle
    /// connection releases the accept loop quickly).
    pub fn set_read_timeout(&mut self, timeout: Duration) {
        self.read_timeout = timeout;
    }

    /// The bound address (for logging and for tests using port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and serve connections sequentially until a `shutdown`
    /// request arrives. I/O errors on one connection drop that
    /// connection, not the server.
    pub fn serve(self) -> Result<()> {
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            match self.handle_conn(stream) {
                Ok(true) => return Ok(()),
                Ok(false) => {}
                Err(_) => {} // connection-level failure; keep serving
            }
        }
        Ok(())
    }

    /// Serve one connection; `Ok(true)` means shutdown was requested.
    fn handle_conn(&self, stream: TcpStream) -> Result<bool> {
        // The read timeout is the anti-wedge guard: connections are
        // served sequentially, so without it one idle client would
        // block every later client's accept forever.
        stream
            .set_read_timeout(Some(self.read_timeout))
            .context("serve: setting read timeout")?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        loop {
            let line = match read_line_bounded(&mut reader, MAX_LINE_BYTES) {
                Ok(LineRead::Line(bytes)) => match String::from_utf8(bytes) {
                    Ok(s) => s,
                    Err(_) => {
                        writeln!(writer, "{}", err_line("request line is not UTF-8"))?;
                        continue;
                    }
                },
                Ok(LineRead::Eof) => return Ok(false),
                Ok(LineRead::Oversized) => {
                    // Tell the client why, then drop the connection:
                    // the unread tail of the oversized line cannot be
                    // resynced to a line boundary.
                    writeln!(
                        writer,
                        "{}",
                        err_line(&format!("request line exceeds {MAX_LINE_BYTES} bytes"))
                    )?;
                    return Ok(false);
                }
                // Timed out waiting for the next request (or any other
                // read failure): drop this connection and release the
                // accept loop for the next client.
                Err(_) => return Ok(false),
            };
            if line.trim().is_empty() {
                continue;
            }
            match self.handle_line(&line, &mut writer) {
                Ok(true) => return Ok(true),
                Ok(false) => {}
                Err(e) => {
                    writeln!(writer, "{}", err_line(&format!("{e:#}")))?;
                }
            }
        }
    }

    /// Dispatch one request line; `Ok(true)` means shutdown.
    fn handle_line(&self, line: &str, out: &mut dyn Write) -> Result<bool> {
        let req = Json::parse(line).ok_or_else(|| anyhow!("request is not valid JSON"))?;
        let op = req
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("request needs a string \"op\" field"))?;
        match op {
            "ping" => {
                writeln!(out, "{{\"ok\":true,\"pong\":true}}")?;
                Ok(false)
            }
            "shutdown" => {
                writeln!(out, "{{\"ok\":true,\"bye\":true}}")?;
                Ok(true)
            }
            "stats" => {
                let store = Store::open(&self.store_dir)?;
                writeln!(
                    out,
                    "{{\"ok\":true,\"records\":{},\"segments_loaded\":{},\
                     \"records_loaded\":{},\"quarantined\":{}}}",
                    store.len(),
                    store.segments_loaded,
                    store.records_loaded,
                    store.quarantined
                )?;
                Ok(false)
            }
            "get" => {
                let key = req
                    .get("key")
                    .and_then(Json::as_str)
                    .and_then(parse_key_hex)
                    .ok_or_else(|| anyhow!("get needs \"key\": 16 hex digits"))?;
                let store = Store::open(&self.store_dir)?;
                match store.get(key) {
                    Some(rec) => writeln!(
                        out,
                        "{{\"ok\":true,\"found\":true,\"record\":{}}}",
                        rec.to_json_line(key)
                    )?,
                    None => writeln!(
                        out,
                        "{{\"ok\":true,\"found\":false,\"key\":\"{}\"}}",
                        key_hex(key)
                    )?,
                }
                Ok(false)
            }
            "query" => {
                let workload = req.get("workload").and_then(Json::as_str);
                let variant = req.get("variant").and_then(Json::as_str);
                let elems = req.get("elems").and_then(Json::as_u64).map(|e| e as usize);
                let limit = req
                    .get("limit")
                    .and_then(Json::as_u64)
                    .map(|l| (l as usize).min(MAX_QUERY_ROWS))
                    .unwrap_or(MAX_QUERY_ROWS);
                let store = Store::open(&self.store_dir)?;
                let rows = store.query(workload, variant, elems);
                let body = rows
                    .iter()
                    .take(limit)
                    .map(|(k, r)| r.to_json_line(*k))
                    .collect::<Vec<_>>()
                    .join(",");
                writeln!(
                    out,
                    "{{\"ok\":true,\"matched\":{},\"returned\":{},\"rows\":[{}]}}",
                    rows.len(),
                    rows.len().min(limit),
                    body
                )?;
                Ok(false)
            }
            "campaign" => {
                let spec = self.spec_for_run(&req)?;
                let mut sink = &mut *out;
                let report = run_campaign_observed(&spec, &mut |p| {
                    // Progress write failures (client gone) are ignored:
                    // the campaign itself must complete and commit.
                    let _ = writeln!(
                        sink,
                        "{{\"ok\":true,\"event\":\"progress\",\"total_jobs\":{},\
                         \"cached_jobs\":{},\"simulated_jobs\":{},\"pending_jobs\":{}}}",
                        p.total_jobs, p.cached_jobs, p.simulated_jobs, p.pending_jobs
                    );
                    let _ = sink.flush();
                })?;
                writeln!(
                    out,
                    "{{\"ok\":true,\"event\":\"done\",\"cells\":{},\"ran\":{},\
                     \"all_ok\":{},\"cache_hits\":{},\"cache_misses\":{},\
                     \"simulated_ns_saved\":{},\"report\":\"{}\"}}",
                    report.cells.len(),
                    report.ran_cells(),
                    report.all_ok(),
                    report.cache.hits,
                    report.cache.misses,
                    report.cache.simulated_ns_saved,
                    json_escape(&report.to_json())
                )?;
                Ok(false)
            }
            "diff" => {
                let spec = self.spec_for_run(&req)?;
                let overrides = parse_overrides(
                    req.get("overrides")
                        .ok_or_else(|| anyhow!("diff needs \"overrides\": [[\"field\",value],…]"))?,
                )?;
                let diff = diff_cost_models(&spec, &overrides)?;
                writeln!(
                    out,
                    "{{\"ok\":true,\"rows\":{},\"cache_hits\":{},\"cache_misses\":{},\
                     \"diff\":\"{}\"}}",
                    diff.rows.len(),
                    diff.cache.hits,
                    diff.cache.misses,
                    json_escape(&diff.to_json())
                )?;
                Ok(false)
            }
            other => bail!("unknown op '{other}'"),
        }
    }

    /// Build the spec a submitted run executes: the client's `spec`
    /// pinned to the server's store directory.
    fn spec_for_run(&self, req: &Json) -> Result<CampaignSpec> {
        let mut spec = match req.get("spec") {
            Some(s) => spec_from_json(s)?,
            None => bail!("needs a \"spec\" object"),
        };
        spec.store = Some(self.store_dir.to_string_lossy().into_owned());
        Ok(spec)
    }
}

/// One attempt to read a request line.
enum LineRead {
    /// A complete line (newline stripped; also returned for a non-empty
    /// final line at EOF, matching `BufRead::lines`).
    Line(Vec<u8>),
    /// Clean end of stream at a line boundary.
    Eof,
    /// The line exceeded the cap before its newline arrived.
    Oversized,
}

/// Read one newline-terminated line of at most `max` bytes. Unlike
/// `BufRead::read_until`, the buffer cannot grow past the cap: the
/// moment the accumulated prefix exceeds it, the read stops with
/// [`LineRead::Oversized`]. Timeouts and I/O failures surface as `Err`.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    max: usize,
) -> std::io::Result<LineRead> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let buf = reader.fill_buf()?;
        if buf.is_empty() {
            return Ok(if line.is_empty() { LineRead::Eof } else { LineRead::Line(line) });
        }
        match buf.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if line.len() + pos > max {
                    return Ok(LineRead::Oversized);
                }
                line.extend_from_slice(&buf[..pos]);
                reader.consume(pos + 1);
                return Ok(LineRead::Line(line));
            }
            None => {
                let n = buf.len();
                if line.len() + n > max {
                    return Ok(LineRead::Oversized);
                }
                line.extend_from_slice(buf);
                reader.consume(n);
            }
        }
    }
}

fn err_line(msg: &str) -> String {
    format!("{{\"ok\":false,\"error\":\"{}\"}}", json_escape(msg))
}

/// Decode a client-submitted campaign spec. Starts from
/// [`CampaignSpec::default`]; unknown fields are rejected (a typo'd
/// filter silently running the full default grid would be far worse).
/// Trace exports and explicit store paths are not accepted over the
/// wire — the server pins the store, and traces are a CLI concern.
pub fn spec_from_json(v: &Json) -> Result<CampaignSpec> {
    let Json::Obj(fields) = v else { bail!("spec must be a JSON object") };
    let mut spec = CampaignSpec::default();
    for (key, val) in fields {
        match key.as_str() {
            "workloads" => spec.workloads = str_vec(val, "workloads")?,
            "variants" => spec.variants = str_vec(val, "variants")?,
            "elems" => {
                spec.elems = u64_vec(val, "elems")?.into_iter().map(|e| e as usize).collect()
            }
            "queues" => {
                spec.queues = u64_vec(val, "queues")?.into_iter().map(|q| q as usize).collect()
            }
            "seeds" => spec.seeds = u64_vec(val, "seeds")?,
            "topos" => {
                let arr = val.as_arr().ok_or_else(|| anyhow!("topos must be an array"))?;
                let mut topos = Vec::with_capacity(arr.len());
                for t in arr {
                    let pair = t.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
                        anyhow!("each topo must be a [nodes, ranks_per_node] pair")
                    })?;
                    let nodes = pair[0].as_u64().ok_or_else(|| anyhow!("topo nodes"))?;
                    let rpn = pair[1].as_u64().ok_or_else(|| anyhow!("topo ranks_per_node"))?;
                    topos.push((nodes as usize, rpn as usize));
                }
                spec.topos = topos;
            }
            "iters" => {
                spec.iters =
                    val.as_u64().ok_or_else(|| anyhow!("iters must be an integer"))? as usize
            }
            "jitter" => {
                spec.jitter = val.as_f64().ok_or_else(|| anyhow!("jitter must be a number"))?
            }
            "dwq_slots" => {
                spec.dwq_slots = match val {
                    Json::Null => None,
                    v => Some(
                        v.as_u64().ok_or_else(|| anyhow!("dwq_slots must be an integer"))?
                            as usize,
                    ),
                }
            }
            "threads" => {
                spec.threads = match val {
                    Json::Null => None,
                    v => Some(
                        v.as_u64().ok_or_else(|| anyhow!("threads must be an integer"))? as usize,
                    ),
                }
            }
            "fault_preset" => {
                spec.faults = match val {
                    Json::Null => None,
                    v => {
                        let name = v
                            .as_str()
                            .ok_or_else(|| anyhow!("fault_preset must be a preset name"))?;
                        Some(FaultSpec::preset(name, 0).ok_or_else(|| {
                            anyhow!(
                                "unknown fault preset '{name}' (known: {:?})",
                                FaultSpec::preset_names()
                            )
                        })?)
                    }
                }
            }
            "fault_seed" => {
                let seed =
                    val.as_u64().ok_or_else(|| anyhow!("fault_seed must be an integer"))?;
                match spec.faults.as_mut() {
                    Some(f) => f.seed = seed,
                    None => bail!("fault_seed needs fault_preset first (field order matters)"),
                }
            }
            "cost_overrides" => spec.cost_overrides = parse_overrides(val)?,
            other => bail!(
                "unknown spec field '{other}' (known: workloads, variants, elems, topos, \
                 queues, seeds, iters, jitter, dwq_slots, threads, fault_preset, fault_seed, \
                 cost_overrides)"
            ),
        }
    }
    Ok(spec)
}

/// Decode `[["field", value], …]` cost-model override pairs.
pub fn parse_overrides(v: &Json) -> Result<Vec<(String, f64)>> {
    let arr = v.as_arr().ok_or_else(|| anyhow!("overrides must be an array of pairs"))?;
    let mut out = Vec::with_capacity(arr.len());
    for pair in arr {
        let p = pair
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| anyhow!("each override must be a [\"field\", value] pair"))?;
        let field =
            p[0].as_str().ok_or_else(|| anyhow!("override field must be a string"))?;
        let value = p[1].as_f64().ok_or_else(|| anyhow!("override value must be a number"))?;
        out.push((field.to_string(), value));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::io::Read as _;

    fn bind_test_server(tag: &str, timeout: Duration) -> (std::path::PathBuf, SocketAddr, std::thread::JoinHandle<Result<()>>) {
        let dir = std::env::temp_dir()
            .join(format!("stmpi-serve-unit-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut srv = Server::bind("127.0.0.1:0", &dir).expect("bind 127.0.0.1:0");
        srv.set_read_timeout(timeout);
        let addr = srv.local_addr().expect("local addr");
        let handle = std::thread::spawn(move || srv.serve());
        (dir, addr, handle)
    }

    fn request_line(stream: &mut TcpStream, line: &str) -> String {
        writeln!(stream, "{line}").expect("request write");
        let mut rd = BufReader::new(stream.try_clone().expect("clone"));
        let mut resp = String::new();
        rd.read_line(&mut resp).expect("response read");
        resp
    }

    /// Regression: an idle connection must not wedge the sequential
    /// serve loop — the read timeout drops it and the next client's
    /// `ping` is answered.
    #[test]
    fn idle_connection_does_not_wedge_the_next_client() {
        let (dir, addr, handle) =
            bind_test_server("idle", Duration::from_millis(100));
        // First client connects and never sends a byte.
        let idle = TcpStream::connect(addr).expect("idle connect");
        // Give the accept loop a moment to pick the idle connection up
        // first, so the second client genuinely queues behind it.
        std::thread::sleep(Duration::from_millis(30));
        let mut c2 = TcpStream::connect(addr).expect("second connect");
        c2.set_read_timeout(Some(Duration::from_secs(30))).expect("client timeout");
        let resp = request_line(&mut c2, "{\"op\":\"ping\"}");
        assert!(resp.contains("\"pong\":true"), "second client served: {resp}");
        // Shut down from a fresh connection: c2 may itself have been
        // timed out by now (the short test timeout applies to every
        // connection), and that must not matter.
        drop(idle);
        drop(c2);
        let mut c3 = TcpStream::connect(addr).expect("shutdown connect");
        c3.set_read_timeout(Some(Duration::from_secs(30))).expect("client timeout");
        let bye = request_line(&mut c3, "{\"op\":\"shutdown\"}");
        assert!(bye.contains("\"bye\":true"), "{bye}");
        handle.join().expect("server thread").expect("serve exits clean");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// An oversized request line gets an error response and the
    /// connection is dropped; later clients are unaffected.
    #[test]
    fn oversized_request_line_is_rejected_then_dropped() {
        let (dir, addr, handle) =
            bind_test_server("oversize", Duration::from_secs(5));
        let mut c = TcpStream::connect(addr).expect("connect");
        c.set_read_timeout(Some(Duration::from_secs(30))).expect("client timeout");
        let big = vec![b'x'; MAX_LINE_BYTES + 16];
        c.write_all(&big).expect("oversized write");
        c.write_all(b"\n").expect("newline write");
        let mut resp = String::new();
        let mut rd = BufReader::new(c.try_clone().expect("clone"));
        rd.read_line(&mut resp).expect("error response");
        assert!(resp.contains("\"ok\":false"), "{resp}");
        assert!(resp.contains("exceeds"), "{resp}");
        // The server dropped the connection after responding.
        let mut rest = Vec::new();
        rd.read_to_end(&mut rest).expect("eof after error");
        assert!(rest.is_empty(), "connection closed after the error line");
        // And a fresh client is still served.
        let mut c2 = TcpStream::connect(addr).expect("second connect");
        c2.set_read_timeout(Some(Duration::from_secs(30))).expect("client timeout");
        let bye = request_line(&mut c2, "{\"op\":\"shutdown\"}");
        assert!(bye.contains("\"bye\":true"), "{bye}");
        handle.join().expect("server thread").expect("serve exits clean");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spec_from_json_decodes_and_rejects() {
        let v = Json::parse(
            "{\"workloads\": [\"halo3d\"], \"variants\": [\"st\"], \"elems\": [48], \
             \"topos\": [[2, 1]], \"seeds\": [5], \"iters\": 2, \"jitter\": 0.0, \
             \"threads\": 1, \"fault_preset\": \"rdv-drops\", \"fault_seed\": 7, \
             \"cost_overrides\": [[\"wire_latency\", 2000]]}",
        )
        .unwrap();
        let spec = spec_from_json(&v).unwrap();
        assert_eq!(spec.workloads, vec!["halo3d".to_string()]);
        assert_eq!(spec.variants, vec!["st".to_string()]);
        assert_eq!(spec.elems, vec![48]);
        assert_eq!(spec.topos, vec![(2, 1)]);
        assert_eq!(spec.seeds, vec![5]);
        assert_eq!(spec.iters, 2);
        assert_eq!(spec.threads, Some(1));
        let f = spec.faults.expect("fault preset decoded");
        assert!(f.rdv_drop_prob > 0.0);
        assert_eq!(f.seed, 7);
        assert_eq!(spec.cost_overrides, vec![("wire_latency".to_string(), 2000.0)]);

        let bad = Json::parse("{\"workload\": [\"halo3d\"]}").unwrap();
        let err = format!("{:#}", spec_from_json(&bad).unwrap_err());
        assert!(err.contains("unknown spec field"), "{err}");
        let bad = Json::parse("{\"fault_preset\": \"nope\"}").unwrap();
        assert!(spec_from_json(&bad).is_err());
    }

    #[test]
    fn parse_overrides_validates_shape() {
        let v = Json::parse("[[\"wire_bw\", 1.5], [\"nic_match\", 40]]").unwrap();
        let o = parse_overrides(&v).unwrap();
        assert_eq!(
            o,
            vec![("wire_bw".to_string(), 1.5), ("nic_match".to_string(), 40.0)]
        );
        assert!(parse_overrides(&Json::parse("[\"wire_bw\"]").unwrap()).is_err());
        assert!(parse_overrides(&Json::parse("[[\"wire_bw\"]]").unwrap()).is_err());
    }
}
