//! Campaign store: a content-addressed, persistent result cache.
//!
//! The campaign driver's unit of work is one `(cell × seed)` job — a
//! deterministic function of its full configuration. This module gives
//! every job a **canonical fingerprint** ([`CellKey`]) and persists its
//! result ([`SeedRecord`]) in an on-disk index, so reruns are
//! *incremental*: jobs whose fingerprint is already present are served
//! from the store byte-identically, and only new fingerprints are
//! simulated. On top of it, `store::server` exposes the whole engine as
//! a long-running queryable service.
//!
//! ## Fingerprint canon
//!
//! A key canonicalizes exactly the inputs a run is a function of:
//! workload, variant, payload size, topology, queues-per-rank, the
//! explicit DWQ-slot override, iteration count, seed, the
//! [`crate::costmodel::CostModel::stable_hash`] of the *effective* cost
//! model (which already folds in campaign jitter, DWQ-slot and `diff`
//! overrides), the [`crate::fault::FaultSpec::stable_hash`] of the
//! fault spec (or its absence), and whether event recording was enabled
//! ([`crate::obs::recording_enabled`] — the overlap/critical-path
//! columns exist only when it was). The canon is rendered as one pinned
//! string (see [`CellKey::canon`]) and hashed with the repo's stable
//! FNV-1a ([`crate::sim::rng::Fnv64`]); [`SCHEMA_VERSION`] leads the
//! string, so a format change invalidates every old key at once instead
//! of misreading old records.
//!
//! ## Segment log
//!
//! A store directory holds append-only JSON-lines segments
//! (`seg-NNNNNN.log`), one record per line, each line carrying its key.
//! [`Store::open`] replays every segment in name order into an
//! in-memory map (later records win — that is the upsert rule); each
//! process appends to a fresh segment, so the single-committer writer
//! (the campaign thread; sweep workers only simulate) never interleaves
//! with historical data. A segment that fails to parse is **quarantined,
//! not fatal**: the valid prefix of its records is kept, the file is
//! renamed `*.quarantined`, and the open continues — a truncated tail
//! from a killed process costs at most the cells of that tail, which
//! the next campaign simply re-simulates.
//!
//! Everything here is hand-rolled std (no serde, no async): the JSON
//! layer is [`Json`], a minimal value parser that keeps numbers as raw
//! text so `u64` counters survive without an `f64` round-trip.

pub mod server;

use std::collections::HashMap;
use std::fs::{self, File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::report::json_escape;
use crate::obs::{CritPath, Overlap};
use crate::sim::rng::Fnv64;
use crate::workloads::QueueSlotStats;

/// Store schema version, folded into every [`CellKey`]. Bump it when
/// the record schema, the key canon, or any hash feeding the canon
/// changes meaning: old segments remain parseable history but all old
/// keys stop matching, which is the safe failure mode.
pub const SCHEMA_VERSION: u32 = 1;

// ---------------------------------------------------------------------
// Cell keys
// ---------------------------------------------------------------------

/// The canonical identity of one `(cell × seed)` campaign job — the
/// content address of its result. See the module docs for exactly what
/// is (and is not) part of the canon.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellKey<'a> {
    pub workload: &'a str,
    pub variant: &'a str,
    pub elems: usize,
    pub nodes: usize,
    pub rpn: usize,
    pub queues: usize,
    /// The campaign's explicit DWQ-slot override (`None` = preset
    /// default). Also folded into `cost_hash`; kept explicit so the
    /// canon string stays a readable record of the grid point.
    pub dwq_slots: Option<usize>,
    pub iters: usize,
    pub seed: u64,
    /// [`crate::costmodel::CostModel::stable_hash`] of the *effective*
    /// model (jitter, DWQ and diff overrides applied).
    pub cost_hash: u64,
    /// [`crate::fault::FaultSpec::stable_hash`], `None` when the
    /// campaign runs fault-free.
    pub fault_hash: Option<u64>,
    /// Whether event recording was enabled for the run
    /// ([`crate::obs::recording_enabled`]): it decides whether the
    /// overlap/critical-path fields exist, so it is result-relevant.
    pub trace_on: bool,
}

impl CellKey<'_> {
    /// The pinned canonical string. Format (`-` marks an absent
    /// optional component):
    ///
    /// ```text
    /// stmpi-store/v1|<workload>|<variant>|e<elems>|<nodes>x<rpn>|q<queues>|dwq<slots|->|i<iters>|s<seed>|c<cost_hash:016x>|f<fault_hash:016x|->|t<0|1>
    /// ```
    pub fn canon(&self) -> String {
        let dwq = match self.dwq_slots {
            Some(n) => n.to_string(),
            None => "-".to_string(),
        };
        let fault = match self.fault_hash {
            Some(h) => format!("{h:016x}"),
            None => "-".to_string(),
        };
        format!(
            "stmpi-store/v{}|{}|{}|e{}|{}x{}|q{}|dwq{}|i{}|s{}|c{:016x}|f{}|t{}",
            SCHEMA_VERSION,
            self.workload,
            self.variant,
            self.elems,
            self.nodes,
            self.rpn,
            self.queues,
            dwq,
            self.iters,
            self.seed,
            self.cost_hash,
            fault,
            u8::from(self.trace_on),
        )
    }

    /// Stable FNV-1a fingerprint of [`CellKey::canon`] — the store key.
    pub fn fingerprint(&self) -> u64 {
        Fnv64::hash_str(&self.canon())
    }
}

/// Render a store key the way segment lines and query responses carry
/// it: 16 lowercase hex digits.
pub fn key_hex(key: u64) -> String {
    format!("{key:016x}")
}

/// Parse a 16-hex-digit store key (the inverse of [`key_hex`]).
pub fn parse_key_hex(s: &str) -> Option<u64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

// ---------------------------------------------------------------------
// Seed records
// ---------------------------------------------------------------------

/// The persisted result of one `(cell × seed)` job: every field the
/// campaign report reads when assembling a cell row, in the exact
/// integer domains the report math uses — which is what makes a cached
/// row **byte-identical** to the cold row it replaced. Stall outcomes
/// are records too (`stalled == true` with the diagnosis strings), so a
/// chaos campaign is just as cacheable as a clean one.
#[derive(Debug, Clone, PartialEq)]
pub struct SeedRecord {
    pub workload: String,
    pub variant: String,
    pub elems: usize,
    pub nodes: usize,
    pub rpn: usize,
    pub qpr: usize,
    pub seed: u64,
    /// True when this seed ended in a stall report instead of
    /// completing; the metric fields below are then zero and the two
    /// `stall_*` strings carry the diagnosis.
    pub stalled: bool,
    /// Figure of merit in virtual ns (0 for stalled seeds).
    pub time_ns: u64,
    pub validation_ok: bool,
    /// The rendered [`crate::workloads::Validation::label`].
    pub validation_label: String,
    pub bytes_wire: u64,
    pub wire_msgs: u64,
    pub max_ingress_wait_ns: u64,
    pub max_egress_wait_ns: u64,
    pub dwq_slot_waits: u64,
    pub dwq_peak: u64,
    /// GPU-initiated command-ring descriptors the NIC consumed (zero
    /// for every non-GI variant — and for records written before the
    /// GI variant existed, which decode tolerantly; see
    /// [`SeedRecord::from_json_line`]).
    pub gi_posts: u64,
    /// Kernel tails that stalled on a full per-launch command ring.
    pub gi_ring_full_waits: u64,
    pub unexpected_msgs: u64,
    pub events: u64,
    pub faults_injected: u64,
    pub retries: u64,
    pub timeouts: u64,
    pub per_queue: Vec<QueueSlotStats>,
    /// Raw overlap counters (the report derives the percentage).
    pub overlap: Option<Overlap>,
    pub crit: Option<CritPath>,
    /// [`crate::sim::StallReport`] headline (empty unless stalled).
    pub stall_headline: String,
    /// Full rendered stall report (empty unless stalled).
    pub stall_report: String,
}

impl SeedRecord {
    /// Serialize as one segment-log line (no trailing newline), keyed.
    pub fn to_json_line(&self, key: u64) -> String {
        let pq = self
            .per_queue
            .iter()
            .map(|q| format!("[{},{},{}]", q.slot, q.dwq_posts, q.dwq_slot_waits))
            .collect::<Vec<_>>()
            .join(",");
        let overlap = match &self.overlap {
            Some(o) => format!("[{},{}]", o.wire_ns, o.hidden_ns),
            None => "null".to_string(),
        };
        let crit = match &self.crit {
            Some(c) => format!(
                "[{},{},{},{},{},{},{}]",
                c.total_ns,
                c.compute_ns,
                c.wire_ns,
                c.trigger_ns,
                c.backpressure_ns,
                c.retransmit_ns,
                c.other_ns
            ),
            None => "null".to_string(),
        };
        format!(
            "{{\"key\":\"{}\",\"workload\":\"{}\",\"variant\":\"{}\",\"elems\":{},\
             \"nodes\":{},\"rpn\":{},\"qpr\":{},\"seed\":{},\"stalled\":{},\
             \"time_ns\":{},\"validation_ok\":{},\"validation_label\":\"{}\",\
             \"bytes_wire\":{},\"wire_msgs\":{},\"max_ingress_wait_ns\":{},\
             \"max_egress_wait_ns\":{},\"dwq_slot_waits\":{},\"dwq_peak\":{},\
             \"gi_posts\":{},\"gi_ring_full_waits\":{},\
             \"unexpected_msgs\":{},\"events\":{},\"faults_injected\":{},\
             \"retries\":{},\"timeouts\":{},\"per_queue\":[{}],\"overlap\":{},\
             \"crit\":{},\"stall_headline\":\"{}\",\"stall_report\":\"{}\"}}",
            key_hex(key),
            json_escape(&self.workload),
            json_escape(&self.variant),
            self.elems,
            self.nodes,
            self.rpn,
            self.qpr,
            self.seed,
            self.stalled,
            self.time_ns,
            self.validation_ok,
            json_escape(&self.validation_label),
            self.bytes_wire,
            self.wire_msgs,
            self.max_ingress_wait_ns,
            self.max_egress_wait_ns,
            self.dwq_slot_waits,
            self.dwq_peak,
            self.gi_posts,
            self.gi_ring_full_waits,
            self.unexpected_msgs,
            self.events,
            self.faults_injected,
            self.retries,
            self.timeouts,
            pq,
            overlap,
            crit,
            json_escape(&self.stall_headline),
            json_escape(&self.stall_report),
        )
    }

    /// Decode one segment-log line. `None` on any structural or type
    /// mismatch — the store treats that as corruption and quarantines
    /// the segment. Exception: the `gi_*` counters (added with the
    /// GPU-initiated variant) decode *tolerantly*, defaulting to 0 when
    /// absent, so segments written before GI existed replay unchanged —
    /// a warm rerun of a pre-GI store must serve every old host/ST/KT
    /// cell from disk instead of re-keying or quarantining it.
    pub fn from_json_line(line: &str) -> Option<(u64, SeedRecord)> {
        let v = Json::parse(line)?;
        let key = parse_key_hex(v.get("key")?.as_str()?)?;
        let per_queue = v
            .get("per_queue")?
            .as_arr()?
            .iter()
            .map(|q| {
                let t = q.as_arr()?;
                if t.len() != 3 {
                    return None;
                }
                Some(QueueSlotStats {
                    slot: t[0].as_u64()? as usize,
                    dwq_posts: t[1].as_u64()?,
                    dwq_slot_waits: t[2].as_u64()?,
                })
            })
            .collect::<Option<Vec<_>>>()?;
        let overlap = match v.get("overlap")? {
            Json::Null => None,
            o => {
                let t = o.as_arr()?;
                if t.len() != 2 {
                    return None;
                }
                Some(Overlap { wire_ns: t[0].as_u64()?, hidden_ns: t[1].as_u64()? })
            }
        };
        let crit = match v.get("crit")? {
            Json::Null => None,
            c => {
                let t = c.as_arr()?;
                if t.len() != 7 {
                    return None;
                }
                Some(CritPath {
                    total_ns: t[0].as_u64()?,
                    compute_ns: t[1].as_u64()?,
                    wire_ns: t[2].as_u64()?,
                    trigger_ns: t[3].as_u64()?,
                    backpressure_ns: t[4].as_u64()?,
                    retransmit_ns: t[5].as_u64()?,
                    other_ns: t[6].as_u64()?,
                })
            }
        };
        let rec = SeedRecord {
            workload: v.get("workload")?.as_str()?.to_string(),
            variant: v.get("variant")?.as_str()?.to_string(),
            elems: v.get("elems")?.as_u64()? as usize,
            nodes: v.get("nodes")?.as_u64()? as usize,
            rpn: v.get("rpn")?.as_u64()? as usize,
            qpr: v.get("qpr")?.as_u64()? as usize,
            seed: v.get("seed")?.as_u64()?,
            stalled: v.get("stalled")?.as_bool()?,
            time_ns: v.get("time_ns")?.as_u64()?,
            validation_ok: v.get("validation_ok")?.as_bool()?,
            validation_label: v.get("validation_label")?.as_str()?.to_string(),
            bytes_wire: v.get("bytes_wire")?.as_u64()?,
            wire_msgs: v.get("wire_msgs")?.as_u64()?,
            max_ingress_wait_ns: v.get("max_ingress_wait_ns")?.as_u64()?,
            max_egress_wait_ns: v.get("max_egress_wait_ns")?.as_u64()?,
            dwq_slot_waits: v.get("dwq_slot_waits")?.as_u64()?,
            dwq_peak: v.get("dwq_peak")?.as_u64()?,
            gi_posts: v.get("gi_posts").and_then(|x| x.as_u64()).unwrap_or(0),
            gi_ring_full_waits: v
                .get("gi_ring_full_waits")
                .and_then(|x| x.as_u64())
                .unwrap_or(0),
            unexpected_msgs: v.get("unexpected_msgs")?.as_u64()?,
            events: v.get("events")?.as_u64()?,
            faults_injected: v.get("faults_injected")?.as_u64()?,
            retries: v.get("retries")?.as_u64()?,
            timeouts: v.get("timeouts")?.as_u64()?,
            per_queue,
            overlap,
            crit,
            stall_headline: v.get("stall_headline")?.as_str()?.to_string(),
            stall_report: v.get("stall_report")?.as_str()?.to_string(),
        };
        Some((key, rec))
    }
}

// ---------------------------------------------------------------------
// Minimal JSON value parser
// ---------------------------------------------------------------------

/// A parsed JSON value. Numbers keep their raw text
/// ([`Json::as_u64`]/[`Json::as_f64`] parse on demand), so 64-bit
/// counters never round-trip through `f64`. This is the decoding
/// counterpart of the campaign module's syntax-only
/// [`crate::workloads::campaign::json_parses`] validator; string
/// escapes are decoded exactly as
/// [`crate::coordinator::report::json_escape`] emits them (plus the
/// spec's remaining standard escapes).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Raw number text, e.g. `"18446744073709551615"` or `"-1.5e3"`.
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Recursion guard for the parser: segment lines and service requests
/// are shallow; anything deeper is treated as corrupt rather than
/// risking the stack.
const MAX_DEPTH: usize = 128;

impl Json {
    /// Parse one complete JSON value (surrounding whitespace allowed;
    /// trailing garbage rejects). `None` on any syntax error.
    pub fn parse(s: &str) -> Option<Json> {
        let b = s.as_bytes();
        let mut i = 0usize;
        let v = parse_value(b, &mut i, 0)?;
        skip_ws(b, &mut i);
        if i == b.len() {
            Some(v)
        } else {
            None
        }
    }

    /// Object field lookup (first occurrence; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Exact unsigned 64-bit parse of the raw number text (no `f64`
    /// round-trip; rejects signs, fractions, and exponents).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) => n.parse::<u64>().ok(),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => n.parse::<f64>().ok(),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn parse_value(b: &[u8], i: &mut usize, depth: usize) -> Option<Json> {
    if depth > MAX_DEPTH {
        return None;
    }
    skip_ws(b, i);
    match b.get(*i).copied()? {
        b'{' => parse_obj(b, i, depth),
        b'[' => parse_arr(b, i, depth),
        b'"' => parse_str(b, i).map(Json::Str),
        b't' => parse_lit(b, i, b"true").then_some(Json::Bool(true)),
        b'f' => parse_lit(b, i, b"false").then_some(Json::Bool(false)),
        b'n' => parse_lit(b, i, b"null").then_some(Json::Null),
        c if c == b'-' || c.is_ascii_digit() => parse_num(b, i),
        _ => None,
    }
}

fn parse_lit(b: &[u8], i: &mut usize, lit: &[u8]) -> bool {
    if b[*i..].starts_with(lit) {
        *i += lit.len();
        true
    } else {
        false
    }
}

fn parse_num(b: &[u8], i: &mut usize) -> Option<Json> {
    let start = *i;
    if b.get(*i).copied() == Some(b'-') {
        *i += 1;
    }
    let d0 = *i;
    while *i < b.len() && b[*i].is_ascii_digit() {
        *i += 1;
    }
    if *i == d0 {
        return None;
    }
    if b.get(*i).copied() == Some(b'.') {
        *i += 1;
        let f0 = *i;
        while *i < b.len() && b[*i].is_ascii_digit() {
            *i += 1;
        }
        if *i == f0 {
            return None;
        }
    }
    if matches!(b.get(*i).copied(), Some(b'e') | Some(b'E')) {
        *i += 1;
        if matches!(b.get(*i).copied(), Some(b'+') | Some(b'-')) {
            *i += 1;
        }
        let e0 = *i;
        while *i < b.len() && b[*i].is_ascii_digit() {
            *i += 1;
        }
        if *i == e0 {
            return None;
        }
    }
    // The slice is ASCII by construction.
    Some(Json::Num(String::from_utf8_lossy(&b[start..*i]).into_owned()))
}

fn parse_str(b: &[u8], i: &mut usize) -> Option<String> {
    debug_assert_eq!(b.get(*i).copied(), Some(b'"'));
    *i += 1;
    let mut out = String::new();
    let mut run = *i; // start of the current unescaped byte run
    while *i < b.len() {
        match b[*i] {
            b'"' => {
                out.push_str(std::str::from_utf8(&b[run..*i]).ok()?);
                *i += 1;
                return Some(out);
            }
            b'\\' => {
                out.push_str(std::str::from_utf8(&b[run..*i]).ok()?);
                *i += 1;
                let esc = b.get(*i).copied()?;
                *i += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let cp = parse_hex4(b, i)?;
                        // Combine surrogate pairs; a lone surrogate is
                        // corruption.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if b.get(*i).copied() != Some(b'\\')
                                || b.get(*i + 1).copied() != Some(b'u')
                            {
                                return None;
                            }
                            *i += 2;
                            let lo = parse_hex4(b, i)?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return None;
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)?
                        } else {
                            char::from_u32(cp)?
                        };
                        out.push(ch);
                    }
                    _ => return None,
                }
                run = *i;
            }
            c if c < 0x20 => return None, // raw control char
            _ => *i += 1,
        }
    }
    None
}

fn parse_hex4(b: &[u8], i: &mut usize) -> Option<u32> {
    if *i + 4 > b.len() {
        return None;
    }
    let s = std::str::from_utf8(&b[*i..*i + 4]).ok()?;
    let v = u32::from_str_radix(s, 16).ok()?;
    *i += 4;
    Some(v)
}

fn parse_obj(b: &[u8], i: &mut usize, depth: usize) -> Option<Json> {
    *i += 1; // consume '{'
    let mut fields = Vec::new();
    skip_ws(b, i);
    if b.get(*i).copied() == Some(b'}') {
        *i += 1;
        return Some(Json::Obj(fields));
    }
    loop {
        skip_ws(b, i);
        if b.get(*i).copied() != Some(b'"') {
            return None;
        }
        let key = parse_str(b, i)?;
        skip_ws(b, i);
        if b.get(*i).copied() != Some(b':') {
            return None;
        }
        *i += 1;
        let val = parse_value(b, i, depth + 1)?;
        fields.push((key, val));
        skip_ws(b, i);
        match b.get(*i).copied() {
            Some(b',') => *i += 1,
            Some(b'}') => {
                *i += 1;
                return Some(Json::Obj(fields));
            }
            _ => return None,
        }
    }
}

fn parse_arr(b: &[u8], i: &mut usize, depth: usize) -> Option<Json> {
    *i += 1; // consume '['
    let mut items = Vec::new();
    skip_ws(b, i);
    if b.get(*i).copied() == Some(b']') {
        *i += 1;
        return Some(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, i, depth + 1)?);
        skip_ws(b, i);
        match b.get(*i).copied() {
            Some(b',') => *i += 1,
            Some(b']') => {
                *i += 1;
                return Some(Json::Arr(items));
            }
            _ => return None,
        }
    }
}

// ---------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------

/// Cache accounting of one store-backed campaign (rendered into
/// `STORE_stats.json` and the CLI summary — deliberately *not* into the
/// campaign report, whose bytes must not depend on cache temperature).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Jobs served from the store.
    pub hits: u64,
    /// Jobs that had to be simulated.
    pub misses: u64,
    /// Virtual ns of simulation served from the store instead of rerun
    /// (the sum of cached records' figures of merit).
    pub simulated_ns_saved: u64,
}

/// The persistent campaign store: an in-memory map rebuilt from the
/// segment log on open, plus one append segment for this process's
/// upserts. See the module docs for the on-disk format and the
/// quarantine rule.
#[derive(Debug)]
pub struct Store {
    dir: PathBuf,
    map: HashMap<u64, SeedRecord>,
    /// Lazily created on first upsert, so read-only opens add no files.
    seg: Option<File>,
    next_seg_idx: u64,
    /// Segments replayed cleanly on open.
    pub segments_loaded: usize,
    /// Records replayed on open (before dedup by key).
    pub records_loaded: usize,
    /// Segments renamed `*.quarantined` on open (parse failure; their
    /// valid prefix was kept).
    pub quarantined: usize,
    /// Records appended by this process.
    pub upserts: u64,
}

impl Store {
    /// Open (or create) a store directory and replay its segment log.
    pub fn open(dir: &Path) -> Result<Store> {
        fs::create_dir_all(dir)
            .with_context(|| format!("store: creating {}", dir.display()))?;
        let mut segs: Vec<(u64, PathBuf)> = Vec::new();
        for entry in
            fs::read_dir(dir).with_context(|| format!("store: listing {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if let Some(idx) = segment_index(&name) {
                segs.push((idx, entry.path()));
            }
        }
        segs.sort();
        let mut store = Store {
            dir: dir.to_path_buf(),
            map: HashMap::new(),
            seg: None,
            next_seg_idx: segs.iter().map(|&(i, _)| i + 1).max().unwrap_or(1),
            segments_loaded: 0,
            records_loaded: 0,
            quarantined: 0,
            upserts: 0,
        };
        for (_, path) in segs {
            store.replay_segment(&path)?;
        }
        Ok(store)
    }

    /// Replay one segment into the map; on a malformed line, keep the
    /// valid prefix and quarantine the file. Only real I/O errors
    /// propagate.
    fn replay_segment(&mut self, path: &Path) -> Result<()> {
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // Not UTF-8 — treat like any other corruption.
                self.quarantine(path)?;
                return Ok(());
            }
            Err(e) => {
                return Err(anyhow!(e)).with_context(|| format!("store: reading {}", path.display()))
            }
        };
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match SeedRecord::from_json_line(line) {
                Some((key, rec)) => {
                    self.records_loaded += 1;
                    self.map.insert(key, rec);
                }
                None => {
                    self.quarantine(path)?;
                    return Ok(());
                }
            }
        }
        self.segments_loaded += 1;
        Ok(())
    }

    fn quarantine(&mut self, path: &Path) -> Result<()> {
        let mut to = path.as_os_str().to_owned();
        to.push(".quarantined");
        fs::rename(path, &to)
            .with_context(|| format!("store: quarantining {}", path.display()))?;
        self.quarantined += 1;
        Ok(())
    }

    /// The store directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Records currently addressable.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look a record up by its fingerprint.
    pub fn get(&self, key: u64) -> Option<&SeedRecord> {
        self.map.get(&key)
    }

    /// Insert-or-replace a record and append it to this process's
    /// segment. An upsert identical to the stored record is a no-op
    /// (no segment growth on re-simulating known cells).
    pub fn upsert(&mut self, key: u64, rec: &SeedRecord) -> Result<()> {
        if self.map.get(&key) == Some(rec) {
            return Ok(());
        }
        let line = rec.to_json_line(key);
        if self.seg.is_none() {
            let path = self.dir.join(format!("seg-{:06}.log", self.next_seg_idx));
            let f = OpenOptions::new()
                .create_new(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("store: creating segment {}", path.display()))?;
            self.seg = Some(f);
        }
        if let Some(f) = self.seg.as_mut() {
            writeln!(f, "{line}").context("store: appending segment record")?;
        }
        self.map.insert(key, rec.clone());
        self.upserts += 1;
        Ok(())
    }

    /// Flush the append segment to disk (campaigns call this once per
    /// batch of committed results).
    pub fn flush(&mut self) -> Result<()> {
        if let Some(f) = self.seg.as_mut() {
            f.flush().context("store: flushing segment")?;
        }
        Ok(())
    }

    /// All records matching the optional filters, in a deterministic
    /// order (cell identity, then seed, then key).
    pub fn query(
        &self,
        workload: Option<&str>,
        variant: Option<&str>,
        elems: Option<usize>,
    ) -> Vec<(u64, &SeedRecord)> {
        let mut out: Vec<(u64, &SeedRecord)> = self
            .map
            .iter()
            .filter(|(_, r)| {
                workload.is_none_or(|w| r.workload == w)
                    && variant.is_none_or(|v| r.variant == v)
                    && elems.is_none_or(|e| r.elems == e)
            })
            .map(|(&k, r)| (k, r))
            .collect();
        out.sort_by(|a, b| {
            let ka = (&a.1.workload, &a.1.variant, a.1.elems, a.1.nodes, a.1.rpn, a.1.qpr, a.1.seed, a.0);
            let kb = (&b.1.workload, &b.1.variant, b.1.elems, b.1.nodes, b.1.rpn, b.1.qpr, b.1.seed, b.0);
            ka.cmp(&kb)
        });
        out
    }

    /// Render the `STORE_stats.json` payload: store shape + this run's
    /// cache accounting.
    pub fn stats_json(&self, cache: &CacheStats) -> String {
        format!(
            "{{\n  \"records\": {},\n  \"segments_loaded\": {},\n  \"records_loaded\": {},\n  \
             \"quarantined\": {},\n  \"upserts\": {},\n  \"cache_hits\": {},\n  \
             \"cache_misses\": {},\n  \"simulated_ns_saved\": {}\n}}\n",
            self.len(),
            self.segments_loaded,
            self.records_loaded,
            self.quarantined,
            self.upserts,
            cache.hits,
            cache.misses,
            cache.simulated_ns_saved,
        )
    }
}

impl Drop for Store {
    fn drop(&mut self) {
        if let Some(f) = self.seg.as_mut() {
            let _ = f.flush();
        }
    }
}

/// Parse `seg-NNNNNN.log` → `NNNNNN` (quarantined and foreign files
/// return `None` and are ignored by [`Store::open`]).
fn segment_index(name: &str) -> Option<u64> {
    let idx = name.strip_prefix("seg-")?.strip_suffix(".log")?;
    idx.parse::<u64>().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record(seed: u64) -> SeedRecord {
        SeedRecord {
            workload: "halo3d".into(),
            variant: "st".into(),
            elems: 48,
            nodes: 2,
            rpn: 1,
            qpr: 1,
            seed,
            stalled: false,
            time_ns: 1_234_567,
            validation_ok: true,
            validation_label: "passed(96)".into(),
            bytes_wire: 18_446_744_073_709_551_615, // u64::MAX survives
            wire_msgs: 52,
            max_ingress_wait_ns: 3,
            max_egress_wait_ns: 4,
            dwq_slot_waits: 5,
            dwq_peak: 6,
            gi_posts: 48,
            gi_ring_full_waits: 2,
            unexpected_msgs: 7,
            events: 8_000,
            faults_injected: 0,
            retries: 0,
            timeouts: 0,
            per_queue: vec![QueueSlotStats { slot: 0, dwq_posts: 12, dwq_slot_waits: 1 }],
            overlap: Some(Overlap { wire_ns: 100, hidden_ns: 40 }),
            crit: Some(CritPath {
                total_ns: 7,
                compute_ns: 1,
                wire_ns: 2,
                trigger_ns: 1,
                backpressure_ns: 0,
                retransmit_ns: 0,
                other_ns: 3,
            }),
            stall_headline: String::new(),
            stall_report: String::new(),
        }
    }

    #[test]
    fn cell_key_canon_and_fingerprint_are_pinned() {
        // Golden values: any drift here silently invalidates (or worse,
        // aliases) every persisted store in the wild — bump
        // SCHEMA_VERSION instead of editing the expectations.
        let key = CellKey {
            workload: "halo3d",
            variant: "st",
            elems: 48,
            nodes: 2,
            rpn: 1,
            queues: 1,
            dwq_slots: None,
            iters: 2,
            seed: 5,
            cost_hash: 0x0123_4567_89ab_cdef,
            fault_hash: None,
            trace_on: true,
        };
        assert_eq!(
            key.canon(),
            "stmpi-store/v1|halo3d|st|e48|2x1|q1|dwq-|i2|s5|c0123456789abcdef|f-|t1"
        );
        assert_eq!(key.fingerprint(), 0x72f5_c907_68e2_233d);
        assert_eq!(key_hex(key.fingerprint()), "72f5c90768e2233d");
        assert_eq!(parse_key_hex("72f5c90768e2233d"), Some(0x72f5_c907_68e2_233d));
    }

    #[test]
    fn cell_key_components_all_matter() {
        let base = CellKey {
            workload: "halo3d",
            variant: "st",
            elems: 48,
            nodes: 2,
            rpn: 1,
            queues: 1,
            dwq_slots: None,
            iters: 2,
            seed: 5,
            cost_hash: 1,
            fault_hash: None,
            trace_on: true,
        };
        let fp = base.fingerprint();
        let variants = [
            CellKey { workload: "allreduce", ..base },
            CellKey { variant: "kt", ..base },
            CellKey { elems: 64, ..base },
            CellKey { nodes: 4, ..base },
            CellKey { rpn: 2, ..base },
            CellKey { queues: 2, ..base },
            CellKey { dwq_slots: Some(8), ..base },
            CellKey { iters: 3, ..base },
            CellKey { seed: 6, ..base },
            CellKey { cost_hash: 2, ..base },
            CellKey { fault_hash: Some(1), ..base },
            CellKey { trace_on: false, ..base },
        ];
        for v in variants {
            assert_ne!(v.fingerprint(), fp, "component change must change the key: {v:?}");
        }
    }

    #[test]
    fn seed_record_round_trips_through_a_segment_line() {
        let rec = sample_record(5);
        let line = rec.to_json_line(0xdead_beef_0000_0001);
        let (key, back) = SeedRecord::from_json_line(&line).unwrap();
        assert_eq!(key, 0xdead_beef_0000_0001);
        assert_eq!(back, rec);
        // And the line is valid JSON by the syntax checker too.
        assert!(crate::workloads::campaign::json_parses(&line));
    }

    #[test]
    fn pre_gi_segment_line_decodes_with_zero_gi_counters() {
        // A segment line written before the GI variant existed carries
        // no `gi_*` fields. It must decode (tolerant default 0), not
        // quarantine — warm reruns of old stores depend on this.
        let rec = sample_record(5);
        let line = rec.to_json_line(17);
        let old_line = line
            .replace("\"gi_posts\":48,", "")
            .replace("\"gi_ring_full_waits\":2,", "");
        assert!(!old_line.contains("gi_"), "old-format line fully stripped");
        let (key, back) = SeedRecord::from_json_line(&old_line).unwrap();
        assert_eq!(key, 17);
        assert_eq!(back.gi_posts, 0);
        assert_eq!(back.gi_ring_full_waits, 0);
        // Every other field survives untouched.
        assert_eq!(back, SeedRecord { gi_posts: 0, gi_ring_full_waits: 0, ..rec });
    }

    #[test]
    fn stalled_record_round_trips_with_escaped_report() {
        let mut rec = sample_record(9);
        rec.stalled = true;
        rec.overlap = None;
        rec.crit = None;
        rec.stall_headline = "2 parked hosts".into();
        rec.stall_report = "line1\nline2\t\"quoted\" \\ backslash\u{1}".into();
        let line = rec.to_json_line(7);
        assert!(!line.contains('\n'), "segment lines must stay single-line");
        let (key, back) = SeedRecord::from_json_line(&line).unwrap();
        assert_eq!(key, 7);
        assert_eq!(back, rec);
    }

    #[test]
    fn json_parser_decodes_escapes_and_keeps_numbers_raw() {
        let v = Json::parse(
            "{\"s\": \"a\\n\\\"b\\\"\\u0041\\u00e9\", \"big\": 18446744073709551615, \
             \"f\": -1.5e3, \"arr\": [1, null, true]}",
        )
        .unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a\n\"b\"A\u{e9}"));
        assert_eq!(v.get("big").unwrap().as_u64(), Some(u64::MAX));
        assert_eq!(v.get("f").unwrap().as_f64(), Some(-1500.0));
        let arr = v.get("arr").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[1], Json::Null);
        assert_eq!(arr[2].as_bool(), Some(true));
        // Surrogate pair.
        let v = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        // Rejections: trailing garbage, lone surrogate, raw control,
        // unterminated, absurd depth.
        assert!(Json::parse("{} x").is_none());
        assert!(Json::parse("\"\\ud83d\"").is_none());
        assert!(Json::parse("\"a\u{1}b\"").is_none());
        assert!(Json::parse("\"abc").is_none());
        assert!(Json::parse(&("[".repeat(200) + &"]".repeat(200))).is_none());
    }

    #[test]
    fn store_persists_reopens_and_dedups_identical_upserts() {
        let dir = std::env::temp_dir()
            .join(format!("stmpi-store-unit-{}-persist", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        {
            let mut st = Store::open(&dir).unwrap();
            assert!(st.is_empty());
            st.upsert(1, &sample_record(5)).unwrap();
            st.upsert(2, &sample_record(9)).unwrap();
            st.upsert(1, &sample_record(5)).unwrap(); // identical — no growth
            assert_eq!(st.upserts, 2);
            st.flush().unwrap();
        }
        {
            let mut st = Store::open(&dir).unwrap();
            assert_eq!(st.len(), 2);
            assert_eq!(st.segments_loaded, 1);
            assert_eq!(st.records_loaded, 2);
            assert_eq!(st.get(1), Some(&sample_record(5)));
            // Upsert with changed content wins on the next open.
            let mut newer = sample_record(5);
            newer.time_ns = 42;
            st.upsert(1, &newer).unwrap();
            st.flush().unwrap();
        }
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 2);
        assert_eq!(st.get(1).map(|r| r.time_ns), Some(42));
        assert_eq!(st.quarantined, 0);
        let q = st.query(Some("halo3d"), Some("st"), None);
        assert_eq!(q.len(), 2);
        assert!(q[0].1.seed <= q[1].1.seed, "query order is deterministic");
        assert!(st.query(Some("nope"), None, None).is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_segment_is_quarantined_with_valid_prefix_kept() {
        let dir = std::env::temp_dir()
            .join(format!("stmpi-store-unit-{}-corrupt", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        {
            let mut st = Store::open(&dir).unwrap();
            st.upsert(1, &sample_record(5)).unwrap();
            st.upsert(2, &sample_record(9)).unwrap();
            st.flush().unwrap();
        }
        // Truncate the tail of the segment mid-line (killed-process
        // shape) — the valid prefix must survive, the file must be
        // quarantined, and nothing may panic.
        let seg = dir.join("seg-000001.log");
        let text = fs::read_to_string(&seg).unwrap();
        let cut = text.len() - 25;
        fs::write(&seg, &text[..cut]).unwrap();
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.quarantined, 1);
        assert_eq!(st.len(), 1, "valid prefix record kept");
        assert_eq!(st.get(1), Some(&sample_record(5)));
        assert!(dir.join("seg-000001.log.quarantined").exists());
        assert!(!seg.exists());
        // A fresh write after quarantine gets a new segment name.
        let mut st = Store::open(&dir).unwrap();
        st.upsert(3, &sample_record(11)).unwrap();
        st.flush().unwrap();
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn garbage_file_quarantines_without_losing_other_segments() {
        let dir = std::env::temp_dir()
            .join(format!("stmpi-store-unit-{}-garbage", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        {
            let mut st = Store::open(&dir).unwrap();
            st.upsert(1, &sample_record(5)).unwrap();
            st.flush().unwrap();
        }
        fs::write(dir.join("seg-000002.log"), b"not json at all\n").unwrap();
        fs::write(dir.join("seg-000003.log"), [0xFF, 0xFE, 0x00]).unwrap(); // not UTF-8
        fs::write(dir.join("README.txt"), b"ignored\n").unwrap();
        let st = Store::open(&dir).unwrap();
        assert_eq!(st.quarantined, 2);
        assert_eq!(st.segments_loaded, 1);
        assert_eq!(st.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }
}
