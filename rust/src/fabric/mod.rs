//! Network fabric: inter-node links with latency + bandwidth serialization.
//!
//! Models the Slingshot fabric at the level the paper's experiments need:
//! a full bisection network (8 nodes, one NIC port each) where each
//! message pays a one-way latency plus store-and-forward serialization on
//! the source egress port and destination ingress port. Port busy-until
//! times give first-order congestion behaviour when many messages leave
//! or arrive at one node simultaneously (the 64-rank Fig 8 case).

use crate::obs::{Event, WireDir, NO_RANK};
use crate::sim::Time;
use crate::world::{Callback, Ctx, World};

/// Per-node port state (one NIC port per node, as on the testbed).
#[derive(Debug, Default, Clone)]
pub struct Port {
    pub egress_busy_until: Time,
    pub ingress_busy_until: Time,
}

/// Trace attribution carried alongside a transfer (see [`crate::obs`]):
/// which rank originated the payload and whether it is a watchdog
/// retransmission. Purely observational — it never affects timing.
#[derive(Debug, Clone, Copy)]
pub struct WireTag {
    /// Originating rank ([`crate::obs::NO_RANK`] when the caller sits
    /// below the layer that knows it).
    pub src_rank: u32,
    /// True for watchdog-retransmitted payloads.
    pub retransmit: bool,
}

impl Default for WireTag {
    fn default() -> Self {
        Self { src_rank: NO_RANK, retransmit: false }
    }
}

/// Schedule delivery of `bytes` from `src_node` to `dst_node`; runs `cb`
/// at the arrival instant. Returns the virtual time at which the payload
/// has fully left the source port (local send completion for eager sends).
pub fn transfer(
    w: &mut World,
    core: &mut Ctx,
    src_node: usize,
    dst_node: usize,
    bytes: usize,
    cb: Callback,
) -> Time {
    transfer_tagged(w, core, src_node, dst_node, bytes, WireTag::default(), cb)
}

/// [`transfer`] with an explicit [`WireTag`] for trace attribution (the
/// NIC eager path passes the sending rank; the watchdog marks
/// retransmissions). Timing is identical to the untagged call.
pub fn transfer_tagged(
    w: &mut World,
    core: &mut Ctx,
    src_node: usize,
    dst_node: usize,
    bytes: usize,
    tag: WireTag,
    cb: Callback,
) -> Time {
    debug_assert_ne!(src_node, dst_node, "fabric::transfer is inter-node only");
    w.metrics.bytes_wire += bytes as u64;
    w.metrics.wire_msgs += 1;
    let now = core.now();
    let ser = w.cost.wire_serialize(bytes);

    // Source egress port serialization.
    let start = now.max(w.nics[src_node].port.egress_busy_until);
    let left_src = start + ser;
    w.nics[src_node].port.egress_busy_until = left_src;
    // Congestion visibility for workload reports: how long this message
    // queued behind earlier traffic on each port.
    w.metrics.max_egress_wait_ns = w.metrics.max_egress_wait_ns.max(start - now);

    // Wire latency.
    let at_dst = left_src + w.cost.wire_latency;

    // Destination ingress port serialization (store-and-forward model:
    // the message occupies the ingress port for its serialization time).
    let in_start = at_dst.max(w.nics[dst_node].port.ingress_busy_until);
    let arrive = in_start + ser;
    w.nics[dst_node].port.ingress_busy_until = arrive;
    w.metrics.max_ingress_wait_ns = w.metrics.max_ingress_wait_ns.max(in_start - at_dst);

    if core.trace_on() {
        let (src_node, dst_node) = (src_node as u32, dst_node as u32);
        core.trace_push(Event::Wire {
            t0: start,
            dur: ser,
            src_node,
            dst_node,
            bytes: bytes as u64,
            src_rank: tag.src_rank,
            dir: WireDir::Egress,
            retransmit: tag.retransmit,
        });
        core.trace_push(Event::Wire {
            t0: in_start,
            dur: ser,
            src_node,
            dst_node,
            bytes: bytes as u64,
            src_rank: tag.src_rank,
            dir: WireDir::Ingress,
            retransmit: tag.retransmit,
        });
    }

    core.schedule_at(arrive, cb);
    left_src
}

/// Fault-injection entry point: like [`transfer`], but the message enters
/// the fabric `extra_ns` late (a delayed wire message from an active
/// [`crate::fault::FaultPlan`]). Because port busy-until state is only
/// consulted at entry time, the delay composes with congestion exactly as
/// a late NIC would. `done(w, core, left_src)` runs at entry with the
/// time the payload fully left the source port (the local-completion
/// anchor). With `extra_ns == 0` this is [`transfer`] plus an immediate
/// `done` — same event sequence, same timing.
pub fn transfer_delayed(
    w: &mut World,
    core: &mut Ctx,
    src_node: usize,
    dst_node: usize,
    bytes: usize,
    extra_ns: Time,
    cb: Callback,
    done: Box<dyn FnOnce(&mut World, &mut Ctx, Time) + Send>,
) {
    transfer_delayed_tagged(
        w,
        core,
        src_node,
        dst_node,
        bytes,
        WireTag::default(),
        extra_ns,
        cb,
        done,
    )
}

/// [`transfer_delayed`] with an explicit [`WireTag`] (see
/// [`transfer_tagged`]). Timing is identical to the untagged call.
#[allow(clippy::too_many_arguments)]
pub fn transfer_delayed_tagged(
    w: &mut World,
    core: &mut Ctx,
    src_node: usize,
    dst_node: usize,
    bytes: usize,
    tag: WireTag,
    extra_ns: Time,
    cb: Callback,
    done: Box<dyn FnOnce(&mut World, &mut Ctx, Time) + Send>,
) {
    if extra_ns == 0 {
        let left_src = transfer_tagged(w, core, src_node, dst_node, bytes, tag, cb);
        done(w, core, left_src);
        return;
    }
    core.schedule(
        extra_ns,
        Box::new(move |w, core| {
            let left_src = transfer_tagged(w, core, src_node, dst_node, bytes, tag, cb);
            done(w, core, left_src);
        }),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::presets;
    use crate::nic::Nic;
    use crate::sim::Engine;
    use crate::world::Topology;

    fn world2() -> World {
        let mut w = World::new(presets::frontier_like(), Topology::new(2, 1));
        w.nics.push(Nic::new(0));
        w.nics.push(Nic::new(1));
        w
    }

    /// Run a closure in a 2-node world, recording arrival times via a
    /// shared readout.
    fn arrivals_of(n_msgs: usize, bytes: usize) -> Vec<Time> {
        use std::sync::{Arc, Mutex};
        let readout: Arc<Mutex<Vec<Time>>> = Arc::new(Mutex::new(Vec::new()));
        let eng = Engine::new(world2(), 1);
        for _ in 0..n_msgs {
            let ro = readout.clone();
            eng.setup(move |w, core| {
                transfer(
                    w,
                    core,
                    0,
                    1,
                    bytes,
                    Box::new(move |_, c| ro.lock().unwrap().push(c.now())),
                );
            });
        }
        eng.run().unwrap();
        let v = readout.lock().unwrap().clone();
        v
    }

    #[test]
    fn single_transfer_arrival_time() {
        let t = arrivals_of(1, 25_000);
        // ser = 25_000/25 = 1000 ns on each port; latency 1800 ns.
        assert_eq!(t, vec![1000 + 1800 + 1000]);
    }

    #[test]
    fn transfers_serialize_on_ports() {
        let t = arrivals_of(3, 25_000);
        assert_eq!(t.len(), 3);
        // Back-to-back messages pipeline across ports: steady-state spacing
        // is one serialization quantum (1000 ns at 25 B/ns).
        assert_eq!(t[1] - t[0], 1000);
        assert_eq!(t[2] - t[1], 1000);
    }

    /// Pins the egress/ingress busy-until serialization order for
    /// simultaneous transfers — the Fig-8-style congestion behaviour the
    /// incast workload depends on.
    ///
    /// Numbers below use the frontier_like preset: ser(25_000 B) =
    /// 25_000 / 25 B/ns = 1000 ns per port, wire latency 1800 ns.
    #[test]
    fn simultaneous_transfers_pin_port_serialization_order() {
        use std::sync::{Arc, Mutex};
        let mut w = World::new(presets::frontier_like(), Topology::new(3, 1));
        for n in 0..3 {
            w.nics.push(Nic::new(n));
        }
        let readout: Arc<Mutex<Vec<(usize, Time)>>> = Arc::new(Mutex::new(Vec::new()));
        let eng = Engine::new(w, 1);
        let ro1 = readout.clone();
        let ro2 = readout.clone();
        let ro3 = readout.clone();
        eng.setup(move |w, core| {
            // Two different sources into one destination at t = 0: the
            // second message pays the full ingress serialization of the
            // first on top of its own.
            transfer(w, core, 1, 0, 25_000, Box::new(move |_, c| ro1.lock().unwrap().push((1, c.now()))));
            transfer(w, core, 2, 0, 25_000, Box::new(move |_, c| ro2.lock().unwrap().push((2, c.now()))));
            // A second message out of source 1 at t = 0: it queues on the
            // *egress* port first, then behind both earlier arrivals on
            // the shared ingress port.
            transfer(w, core, 1, 0, 25_000, Box::new(move |_, c| ro3.lock().unwrap().push((3, c.now()))));
        });
        let (w, _) = eng.run().unwrap();
        let arrivals = readout.lock().unwrap().clone();
        // msg1: egress [0,1000], +1800 wire, ingress [2800,3800].
        // msg2: egress [0,1000] on its own port, at dst 2800 but ingress
        //       busy until 3800 -> [3800,4800].
        // msg3: egress [1000,2000] (behind msg1), at dst 3800, ingress
        //       busy until 4800 -> [4800,5800].
        assert_eq!(arrivals, vec![(1, 3800), (2, 4800), (3, 5800)]);
        // Port busy-until state reflects the serialization order.
        assert_eq!(w.nics[0].port.ingress_busy_until, 5800);
        assert_eq!(w.nics[1].port.egress_busy_until, 2000);
        assert_eq!(w.nics[2].port.egress_busy_until, 1000);
        assert_eq!(w.nics[0].port.egress_busy_until, 0);
        // Congestion metrics: msg3 queued 1000 ns on egress (behind msg1)
        // and 1000 ns on ingress (it reached the port at 3800 with the
        // port busy until 4800); msg2 also waited 1000 ns on ingress.
        assert_eq!(w.metrics.wire_msgs, 3);
        assert_eq!(w.metrics.max_egress_wait_ns, 1000);
        assert_eq!(w.metrics.max_ingress_wait_ns, 1000);
    }

    /// An uncontended transfer records zero queueing on both ports.
    #[test]
    fn uncontended_transfer_has_zero_port_wait() {
        let eng = Engine::new(world2(), 1);
        eng.setup(|w, core| {
            transfer(w, core, 0, 1, 25_000, Box::new(|_, _| {}));
        });
        let (w, _) = eng.run().unwrap();
        assert_eq!(w.metrics.wire_msgs, 1);
        assert_eq!(w.metrics.max_egress_wait_ns, 0);
        assert_eq!(w.metrics.max_ingress_wait_ns, 0);
    }

    #[test]
    fn wire_byte_metric_accumulates() {
        let eng = Engine::new(world2(), 1);
        eng.setup(|w, core| {
            transfer(w, core, 0, 1, 100, Box::new(|_, _| {}));
            transfer(w, core, 1, 0, 200, Box::new(|_, _| {}));
        });
        let (w, _) = eng.run().unwrap();
        assert_eq!(w.metrics.bytes_wire, 300);
    }
}
