//! Network fabric: inter-node links with latency + bandwidth serialization.
//!
//! Models the Slingshot fabric at the level the paper's experiments need:
//! a full bisection network (8 nodes, one NIC port each) where each
//! message pays a one-way latency plus store-and-forward serialization on
//! the source egress port and destination ingress port. Port busy-until
//! times give first-order congestion behaviour when many messages leave
//! or arrive at one node simultaneously (the 64-rank Fig 8 case).

use crate::sim::Time;
use crate::world::{Callback, Ctx, World};

/// Per-node port state (one NIC port per node, as on the testbed).
#[derive(Debug, Default, Clone)]
pub struct Port {
    pub egress_busy_until: Time,
    pub ingress_busy_until: Time,
}

/// Schedule delivery of `bytes` from `src_node` to `dst_node`; runs `cb`
/// at the arrival instant. Returns the virtual time at which the payload
/// has fully left the source port (local send completion for eager sends).
pub fn transfer(
    w: &mut World,
    core: &mut Ctx,
    src_node: usize,
    dst_node: usize,
    bytes: usize,
    cb: Callback,
) -> Time {
    debug_assert_ne!(src_node, dst_node, "fabric::transfer is inter-node only");
    w.metrics.bytes_wire += bytes as u64;
    let now = core.now();
    let ser = w.cost.wire_serialize(bytes);

    // Source egress port serialization.
    let egress = &mut w.nics[src_node].port.egress_busy_until;
    let start = now.max(*egress);
    let left_src = start + ser;
    *egress = left_src;

    // Wire latency.
    let at_dst = left_src + w.cost.wire_latency;

    // Destination ingress port serialization (store-and-forward model:
    // the message occupies the ingress port for its serialization time).
    let ingress = &mut w.nics[dst_node].port.ingress_busy_until;
    let arrive = at_dst.max(*ingress) + ser;
    *ingress = arrive;

    core.schedule_at(arrive, cb);
    left_src
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::presets;
    use crate::nic::Nic;
    use crate::sim::Engine;
    use crate::world::Topology;

    fn world2() -> World {
        let mut w = World::new(presets::frontier_like(), Topology::new(2, 1));
        w.nics.push(Nic::new(0));
        w.nics.push(Nic::new(1));
        w
    }

    /// Run a closure in a 2-node world, recording arrival times via a
    /// shared readout.
    fn arrivals_of(n_msgs: usize, bytes: usize) -> Vec<Time> {
        use std::sync::{Arc, Mutex};
        let readout: Arc<Mutex<Vec<Time>>> = Arc::new(Mutex::new(Vec::new()));
        let eng = Engine::new(world2(), 1);
        for _ in 0..n_msgs {
            let ro = readout.clone();
            eng.setup(move |w, core| {
                transfer(
                    w,
                    core,
                    0,
                    1,
                    bytes,
                    Box::new(move |_, c| ro.lock().unwrap().push(c.now())),
                );
            });
        }
        eng.run().unwrap();
        let v = readout.lock().unwrap().clone();
        v
    }

    #[test]
    fn single_transfer_arrival_time() {
        let t = arrivals_of(1, 25_000);
        // ser = 25_000/25 = 1000 ns on each port; latency 1800 ns.
        assert_eq!(t, vec![1000 + 1800 + 1000]);
    }

    #[test]
    fn transfers_serialize_on_ports() {
        let t = arrivals_of(3, 25_000);
        assert_eq!(t.len(), 3);
        // Back-to-back messages pipeline across ports: steady-state spacing
        // is one serialization quantum (1000 ns at 25 B/ns).
        assert_eq!(t[1] - t[0], 1000);
        assert_eq!(t[2] - t[1], 1000);
    }

    #[test]
    fn wire_byte_metric_accumulates() {
        let eng = Engine::new(world2(), 1);
        eng.setup(|w, core| {
            transfer(w, core, 0, 1, 100, Box::new(|_, _| {}));
            transfer(w, core, 1, 0, 200, Box::new(|_, _| {}));
        });
        let (w, _) = eng.run().unwrap();
        assert_eq!(w.metrics.bytes_wire, 300);
    }
}
