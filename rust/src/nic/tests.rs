//! NIC unit tests: DWQ triggered operations, eager/rendezvous protocols.

use super::*;
use crate::coordinator::build_world;
use crate::costmodel::presets;
use crate::mpi::{self, SrcSel, TagSel};
use crate::sim::Engine;
use crate::world::Topology;

fn engine(nodes: usize, rpn: usize) -> Engine<World> {
    let mut cost = presets::frontier_like();
    cost.jitter_sigma = 0.0;
    Engine::new(build_world(cost, Topology::new(nodes, rpn)), 1)
}

#[test]
fn triggered_send_defers_until_threshold() {
    let eng = engine(2, 1);
    let delivered_at = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let da = delivered_at.clone();
    eng.setup(|w, core| {
        let src = w.bufs.alloc_init(vec![7.0; 16]);
        let dst = w.bufs.alloc(16);
        let trig = alloc_counter(w, core, 0, "t").unwrap();
        let env = Envelope { src_rank: 0, dst_rank: 1, tag: 5, comm: 0, elems: 16 };
        // Receiver posts first.
        mpi::post_recv(
            w,
            core,
            1,
            SrcSel::Rank(0),
            TagSel::Tag(5),
            0,
            BufSlice::whole(dst, 16),
            Done::call(Box::new(move |w, core| {
                assert_eq!(w.bufs.get(crate::world::BufId(1))[0], 7.0);
                *da.lock().unwrap() = core.now();
            })),
        );
        post_triggered_send(w, core, trig, 1, env, BufSlice::whole(src, 16), Done::none(), None);
        // Trigger fires only at t = 50_000.
        core.schedule(50_000, Box::new(move |_, c| c.write_cell(trig, 1)));
    });
    let (w, _) = eng.run().unwrap();
    let t = *delivered_at.lock().unwrap();
    assert!(t > 50_000, "delivered at {t}, before the trigger");
    assert_eq!(w.metrics.dwq_triggered, 1);
    assert_eq!(w.metrics.eager_sends, 1);
}

#[test]
fn triggered_send_reads_buffer_at_trigger_time() {
    // §III-B2 item 2: kernels may mutate the buffer until the trigger
    // write executes in stream order — the DMA must snapshot late.
    let eng = engine(2, 1);
    let value_seen = std::sync::Arc::new(std::sync::Mutex::new(0.0f32));
    let vs = value_seen.clone();
    eng.setup(|w, core| {
        let src = w.bufs.alloc_init(vec![1.0; 8]);
        let dst = w.bufs.alloc(8);
        let trig = alloc_counter(w, core, 0, "t").unwrap();
        let env = Envelope { src_rank: 0, dst_rank: 1, tag: 1, comm: 0, elems: 8 };
        mpi::post_recv(
            w,
            core,
            1,
            SrcSel::Rank(0),
            TagSel::Tag(1),
            0,
            BufSlice::whole(dst, 8),
            Done::call(Box::new(move |w, _| {
                *vs.lock().unwrap() = w.bufs.get(crate::world::BufId(1))[0];
            })),
        );
        post_triggered_send(w, core, trig, 1, env, BufSlice::whole(src, 8), Done::none(), None);
        // Buffer is overwritten BEFORE the trigger fires.
        core.schedule(1_000, Box::new(move |w: &mut World, _c: &mut Ctx| {
            w.bufs.get_mut(crate::world::BufId(0)).fill(42.0);
        }));
        core.schedule(2_000, Box::new(move |_, c| c.write_cell(trig, 1)));
    });
    eng.run().unwrap();
    assert_eq!(*value_seen.lock().unwrap(), 42.0, "DMA must read at trigger time");
}

#[test]
fn large_messages_use_rendezvous() {
    let eng = engine(2, 1);
    let got = std::sync::Arc::new(std::sync::Mutex::new(0.0f32));
    let gc = got.clone();
    eng.setup(|w, core| {
        let elems = 32 * 1024; // 128 KiB > eager threshold
        let src = w.bufs.alloc_init(vec![3.5; elems]);
        let dst = w.bufs.alloc(elems);
        let env = Envelope { src_rank: 0, dst_rank: 1, tag: 9, comm: 0, elems };
        mpi::post_recv(
            w,
            core,
            1,
            SrcSel::Rank(0),
            TagSel::Tag(9),
            0,
            BufSlice::whole(dst, elems),
            Done::call(Box::new(move |w, _| {
                *gc.lock().unwrap() = w.bufs.get(crate::world::BufId(1))[elems - 1];
            })),
        );
        execute_send(w, core, env, BufSlice::whole(src, elems), Done::none());
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(*got.lock().unwrap(), 3.5);
    assert_eq!(w.metrics.rendezvous_sends, 1);
    assert_eq!(w.metrics.eager_sends, 0);
}

#[test]
fn rendezvous_waits_for_late_receiver() {
    let eng = engine(2, 1);
    let done_at = std::sync::Arc::new(std::sync::Mutex::new((0u64, 0u64)));
    let dc = done_at.clone();
    let dc2 = done_at.clone();
    eng.setup(|w, core| {
        let elems = 32 * 1024;
        let src = w.bufs.alloc_init(vec![1.25; elems]);
        let dst = w.bufs.alloc(elems);
        let env = Envelope { src_rank: 0, dst_rank: 1, tag: 2, comm: 0, elems };
        execute_send(
            w,
            core,
            env,
            BufSlice::whole(src, elems),
            Done::call(Box::new(move |_, core| dc.lock().unwrap().0 = core.now())),
        );
        // Receiver posts much later.
        core.schedule(
            200_000,
            Box::new(move |w, core| {
                mpi::post_recv(
                    w,
                    core,
                    1,
                    SrcSel::Rank(0),
                    TagSel::Tag(2),
                    0,
                    BufSlice::whole(dst, elems),
                    Done::call(Box::new(move |w, core| {
                        assert_eq!(w.bufs.get(dst)[0], 1.25);
                        dc2.lock().unwrap().1 = core.now();
                    })),
                );
            }),
        );
    });
    let (w, _) = eng.run().unwrap();
    let (send_done, recv_done) = *done_at.lock().unwrap();
    assert!(send_done > 200_000, "sender completes only after match (got {send_done})");
    assert!(recv_done >= send_done || recv_done > 200_000);
    assert_eq!(w.metrics.unexpected_msgs, 1, "RTS must land unexpected");
}

#[test]
fn triggered_put_moves_data_on_trigger() {
    let eng = engine(2, 1);
    let ok = std::sync::Arc::new(std::sync::Mutex::new(false));
    let okc = ok.clone();
    eng.setup(|w, core| {
        let src = w.bufs.alloc_init(vec![9.0; 64]);
        let dst = w.bufs.alloc(64);
        let trig = alloc_counter(w, core, 0, "t").unwrap();
        post_triggered_put(
            w,
            core,
            trig,
            2,
            0,
            1,
            BufSlice::whole(src, 64),
            BufSlice::whole(dst, 64),
            Done::none(),
            Done::call(Box::new(move |w, _| {
                *okc.lock().unwrap() = w.bufs.get(dst).iter().all(|&x| x == 9.0);
            })),
        );
        // Two increments needed.
        core.schedule(10, Box::new(move |_, c| { c.add_cell(trig, 1); }));
        core.schedule(20, Box::new(move |_, c| { c.add_cell(trig, 1); }));
    });
    eng.run().unwrap();
    assert!(*ok.lock().unwrap());
}

#[test]
fn triggered_atomic_add_bumps_target() {
    let eng = engine(1, 1);
    let v = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let vc = v.clone();
    eng.setup(|w, core| {
        let trig = alloc_counter(w, core, 0, "t").unwrap();
        let target = alloc_counter(w, core, 0, "tgt").unwrap();
        post_triggered_atomic_add(w, core, trig, 1, target, 5);
        core.schedule(10, Box::new(move |_, c| c.write_cell(trig, 1)));
        core.schedule(
            100_000,
            Box::new(move |_, c| {
                *vc.lock().unwrap() = c.cell(target);
            }),
        );
    });
    eng.run().unwrap();
    assert_eq!(*v.lock().unwrap(), 5);
}

#[test]
fn counter_alloc_tracks_count() {
    let eng = engine(2, 1);
    eng.setup(|w, core| {
        alloc_counter(w, core, 0, "a").unwrap();
        alloc_counter(w, core, 0, "b").unwrap();
        alloc_counter(w, core, 1, "c").unwrap();
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(w.nics[0].counters_allocated, 2);
    assert_eq!(w.nics[1].counters_allocated, 1);
}

/// The counter pool is finite per NIC and `release_counter` returns
/// capacity, so a freed queue's counters can be reused.
#[test]
fn counter_pool_exhausts_and_recovers() {
    let eng = engine(1, 1);
    eng.setup(|w, core| {
        w.cost.nic_counter_limit = 2;
        assert!(alloc_counter(w, core, 0, "a").is_some());
        assert!(alloc_counter(w, core, 0, "b").is_some());
        assert!(alloc_counter(w, core, 0, "c").is_none(), "pool of 2 must refuse a third");
        release_counter(w, 0);
        assert!(alloc_counter(w, core, 0, "d").is_some(), "released capacity is reusable");
        assert_eq!(w.nics[0].counters_in_use, 2);
        assert_eq!(w.nics[0].counters_allocated, 3, "total-ever keeps counting");
    });
    eng.run().unwrap();
}

/// DWQ slots: reservations fail at capacity, and the slot returns to the
/// pool when the descriptor's trigger fires.
#[test]
fn dwq_slots_exhaust_and_release_on_trigger() {
    let eng = engine(2, 1);
    eng.setup(|w, core| {
        w.cost.dwq_slots_per_nic = 1;
        let src = w.bufs.alloc_init(vec![1.0; 8]);
        let trig = alloc_counter(w, core, 0, "t").unwrap();
        let env = Envelope { src_rank: 0, dst_rank: 1, tag: 3, comm: 0, elems: 8 };
        assert!(dwq_reserve(w, core, 0).is_ok());
        assert_eq!(dwq_reserve(w, core, 0), Err(DwqFull { node: 0 }), "one slot only");
        assert_eq!(w.metrics.dwq_peak, 1);
        post_triggered_send(w, core, trig, 1, env, BufSlice::whole(src, 8), Done::none(), None);
        core.schedule(1_000, Box::new(move |_, c| c.write_cell(trig, 1)));
        // Once the trigger has fired the descriptor has left the DWQ.
        core.schedule(
            100_000,
            Box::new(|w, core| {
                assert!(dwq_reserve(w, core, 0).is_ok(), "slot must be free after the trigger");
            }),
        );
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(w.metrics.dwq_triggered, 1);
}

/// Triggered receives: the descriptor is armed against the counter, the
/// NIC posts it into the matching engine only after the threshold, and
/// a matching posted-path delivery lands with no host involvement.
#[test]
fn triggered_recv_defers_until_threshold() {
    let eng = engine(2, 1);
    let landed_at = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let la = landed_at.clone();
    eng.setup(|w, core| {
        let src = w.bufs.alloc_init(vec![3.5; 16]);
        let dst = w.bufs.alloc(16);
        let trig = alloc_counter(w, core, 1, "rt").unwrap();
        let env = Envelope { src_rank: 0, dst_rank: 1, tag: 8, comm: 0, elems: 16 };
        post_triggered_recv(
            w,
            core,
            trig,
            1,
            1,
            0,
            8,
            0,
            BufSlice::whole(dst, 16),
            Done::call(Box::new(move |w, core| {
                assert_eq!(w.bufs.get(crate::world::BufId(1))[0], 3.5);
                *la.lock().unwrap() = core.now();
            })),
            None,
        );
        // The message is sent immediately; the recv descriptor fires
        // only at t = 80_000, so the arrival buffers as unexpected.
        execute_send(w, core, env, BufSlice::whole(src, 16), Done::none());
        core.schedule(80_000, Box::new(move |_, c| c.write_cell(trig, 1)));
    });
    let (w, _) = eng.run().unwrap();
    let t = *landed_at.lock().unwrap();
    assert!(t > 80_000, "landed at {t}, before the recv trigger");
    assert_eq!(w.metrics.unexpected_msgs, 1, "the payload beat the descriptor");
    assert_eq!(w.metrics.triggered_recvs, 1);
    assert_eq!(w.metrics.dwq_triggered, 1, "the recv descriptor fired from the DWQ");
}

/// Triggered receive firing BEFORE the arrival: the descriptor waits in
/// the posted queue and the arrival hardware-matches it directly (no
/// unexpected buffering).
#[test]
fn triggered_recv_before_arrival_matches_posted() {
    let eng = engine(2, 1);
    let got = std::sync::Arc::new(std::sync::Mutex::new(0.0f32));
    let gc = got.clone();
    eng.setup(|w, core| {
        let src = w.bufs.alloc_init(vec![5.0; 8]);
        let dst = w.bufs.alloc(8);
        let trig = alloc_counter(w, core, 1, "rt").unwrap();
        let env = Envelope { src_rank: 0, dst_rank: 1, tag: 2, comm: 0, elems: 8 };
        post_triggered_recv(
            w,
            core,
            trig,
            1,
            1,
            0,
            2,
            0,
            BufSlice::whole(dst, 8),
            Done::call(Box::new(move |w, _| {
                *gc.lock().unwrap() = w.bufs.get(crate::world::BufId(1))[0];
            })),
            None,
        );
        // Trigger at once; the send only starts at t = 100_000.
        core.schedule(0, Box::new(move |_, c| c.write_cell(trig, 1)));
        core.schedule(
            100_000,
            Box::new(move |w: &mut World, c: &mut Ctx| {
                execute_send(w, c, env, BufSlice::whole(src, 8), Done::none());
            }),
        );
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(*got.lock().unwrap(), 5.0);
    assert_eq!(w.metrics.unexpected_msgs, 0, "the descriptor was already posted");
    assert_eq!(w.metrics.matched_posted, 1);
    assert_eq!(w.metrics.triggered_recvs, 1);
}

/// The recv descriptor occupies a DWQ slot until its trigger fires,
/// exactly like a triggered send.
#[test]
fn triggered_recv_releases_dwq_slot_on_fire() {
    let eng = engine(2, 1);
    eng.setup(|w, core| {
        w.cost.dwq_slots_per_nic = 1;
        let src = w.bufs.alloc_init(vec![2.0; 8]);
        let dst = w.bufs.alloc(8);
        let trig = alloc_counter(w, core, 1, "rt").unwrap();
        let env = Envelope { src_rank: 0, dst_rank: 1, tag: 9, comm: 0, elems: 8 };
        assert!(dwq_reserve(w, core, 1).is_ok());
        assert_eq!(dwq_reserve(w, core, 1), Err(DwqFull { node: 1 }), "one slot only");
        post_triggered_recv(
            w,
            core,
            trig,
            1,
            1,
            0,
            9,
            0,
            BufSlice::whole(dst, 8),
            Done::none(),
            None,
        );
        core.schedule(
            1_000,
            Box::new(move |w: &mut World, c: &mut Ctx| {
                execute_send(w, c, env, BufSlice::whole(src, 8), Done::none());
            }),
        );
        core.schedule(2_000, Box::new(move |_, c| c.write_cell(trig, 1)));
        core.schedule(
            200_000,
            Box::new(|w, core| {
                assert!(dwq_reserve(w, core, 1).is_ok(), "slot must be free after the fire");
            }),
        );
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(w.metrics.triggered_recvs, 1);
}

/// Snapshot-and-reset leak audit at the NIC layer: exhaust the hardware
/// counter pool and hit `DwqFull` backpressure, carry the exhausted
/// world through `World::reset`, and verify the next run starts from a
/// full pool — no counter or DWQ slot leaks across the reset boundary.
#[test]
fn reset_restores_counter_and_dwq_capacity_after_exhaustion() {
    let mut cost = presets::frontier_like();
    cost.jitter_sigma = 0.0;
    cost.nic_counter_limit = 3;
    cost.dwq_slots_per_nic = 2;
    let eng = Engine::new(build_world(cost, Topology::new(2, 1)), 1);
    eng.setup(|w, core| {
        for i in 0..3 {
            assert!(alloc_counter(w, core, 0, "x").is_some(), "counter {i} fits the pool");
        }
        assert!(alloc_counter(w, core, 0, "over").is_none(), "pool of 3 must exhaust");
        assert!(dwq_reserve(w, core, 0).is_ok());
        assert!(dwq_reserve(w, core, 0).is_ok());
        assert_eq!(dwq_reserve(w, core, 0), Err(DwqFull { node: 0 }), "DWQ backpressure");
    });
    let (mut w, _) = eng.run().unwrap();
    assert_eq!(w.nics[0].counters_in_use, 3);
    assert_eq!(w.nics[0].dwq_posted, 2);
    let snap = w.snapshot();
    w.reset(&snap);
    assert_eq!(w.nics[0].counters_allocated, 0, "reset returns the whole counter pool");
    assert_eq!(w.nics[0].counters_in_use, 0);
    assert_eq!(w.nics[0].dwq_posted, 0, "reset returns every DWQ slot");
    // The reset world offers full capacity again (fresh core, fresh
    // lazily-created release cell).
    let eng = Engine::new(w, 2);
    eng.setup(|w, core| {
        for i in 0..3 {
            assert!(alloc_counter(w, core, 0, "again").is_some(), "counter {i} after reset");
        }
        assert!(dwq_reserve(w, core, 0).is_ok());
        assert!(dwq_reserve(w, core, 0).is_ok());
        assert_eq!(dwq_reserve(w, core, 0), Err(DwqFull { node: 0 }));
    });
    eng.run().unwrap();
}
