//! Simulated Slingshot-11 NIC: triggered operations / deferred work queues.
//!
//! Implements the hardware contract the paper's ST design builds on
//! (§II-C):
//!
//! * **hardware counters** — allocated per `MPIX_Queue`, mapped into
//!   GPU-CP-visible memory (here: engine cells, so a GPU stream
//!   `writeValue64`, a device-scope store from inside a running kernel
//!   (the KT path, [`crate::gpu::KernelCtx`]), a NIC DWQ atomic, and the
//!   NIC's own deferred-work waiters all alias the *same* word, exactly
//!   like the real counter mapping);
//! * **deferred work queue (DWQ)** — a command descriptor (`DMA desc +
//!   trigger counter + threshold + completion counter`) appended to the
//!   NIC command queue but *not executed* until the trigger counter
//!   reaches the threshold;
//! * supported DWQ ops: tagged sends (what ST uses), plus one-sided put
//!   and fetching/non-fetching atomics (used by the collectives layer);
//! * **triggered receives** ([`post_triggered_recv`]) — absent from the
//!   paper's Slingshot-11 testbed and modeled here after the follow-on
//!   receive-side offload (arXiv 2306.15773, 2406.05594): a fired
//!   descriptor is appended to the matching engine by the NIC's
//!   list-processing engine itself, so matched payloads land without a
//!   host `ResumeHost`. The paper's ST path deliberately does **not**
//!   use them — its receives stay progress-thread emulated (§IV-A2),
//!   which is the penalty the paper measures — while the
//!   kernel-triggered variant rides the hardware path (see
//!   `stx`/DESIGN.md §Triggered receives);
//! * **eager/rendezvous** protocols with hardware tag matching on arrival
//!   (delivery calls into the per-rank matching engine, the moral
//!   equivalent of the NIC's list-processing engine);
//! * **GPU-initiated consumption** ([`gi_consume`]) — the fourth variant
//!   axis (GICC / NVSHMEM-style): device threads build descriptors into
//!   per-thread-block command rings ([`crate::gpu::GiCtx`]) and the NIC
//!   drains them directly — no trigger counters and no pre-armed DWQ
//!   slots, in exchange for per-descriptor device build cost inside the
//!   kernel window.

use crate::fabric::{self, Port, WireTag};
use crate::fault::{LostMsg, WireFault};
use crate::obs::Event;
use crate::sim::CellId;
use crate::world::{ArmedEntry, BufId, Callback, Ctx, World};

/// A contiguous f32 region of a device buffer.
#[derive(Debug, Clone, Copy)]
pub struct BufSlice {
    pub buf: BufId,
    pub off: usize,
    pub elems: usize,
}

impl BufSlice {
    pub fn new(buf: BufId, off: usize, elems: usize) -> Self {
        Self { buf, off, elems }
    }

    pub fn whole(buf: BufId, elems: usize) -> Self {
        Self { buf, off: 0, elems }
    }

    pub fn bytes(&self) -> usize {
        self.elems * 4
    }
}

/// Two-sided message envelope.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Envelope {
    pub src_rank: usize,
    pub dst_rank: usize,
    pub tag: i32,
    pub comm: u16,
    pub elems: usize,
}

/// Completion actions attached to an operation: counter cells to bump
/// (each by 1) plus an optional callback.
pub struct Done {
    pub cells: Vec<CellId>,
    pub cb: Option<Callback>,
}

impl Done {
    pub fn none() -> Self {
        Self { cells: Vec::new(), cb: None }
    }

    pub fn cell(c: CellId) -> Self {
        Self { cells: vec![c], cb: None }
    }

    pub fn cells(cs: Vec<CellId>) -> Self {
        Self { cells: cs, cb: None }
    }

    pub fn call(cb: Callback) -> Self {
        Self { cells: Vec::new(), cb: Some(cb) }
    }

    pub fn fire(self, w: &mut World, core: &mut Ctx) {
        for c in self.cells {
            core.add_cell(c, 1);
        }
        if let Some(cb) = self.cb {
            cb(w, core);
        }
    }

    /// Schedule this completion at absolute virtual time `t`. Single-cell
    /// completions (the dominant shape: request "done" counters) go
    /// through the engine's typed event path — no closure allocation;
    /// multi-cell or callback-carrying completions keep the boxed path so
    /// all their effects stay atomic within one event.
    pub fn schedule_fire_at(self, core: &mut Ctx, t: crate::sim::Time) {
        if self.cb.is_none() {
            match self.cells.len() {
                0 => {} // nothing to do — skip the event entirely
                1 => core.schedule_cell_add_at(t, self.cells[0], 1),
                _ => core.schedule_at(t, Box::new(move |w, core| self.fire(w, core))),
            }
        } else {
            core.schedule_at(t, Box::new(move |w, core| self.fire(w, core)));
        }
    }
}

impl std::fmt::Debug for Done {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Done({} cells, cb={})", self.cells.len(), self.cb.is_some())
    }
}

/// What arrives at a destination NIC for the matching engine.
pub enum WireMsg {
    /// Eager: the payload travelled with the envelope. `seq` is the wire
    /// sequence number for idempotent duplicate resolution (0 =
    /// unsequenced, assigned only while a fault plan is active; a
    /// duplicate or redundant retransmit carries the original's `seq`
    /// and is discarded by the matching engine).
    Eager { env: Envelope, payload: Vec<f32>, seq: u64 },
    /// Rendezvous RTS: payload stays at the source until matched.
    Rts { env: Envelope, src: BufSlice, src_node: usize, src_done: Done },
}

impl WireMsg {
    pub fn env(&self) -> &Envelope {
        match self {
            WireMsg::Eager { env, .. } => env,
            WireMsg::Rts { env, .. } => env,
        }
    }
}

/// The simulated NIC (one per node, as on the testbed).
pub struct Nic {
    pub node: usize,
    pub port: Port,
    /// Total hardware counters ever handed out (diagnostics; the
    /// counters themselves are engine cells).
    pub counters_allocated: usize,
    /// Counters currently held by live queues — bounded by
    /// `cost.nic_counter_limit` (finite hardware pool, §II-C).
    pub counters_in_use: usize,
    /// Deferred-work-queue descriptors ever posted to this NIC. Together
    /// with [`Nic::dwq_released`] this tracks DWQ occupancy:
    /// `in_use = dwq_posted - cell(dwq_released)`.
    pub dwq_posted: u64,
    /// Cell counting DWQ descriptors released (trigger fired, descriptor
    /// left the queue). A cell — not a plain counter — so hosts blocked
    /// on a full DWQ can wait for the next release. Lazily allocated.
    pub dwq_released: Option<CellId>,
}

impl Nic {
    pub fn new(node: usize) -> Self {
        Self {
            node,
            port: Port::default(),
            counters_allocated: 0,
            counters_in_use: 0,
            dwq_posted: 0,
            dwq_released: None,
        }
    }

    /// Rewind to the just-built state (part of
    /// [`crate::world::World::reset`]): port busy-until times, the full
    /// hardware counter pool, and the whole DWQ slot pool come back —
    /// including slots a leaked or force-freed descriptor still held —
    /// because the next run gets a fresh engine core and fresh cells.
    /// `dwq_released` refers to a cell of the *previous* run's core, so
    /// it must be dropped here (the next run lazily re-creates it with
    /// an identical cell id, keeping reset runs byte-identical to cold
    /// ones).
    pub fn reset(&mut self) {
        self.port = Port::default();
        self.counters_allocated = 0;
        self.counters_in_use = 0;
        self.dwq_posted = 0;
        self.dwq_released = None;
    }
}

/// Allocate a NIC hardware counter, mapped GPU-visible (an engine cell).
/// Returns `None` when the node's finite counter pool
/// (`cost.nic_counter_limit`) is exhausted; [`release_counter`] returns
/// capacity to the pool.
pub fn alloc_counter(w: &mut World, core: &mut Ctx, node: usize, name: &str) -> Option<CellId> {
    if w.nics[node].counters_in_use >= w.cost.nic_counter_limit {
        return None;
    }
    w.nics[node].counters_in_use += 1;
    w.nics[node].counters_allocated += 1;
    Some(core.new_cell(format!("nic{node}.ctr.{name}"), 0))
}

/// Return one hardware counter to `node`'s pool. The engine cell itself
/// is not recycled (cells are cheap); only the modeled hardware capacity
/// is.
pub fn release_counter(w: &mut World, node: usize) {
    let n = &mut w.nics[node].counters_in_use;
    debug_assert!(*n > 0, "release_counter without a matching alloc");
    *n = n.saturating_sub(1);
}

/// A DWQ slot reservation failed: `node`'s deferred-work queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DwqFull {
    pub node: usize,
}

/// The cell counting DWQ descriptors released on `node` (lazily
/// allocated). Blocked producers wait on this to observe the next free
/// slot.
pub fn dwq_released_cell(w: &mut World, core: &mut Ctx, node: usize) -> CellId {
    if let Some(c) = w.nics[node].dwq_released {
        return c;
    }
    let c = core.new_cell(format!("nic{node}.dwq.released"), 0);
    w.nics[node].dwq_released = Some(c);
    c
}

/// Reserve one DWQ descriptor slot on `node` for a deferred operation.
/// Fails when occupancy has reached `cost.dwq_slots_per_nic`; the caller
/// owns the slot until the descriptor's trigger fires
/// ([`post_triggered_send`] releases it). Also maintains the
/// `Metrics::dwq_peak` high-water mark (HTQ pressure).
pub fn dwq_reserve(w: &mut World, core: &mut Ctx, node: usize) -> Result<(), DwqFull> {
    let released = match w.nics[node].dwq_released {
        Some(c) => core.cell(c),
        None => 0,
    };
    let in_use = w.nics[node].dwq_posted.saturating_sub(released);
    if in_use >= w.cost.dwq_slots_per_nic as u64 {
        return Err(DwqFull { node });
    }
    // Allocate the release cell eagerly so a later full-DWQ producer has
    // something to wait on, and the descriptor's own release is a plain
    // cell add.
    dwq_released_cell(w, core, node);
    w.nics[node].dwq_posted += 1;
    if in_use + 1 > w.metrics.dwq_peak {
        w.metrics.dwq_peak = in_use + 1;
    }
    core.trace_push(Event::DwqReserve {
        t: core.now(),
        node: node as u32,
        in_use: (in_use + 1) as u32,
    });
    Ok(())
}

/// Cancel-and-release one armed DWQ descriptor slot on `node` without a
/// trigger fire: credits the released cell exactly as a fired trigger
/// would, so producers blocked on a full DWQ observe the freed slot.
/// Used by the force-free recovery path for queues abandoned after a
/// watchdog timeout (their triggers will never fire).
pub fn dwq_cancel(w: &mut World, core: &mut Ctx, node: usize) {
    let rel = dwq_released_cell(w, core, node);
    core.trace_push(Event::DwqRelease { t: core.now(), node: node as u32 });
    core.add_cell(rel, 1);
}

/// Origin of a deferred descriptor, for stall diagnosis: which stx queue
/// (and what logical slot/operation) armed it. Carried into the
/// [`crate::world::ArmedRegistry`] so a [`crate::sim::StallReport`] can
/// name the exact queue and slot of every descriptor that never fired.
#[derive(Debug, Clone)]
pub struct DwqOrigin {
    /// Owning stx queue id, when armed by a queue.
    pub queue: Option<usize>,
    /// Human label, e.g. `q3 slot 1 plan-send`.
    pub label: String,
}

/// Track an armed descriptor in the world registry; the returned token is
/// cleared by the trigger-fire callback.
fn register_armed(w: &mut World, node: usize, origin: Option<DwqOrigin>, desc: &str) -> usize {
    let (queue, label) = match origin {
        Some(o) => (o.queue, format!("{desc} [{}]", o.label)),
        None => (None, desc.to_string()),
    };
    w.armed.register(ArmedEntry { node, queue, desc: label })
}

/// Extra ns a tripped descriptor waits before firing (fault injection;
/// 0 whenever no plan is active).
fn trigger_fire_extra(w: &mut World) -> u64 {
    let extra = match w.fault.as_mut() {
        Some(f) => f.plan.trigger_extra(),
        None => 0,
    };
    if extra > 0 {
        w.metrics.faults_injected += 1;
    }
    extra
}

/// Post a *triggered* tagged send to the NIC command queue: it executes
/// when `trigger >= threshold` (paper §II-C). The payload is read from
/// GPU memory at execution time (RDMA), so kernels may mutate the buffer
/// up to the stream-ordered trigger write — the exact semantics §III-B2
/// requires. `origin` labels the descriptor in stall reports.
#[allow(clippy::too_many_arguments)]
pub fn post_triggered_send(
    w: &mut World,
    core: &mut Ctx,
    trigger: CellId,
    threshold: u64,
    env: Envelope,
    src: BufSlice,
    send_done: Done,
    origin: Option<DwqOrigin>,
) {
    let src_node = w.topo.node_of(env.src_rank);
    debug_assert!(
        !w.topo.same_node(env.src_rank, env.dst_rank),
        "triggered sends are inter-node; intra-node ST is progress-thread emulated"
    );
    let desc = format!(
        "nic{src_node} DWQ send {}->{} tag {}",
        env.src_rank, env.dst_rank, env.tag
    );
    if core.trace_on() {
        let label = core.trace_intern(&desc);
        core.trace_push(Event::TriggerArm {
            t: core.now(),
            node: src_node as u32,
            threshold,
            label,
        });
    }
    let token = register_armed(w, src_node, origin, &desc);
    core.on_ge(
        trigger,
        threshold,
        desc,
        Box::new(move |w, core| {
            w.armed.clear(token);
            w.metrics.dwq_triggered += 1;
            // The descriptor leaves the deferred-work queue: return its
            // slot (see `dwq_reserve`; callers that never reserved are
            // tolerated — occupancy saturates at zero).
            let rel = dwq_released_cell(w, core, src_node);
            core.trace_push(Event::DwqRelease { t: core.now(), node: src_node as u32 });
            core.add_cell(rel, 1);
            let lat = w.cost.nic_trigger_latency + trigger_fire_extra(w);
            core.trace_push(Event::TriggerFire {
                t0: core.now(),
                dur: lat,
                node: src_node as u32,
            });
            core.schedule(
                lat,
                Box::new(move |w, core| execute_send(w, core, env, src, send_done)),
            );
        }),
    );
}

/// Immediately execute a tagged send (the standard `MPI_Isend` data path
/// once the host has posted the command). Returns nothing; completion is
/// signalled through `send_done`.
pub fn execute_send(w: &mut World, core: &mut Ctx, env: Envelope, src: BufSlice, send_done: Done) {
    let src_node = w.topo.node_of(env.src_rank);
    let dst_node = w.topo.node_of(env.dst_rank);
    let bytes = src.bytes();
    let proc_delay = w.cost.jittered(w.cost.nic_proc, core.rng());
    if w.cost.is_rendezvous(bytes) {
        w.metrics.rendezvous_sends += 1;
        // RTS control message (tiny).
        core.schedule(
            proc_delay,
            Box::new(move |w, core| {
                // Rendezvous-path fault decision. `FaultPlan::rdv_drop`
                // consumes a draw only when `rdv_drop_prob` is set, so
                // eager-only specs keep their exact decision streams.
                if w.fault.as_mut().is_some_and(|f| f.plan.rdv_drop()) {
                    // The RTS leaves the source port (the NIC believes
                    // it sent) but vanishes in the fabric: the receiver
                    // never learns the payload exists — the silent-hang
                    // scenario — until the stx watchdog replays the
                    // send descriptor from the lost ledger. The payload
                    // itself never moved (it only travels on the Get
                    // pull), so only the descriptor is recorded.
                    w.metrics.faults_injected += 1;
                    if let Some(f) = w.fault.as_mut() {
                        f.lost.push(LostMsg::Rts {
                            env,
                            src,
                            src_node,
                            dst_node,
                            src_done: send_done,
                        });
                    }
                    fabric::transfer_tagged(
                        w,
                        core,
                        src_node,
                        dst_node,
                        64, // RTS descriptor size
                        WireTag { src_rank: env.src_rank as u32, retransmit: false },
                        Box::new(|_, _| {}),
                    );
                    return;
                }
                let msg = WireMsg::Rts { env, src, src_node, src_done: send_done };
                let match_cost = w.cost.nic_match;
                fabric::transfer_tagged(
                    w,
                    core,
                    src_node,
                    dst_node,
                    64, // RTS descriptor size
                    WireTag { src_rank: env.src_rank as u32, retransmit: false },
                    Box::new(move |w, core| {
                        core.schedule(
                            match_cost,
                            Box::new(move |w2, c2| crate::mpi::deliver_from_wire(w2, c2, msg)),
                        );
                        let _ = w;
                    }),
                );
            }),
        );
    } else {
        w.metrics.eager_sends += 1;
        core.schedule(
            proc_delay,
            Box::new(move |w, core| {
                // Snapshot the payload at DMA time (empty in Modeled mode).
                let payload = if w.is_real() {
                    w.bufs.get(src.buf)[src.off..src.off + src.elems].to_vec()
                } else {
                    Vec::new()
                };
                // Fault decision — inert (seq 0, WireFault::None, zero
                // extra draws) when no plan is active. Eager payloads
                // take the full drop/dup/delay menu; the rendezvous
                // path has its own RTS-drop site above (DESIGN.md
                // §Fault model).
                let mut seq = 0u64;
                let mut fault = WireFault::None;
                if let Some(f) = w.fault.as_mut() {
                    seq = f.next_seq();
                    fault = f.plan.wire_fault();
                }
                match fault {
                    WireFault::None => {
                        eager_wire_send(
                            w, core, env, payload, seq, src_node, dst_node, bytes, send_done,
                            0, true, false,
                        );
                    }
                    WireFault::Drop => {
                        // The payload still leaves the source port (the
                        // NIC believes it sent) but vanishes in the
                        // fabric; the stx watchdog replays it from the
                        // lost ledger.
                        w.metrics.faults_injected += 1;
                        if let Some(f) = w.fault.as_mut() {
                            f.lost.push(LostMsg::Eager {
                                env,
                                payload: payload.clone(),
                                seq,
                                src_node,
                                dst_node,
                                bytes,
                            });
                        }
                        eager_wire_send(
                            w, core, env, payload, seq, src_node, dst_node, bytes, send_done,
                            0, false, false,
                        );
                    }
                    WireFault::Dup => {
                        // Two copies, one sequence number: the matching
                        // engine delivers the first and discards the
                        // second (idempotent duplicate resolution).
                        w.metrics.faults_injected += 1;
                        eager_wire_send(
                            w,
                            core,
                            env,
                            payload.clone(),
                            seq,
                            src_node,
                            dst_node,
                            bytes,
                            send_done,
                            0,
                            true,
                            false,
                        );
                        eager_wire_send(
                            w,
                            core,
                            env,
                            payload,
                            seq,
                            src_node,
                            dst_node,
                            bytes,
                            Done::none(),
                            0,
                            true,
                            false,
                        );
                    }
                    WireFault::Delay(extra) => {
                        w.metrics.faults_injected += 1;
                        eager_wire_send(
                            w, core, env, payload, seq, src_node, dst_node, bytes, send_done,
                            extra, true, false,
                        );
                    }
                }
            }),
        );
    }
}

/// Put one eager payload on the wire: fabric transfer (optionally
/// entering `extra_ns` late), remote delivery into the matching engine
/// (unless `deliver` is false — a dropped message occupies the ports but
/// vanishes before matching), and local completion through `send_done`.
/// Shared by the normal path, every wire-fault flavor, and watchdog
/// retransmits (which set `retransmit` so the trace's wire spans carry
/// the replay provenance). With `extra_ns == 0` and `deliver == true`
/// the event sequence is identical to the pre-fault-layer code path.
#[allow(clippy::too_many_arguments)]
fn eager_wire_send(
    w: &mut World,
    core: &mut Ctx,
    env: Envelope,
    payload: Vec<f32>,
    seq: u64,
    src_node: usize,
    dst_node: usize,
    bytes: usize,
    send_done: Done,
    extra_ns: u64,
    deliver: bool,
    retransmit: bool,
) {
    let match_cost = w.cost.nic_match;
    let cb: Callback = if deliver {
        let msg = WireMsg::Eager { env, payload, seq };
        Box::new(move |w, core| {
            core.schedule(
                match_cost,
                Box::new(move |w2, c2| crate::mpi::deliver_from_wire(w2, c2, msg)),
            );
            let _ = w;
        })
    } else {
        Box::new(|_, _| {})
    };
    fabric::transfer_delayed_tagged(
        w,
        core,
        src_node,
        dst_node,
        bytes,
        WireTag { src_rank: env.src_rank as u32, retransmit },
        extra_ns,
        cb,
        Box::new(move |w, core, left_src| {
            // Local send completion: payload has left the NIC.
            let comp = left_src + w.cost.nic_completion;
            send_done.schedule_fire_at(core, comp);
            let _ = w;
        }),
    );
}

/// Replay a dropped message from the lost ledger (stx watchdog
/// recovery). Retransmits bypass further fault injection — they always
/// reach the destination — so bounded retries converge. For eager
/// payloads the receiver's sequence dedup makes a redundant replay
/// harmless, and only remote delivery is replayed (local completion
/// already fired at the original send). For a dropped rendezvous RTS
/// the whole control message is replayed — the source completion rides
/// in it and fires exactly once, when the matched receiver's Get pull
/// finally drains the payload.
pub fn retransmit(w: &mut World, core: &mut Ctx, lost: LostMsg) {
    w.metrics.retries += 1;
    match lost {
        LostMsg::Eager { env, payload, seq, src_node, dst_node, bytes } => {
            eager_wire_send(
                w,
                core,
                env,
                payload,
                seq,
                src_node,
                dst_node,
                bytes,
                Done::none(),
                0,
                true,
                true,
            );
        }
        LostMsg::Rts { env, src, src_node, dst_node, src_done } => {
            let msg = WireMsg::Rts { env, src, src_node, src_done };
            let match_cost = w.cost.nic_match;
            fabric::transfer_tagged(
                w,
                core,
                src_node,
                dst_node,
                64, // RTS descriptor size
                WireTag { src_rank: env.src_rank as u32, retransmit: true },
                Box::new(move |w, core| {
                    core.schedule(
                        match_cost,
                        Box::new(move |w2, c2| crate::mpi::deliver_from_wire(w2, c2, msg)),
                    );
                    let _ = w;
                }),
            );
        }
    }
}

/// Post a *triggered* tagged receive to the NIC command queue: when
/// `trigger >= threshold`, the NIC's list-processing engine appends the
/// receive descriptor to `rank`'s matching engine itself — no host
/// `ResumeHost`, no progress thread. Interleavings with early arrivals
/// resolve through the standard unexpected-message queue: a payload that
/// beat the descriptor is consumed at post time, exactly as if a host
/// had posted the receive. Wildcards are not supported (deferred
/// descriptors carry concrete selectors, §III-D).
///
/// The caller owns a DWQ descriptor slot until the trigger fires (see
/// [`dwq_reserve`]); like [`post_triggered_send`], the fire releases it.
/// `done` fires when the matched payload has landed in `dst`.
#[allow(clippy::too_many_arguments)]
pub fn post_triggered_recv(
    w: &mut World,
    core: &mut Ctx,
    trigger: CellId,
    threshold: u64,
    rank: usize,
    src_rank: usize,
    tag: i32,
    comm: u16,
    dst: BufSlice,
    done: Done,
    origin: Option<DwqOrigin>,
) {
    let node = w.topo.node_of(rank);
    let desc = format!("nic{node} DWQ recv r{rank} from {src_rank} tag {tag}");
    if core.trace_on() {
        let label = core.trace_intern(&desc);
        core.trace_push(Event::TriggerArm { t: core.now(), node: node as u32, threshold, label });
    }
    let token = register_armed(w, node, origin, &desc);
    core.on_ge(
        trigger,
        threshold,
        desc,
        Box::new(move |w, core| {
            w.armed.clear(token);
            w.metrics.dwq_triggered += 1;
            // The descriptor leaves the deferred-work queue: return its
            // slot (callers that never reserved are tolerated, as with
            // triggered sends).
            let rel = dwq_released_cell(w, core, node);
            core.trace_push(Event::DwqRelease { t: core.now(), node: node as u32 });
            core.add_cell(rel, 1);
            let lat = w.cost.nic_trigger_latency + w.cost.nic_recv_post + trigger_fire_extra(w);
            core.trace_push(Event::TriggerFire { t0: core.now(), dur: lat, node: node as u32 });
            core.schedule(
                lat,
                Box::new(move |w, core| {
                    execute_recv_post(w, core, rank, src_rank, tag, comm, dst, done)
                }),
            );
        }),
    );
}

/// Immediately append a receive descriptor to `rank`'s matching engine
/// on the NIC's behalf (the list-engine append both NIC-driven receive
/// paths share): consumes a matching unexpected message if one already
/// arrived, lands in the posted-receive queue otherwise. Shared by the
/// deferred DWQ path ([`post_triggered_recv`]) and the kernel-triggered
/// doorbell path ([`crate::gpu::KtAction::PostRecv`]).
#[allow(clippy::too_many_arguments)]
pub fn execute_recv_post(
    w: &mut World,
    core: &mut Ctx,
    rank: usize,
    src_rank: usize,
    tag: i32,
    comm: u16,
    dst: BufSlice,
    done: Done,
) {
    w.metrics.triggered_recvs += 1;
    core.trace_push(Event::RecvPost {
        t: core.now(),
        rank: rank as u32,
        node: w.topo.node_of(rank) as u32,
    });
    crate::mpi::post_recv(
        w,
        core,
        rank,
        crate::mpi::SrcSel::Rank(src_rank),
        crate::mpi::TagSel::Tag(tag),
        comm,
        dst,
        done,
    );
}

/// Consume one GPU-initiated command-ring descriptor chain (the GI
/// variant's NIC path, [`crate::gpu::GiCtx`]): the kernel's closing
/// wavefronts built `chunks` ring descriptors; the NIC fetches the
/// chain — charged `nic_cmd_post + nic_proc` like any doorbell'd
/// command — and executes the action. No trigger counter, no threshold,
/// and crucially **no pre-armed DWQ slot**: GI dodges the KT
/// total-DWQ-demand caveat entirely, paying the per-descriptor device
/// build cost (`cost.gi_descr_build_ns`, inside the kernel window)
/// instead. Sends route by locality through [`crate::mpi::do_send`]
/// (eager/rendezvous over the wire with the full wire-fault menu, IPC
/// intra-node); receives take the shared list-engine append
/// ([`execute_recv_post`]) after the receive-descriptor charge.
pub fn gi_consume(w: &mut World, core: &mut Ctx, chunks: u64, action: crate::gpu::GiAction) {
    w.metrics.gi_posts += chunks;
    let lat = w.cost.nic_cmd_post + w.cost.nic_proc;
    match action {
        crate::gpu::GiAction::Send { env, src, done } => {
            core.schedule(
                lat,
                Box::new(move |w, core| crate::mpi::do_send(w, core, env, src, done)),
            );
        }
        crate::gpu::GiAction::Recv(r) => {
            let lat = lat + w.cost.nic_recv_post;
            core.schedule(
                lat,
                Box::new(move |w, core| {
                    execute_recv_post(w, core, r.rank, r.src_rank, r.tag, r.comm, r.dst, r.done)
                }),
            );
        }
    }
}

/// Issue the rendezvous Get: the destination NIC (having matched an RTS)
/// pulls `src` from `src_node` into `dst`. Fires `recv_done` locally and
/// `src_done` at the source when the pull completes.
pub fn rendezvous_get(
    w: &mut World,
    core: &mut Ctx,
    src_node: usize,
    dst_node: usize,
    src: BufSlice,
    dst: BufSlice,
    src_done: Done,
    recv_done: Done,
) {
    debug_assert_eq!(src.elems, dst.elems, "rendezvous size mismatch");
    // CTS/Get request travels back to the source...
    let ctrl = w.cost.rendezvous_ctrl;
    core.schedule(
        ctrl,
        Box::new(move |w, core| {
            fabric::transfer(
                w,
                core,
                dst_node,
                src_node,
                64, // Get descriptor
                Box::new(move |w, core| {
                    // ...source NIC streams the data to the destination.
                    let payload = if w.is_real() {
                        w.bufs.get(src.buf)[src.off..src.off + src.elems].to_vec()
                    } else {
                        Vec::new()
                    };
                    let bytes = src.bytes();
                    let left_src = fabric::transfer(
                        w,
                        core,
                        src_node,
                        dst_node,
                        bytes,
                        Box::new(move |w, core| {
                            if w.is_real() {
                                let dstbuf = w.bufs.get_mut(dst.buf);
                                dstbuf[dst.off..dst.off + dst.elems].copy_from_slice(&payload);
                            }
                            recv_done.fire(w, core);
                        }),
                    );
                    // Source-side completion when the data has left.
                    let comp = left_src + w.cost.nic_completion;
                    src_done.schedule_fire_at(core, comp);
                }),
            );
        }),
    );
}

/// One-sided put with deferred-execution support (DWQ RMA), used by the
/// collectives layer. Writes `src` (read at execution time) into
/// `dst` on `dst_rank`'s buffer space, then fires `done` at the target
/// and `src_done` locally.
#[allow(clippy::too_many_arguments)]
pub fn post_triggered_put(
    w: &mut World,
    core: &mut Ctx,
    trigger: CellId,
    threshold: u64,
    src_rank: usize,
    dst_rank: usize,
    src: BufSlice,
    dst: BufSlice,
    src_done: Done,
    dst_done: Done,
) {
    let src_node = w.topo.node_of(src_rank);
    let desc = format!("nic{src_node} DWQ put {src_rank}->{dst_rank}");
    if core.trace_on() {
        let label = core.trace_intern(&desc);
        core.trace_push(Event::TriggerArm {
            t: core.now(),
            node: src_node as u32,
            threshold,
            label,
        });
    }
    let token = register_armed(w, src_node, None, &desc);
    core.on_ge(
        trigger,
        threshold,
        desc,
        Box::new(move |w, core| {
            w.armed.clear(token);
            w.metrics.dwq_triggered += 1;
            let lat = w.cost.nic_trigger_latency + w.cost.nic_proc + trigger_fire_extra(w);
            core.trace_push(Event::TriggerFire {
                t0: core.now(),
                dur: lat,
                node: src_node as u32,
            });
            core.schedule(
                lat,
                Box::new(move |w, core| {
                    execute_put(w, core, src_rank, dst_rank, src, dst, src_done, dst_done);
                }),
            );
        }),
    );
}

/// Immediately execute a one-sided put whose descriptor has already been
/// validated: snapshot `src` now (DMA-time read), move it to `dst_rank`'s
/// node over the loopback DMA engine or the fabric, then fire `dst_done`
/// at the target and `src_done` at the source. Shared by the deferred
/// DWQ path ([`post_triggered_put`]) and the kernel-triggered doorbell
/// path ([`crate::gpu::KtAction::Put`]).
#[allow(clippy::too_many_arguments)]
pub fn execute_put(
    w: &mut World,
    core: &mut Ctx,
    src_rank: usize,
    dst_rank: usize,
    src: BufSlice,
    dst: BufSlice,
    src_done: Done,
    dst_done: Done,
) {
    let src_node = w.topo.node_of(src_rank);
    let dst_node = w.topo.node_of(dst_rank);
    let payload = if w.is_real() {
        w.bufs.get(src.buf)[src.off..src.off + src.elems].to_vec()
    } else {
        Vec::new()
    };
    if src_node == dst_node {
        // Loopback put through the local DMA engine.
        let dur = w.cost.ipc_time(src.bytes());
        core.schedule(
            dur,
            Box::new(move |w, core| {
                if w.is_real() {
                    let d = w.bufs.get_mut(dst.buf);
                    d[dst.off..dst.off + dst.elems].copy_from_slice(&payload);
                }
                dst_done.fire(w, core);
                src_done.fire(w, core);
            }),
        );
    } else {
        let left = fabric::transfer_tagged(
            w,
            core,
            src_node,
            dst_node,
            src.bytes(),
            WireTag { src_rank: src_rank as u32, retransmit: false },
            Box::new(move |w, core| {
                if w.is_real() {
                    let d = w.bufs.get_mut(dst.buf);
                    d[dst.off..dst.off + dst.elems].copy_from_slice(&payload);
                }
                dst_done.fire(w, core);
            }),
        );
        let comp = left + w.cost.nic_completion;
        src_done.schedule_fire_at(core, comp);
    }
}

/// Triggered non-fetching atomic add into a counter cell on reaching the
/// trigger threshold (DWQ atomics, §II-C list item 3).
pub fn post_triggered_atomic_add(
    w: &mut World,
    core: &mut Ctx,
    trigger: CellId,
    threshold: u64,
    target: CellId,
    value: u64,
) {
    let token = register_armed(w, 0, None, "DWQ atomic add");
    core.on_ge(
        trigger,
        threshold,
        "DWQ atomic add".to_string(),
        Box::new(move |w, core| {
            w.armed.clear(token);
            w.metrics.dwq_triggered += 1;
            let lat = w.cost.nic_trigger_latency + w.cost.nic_proc;
            // Typed event: the deferred atomic is exactly a cell add.
            core.schedule_cell_add(lat, target, value);
        }),
    );
}

#[cfg(test)]
mod tests;
