//! Deterministic event tracing and overlap analytics.
//!
//! The paper family's thesis is not "ST finishes first" but "ST/KT
//! *hide* communication behind kernels" (the MPI+X triggering taxonomy,
//! arXiv 2406.05594, and the GPU-centric communication survey, arXiv
//! 2503.24230, both evaluate these designs via timeline decomposition
//! and overlap ratios). This module supplies the per-event visibility
//! that makes the metric computable:
//!
//! * [`TraceBuf`] — a bounded, sim-time-stamped structured recorder
//!   stored inside the engine core ([`crate::sim::Core`]). Recording is
//!   **off by default** at the `Core` level (a single `Option` branch on
//!   every emit site — the compile-free runtime off-switch whose
//!   disabled cost is pinned by `benches/engine.rs`); workload runs
//!   enable it through `World::trace_cap` unless `STMPI_TRACE=0`.
//! * [`Event`] — the closed event taxonomy (host park/resume, microtask
//!   dispatch, kernel windows, KT doorbells, trigger arm/fire, DWQ
//!   reserve/release/backpressure, wire egress/ingress occupancy,
//!   matching-engine match/unexpected, triggered-receive posts). Events
//!   are fixed-size and heap-free; repeated labels go through a small
//!   interned string table.
//! * [`chrome_trace`] — Chrome trace-event JSON export (Perfetto /
//!   `chrome://tracing` loadable): one process per node plus an engine
//!   process, one thread per host / stream / NIC facility.
//! * [`achieved_overlap`] — communication hidden ÷ communication total,
//!   from wire-egress-span ∩ kernel-span interval overlap on the source
//!   node. Surfaced as `overlap_pct` in campaign reports.
//! * [`critical_path`] — a deterministic makespan decomposition into
//!   compute / wire / trigger-latency / backpressure-wait / retransmit /
//!   other buckets along the last-finishing rank's blocking timeline
//!   (the longest chain approximation; see DESIGN.md §Observability).
//!
//! # Determinism contract
//!
//! Every event is appended while the engine's big lock is held, in the
//! strict driver/host token order the engine already guarantees, and is
//! stamped with virtual (not wall-clock) time. String-table ids are
//! assigned in first-emission order. Consequently a trace — and every
//! analytics result and exported byte derived from it — is
//! byte-identical across reruns and across any `STMPI_SWEEP_THREADS`
//! setting (each cell's run is single-token regardless of sweep
//! parallelism). `tests/determinism.rs` pins this.

use crate::coordinator::report::json_escape;

/// Virtual time in nanoseconds (mirrors [`crate::sim::Time`]; duplicated
/// here so `obs` stays dependency-free of `sim`).
pub type Time = u64;

/// Interned-string handle into [`TraceBuf::strings`].
pub type StrId = u32;

/// Sentinel [`StrId`] meaning "no label".
pub const NO_STR: StrId = u32::MAX;

/// Sentinel rank meaning "rank unknown" (e.g. wire traffic emitted
/// below the layer that knows the owning rank).
pub const NO_RANK: u32 = u32::MAX;

/// Why a host actor parked (see [`Event::HostPark`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParkKind {
    /// `advance(dt)`: charged host CPU time, resume already in the heap.
    Advance,
    /// `wait_ge`: blocked on a counter cell threshold.
    WaitCell,
}

/// Which half of a wire transfer a [`Event::Wire`] span occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireDir {
    /// Serialization through the source node's egress port.
    Egress,
    /// Serialization through the destination node's ingress port.
    Ingress,
}

/// What a kernel-triggered doorbell ring carried ([`Event::KtDoorbell`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KtKind {
    /// Device-scope counter increment (a KT trigger firing).
    CounterInc,
    /// Device-initiated put descriptor.
    Put,
    /// Device-initiated posted-receive descriptor.
    Recv,
}

/// One trace event. Fixed-size, heap-free; labels are interned
/// ([`TraceBuf::intern`]). Instants carry a single timestamp; spans
/// carry `(t0, dur)` in virtual ns. The full taxonomy table lives in
/// DESIGN.md §Observability.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// Host actor parked (instant; the matching resume closes the gap).
    HostPark {
        /// Park time.
        t: Time,
        /// Host id (== rank under `run_cluster`).
        host: u32,
        /// Why it parked.
        kind: ParkKind,
    },
    /// Host actor handed the execution token (instant).
    HostResume {
        /// Resume time.
        t: Time,
        /// Host id.
        host: u32,
    },
    /// One zero-delay microtask dispatched by the driver loop (instant).
    Microtask {
        /// Dispatch time.
        t: Time,
    },
    /// A kernel's execution window on a GPU stream (span; includes the
    /// CP dispatch cost, matching the cost model's kernel window).
    Kernel {
        /// Window start.
        t0: Time,
        /// Window length.
        dur: u64,
        /// GPU index (== rank: one GPU per rank).
        gpu: u32,
        /// Stream index on that GPU.
        stream: u32,
        /// Interned kernel name.
        name: StrId,
    },
    /// A kernel rang the NIC doorbell from inside its window (instant).
    KtDoorbell {
        /// Ring time (at the trigger fraction of the kernel window).
        t: Time,
        /// GPU index.
        gpu: u32,
        /// What the doorbell carried.
        kind: KtKind,
    },
    /// A triggered operation was armed in a NIC's deferred-work queue
    /// (instant).
    TriggerArm {
        /// Arm time.
        t: Time,
        /// NIC / node index.
        node: u32,
        /// Trigger-counter threshold it waits for.
        threshold: u64,
        /// Interned descriptor label.
        label: StrId,
    },
    /// A trigger fired: span covering the NIC trigger-handshake latency
    /// between counter satisfaction and command execution.
    TriggerFire {
        /// Counter-satisfaction time.
        t0: Time,
        /// Handshake latency (`nic_trigger_latency` + injected extra).
        dur: u64,
        /// NIC / node index.
        node: u32,
    },
    /// A DWQ descriptor slot was reserved (instant).
    DwqReserve {
        /// Reservation time.
        t: Time,
        /// NIC / node index.
        node: u32,
        /// Slots in use after the reservation.
        in_use: u32,
    },
    /// A DWQ descriptor slot returned to the pool (instant).
    DwqRelease {
        /// Release time.
        t: Time,
        /// NIC / node index.
        node: u32,
    },
    /// A host stalled waiting for a free DWQ descriptor slot (span).
    DwqWait {
        /// Stall start.
        t0: Time,
        /// Stall length.
        dur: u64,
        /// The exhausted NIC / node.
        node: u32,
        /// The stalled rank.
        rank: u32,
    },
    /// Wire port occupancy (span): serialization of one message through
    /// an egress or ingress port.
    Wire {
        /// Occupancy start.
        t0: Time,
        /// Serialization time.
        dur: u64,
        /// Source node.
        src_node: u32,
        /// Destination node.
        dst_node: u32,
        /// Payload bytes.
        bytes: u64,
        /// Sending rank ([`NO_RANK`] when unknown at the emit site).
        src_rank: u32,
        /// Egress or ingress half.
        dir: WireDir,
        /// True for watchdog retransmissions of dropped payloads.
        retransmit: bool,
    },
    /// The matching engine matched a message to a posted receive
    /// (instant).
    Match {
        /// Match time.
        t: Time,
        /// Receiving rank.
        rank: u32,
        /// Message tag.
        tag: i32,
    },
    /// A message arrived with no posted receive and was queued
    /// unexpected (instant).
    Unexpected {
        /// Arrival time.
        t: Time,
        /// Receiving rank.
        rank: u32,
        /// Message tag.
        tag: i32,
    },
    /// The NIC list engine posted a triggered-receive descriptor into
    /// the matching engine (instant).
    RecvPost {
        /// Post time.
        t: Time,
        /// Receiving rank.
        rank: u32,
        /// NIC / node index.
        node: u32,
    },
}

impl Event {
    /// The event's (start) timestamp — the sort key used by the
    /// exporter.
    pub fn t(&self) -> Time {
        match *self {
            Event::HostPark { t, .. }
            | Event::HostResume { t, .. }
            | Event::Microtask { t }
            | Event::KtDoorbell { t, .. }
            | Event::TriggerArm { t, .. }
            | Event::DwqReserve { t, .. }
            | Event::DwqRelease { t, .. }
            | Event::Match { t, .. }
            | Event::Unexpected { t, .. }
            | Event::RecvPost { t, .. } => t,
            Event::Kernel { t0, .. }
            | Event::TriggerFire { t0, .. }
            | Event::DwqWait { t0, .. }
            | Event::Wire { t0, .. } => t0,
        }
    }
}

/// Run-level metadata recorded alongside the events (topology mapping
/// for rank→node attribution, plus a human label for the export).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TraceMeta {
    /// Nodes in the run's topology.
    pub nodes: u32,
    /// Ranks per node (rank `r` lives on node `r / ranks_per_node`).
    pub ranks_per_node: u32,
    /// Human label (workload/variant/size), shown in the export header.
    pub label: String,
}

/// The bounded structured-trace recorder. Lives inside
/// [`crate::sim::Core`] as `Option<Box<TraceBuf>>`: `None` is the
/// off-switch (every emit site is a single branch), `Some` records until
/// `cap` events and then counts drops instead of growing (`dropped`) —
/// analytics over a truncated trace cover the recorded prefix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceBuf {
    /// Run metadata (topology mapping + label).
    pub meta: TraceMeta,
    /// Recorded events, in emission (= deterministic engine) order.
    pub events: Vec<Event>,
    /// Interned strings referenced by [`StrId`]s in events.
    pub strings: Vec<String>,
    /// Maximum number of events kept.
    pub cap: usize,
    /// Events discarded after the buffer filled.
    pub dropped: u64,
}

/// Default recorder capacity (events). Small campaign cells record a few
/// thousand events; this bound keeps a pathological run at ~40 MB.
pub const DEFAULT_CAP: usize = 1 << 20;

std::thread_local! {
    /// Per-thread override of the `STMPI_TRACE` switch (see
    /// [`set_recording_override`]).
    static RECORD_OVERRIDE: std::cell::Cell<Option<bool>> =
        const { std::cell::Cell::new(None) };
}

/// Override [`recording_enabled`] for the current thread: `Some(on)`
/// forces the switch, `None` restores the `STMPI_TRACE` environment
/// default. Thread-local on purpose — tests that exercise both the
/// traced and untraced paths (the reset-equivalence blitz) can flip it
/// without racing concurrently running tests the way a process-global
/// `set_var` would.
pub fn set_recording_override(on: Option<bool>) {
    RECORD_OVERRIDE.with(|c| c.set(on));
}

/// The compile-free runtime off-switch for workload-level recording:
/// `STMPI_TRACE=0` disables it (overlap/critical-path report columns
/// render as absent). Any other value — including unset — leaves the
/// default recording on, so campaign reports always carry `overlap_pct`.
/// A thread-local [`set_recording_override`] outranks the environment.
pub fn recording_enabled() -> bool {
    if let Some(on) = RECORD_OVERRIDE.with(|c| c.get()) {
        return on;
    }
    std::env::var("STMPI_TRACE").map(|v| v != "0").unwrap_or(true)
}

impl TraceBuf {
    /// A recorder with the given metadata and capacity.
    pub fn new(meta: TraceMeta, cap: usize) -> Self {
        Self { meta, events: Vec::new(), strings: Vec::new(), cap, dropped: 0 }
    }

    /// Append one event (drops and counts once `cap` is reached).
    #[inline]
    pub fn push(&mut self, ev: Event) {
        if self.events.len() < self.cap {
            self.events.push(ev);
        } else {
            self.dropped += 1;
        }
    }

    /// Intern `s`, returning a stable id (first-emission order; linear
    /// scan — the unique-label population is small).
    pub fn intern(&mut self, s: &str) -> StrId {
        if let Some(i) = self.strings.iter().position(|x| x == s) {
            return i as StrId;
        }
        self.strings.push(s.to_string());
        (self.strings.len() - 1) as StrId
    }

    /// Resolve an interned id (empty string for [`NO_STR`]).
    pub fn lookup(&self, id: StrId) -> &str {
        self.strings.get(id as usize).map(String::as_str).unwrap_or("")
    }

    /// Node hosting `rank` under this trace's topology.
    fn node_of(&self, rank: u32) -> u32 {
        if self.meta.ranks_per_node == 0 {
            0
        } else {
            rank / self.meta.ranks_per_node
        }
    }
}

// ---------------------------------------------------------------------
// Interval arithmetic (the achieved-overlap primitive)
// ---------------------------------------------------------------------

/// Merge half-open intervals `[start, end)` into a disjoint, sorted
/// union. Zero-length and inverted inputs are discarded; adjacent
/// intervals coalesce.
pub fn union_intervals(mut v: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    v.retain(|&(s, e)| e > s);
    v.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(v.len());
    for (s, e) in v {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Length of `span ∩ union`, where `union` is disjoint and sorted (the
/// output shape of [`union_intervals`]).
pub fn overlap_with_union(union: &[(u64, u64)], span: (u64, u64)) -> u64 {
    let (s, e) = span;
    if e <= s {
        return 0;
    }
    // First interval that could intersect: end > s.
    let i = union.partition_point(|&(_, ue)| ue <= s);
    let mut hidden = 0;
    for &(us, ue) in &union[i..] {
        if us >= e {
            break;
        }
        hidden += e.min(ue).saturating_sub(s.max(us));
    }
    hidden
}

// ---------------------------------------------------------------------
// Achieved overlap
// ---------------------------------------------------------------------

/// Achieved communication/computation overlap: of all wire-egress
/// occupancy, how much was hidden behind a kernel executing on the
/// sending node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Overlap {
    /// Total wire-egress occupancy (ns) across the run.
    pub wire_ns: u64,
    /// The part of `wire_ns` during which a kernel was executing on the
    /// source node.
    pub hidden_ns: u64,
}

impl Overlap {
    /// Hidden ÷ total as a percentage in `[0, 100]` (0 when no wire
    /// traffic was recorded).
    pub fn pct(&self) -> f64 {
        if self.wire_ns == 0 {
            0.0
        } else {
            100.0 * self.hidden_ns as f64 / self.wire_ns as f64
        }
    }
}

/// Compute [`Overlap`] from a recorded trace: for every wire-egress span
/// the hidden part is its intersection with the union of kernel windows
/// on the *source* node's GPUs. Returns `None` when the trace recorded
/// no wire-egress spans (intra-node-only or empty runs), so reports can
/// distinguish "no communication" from "0 % hidden".
pub fn achieved_overlap(t: &TraceBuf) -> Option<Overlap> {
    let nodes = t.meta.nodes.max(1) as usize;
    let mut kernels: Vec<Vec<(u64, u64)>> = vec![Vec::new(); nodes];
    for ev in &t.events {
        if let Event::Kernel { t0, dur, gpu, .. } = *ev {
            let n = t.node_of(gpu) as usize;
            if n < nodes {
                kernels[n].push((t0, t0 + dur));
            }
        }
    }
    let unions: Vec<Vec<(u64, u64)>> = kernels.into_iter().map(union_intervals).collect();
    let mut o = Overlap::default();
    let mut saw_wire = false;
    for ev in &t.events {
        if let Event::Wire { t0, dur, src_node, dir: WireDir::Egress, .. } = *ev {
            saw_wire = true;
            o.wire_ns += dur;
            if let Some(u) = unions.get(src_node as usize) {
                o.hidden_ns += overlap_with_union(u, (t0, t0 + dur));
            }
        }
    }
    saw_wire.then_some(o)
}

// ---------------------------------------------------------------------
// Critical-path extraction
// ---------------------------------------------------------------------

/// Deterministic decomposition of a makespan into blocking-activity
/// buckets along one rank's timeline (or the whole run's): at every
/// instant of `[0, finish]` the highest-priority active span category
/// claims the time. Priority (highest first): retransmit, backpressure
/// wait, trigger latency, wire, compute; uncovered time is `other_ns`
/// (host code, progress-thread charges, enqueue gaps).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CritPath {
    /// The decomposed window length (== finish time).
    pub total_ns: u64,
    /// Kernel windows on the subject rank's GPU.
    pub compute_ns: u64,
    /// Wire egress/ingress occupancy touching the subject node.
    pub wire_ns: u64,
    /// NIC trigger-handshake latency on the subject node.
    pub trigger_ns: u64,
    /// Host stalls waiting for DWQ descriptor slots.
    pub backpressure_ns: u64,
    /// Wire occupancy of watchdog-retransmitted payloads.
    pub retransmit_ns: u64,
    /// Uncovered remainder.
    pub other_ns: u64,
}

impl CritPath {
    fn pct(&self, x: u64) -> f64 {
        if self.total_ns == 0 {
            0.0
        } else {
            100.0 * x as f64 / self.total_ns as f64
        }
    }

    /// Compact table cell: `c62/w20/t5/b0/r0/o13` (percent of the
    /// decomposed window per bucket, rounded).
    pub fn md_cell(&self) -> String {
        format!(
            "c{:.0}/w{:.0}/t{:.0}/b{:.0}/r{:.0}/o{:.0}",
            self.pct(self.compute_ns),
            self.pct(self.wire_ns),
            self.pct(self.trigger_ns),
            self.pct(self.backpressure_ns),
            self.pct(self.retransmit_ns),
            self.pct(self.other_ns)
        )
    }

    /// JSON object rendering (used by campaign reports and stall notes).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"total_ns\": {}, \"compute_ns\": {}, \"wire_ns\": {}, \"trigger_ns\": {}, \
             \"backpressure_ns\": {}, \"retransmit_ns\": {}, \"other_ns\": {}}}",
            self.total_ns,
            self.compute_ns,
            self.wire_ns,
            self.trigger_ns,
            self.backpressure_ns,
            self.retransmit_ns,
            self.other_ns
        )
    }

    /// One-line summary for [`crate::sim::StallReport`] notes.
    pub fn headline(&self) -> String {
        format!(
            "trace attribution: compute {:.0}% wire {:.0}% trigger {:.0}% \
             backpressure {:.0}% retransmit {:.0}% other {:.0}%",
            self.pct(self.compute_ns),
            self.pct(self.wire_ns),
            self.pct(self.trigger_ns),
            self.pct(self.backpressure_ns),
            self.pct(self.retransmit_ns),
            self.pct(self.other_ns)
        )
    }
}

/// Bucket priority indices for the sweep (lower wins).
const CAT_RETRANSMIT: usize = 0;
const CAT_BACKPRESSURE: usize = 1;
const CAT_TRIGGER: usize = 2;
const CAT_WIRE: usize = 3;
const CAT_COMPUTE: usize = 4;
const N_CATS: usize = 5;

/// Extract the critical-path bucket decomposition of `[0, finish]`.
///
/// `rank = Some(r)` restricts attribution to rank `r`'s timeline (its
/// GPU's kernels, its node's NIC/wire activity, its own backpressure
/// stalls) — the campaign uses the last-finishing rank, approximating
/// the longest dependency chain. `rank = None` attributes over all
/// nodes at once (used for stall-time attribution, where no rank has
/// finished).
pub fn critical_path(t: &TraceBuf, rank: Option<u32>, finish: Time) -> CritPath {
    let node = rank.map(|r| t.node_of(r));
    let mut spans: Vec<(u64, u64, usize)> = Vec::new();
    let mut add = |t0: Time, dur: u64, cat: usize| {
        let e = (t0 + dur).min(finish);
        if e > t0 {
            spans.push((t0, e, cat));
        }
    };
    for ev in &t.events {
        match *ev {
            Event::Kernel { t0, dur, gpu, .. } => {
                if rank.is_none() || rank == Some(gpu) {
                    add(t0, dur, CAT_COMPUTE);
                }
            }
            Event::Wire { t0, dur, src_node, dst_node, retransmit, .. } => {
                let mine =
                    node.is_none() || node == Some(src_node) || node == Some(dst_node);
                if mine {
                    add(t0, dur, if retransmit { CAT_RETRANSMIT } else { CAT_WIRE });
                }
            }
            Event::TriggerFire { t0, dur, node: n } => {
                if node.is_none() || node == Some(n) {
                    add(t0, dur, CAT_TRIGGER);
                }
            }
            Event::DwqWait { t0, dur, rank: r, .. } => {
                if rank.is_none() || rank == Some(r) {
                    add(t0, dur, CAT_BACKPRESSURE);
                }
            }
            _ => {}
        }
    }
    // Boundary sweep: at each segment between consecutive boundaries the
    // highest-priority active category claims the time.
    let mut points: Vec<(u64, usize, i32)> = Vec::with_capacity(spans.len() * 2);
    for &(s, e, c) in &spans {
        points.push((s, c, 1));
        points.push((e, c, -1));
    }
    points.sort_unstable();
    let mut out = CritPath { total_ns: finish, ..CritPath::default() };
    let mut active = [0i32; N_CATS];
    let mut prev = 0u64;
    let mut covered = 0u64;
    let mut i = 0;
    while i < points.len() {
        let t_here = points[i].0;
        if t_here > prev {
            if let Some(cat) = active.iter().position(|&n| n > 0) {
                let len = t_here.min(finish) - prev.min(finish);
                covered += len;
                match cat {
                    CAT_RETRANSMIT => out.retransmit_ns += len,
                    CAT_BACKPRESSURE => out.backpressure_ns += len,
                    CAT_TRIGGER => out.trigger_ns += len,
                    CAT_WIRE => out.wire_ns += len,
                    _ => out.compute_ns += len,
                }
            }
            prev = t_here;
        }
        while i < points.len() && points[i].0 == t_here {
            active[points[i].1] += points[i].2;
            i += 1;
        }
    }
    out.other_ns = finish.saturating_sub(covered);
    out
}

// ---------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------

/// Render `ns` as Chrome's microsecond timestamps with exact
/// nanosecond precision (`123456` ns → `"123.456"`). Pure integer
/// formatting — byte-deterministic.
fn us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

struct ChromeWriter {
    out: String,
    first: bool,
}

impl ChromeWriter {
    fn event(&mut self, body: String) {
        if !self.first {
            self.out.push_str(",\n");
        }
        self.first = false;
        self.out.push_str("    ");
        self.out.push_str(&body);
    }

    fn span(&mut self, name: &str, t0: Time, dur: u64, pid: u32, tid: u32, args: &str) {
        self.event(format!(
            "{{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {}, \"dur\": {}, \
             \"pid\": {}, \"tid\": {}, \"args\": {{{}}}}}",
            json_escape(name),
            us(t0),
            us(dur),
            pid,
            tid,
            args
        ));
    }

    fn instant(&mut self, name: &str, t: Time, pid: u32, tid: u32, args: &str) {
        self.event(format!(
            "{{\"name\": \"{}\", \"ph\": \"i\", \"ts\": {}, \"s\": \"t\", \
             \"pid\": {}, \"tid\": {}, \"args\": {{{}}}}}",
            json_escape(name),
            us(t),
            pid,
            tid,
            args
        ));
    }

    fn meta(&mut self, kind: &str, pid: u32, tid: u32, name: &str) {
        self.event(format!(
            "{{\"name\": \"{kind}\", \"ph\": \"M\", \"pid\": {pid}, \"tid\": {tid}, \
             \"args\": {{\"name\": \"{}\"}}}}",
            json_escape(name)
        ));
    }
}

/// Track ids within a node process: hosts and streams get low ids, NIC
/// and wire facilities fixed high ids.
const TID_NIC: u32 = 1000;
const TID_WIRE_EGRESS: u32 = 1001;
const TID_WIRE_INGRESS: u32 = 1002;
const TID_HOST_STRIDE: u32 = 16;

/// Export a recorded trace as Chrome trace-event JSON (Perfetto /
/// `chrome://tracing` loadable). One process per node (pid = node), one
/// extra process for the engine (pid = nodes); per node: one thread per
/// host rank, one per GPU stream, plus NIC / wire-egress / wire-ingress
/// facility threads. Output bytes are a pure function of the trace —
/// the export inherits the recorder's determinism contract.
pub fn chrome_trace(t: &TraceBuf) -> String {
    let rpn = t.meta.ranks_per_node.max(1);
    let engine_pid = t.meta.nodes.max(1);
    let node_pid = |rank: u32| (rank / rpn).min(engine_pid - 1);
    let host_tid = |rank: u32| (rank % rpn) * TID_HOST_STRIDE;
    let stream_tid = |rank: u32, stream: u32| (rank % rpn) * TID_HOST_STRIDE + 1 + stream;

    let mut w = ChromeWriter { out: String::new(), first: true };
    w.out.push_str("{\n  \"displayTimeUnit\": \"ns\",\n  \"otherData\": {");
    w.out.push_str(&format!(
        "\"label\": \"{}\", \"nodes\": {}, \"ranks_per_node\": {}, \"events\": {}, \
         \"dropped\": {}",
        json_escape(&t.meta.label),
        t.meta.nodes,
        t.meta.ranks_per_node,
        t.events.len(),
        t.dropped
    ));
    w.out.push_str("},\n  \"traceEvents\": [\n");

    // Process/thread name metadata, in deterministic (node, track) order.
    for n in 0..t.meta.nodes.max(1) {
        w.meta("process_name", n, 0, &format!("node{n}"));
        for lr in 0..rpn {
            let rank = n * rpn + lr;
            w.meta("thread_name", n, lr * TID_HOST_STRIDE, &format!("rank{rank} host"));
        }
        w.meta("thread_name", n, TID_NIC, &format!("nic{n}"));
        w.meta("thread_name", n, TID_WIRE_EGRESS, &format!("nic{n} wire egress"));
        w.meta("thread_name", n, TID_WIRE_INGRESS, &format!("nic{n} wire ingress"));
    }
    w.meta("process_name", engine_pid, 0, "engine");
    w.meta("thread_name", engine_pid, 0, "driver");

    for ev in &t.events {
        match *ev {
            Event::HostPark { t, host, kind } => {
                let name = match kind {
                    ParkKind::Advance => "park(advance)",
                    ParkKind::WaitCell => "park(wait)",
                };
                w.instant(name, t, node_pid(host), host_tid(host), "");
            }
            Event::HostResume { t, host } => {
                w.instant("resume", t, node_pid(host), host_tid(host), "");
            }
            Event::Microtask { t } => {
                w.instant("microtask", t, engine_pid, 0, "");
            }
            Event::Kernel { t0, dur, gpu, stream, name } => {
                w.span(
                    t.lookup(name),
                    t0,
                    dur,
                    node_pid(gpu),
                    stream_tid(gpu, stream),
                    &format!("\"gpu\": {gpu}, \"stream\": {stream}"),
                );
            }
            Event::KtDoorbell { t: tt, gpu, kind } => {
                let name = match kind {
                    KtKind::CounterInc => "kt_doorbell(counter)",
                    KtKind::Put => "kt_doorbell(put)",
                    KtKind::Recv => "kt_doorbell(recv)",
                };
                w.instant(name, tt, node_pid(gpu), TID_NIC, &format!("\"gpu\": {gpu}"));
            }
            Event::TriggerArm { t: tt, node, threshold, label } => {
                w.instant(
                    "trigger_arm",
                    tt,
                    node.min(engine_pid - 1),
                    TID_NIC,
                    &format!(
                        "\"threshold\": {threshold}, \"label\": \"{}\"",
                        json_escape(t.lookup(label))
                    ),
                );
            }
            Event::TriggerFire { t0, dur, node } => {
                w.span("trigger_fire", t0, dur, node.min(engine_pid - 1), TID_NIC, "");
            }
            Event::DwqReserve { t: tt, node, in_use } => {
                w.instant(
                    "dwq_reserve",
                    tt,
                    node.min(engine_pid - 1),
                    TID_NIC,
                    &format!("\"in_use\": {in_use}"),
                );
            }
            Event::DwqRelease { t: tt, node } => {
                w.instant("dwq_release", tt, node.min(engine_pid - 1), TID_NIC, "");
            }
            Event::DwqWait { t0, dur, node, rank } => {
                w.span(
                    "dwq_wait",
                    t0,
                    dur,
                    node_pid(rank),
                    host_tid(rank),
                    &format!("\"nic\": {node}"),
                );
            }
            Event::Wire { t0, dur, src_node, dst_node, bytes, src_rank, dir, retransmit } => {
                let (name, pid, tid) = match dir {
                    WireDir::Egress => (
                        if retransmit { "wire_egress(retransmit)" } else { "wire_egress" },
                        src_node.min(engine_pid - 1),
                        TID_WIRE_EGRESS,
                    ),
                    WireDir::Ingress => (
                        if retransmit { "wire_ingress(retransmit)" } else { "wire_ingress" },
                        dst_node.min(engine_pid - 1),
                        TID_WIRE_INGRESS,
                    ),
                };
                let rank_arg = if src_rank == NO_RANK {
                    String::from("null")
                } else {
                    src_rank.to_string()
                };
                w.span(
                    name,
                    t0,
                    dur,
                    pid,
                    tid,
                    &format!(
                        "\"src_node\": {src_node}, \"dst_node\": {dst_node}, \
                         \"bytes\": {bytes}, \"src_rank\": {rank_arg}, \
                         \"retransmit\": {retransmit}"
                    ),
                );
            }
            Event::Match { t: tt, rank, tag } => {
                w.instant(
                    "match",
                    tt,
                    node_pid(rank),
                    TID_NIC,
                    &format!("\"rank\": {rank}, \"tag\": {tag}"),
                );
            }
            Event::Unexpected { t: tt, rank, tag } => {
                w.instant(
                    "unexpected",
                    tt,
                    node_pid(rank),
                    TID_NIC,
                    &format!("\"rank\": {rank}, \"tag\": {tag}"),
                );
            }
            Event::RecvPost { t: tt, rank, node } => {
                w.instant(
                    "triggered_recv_post",
                    tt,
                    node.min(engine_pid - 1),
                    TID_NIC,
                    &format!("\"rank\": {rank}"),
                );
            }
        }
    }
    w.out.push_str("\n  ]\n}\n");
    w.out
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- interval-overlap unit battery -------------------------------

    #[test]
    fn union_merges_overlapping_and_adjacent() {
        assert_eq!(
            union_intervals(vec![(0, 10), (5, 15), (15, 20)]),
            vec![(0, 20)],
            "overlapping + adjacent intervals coalesce"
        );
    }

    #[test]
    fn union_keeps_disjoint_and_drops_zero_length() {
        assert_eq!(
            union_intervals(vec![(30, 40), (0, 10), (20, 20), (50, 45)]),
            vec![(0, 10), (30, 40)],
            "disjoint stay split; zero-length and inverted vanish"
        );
    }

    #[test]
    fn overlap_nested_span_is_fully_hidden() {
        let u = union_intervals(vec![(0, 100)]);
        assert_eq!(overlap_with_union(&u, (20, 30)), 10);
    }

    #[test]
    fn overlap_disjoint_span_is_zero() {
        let u = union_intervals(vec![(0, 10), (50, 60)]);
        assert_eq!(overlap_with_union(&u, (20, 40)), 0);
    }

    #[test]
    fn overlap_adjacent_half_open_touch_is_zero() {
        let u = union_intervals(vec![(0, 10)]);
        assert_eq!(overlap_with_union(&u, (10, 20)), 0, "half-open: touching ends do not overlap");
        assert_eq!(overlap_with_union(&u, (5, 10)), 5);
    }

    #[test]
    fn overlap_zero_length_span_is_zero() {
        let u = union_intervals(vec![(0, 100)]);
        assert_eq!(overlap_with_union(&u, (50, 50)), 0);
    }

    #[test]
    fn overlap_spanning_multiple_union_pieces() {
        let u = union_intervals(vec![(0, 10), (20, 30), (40, 50)]);
        assert_eq!(overlap_with_union(&u, (5, 45)), 5 + 10 + 5);
    }

    // ---- achieved overlap --------------------------------------------

    fn buf(nodes: u32, rpn: u32) -> TraceBuf {
        TraceBuf::new(
            TraceMeta { nodes, ranks_per_node: rpn, label: "test".into() },
            DEFAULT_CAP,
        )
    }

    fn kernel(t0: u64, dur: u64, gpu: u32) -> Event {
        Event::Kernel { t0, dur, gpu, stream: 0, name: NO_STR }
    }

    fn wire(t0: u64, dur: u64, src_node: u32, dst_node: u32) -> Event {
        Event::Wire {
            t0,
            dur,
            src_node,
            dst_node,
            bytes: 100,
            src_rank: src_node,
            dir: WireDir::Egress,
            retransmit: false,
        }
    }

    #[test]
    fn achieved_overlap_counts_only_source_node_kernels() {
        let mut t = buf(2, 1);
        t.push(kernel(0, 100, 0)); // node 0
        t.push(kernel(0, 100, 1)); // node 1
        t.push(wire(50, 100, 0, 1)); // egress from node 0: half hidden
        let o = achieved_overlap(&t).unwrap();
        assert_eq!(o.wire_ns, 100);
        assert_eq!(o.hidden_ns, 50);
        assert!((o.pct() - 50.0).abs() < 1e-12);
    }

    #[test]
    fn achieved_overlap_none_without_wire_traffic() {
        let mut t = buf(1, 2);
        t.push(kernel(0, 100, 0));
        assert_eq!(achieved_overlap(&t), None);
    }

    #[test]
    fn achieved_overlap_pct_stays_in_range() {
        let mut t = buf(2, 1);
        t.push(kernel(0, 1000, 0));
        t.push(wire(0, 400, 0, 1));
        t.push(wire(900, 400, 0, 1)); // partially uncovered
        let o = achieved_overlap(&t).unwrap();
        assert!(o.hidden_ns <= o.wire_ns);
        assert!((0.0..=100.0).contains(&o.pct()));
    }

    // ---- critical path -----------------------------------------------

    #[test]
    fn critical_path_buckets_partition_the_window() {
        let mut t = buf(2, 1);
        t.push(kernel(0, 100, 0));
        t.push(wire(80, 60, 0, 1)); // 20ns overlap with kernel: wire loses
        t.push(Event::TriggerFire { t0: 200, dur: 25, node: 0 });
        t.push(Event::DwqWait { t0: 300, dur: 40, node: 0, rank: 0 });
        let cp = critical_path(&t, Some(0), 400);
        assert_eq!(cp.total_ns, 400);
        assert_eq!(cp.compute_ns, 100);
        assert_eq!(cp.wire_ns, 40, "kernel window wins overlapped 20ns (priority)");
        assert_eq!(cp.trigger_ns, 25);
        assert_eq!(cp.backpressure_ns, 40);
        assert_eq!(cp.retransmit_ns, 0);
        let sum = cp.compute_ns
            + cp.wire_ns
            + cp.trigger_ns
            + cp.backpressure_ns
            + cp.retransmit_ns
            + cp.other_ns;
        assert_eq!(sum, cp.total_ns, "buckets partition the makespan exactly");
    }

    #[test]
    fn critical_path_wire_outranks_compute_under_priority() {
        // Priority order is retransmit > backpressure > trigger > wire >
        // compute: a retransmitted wire span claims time even inside a
        // kernel window.
        let mut t = buf(2, 1);
        t.push(kernel(0, 100, 0));
        t.push(Event::Wire {
            t0: 10,
            dur: 30,
            src_node: 0,
            dst_node: 1,
            bytes: 1,
            src_rank: 0,
            dir: WireDir::Egress,
            retransmit: true,
        });
        let cp = critical_path(&t, Some(0), 100);
        assert_eq!(cp.retransmit_ns, 30);
        assert_eq!(cp.compute_ns, 70);
    }

    #[test]
    fn critical_path_clips_to_finish() {
        let mut t = buf(1, 1);
        t.push(kernel(50, 100, 0));
        let cp = critical_path(&t, Some(0), 100);
        assert_eq!(cp.compute_ns, 50);
        assert_eq!(cp.other_ns, 50);
    }

    // ---- recorder ----------------------------------------------------

    #[test]
    fn recorder_caps_and_counts_drops() {
        let mut t = TraceBuf::new(TraceMeta::default(), 2);
        t.push(Event::Microtask { t: 1 });
        t.push(Event::Microtask { t: 2 });
        t.push(Event::Microtask { t: 3 });
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.dropped, 1);
    }

    #[test]
    fn intern_dedups_and_lookup_roundtrips() {
        let mut t = TraceBuf::new(TraceMeta::default(), 8);
        let a = t.intern("faces_ax");
        let b = t.intern("faces_pack");
        let a2 = t.intern("faces_ax");
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(t.lookup(a), "faces_ax");
        assert_eq!(t.lookup(NO_STR), "");
    }

    // ---- chrome export -----------------------------------------------

    #[test]
    fn chrome_trace_is_valid_json_and_deterministic() {
        let mut t = buf(2, 2);
        let name = t.intern("faces_ax");
        t.push(Event::HostResume { t: 0, host: 1 });
        t.push(Event::Kernel { t0: 10, dur: 500, gpu: 1, stream: 0, name });
        t.push(Event::TriggerArm { t: 20, node: 0, threshold: 1, label: t.intern("q0 send") });
        t.push(Event::TriggerFire { t0: 520, dur: 900, node: 0 });
        t.push(wire(1500, 1000, 0, 1));
        t.push(Event::Match { t: 2600, rank: 2, tag: 7 });
        t.push(Event::HostPark { t: 2700, host: 1, kind: ParkKind::WaitCell });
        let a = chrome_trace(&t);
        let b = chrome_trace(&t);
        assert_eq!(a, b, "export is a pure function of the trace");
        assert!(crate::workloads::campaign::json_parses(&a), "export must be valid JSON");
        assert!(a.contains("\"faces_ax\""));
        assert!(a.contains("wire_egress"));
    }

    #[test]
    fn chrome_timestamps_are_exact_microseconds() {
        assert_eq!(us(0), "0.000");
        assert_eq!(us(999), "0.999");
        assert_eq!(us(123_456), "123.456");
        assert_eq!(us(1_000_000), "1000.000");
    }
}
