//! The simulation world: all device, NIC, and MPI state, plus topology.
//!
//! `World` is the `W` type threaded through the [`crate::sim`] engine;
//! every event callback and host primitive operates on `(&mut World,
//! &mut Core<World>)`.

use std::sync::Arc;

use crate::costmodel::CostModel;
use crate::fault::FaultState;
use crate::gpu::Gpu;
use crate::mpi::{Proc, Req};
use crate::nic::Nic;
use crate::runtime::Runtime;
use crate::sim::{CellId, Core};
use crate::stx::MpixQueue;

/// Shorthand for the engine core specialized to our world.
pub type Ctx = Core<World>;
/// Shorthand for a scheduled callback.
pub type Callback = Box<dyn FnOnce(&mut World, &mut Ctx) + Send>;

/// Whether GPU kernels execute real numerics (via AOT-compiled XLA
/// programs) or only charge modeled time (buffers untouched).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    /// Kernels run their real payload function (HLO via PJRT, or a
    /// built-in rust closure) — used by correctness runs and examples.
    Real,
    /// Kernels only charge time — used by large timing sweeps where the
    /// numerics are already validated elsewhere.
    Modeled,
}

/// Device buffer handle (index into the global pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BufId(pub usize);

/// Pool of simulated device buffers (f32 payloads).
///
/// Backing stores are recycled across [`World::reset`] cycles: `reset`
/// drains the live buffers into a bounded spare list and `alloc` reuses
/// a spare allocation (cleared and zero-filled, so the observable value
/// is exactly `vec![0.0; len]`) before touching the system allocator —
/// the per-cell cost a 100K-cell campaign would otherwise pay.
#[derive(Default)]
pub struct BufPool {
    bufs: Vec<Vec<f32>>,
    /// Retired backing stores awaiting reuse (never observable: contents
    /// are reset to zeros on re-allocation).
    spare: Vec<Vec<f32>>,
}

/// Retired backing stores kept across resets; beyond this the allocator
/// takes over (bounds held memory for long heterogeneous sweeps).
const BUF_SPARE_CAP: usize = 256;

impl BufPool {
    pub fn alloc(&mut self, len: usize) -> BufId {
        let id = BufId(self.bufs.len());
        match self.spare.pop() {
            Some(mut b) => {
                // Byte-identical to `vec![0.0; len]`: same length, all
                // zeros; only the (unobservable) capacity may differ.
                b.clear();
                b.resize(len, 0.0);
                self.bufs.push(b);
            }
            None => self.bufs.push(vec![0.0; len]),
        }
        id
    }

    /// Rewind to the empty pool, retiring backing stores for reuse by
    /// later `alloc` calls (see [`World::reset`]).
    fn reset(&mut self) {
        self.spare.append(&mut self.bufs);
        self.spare.truncate(BUF_SPARE_CAP);
    }

    pub fn alloc_init(&mut self, data: Vec<f32>) -> BufId {
        let id = BufId(self.bufs.len());
        self.bufs.push(data);
        id
    }

    #[inline]
    pub fn get(&self, id: BufId) -> &[f32] {
        &self.bufs[id.0]
    }

    #[inline]
    pub fn get_mut(&mut self, id: BufId) -> &mut Vec<f32> {
        &mut self.bufs[id.0]
    }

    /// Copy `len` elements between buffers (simulated DMA payload move).
    pub fn copy(&mut self, src: BufId, src_off: usize, dst: BufId, dst_off: usize, len: usize) {
        if src.0 == dst.0 {
            let b = &mut self.bufs[src.0];
            b.copy_within(src_off..src_off + len, dst_off);
            return;
        }
        // Split-borrow the two buffers.
        let (a, b) = if src.0 < dst.0 {
            let (lo, hi) = self.bufs.split_at_mut(dst.0);
            (&lo[src.0], &mut hi[0])
        } else {
            let (lo, hi) = self.bufs.split_at_mut(src.0);
            (&hi[0] as &Vec<f32>, &mut lo[dst.0])
        };
        b[dst_off..dst_off + len].copy_from_slice(&a[src_off..src_off + len]);
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

/// Static cluster topology: which node/GPU/NIC each MPI rank uses.
#[derive(Debug, Clone)]
pub struct Topology {
    pub nodes: usize,
    pub ranks_per_node: usize,
}

impl Topology {
    pub fn new(nodes: usize, ranks_per_node: usize) -> Self {
        Self { nodes, ranks_per_node }
    }

    pub fn world_size(&self) -> usize {
        self.nodes * self.ranks_per_node
    }

    /// Block rank placement, as the paper's runs use (ranks 0..rpn on
    /// node 0, etc.).
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.ranks_per_node
    }

    /// One-to-one rank->GPU mapping within the node (paper §V-C).
    pub fn gpu_of(&self, rank: usize) -> usize {
        rank // global GPU index == rank (one GPU per rank)
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Compact human label ("4nx2r"), used by campaign reports.
    pub fn label(&self) -> String {
        format!("{}nx{}r", self.nodes, self.ranks_per_node)
    }
}

/// Aggregate counters for reporting and assertions.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Metrics {
    pub eager_sends: u64,
    pub rendezvous_sends: u64,
    pub intra_sends: u64,
    pub bytes_wire: u64,
    /// Inter-node messages put on the wire.
    pub wire_msgs: u64,
    /// Worst queueing delay any message saw on a source egress port
    /// (first-order fabric congestion signal; see `fabric::transfer`).
    pub max_egress_wait_ns: u64,
    /// Worst queueing delay any message saw on a destination ingress
    /// port (the incast hotspot signal).
    pub max_ingress_wait_ns: u64,
    pub bytes_ipc: u64,
    pub kernels_launched: u64,
    pub stream_syncs: u64,
    pub memops_executed: u64,
    pub dwq_triggered: u64,
    /// Times an stx operation had to wait for a free DWQ descriptor slot
    /// (multi-queue / multi-rank contention for the NIC's finite
    /// deferred-work queue; per-queue counts live on the queues).
    pub dwq_slot_waits: u64,
    /// Peak concurrent DWQ occupancy across NICs (HTQ pressure
    /// high-water mark).
    pub dwq_peak: u64,
    /// Mid-kernel trigger actions fired (the kernel-triggered path).
    pub kt_triggers: u64,
    /// Receive descriptors the NIC posted into the matching engine
    /// itself — triggered-receive DWQ fires plus kernel doorbell posts
    /// (the receive-side offload; no host, no progress thread).
    pub triggered_recvs: u64,
    pub progress_ops: u64,
    pub unexpected_msgs: u64,
    pub matched_posted: u64,
    /// Wire faults actually injected by an active `FaultPlan` (drops +
    /// dups + delays + delayed trigger fires).
    pub faults_injected: u64,
    /// Dropped payloads retransmitted by the stx watchdog.
    pub retries: u64,
    /// Watchdogs that exhausted `max_retries` without completion.
    pub timeouts: u64,
    /// Runs that ended in a stall (set by the campaign aggregator on
    /// stalled cells; always 0 inside a completed run).
    pub stalls: u64,
    /// Command-ring descriptors the NIC consumed on the GPU-initiated
    /// path (one per `gpu::GI_CHUNK_BYTES` send granule, one per
    /// receive; no pre-armed DWQ slots anywhere on this path).
    pub gi_posts: u64,
    /// Times a GI kernel's producing wavefront found its command ring
    /// full and stalled until the NIC consumed the oldest descriptor
    /// (the GI backpressure signal, analogous to `dwq_slot_waits`).
    pub gi_ring_full_waits: u64,
}

/// One armed-but-not-yet-fired triggered operation (DWQ descriptor),
/// tracked so a [`crate::sim::StallReport`] can name exactly which
/// descriptors never fired — with their NIC, queue, and slot of origin.
#[derive(Debug, Clone)]
pub struct ArmedEntry {
    /// NIC node the descriptor is posted on.
    pub node: usize,
    /// Owning stx queue id, when the descriptor came from a queue.
    pub queue: Option<usize>,
    /// Human-readable label: origin (queue/slot) + descriptor kind.
    pub desc: String,
}

/// Registry of armed DWQ descriptors: slab with token-based clearing.
/// `nic::post_triggered_*` registers an entry when a descriptor is armed
/// and clears it when the trigger fires; whatever remains at stall time
/// is exactly the set of descriptors whose counters never tripped.
#[derive(Debug, Default)]
pub struct ArmedRegistry {
    entries: Vec<Option<ArmedEntry>>,
    free: Vec<usize>,
}

impl ArmedRegistry {
    /// Track an armed descriptor; returns the token to clear it with.
    pub fn register(&mut self, entry: ArmedEntry) -> usize {
        match self.free.pop() {
            Some(i) => {
                self.entries[i] = Some(entry);
                i
            }
            None => {
                self.entries.push(Some(entry));
                self.entries.len() - 1
            }
        }
    }

    /// Clear a descriptor when its trigger fires (idempotent).
    pub fn clear(&mut self, token: usize) {
        if let Some(slot) = self.entries.get_mut(token) {
            if slot.take().is_some() {
                self.free.push(token);
            }
        }
    }

    /// Still-armed descriptors, in arming order.
    pub fn pending(&self) -> impl Iterator<Item = &ArmedEntry> {
        self.entries.iter().flatten()
    }

    /// Number of still-armed descriptors.
    pub fn len(&self) -> usize {
        self.entries.iter().flatten().count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rewind to the empty registry (keeps allocations); part of
    /// [`World::reset`].
    pub fn reset(&mut self) {
        self.entries.clear();
        self.free.clear();
    }

    /// Drain every still-armed descriptor belonging to `queue` (used by
    /// the force-release path after a watchdog timeout). Returns the
    /// cleared entries so the caller can credit DWQ slots back.
    pub fn drain_queue(&mut self, queue: usize) -> Vec<ArmedEntry> {
        let mut out = Vec::new();
        for (i, slot) in self.entries.iter_mut().enumerate() {
            if slot.as_ref().is_some_and(|e| e.queue == Some(queue)) {
                if let Some(e) = slot.take() {
                    self.free.push(i);
                    out.push(e);
                }
            }
        }
        out
    }
}

/// Structural fingerprint of a freshly wired [`World`], captured by
/// [`World::snapshot`] right after the coordinator builds it and checked
/// by [`World::reset`]: a reset may only rewind *mutable run state* — it
/// must never be asked to reshape the cluster (that is a cold rebuild,
/// keyed by the campaign driver's reuse key; see DESIGN.md §Snapshot &
/// reset lifecycle).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorldSnapshot {
    nodes: usize,
    nics: usize,
    gpus: usize,
    procs: usize,
}

/// The complete simulated cluster.
pub struct World {
    pub cost: CostModel,
    pub topo: Topology,
    pub bufs: BufPool,
    pub gpus: Vec<Gpu>,
    pub nics: Vec<Nic>,
    pub procs: Vec<Proc>,
    pub queues: Vec<MpixQueue>,
    pub requests: Vec<Req>,
    pub compute: ComputeMode,
    pub runtime: Option<Arc<Runtime>>,
    pub metrics: Metrics,
    /// Virtual finish time of each rank's program (filled by the
    /// coordinator's run loop).
    pub rank_finish: Vec<u64>,
    /// Fault-injection runtime state; `None` (the default) keeps every
    /// fault and recovery path fully inert — the timeline is
    /// bit-for-bit identical to a build without the fault layer.
    pub fault: Option<FaultState>,
    /// Armed-DWQ-descriptor registry feeding the stall inspector.
    pub armed: ArmedRegistry,
    /// Trace-recorder capacity request (events); `None` (the default)
    /// leaves tracing off. The coordinator's run loop installs a
    /// [`crate::obs::TraceBuf`] of this capacity before the clock starts
    /// (see [`crate::obs`] for the determinism contract).
    pub trace_cap: Option<usize>,
}

impl World {
    /// True when kernels and data paths move real payloads (vs charging
    /// modeled time only — Modeled worlds allocate zero-length buffers).
    pub fn is_real(&self) -> bool {
        self.compute == ComputeMode::Real
    }

    /// Allocate a device buffer: real backing store in Real mode, a
    /// zero-length placeholder in Modeled mode (timing sweeps at
    /// production block sizes would otherwise need tens of GB).
    pub fn alloc_device(&mut self, len: usize) -> BufId {
        if self.is_real() {
            self.bufs.alloc(len)
        } else {
            self.bufs.alloc(0)
        }
    }

    /// Build an empty world; devices/procs are wired by the coordinator.
    pub fn new(cost: CostModel, topo: Topology) -> Self {
        Self {
            cost,
            topo,
            bufs: BufPool::default(),
            gpus: Vec::new(),
            nics: Vec::new(),
            procs: Vec::new(),
            queues: Vec::new(),
            requests: Vec::new(),
            compute: ComputeMode::Real,
            runtime: None,
            metrics: Metrics::default(),
            rank_finish: Vec::new(),
            fault: None,
            armed: ArmedRegistry::default(),
            trace_cap: None,
        }
    }

    /// Capture the structural fingerprint of this (freshly wired) world
    /// for later [`World::reset`] validation.
    pub fn snapshot(&self) -> WorldSnapshot {
        WorldSnapshot {
            nodes: self.topo.nodes,
            nics: self.nics.len(),
            gpus: self.gpus.len(),
            procs: self.procs.len(),
        }
    }

    /// Rewind every piece of mutable run state to the just-built world
    /// the snapshot was captured from, keeping the wiring (topology,
    /// NICs, GPUs, procs) and the recycled allocations (buffer backing
    /// stores, matching-engine deques, counter pools).
    ///
    /// Equivalence contract (pinned by the reset-equivalence blitz in
    /// `tests/properties.rs`): a run on a reset world produces
    /// byte-identical `SimStats`, `Metrics`, per-queue stats, and trace
    /// to the same run on a cold-built world. Streams, queues, requests,
    /// and plans hold per-run ids, so they are cleared here and rebuilt
    /// by the run itself; what survives is the wiring and the memory.
    pub fn reset(&mut self, snap: &WorldSnapshot) {
        debug_assert_eq!(
            *snap,
            self.snapshot(),
            "World::reset may only rewind run state, never reshape the cluster"
        );
        self.bufs.reset();
        for g in &mut self.gpus {
            g.reset();
        }
        for n in &mut self.nics {
            n.reset();
        }
        for p in &mut self.procs {
            p.reset();
        }
        self.queues.clear();
        self.requests.clear();
        self.compute = ComputeMode::Real;
        self.runtime = None;
        self.metrics = Metrics::default();
        self.rank_finish.clear();
        self.fault = None;
        self.armed.reset();
        // `trace_cap` is left as-is: the lease path re-derives it from
        // the recording switch before every run.
    }

    /// Allocate a fresh MPI request; returns its id.
    pub fn new_request(&mut self, core: &mut Ctx, what: &str) -> usize {
        let done = core.new_cell(format!("req.{}.{}", self.requests.len(), what), 0);
        self.requests.push(Req { done, cancelled: false });
        self.requests.len() - 1
    }

    pub fn request_done_cell(&self, req: usize) -> CellId {
        self.requests[req].done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bufpool_copy_between_buffers() {
        let mut p = BufPool::default();
        let a = p.alloc_init(vec![1.0, 2.0, 3.0, 4.0]);
        let b = p.alloc(4);
        p.copy(a, 1, b, 0, 2);
        assert_eq!(p.get(b), &[2.0, 3.0, 0.0, 0.0]);
        // reverse direction (src index > dst index)
        p.copy(b, 0, a, 2, 2);
        assert_eq!(p.get(a), &[1.0, 2.0, 2.0, 3.0]);
    }

    #[test]
    fn bufpool_copy_within_same_buffer() {
        let mut p = BufPool::default();
        let a = p.alloc_init(vec![1.0, 2.0, 3.0, 4.0]);
        p.copy(a, 0, a, 2, 2);
        assert_eq!(p.get(a), &[1.0, 2.0, 1.0, 2.0]);
    }

    #[test]
    fn topology_block_placement() {
        let t = Topology::new(8, 8);
        assert_eq!(t.world_size(), 64);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.node_of(8), 1);
        assert_eq!(t.node_of(63), 7);
        assert!(t.same_node(0, 7));
        assert!(!t.same_node(7, 8));
    }

    #[test]
    fn bufpool_reset_recycles_backing_stores_with_identical_values() {
        let mut p = BufPool::default();
        let a = p.alloc(4);
        p.get_mut(a).copy_from_slice(&[9.0, 8.0, 7.0, 6.0]);
        let b = p.alloc(2);
        p.get_mut(b)[0] = 5.0;
        p.reset();
        assert_eq!(p.len(), 0);
        // Recycled allocations must be value-identical to fresh ones:
        // same ids, same lengths, all zeros — stale data never leaks.
        let a2 = p.alloc(3);
        let b2 = p.alloc(8);
        assert_eq!(a2, BufId(0));
        assert_eq!(b2, BufId(1));
        assert_eq!(p.get(a2), &[0.0; 3]);
        assert_eq!(p.get(b2), &[0.0; 8]);
    }

    #[test]
    fn armed_registry_reset_restores_fresh_token_sequence() {
        let mut r = ArmedRegistry::default();
        let entry = |q| ArmedEntry { node: 0, queue: Some(q), desc: "d".into() };
        let t0 = r.register(entry(0));
        let _t1 = r.register(entry(1));
        r.clear(t0);
        r.reset();
        assert!(r.is_empty());
        // Tokens restart from 0 exactly as on a fresh registry, so a
        // reset world replays the same token ids as a cold-built one.
        assert_eq!(r.register(entry(2)), 0);
        assert_eq!(r.register(entry(3)), 1);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn world_reset_rewinds_run_state_and_keeps_wiring() {
        let mut w = crate::coordinator::build_world(
            crate::costmodel::presets::frontier_like(),
            Topology::new(2, 1),
        );
        let snap = w.snapshot();
        let first = w.bufs.alloc(16);
        w.bufs.get_mut(first)[3] = 42.0;
        w.metrics.eager_sends = 7;
        w.rank_finish = vec![1, 2];
        w.compute = ComputeMode::Modeled;
        w.armed.register(ArmedEntry { node: 0, queue: None, desc: "leak".into() });
        w.reset(&snap);
        assert_eq!(w.bufs.len(), 0);
        assert_eq!(w.metrics, Metrics::default());
        assert!(w.rank_finish.is_empty());
        assert_eq!(w.compute, ComputeMode::Real);
        assert!(w.armed.is_empty());
        assert!(w.queues.is_empty() && w.requests.is_empty());
        assert_eq!(w.nics.len(), 2);
        assert_eq!(w.gpus.len(), 2);
        assert_eq!(w.procs.len(), 2);
        // A post-reset allocation replays the cold-build id sequence.
        assert_eq!(w.bufs.alloc(16), first);
        assert_eq!(w.bufs.get(first), &[0.0; 16]);
    }

    #[test]
    fn topology_one_rank_per_node() {
        let t = Topology::new(8, 1);
        assert_eq!(t.world_size(), 8);
        for r in 0..8 {
            assert_eq!(t.node_of(r), r);
        }
    }
}
