//! PJRT runtime: loads AOT-compiled HLO artifacts and executes them.
//!
//! This is the only place the rust side touches XLA. At build time,
//! `python/compile/aot.py` lowers the L2 JAX entry points (which call the
//! L1 Pallas kernels) to **HLO text** and writes a `manifest.txt`
//! describing every entry point's input/output shapes. At startup the
//! coordinator loads and compiles each entry once; the simulated GPUs then
//! execute them whenever the control processor reaches a kernel in stream
//! order. Python never runs on this path.
//!
//! # Feature gate
//!
//! The PJRT backend needs the `xla` crate (a native XLA build), which is
//! not available in offline/CI environments. The real backend is behind
//! the `xla` cargo feature; without it this module compiles a stub whose
//! [`Runtime::load`] returns an error, so everything that only needs
//! `ComputeMode::Modeled` (all timing sweeps, figures, ablations) builds
//! and runs with no native dependencies. Manifest parsing is plain Rust
//! and always available.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

#[cfg(feature = "xla")]
use anyhow::Context;
#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::path::PathBuf;

/// Shape of one argument/result: dimensions of an f32 array.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgShape(pub Vec<i64>);

impl ArgShape {
    pub fn elems(&self) -> usize {
        self.0.iter().product::<i64>() as usize
    }
}

/// One AOT entry point from the manifest.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<ArgShape>,
    pub outputs: Vec<ArgShape>,
}

#[cfg(feature = "xla")]
struct LoadedEntry {
    meta: EntryMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// Registry of compiled executables over a PJRT CPU client.
#[cfg(feature = "xla")]
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    entries: HashMap<String, LoadedEntry>,
}

// SAFETY: `Runtime` lives inside the simulation `World`, which sits behind
// the engine's single `Mutex`; at most one thread touches it at a time
// (the strict driver/host token alternation). The PJRT CPU client has no
// thread affinity — this wrapper only moves *which* thread calls it, never
// introduces concurrent access.
#[cfg(feature = "xla")]
unsafe impl Send for Runtime {}
// SAFETY: same argument — `&Runtime` is only ever dereferenced by the one
// thread holding the engine lock, so shared references never race.
#[cfg(feature = "xla")]
unsafe impl Sync for Runtime {}

#[cfg(feature = "xla")]
impl Runtime {
    /// Load every entry listed in `<dir>/manifest.txt` and compile it.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {} (run `make artifacts` first)", manifest.display()))?;
        let metas = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut entries = HashMap::new();
        for meta in metas {
            let path: PathBuf = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", meta.name))?;
            entries.insert(meta.name.clone(), LoadedEntry { meta, exe });
        }
        Ok(Self { client, entries })
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn entry_meta(&self, name: &str) -> Option<&EntryMeta> {
        self.entries.get(name).map(|e| &e.meta)
    }

    pub fn entry_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Execute an entry with flat f32 inputs (reshaped per the manifest);
    /// returns flat f32 outputs.
    pub fn execute_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let entry = self
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("unknown AOT entry '{name}' (have: {:?})", self.entry_names()))?;
        if inputs.len() != entry.meta.inputs.len() {
            bail!(
                "entry '{name}': {} inputs given, manifest declares {}",
                inputs.len(),
                entry.meta.inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&entry.meta.inputs) {
            if data.len() != shape.elems() {
                bail!(
                    "entry '{name}': input has {} elems, manifest shape {:?} needs {}",
                    data.len(),
                    shape.0,
                    shape.elems()
                );
            }
            let lit = xla::Literal::vec1(data)
                .reshape(&shape.0)
                .map_err(|e| anyhow!("reshape input for '{name}': {e:?}"))?;
            lits.push(lit);
        }
        let result = entry
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute '{name}': {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of '{name}': {e:?}"))?;
        // aot.py always lowers with return_tuple=True.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untuple result of '{name}': {e:?}"))?;
        if parts.len() != entry.meta.outputs.len() {
            bail!(
                "entry '{name}': runtime produced {} outputs, manifest declares {}",
                parts.len(),
                entry.meta.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (part, shape) in parts.into_iter().zip(&entry.meta.outputs) {
            let v = part
                .to_vec::<f32>()
                .map_err(|e| anyhow!("read output of '{name}': {e:?}"))?;
            if v.len() != shape.elems() {
                bail!(
                    "entry '{name}': output has {} elems, manifest shape {:?} needs {}",
                    v.len(),
                    shape.0,
                    shape.elems()
                );
            }
            out.push(v);
        }
        Ok(out)
    }
}

/// Stub runtime used when the `xla` feature is disabled. [`Runtime::load`]
/// always fails (so no stub instance ever exists and `ComputeMode::Real`
/// is unavailable), but the query methods keep the same signatures as the
/// real backend so every Modeled-compute call site (figures, ablations,
/// benches, tests) type-checks unchanged.
#[cfg(not(feature = "xla"))]
pub struct Runtime;

#[cfg(not(feature = "xla"))]
impl Runtime {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        bail!(
            "stmpi was built without the `xla` feature; cannot load AOT artifacts from {} \
             (ComputeMode::Real requires a PJRT-enabled build — see DESIGN.md §Runtime)",
            dir.as_ref().display()
        )
    }

    pub fn has_entry(&self, _name: &str) -> bool {
        false
    }

    pub fn entry_meta(&self, _name: &str) -> Option<&EntryMeta> {
        None
    }

    pub fn entry_names(&self) -> Vec<&str> {
        Vec::new()
    }

    pub fn execute_f32(&self, name: &str, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        bail!("stmpi was built without the `xla` feature; cannot execute '{name}'")
    }
}

/// Parse the artifact manifest. Line format (one entry per line):
///
/// ```text
/// name=faces_pack file=faces_pack.hlo.txt in=32x32x32 out=6144,736,8
/// ```
///
/// Shapes are `x`-separated dims; multiple args are comma-separated;
/// blank lines and `#` comments are ignored. `in=-` means no inputs.
pub fn parse_manifest(text: &str) -> Result<Vec<EntryMeta>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut name = None;
        let mut file = None;
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        for field in line.split_whitespace() {
            let (k, v) = field
                .split_once('=')
                .ok_or_else(|| anyhow!("manifest line {}: bad field '{field}'", lineno + 1))?;
            match k {
                "name" => name = Some(v.to_string()),
                "file" => file = Some(v.to_string()),
                "in" => inputs = parse_shapes(v, lineno)?,
                "out" => outputs = parse_shapes(v, lineno)?,
                other => bail!("manifest line {}: unknown key '{other}'", lineno + 1),
            }
        }
        out.push(EntryMeta {
            name: name.ok_or_else(|| anyhow!("manifest line {}: missing name", lineno + 1))?,
            file: file.ok_or_else(|| anyhow!("manifest line {}: missing file", lineno + 1))?,
            inputs,
            outputs,
        });
    }
    Ok(out)
}

fn parse_shapes(v: &str, lineno: usize) -> Result<Vec<ArgShape>> {
    if v.is_empty() || v == "-" {
        return Ok(Vec::new());
    }
    v.split(',')
        .map(|s| {
            s.split('x')
                .map(|d| {
                    d.parse::<i64>()
                        .map_err(|_| anyhow!("manifest line {}: bad dim '{d}'", lineno + 1))
                })
                .collect::<Result<Vec<i64>>>()
                .map(ArgShape)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_basic_line() {
        let m = parse_manifest(
            "# comment\nname=ax file=ax.hlo.txt in=64x8x8x8,8x8 out=64x8x8x8\n\n",
        )
        .unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].name, "ax");
        assert_eq!(m[0].file, "ax.hlo.txt");
        assert_eq!(m[0].inputs.len(), 2);
        assert_eq!(m[0].inputs[0].0, vec![64, 8, 8, 8]);
        assert_eq!(m[0].inputs[0].elems(), 64 * 512);
        assert_eq!(m[0].inputs[1].0, vec![8, 8]);
        assert_eq!(m[0].outputs[0].elems(), 64 * 512);
    }

    #[test]
    fn manifest_scalar_shape() {
        let m = parse_manifest("name=s file=s.hlo.txt in=1 out=1").unwrap();
        assert_eq!(m[0].inputs[0].elems(), 1);
    }

    #[test]
    fn manifest_empty_inputs() {
        let m = parse_manifest("name=init file=init.hlo.txt in=- out=16").unwrap();
        assert!(m[0].inputs.is_empty());
        assert_eq!(m[0].outputs[0].elems(), 16);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("name=x garbage").is_err());
        assert!(parse_manifest("file=x.hlo.txt in=4 out=4").is_err());
        assert!(parse_manifest("name=x file=f in=4xq out=4").is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_load_reports_missing_feature() {
        let err = Runtime::load("artifacts").unwrap_err();
        assert!(format!("{err}").contains("xla"), "got: {err}");
    }
}
