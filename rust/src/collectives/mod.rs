//! Collectives built on the triggered-op primitives: a ring allreduce
//! and a recursive-doubling allreduce whose every communication step is
//! stream-triggered, a kernel-triggered ring ([`ring_allreduce_kt`])
//! where the per-step trigger/wait pair rides the reduction kernels
//! themselves, and a GPU-initiated ring ([`ring_allreduce_gi`]) where
//! the kernels build the per-step command-ring descriptors outright.
//!
//! This demonstrates the paper's API composing into higher-level
//! operations: each ST step enqueues a deferred send + receive, one
//! `MPIX_Enqueue_start` triggers them from the GPU stream, and the
//! reduction kernel that consumes the received data is ordered after the
//! `MPIX_Enqueue_wait` — the host never synchronizes inside the
//! collective. The KT ring goes further: no per-step stream memory ops
//! at all (arXiv 2306.15773).

use crate::gpu::{self, host_enqueue, KernelPayload, KernelSpec, StreamOp};
use crate::nic::BufSlice;
use crate::sim::HostCtx;
use crate::stx::Queue;
use crate::world::{BufId, World};

/// Precondition violation of [`recursive_doubling_allreduce_st`]: the
/// rank count is not a power of two (zero included).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NotPowerOfTwo(pub usize);

impl std::fmt::Display for NotPowerOfTwo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "recursive-doubling allreduce needs a power-of-two rank count, got {}", self.0)
    }
}

impl std::error::Error for NotPowerOfTwo {}

/// Chunk boundaries for an `n`-way ring over a buffer of `len` elements.
///
/// Every chunk is `len/n` or `len/n + 1` elements; the first `len % n`
/// chunks carry the extra element, offsets are contiguous, and the sizes
/// always sum to `len` — including the `len < n` (some chunks empty) and
/// `len == 0` (all chunks empty) edge cases. `n == 0` yields no chunks.
pub fn chunks(len: usize, n: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut off = 0;
    for i in 0..n {
        let sz = base + usize::from(i < rem);
        out.push((off, sz));
        off += sz;
    }
    out
}

/// Schedule of reduce-scatter step `s` of the two-phase ring: (chunk to
/// send, chunk to receive+accumulate, step tag). Shared by the ST ring
/// and the workload engine's host-driven baseline ring so the two
/// variants can never drift apart in schedule or tag layout.
pub fn ring_rs_step(rank: usize, n: usize, s: usize) -> (usize, usize, i32) {
    ((rank + n - s) % n, (rank + n - s - 1) % n, 1000 + s as i32)
}

/// Schedule of allgather step `s` of the two-phase ring: (chunk to send,
/// chunk to receive verbatim, step tag).
pub fn ring_ag_step(rank: usize, n: usize, s: usize) -> (usize, usize, i32) {
    ((rank + 1 + n - s) % n, (rank + n - s) % n, 2000 + s as i32)
}

/// Stream-triggered ring allreduce (sum) of `data` (length `len`) across
/// all `n` ranks, using the typed queue handle `q` (bound to `sid`) and
/// `tmp` (at least ceil(len/n) elements) as the receive staging buffer.
///
/// Standard two-phase ring: (n-1) reduce-scatter steps, then (n-1)
/// allgather steps. Tags encode the step so matching is unambiguous.
/// `n <= 1` (including the degenerate `n == 0`) is the identity: the
/// call returns without touching the queue or the buffers.
#[allow(clippy::too_many_arguments)]
pub fn ring_allreduce_st(
    ctx: &mut HostCtx<World>,
    rank: usize,
    n: usize,
    q: &Queue,
    sid: gpu::StreamId,
    data: BufId,
    len: usize,
    tmp: BufId,
    comm: u16,
) {
    if n <= 1 {
        return;
    }
    let next = (rank + 1) % n;
    let prev = (rank + n - 1) % n;
    let ch = chunks(len, n);

    // Phase 1: reduce-scatter. In step s, send chunk (rank - s) and
    // receive + accumulate chunk (rank - s - 1).
    for s in 0..n - 1 {
        let (send_c, recv_c, tag) = ring_rs_step(rank, n, s);
        let (soff, slen) = ch[send_c];
        let (roff, rlen) = ch[recv_c];
        q.send(ctx, next, BufSlice::new(data, soff, slen), tag, comm).expect("ring send");
        q.recv(ctx, prev, BufSlice::new(tmp, 0, rlen), tag, comm).expect("ring recv");
        q.start(ctx).expect("ring start");
        q.wait(ctx).expect("ring wait");
        // Accumulate the received chunk, ordered after the wait.
        host_enqueue(
            ctx,
            sid,
            StreamOp::Kernel(KernelSpec {
                name: format!("ring_acc[{s}]"),
                flops: rlen as u64,
                bytes: 3 * 4 * rlen as u64,
                payload: KernelPayload::Fn(Box::new(move |w, _| {
                    let t = w.bufs.get(tmp)[..rlen].to_vec();
                    let d = w.bufs.get_mut(data);
                    for (dst, src) in d[roff..roff + rlen].iter_mut().zip(&t) {
                        *dst += src;
                    }
                })),
            }),
        );
    }

    // Phase 2: allgather. In step s, send chunk (rank + 1 - s) and
    // receive chunk (rank - s) verbatim.
    for s in 0..n - 1 {
        let (send_c, recv_c, tag) = ring_ag_step(rank, n, s);
        let (soff, slen) = ch[send_c];
        let (roff, rlen) = ch[recv_c];
        q.send(ctx, next, BufSlice::new(data, soff, slen), tag, comm).expect("ring send");
        q.recv(ctx, prev, BufSlice::new(data, roff, rlen), tag, comm).expect("ring recv");
        q.start(ctx).expect("ring start");
        q.wait(ctx).expect("ring wait");
    }
}

/// Kernel-triggered ring allreduce (sum): the same two-phase schedule
/// as [`ring_allreduce_st`] — guaranteed, both call [`ring_rs_step`] /
/// [`ring_ag_step`] — but with no per-step stream memory ops. Step `s`'s
/// completion wait rides the prologue of the kernel that consumes its
/// data, and step `s+1`'s trigger fires from inside that same kernel
/// once the chunk it sends is globally visible. The allgather phase,
/// which has no reduction work, is driven by tiny device-side progress
/// kernels (the fully offloaded pattern of arXiv 2306.15773). Only step
/// 0 is kicked by a host-enqueued `MPIX_Enqueue_start`: there is no
/// earlier kernel to ride. The final progress kernel's prologue drains
/// the last step, so a trailing `stream_synchronize` leaves the queue
/// idle.
#[allow(clippy::too_many_arguments)]
pub fn ring_allreduce_kt(
    ctx: &mut HostCtx<World>,
    rank: usize,
    n: usize,
    q: &Queue,
    sid: gpu::StreamId,
    data: BufId,
    len: usize,
    tmp: BufId,
    comm: u16,
) {
    if n <= 1 {
        return;
    }
    let next = (rank + 1) % n;
    let prev = (rank + n - 1) % n;
    let ch = chunks(len, n);
    let rs_steps = n - 1;
    let total_steps = 2 * (n - 1);

    // Post one step's deferred send + receive (reduce-scatter steps
    // stage the incoming chunk in `tmp`; allgather steps land in place).
    let post_step = |ctx: &mut HostCtx<World>, i: usize| {
        let (send_c, recv_c, tag, stage) = if i < rs_steps {
            let (s, r, t) = ring_rs_step(rank, n, i);
            (s, r, t, true)
        } else {
            let (s, r, t) = ring_ag_step(rank, n, i - rs_steps);
            (s, r, t, false)
        };
        let (soff, slen) = ch[send_c];
        let (roff, rlen) = ch[recv_c];
        q.send(ctx, next, BufSlice::new(data, soff, slen), tag, comm).expect("kt ring send");
        let dst = if stage { BufSlice::new(tmp, 0, rlen) } else { BufSlice::new(data, roff, rlen) };
        q.recv(ctx, prev, dst, tag, comm).expect("kt ring recv");
    };

    // Step 0 is kicked by the one stream memop (data is ready at entry).
    post_step(ctx, 0);
    q.start(ctx).expect("kt ring kick");

    for i in 0..total_steps {
        let mut kt = gpu::KernelCtx::new();
        // This step's send+recv completion rides the kernel prologue.
        q.kt_wait(ctx, &mut kt).expect("kt ring wait");
        if i + 1 < total_steps {
            post_step(ctx, i + 1);
            // The next step's trigger fires at this kernel's tail, once
            // the chunk it sends is globally visible.
            q.kt_start(ctx, &mut kt, 1.0).expect("kt ring start");
        }
        let spec = if i < rs_steps {
            let (_, recv_c, _) = ring_rs_step(rank, n, i);
            let (roff, rlen) = ch[recv_c];
            KernelSpec {
                name: format!("kt_ring_acc[{i}]"),
                flops: rlen as u64,
                bytes: 3 * 4 * rlen as u64,
                payload: KernelPayload::Fn(Box::new(move |w, _| {
                    let t = w.bufs.get(tmp)[..rlen].to_vec();
                    let d = w.bufs.get_mut(data);
                    for (dst, src) in d[roff..roff + rlen].iter_mut().zip(&t) {
                        *dst += src;
                    }
                })),
            }
        } else {
            // Device-side progress kernel: carries the wait/trigger pair
            // for an allgather step that has no reduction work.
            KernelSpec {
                name: format!("kt_ring_step[{i}]"),
                flops: 0,
                bytes: 0,
                payload: KernelPayload::None,
            }
        };
        host_enqueue(ctx, sid, StreamOp::KtKernel(spec, kt));
    }
}

/// GPU-initiated ring allreduce (sum): the same two-phase schedule as
/// [`ring_allreduce_st`] / [`ring_allreduce_kt`] — guaranteed, all
/// three call [`ring_rs_step`] / [`ring_ag_step`] — but every step's
/// send and receive become command-ring descriptors the step's kernel
/// builds itself ([`crate::gpu::StreamOp::GiKernel`]): no host arming
/// cost, no trigger counters, no DWQ slots, at the price of
/// `cost.gi_descr_build_ns` of device time per descriptor inside the
/// kernel window. Step `s`'s completion wait rides the prologue of the
/// kernel that consumes its data (threshold shipped as a kernel
/// argument), and step `s+1`'s descriptors are built at that same
/// kernel's tail. The allgather phase rides tiny device-side progress
/// kernels, exactly like the KT ring. Where KT kicks step 0 with one
/// host-enqueued stream memop, GI uses a tiny leading kick *kernel*
/// whose tail builds step 0's descriptors: the GI path enqueues no
/// stream memory ops at all. The last kernel's prologue waits through
/// the final step, so a trailing `stream_synchronize` leaves the queue
/// idle.
#[allow(clippy::too_many_arguments)]
pub fn ring_allreduce_gi(
    ctx: &mut HostCtx<World>,
    rank: usize,
    n: usize,
    q: &Queue,
    sid: gpu::StreamId,
    data: BufId,
    len: usize,
    tmp: BufId,
    comm: u16,
) {
    if n <= 1 {
        return;
    }
    let next = (rank + 1) % n;
    let prev = (rank + n - 1) % n;
    let ch = chunks(len, n);
    let rs_steps = n - 1;
    let total_steps = 2 * (n - 1);

    // Post one step's send + receive into a GI descriptor plan
    // (reduce-scatter steps stage the incoming chunk in `tmp`;
    // allgather steps land in place).
    let post_step = |ctx: &mut HostCtx<World>, gi: &mut gpu::GiCtx, i: usize| {
        let (send_c, recv_c, tag, stage) = if i < rs_steps {
            let (s, r, t) = ring_rs_step(rank, n, i);
            (s, r, t, true)
        } else {
            let (s, r, t) = ring_ag_step(rank, n, i - rs_steps);
            (s, r, t, false)
        };
        let (soff, slen) = ch[send_c];
        let (roff, rlen) = ch[recv_c];
        q.gi_send(ctx, gi, next, BufSlice::new(data, soff, slen), tag, comm)
            .expect("gi ring send");
        let dst = if stage { BufSlice::new(tmp, 0, rlen) } else { BufSlice::new(data, roff, rlen) };
        q.gi_recv(ctx, gi, prev, dst, tag, comm).expect("gi ring recv");
    };

    // Kick kernel: builds step 0's descriptors at its tail (data is
    // ready at entry, so it waits on nothing).
    let mut kick = gpu::GiCtx::new();
    post_step(ctx, &mut kick, 0);
    host_enqueue(
        ctx,
        sid,
        StreamOp::GiKernel(
            KernelSpec {
                name: "gi_ring_kick".into(),
                flops: 0,
                bytes: 0,
                payload: KernelPayload::None,
            },
            kick,
        ),
    );

    for i in 0..total_steps {
        let mut gi = gpu::GiCtx::new();
        // This step's send+recv completion rides the kernel prologue
        // (threshold snapshot taken before step i+1's posts are
        // recorded, so it covers exactly steps 0..=i).
        q.gi_wait(ctx, &mut gi).expect("gi ring wait");
        if i + 1 < total_steps {
            // The next step's descriptors are built at this kernel's
            // tail, once the chunk it sends is globally visible.
            post_step(ctx, &mut gi, i + 1);
        }
        let spec = if i < rs_steps {
            let (_, recv_c, _) = ring_rs_step(rank, n, i);
            let (roff, rlen) = ch[recv_c];
            KernelSpec {
                name: format!("gi_ring_acc[{i}]"),
                flops: rlen as u64,
                bytes: 3 * 4 * rlen as u64,
                payload: KernelPayload::Fn(Box::new(move |w, _| {
                    let t = w.bufs.get(tmp)[..rlen].to_vec();
                    let d = w.bufs.get_mut(data);
                    for (dst, src) in d[roff..roff + rlen].iter_mut().zip(&t) {
                        *dst += src;
                    }
                })),
            }
        } else {
            // Device-side progress kernel: carries the wait and builds
            // the next allgather step's descriptors.
            KernelSpec {
                name: format!("gi_ring_step[{i}]"),
                flops: 0,
                bytes: 0,
                payload: KernelPayload::None,
            }
        };
        host_enqueue(ctx, sid, StreamOp::GiKernel(spec, gi));
    }
}

/// Stream-triggered recursive-doubling allreduce (sum) of `data` (length
/// `len`) across all `n` ranks; `n` must be a power of two.
///
/// log2(n) rounds; in round k each rank exchanges its *entire* current
/// vector with partner `rank ^ 2^k` and accumulates — latency-optimal
/// for small messages where the ring's 2(n-1) serialized steps dominate.
/// `tmp` must hold at least `len` elements (the full received vector),
/// unlike the ring's ceil(len/n) staging chunk.
///
/// `n == 1` is the identity. `n == 0` or non-power-of-two is rejected
/// before any operation is enqueued.
#[allow(clippy::too_many_arguments)]
pub fn recursive_doubling_allreduce_st(
    ctx: &mut HostCtx<World>,
    rank: usize,
    n: usize,
    q: &Queue,
    sid: gpu::StreamId,
    data: BufId,
    len: usize,
    tmp: BufId,
    comm: u16,
) -> Result<(), NotPowerOfTwo> {
    if n == 0 || !n.is_power_of_two() {
        return Err(NotPowerOfTwo(n));
    }
    if n == 1 {
        return Ok(());
    }
    let rounds = n.trailing_zeros();
    for k in 0..rounds {
        let partner = rank ^ (1usize << k);
        let tag = 3000 + k as i32;
        q.send(ctx, partner, BufSlice::whole(data, len), tag, comm).expect("rd send");
        q.recv(ctx, partner, BufSlice::whole(tmp, len), tag, comm).expect("rd recv");
        q.start(ctx).expect("rd start");
        q.wait(ctx).expect("rd wait");
        // Accumulate the partner's vector, ordered after the wait (and
        // before the next round's trigger, which protects `data` from
        // being read mid-update).
        host_enqueue(
            ctx,
            sid,
            StreamOp::Kernel(KernelSpec {
                name: format!("rd_acc[{k}]"),
                flops: len as u64,
                bytes: 3 * 4 * len as u64,
                payload: KernelPayload::Fn(Box::new(move |w, _| {
                    let t = w.bufs.get(tmp)[..len].to_vec();
                    let d = w.bufs.get_mut(data);
                    for (dst, src) in d[..len].iter_mut().zip(&t) {
                        *dst += src;
                    }
                })),
            }),
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{build_world, run_cluster};
    use crate::costmodel::presets;
    use crate::stx::Variant;
    use crate::gpu::stream_synchronize;
    use crate::mpi::COMM_WORLD;
    use crate::world::Topology;

    #[test]
    fn chunks_cover_everything() {
        for (len, n) in [(10, 3), (16, 4), (7, 8), (100, 7)] {
            let ch = chunks(len, n);
            assert_eq!(ch.len(), n);
            assert_eq!(ch.iter().map(|c| c.1).sum::<usize>(), len);
            let mut off = 0;
            for (o, s) in ch {
                assert_eq!(o, off);
                off += s;
            }
        }
    }

    /// Property test (hand-rolled, seeded): for random (len, n) including
    /// the len < n, len == 0, and n == 0 edge cases, chunks() must yield
    /// n contiguous chunks whose sizes sum to len, differ by at most one,
    /// and give the first len % n chunks the extra element.
    #[test]
    fn prop_chunks_edge_cases() {
        let mut rng = crate::sim::rng::SplitMix64::new(0xC0FFEE);
        for case in 0..500 {
            // Bias toward the edges: small n and len, frequent zeros.
            let n = (rng.below(12)) as usize;
            let len = match case % 4 {
                0 => 0,
                1 => (rng.below(n.max(1) as u64)) as usize, // len < n
                _ => (rng.below(200)) as usize,
            };
            let ch = chunks(len, n);
            assert_eq!(ch.len(), n, "len={len} n={n}");
            if n == 0 {
                // No chunks to cover anything: documented degenerate case.
                continue;
            }
            assert_eq!(ch.iter().map(|c| c.1).sum::<usize>(), len, "len={len} n={n}");
            let (base, rem) = (len / n, len % n);
            let mut off = 0;
            for (i, (o, s)) in ch.iter().enumerate() {
                assert_eq!(*o, off, "offsets must be contiguous (len={len} n={n})");
                let expect = base + usize::from(i < rem);
                assert_eq!(*s, expect, "rem distribution (len={len} n={n} i={i})");
                off += s;
            }
            assert_eq!(off, len);
        }
    }

    #[test]
    fn chunks_zero_ways_is_empty() {
        assert!(chunks(0, 0).is_empty());
        assert!(chunks(17, 0).is_empty());
    }

    fn run_allreduce(nodes: usize, rpn: usize, len: usize) {
        let n = nodes * rpn;
        let mut cost = presets::frontier_like();
        cost.jitter_sigma = 0.0;
        let mut w = build_world(cost, Topology::new(nodes, rpn));
        let data: Vec<BufId> = (0..n)
            .map(|r| w.bufs.alloc_init((0..len).map(|i| (r * len + i) as f32).collect()))
            .collect();
        let tmp: Vec<BufId> = (0..n).map(|_| w.bufs.alloc(len / n + 1)).collect();
        // Expected: elementwise sum over ranks.
        let expect: Vec<f32> = (0..len)
            .map(|i| (0..n).map(|r| (r * len + i) as f32).sum())
            .collect();
        let data2 = data.clone();
        let out = run_cluster(w, 1, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let q = Queue::create(ctx, rank, sid, Variant::StreamTriggered).unwrap();
            ring_allreduce_st(ctx, rank, n, &q, sid, data2[rank], len, tmp[rank], COMM_WORLD);
            stream_synchronize(ctx, sid);
        })
        .unwrap();
        for r in 0..n {
            assert_eq!(
                out.world.bufs.get(data[r]),
                &expect[..],
                "rank {r} allreduce result wrong"
            );
        }
    }

    #[test]
    fn allreduce_two_ranks_inter_node() {
        run_allreduce(2, 1, 16);
    }

    #[test]
    fn allreduce_four_ranks_intra_node() {
        run_allreduce(1, 4, 32);
    }

    #[test]
    fn allreduce_mixed_topology() {
        run_allreduce(2, 2, 37); // non-divisible length
    }

    #[test]
    fn allreduce_eight_ranks() {
        run_allreduce(4, 2, 64);
    }

    #[test]
    fn allreduce_single_rank_noop() {
        run_allreduce(1, 1, 8);
    }

    /// `n == 0` and `n == 1` are the identity: no panic, no traffic, data
    /// untouched.
    #[test]
    fn ring_degenerate_rank_counts_are_noops() {
        let mut cost = presets::frontier_like();
        cost.jitter_sigma = 0.0;
        let mut w = build_world(cost, Topology::new(1, 1));
        let data = w.bufs.alloc_init(vec![1.0, 2.0, 3.0]);
        let tmp = w.bufs.alloc(4);
        let out = run_cluster(w, 1, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let q = Queue::create(ctx, rank, sid, Variant::StreamTriggered).unwrap();
            ring_allreduce_st(ctx, rank, 0, &q, sid, data, 3, tmp, COMM_WORLD);
            ring_allreduce_st(ctx, rank, 1, &q, sid, data, 3, tmp, COMM_WORLD);
            stream_synchronize(ctx, sid);
            q.free(ctx).expect("queue idle");
        })
        .unwrap();
        assert_eq!(out.world.bufs.get(data), &[1.0, 2.0, 3.0]);
        assert_eq!(out.world.metrics.bytes_wire, 0);
        assert_eq!(out.world.metrics.bytes_ipc, 0);
    }

    fn run_kt_allreduce(nodes: usize, rpn: usize, len: usize) {
        let n = nodes * rpn;
        let mut cost = presets::frontier_like();
        cost.jitter_sigma = 0.0;
        let mut w = build_world(cost, Topology::new(nodes, rpn));
        let data: Vec<BufId> = (0..n)
            .map(|r| w.bufs.alloc_init((0..len).map(|i| (r * len + i) as f32).collect()))
            .collect();
        let tmp: Vec<BufId> = (0..n).map(|_| w.bufs.alloc(len / n + 1)).collect();
        let expect: Vec<f32> = (0..len)
            .map(|i| (0..n).map(|r| (r * len + i) as f32).sum())
            .collect();
        let data2 = data.clone();
        let out = run_cluster(w, 1, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let q = Queue::create(ctx, rank, sid, Variant::StreamTriggered).unwrap();
            ring_allreduce_kt(ctx, rank, n, &q, sid, data2[rank], len, tmp[rank], COMM_WORLD);
            stream_synchronize(ctx, sid);
            q.free(ctx).expect("queue idle after KT ring");
        })
        .unwrap();
        for r in 0..n {
            assert_eq!(
                out.world.bufs.get(data[r]),
                &expect[..],
                "rank {r} kt-allreduce result wrong"
            );
        }
    }

    #[test]
    fn kt_allreduce_two_ranks_inter_node() {
        run_kt_allreduce(2, 1, 16);
    }

    #[test]
    fn kt_allreduce_four_ranks_intra_node() {
        run_kt_allreduce(1, 4, 32);
    }

    #[test]
    fn kt_allreduce_mixed_topology_odd_len() {
        run_kt_allreduce(2, 2, 37);
    }

    /// KT fires its per-step triggers from inside the reduction kernels:
    /// the run must record mid-kernel trigger actions and fewer stream
    /// memops than the ST ring (one kick vs 2(n-1) start/wait pairs).
    #[test]
    fn kt_allreduce_uses_kernel_triggers_not_memops() {
        let n = 4;
        let len = 32;
        let mut cost = presets::frontier_like();
        cost.jitter_sigma = 0.0;
        let mut w = build_world(cost, Topology::new(n, 1));
        let data: Vec<BufId> = (0..n).map(|_| w.bufs.alloc(len)).collect();
        let tmp: Vec<BufId> = (0..n).map(|_| w.bufs.alloc(len)).collect();
        let out = run_cluster(w, 1, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let q = Queue::create(ctx, rank, sid, Variant::StreamTriggered).unwrap();
            ring_allreduce_kt(ctx, rank, n, &q, sid, data[rank], len, tmp[rank], COMM_WORLD);
            stream_synchronize(ctx, sid);
            q.free(ctx).expect("queue idle after KT ring");
        })
        .unwrap();
        let m = &out.world.metrics;
        // 2(n-1) - 1 triggers ride kernels on each of the n ranks.
        assert_eq!(m.kt_triggers, (n as u64) * (2 * (n as u64 - 1) - 1));
        // The only memop per rank is the step-0 kick.
        assert_eq!(m.memops_executed, n as u64);
    }

    fn run_gi_allreduce(nodes: usize, rpn: usize, len: usize) {
        let n = nodes * rpn;
        let mut cost = presets::frontier_like();
        cost.jitter_sigma = 0.0;
        let mut w = build_world(cost, Topology::new(nodes, rpn));
        let data: Vec<BufId> = (0..n)
            .map(|r| w.bufs.alloc_init((0..len).map(|i| (r * len + i) as f32).collect()))
            .collect();
        let tmp: Vec<BufId> = (0..n).map(|_| w.bufs.alloc(len / n + 1)).collect();
        let expect: Vec<f32> = (0..len)
            .map(|i| (0..n).map(|r| (r * len + i) as f32).sum())
            .collect();
        let data2 = data.clone();
        let out = run_cluster(w, 1, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let q = Queue::create(ctx, rank, sid, Variant::GpuInitiated).unwrap();
            ring_allreduce_gi(ctx, rank, n, &q, sid, data2[rank], len, tmp[rank], COMM_WORLD);
            stream_synchronize(ctx, sid);
            q.free(ctx).expect("queue idle after GI ring");
        })
        .unwrap();
        for r in 0..n {
            assert_eq!(
                out.world.bufs.get(data[r]),
                &expect[..],
                "rank {r} gi-allreduce result wrong"
            );
        }
    }

    #[test]
    fn gi_allreduce_two_ranks_inter_node() {
        run_gi_allreduce(2, 1, 16);
    }

    #[test]
    fn gi_allreduce_four_ranks_intra_node() {
        run_gi_allreduce(1, 4, 32);
    }

    #[test]
    fn gi_allreduce_mixed_topology_odd_len() {
        run_gi_allreduce(2, 2, 37);
    }

    /// GI posts every step's send+recv as command-ring descriptors built
    /// by the kernels themselves: the run must record ring consumptions,
    /// no stream memops at all (not even KT's step-0 kick), and no DWQ
    /// descriptor posts (the NIC drains the ring directly).
    #[test]
    fn gi_allreduce_uses_command_rings_not_memops_or_dwq() {
        let n = 4;
        let len = 32;
        let mut cost = presets::frontier_like();
        cost.jitter_sigma = 0.0;
        let mut w = build_world(cost, Topology::new(n, 1));
        let data: Vec<BufId> = (0..n).map(|_| w.bufs.alloc(len)).collect();
        let tmp: Vec<BufId> = (0..n).map(|_| w.bufs.alloc(len)).collect();
        let out = run_cluster(w, 1, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let q = Queue::create(ctx, rank, sid, Variant::GpuInitiated).unwrap();
            ring_allreduce_gi(ctx, rank, n, &q, sid, data[rank], len, tmp[rank], COMM_WORLD);
            stream_synchronize(ctx, sid);
            q.free(ctx).expect("queue idle after GI ring");
        })
        .unwrap();
        let m = &out.world.metrics;
        // Every step's send+recv rides the ring: at least 2 * 2(n-1)
        // descriptors per rank (sends past GI_CHUNK_BYTES would add
        // more; these chunks are tiny, so exactly one each).
        assert_eq!(m.gi_posts, (n as u64) * 2 * 2 * (n as u64 - 1));
        assert_eq!(m.memops_executed, 0);
        assert_eq!(m.kt_triggers, 0);
        let dwq_posts: u64 = out.world.queues.iter().map(|q| q.dwq_posts).sum();
        assert_eq!(dwq_posts, 0);
    }

    fn run_rd_allreduce(nodes: usize, rpn: usize, len: usize) {
        let n = nodes * rpn;
        assert!(n.is_power_of_two());
        let mut cost = presets::frontier_like();
        cost.jitter_sigma = 0.0;
        let mut w = build_world(cost, Topology::new(nodes, rpn));
        let data: Vec<BufId> = (0..n)
            .map(|r| w.bufs.alloc_init((0..len).map(|i| (r * len + i) as f32).collect()))
            .collect();
        let tmp: Vec<BufId> = (0..n).map(|_| w.bufs.alloc(len)).collect();
        let expect: Vec<f32> = (0..len)
            .map(|i| (0..n).map(|r| (r * len + i) as f32).sum())
            .collect();
        let data2 = data.clone();
        let out = run_cluster(w, 1, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let q = Queue::create(ctx, rank, sid, Variant::StreamTriggered).unwrap();
            recursive_doubling_allreduce_st(
                ctx, rank, n, &q, sid, data2[rank], len, tmp[rank], COMM_WORLD,
            )
            .expect("power-of-two world");
            stream_synchronize(ctx, sid);
            q.free(ctx).expect("queue idle");
        })
        .unwrap();
        for r in 0..n {
            assert_eq!(
                out.world.bufs.get(data[r]),
                &expect[..],
                "rank {r} rd-allreduce result wrong"
            );
        }
    }

    #[test]
    fn rd_allreduce_two_ranks_inter_node() {
        run_rd_allreduce(2, 1, 16);
    }

    #[test]
    fn rd_allreduce_four_ranks_intra_node() {
        run_rd_allreduce(1, 4, 33); // odd length
    }

    #[test]
    fn rd_allreduce_eight_ranks_mixed() {
        run_rd_allreduce(4, 2, 64);
    }

    #[test]
    fn rd_allreduce_single_rank_noop() {
        run_rd_allreduce(1, 1, 5);
    }

    /// Non-power-of-two (and zero) rank counts are rejected before any
    /// operation is enqueued.
    #[test]
    fn rd_allreduce_rejects_bad_rank_counts() {
        let mut cost = presets::frontier_like();
        cost.jitter_sigma = 0.0;
        let w = build_world(cost, Topology::new(3, 1));
        let out = run_cluster(w, 1, move |rank, ctx| {
            let (data, tmp) = ctx.with(|w, _| (w.bufs.alloc(4), w.bufs.alloc(4)));
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let q = Queue::create(ctx, rank, sid, Variant::StreamTriggered).unwrap();
            assert_eq!(
                recursive_doubling_allreduce_st(ctx, rank, 3, &q, sid, data, 4, tmp, COMM_WORLD),
                Err(NotPowerOfTwo(3))
            );
            assert_eq!(
                recursive_doubling_allreduce_st(ctx, rank, 0, &q, sid, data, 4, tmp, COMM_WORLD),
                Err(NotPowerOfTwo(0))
            );
            q.free(ctx).expect("nothing was enqueued");
        })
        .unwrap();
        assert_eq!(out.world.metrics.bytes_wire, 0);
    }
}
