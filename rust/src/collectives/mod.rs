//! Collectives built on the ST primitives: a ring allreduce whose every
//! communication step is stream-triggered.
//!
//! This demonstrates the paper's API composing into higher-level
//! operations: each ring step enqueues a deferred send + receive, one
//! `MPIX_Enqueue_start` triggers them from the GPU stream, and the
//! reduction kernel that consumes the received chunk is ordered after the
//! `MPIX_Enqueue_wait` — the host never synchronizes inside the ring.

use crate::gpu::{self, host_enqueue, KernelPayload, KernelSpec, StreamOp};
use crate::nic::BufSlice;
use crate::sim::HostCtx;
use crate::stx;
use crate::world::{BufId, World};

/// Chunk boundaries for an `n`-way ring over a buffer of `len` elements.
pub fn chunks(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut off = 0;
    for i in 0..n {
        let sz = base + usize::from(i < rem);
        out.push((off, sz));
        off += sz;
    }
    out
}

/// Stream-triggered ring allreduce (sum) of `data` (length `len`) across
/// all `n` ranks, using `queue` (bound to `sid`) for communication and
/// `tmp` (at least ceil(len/n) elements) as the receive staging buffer.
///
/// Standard two-phase ring: (n-1) reduce-scatter steps, then (n-1)
/// allgather steps. Tags encode the step so matching is unambiguous.
#[allow(clippy::too_many_arguments)]
pub fn ring_allreduce_st(
    ctx: &mut HostCtx<World>,
    rank: usize,
    n: usize,
    queue: usize,
    sid: gpu::StreamId,
    data: BufId,
    len: usize,
    tmp: BufId,
    comm: u16,
) {
    if n == 1 {
        return;
    }
    let next = (rank + 1) % n;
    let prev = (rank + n - 1) % n;
    let ch = chunks(len, n);

    // Phase 1: reduce-scatter. In step s, send chunk (rank - s) and
    // receive + accumulate chunk (rank - s - 1).
    for s in 0..n - 1 {
        let send_c = (rank + n - s) % n;
        let recv_c = (rank + n - s - 1) % n;
        let (soff, slen) = ch[send_c];
        let (roff, rlen) = ch[recv_c];
        let tag = 1000 + s as i32;
        stx::enqueue_send(ctx, queue, next, BufSlice::new(data, soff, slen), tag, comm)
            .expect("ring send");
        stx::enqueue_recv(ctx, queue, prev, BufSlice::new(tmp, 0, rlen), tag, comm)
            .expect("ring recv");
        stx::enqueue_start(ctx, queue).expect("ring start");
        stx::enqueue_wait(ctx, queue).expect("ring wait");
        // Accumulate the received chunk, ordered after the wait.
        host_enqueue(
            ctx,
            sid,
            StreamOp::Kernel(KernelSpec {
                name: format!("ring_acc[{s}]"),
                flops: rlen as u64,
                bytes: 3 * 4 * rlen as u64,
                payload: KernelPayload::Fn(Box::new(move |w, _| {
                    let t = w.bufs.get(tmp)[..rlen].to_vec();
                    let d = w.bufs.get_mut(data);
                    for (dst, src) in d[roff..roff + rlen].iter_mut().zip(&t) {
                        *dst += src;
                    }
                })),
            }),
        );
    }

    // Phase 2: allgather. In step s, send chunk (rank + 1 - s) and
    // receive chunk (rank - s) verbatim.
    for s in 0..n - 1 {
        let send_c = (rank + 1 + n - s) % n;
        let recv_c = (rank + n - s) % n;
        let (soff, slen) = ch[send_c];
        let (roff, rlen) = ch[recv_c];
        let tag = 2000 + s as i32;
        stx::enqueue_send(ctx, queue, next, BufSlice::new(data, soff, slen), tag, comm)
            .expect("ring send");
        stx::enqueue_recv(ctx, queue, prev, BufSlice::new(data, roff, rlen), tag, comm)
            .expect("ring recv");
        stx::enqueue_start(ctx, queue).expect("ring start");
        stx::enqueue_wait(ctx, queue).expect("ring wait");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{build_world, run_cluster};
    use crate::costmodel::{presets, MemOpFlavor};
    use crate::gpu::stream_synchronize;
    use crate::mpi::COMM_WORLD;
    use crate::world::Topology;

    #[test]
    fn chunks_cover_everything() {
        for (len, n) in [(10, 3), (16, 4), (7, 8), (100, 7)] {
            let ch = chunks(len, n);
            assert_eq!(ch.len(), n);
            assert_eq!(ch.iter().map(|c| c.1).sum::<usize>(), len);
            let mut off = 0;
            for (o, s) in ch {
                assert_eq!(o, off);
                off += s;
            }
        }
    }

    fn run_allreduce(nodes: usize, rpn: usize, len: usize) {
        let n = nodes * rpn;
        let mut cost = presets::frontier_like();
        cost.jitter_sigma = 0.0;
        let mut w = build_world(cost, Topology::new(nodes, rpn));
        let data: Vec<BufId> = (0..n)
            .map(|r| w.bufs.alloc_init((0..len).map(|i| (r * len + i) as f32).collect()))
            .collect();
        let tmp: Vec<BufId> = (0..n).map(|_| w.bufs.alloc(len / n + 1)).collect();
        // Expected: elementwise sum over ranks.
        let expect: Vec<f32> = (0..len)
            .map(|i| (0..n).map(|r| (r * len + i) as f32).sum())
            .collect();
        let data2 = data.clone();
        let out = run_cluster(w, 1, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let q = stx::create_queue(ctx, rank, sid, MemOpFlavor::Hip);
            ring_allreduce_st(ctx, rank, n, q, sid, data2[rank], len, tmp[rank], COMM_WORLD);
            stream_synchronize(ctx, sid);
        })
        .unwrap();
        for r in 0..n {
            assert_eq!(
                out.world.bufs.get(data[r]),
                &expect[..],
                "rank {r} allreduce result wrong"
            );
        }
    }

    #[test]
    fn allreduce_two_ranks_inter_node() {
        run_allreduce(2, 1, 16);
    }

    #[test]
    fn allreduce_four_ranks_intra_node() {
        run_allreduce(1, 4, 32);
    }

    #[test]
    fn allreduce_mixed_topology() {
        run_allreduce(2, 2, 37); // non-divisible length
    }

    #[test]
    fn allreduce_eight_ranks() {
        run_allreduce(4, 2, 64);
    }

    #[test]
    fn allreduce_single_rank_noop() {
        run_allreduce(1, 1, 8);
    }
}
