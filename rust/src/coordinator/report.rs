//! Run reporting: paper-style avg/min/max summaries and tables.

/// Summary statistics over a set of measured runs (the paper reports
/// average, minimum, and maximum over 5 runs; §V-B).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub avg: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "summary of zero samples");
        let n = samples.len();
        let avg = samples.iter().sum::<f64>() / n as f64;
        let min = samples.iter().copied().fold(f64::INFINITY, f64::min);
        let max = samples.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        Self { avg, min, max, n }
    }
}

/// Percentage difference of `b` relative to `a` (positive = b slower).
pub fn pct_delta(a: f64, b: f64) -> f64 {
    (b - a) / a * 100.0
}

/// Format a virtual-ns quantity as seconds with 4 significant decimals.
pub fn ns_to_s(ns: u64) -> f64 {
    ns as f64 / 1e9
}

/// Render a fixed-width table (first row is the header).
pub fn render_table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    for (ri, row) in rows.iter().enumerate() {
        for (i, cell) in row.iter().enumerate() {
            if i > 0 {
                out.push_str("  ");
            }
            out.push_str(&format!("{:<width$}", cell, width = widths[i]));
        }
        out.push('\n');
        if ri == 0 {
            for (i, w) in widths.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&"-".repeat(*w));
            }
            out.push('\n');
        }
    }
    out
}

/// Render a Markdown pipe table (first row is the header).
pub fn markdown_table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut out = String::new();
    for (ri, row) in rows.iter().enumerate() {
        out.push('|');
        for i in 0..cols {
            out.push(' ');
            out.push_str(row.get(i).map(|c| c.as_str()).unwrap_or(""));
            out.push_str(" |");
        }
        out.push('\n');
        if ri == 0 {
            out.push('|');
            for _ in 0..cols {
                out.push_str(" --- |");
            }
            out.push('\n');
        }
    }
    out
}

/// Escape a string for embedding in a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.avg, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn pct_delta_signs() {
        assert!((pct_delta(100.0, 110.0) - 10.0).abs() < 1e-12);
        assert!((pct_delta(100.0, 96.0) + 4.0).abs() < 1e-12);
    }

    #[test]
    fn markdown_table_renders_header_rule_and_rows() {
        let t = markdown_table(&[
            vec!["workload".into(), "avg".into()],
            vec!["halo3d".into(), "1.00".into()],
        ]);
        assert_eq!(t, "| workload | avg |\n| --- | --- |\n| halo3d | 1.00 |\n");
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(&[
            vec!["variant".into(), "avg".into()],
            vec!["baseline".into(), "1.00".into()],
            vec!["st".into(), "1.10".into()],
        ]);
        assert!(t.contains("variant"));
        assert!(t.lines().count() == 4);
    }
}
