//! Minimal configuration system: a TOML-subset `key = value` parser.
//!
//! No external parser crates are available offline, so this implements
//! the subset the launcher needs: sections (`[faces]`), strings, ints,
//! floats, booleans, and `AxBxC` triples, with `#` comments. Values are
//! accessed through typed getters with defaults.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed configuration: `section.key -> raw string value`.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", i + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", i + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            if values.insert(key.clone(), val).is_some() {
                bail!("line {}: duplicate key '{key}'", i + 1);
            }
        }
        Ok(Self { values })
    }

    pub fn load(path: &str) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        Self::parse(&text)
    }

    /// Overlay `key=value` CLI overrides on top of the file values.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<()> {
        for o in overrides {
            let (k, v) = o
                .split_once('=')
                .ok_or_else(|| anyhow!("override '{o}': expected key=value"))?;
            self.values.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("config '{key}': bad integer '{v}'")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(key, default as u64)? as usize)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("config '{key}': bad float '{v}'")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("config '{key}': bad bool '{v}'"),
        }
    }

    /// Parse an `AxBxC` triple (e.g. a Faces process distribution).
    pub fn triple_or(&self, key: &str, default: (usize, usize, usize)) -> Result<(usize, usize, usize)> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => parse_triple(v).ok_or_else(|| anyhow!("config '{key}': bad triple '{v}'")),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse `AxBxC` (also accepts `A x B x C` with whitespace).
pub fn parse_triple(v: &str) -> Option<(usize, usize, usize)> {
    let parts: Vec<_> = v.split('x').map(|p| p.trim()).collect();
    if parts.len() != 3 {
        return None;
    }
    Some((
        parts[0].parse().ok()?,
        parts[1].parse().ok()?,
        parts[2].parse().ok()?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(
            r#"
            # top comment
            seed = 42
            [faces]
            dist = 2x2x2   # trailing comment
            grid = 32
            variant = "st"
            jitter = 0.03
            check = true
            "#,
        )
        .unwrap();
        assert_eq!(c.u64_or("seed", 0).unwrap(), 42);
        assert_eq!(c.triple_or("faces.dist", (1, 1, 1)).unwrap(), (2, 2, 2));
        assert_eq!(c.usize_or("faces.grid", 0).unwrap(), 32);
        assert_eq!(c.str_or("faces.variant", ""), "st");
        assert!((c.f64_or("faces.jitter", 0.0).unwrap() - 0.03).abs() < 1e-12);
        assert!(c.bool_or("faces.check", false).unwrap());
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.u64_or("nope", 7).unwrap(), 7);
        assert_eq!(c.str_or("nope", "x"), "x");
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(Config::parse("a = 1\na = 2").is_err());
        assert!(Config::parse("just words").is_err());
        assert!(Config::parse("[unterminated").is_err());
    }

    #[test]
    fn overrides_replace_file_values() {
        let mut c = Config::parse("a = 1").unwrap();
        c.apply_overrides(&["a=5".into(), "b.c=7".into()]).unwrap();
        assert_eq!(c.u64_or("a", 0).unwrap(), 5);
        assert_eq!(c.u64_or("b.c", 0).unwrap(), 7);
    }

    #[test]
    fn bad_typed_values_error() {
        let c = Config::parse("a = xyz").unwrap();
        assert!(c.u64_or("a", 0).is_err());
        assert!(c.f64_or("a", 0.0).is_err());
        assert!(c.bool_or("a", false).is_err());
    }

    #[test]
    fn triple_parsing() {
        assert_eq!(parse_triple("8x1x1"), Some((8, 1, 1)));
        assert_eq!(parse_triple("2 x 2 x 2"), Some((2, 2, 2)));
        assert_eq!(parse_triple("2x2"), None);
        assert_eq!(parse_triple("axbxc"), None);
    }
}
