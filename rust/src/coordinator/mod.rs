//! Coordinator: builds the simulated cluster and orchestrates runs.
//!
//! This is the L3 "launcher" layer: it wires nodes, GPUs, NICs, and MPI
//! processes according to a [`Topology`], spawns one host actor per MPI
//! rank, runs the workload, and collects metrics/timings.

pub mod config;
pub mod report;

use crate::costmodel::CostModel;
use crate::gpu::Gpu;
use crate::mpi::Proc;
use crate::nic::Nic;
use crate::obs::{self, CritPath, Overlap, TraceBuf, TraceMeta};
use crate::sim::{Engine, HostCtx, SimError, SimStats, StallDetail};
use crate::world::{ComputeMode, Topology, World};

/// Build a fully-wired world: one NIC per node, one GPU + one MPI process
/// per rank (the paper's one-rank-per-GPU mapping, §V-C).
pub fn build_world(cost: CostModel, topo: Topology) -> World {
    let mut w = World::new(cost, topo.clone());
    // Workload-level runs record a structured trace by default (the
    // compile-free off-switch is `STMPI_TRACE=0`); raw-`Engine` users —
    // the microbenchmarks — never pass through here and stay trace-free.
    w.trace_cap = obs::recording_enabled().then_some(obs::DEFAULT_CAP);
    for n in 0..topo.nodes {
        w.nics.push(Nic::new(n));
    }
    for r in 0..topo.world_size() {
        let node = topo.node_of(r);
        w.gpus.push(Gpu::new(node));
        w.procs.push(Proc::new(r, node, r));
    }
    w
}

/// Result of a cluster run.
pub struct RunOutcome {
    pub world: World,
    pub stats: SimStats,
    /// Wall-clock (virtual ns) at which each rank's program finished.
    pub rank_finish: Vec<u64>,
    /// max over ranks of finish time == the job's makespan.
    pub makespan: u64,
    /// Structured event trace, present when the world requested one via
    /// [`World::trace_cap`](crate::world::World). Byte-deterministic:
    /// identical across reruns and `STMPI_SWEEP_THREADS` settings.
    pub trace: Option<TraceBuf>,
}

/// Trace-derived analytics of a finished run (see [`crate::obs`]): the
/// report-facing summary plus the raw buffer for Chrome-trace export.
pub struct TraceAnalytics {
    /// Achieved communication/computation overlap (`None` when tracing
    /// was off or the run moved nothing over the wire).
    pub overlap: Option<Overlap>,
    /// Critical-path attribution for the last-finishing rank (`None`
    /// when tracing was off).
    pub crit: Option<CritPath>,
    /// The raw event trace, moved out of the outcome.
    pub trace: Option<TraceBuf>,
}

impl RunOutcome {
    /// Move the trace buffer out and derive the report analytics: the
    /// achieved overlap over the whole run, and the critical path of the
    /// last-finishing rank (its timeline approximates the run's longest
    /// dependency chain; finish-time ties break to the highest rank —
    /// any deterministic choice works).
    pub fn take_analytics(&mut self) -> TraceAnalytics {
        let trace = self.trace.take();
        let overlap = trace.as_ref().and_then(obs::achieved_overlap);
        let crit = trace.as_ref().map(|tb| {
            let rank = self
                .rank_finish
                .iter()
                .enumerate()
                .max_by_key(|&(i, t)| (*t, i))
                .map(|(i, _)| i as u32);
            obs::critical_path(tb, rank, self.makespan)
        });
        TraceAnalytics { overlap, crit, trace }
    }
}

/// Launch `world_size` host actors (one per rank) running `program(rank,
/// ctx)`, drive the simulation to completion, and return the outcome.
pub fn run_cluster<F>(
    world: World,
    seed: u64,
    program: F,
) -> Result<RunOutcome, SimError>
where
    F: Fn(usize, &mut HostCtx<World>) + Send + Sync + Clone + 'static,
{
    let world_size = world.topo.world_size();
    let mut eng = Engine::new(world, seed);
    // If the run stalls (event heap drained with parked hosts), enrich
    // the engine's StallReport with cluster-level state: every armed DWQ
    // descriptor still waiting on its trigger, per-rank matching-queue
    // depths, and (under fault injection) the recovery counters.
    eng.set_stall_inspector(|w: &World, core| {
        let mut d = StallDetail::default();
        if let Some(tb) = core.trace() {
            d.notes.push(obs::critical_path(tb, None, core.now()).headline());
        }
        for e in w.armed.pending() {
            match e.queue {
                Some(q) => d.armed.push(format!("nic{} queue {} {}", e.node, q, e.desc)),
                None => d.armed.push(format!("nic{} {}", e.node, e.desc)),
            }
        }
        for p in &w.procs {
            if !p.posted.is_empty() || !p.unexpected.is_empty() {
                d.notes.push(format!(
                    "rank {}: {} posted recv(s) unmatched, {} unexpected message(s) queued",
                    p.rank,
                    p.posted.len(),
                    p.unexpected.len()
                ));
            }
        }
        if let Some(f) = w.fault.as_ref() {
            d.notes.push(format!(
                "fault plan active: {} injected, {} retransmits, {} timeouts, {} payload(s) still lost",
                w.metrics.faults_injected,
                w.metrics.retries,
                w.metrics.timeouts,
                f.lost.len()
            ));
        }
        d
    });
    eng.setup(move |w, core| {
        w.rank_finish = vec![0; world_size];
        if let Some(cap) = w.trace_cap {
            let meta = TraceMeta {
                nodes: w.topo.nodes as u32,
                ranks_per_node: w.topo.ranks_per_node as u32,
                label: String::new(),
            };
            core.trace_start(TraceBuf::new(meta, cap));
        }
    });
    for rank in 0..world_size {
        let program = program.clone();
        eng.spawn_host(format!("rank{rank}"), move |ctx| {
            program(rank, ctx);
            let t = ctx.now();
            ctx.with(move |w, _| w.rank_finish[rank] = t);
        });
    }
    let (world, stats, trace) = eng.run_traced()?;
    let rank_finish = world.rank_finish.clone();
    let makespan = rank_finish.iter().copied().max().unwrap_or(0);
    Ok(RunOutcome { world, stats, rank_finish, makespan, trace })
}

/// Convenience: build + run in one call.
pub fn run(
    cost: CostModel,
    topo: Topology,
    compute: ComputeMode,
    seed: u64,
    program: impl Fn(usize, &mut HostCtx<World>) + Send + Sync + Clone + 'static,
) -> Result<RunOutcome, SimError> {
    let mut w = build_world(cost, topo);
    w.compute = compute;
    run_cluster(w, seed, program)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::presets;

    #[test]
    fn build_world_wires_everything() {
        let w = build_world(presets::frontier_like(), Topology::new(4, 2));
        assert_eq!(w.nics.len(), 4);
        assert_eq!(w.gpus.len(), 8);
        assert_eq!(w.procs.len(), 8);
        assert_eq!(w.procs[5].node, 2);
        assert_eq!(w.gpus[5].node, 2);
    }

    #[test]
    fn run_cluster_records_finish_times() {
        let out = run(
            presets::frontier_like(),
            Topology::new(2, 1),
            ComputeMode::Modeled,
            1,
            |rank, ctx| {
                ctx.advance(100 * (rank as u64 + 1));
            },
        )
        .unwrap();
        assert_eq!(out.rank_finish.len(), 2);
        assert_eq!(out.rank_finish[0], 100);
        assert_eq!(out.rank_finish[1], 200);
        assert_eq!(out.makespan, 200);
    }
}
