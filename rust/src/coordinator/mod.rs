//! Coordinator: builds the simulated cluster and orchestrates runs.
//!
//! This is the L3 "launcher" layer: it wires nodes, GPUs, NICs, and MPI
//! processes according to a [`Topology`], spawns one host actor per MPI
//! rank, runs the workload, and collects metrics/timings.

pub mod config;
pub mod report;

use std::cell::RefCell;

use crate::costmodel::CostModel;
use crate::gpu::Gpu;
use crate::mpi::Proc;
use crate::nic::Nic;
use crate::obs::{self, CritPath, Overlap, TraceBuf, TraceMeta};
use crate::sim::{Engine, HostCtx, SimError, SimStats, StallDetail};
use crate::world::{ComputeMode, Topology, World, WorldSnapshot};

/// Build a fully-wired world: one NIC per node, one GPU + one MPI process
/// per rank (the paper's one-rank-per-GPU mapping, §V-C).
pub fn build_world(cost: CostModel, topo: Topology) -> World {
    let mut w = World::new(cost, topo.clone());
    // Workload-level runs record a structured trace by default (the
    // compile-free off-switch is `STMPI_TRACE=0`); raw-`Engine` users —
    // the microbenchmarks — never pass through here and stay trace-free.
    w.trace_cap = obs::recording_enabled().then_some(obs::DEFAULT_CAP);
    for n in 0..topo.nodes {
        w.nics.push(Nic::new(n));
    }
    for r in 0..topo.world_size() {
        let node = topo.node_of(r);
        w.gpus.push(Gpu::new(node));
        w.procs.push(Proc::new(r, node, r));
    }
    w
}

/// Max worlds parked per worker thread. A campaign worker touches a
/// handful of (workload, variant, topology, queues, dwq-slots) tuples;
/// 16 comfortably covers the grids in [`crate::workloads::campaign`]
/// without hoarding memory.
const WORLD_POOL_CAP: usize = 16;

std::thread_local! {
    /// Per-thread pool of reset worlds keyed by reuse key (see
    /// `workloads::scaffold`): build once per key, snapshot, then
    /// reset-and-release per cell. Thread-local so sweep workers never
    /// contend; `sim::sweep::map` with one thread runs on the caller
    /// thread, so single-threaded campaigns keep their pool across calls.
    static WORLD_POOL: RefCell<Vec<(String, World, WorldSnapshot)>> =
        const { RefCell::new(Vec::new()) };
}

/// Lease a world for `key`: a pooled world is rewound via
/// [`World::reset`] — same wiring and buffer backing stores, fresh run
/// state, byte-identical behavior to a cold build (pinned by the
/// reset-equivalence blitz in `rust/tests/properties.rs`). On a pool
/// miss the world is built cold via [`build_world`]. Tracing capacity is
/// re-derived at lease time so the `STMPI_TRACE` / recording-override
/// state of the *calling* thread wins, exactly as in a cold build.
pub fn lease_world(key: &str, cost: CostModel, topo: Topology) -> World {
    let hit = WORLD_POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.iter().position(|(k, _, _)| k == key).map(|i| pool.remove(i))
    });
    match hit {
        Some((_, mut w, snap)) => {
            w.reset(&snap);
            w.trace_cap = obs::recording_enabled().then_some(obs::DEFAULT_CAP);
            w
        }
        None => build_world(cost, topo),
    }
}

/// Return a finished world to this thread's pool under `key`, reset and
/// ready for the next [`lease_world`]. At most [`WORLD_POOL_CAP`]
/// entries are kept; the oldest is evicted.
pub fn stash_world(key: &str, mut w: World) {
    let snap = w.snapshot();
    w.reset(&snap);
    WORLD_POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.push((key.to_string(), w, snap));
        if pool.len() > WORLD_POOL_CAP {
            pool.remove(0);
        }
    });
}

/// Drop every pooled world on this thread. Tests call this to force the
/// cold-build path (and then rerun to exercise the reset path).
pub fn clear_world_pool() {
    WORLD_POOL.with(|p| p.borrow_mut().clear());
}

/// Result of a cluster run.
pub struct RunOutcome {
    pub world: World,
    pub stats: SimStats,
    /// Wall-clock (virtual ns) at which each rank's program finished.
    pub rank_finish: Vec<u64>,
    /// max over ranks of finish time == the job's makespan.
    pub makespan: u64,
    /// Structured event trace, present when the world requested one via
    /// [`World::trace_cap`](crate::world::World). Byte-deterministic:
    /// identical across reruns and `STMPI_SWEEP_THREADS` settings.
    pub trace: Option<TraceBuf>,
}

/// Trace-derived analytics of a finished run (see [`crate::obs`]): the
/// report-facing summary plus the raw buffer for Chrome-trace export.
pub struct TraceAnalytics {
    /// Achieved communication/computation overlap (`None` when tracing
    /// was off or the run moved nothing over the wire).
    pub overlap: Option<Overlap>,
    /// Critical-path attribution for the last-finishing rank (`None`
    /// when tracing was off).
    pub crit: Option<CritPath>,
    /// The raw event trace, moved out of the outcome.
    pub trace: Option<TraceBuf>,
}

impl RunOutcome {
    /// Move the trace buffer out and derive the report analytics: the
    /// achieved overlap over the whole run, and the critical path of the
    /// last-finishing rank (its timeline approximates the run's longest
    /// dependency chain; finish-time ties break to the highest rank —
    /// any deterministic choice works).
    pub fn take_analytics(&mut self) -> TraceAnalytics {
        let trace = self.trace.take();
        let overlap = trace.as_ref().and_then(obs::achieved_overlap);
        let crit = trace.as_ref().map(|tb| {
            let rank = self
                .rank_finish
                .iter()
                .enumerate()
                .max_by_key(|&(i, t)| (*t, i))
                .map(|(i, _)| i as u32);
            obs::critical_path(tb, rank, self.makespan)
        });
        TraceAnalytics { overlap, crit, trace }
    }
}

/// Launch `world_size` host actors (one per rank) running `program(rank,
/// ctx)`, drive the simulation to completion, and return the outcome.
pub fn run_cluster<F>(
    world: World,
    seed: u64,
    program: F,
) -> Result<RunOutcome, SimError>
where
    F: Fn(usize, &mut HostCtx<World>) + Send + Sync + Clone + 'static,
{
    let world_size = world.topo.world_size();
    let mut eng = Engine::new(world, seed);
    // If the run stalls (event heap drained with parked hosts), enrich
    // the engine's StallReport with cluster-level state: every armed DWQ
    // descriptor still waiting on its trigger, per-rank matching-queue
    // depths, and (under fault injection) the recovery counters.
    eng.set_stall_inspector(|w: &World, core| {
        let mut d = StallDetail::default();
        if let Some(tb) = core.trace() {
            d.notes.push(obs::critical_path(tb, None, core.now()).headline());
        }
        for e in w.armed.pending() {
            match e.queue {
                Some(q) => d.armed.push(format!("nic{} queue {} {}", e.node, q, e.desc)),
                None => d.armed.push(format!("nic{} {}", e.node, e.desc)),
            }
        }
        for p in &w.procs {
            if !p.posted.is_empty() || !p.unexpected.is_empty() {
                d.notes.push(format!(
                    "rank {}: {} posted recv(s) unmatched, {} unexpected message(s) queued",
                    p.rank,
                    p.posted.len(),
                    p.unexpected.len()
                ));
            }
        }
        if let Some(f) = w.fault.as_ref() {
            d.notes.push(format!(
                "fault plan active: {} injected, {} retransmits, {} timeouts, {} payload(s) still lost",
                w.metrics.faults_injected,
                w.metrics.retries,
                w.metrics.timeouts,
                f.lost.len()
            ));
        }
        d
    });
    eng.setup(move |w, core| {
        w.rank_finish = vec![0; world_size];
        if let Some(cap) = w.trace_cap {
            let meta = TraceMeta {
                nodes: w.topo.nodes as u32,
                ranks_per_node: w.topo.ranks_per_node as u32,
                label: String::new(),
            };
            core.trace_start(TraceBuf::new(meta, cap));
        }
    });
    for rank in 0..world_size {
        let program = program.clone();
        eng.spawn_host(format!("rank{rank}"), move |ctx| {
            program(rank, ctx);
            let t = ctx.now();
            ctx.with(move |w, _| w.rank_finish[rank] = t);
        });
    }
    let (world, stats, trace) = eng.run_traced()?;
    let rank_finish = world.rank_finish.clone();
    let makespan = rank_finish.iter().copied().max().unwrap_or(0);
    Ok(RunOutcome { world, stats, rank_finish, makespan, trace })
}

/// Convenience: build + run in one call.
pub fn run(
    cost: CostModel,
    topo: Topology,
    compute: ComputeMode,
    seed: u64,
    program: impl Fn(usize, &mut HostCtx<World>) + Send + Sync + Clone + 'static,
) -> Result<RunOutcome, SimError> {
    let mut w = build_world(cost, topo);
    w.compute = compute;
    run_cluster(w, seed, program)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::presets;

    #[test]
    fn build_world_wires_everything() {
        let w = build_world(presets::frontier_like(), Topology::new(4, 2));
        assert_eq!(w.nics.len(), 4);
        assert_eq!(w.gpus.len(), 8);
        assert_eq!(w.procs.len(), 8);
        assert_eq!(w.procs[5].node, 2);
        assert_eq!(w.gpus[5].node, 2);
    }

    #[test]
    fn world_pool_round_trip_reuses_wiring() {
        clear_world_pool();
        let topo = Topology::new(3, 2);
        let w = lease_world("pool-test-key", presets::frontier_like(), topo.clone());
        assert_eq!(w.nics.len(), 3);
        assert_eq!(w.gpus.len(), 6);
        stash_world("pool-test-key", w);
        // Same key leases the pooled world (reset, wiring intact)...
        let w2 = lease_world("pool-test-key", presets::frontier_like(), topo.clone());
        assert_eq!(w2.nics.len(), 3);
        assert_eq!(w2.procs.len(), 6);
        assert!(w2.queues.is_empty() && w2.requests.is_empty());
        // ...and the pool is now empty again: a different key builds cold.
        let w3 = lease_world("other-key", presets::frontier_like(), Topology::new(2, 1));
        assert_eq!(w3.nics.len(), 2);
        clear_world_pool();
    }

    #[test]
    fn run_cluster_records_finish_times() {
        let out = run(
            presets::frontier_like(),
            Topology::new(2, 1),
            ComputeMode::Modeled,
            1,
            |rank, ctx| {
                ctx.advance(100 * (rank as u64 + 1));
            },
        )
        .unwrap();
        assert_eq!(out.rank_finish.len(), 2);
        assert_eq!(out.rank_finish[0], 100);
        assert_eq!(out.rank_finish[1], 200);
        assert_eq!(out.makespan, 200);
    }
}
