//! # stmpi — GPU Stream-Aware Message Passing using Triggered Operations
//!
//! A from-scratch reproduction of the HPE paper *"Exploring GPU
//! Stream-Aware Message Passing using Triggered Operations"* (CS.DC 2022):
//! the **stream-triggered (ST)** MPI communication strategy, implemented
//! over a deterministic virtual-time simulation of a Frontier-like
//! cluster — simulated Slingshot-11 NICs with triggered operations
//! (deferred work queues, hardware counters), simulated GPUs with streams
//! and a control processor, a two-sided MPI matching layer with progress
//! threads — while the *numerics* of every GPU kernel flow through real
//! AOT-compiled XLA programs (JAX + Pallas, lowered at build time, loaded
//! via PJRT on the rust side). The **kernel-triggered (KT)** follow-on
//! design (arXiv 2306.15773) is modeled as a third variant beside the
//! host baseline and ST: see [`stx::Variant`] and [`gpu::KernelCtx`].
//!
//! ## Architecture map
//!
//! | module | role |
//! |---|---|
//! | [`sim`] | virtual-time discrete-event engine, host actors, parallel sweep executor |
//! | [`world`] | the simulated cluster state threaded through the engine |
//! | [`costmodel`] | calibrated latencies/bandwidths of the Frontier-like testbed |
//! | [`gpu`] | streams + control processor, stream memory ops, KT kernel hooks |
//! | [`nic`] | Slingshot-11 counters, deferred work queues (triggered sends/puts/receives), eager/rendezvous |
//! | [`fabric`] | inter-node wire with per-port serialization + congestion metrics |
//! | [`fault`] | deterministic fault injection (drop/dup/delay, trigger delay, stragglers) + recovery knobs |
//! | [`mpi`] | two-sided matching engine, requests, progress threads |
//! | [`obs`] | deterministic event tracing, Chrome-trace export, overlap + critical-path analytics |
//! | [`stx`] | stx v2: typed [`stx::Queue`] handles, persistent [`stx::CommPlan`]s, KT hooks, the [`stx::Variant`] axis |
//! | [`collectives`] | ST ring / ST recursive-doubling / KT ring allreduce |
//! | [`faces`] | the Faces halo-exchange benchmark + figure harness |
//! | [`workloads`] | `Workload` trait, nine scenarios, run scaffold, campaign driver |
//! | [`store`] | content-addressed campaign store: cell fingerprints, segment-log persistence, incremental reruns, query service |
//! | [`coordinator`] | world building, cluster run loop, config, reporting |
//! | [`runtime`] | PJRT loader for AOT HLO artifacts (feature `xla`) |
//! | [`train`] | ST-allreduce data-parallel trainer |
//!
//! See `README.md` for the quickstart, `DESIGN.md` for the architecture
//! and trigger timelines, and `EXPERIMENTS.md` for the reproduced
//! figures and the campaign report schema.

pub mod collectives;
pub mod coordinator;
pub mod costmodel;
pub mod faces;
pub mod fabric;
pub mod fault;
pub mod gpu;
pub mod mpi;
pub mod nic;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod stx;
pub mod train;
pub mod workloads;
pub mod world;
