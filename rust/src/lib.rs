//! # stmpi — GPU Stream-Aware Message Passing using Triggered Operations
//!
//! A from-scratch reproduction of the HPE paper *"Exploring GPU
//! Stream-Aware Message Passing using Triggered Operations"* (CS.DC 2022):
//! the **stream-triggered (ST)** MPI communication strategy, implemented
//! over a deterministic virtual-time simulation of a Frontier-like
//! cluster — simulated Slingshot-11 NICs with triggered operations
//! (deferred work queues, hardware counters), simulated GPUs with streams
//! and a control processor, a two-sided MPI matching layer with progress
//! threads — while the *numerics* of every GPU kernel flow through real
//! AOT-compiled XLA programs (JAX + Pallas, lowered at build time, loaded
//! via PJRT on the rust side).
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! reproduced figures.

pub mod collectives;
pub mod coordinator;
pub mod costmodel;
pub mod faces;
pub mod fabric;
pub mod gpu;
pub mod mpi;
pub mod nic;
pub mod runtime;
pub mod sim;
pub mod stx;
pub mod train;
pub mod workloads;
pub mod world;
