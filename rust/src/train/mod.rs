//! Data-parallel trainer over the ST stack: each rank runs the
//! AOT-compiled `train_grad` step (a small causal LM, see
//! `python/compile/model.py`), allreduces the flat gradient with the
//! stream-triggered ring collective, and applies `sgd_apply` — all kernel
//! launches and communication driven through the GPU stream.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::collectives::ring_allreduce_st;
use crate::coordinator::{build_world, run_cluster};
use crate::costmodel::{CostModel, MemOpFlavor};
use crate::gpu::{self, host_enqueue, stream_synchronize, KernelPayload, KernelSpec, StreamOp};
use crate::mpi::COMM_WORLD;
use crate::runtime::Runtime;
use crate::sim::HostCtx;
use crate::world::{BufId, ComputeMode, Topology, World};

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub nodes: usize,
    pub ranks_per_node: usize,
    pub steps: usize,
    pub seed: u64,
    pub cost: CostModel,
    /// Memop flavor for the ST collective.
    pub flavor: MemOpFlavor,
}

/// Outcome: the loss curve (mean across ranks per step) + timings.
#[derive(Debug)]
pub struct TrainResult {
    pub losses: Vec<f32>,
    pub time_ns: u64,
    pub world_size: usize,
}

/// Deterministic synthetic corpus: rank- and step-dependent token batch.
/// Low-entropy pattern (token ~ linear in position with drift) so the LM
/// has something learnable.
fn batch_tokens(elems: usize, vocab: usize, rank: usize, step: usize) -> Vec<f32> {
    (0..elems)
        .map(|i| {
            let v = (i * 3 + rank * 7 + step + (i / 17)) % vocab;
            v as f32
        })
        .collect()
}

/// Run data-parallel training with the ST ring allreduce.
pub fn train(cfg: &TrainConfig) -> Result<TrainResult> {
    let n = cfg.nodes * cfg.ranks_per_node;
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::load(&dir).context("loading AOT artifacts (run `make artifacts`)")?;
    for e in ["train_init", "train_grad", "sgd_apply"] {
        if !rt.has_entry(e) {
            bail!("artifact '{e}' missing");
        }
    }
    let params0 = rt.execute_f32("train_init", &[])?.remove(0);
    let p_len = params0.len();
    let tok_elems = rt.entry_meta("train_grad").unwrap().inputs[1].elems();

    let mut world = build_world(cfg.cost.clone(), Topology::new(cfg.nodes, cfg.ranks_per_node));
    world.compute = ComputeMode::Real;
    world.runtime = Some(Arc::new(rt));

    // Per-rank buffers.
    let params: Vec<BufId> = (0..n).map(|_| world.bufs.alloc_init(params0.clone())).collect();
    let grads: Vec<BufId> = (0..n).map(|_| world.bufs.alloc(p_len)).collect();
    let tmp: Vec<BufId> = (0..n).map(|_| world.bufs.alloc(p_len / n + 1)).collect();
    let loss: Vec<BufId> = (0..n).map(|_| world.bufs.alloc(1)).collect();
    let toks: Vec<BufId> = (0..n).map(|_| world.bufs.alloc(tok_elems)).collect();

    let losses: Arc<Mutex<Vec<Vec<f32>>>> = Arc::new(Mutex::new(vec![Vec::new(); n]));
    let steps = cfg.steps;
    let flavor = cfg.flavor;
    let (params2, grads2, tmp2, loss2, toks2) =
        (params.clone(), grads.clone(), tmp.clone(), loss.clone(), toks.clone());
    let losses2 = losses.clone();

    let out = run_cluster(world, cfg.seed, move |rank, ctx| {
        let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
        let variant = match flavor {
            MemOpFlavor::Shader => crate::stx::Variant::StreamTriggeredShader,
            MemOpFlavor::Hip => crate::stx::Variant::StreamTriggered,
        };
        let q = crate::stx::Queue::create(ctx, rank, sid, variant)
            .expect("NIC counter pool exhausted");
        let (p, g, t, l, tk) = (params2[rank], grads2[rank], tmp2[rank], loss2[rank], toks2[rank]);
        for step in 0..steps {
            // Load this rank's shard of the synthetic corpus.
            ctx.with(move |w, _| {
                *w.bufs.get_mut(tk) = batch_tokens(tok_elems, 32, rank, step);
            });
            // Forward+backward on the device.
            host_enqueue(
                ctx,
                sid,
                StreamOp::Kernel(KernelSpec {
                    name: format!("train_grad[{step}]"),
                    flops: 40 * p_len as u64, // fwd+bwd roofline estimate
                    bytes: 8 * p_len as u64,
                    payload: KernelPayload::Hlo {
                        entry: "train_grad".into(),
                        inputs: vec![p, tk],
                        outputs: vec![l, g],
                    },
                }),
            );
            // Stream-triggered gradient allreduce (sum).
            let ws = ctx_world_size(ctx);
            ring_allreduce_st(ctx, rank, ws, &q, sid, g, p_len, t, COMM_WORLD);
            // Average + SGD apply.
            let world_n = ws as f32;
            host_enqueue(
                ctx,
                sid,
                StreamOp::Kernel(KernelSpec {
                    name: format!("scale[{step}]"),
                    flops: p_len as u64,
                    bytes: 8 * p_len as u64,
                    payload: KernelPayload::Fn(Box::new(move |w, _| {
                        for x in w.bufs.get_mut(g).iter_mut() {
                            *x /= world_n;
                        }
                    })),
                }),
            );
            host_enqueue(
                ctx,
                sid,
                StreamOp::Kernel(KernelSpec {
                    name: format!("sgd[{step}]"),
                    flops: 2 * p_len as u64,
                    bytes: 12 * p_len as u64,
                    payload: KernelPayload::Hlo {
                        entry: "sgd_apply".into(),
                        inputs: vec![p, g],
                        outputs: vec![p],
                    },
                }),
            );
            stream_synchronize(ctx, sid);
            let lz = losses2.clone();
            ctx.with(move |w, _| {
                lz.lock().unwrap()[rank].push(w.bufs.get(l)[0]);
            });
        }
        q.free(ctx).expect("queue drained");
    })
    .map_err(|e| anyhow::anyhow!("training run failed: {e}"))?;

    let per_rank = losses.lock().unwrap().clone();
    let mut curve = Vec::with_capacity(steps);
    for s in 0..steps {
        let mean = per_rank.iter().map(|r| r[s]).sum::<f32>() / n as f32;
        curve.push(mean);
    }
    Ok(TrainResult { losses: curve, time_ns: out.makespan, world_size: n })
}

/// World size as seen from inside a host program.
fn ctx_world_size(ctx: &mut HostCtx<World>) -> usize {
    ctx.with(|w, _| w.topo.world_size())
}

#[cfg(test)]
mod tests {
    use super::*;
    #[cfg(feature = "xla")]
    use crate::costmodel::presets;

    #[test]
    fn chunked_batches_are_deterministic_and_in_vocab() {
        let a = batch_tokens(136, 32, 1, 2);
        let b = batch_tokens(136, 32, 1, 2);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0.0..32.0).contains(&t)));
        assert_ne!(batch_tokens(136, 32, 0, 0), batch_tokens(136, 32, 1, 0));
    }

    /// Needs the PJRT backend (`--features xla` + AOT artifacts).
    #[cfg(feature = "xla")]
    #[test]
    fn two_rank_training_reduces_loss() {
        let cfg = TrainConfig {
            nodes: 2,
            ranks_per_node: 1,
            steps: 12,
            seed: 1,
            cost: presets::frontier_like(),
            flavor: MemOpFlavor::Hip,
        };
        let r = train(&cfg).unwrap();
        assert_eq!(r.losses.len(), 12);
        assert!(r.losses.iter().all(|l| l.is_finite()));
        let first = r.losses[0];
        let last = *r.losses.last().unwrap();
        assert!(last < first, "loss must decrease: {first} -> {last}");
    }
}
