//! `faces` workload: adapter over the existing Faces benchmark
//! ([`crate::faces::run_faces`]), exposing the paper's nearest-neighbor
//! halo exchange to the campaign driver.
//!
//! The size axis maps to the Faces block edge: `elems` approximates the
//! face-message payload, so `g = max(4, round(sqrt(elems)))`. Runs use
//! Modeled compute (the Faces numerics are validated by their own
//! Real-compute e2e tests), hence [`Validation::NotChecked`].

use anyhow::{anyhow, bail, Result};

use crate::faces::{run_faces, FacesConfig, Variant};
use crate::world::ComputeMode;

use super::{grid_for, ScenarioCfg, ScenarioRun, Validation, Workload};

pub struct FacesAdapter;

fn parse_variant(name: &str) -> Result<Variant> {
    Variant::parse(name).ok_or_else(|| anyhow!("faces: unknown variant '{name}'"))
}

/// Block edge approximating a face payload of `elems` f32s.
fn edge_for(elems: usize) -> usize {
    ((elems as f64).sqrt().round() as usize).max(4)
}

impl Workload for FacesAdapter {
    fn name(&self) -> &'static str {
        "faces"
    }

    fn description(&self) -> &'static str {
        "Nekbone nearest-neighbor halo exchange (paper §V), via run_faces"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "st", "st-shader", "kt", "gi"]
    }

    fn default_elems(&self) -> &'static [usize] {
        // Face payloads of 1 KiB / 16 KiB / 64 KiB (elems * 4 bytes).
        &[256, 4096, 16384]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        parse_variant(&cfg.variant)?;
        if cfg.world_size() == 0 {
            bail!("faces: empty world");
        }
        if cfg.queues_per_rank != 1 {
            bail!("faces: the Faces benchmark drives exactly one queue per rank");
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let variant = parse_variant(&cfg.variant)?;
        let fc = FacesConfig {
            dist: grid_for(cfg.world_size()),
            nodes: cfg.nodes,
            ranks_per_node: cfg.ranks_per_node,
            g: edge_for(cfg.elems),
            outer: 1,
            middle: 1,
            inner: cfg.iters,
            variant,
            compute: ComputeMode::Modeled,
            check: false,
            seed: cfg.seed,
            cost: cfg.cost.clone(),
            faults: cfg.faults.clone(),
        };
        let r = run_faces(&fc)?;
        Ok(ScenarioRun {
            time_ns: r.time_ns,
            metrics: r.metrics,
            stats: r.stats,
            validation: Validation::NotChecked,
            // run_faces returns no world handle, so the adapter cannot
            // observe per-queue counters (reports render `--`).
            per_queue: Vec::new(),
            overlap: r.overlap,
            crit: r.crit,
            trace: r.trace,
        })
    }
}
