//! Workload engine: a trait-based scenario subsystem.
//!
//! The paper evaluates stream-triggered communication on exactly one
//! pattern (Faces, the Nekbone nearest-neighbor exchange), but the design
//! questions it raises — triggered-op counts, progress-thread pressure,
//! fabric contention — only show up across *diverse* patterns: halos,
//! collectives, all-to-all, incast. This module turns "a scenario" into a
//! ~100-line plug-in instead of a bespoke `build_world`/`run_cluster`
//! module:
//!
//! * [`Workload`] — the scenario contract: **configure** (feasibility of
//!   one grid cell) → **run** (per-rank host actor bodies under
//!   [`crate::coordinator::run_cluster`]) → **validate** (host-side
//!   reference where the pattern moves real payloads) → **metrics
//!   summary** ([`ScenarioRun`]).
//! * [`registry`] — the name-keyed catalogue of shipped workloads.
//! * [`campaign`] — the cross-product driver: {workload × variant ×
//!   message size × topology × queues-per-rank × seed} on the parallel
//!   sweep executor, emitting one JSON + Markdown comparative report.
//! * [`scaffold`] — the shared per-rank run scaffold (stream/queue
//!   setup, timers, exact-compare validation) that shrinks a plug-in to
//!   pattern + compute.
//!
//! Shipped workloads:
//!
//! | name        | pattern                                          |
//! |-------------|--------------------------------------------------|
//! | `faces`     | adapter over [`crate::faces::run_faces`]         |
//! | `halo3d`    | 27-point stencil exchange (faces+edges+corners)  |
//! | `allreduce` | host / ST / KT / GI ring + ST recursive-doubling |
//! | `alltoall`  | transpose-style personalized exchange            |
//! | `incast`    | N→1 hotspot stress on one NIC ingress port       |
//! | `allgather` | ring gather phase over persistent `CommPlan`s    |
//! | `halograph` | sparse random-graph halo, skewed arrivals driving the unexpected-message path |
//! | `reduce-scatter` | ring reduce phase: serialized add-kernel chain over per-step CommPlans |
//! | `broadcast` | binomial-tree root-to-all relay: log-depth receive-before-forward chains |
//!
//! Every workload sweeps the [`crate::stx::Variant`] axis: the host
//! baseline, the paper's stream-triggered path (`st` / `st-shader`),
//! the kernel-triggered path (`kt`, arXiv 2306.15773) in which
//! triggers fire from inside kernels and completion waits ride kernel
//! prologues — no per-iteration stream memory ops at all — and the
//! GPU-initiated path (`gi`, arXiv 2503.24230) in which the kernel
//! itself builds command-ring descriptors the NIC drains, trading zero
//! host arming cost for per-descriptor device time
//! (`cost.gi_descr_build_ns`).

pub mod campaign;
pub mod scaffold;

mod allgather;
mod allreduce;
mod alltoall;
mod broadcast;
mod faces;
mod halo3d;
mod halograph;
mod incast;
mod reduce_scatter;

pub use campaign::{
    diff_cost_models, run_campaign, run_campaign_observed, CampaignProgress, CampaignReport,
    CampaignSpec, CostDiff,
};

use anyhow::{anyhow, Result};

use crate::costmodel::CostModel;
use crate::fault::FaultSpec;
use crate::obs::{CritPath, Overlap, TraceBuf};
use crate::sim::SimStats;
use crate::stx::Variant;
use crate::world::{Metrics, Topology};

/// One cell of a campaign grid: everything a workload needs for one run.
#[derive(Debug, Clone)]
pub struct ScenarioCfg {
    /// One of the workload's [`Workload::variants`] names.
    pub variant: String,
    /// Per-message payload size in f32 elements (each workload documents
    /// what exactly it scales by this).
    pub elems: usize,
    pub nodes: usize,
    pub ranks_per_node: usize,
    /// Timed iterations of the pattern.
    pub iters: usize,
    /// `stx::Queue`s per rank — the multi-queue contention axis. The
    /// scaffold-based workloads stripe their plans over this many
    /// queues; workloads that drive exactly one queue reject other
    /// values in `configure` (the campaign reports those cells as
    /// skipped).
    pub queues_per_rank: usize,
    pub seed: u64,
    pub cost: CostModel,
    /// Fault-injection plan for this cell (`None` = no chaos; the
    /// no-fault timeline is bit-for-bit identical to earlier releases).
    /// The per-cell decision stream is keyed by
    /// [`crate::fault::fingerprint`] over [`ScenarioCfg::fault_label`],
    /// so chaos campaigns replay byte-identically at any sweep thread
    /// count.
    pub faults: Option<FaultSpec>,
}

impl ScenarioCfg {
    /// Small default cell used by tests.
    pub fn smoke(variant: &str, nodes: usize, rpn: usize, elems: usize) -> Self {
        let mut cost = crate::costmodel::presets::frontier_like();
        cost.jitter_sigma = 0.0;
        Self {
            variant: variant.to_string(),
            elems,
            nodes,
            ranks_per_node: rpn,
            iters: 2,
            queues_per_rank: 1,
            seed: 7,
            cost,
            faults: None,
        }
    }

    pub fn world_size(&self) -> usize {
        self.nodes * self.ranks_per_node
    }

    pub fn topology(&self) -> Topology {
        Topology::new(self.nodes, self.ranks_per_node)
    }

    /// Stable label identifying this cell for the fault fingerprint:
    /// `workload/variant/elems/nodesxrpn/qN/sSEED`.
    pub fn fault_label(&self, workload: &str) -> String {
        format!(
            "{workload}/{}/{}/{}x{}/q{}/s{}",
            self.variant, self.elems, self.nodes, self.ranks_per_node, self.queues_per_rank,
            self.seed
        )
    }
}

/// Validation outcome of one scenario run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Validation {
    /// `checked` values were compared against the host-side reference and
    /// all matched exactly.
    Passed { checked: usize },
    /// Timing-only run; the pattern's numerics are validated elsewhere
    /// (e.g. the faces adapter defers to the Real-compute e2e tests).
    NotChecked,
    Failed { detail: String },
}

impl Validation {
    pub fn ok(&self) -> bool {
        !matches!(self, Validation::Failed { .. })
    }

    /// Short label used by the campaign report.
    pub fn label(&self) -> String {
        match self {
            Validation::Passed { checked } => format!("passed({checked})"),
            Validation::NotChecked => "not-checked".to_string(),
            Validation::Failed { detail } => format!("FAILED: {detail}"),
        }
    }
}

/// Per-queue-slot aggregate of a run's [`crate::stx::QueueStats`]-style
/// counters: DWQ descriptor posts and slot-wait stalls, summed over all
/// ranks for each *within-rank* queue slot. This is the per-queue split
/// of the campaign report's aggregated `dwq waits` column — slot `s`
/// collects the s-th queue every rank created.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueueSlotStats {
    /// Within-rank queue index (0..queues_per_rank).
    pub slot: usize,
    /// DWQ descriptor posts by this slot's queues, summed over ranks.
    pub dwq_posts: u64,
    /// DWQ slot-wait stalls by this slot's queues, summed over ranks.
    pub dwq_slot_waits: u64,
}

/// Result of one scenario run: the figure of merit plus the counters the
/// campaign report aggregates. `Eq` on the whole struct is what the
/// reset-equivalence blitz compares: a snapshot-reset world must
/// reproduce a fresh build byte-for-byte, trace included.
#[derive(Debug, PartialEq, Eq)]
pub struct ScenarioRun {
    /// Max over ranks of accumulated timed-region wall time (virtual ns).
    pub time_ns: u64,
    pub metrics: Metrics,
    pub stats: SimStats,
    pub validation: Validation,
    /// Per-queue-slot DWQ counters (empty when the run created no
    /// queues, or for adapters that cannot observe the world — the
    /// `faces` adapter reports none).
    pub per_queue: Vec<QueueSlotStats>,
    /// Achieved communication/computation overlap from the run's trace
    /// (`None` when tracing is off — `STMPI_TRACE=0` — or the run moved
    /// nothing over the wire).
    pub overlap: Option<Overlap>,
    /// Critical-path time attribution for the last-finishing rank
    /// (`None` when tracing is off).
    pub crit: Option<CritPath>,
    /// The raw event trace, for Chrome-trace export (`None` when
    /// tracing is off). Campaign cells drop it unless an export was
    /// requested, so sweeps don't hold every cell's buffer.
    pub trace: Option<TraceBuf>,
}

/// A communication scenario runnable by the campaign driver.
///
/// Contract (documented in EXPERIMENTS.md §Workload layer):
///
/// 1. `configure` is a cheap feasibility check of one grid cell; the
///    campaign skips (and reports) infeasible cells instead of failing.
/// 2. `run` executes one configured cell to completion: it builds the
///    world, spawns one host actor per rank, times the pattern, validates
///    against a host-side reference where applicable, and returns the
///    summary. Runs must be deterministic functions of the config
///    (randomness only via `cfg.seed`).
/// 3. Variants must keep their timed regions comparable: every variant
///    of a workload ends its region fully drained (kernels complete,
///    triggered sends completed), so figures of merit differ only by
///    the control path under study.
pub trait Workload: Send + Sync {
    /// Registry key, stable across releases (used by CLI filters and
    /// report rows).
    fn name(&self) -> &'static str;
    /// One-line human description shown by reports.
    fn description(&self) -> &'static str;
    /// Variant names in deterministic order. The first entry is the
    /// workload's *reference* variant: campaign reports compute every
    /// other cell's baseline-relative delta against it.
    fn variants(&self) -> &'static [&'static str];
    /// Default message sizes (f32 elems) used when a campaign does not
    /// override the size axis.
    fn default_elems(&self) -> &'static [usize];
    /// Cheap feasibility check of one grid cell.
    fn configure(&self, cfg: &ScenarioCfg) -> Result<()>;
    /// Run one configured cell to completion.
    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun>;
}

/// The name-keyed workload catalogue, in report order.
pub fn registry() -> Vec<Box<dyn Workload>> {
    vec![
        Box::new(faces::FacesAdapter),
        Box::new(halo3d::Halo3d),
        Box::new(allreduce::Allreduce),
        Box::new(alltoall::AllToAll),
        Box::new(incast::Incast),
        Box::new(allgather::Allgather),
        Box::new(halograph::HaloGraph),
        Box::new(reduce_scatter::ReduceScatter),
        Box::new(broadcast::Broadcast),
    ]
}

/// Look a workload up by its registry name.
pub fn by_name(name: &str) -> Option<Box<dyn Workload>> {
    registry().into_iter().find(|w| w.name() == name)
}

/// All registered workload names, in report order.
pub fn names() -> Vec<&'static str> {
    registry().iter().map(|w| w.name()).collect()
}

/// Shared variant axis for the point-to-point workloads — the
/// [`crate::stx::Variant`] names: `baseline` (host-synchronized MPI),
/// `st`/`st-shader` (stream-triggered with the HIP or hand-coded-shader
/// memop flavor, paper §V-F), `kt` (kernel-triggered, arXiv 2306.15773),
/// and `gi` (GPU-initiated command rings, arXiv 2503.24230). `workload`
/// names the caller in the rejection message.
pub(crate) fn comm_variant(workload: &str, variant: &str) -> Result<Variant> {
    Variant::parse(variant).ok_or_else(|| {
        anyhow!("{workload}: unknown variant '{variant}' (known: baseline, st, st-shader, kt, gi)")
    })
}

/// Deterministic payload element shared by the validated workloads: small
/// positive integers (< 8192), exactly representable in f32, so host-side
/// references can compare with `==` even after accumulation (sums stay
/// far below 2^24).
pub(crate) fn payload(rank: usize, lane: usize, j: usize) -> f32 {
    (((rank * 131 + lane * 31 + j) % 8191) + 1) as f32
}

/// Choose a (px, py, pz) process grid for `n` ranks, as close to cubic as
/// the factorization of `n` allows (px >= py >= pz, px*py*pz == n).
pub fn grid_for(n: usize) -> (usize, usize, usize) {
    assert!(n >= 1, "grid_for needs at least one rank");
    let mut best = (n, 1, 1);
    let mut best_score = n + 2;
    for pz in 1..=n {
        if n % pz != 0 {
            continue;
        }
        let m = n / pz;
        for py in pz..=m {
            if m % py != 0 {
                continue;
            }
            let px = m / py;
            if px < py {
                continue;
            }
            let score = px + py + pz;
            if score < best_score {
                best_score = score;
                best = (px, py, pz);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests;
