//! `halograph` workload: sparse irregular-neighborhood halo exchange —
//! the ROADMAP's "graph neighborhoods instead of grids" scenario, built
//! to stress the matching engine's **unexpected-message path** that
//! triggered receives must interoperate with.
//!
//! The neighborhood is a seeded random graph (a ring backbone for
//! connectivity plus random chords targeting an average extra degree of
//! ~4), with an independently drawn payload size per *directed* edge —
//! no two neighbors exchange the same amount, unlike the grid
//! workloads. Each iteration every rank first advances a deliberately
//! skewed amount of host time (a per-(rank, iteration) ramp of several
//! µs plus seeded jitter, far larger than the wire latency), then runs
//! one [`crate::stx::CommPlan`] round: pack kernel → deferred sends +
//! **deferred receives** under the variant protocol. Because adjacent
//! ranks are skewed by more than a full kernel-plus-wire round trip,
//! every iteration some ranks' messages arrive *before* the receiver
//! has posted its receives — driving traffic through the
//! unexpected-message queue on every variant:
//!
//! * `baseline` — receives are late host `MPI_Irecv`s;
//! * `st`/`st-shader` — receives are progress-thread-emulated deferred
//!   receives released by the CP trigger (§IV-A2);
//! * `kt` — receives are **NIC triggered-receive descriptors**
//!   ([`crate::nic::post_triggered_recv`]): the unexpected interleaving
//!   resolves entirely inside the NIC/matching engine, no host in the
//!   loop.
//!
//! Validation is exact: the pack kernel writes `payload(rank, lane, j)
//! + iter`, so after the final iteration every receive slot must hold
//! its peer's value for the *last* iteration — a message matched to the
//! wrong receive, lost to the unexpected queue, or crossed between
//! iterations (pairwise FIFO violation) fails the check.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::run_cluster;
use crate::gpu::{stream_synchronize, KernelPayload, KernelSpec};
use crate::mpi::{SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::sim::rng::SplitMix64;
use crate::world::{BufId, ComputeMode, World};

use super::scaffold::{check_exact, lease_world, scenario_run, RankComm, Timers};
use super::{comm_variant, payload, ScenarioCfg, ScenarioRun, Workload};

pub struct HaloGraph;

/// Tag base; disjoint from halo3d (direction tags), allreduce
/// (1000/2000/3000), allgather (4000), and incast (900).
const HG_TAG: i32 = 5000;

/// Skew ramp quantum: adjacent ranks differ by at least one quantum per
/// iteration, which exceeds a pack-kernel-plus-wire round trip by a wide
/// margin — the guarantee that unexpected arrivals occur every
/// iteration.
const SKEW_QUANTUM: u64 = 8_000;

/// An undirected edge with one payload size per direction.
struct GraphEdge {
    u: usize,
    v: usize,
    /// f32 elems carried u -> v.
    elems_uv: usize,
    /// f32 elems carried v -> u.
    elems_vu: usize,
}

/// One directed message of a rank's schedule (both its slot in the
/// packed send buffer and the matching slot in the receive buffer).
struct NbrMsg {
    peer: usize,
    tag_send: i32,
    tag_recv: i32,
    /// The lane the *peer* packs with for what we receive.
    lane_recv: usize,
    send_off: usize,
    send_elems: usize,
    recv_off: usize,
    recv_elems: usize,
}

/// Per-rank buffers + schedule + the pack kernel's base image.
struct RankPlan {
    send: BufId,
    recv: BufId,
    total_send: usize,
    send_image: Vec<f32>,
    nbrs: Vec<NbrMsg>,
}

/// Seeded sparse graph: ring backbone (connectivity, min degree 2 for
/// n >= 3) plus random chords at a probability targeting ~4 extra
/// neighbors per rank, with an independent payload size per direction.
/// Deterministic in (n, max_elems, seed).
fn build_edges(n: usize, max_elems: usize, seed: u64) -> Vec<GraphEdge> {
    let mut rng = SplitMix64::new(seed ^ 0x6861_6c6f); // "halo"
    let size = |rng: &mut SplitMix64| 1 + rng.below(max_elems as u64) as usize;
    let mut edges = Vec::new();
    for u in 0..n - 1 {
        let (a, b) = (size(&mut rng), size(&mut rng));
        edges.push(GraphEdge { u, v: u + 1, elems_uv: a, elems_vu: b });
    }
    if n > 2 {
        let (a, b) = (size(&mut rng), size(&mut rng));
        edges.push(GraphEdge { u: 0, v: n - 1, elems_uv: a, elems_vu: b });
    }
    // Random chords: probability ~ 400/(n-1) percent per candidate pair
    // keeps the expected extra degree near 4 at any world size (the
    // floor of 1% only guards against rounding to a chord-free ring on
    // very large worlds — no 5%-style floor that would densify them).
    let p = (400 / (n - 1).max(1)).clamp(1, 100) as u64;
    for u in 0..n {
        for v in (u + 2)..n {
            if u == 0 && v == n - 1 {
                continue; // already the ring wrap edge
            }
            if rng.below(100) < p {
                let (a, b) = (size(&mut rng), size(&mut rng));
                edges.push(GraphEdge { u, v, elems_uv: a, elems_vu: b });
            }
        }
    }
    edges
}

/// The deliberate per-(iteration, rank) arrival skew: a ramp that
/// guarantees adjacent ranks differ by at least [`SKEW_QUANTUM`], plus
/// seeded jitter small enough never to cancel the ramp.
fn build_skews(n: usize, iters: usize, rng: &mut SplitMix64) -> Vec<Vec<u64>> {
    (0..iters)
        .map(|it| {
            (0..n)
                .map(|r| {
                    let ramp = ((r * 7919 + it * 2531) % 8) as u64 * SKEW_QUANTUM;
                    ramp + rng.below(2_000)
                })
                .collect()
        })
        .collect()
}

fn build_plans(w: &mut World, n: usize, edges: &[GraphEdge]) -> Vec<RankPlan> {
    // Directed-edge index doubles as the payload lane, so each direction
    // carries a distinct, validator-known pattern. Per rank: (schedule,
    // pack image, send elems, recv elems).
    let mut plans: Vec<_> = (0..n)
        .map(|_| (Vec::<NbrMsg>::new(), Vec::<f32>::new(), 0usize, 0usize))
        .collect();
    for (i, e) in edges.iter().enumerate() {
        let (lane_uv, lane_vu) = (2 * i, 2 * i + 1);
        let (tag_uv, tag_vu) = (HG_TAG + lane_uv as i32, HG_TAG + lane_vu as i32);
        // u's view: sends u->v, receives v->u.
        {
            let (nbrs, image, soff, roff) = &mut plans[e.u];
            for j in 0..e.elems_uv {
                image.push(payload(e.u, lane_uv, j));
            }
            nbrs.push(NbrMsg {
                peer: e.v,
                tag_send: tag_uv,
                tag_recv: tag_vu,
                lane_recv: lane_vu,
                send_off: *soff,
                send_elems: e.elems_uv,
                recv_off: *roff,
                recv_elems: e.elems_vu,
            });
            *soff += e.elems_uv;
            *roff += e.elems_vu;
        }
        // v's view: sends v->u, receives u->v.
        {
            let (nbrs, image, soff, roff) = &mut plans[e.v];
            for j in 0..e.elems_vu {
                image.push(payload(e.v, lane_vu, j));
            }
            nbrs.push(NbrMsg {
                peer: e.u,
                tag_send: tag_vu,
                tag_recv: tag_uv,
                lane_recv: lane_uv,
                send_off: *soff,
                send_elems: e.elems_vu,
                recv_off: *roff,
                recv_elems: e.elems_uv,
            });
            *soff += e.elems_vu;
            *roff += e.elems_uv;
        }
    }
    plans
        .into_iter()
        .map(|(nbrs, send_image, total_send, total_recv)| {
            let send = w.bufs.alloc(total_send);
            let recv = w.bufs.alloc(total_recv);
            RankPlan { send, recv, total_send, send_image, nbrs }
        })
        .collect()
}

impl Workload for HaloGraph {
    fn name(&self) -> &'static str {
        "halograph"
    }

    fn description(&self) -> &'static str {
        "sparse random-graph halo exchange, skewed arrivals stressing the unexpected path"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "st", "st-shader", "kt", "gi"]
    }

    fn default_elems(&self) -> &'static [usize] {
        // Upper bound of the per-edge size draw (sizes are 1..=elems).
        &[16, 256, 4096]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        comm_variant("halograph", &cfg.variant)?;
        if cfg.world_size() < 2 {
            bail!("halograph needs at least two ranks");
        }
        if cfg.elems == 0 {
            bail!("halograph: edges must carry at least one element");
        }
        if cfg.queues_per_rank == 0 {
            bail!("halograph: at least one queue per rank");
        }
        // Multi-queue striping leans on the ring backbone's guaranteed
        // degree of 2; random chords are not guaranteed per seed.
        if cfg.queues_per_rank > 1 && (cfg.world_size() < 3 || cfg.queues_per_rank > 2) {
            bail!(
                "halograph: {} queues per rank exceed the guaranteed degree (2 on >= 3 ranks)",
                cfg.queues_per_rank
            );
        }
        if cfg.iters == 0 {
            bail!("halograph: the last-iteration reference needs at least one iteration");
        }
        // Exact f32 validation: payload (< 8192) + iter stays exactly
        // representable while iters is far below 2^24.
        if cfg.iters > 2048 {
            bail!("halograph: exact f32 validation bounds iters to 2048, got {}", cfg.iters);
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let variant = comm_variant("halograph", &cfg.variant)?;
        let n = cfg.world_size();
        let edges = build_edges(n, cfg.elems, cfg.seed);
        let mut skew_rng = SplitMix64::new(cfg.seed ^ 0x736b_6577); // "skew"
        let skews = Arc::new(build_skews(n, cfg.iters, &mut skew_rng));

        let mut world = lease_world("halograph", cfg);
        world.compute = ComputeMode::Real;
        let plans = Arc::new(build_plans(&mut world, n, &edges));
        let times = Timers::new(n);

        let (iters, qpr) = (cfg.iters, cfg.queues_per_rank);
        let (plans2, skews2, times2) = (plans.clone(), skews.clone(), times.clone());
        let out = run_cluster(world, cfg.seed, move |rank, ctx| {
            let plan = &plans2[rank];
            let comm = RankComm::new(ctx, rank, variant, qpr);
            // Build-once: the whole irregular neighborhood is one plan;
            // receives are *deferred* on every variant (host rounds fall
            // back to late irecvs; KT rounds arm NIC triggered-receive
            // descriptors).
            let mut b = comm.builder();
            for m in &plan.nbrs {
                b.send(
                    m.peer,
                    BufSlice::new(plan.send, m.send_off, m.send_elems),
                    m.tag_send,
                    COMM_WORLD,
                );
                b.recv_deferred(
                    SrcSel::Rank(m.peer),
                    TagSel::Tag(m.tag_recv),
                    COMM_WORLD,
                    BufSlice::new(plan.recv, m.recv_off, m.recv_elems),
                )
                .expect("concrete selectors");
            }
            let cplan = b.build(ctx).expect("halograph plan build");

            let t0 = ctx.now();
            for iter in 0..iters {
                // The skewed arrival order: ranks enter the round far
                // apart, so fast neighbors' messages beat this rank's
                // receive posts into the matching engine.
                ctx.advance(skews2[iter][rank]);
                let (send, total, plans_k) = (plan.send, plan.total_send, plans2.clone());
                let pack = KernelSpec {
                    name: "halograph_pack".into(),
                    flops: 0,
                    bytes: 2 * 4 * total as u64,
                    payload: KernelPayload::Fn(Box::new(move |w, _| {
                        let img = &plans_k[rank].send_image;
                        let b = w.bufs.get_mut(send);
                        for (dst, &x) in b[..total].iter_mut().zip(img) {
                            *dst = x + iter as f32;
                        }
                    })),
                };
                let round = cplan.round(ctx, vec![pack]).expect("halograph round");
                cplan.complete(ctx, round).expect("halograph complete");
            }
            // Drain inside the timed region, like every workload: KT's
            // outstanding completions, then the stream (covers ST's
            // final waitValue64 and the last pack kernel).
            comm.drain_if_kt(ctx, &cplan, "halograph");
            stream_synchronize(ctx, comm.sid);
            times2.record(rank, ctx.now() - t0);
            comm.finish(ctx, "halograph");
        })
        .context("halograph run failed")?;

        // Reference: every receive slot holds the peer's last-iteration
        // packed value for that directed edge.
        let last = (cfg.iters - 1) as f32;
        let pairs = plans.iter().flat_map(|plan| {
            let recv = out.world.bufs.get(plan.recv);
            plan.nbrs.iter().flat_map(move |m| {
                (0..m.recv_elems).map(move |j| {
                    (recv[m.recv_off + j], payload(m.peer, m.lane_recv, j) + last)
                })
            })
        });
        let validation = check_exact(pairs, |i| format!("halograph recv slot {i}"));
        Ok(scenario_run("halograph", cfg, out, &times, validation))
    }
}
