//! Shared per-workload run scaffold: the pieces every scenario plug-in
//! used to hand-roll — per-rank stream/queue setup (including
//! multi-queue ranks), the per-rank timer vector with the max-over-ranks
//! figure of merit, the exact-compare validation loop, and the
//! [`ScenarioRun`] assembly — folded into helpers so a plug-in shrinks
//! to *pattern + compute* (see `allgather.rs` for the ~100-line shape).
//!
//! The communication protocol itself (the per-variant send block the
//! ROADMAP flagged as four-way duplication) lives one layer down, in
//! [`CommPlan::round`] / [`CommPlan::complete`]: workloads record their
//! pattern once through [`RankComm::builder`] and re-arm it every
//! iteration with zero per-iteration enqueue calls.

use std::cell::Cell;
use std::sync::{Arc, Mutex};

use crate::coordinator::{self, RunOutcome};
use crate::fault::{fingerprint, FaultPlan, FaultState};
use crate::gpu::{self, StreamId};
use crate::sim::HostCtx;
use crate::stx::{CommPlan, CommPlanBuilder, Queue, Variant};
use crate::world::{Topology, World};

use super::{QueueSlotStats, ScenarioCfg, ScenarioRun, Validation};

/// Install the cell's fault plan (if any) into a freshly built world:
/// the per-cell decision stream is keyed by the fingerprint of
/// [`ScenarioCfg::fault_label`], so the same cell replays its chaos
/// byte-identically on every rerun and at any sweep thread count. A
/// `None` spec leaves the world untouched (fully inert fault layer).
pub fn install_faults(world: &mut World, workload: &str, cfg: &ScenarioCfg) {
    if let Some(spec) = &cfg.faults {
        let fp = fingerprint(spec.seed, &cfg.fault_label(workload));
        world.fault = Some(FaultState::new(FaultPlan::new(spec.clone(), fp, cfg.world_size())));
    }
}

/// World-reuse key for a cell: everything that shapes the *structure* of
/// the world — workload, variant, topology, queue count, and the full
/// cost model (which carries the DWQ slot depth and jitter knobs).
/// Payload size, seed, iteration count, and fault spec are deliberately
/// excluded: they only shape per-run state, which [`World::reset`]
/// rewinds (faults are re-installed per lease by [`lease_world`]).
pub fn reuse_key(workload: &str, cfg: &ScenarioCfg) -> String {
    format!(
        "{workload}/{}/{}x{}/q{}/{:?}",
        cfg.variant, cfg.nodes, cfg.ranks_per_node, cfg.queues_per_rank, cfg.cost
    )
}

/// Lease a world for this cell from the per-thread pool (see
/// [`coordinator::lease_world`]) and install the cell's fault plan. On a
/// pool miss this is exactly the old cold-build path; on a hit, the
/// pooled world is rewound and behaves byte-identically. Pair with
/// [`scenario_run`], which stashes the world back after a clean run.
pub fn lease_world(workload: &str, cfg: &ScenarioCfg) -> World {
    let topo = Topology::new(cfg.nodes, cfg.ranks_per_node);
    let mut world = coordinator::lease_world(&reuse_key(workload, cfg), cfg.cost.clone(), topo);
    install_faults(&mut world, workload, cfg);
    world
}

/// One rank's communication context: its GPU stream plus the queue set
/// its plans stripe over (`queues_per_rank` queues for queue-using
/// variants, none for the host baseline).
pub struct RankComm {
    /// The communication variant this rank runs.
    pub variant: Variant,
    /// The rank's GPU stream.
    pub sid: StreamId,
    rank: usize,
    queues: Vec<Queue>,
    /// Plans built so far — rotates the striping start slot so a
    /// sequence of small plans (one per collective step) spreads over
    /// the queue set instead of all landing on queue 0.
    plans_built: Cell<usize>,
}

impl RankComm {
    /// Create the stream and `queues_per_rank` queues for `rank`
    /// (outside the timed region, like every workload did by hand).
    pub fn new(
        ctx: &mut HostCtx<World>,
        rank: usize,
        variant: Variant,
        queues_per_rank: usize,
    ) -> RankComm {
        let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
        let queues = if variant.uses_queue() {
            (0..queues_per_rank.max(1))
                .map(|_| {
                    Queue::create(ctx, rank, sid, variant).expect("NIC counter pool exhausted")
                })
                .collect()
        } else {
            Vec::new()
        };
        RankComm { variant, sid, rank, queues, plans_built: Cell::new(0) }
    }

    /// Start recording this rank's [`CommPlan`] (ops stripe round-robin
    /// over the rank's queues; successive plans start at successive
    /// slots).
    pub fn builder(&self) -> CommPlanBuilder {
        let mut b = CommPlan::builder(self.rank, self.sid, self.variant, &self.queues);
        if !self.queues.is_empty() {
            b.stripe_from(self.plans_built.get());
        }
        self.plans_built.set(self.plans_built.get() + 1);
        b
    }

    /// KT/GI epilogue inside the timed region: drain the plan's
    /// outstanding send completions (ST already waited via its stream
    /// waits), so the variants' figures of merit compare like for like.
    pub fn drain_if_kt(&self, ctx: &mut HostCtx<World>, plan: &CommPlan, what: &str) {
        if matches!(self.variant, Variant::KernelTriggered | Variant::GpuInitiated) {
            plan.drain(ctx)
                .unwrap_or_else(|e| panic!("{what}: {} queue drain: {e}", self.variant.name()));
        }
    }

    /// Teardown: free every queue (they must be idle — `what` names the
    /// workload in the violation message).
    pub fn finish(self, ctx: &mut HostCtx<World>, what: &str) {
        for q in self.queues {
            q.free(ctx)
                .unwrap_or_else(|(_, e)| panic!("{what}: queue not idle at teardown: {e}"));
        }
    }
}

/// Per-rank timed-region accumulator shared across the host actors; the
/// figure of merit is the max over ranks ([`Timers::max_ns`]).
#[derive(Clone)]
pub struct Timers(Arc<Mutex<Vec<u64>>>);

impl Timers {
    /// One slot per rank, all zero.
    pub fn new(ranks: usize) -> Timers {
        Timers(Arc::new(Mutex::new(vec![0; ranks])))
    }

    /// Record `rank`'s accumulated timed-region nanoseconds.
    pub fn record(&self, rank: usize, dt: u64) {
        self.0.lock().unwrap()[rank] = dt;
    }

    /// The max-over-ranks figure of merit.
    pub fn max_ns(&self) -> u64 {
        self.0.lock().unwrap().iter().copied().max().unwrap_or(0)
    }
}

/// Exact-compare validation loop: every `(got, expected)` pair must match
/// bit-for-bit (workload payloads are small integers, exactly
/// representable in f32). `label(i)` names pair `i` in the failure
/// detail — only evaluated on mismatch.
pub fn check_exact(
    pairs: impl IntoIterator<Item = (f32, f32)>,
    label: impl Fn(usize) -> String,
) -> Validation {
    let mut checked = 0;
    for (i, (got, expect)) in pairs.into_iter().enumerate() {
        if got != expect {
            return Validation::Failed { detail: format!("{}: {got} != {expect}", label(i)) };
        }
        checked += 1;
    }
    Validation::Passed { checked }
}

/// Aggregate the run's per-queue counters by *within-rank* slot: the
/// s-th queue each rank created contributes to slot `s`. Queues appear
/// in `World::queues` in (deterministic) creation order, so the
/// grouping is stable across reruns and sweep thread counts.
pub fn per_queue_stats(world: &World) -> Vec<QueueSlotStats> {
    let mut next_slot = vec![0usize; world.procs.len()];
    let mut rows: Vec<QueueSlotStats> = Vec::new();
    for q in &world.queues {
        let slot = next_slot[q.rank];
        next_slot[q.rank] += 1;
        if rows.len() <= slot {
            rows.push(QueueSlotStats { slot, dwq_posts: 0, dwq_slot_waits: 0 });
        }
        rows[slot].dwq_posts += q.dwq_posts;
        rows[slot].dwq_slot_waits += q.dwq_slot_waits;
    }
    rows
}

/// Assemble the [`ScenarioRun`] summary every workload returns: the
/// max-over-ranks figure of merit plus the run's metrics, engine stats,
/// per-queue-slot DWQ counters, and — when the run recorded a trace —
/// the achieved-overlap and critical-path analytics. Consumes the
/// outcome: once the summary is assembled, the world goes back to the
/// per-thread pool under [`reuse_key`] so the next cell with the same
/// shape skips the cold build (error paths never reach here, so a
/// stalled world is dropped, not pooled).
pub fn scenario_run(
    workload: &str,
    cfg: &ScenarioCfg,
    mut out: RunOutcome,
    times: &Timers,
    validation: Validation,
) -> ScenarioRun {
    let a = out.take_analytics();
    let run = ScenarioRun {
        time_ns: times.max_ns(),
        metrics: out.world.metrics.clone(),
        stats: out.stats.clone(),
        validation,
        per_queue: per_queue_stats(&out.world),
        overlap: a.overlap,
        crit: a.crit,
        trace: a.trace,
    };
    coordinator::stash_world(&reuse_key(workload, cfg), out.world);
    run
}
