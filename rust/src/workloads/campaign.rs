//! Campaign driver: run a cross-product of {workload × variant × message
//! size × topology × queues-per-rank × seed} on the parallel sweep
//! executor and emit one comparative report as JSON + Markdown.
//!
//! Determinism contract: cells are enumerated in a fixed order (workload
//! registry order → variant order → size order → topology order →
//! queue-count order), every
//! job draws randomness only from its own `(cell, seed)` config, and the
//! sweep executor writes results by job index — so the rendered report
//! is byte-identical across reruns at any `STMPI_SWEEP_THREADS`
//! (pinned by `rust/tests/determinism.rs`).
//!
//! Infeasible cells (a workload's `configure` rejects the grid point,
//! e.g. recursive doubling on a non-power-of-two world) are reported as
//! `skipped` rows instead of failing the campaign. Cells whose runs
//! *stall* — the engine's stall detector fired, e.g. under injected
//! faults the watchdog could not recover from, or the pinned KT
//! tight-DWQ stress cell — are reported as `stalled` rows carrying the
//! full [`crate::sim::StallReport`], again instead of aborting the
//! sweep (EXPERIMENTS.md §Chaos axis).
//!
//! Every ran cell also carries a baseline-relative delta (`vs ref` /
//! `delta_vs_ref_pct`): its figure of merit against the workload's
//! reference variant at the same size and topology, so ST and KT
//! speedups are readable directly from the report.
//!
//! With [`CampaignSpec::store`] set, the campaign is *incremental*: each
//! `(cell × seed)` job is fingerprinted ([`crate::store::CellKey`]) and
//! jobs already present in the campaign store are served from disk
//! instead of simulated. Cell assembly consumes only
//! [`crate::store::SeedRecord`]s — the same type whether a job ran cold
//! or came from the cache — so a warm rerun's report is byte-identical
//! to the cold one while simulating zero cells. Cache accounting lands
//! in [`CampaignReport::cache`] (and `STORE_stats.json`), deliberately
//! outside the rendered report bytes. [`diff_cost_models`] builds on
//! this to compare one grid under two cost models cell-by-cell.

use std::path::Path;

use anyhow::{anyhow, bail, Context as _, Result};

use crate::coordinator::report::{json_escape, markdown_table, pct_delta, Summary};
use crate::costmodel::presets;
use crate::fault::FaultSpec;
use crate::obs::{self, CritPath, TraceBuf};
use crate::sim::{sweep, SimError, StallReport};
use crate::store::{CacheStats, CellKey, SeedRecord, Store};
use crate::world::Topology;

use super::{registry, QueueSlotStats, ScenarioCfg, ScenarioRun, Workload};

/// What to run: empty vectors mean "use the defaults" (all workloads,
/// each workload's own variants and default sizes).
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Workload names from the registry; empty = all.
    pub workloads: Vec<String>,
    /// Variant-name filter applied to each workload; empty = all.
    pub variants: Vec<String>,
    /// Message sizes (f32 elems); empty = each workload's defaults.
    pub elems: Vec<usize>,
    /// (nodes, ranks_per_node) grid points.
    pub topos: Vec<(usize, usize)>,
    /// `stx::Queue`s per rank — the multi-queue contention axis.
    /// Workloads that drive exactly one queue report q>1 cells as
    /// skipped.
    pub queues: Vec<usize>,
    pub seeds: Vec<u64>,
    /// Timed iterations per run.
    pub iters: usize,
    /// Cost-model jitter sigma (timing only; validation is unaffected).
    pub jitter: f64,
    /// Override `cost.dwq_slots_per_nic` (None = the preset's ample
    /// default); dialing it down makes multi-queue DWQ contention
    /// visible in the `dwq waits` column.
    pub dwq_slots: Option<usize>,
    /// Sweep worker threads; None = `sweep::default_threads()`.
    pub threads: Option<usize>,
    /// Fault-injection plan applied to every cell (the chaos axis).
    /// `None` keeps the timeline bit-identical to fault-free releases;
    /// `Some` keys each cell's decision stream off
    /// [`ScenarioCfg::fault_label`], so chaos campaigns stay
    /// byte-identical across reruns and `STMPI_SWEEP_THREADS`. Cells
    /// that stall under injected faults are recorded as `stalled` rows
    /// carrying the [`crate::sim::StallReport`] instead of aborting the
    /// sweep.
    pub faults: Option<FaultSpec>,
    /// Chrome-trace export prefix: `Some(prefix)` renders each cell's
    /// first-seed event trace as
    /// `<prefix>_<workload>_<variant>_<elems>_<topo>_q<q>.json`
    /// (Perfetto-loadable; written by the CLI). `None` skips the export
    /// — the overlap/critical-path columns are computed either way
    /// (tracing itself is only off under `STMPI_TRACE=0`).
    pub trace: Option<String>,
    /// Campaign-store directory: `Some(dir)` makes the run incremental
    /// — jobs whose [`crate::store::CellKey`] fingerprint is already in
    /// the store are served from disk, fresh results are upserted. A
    /// trace export ([`CampaignSpec::trace`]) disables store *reads*
    /// for the run (cached records carry no event buffers to render)
    /// but results are still written. `None` = every job simulates.
    pub store: Option<String>,
    /// Cost-model field overrides (`(field, value)` pairs applied via
    /// [`crate::costmodel::CostModel::apply_override`] after jitter and
    /// DWQ handling) — the cost-model diff axis. Overrides change the
    /// effective model's stable hash, so a store-backed run under an
    /// override re-simulates every cell instead of aliasing cached
    /// baseline results.
    pub cost_overrides: Vec<(String, f64)>,
}

impl Default for CampaignSpec {
    fn default() -> Self {
        Self {
            workloads: Vec::new(),
            variants: Vec::new(),
            elems: Vec::new(),
            topos: vec![(2, 1), (4, 1)],
            queues: vec![1],
            seeds: vec![11, 23],
            iters: 3,
            jitter: 0.01,
            dwq_slots: None,
            threads: None,
            faults: None,
            trace: None,
            store: None,
            cost_overrides: Vec::new(),
        }
    }
}

impl CampaignSpec {
    /// Tiny smoke campaign (2 workloads × 4 variants each — host, ST,
    /// KT, and GI — × 1 size × 1 topo): fast enough for CI and the
    /// `campaign` example's assertions.
    pub fn smoke() -> Self {
        Self {
            workloads: vec!["halo3d".into(), "allreduce".into()],
            variants: vec![
                "baseline".into(),
                "st".into(),
                "kt".into(),
                "gi".into(),
                "ring-st".into(),
                "ring-kt".into(),
                "ring-gi".into(),
            ],
            elems: vec![48],
            topos: vec![(2, 1)],
            queues: vec![1],
            seeds: vec![5, 9],
            iters: 2,
            jitter: 0.0,
            dwq_slots: None,
            threads: None,
            faults: None,
            trace: None,
            store: None,
            cost_overrides: Vec::new(),
        }
    }

    /// The smoke campaign under the full chaos preset ({drop, dup,
    /// delay, trigger-delay, straggler} at once) — the CI chaos leg
    /// (`STMPI_FAULTS=1`). Every cell must either exact-validate
    /// (recovered via watchdog retransmit) or render as a `stalled` row;
    /// the report stays byte-identical across reruns and thread counts.
    pub fn chaos_smoke(seed: u64) -> Self {
        Self { faults: Some(FaultSpec::chaos(seed)), ..Self::smoke() }
    }

    /// KT tight-DWQ stress cell: a kernel-triggered run whose pre-armed
    /// descriptor demand exceeds `dwq_slots_per_nic`, pinned by tests to
    /// fail fast with a [`crate::sim::StallReport`] naming the exhausted
    /// slot pool (`stx DWQ slot on nic...`) rather than hanging. See
    /// DESIGN.md §Fault model & stall diagnosis for the backpressure
    /// contract.
    pub fn kt_tight_dwq() -> Self {
        Self {
            workloads: vec!["alltoall".into()],
            variants: vec!["kt".into()],
            elems: vec![48],
            topos: vec![(4, 1)],
            queues: vec![1],
            seeds: vec![5],
            iters: 2,
            jitter: 0.0,
            dwq_slots: Some(1),
            threads: None,
            faults: None,
            trace: None,
            store: None,
            cost_overrides: Vec::new(),
        }
    }
}

/// One rendered grid cell of the campaign report.
#[derive(Debug, Clone)]
pub struct CampaignCell {
    pub workload: String,
    pub variant: String,
    pub elems: usize,
    pub nodes: usize,
    pub ranks_per_node: usize,
    /// `stx::Queue`s per rank this cell ran with (multi-queue axis).
    pub queues_per_rank: usize,
    /// avg/min/max over seeds in virtual ms; None when the cell was
    /// skipped as infeasible.
    pub summary: Option<Summary>,
    /// Figure-of-merit delta vs the workload's *reference* variant
    /// (`variants()[0]`) at the same size and topology, in percent
    /// (positive = slower than the reference). None for the reference
    /// cell itself, for skipped cells, and when the reference cell is
    /// missing from the grid.
    pub delta_vs_ref_pct: Option<f64>,
    /// Validation label ("passed(n)" / "not-checked" / "FAILED: ..." /
    /// "skipped: ...").
    pub validation: String,
    pub ok: bool,
    /// Wire metrics of the first seed's run (deterministic).
    pub bytes_wire: u64,
    pub wire_msgs: u64,
    pub max_ingress_wait_ns: u64,
    pub max_egress_wait_ns: u64,
    /// DWQ-slot stalls of the first seed's run (multi-queue contention;
    /// see `Metrics::dwq_slot_waits`).
    pub dwq_slot_waits: u64,
    /// Peak concurrent DWQ occupancy of the first seed's run (HTQ
    /// pressure high-water mark).
    pub dwq_peak: u64,
    /// GPU-initiated command-ring descriptors the NIC consumed (first
    /// seed's run; see `Metrics::gi_posts`). Zero for every non-GI
    /// variant.
    pub gi_posts: u64,
    /// Kernel tails that stalled on a full per-launch command ring
    /// (first seed's run; see `Metrics::gi_ring_full_waits`).
    pub gi_ring_full_waits: u64,
    /// The aggregated `dwq waits`/`dwq posts` split per within-rank
    /// queue slot (first seed's run; empty when the run created no
    /// queues or the workload cannot observe them).
    pub per_queue: Vec<QueueSlotStats>,
    /// Messages that arrived before a matching receive was posted
    /// (first seed's run) — the matching engine's unexpected-path
    /// pressure the `halograph` workload is built to drive.
    pub unexpected_msgs: u64,
    /// Engine events of the first seed's run.
    pub events: u64,
    /// Wire faults injected (first completed seed's run; the chaos axis).
    pub faults_injected: u64,
    /// Watchdog retransmits of dropped payloads (first completed seed).
    pub retries: u64,
    /// Watchdogs that exhausted their retry budget (first completed
    /// seed).
    pub timeouts: u64,
    /// Seeds of this cell that ended in a [`crate::sim::StallReport`]
    /// instead of completing (recorded as a `stalled` row, not a sweep
    /// abort).
    pub stalls: u64,
    /// Full stall diagnosis of the first stalled seed (park sites,
    /// waiter counters, armed descriptors, unmatched receives).
    pub stall_report: Option<String>,
    /// Achieved communication/computation overlap of the first seed's
    /// run, in percent (wire-egress occupancy hidden behind source-node
    /// kernels ÷ total; see [`crate::obs::achieved_overlap`]). `None`
    /// when tracing was off (`STMPI_TRACE=0`), the cell was skipped, or
    /// the run moved nothing over the wire.
    pub overlap_pct: Option<f64>,
    /// Critical-path attribution of the first seed's run
    /// (last-finishing rank; see [`crate::obs::critical_path`]).
    pub crit: Option<CritPath>,
    /// Rendered Chrome-trace JSON of the first seed's run, present only
    /// when [`CampaignSpec::trace`] requested an export (the CLI writes
    /// it to disk; not embedded in the report JSON).
    pub trace_json: Option<String>,
}

impl CampaignCell {
    fn topo_label(&self) -> String {
        Topology::new(self.nodes, self.ranks_per_node).label()
    }
}

/// The assembled campaign report.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub seeds: Vec<u64>,
    pub iters: usize,
    pub cells: Vec<CampaignCell>,
    /// Cache accounting of this run (zero unless [`CampaignSpec::store`]
    /// was set). Deliberately excluded from [`CampaignReport::to_json`]
    /// and [`CampaignReport::to_markdown`]: the rendered report must be
    /// byte-identical whether its rows simulated or came from the store.
    /// The CLI writes it to `STORE_stats.json` instead.
    pub cache: CacheStats,
}

impl CampaignReport {
    /// True when no cell failed validation (skipped cells are ok).
    pub fn all_ok(&self) -> bool {
        self.cells.iter().all(|c| c.ok)
    }

    /// Cells that actually ran (not skipped).
    pub fn ran_cells(&self) -> usize {
        self.cells.iter().filter(|c| c.summary.is_some()).count()
    }

    /// Distinct workloads with at least one ran cell.
    pub fn workloads_covered(&self) -> usize {
        let mut names: Vec<&str> = self
            .cells
            .iter()
            .filter(|c| c.summary.is_some())
            .map(|c| c.workload.as_str())
            .collect();
        names.sort_unstable();
        names.dedup();
        names.len()
    }

    /// Deterministic JSON rendering (schema in EXPERIMENTS.md).
    pub fn to_json(&self) -> String {
        let seeds =
            self.seeds.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(", ");
        let mut s = String::new();
        s.push_str("{\n  \"campaign\": {\n");
        s.push_str(&format!("    \"seeds\": [{seeds}],\n"));
        s.push_str(&format!("    \"iters\": {},\n", self.iters));
        s.push_str("    \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            s.push_str("      { ");
            s.push_str(&format!(
                "\"workload\": \"{}\", \"variant\": \"{}\", \"elems\": {}, \
                 \"nodes\": {}, \"ranks_per_node\": {}, \"queues_per_rank\": {}, ",
                json_escape(&c.workload),
                json_escape(&c.variant),
                c.elems,
                c.nodes,
                c.ranks_per_node,
                c.queues_per_rank
            ));
            // `stalled` outranks `ok`: any stalled seed marks the row.
            match (&c.summary, c.stalls) {
                (Some(sm), 0) => s.push_str(&format!(
                    "\"status\": \"ok\", \"avg_ms\": {:.6}, \"min_ms\": {:.6}, \
                     \"max_ms\": {:.6}, ",
                    sm.avg, sm.min, sm.max
                )),
                (Some(sm), _) => s.push_str(&format!(
                    "\"status\": \"stalled\", \"avg_ms\": {:.6}, \"min_ms\": {:.6}, \
                     \"max_ms\": {:.6}, ",
                    sm.avg, sm.min, sm.max
                )),
                (None, 0) => s.push_str("\"status\": \"skipped\", "),
                (None, _) => s.push_str("\"status\": \"stalled\", "),
            }
            match c.delta_vs_ref_pct {
                Some(d) => s.push_str(&format!("\"delta_vs_ref_pct\": {d:.3}, ")),
                None => s.push_str("\"delta_vs_ref_pct\": null, "),
            }
            match c.overlap_pct {
                Some(p) => s.push_str(&format!("\"overlap_pct\": {p:.3}, ")),
                None => s.push_str("\"overlap_pct\": null, "),
            }
            match &c.crit {
                Some(cp) => s.push_str(&format!("\"critical_path\": {}, ", cp.to_json())),
                None => s.push_str("\"critical_path\": null, "),
            }
            let dwq_queues = c
                .per_queue
                .iter()
                .map(|q| {
                    format!(
                        "{{\"slot\": {}, \"dwq_posts\": {}, \"dwq_slot_waits\": {}}}",
                        q.slot, q.dwq_posts, q.dwq_slot_waits
                    )
                })
                .collect::<Vec<_>>()
                .join(", ");
            s.push_str(&format!(
                "\"validation\": \"{}\", \"bytes_wire\": {}, \"wire_msgs\": {}, \
                 \"max_ingress_wait_ns\": {}, \"max_egress_wait_ns\": {}, \
                 \"dwq_slot_waits\": {}, \"dwq_peak\": {}, \"dwq_queues\": [{}], \
                 \"gi_posts\": {}, \"gi_ring_full_waits\": {}, \
                 \"unexpected_msgs\": {}, \"events\": {}, \
                 \"faults_injected\": {}, \"retries\": {}, \"timeouts\": {}, \
                 \"stalls\": {}, \"stall_report\": {} }}",
                json_escape(&c.validation),
                c.bytes_wire,
                c.wire_msgs,
                c.max_ingress_wait_ns,
                c.max_egress_wait_ns,
                c.dwq_slot_waits,
                c.dwq_peak,
                dwq_queues,
                c.gi_posts,
                c.gi_ring_full_waits,
                c.unexpected_msgs,
                c.events,
                c.faults_injected,
                c.retries,
                c.timeouts,
                c.stalls,
                match &c.stall_report {
                    Some(rep) => format!("\"{}\"", json_escape(rep)),
                    None => "null".to_string(),
                }
            ));
            s.push_str(if i + 1 == self.cells.len() { "\n" } else { ",\n" });
        }
        s.push_str("    ]\n  }\n}\n");
        s
    }

    /// Deterministic Markdown rendering.
    pub fn to_markdown(&self) -> String {
        let mut rows = vec![vec![
            "workload".to_string(),
            "variant".to_string(),
            "elems".to_string(),
            "topo".to_string(),
            "q".to_string(),
            "avg ms".to_string(),
            "min ms".to_string(),
            "max ms".to_string(),
            "vs ref".to_string(),
            "overlap %".to_string(),
            "crit path".to_string(),
            "validation".to_string(),
            "wire B".to_string(),
            "wire msgs".to_string(),
            "max ingress wait ns".to_string(),
            "max egress wait ns".to_string(),
            "dwq waits".to_string(),
            "dwq peak".to_string(),
            "dwq/q".to_string(),
            "gi posts".to_string(),
            "gi ring waits".to_string(),
            "unexp".to_string(),
            "faults".to_string(),
            "retries".to_string(),
            "timeouts".to_string(),
            "stalls".to_string(),
        ]];
        for c in &self.cells {
            let (avg, min, max) = match &c.summary {
                Some(sm) => (
                    format!("{:.3}", sm.avg),
                    format!("{:.3}", sm.min),
                    format!("{:.3}", sm.max),
                ),
                None => ("--".to_string(), "--".to_string(), "--".to_string()),
            };
            let vs_ref = match c.delta_vs_ref_pct {
                Some(d) => format!("{d:+.1}%"),
                None => "--".to_string(),
            };
            let overlap = match c.overlap_pct {
                Some(p) => format!("{p:.1}"),
                None => "--".to_string(),
            };
            let crit = match &c.crit {
                Some(cp) => cp.md_cell(),
                None => "--".to_string(),
            };
            // Per-queue split, slot-ordered: "posts:waits/posts:waits"
            // (slash-separated — a pipe would break the Markdown table).
            let dwq_q = if c.per_queue.is_empty() {
                "--".to_string()
            } else {
                c.per_queue
                    .iter()
                    .map(|q| format!("{}:{}", q.dwq_posts, q.dwq_slot_waits))
                    .collect::<Vec<_>>()
                    .join("/")
            };
            rows.push(vec![
                c.workload.clone(),
                c.variant.clone(),
                c.elems.to_string(),
                c.topo_label(),
                c.queues_per_rank.to_string(),
                avg,
                min,
                max,
                vs_ref,
                overlap,
                crit,
                c.validation.clone(),
                c.bytes_wire.to_string(),
                c.wire_msgs.to_string(),
                c.max_ingress_wait_ns.to_string(),
                c.max_egress_wait_ns.to_string(),
                c.dwq_slot_waits.to_string(),
                c.dwq_peak.to_string(),
                dwq_q,
                c.gi_posts.to_string(),
                c.gi_ring_full_waits.to_string(),
                c.unexpected_msgs.to_string(),
                c.faults_injected.to_string(),
                c.retries.to_string(),
                c.timeouts.to_string(),
                c.stalls.to_string(),
            ]);
        }
        format!(
            "# stmpi campaign report\n\n\
             {} workloads covered, {} cells ran ({} total), seeds {:?}, \
             {} iters/run, all_ok: {}\n\n{}",
            self.workloads_covered(),
            self.ran_cells(),
            self.cells.len(),
            self.seeds,
            self.iters,
            self.all_ok(),
            markdown_table(&rows)
        )
    }
}

/// Progress snapshot of one campaign run: reported once after the
/// cache partition (so `cached_jobs` is final immediately) and again
/// after every committed batch of simulations. `stmpi serve` streams
/// these to the client as JSON lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CampaignProgress {
    /// All feasible `(cell × seed)` jobs in the grid.
    pub total_jobs: usize,
    /// Jobs served from the campaign store.
    pub cached_jobs: usize,
    /// Jobs simulated and committed so far.
    pub simulated_jobs: usize,
    /// Jobs still waiting to simulate.
    pub pending_jobs: usize,
}

/// One planned grid cell (shared by the run loop and the record
/// converters below).
struct CellPlan<'a> {
    w: &'a dyn Workload,
    variant: String,
    elems: usize,
    nodes: usize,
    rpn: usize,
    qpr: usize,
    /// Why the cell was skipped (configure rejection), if it was.
    skip: Option<String>,
}

/// Convert one completed run into its persistent record — the *only*
/// path from a `ScenarioRun` to report-visible numbers, so cached and
/// fresh rows cannot diverge.
fn record_of(p: &CellPlan<'_>, seed: u64, r: &ScenarioRun) -> SeedRecord {
    SeedRecord {
        workload: p.w.name().to_string(),
        variant: p.variant.clone(),
        elems: p.elems,
        nodes: p.nodes,
        rpn: p.rpn,
        qpr: p.qpr,
        seed,
        stalled: false,
        time_ns: r.time_ns,
        validation_ok: r.validation.ok(),
        validation_label: r.validation.label(),
        bytes_wire: r.metrics.bytes_wire,
        wire_msgs: r.metrics.wire_msgs,
        max_ingress_wait_ns: r.metrics.max_ingress_wait_ns,
        max_egress_wait_ns: r.metrics.max_egress_wait_ns,
        dwq_slot_waits: r.metrics.dwq_slot_waits,
        dwq_peak: r.metrics.dwq_peak,
        gi_posts: r.metrics.gi_posts,
        gi_ring_full_waits: r.metrics.gi_ring_full_waits,
        unexpected_msgs: r.metrics.unexpected_msgs,
        events: r.stats.events,
        faults_injected: r.metrics.faults_injected,
        retries: r.metrics.retries,
        timeouts: r.metrics.timeouts,
        per_queue: r.per_queue.clone(),
        overlap: r.overlap,
        crit: r.crit,
        stall_headline: String::new(),
        stall_report: String::new(),
    }
}

/// Convert one stalled seed into its persistent record (stalls are data
/// — and they are cacheable data: a warm rerun serves the stall row
/// from the store too).
fn stall_record_of(p: &CellPlan<'_>, seed: u64, rep: &StallReport) -> SeedRecord {
    SeedRecord {
        workload: p.w.name().to_string(),
        variant: p.variant.clone(),
        elems: p.elems,
        nodes: p.nodes,
        rpn: p.rpn,
        qpr: p.qpr,
        seed,
        stalled: true,
        time_ns: 0,
        validation_ok: false,
        validation_label: String::new(),
        bytes_wire: 0,
        wire_msgs: 0,
        max_ingress_wait_ns: 0,
        max_egress_wait_ns: 0,
        dwq_slot_waits: 0,
        dwq_peak: 0,
        gi_posts: 0,
        gi_ring_full_waits: 0,
        unexpected_msgs: 0,
        events: 0,
        faults_injected: 0,
        retries: 0,
        timeouts: 0,
        per_queue: Vec::new(),
        overlap: None,
        crit: None,
        stall_headline: rep.headline(),
        stall_report: format!("{rep}"),
    }
}

/// Run a campaign: enumerate the grid, fan the (cell × seed) jobs out on
/// the sweep executor, aggregate per-cell summaries. With
/// [`CampaignSpec::store`] set the run is incremental (see the module
/// docs).
pub fn run_campaign(spec: &CampaignSpec) -> Result<CampaignReport> {
    run_campaign_observed(spec, &mut |_| {})
}

/// [`run_campaign`] with a progress callback (used by `stmpi serve` to
/// stream job counts while a submitted campaign runs).
pub fn run_campaign_observed(
    spec: &CampaignSpec,
    on_progress: &mut dyn FnMut(&CampaignProgress),
) -> Result<CampaignReport> {
    if spec.seeds.is_empty() {
        bail!("campaign needs at least one seed");
    }
    if spec.topos.is_empty() {
        bail!("campaign needs at least one topology");
    }
    if spec.iters == 0 {
        bail!("campaign needs at least one iteration");
    }
    if spec.queues.is_empty() {
        bail!("campaign needs at least one queues-per-rank grid point");
    }
    let catalogue = registry();
    let selected: Vec<&dyn Workload> = if spec.workloads.is_empty() {
        catalogue.iter().map(|w| w.as_ref()).collect()
    } else {
        spec.workloads
            .iter()
            .map(|name| {
                catalogue
                    .iter()
                    .find(|w| w.name() == name.as_str())
                    .map(|w| w.as_ref())
                    .ok_or_else(|| {
                        anyhow!("unknown workload '{name}' (known: {:?})", super::names())
                    })
            })
            .collect::<Result<Vec<_>>>()?
    };

    let mut cost = presets::frontier_like();
    cost.jitter_sigma = spec.jitter;
    if let Some(slots) = spec.dwq_slots {
        cost.dwq_slots_per_nic = slots;
    }
    for (field, value) in &spec.cost_overrides {
        cost.apply_override(field, *value)
            .with_context(|| format!("campaign cost override {field}={value}"))?;
    }

    let mut plans: Vec<CellPlan<'_>> = Vec::new();
    for w in &selected {
        let variants: Vec<&str> = w
            .variants()
            .iter()
            .copied()
            .filter(|v| spec.variants.is_empty() || spec.variants.iter().any(|f| f == v))
            .collect();
        if variants.is_empty() {
            // Make the exclusion visible in the report instead of
            // silently dropping the workload from the grid.
            plans.push(CellPlan {
                w: *w,
                variant: "(none)".to_string(),
                elems: 0,
                nodes: 0,
                rpn: 0,
                qpr: 0,
                skip: Some(format!(
                    "variant filter {:?} matches none of {:?}",
                    spec.variants,
                    w.variants()
                )),
            });
            continue;
        }
        let sizes: Vec<usize> =
            if spec.elems.is_empty() { w.default_elems().to_vec() } else { spec.elems.clone() };
        for variant in variants {
            for &elems in &sizes {
                for &(nodes, rpn) in &spec.topos {
                    for &qpr in &spec.queues {
                        let cfg = ScenarioCfg {
                            variant: variant.to_string(),
                            elems,
                            nodes,
                            ranks_per_node: rpn,
                            iters: spec.iters,
                            queues_per_rank: qpr,
                            seed: spec.seeds[0],
                            cost: cost.clone(),
                            faults: spec.faults.clone(),
                        };
                        let skip = w.configure(&cfg).err().map(|e| format!("{e}"));
                        plans.push(CellPlan {
                            w: *w,
                            variant: variant.to_string(),
                            elems,
                            nodes,
                            rpn,
                            qpr,
                            skip,
                        });
                    }
                }
            }
        }
    }

    if plans.is_empty() {
        bail!(
            "campaign planned zero cells: the variant filter {:?} matches no \
             variant of the selected workloads",
            spec.variants
        );
    }

    // Fan the feasible (cell × seed) grid out on the sweep executor.
    let mut jobs: Vec<(usize, u64)> = plans
        .iter()
        .enumerate()
        .filter(|(_, p)| p.skip.is_none())
        .flat_map(|(i, _)| spec.seeds.iter().map(move |&s| (i, s)))
        .collect();
    if jobs.is_empty() {
        let reason = plans.iter().find_map(|p| p.skip.clone()).unwrap_or_default();
        bail!("campaign: every planned cell was skipped as infeasible (e.g. {reason})");
    }
    // Group jobs by world-reuse key (workload, variant, topology, queue
    // count — see `scaffold::reuse_key`; payload size and seed share a
    // world) so sweep workers drive the snapshot-and-reset path instead
    // of cold-building a world per cell; a key change falls back to a
    // cold build. The sort is stable and keyed only on cell identity, so
    // seeds keep their spec order within a cell and the per-cell
    // regrouping below (keyed on the cell index riding with each job) is
    // byte-identical to the unsorted order.
    jobs.sort_by(|&(a, _), &(b, _)| {
        let (pa, pb) = (&plans[a], &plans[b]);
        (pa.w.name(), &pa.variant, pa.nodes, pa.rpn, pa.qpr, a)
            .cmp(&(pb.w.name(), &pb.variant, pb.nodes, pb.rpn, pb.qpr, b))
    });
    let threads = spec.threads.unwrap_or_else(sweep::default_threads);

    // Content-address every job. The effective cost model (jitter, DWQ
    // and diff overrides already folded in) is hashed once; the fault
    // spec likewise.
    let cost_hash = cost.stable_hash();
    let fault_hash = spec.faults.as_ref().map(|f| f.stable_hash());
    let trace_on = obs::recording_enabled();
    let fp = |i: usize, seed: u64| -> u64 {
        let p = &plans[i];
        CellKey {
            workload: p.w.name(),
            variant: &p.variant,
            elems: p.elems,
            nodes: p.nodes,
            rpn: p.rpn,
            queues: p.qpr,
            dwq_slots: spec.dwq_slots,
            iters: spec.iters,
            seed,
            cost_hash,
            fault_hash,
            trace_on,
        }
        .fingerprint()
    };
    let mut store = match &spec.store {
        Some(dir) => Some(Store::open(Path::new(dir))?),
        None => None,
    };
    // A trace export needs live event buffers, which the store does not
    // persist — so an export run reads nothing from the store (every
    // job simulates) but still commits its results for later reruns.
    let read_from_store = store.is_some() && spec.trace.is_none();

    // Partition jobs into cache hits (records served from the store)
    // and misses (still to simulate). `records` is job-indexed; cell
    // assembly below consumes only this vector, so it cannot tell a
    // cached record from a fresh one.
    let mut records: Vec<Option<SeedRecord>> = vec![None; jobs.len()];
    let mut traces: Vec<Option<TraceBuf>> = vec![None; jobs.len()];
    let mut cache = CacheStats::default();
    let mut to_sim: Vec<usize> = Vec::new();
    for (j, &(i, seed)) in jobs.iter().enumerate() {
        if read_from_store {
            if let Some(rec) = store.as_ref().and_then(|s| s.get(fp(i, seed))) {
                cache.hits += 1;
                cache.simulated_ns_saved += rec.time_ns;
                records[j] = Some(rec.clone());
                continue;
            }
        }
        cache.misses += 1;
        to_sim.push(j);
    }
    let mut progress = CampaignProgress {
        total_jobs: jobs.len(),
        cached_jobs: cache.hits as usize,
        simulated_jobs: 0,
        pending_jobs: to_sim.len(),
    };
    on_progress(&progress);

    // Simulate the misses on the sweep executor. Store-backed runs go
    // in batches so results commit (and progress streams)
    // incrementally; the plain path keeps the single fan-out. Batch
    // boundaries cannot change bytes: every job is an independent
    // deterministic function of its config, placed by job index. A seed
    // that stalls — the engine's stall detector fired — becomes data (a
    // `stalled` row carrying the report) instead of aborting the whole
    // sweep; any other failure still propagates.
    let batch = if store.is_some() { 512 } else { to_sim.len().max(1) };
    for chunk in to_sim.chunks(batch) {
        let chunk_jobs: Vec<(usize, u64)> = chunk.iter().map(|&j| jobs[j]).collect();
        let results: Vec<Result<ScenarioRun>> =
            sweep::map(&chunk_jobs, threads, |_, &(i, seed)| {
                let p = &plans[i];
                let cfg = ScenarioCfg {
                    variant: p.variant.clone(),
                    elems: p.elems,
                    nodes: p.nodes,
                    ranks_per_node: p.rpn,
                    iters: spec.iters,
                    queues_per_rank: p.qpr,
                    seed,
                    cost: cost.clone(),
                    faults: spec.faults.clone(),
                };
                p.w.run(&cfg).map(|mut r| {
                    // Keep the raw event buffer only where the export
                    // needs it (first seed of each cell, export
                    // requested) so the sweep never holds every cell's
                    // trace at once; the derived overlap/crit fields
                    // are already computed and stay.
                    if spec.trace.is_none() || seed != spec.seeds[0] {
                        r.trace = None;
                    }
                    r
                })
            });
        for (&j, res) in chunk.iter().zip(results) {
            let (i, seed) = jobs[j];
            let p = &plans[i];
            let rec = match res {
                Ok(mut run) => {
                    traces[j] = run.trace.take();
                    record_of(p, seed, &run)
                }
                Err(e) => {
                    // `.context(...)` in the workloads preserves the
                    // SimError payload for exactly this downcast.
                    if let Some(SimError::Stall { report }) = e.downcast_ref::<SimError>() {
                        stall_record_of(p, seed, report)
                    } else {
                        return Err(anyhow!(
                            "campaign cell {}/{} elems={} {}x{} seed={seed} failed: {e}",
                            p.w.name(),
                            p.variant,
                            p.elems,
                            p.nodes,
                            p.rpn
                        ));
                    }
                }
            };
            if let Some(st) = store.as_mut() {
                st.upsert(fp(i, seed), &rec)?;
            }
            records[j] = Some(rec);
        }
        if let Some(st) = store.as_mut() {
            st.flush()?;
        }
        progress.simulated_jobs += chunk.len();
        progress.pending_jobs -= chunk.len();
        on_progress(&progress);
    }

    // Group the job-indexed records back per cell (job order is
    // cell-major with seeds in spec order).
    let mut by_cell: Vec<Vec<usize>> = plans.iter().map(|_| Vec::new()).collect();
    for (j, &(i, _)) in jobs.iter().enumerate() {
        by_cell[i].push(j);
    }

    let mut cells = Vec::with_capacity(plans.len());
    for (i, p) in plans.iter().enumerate() {
        if let Some(reason) = &p.skip {
            cells.push(CampaignCell {
                workload: p.w.name().to_string(),
                variant: p.variant.clone(),
                elems: p.elems,
                nodes: p.nodes,
                ranks_per_node: p.rpn,
                queues_per_rank: p.qpr,
                summary: None,
                delta_vs_ref_pct: None,
                validation: format!("skipped: {reason}"),
                ok: true,
                bytes_wire: 0,
                wire_msgs: 0,
                max_ingress_wait_ns: 0,
                max_egress_wait_ns: 0,
                dwq_slot_waits: 0,
                dwq_peak: 0,
                gi_posts: 0,
                gi_ring_full_waits: 0,
                per_queue: Vec::new(),
                unexpected_msgs: 0,
                events: 0,
                faults_injected: 0,
                retries: 0,
                timeouts: 0,
                stalls: 0,
                stall_report: None,
                overlap_pct: None,
                crit: None,
                trace_json: None,
            });
            continue;
        }
        let recs: Vec<&SeedRecord> =
            by_cell[i].iter().filter_map(|&j| records[j].as_ref()).collect();
        let ran: Vec<&SeedRecord> = recs.iter().copied().filter(|r| !r.stalled).collect();
        let stalled: Vec<&SeedRecord> = recs.iter().copied().filter(|r| r.stalled).collect();
        let ms: Vec<f64> = ran.iter().map(|r| r.time_ns as f64 / 1e6).collect();
        // A stalled seed dominates the cell's verdict: the row renders
        // as `STALLED: <headline>` even when other seeds completed.
        let validation = if let Some(rep) = stalled.first() {
            format!("STALLED: {}", rep.stall_headline)
        } else {
            // The last failing seed's label wins, matching the
            // pre-store assembly (`Validation::ok()` is false exactly
            // for `Failed`).
            let mut v = ran[0].validation_label.clone();
            for r in &ran {
                if !r.validation_ok {
                    v = r.validation_label.clone();
                }
            }
            v
        };
        let ok = stalled.is_empty() && ran.iter().all(|r| r.validation_ok);
        let first: Option<&SeedRecord> = ran.first().copied();
        let m = |f: fn(&SeedRecord) -> u64| first.map(f).unwrap_or(0);
        // The export trace of the first completed seed, if the sweep
        // kept one (store hits never carry traces; export runs bypass
        // store reads precisely so this buffer exists).
        let trace_json = by_cell[i]
            .iter()
            .find(|&&j| records[j].as_ref().is_some_and(|r| !r.stalled))
            .and_then(|&j| traces[j].as_ref())
            .map(|tb| {
                let mut tb = tb.clone();
                tb.meta.label = format!(
                    "{}/{}/{}/{}x{}/q{}",
                    p.w.name(),
                    p.variant,
                    p.elems,
                    p.nodes,
                    p.rpn,
                    p.qpr
                );
                obs::chrome_trace(&tb)
            });
        cells.push(CampaignCell {
            workload: p.w.name().to_string(),
            variant: p.variant.clone(),
            elems: p.elems,
            nodes: p.nodes,
            ranks_per_node: p.rpn,
            queues_per_rank: p.qpr,
            summary: if ms.is_empty() { None } else { Some(Summary::of(&ms)) },
            delta_vs_ref_pct: None,
            validation,
            ok,
            bytes_wire: m(|r| r.bytes_wire),
            wire_msgs: m(|r| r.wire_msgs),
            max_ingress_wait_ns: m(|r| r.max_ingress_wait_ns),
            max_egress_wait_ns: m(|r| r.max_egress_wait_ns),
            dwq_slot_waits: m(|r| r.dwq_slot_waits),
            dwq_peak: m(|r| r.dwq_peak),
            gi_posts: m(|r| r.gi_posts),
            gi_ring_full_waits: m(|r| r.gi_ring_full_waits),
            per_queue: first.map(|r| r.per_queue.clone()).unwrap_or_default(),
            unexpected_msgs: m(|r| r.unexpected_msgs),
            events: m(|r| r.events),
            faults_injected: m(|r| r.faults_injected),
            retries: m(|r| r.retries),
            timeouts: m(|r| r.timeouts),
            stalls: stalled.len() as u64,
            stall_report: stalled.first().map(|r| r.stall_report.clone()),
            overlap_pct: first.and_then(|r| r.overlap.map(|o| o.pct())),
            crit: first.and_then(|r| r.crit),
            trace_json,
        });
    }

    // Baseline-relative delta column (the figure harness's "vs baseline"
    // generalized, per the ROADMAP): every ran cell vs its workload's
    // reference variant — variants()[0] — at the same size and topology.
    // The reference cell itself, and cells whose reference is missing or
    // skipped, carry no delta.
    let mut deltas: Vec<Option<f64>> = vec![None; cells.len()];
    for (i, c) in cells.iter().enumerate() {
        let Some(sm) = &c.summary else { continue };
        let Some(rv) = selected
            .iter()
            .find(|w| w.name() == c.workload)
            .map(|w| w.variants()[0])
        else {
            continue;
        };
        if c.variant == rv {
            continue;
        }
        let reference = cells.iter().find(|r| {
            r.workload == c.workload
                && r.variant == rv
                && r.elems == c.elems
                && r.nodes == c.nodes
                && r.ranks_per_node == c.ranks_per_node
                && r.queues_per_rank == c.queues_per_rank
        });
        if let Some(rs) = reference.and_then(|r| r.summary.as_ref()) {
            deltas[i] = Some(pct_delta(rs.avg, sm.avg));
        }
    }
    for (c, d) in cells.iter_mut().zip(deltas) {
        c.delta_vs_ref_pct = d;
    }

    Ok(CampaignReport { seeds: spec.seeds.clone(), iters: spec.iters, cells, cache })
}

// ---------------------------------------------------------------------
// Cost-model diff
// ---------------------------------------------------------------------

/// One joined row of a cost-model diff: the same grid cell under the
/// base and the overridden cost model.
#[derive(Debug, Clone)]
pub struct DiffRow {
    pub workload: String,
    pub variant: String,
    pub elems: usize,
    pub nodes: usize,
    pub ranks_per_node: usize,
    pub queues_per_rank: usize,
    /// `"ok"` / `"stalled"` / `"skipped"` under the base model.
    pub base_status: String,
    /// Same, under the overridden model.
    pub alt_status: String,
    pub base_avg_ms: Option<f64>,
    pub alt_avg_ms: Option<f64>,
    /// Percent delta of the override vs the base (positive = the
    /// override made the cell slower); `None` unless both sides ran
    /// clean.
    pub delta_pct: Option<f64>,
}

/// The assembled cost-model diff (see [`diff_cost_models`]).
#[derive(Debug, Clone)]
pub struct CostDiff {
    /// The cost-model overrides the alternative side ran under.
    pub overrides: Vec<(String, f64)>,
    pub rows: Vec<DiffRow>,
    /// Combined cache accounting of the two underlying runs.
    pub cache: CacheStats,
}

impl CostDiff {
    /// Deterministic JSON rendering.
    pub fn to_json(&self) -> String {
        let overrides = self
            .overrides
            .iter()
            .map(|(f, v)| format!("{{\"field\": \"{}\", \"value\": {v}}}", json_escape(f)))
            .collect::<Vec<_>>()
            .join(", ");
        let mut s = String::new();
        s.push_str("{\n  \"cost_diff\": {\n");
        s.push_str(&format!("    \"overrides\": [{overrides}],\n"));
        s.push_str("    \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            let fmt_ms = |v: Option<f64>| match v {
                Some(ms) => format!("{ms:.6}"),
                None => "null".to_string(),
            };
            let delta = match r.delta_pct {
                Some(d) => format!("{d:.3}"),
                None => "null".to_string(),
            };
            s.push_str(&format!(
                "      {{ \"workload\": \"{}\", \"variant\": \"{}\", \"elems\": {}, \
                 \"nodes\": {}, \"ranks_per_node\": {}, \"queues_per_rank\": {}, \
                 \"base_status\": \"{}\", \"alt_status\": \"{}\", \
                 \"base_avg_ms\": {}, \"alt_avg_ms\": {}, \"delta_pct\": {} }}{}",
                json_escape(&r.workload),
                json_escape(&r.variant),
                r.elems,
                r.nodes,
                r.ranks_per_node,
                r.queues_per_rank,
                json_escape(&r.base_status),
                json_escape(&r.alt_status),
                fmt_ms(r.base_avg_ms),
                fmt_ms(r.alt_avg_ms),
                delta,
                if i + 1 == self.rows.len() { "\n" } else { ",\n" }
            ));
        }
        s.push_str("    ]\n  }\n}\n");
        s
    }

    /// Deterministic Markdown rendering.
    pub fn to_markdown(&self) -> String {
        let overrides = self
            .overrides
            .iter()
            .map(|(f, v)| format!("`{f}={v}`"))
            .collect::<Vec<_>>()
            .join(", ");
        let mut rows = vec![vec![
            "workload".to_string(),
            "variant".to_string(),
            "elems".to_string(),
            "topo".to_string(),
            "q".to_string(),
            "base ms".to_string(),
            "alt ms".to_string(),
            "delta".to_string(),
            "base".to_string(),
            "alt".to_string(),
        ]];
        for r in &self.rows {
            let fmt_ms = |v: Option<f64>| match v {
                Some(ms) => format!("{ms:.3}"),
                None => "--".to_string(),
            };
            let delta = match r.delta_pct {
                Some(d) => format!("{d:+.1}%"),
                None => "--".to_string(),
            };
            rows.push(vec![
                r.workload.clone(),
                r.variant.clone(),
                r.elems.to_string(),
                Topology::new(r.nodes, r.ranks_per_node).label(),
                r.queues_per_rank.to_string(),
                fmt_ms(r.base_avg_ms),
                fmt_ms(r.alt_avg_ms),
                delta,
                r.base_status.clone(),
                r.alt_status.clone(),
            ]);
        }
        format!(
            "# stmpi cost-model diff\n\noverrides: {}\n\n{}",
            overrides,
            markdown_table(&rows)
        )
    }
}

fn cell_status(c: &CampaignCell) -> &'static str {
    if c.stalls > 0 {
        "stalled"
    } else if c.summary.is_some() {
        "ok"
    } else {
        "skipped"
    }
}

/// Run the same campaign grid under the base cost model and under
/// `overrides` (applied via
/// [`crate::costmodel::CostModel::apply_override`]), and join the two
/// reports cell-by-cell. The join key is the cell identity — every
/// fingerprint component except the cost hash — so with
/// [`CampaignSpec::store`] set, whichever side is already cached is
/// served from the store and only the other side simulates.
pub fn diff_cost_models(spec: &CampaignSpec, overrides: &[(String, f64)]) -> Result<CostDiff> {
    if overrides.is_empty() {
        bail!("cost-model diff needs at least one field=value override");
    }
    let mut base_spec = spec.clone();
    base_spec.trace = None; // exports would force store bypass for no benefit
    base_spec.cost_overrides = Vec::new();
    let mut alt_spec = base_spec.clone();
    alt_spec.cost_overrides = overrides.to_vec();
    let base = run_campaign(&base_spec)?;
    let alt = run_campaign(&alt_spec)?;
    // The two specs differ only in cost-model overrides, so the grids
    // enumerate identically and the join is positional.
    if base.cells.len() != alt.cells.len() {
        bail!(
            "cost diff: grids diverged ({} vs {} cells) — this is a bug",
            base.cells.len(),
            alt.cells.len()
        );
    }
    let mut rows = Vec::with_capacity(base.cells.len());
    for (b, a) in base.cells.iter().zip(&alt.cells) {
        if (b.workload.as_str(), b.variant.as_str(), b.elems, b.nodes, b.ranks_per_node, b.queues_per_rank)
            != (a.workload.as_str(), a.variant.as_str(), a.elems, a.nodes, a.ranks_per_node, a.queues_per_rank)
        {
            bail!(
                "cost diff: cell identity diverged ({}/{} vs {}/{}) — this is a bug",
                b.workload,
                b.variant,
                a.workload,
                a.variant
            );
        }
        let base_status = cell_status(b);
        let alt_status = cell_status(a);
        let base_avg_ms = b.summary.as_ref().map(|s| s.avg);
        let alt_avg_ms = a.summary.as_ref().map(|s| s.avg);
        let delta_pct = match (base_status, alt_status, base_avg_ms, alt_avg_ms) {
            ("ok", "ok", Some(bm), Some(am)) => Some(pct_delta(bm, am)),
            _ => None,
        };
        rows.push(DiffRow {
            workload: b.workload.clone(),
            variant: b.variant.clone(),
            elems: b.elems,
            nodes: b.nodes,
            ranks_per_node: b.ranks_per_node,
            queues_per_rank: b.queues_per_rank,
            base_status: base_status.to_string(),
            alt_status: alt_status.to_string(),
            base_avg_ms,
            alt_avg_ms,
            delta_pct,
        });
    }
    let cache = CacheStats {
        hits: base.cache.hits + alt.cache.hits,
        misses: base.cache.misses + alt.cache.misses,
        simulated_ns_saved: base.cache.simulated_ns_saved + alt.cache.simulated_ns_saved,
    };
    Ok(CostDiff { overrides: overrides.to_vec(), rows, cache })
}

// ---------------------------------------------------------------------
// Minimal JSON syntax validator
// ---------------------------------------------------------------------

/// Validate that `s` is one syntactically well-formed JSON value (no
/// external parser crates are available offline). Escape sequences inside
/// strings are skipped, not decoded — this is a syntax check, not a
/// decoder.
pub fn json_parses(s: &str) -> bool {
    let b = s.as_bytes();
    let mut i = 0usize;
    if !parse_value(b, &mut i) {
        return false;
    }
    skip_ws(b, &mut i);
    i == b.len()
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn parse_value(b: &[u8], i: &mut usize) -> bool {
    skip_ws(b, i);
    match b.get(*i).copied() {
        Some(b'{') => parse_object(b, i),
        Some(b'[') => parse_array(b, i),
        Some(b'"') => parse_string(b, i),
        Some(b't') => parse_lit(b, i, b"true"),
        Some(b'f') => parse_lit(b, i, b"false"),
        Some(b'n') => parse_lit(b, i, b"null"),
        Some(c) if c == b'-' || c.is_ascii_digit() => parse_number(b, i),
        _ => false,
    }
}

fn parse_lit(b: &[u8], i: &mut usize, lit: &[u8]) -> bool {
    if b[*i..].starts_with(lit) {
        *i += lit.len();
        true
    } else {
        false
    }
}

fn parse_string(b: &[u8], i: &mut usize) -> bool {
    debug_assert_eq!(b[*i], b'"');
    *i += 1;
    while *i < b.len() {
        match b[*i] {
            b'"' => {
                *i += 1;
                return true;
            }
            b'\\' => *i += 2,
            _ => *i += 1,
        }
    }
    false
}

fn parse_number(b: &[u8], i: &mut usize) -> bool {
    if b.get(*i).copied() == Some(b'-') {
        *i += 1;
    }
    let d0 = *i;
    while *i < b.len() && b[*i].is_ascii_digit() {
        *i += 1;
    }
    if *i == d0 {
        return false;
    }
    if b.get(*i).copied() == Some(b'.') {
        *i += 1;
        let f0 = *i;
        while *i < b.len() && b[*i].is_ascii_digit() {
            *i += 1;
        }
        if *i == f0 {
            return false;
        }
    }
    if matches!(b.get(*i).copied(), Some(b'e') | Some(b'E')) {
        *i += 1;
        if matches!(b.get(*i).copied(), Some(b'+') | Some(b'-')) {
            *i += 1;
        }
        let e0 = *i;
        while *i < b.len() && b[*i].is_ascii_digit() {
            *i += 1;
        }
        if *i == e0 {
            return false;
        }
    }
    true
}

fn parse_object(b: &[u8], i: &mut usize) -> bool {
    *i += 1; // consume '{'
    skip_ws(b, i);
    if b.get(*i).copied() == Some(b'}') {
        *i += 1;
        return true;
    }
    loop {
        skip_ws(b, i);
        if b.get(*i).copied() != Some(b'"') || !parse_string(b, i) {
            return false;
        }
        skip_ws(b, i);
        if b.get(*i).copied() != Some(b':') {
            return false;
        }
        *i += 1;
        if !parse_value(b, i) {
            return false;
        }
        skip_ws(b, i);
        match b.get(*i).copied() {
            Some(b',') => *i += 1,
            Some(b'}') => {
                *i += 1;
                return true;
            }
            _ => return false,
        }
    }
}

fn parse_array(b: &[u8], i: &mut usize) -> bool {
    *i += 1; // consume '['
    skip_ws(b, i);
    if b.get(*i).copied() == Some(b']') {
        *i += 1;
        return true;
    }
    loop {
        if !parse_value(b, i) {
            return false;
        }
        skip_ws(b, i);
        match b.get(*i).copied() {
            Some(b',') => *i += 1,
            Some(b']') => {
                *i += 1;
                return true;
            }
            _ => return false,
        }
    }
}
