//! `alltoall` workload: transpose-style personalized exchange — every
//! rank sends a distinct `elems`-sized block to every other rank each
//! iteration, stressing fabric port serialization (n-1 messages leave
//! and enter every NIC port back-to-back).
//!
//! Per iteration: pre-post n-1 receives → pack kernel + one
//! [`crate::stx::CommPlan`] round (host-synchronized baseline vs
//! stream-triggered vs kernel-triggered) → local self-block copy kernel
//! → wait receives → drain. The n-1-send pattern is recorded once; with
//! `queues_per_rank > 1` it stripes over multiple queues contending for
//! DWQ slots. Validation is exact: the block received from rank `s`
//! must be `payload(s, my_rank, j)`.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::run_cluster;
use crate::gpu::{host_enqueue, stream_synchronize, KernelPayload, KernelSpec, StreamOp};
use crate::mpi::{self, SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::world::ComputeMode;

use super::scaffold::{check_exact, lease_world, scenario_run, RankComm, Timers};
use super::{comm_variant, payload, ScenarioCfg, ScenarioRun, Workload};

pub struct AllToAll;

const A2A_TAG: i32 = 500;

impl Workload for AllToAll {
    fn name(&self) -> &'static str {
        "alltoall"
    }

    fn description(&self) -> &'static str {
        "personalized all-to-all (transpose) stressing fabric port serialization"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "st", "st-shader", "kt", "gi"]
    }

    fn default_elems(&self) -> &'static [usize] {
        &[64, 1024, 16384]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        comm_variant("alltoall", &cfg.variant)?;
        if cfg.world_size() == 0 {
            bail!("alltoall: empty world");
        }
        if cfg.elems == 0 {
            bail!("alltoall: blocks must carry at least one element");
        }
        if cfg.queues_per_rank == 0 {
            bail!("alltoall: at least one queue per rank");
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let variant = comm_variant("alltoall", &cfg.variant)?;
        let n = cfg.world_size();
        let elems = cfg.elems;

        let mut world = lease_world("alltoall", cfg);
        world.compute = ComputeMode::Real;
        // Per rank: a send matrix and a recv matrix of n blocks each.
        let send: Vec<_> = (0..n).map(|_| world.bufs.alloc(n * elems)).collect();
        let recv: Vec<_> = (0..n).map(|_| world.bufs.alloc(n * elems)).collect();
        // What rank r's pack kernel writes: block p = payload(r, p, j).
        let images: Arc<Vec<Vec<f32>>> = Arc::new(
            (0..n)
                .map(|r| {
                    (0..n)
                        .flat_map(|p| (0..elems).map(move |j| payload(r, p, j)))
                        .collect()
                })
                .collect(),
        );

        let times = Timers::new(n);
        let (iters, qpr) = (cfg.iters, cfg.queues_per_rank);
        let (send2, recv2, images2, times2) =
            (send.clone(), recv.clone(), images.clone(), times.clone());
        let out = run_cluster(world, cfg.seed, move |rank, ctx| {
            let comm = RankComm::new(ctx, rank, variant, qpr);
            let (sb, rb) = (send2[rank], recv2[rank]);
            // Build-once: n-1 personalized sends + n-1 posted receives
            // (src-disambiguated, shared tag).
            let mut b = comm.builder();
            for p in 0..n {
                if p != rank {
                    b.send(p, BufSlice::new(sb, p * elems, elems), A2A_TAG, COMM_WORLD);
                }
            }
            for s in 0..n {
                if s != rank {
                    b.recv(
                        SrcSel::Rank(s),
                        TagSel::Tag(A2A_TAG),
                        COMM_WORLD,
                        BufSlice::new(rb, s * elems, elems),
                    );
                }
            }
            let cplan = b.build(ctx).expect("alltoall plan build");

            let t0 = ctx.now();
            for _iter in 0..iters {
                // 1. Pre-post receives into the recv matrix.
                let rreqs = cplan.post_recvs(ctx, 0);
                // 2. Pack kernel: write all n outgoing blocks (the image
                //    travels by Arc, not by per-iteration clone).
                let images_k = images2.clone();
                let total = n * elems;
                let pack = KernelSpec {
                    name: "a2a_pack".into(),
                    flops: 0,
                    bytes: 2 * 4 * total as u64,
                    payload: KernelPayload::Fn(Box::new(move |w, _| {
                        w.bufs.get_mut(sb)[..total].copy_from_slice(&images_k[rank]);
                    })),
                };
                // 3. One plan round: sends to all peers under the
                //    variant protocol, then its completion wait.
                let round = cplan.round(ctx, vec![pack]).expect("alltoall round");
                cplan.complete(ctx, round).expect("alltoall complete");
                // 4. Self block: device-local copy (stream-ordered after
                //    pack in every variant).
                host_enqueue(
                    ctx,
                    comm.sid,
                    StreamOp::Kernel(KernelSpec {
                        name: "a2a_self".into(),
                        flops: 0,
                        bytes: 2 * 4 * elems as u64,
                        payload: KernelPayload::Fn(Box::new(move |w, _| {
                            w.bufs.copy(sb, rank * elems, rb, rank * elems, elems);
                        })),
                    }),
                );
                // 5. Wait receives, then drain before buffers are reused.
                mpi::waitall(ctx, &rreqs);
                stream_synchronize(ctx, comm.sid);
            }
            comm.drain_if_kt(ctx, &cplan, "alltoall");
            times2.record(rank, ctx.now() - t0);
            comm.finish(ctx, "alltoall");
        })
        .context("alltoall run failed")?;

        // Reference: recv block s on rank r == payload(s, r, j).
        let pairs = recv.iter().enumerate().flat_map(|(r, rb)| {
            let got = out.world.bufs.get(*rb);
            (0..n)
                .flat_map(move |s| (0..elems).map(move |j| (got[s * elems + j], payload(s, r, j))))
        });
        let validation = check_exact(pairs, |i| {
            let (r, s, j) = (i / (n * elems), (i / elems) % n, i % elems);
            format!("alltoall rank {r} block {s} elem {j}")
        });
        Ok(scenario_run("alltoall", cfg, out, &times, validation))
    }
}
