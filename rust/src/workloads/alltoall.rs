//! `alltoall` workload: transpose-style personalized exchange — every
//! rank sends a distinct `elems`-sized block to every other rank each
//! iteration, stressing fabric port serialization (n-1 messages leave
//! and enter every NIC port back-to-back).
//!
//! Per iteration: pre-post n-1 receives → pack kernel (writes all
//! outgoing blocks) → sends (host-synchronized baseline vs
//! stream-triggered vs kernel-triggered) → local self-block copy kernel
//! → wait receives → drain. Validation is exact: the block received
//! from rank `s` must be `payload(s, my_rank, j)`.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{build_world, run_cluster};
use crate::gpu::{self, host_enqueue, stream_synchronize, KernelPayload, KernelSpec, StreamOp};
use crate::mpi::{self, SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::stx::{self, Variant};
use crate::world::ComputeMode;

use super::{comm_variant, payload, ScenarioCfg, ScenarioRun, Validation, Workload};

pub struct AllToAll;

const A2A_TAG: i32 = 500;

impl Workload for AllToAll {
    fn name(&self) -> &'static str {
        "alltoall"
    }

    fn description(&self) -> &'static str {
        "personalized all-to-all (transpose) stressing fabric port serialization"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "st", "st-shader", "kt"]
    }

    fn default_elems(&self) -> &'static [usize] {
        &[64, 1024, 16384]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        comm_variant("alltoall", &cfg.variant)?;
        if cfg.world_size() == 0 {
            bail!("alltoall: empty world");
        }
        if cfg.elems == 0 {
            bail!("alltoall: blocks must carry at least one element");
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let variant = comm_variant("alltoall", &cfg.variant)?;
        let n = cfg.world_size();
        let elems = cfg.elems;

        let mut world = build_world(cfg.cost.clone(), cfg.topology());
        world.compute = ComputeMode::Real;
        // Per rank: a send matrix and a recv matrix of n blocks each.
        let send: Vec<_> = (0..n).map(|_| world.bufs.alloc(n * elems)).collect();
        let recv: Vec<_> = (0..n).map(|_| world.bufs.alloc(n * elems)).collect();
        // What rank r's pack kernel writes: block p = payload(r, p, j).
        let images: Arc<Vec<Vec<f32>>> = Arc::new(
            (0..n)
                .map(|r| {
                    (0..n)
                        .flat_map(|p| (0..elems).map(move |j| payload(r, p, j)))
                        .collect()
                })
                .collect(),
        );

        let times: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(vec![0; n]));
        let iters = cfg.iters;
        let (send2, recv2, images2, times2) =
            (send.clone(), recv.clone(), images.clone(), times.clone());
        let out = run_cluster(world, cfg.seed, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let queue = variant
                .uses_queue()
                .then(|| stx::create_queue(ctx, rank, sid, variant.flavor()));
            let (sb, rb) = (send2[rank], recv2[rank]);

            let t0 = ctx.now();
            for _iter in 0..iters {
                // 1. Pre-post receives: block s of the recv matrix takes
                //    rank s's message (src-disambiguated, shared tag).
                let mut rreqs = Vec::with_capacity(n - 1);
                for s in 0..n {
                    if s == rank {
                        continue;
                    }
                    rreqs.push(mpi::irecv(
                        ctx,
                        rank,
                        SrcSel::Rank(s),
                        TagSel::Tag(A2A_TAG),
                        COMM_WORLD,
                        BufSlice::new(rb, s * elems, elems),
                    ));
                }
                // 2. Pack kernel: write all n outgoing blocks (the image
                //    travels by Arc, not by per-iteration clone).
                let images_k = images2.clone();
                let total = n * elems;
                let pack = KernelSpec {
                    name: "a2a_pack".into(),
                    flops: 0,
                    bytes: 2 * 4 * total as u64,
                    payload: KernelPayload::Fn(Box::new(move |w, _| {
                        w.bufs.get_mut(sb)[..total].copy_from_slice(&images_k[rank]);
                    })),
                };
                // 3. Sends to all peers.
                match variant {
                    Variant::Host => {
                        host_enqueue(ctx, sid, StreamOp::Kernel(pack));
                        stream_synchronize(ctx, sid);
                        let mut sreqs = Vec::with_capacity(n - 1);
                        for p in 0..n {
                            if p == rank {
                                continue;
                            }
                            sreqs.push(mpi::isend(
                                ctx,
                                rank,
                                p,
                                BufSlice::new(sb, p * elems, elems),
                                A2A_TAG,
                                COMM_WORLD,
                            ));
                        }
                        mpi::waitall(ctx, &sreqs);
                    }
                    Variant::KernelTriggered => {
                        // KT: the previous iteration's send completions
                        // ride the pack prologue; this iteration's
                        // trigger fires from inside the pack kernel.
                        let q = queue.unwrap();
                        let mut kt = gpu::KernelCtx::new();
                        stx::kt_wait(ctx, q, &mut kt).expect("alltoall kt_wait");
                        for p in 0..n {
                            if p == rank {
                                continue;
                            }
                            stx::enqueue_send(
                                ctx,
                                q,
                                p,
                                BufSlice::new(sb, p * elems, elems),
                                A2A_TAG,
                                COMM_WORLD,
                            )
                            .expect("alltoall enqueue_send");
                        }
                        stx::kt_start(ctx, q, &mut kt, stx::KT_TRIGGER_FRAC)
                            .expect("alltoall kt_start");
                        host_enqueue(ctx, sid, StreamOp::KtKernel(pack, kt));
                    }
                    _ => {
                        host_enqueue(ctx, sid, StreamOp::Kernel(pack));
                        let q = queue.unwrap();
                        for p in 0..n {
                            if p == rank {
                                continue;
                            }
                            stx::enqueue_send(
                                ctx,
                                q,
                                p,
                                BufSlice::new(sb, p * elems, elems),
                                A2A_TAG,
                                COMM_WORLD,
                            )
                            .expect("alltoall enqueue_send");
                        }
                        stx::enqueue_start(ctx, q).expect("alltoall enqueue_start");
                        stx::enqueue_wait(ctx, q).expect("alltoall enqueue_wait");
                    }
                }
                // 4. Self block: device-local copy (stream-ordered after
                //    pack in both variants).
                host_enqueue(
                    ctx,
                    sid,
                    StreamOp::Kernel(KernelSpec {
                        name: "a2a_self".into(),
                        flops: 0,
                        bytes: 2 * 4 * elems as u64,
                        payload: KernelPayload::Fn(Box::new(move |w, _| {
                            w.bufs.copy(sb, rank * elems, rb, rank * elems, elems);
                        })),
                    }),
                );
                // 5. Wait receives, then drain before buffers are reused.
                mpi::waitall(ctx, &rreqs);
                stream_synchronize(ctx, sid);
            }
            // KT drains its outstanding send completions inside the
            // timed region (ST already waited via enqueue_wait).
            if variant == Variant::KernelTriggered {
                stx::queue_drain(ctx, queue.unwrap()).expect("alltoall queue drain");
            }
            let dt = ctx.now() - t0;
            if let Some(q) = queue {
                stx::free_queue(ctx, q).expect("alltoall queue idle at teardown");
            }
            times2.lock().unwrap()[rank] = dt;
        })
        .map_err(|e| anyhow!("alltoall run failed: {e}"))?;

        // Reference: recv block s on rank r == payload(s, r, j).
        let mut validation = Validation::Passed { checked: n * n * elems };
        'outer: for (r, rb) in recv.iter().enumerate() {
            let got = out.world.bufs.get(*rb);
            for s in 0..n {
                for j in 0..elems {
                    let expect = payload(s, r, j);
                    if got[s * elems + j] != expect {
                        validation = Validation::Failed {
                            detail: format!(
                                "rank {r} block {s} elem {j}: {} != {expect}",
                                got[s * elems + j]
                            ),
                        };
                        break 'outer;
                    }
                }
            }
        }

        let rank_time = times.lock().unwrap().clone();
        Ok(ScenarioRun {
            time_ns: rank_time.iter().copied().max().unwrap_or(0),
            metrics: out.world.metrics.clone(),
            stats: out.stats,
            validation,
        })
    }
}
