//! `halo3d` workload: 27-point stencil halo exchange — the Faces pattern
//! generalized into a standalone, self-validating scenario.
//!
//! Every rank of a near-cubic process grid exchanges with all of its up
//! to 26 neighbors each iteration: face messages carry `elems` f32s, edge
//! messages `max(elems/16, 1)`, corner messages 1 (the Nekbone surface
//! ratio, coarsened). Per iteration: pre-post receives → pack kernel →
//! one [`crate::stx::CommPlan`] round (host-synchronized baseline vs
//! stream-triggered vs kernel-triggered, where the trigger fires from
//! inside the pack kernel) → wait receives → unpack-accumulate kernel →
//! drain. The plan is built once per rank; iterations contain no enqueue
//! calls.
//!
//! Validation is exact: send payloads are deterministic small integers
//! ([`super::payload`]), the unpack kernel accumulates them, and the
//! host-side reference knows precisely what every accumulator slot must
//! hold after `iters` iterations. An ST trigger firing before its pack
//! kernel (a stream-ordering bug) would ship zeros and fail the check.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::run_cluster;
use crate::faces::domain::ProcGrid;
use crate::gpu::{host_enqueue, stream_synchronize, KernelPayload, KernelSpec, StreamOp};
use crate::mpi::{self, SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::sim::HostCtx;
use crate::stx::Variant;
use crate::world::{BufId, ComputeMode, World};

use super::scaffold::{check_exact, lease_world, scenario_run, RankComm, Timers};
use super::{comm_variant, grid_for, payload, ScenarioCfg, ScenarioRun, Workload};

pub struct Halo3d;

/// Message size for a neighbor of the given order (1 = face, 2 = edge,
/// 3 = corner).
fn msg_elems(elems: usize, order: u32) -> usize {
    match order {
        1 => elems,
        2 => (elems / 16).max(1),
        _ => 1,
    }
}

/// One neighbor's slot in the packed send/recv buffers.
struct NbrPlan {
    nbr: usize,
    tag_send: i32,
    tag_recv: i32,
    /// The lane the *sender* used when packing what we receive.
    lane_recv: usize,
    send_off: usize,
    recv_off: usize,
    elems: usize,
}

/// Per-rank buffers + message schedule.
struct RankPlan {
    send: BufId,
    recv: BufId,
    acc: BufId,
    total_send: usize,
    total_recv: usize,
    /// What the pack kernel writes each iteration (the rank's surface).
    send_image: Vec<f32>,
    nbrs: Vec<NbrPlan>,
}

fn build_plans(w: &mut World, grid: &ProcGrid, elems: usize) -> Vec<RankPlan> {
    (0..grid.size())
        .map(|rank| {
            let mut nbrs = Vec::new();
            let mut send_image = Vec::new();
            let (mut soff, mut roff) = (0usize, 0usize);
            for (d, nbr) in grid.neighbors(rank) {
                let m = msg_elems(elems, d.order());
                let lane_send = d.tag() as usize;
                for j in 0..m {
                    send_image.push(payload(rank, lane_send, j));
                }
                nbrs.push(NbrPlan {
                    nbr,
                    tag_send: d.tag(),
                    tag_recv: d.opposite().tag(),
                    lane_recv: d.opposite().tag() as usize,
                    send_off: soff,
                    recv_off: roff,
                    elems: m,
                });
                soff += m;
                roff += m;
            }
            let send = w.bufs.alloc(soff);
            let recv = w.bufs.alloc(roff);
            let acc = w.bufs.alloc(roff);
            RankPlan { send, recv, acc, total_send: soff, total_recv: roff, send_image, nbrs }
        })
        .collect()
}

fn rank_program(
    iters: usize,
    plans: &Arc<Vec<RankPlan>>,
    rank: usize,
    ctx: &mut HostCtx<World>,
    variant: Variant,
    queues_per_rank: usize,
    times: &Timers,
) {
    let plan = &plans[rank];
    let comm = RankComm::new(ctx, rank, variant, queues_per_rank);
    // Build-once: the whole neighbor pattern is recorded in one plan;
    // iterations only re-arm it.
    let mut b = comm.builder();
    for m in &plan.nbrs {
        b.send(m.nbr, BufSlice::new(plan.send, m.send_off, m.elems), m.tag_send, COMM_WORLD);
        b.recv(
            SrcSel::Rank(m.nbr),
            TagSel::Tag(m.tag_recv),
            COMM_WORLD,
            BufSlice::new(plan.recv, m.recv_off, m.elems),
        );
    }
    let cplan = b.build(ctx).expect("halo3d plan build");

    let t0 = ctx.now();
    for _iter in 0..iters {
        // 1. Pre-post all receives (every rank posts receives before
        //    initiating sends, so rendezvous cannot deadlock).
        let rreqs = cplan.post_recvs(ctx, 0);
        // 2. Pack kernel: surface -> contiguous send buffer (the image
        //    travels by Arc, not by per-iteration clone).
        let (send, total, plans_k) = (plan.send, plan.total_send, plans.clone());
        let pack = KernelSpec {
            name: "halo3d_pack".into(),
            flops: 0,
            bytes: 2 * 4 * total as u64,
            payload: KernelPayload::Fn(Box::new(move |w, _| {
                w.bufs.get_mut(send)[..total].copy_from_slice(&plans_k[rank].send_image);
            })),
        };
        // 3. One plan round drives the sends under the variant protocol
        //    (Fig-1 sync + isends / deferred sends + CP trigger / KT
        //    hooks riding the pack kernel), and its completion wait.
        let round = cplan.round(ctx, vec![pack]).expect("halo3d round");
        cplan.complete(ctx, round).expect("halo3d complete");
        // 4. Wait receives on the host, then unpack-accumulate.
        mpi::waitall(ctx, &rreqs);
        let (recv, acc, total_r) = (plan.recv, plan.acc, plan.total_recv);
        host_enqueue(
            ctx,
            comm.sid,
            StreamOp::Kernel(KernelSpec {
                name: "halo3d_unpack".into(),
                flops: total_r as u64,
                bytes: 3 * 4 * total_r as u64,
                payload: KernelPayload::Fn(Box::new(move |w, _| {
                    let r = w.bufs.get(recv)[..total_r].to_vec();
                    let a = w.bufs.get_mut(acc);
                    for (dst, src) in a[..total_r].iter_mut().zip(&r) {
                        *dst += src;
                    }
                })),
            }),
        );
        // 5. Drain: every iteration's unpack lands strictly before the
        //    next iteration's receives reuse the buffers.
        stream_synchronize(ctx, comm.sid);
    }
    // KT/GI drain their outstanding send completions inside the timed
    // region (ST already waited via the stream), keeping the variants'
    // figures of merit comparable.
    comm.drain_if_kt(ctx, &cplan, "halo3d");
    times.record(rank, ctx.now() - t0);
    comm.finish(ctx, "halo3d");
}

impl Workload for Halo3d {
    fn name(&self) -> &'static str {
        "halo3d"
    }

    fn description(&self) -> &'static str {
        "27-point stencil halo exchange (faces+edges+corners), exact-validated"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "st", "st-shader", "kt", "gi"]
    }

    fn default_elems(&self) -> &'static [usize] {
        &[64, 1024, 8192]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        comm_variant("halo3d", &cfg.variant)?;
        if cfg.world_size() == 0 {
            bail!("halo3d: empty world");
        }
        if cfg.elems == 0 {
            bail!("halo3d: face message must carry at least one element");
        }
        if cfg.queues_per_rank == 0 {
            bail!("halo3d: at least one queue per rank");
        }
        // Exact-equality validation: accumulator sums stay exactly
        // representable in f32 only while iters * max_payload < 2^24
        // (payload values are < 8192, so 2048 iterations).
        if cfg.iters > 2048 {
            bail!("halo3d: exact f32 validation bounds iters to 2048, got {}", cfg.iters);
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let variant = comm_variant("halo3d", &cfg.variant)?;
        let (px, py, pz) = grid_for(cfg.world_size());
        let grid = ProcGrid::new(px, py, pz);
        let mut world = lease_world("halo3d", cfg);
        world.compute = ComputeMode::Real; // Fn-payload kernels move real data
        let plans = Arc::new(build_plans(&mut world, &grid, cfg.elems));
        let times = Timers::new(grid.size());

        let (iters, qpr) = (cfg.iters, cfg.queues_per_rank);
        let plans2 = plans.clone();
        let times2 = times.clone();
        let out = run_cluster(world, cfg.seed, move |rank, ctx| {
            rank_program(iters, &plans2, rank, ctx, variant, qpr, &times2);
        })
        .context("halo3d run failed")?;

        // Host-side reference: every accumulator slot holds iters * the
        // neighbor's packed value for the opposing direction.
        let pairs = plans.iter().flat_map(|plan| {
            let acc = out.world.bufs.get(plan.acc);
            plan.nbrs.iter().flat_map(move |m| {
                (0..m.elems).map(move |j| {
                    (acc[m.recv_off + j], iters as f32 * payload(m.nbr, m.lane_recv, j))
                })
            })
        });
        let validation = check_exact(pairs, |i| format!("halo3d acc slot {i}"));
        Ok(scenario_run("halo3d", cfg, out, &times, validation))
    }
}
