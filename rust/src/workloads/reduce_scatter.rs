//! `reduce-scatter` workload: the ring's reduce phase as a standalone,
//! sweepable scenario — the dual of [`super::allgather`]. Each of the
//! n-1 ring steps is one persistent [`crate::stx::CommPlan`] (send the
//! running partial sum of chunk `rank-s` to `next`, deferred-receive
//! chunk `rank-s-1` from `prev` into a per-step staging slot) built
//! before the timed region and re-armed every iteration.
//!
//! Per iteration: step 0's round carries the init kernel (resets all n
//! chunks to this rank's contribution, so iterations accumulate
//! idempotently); step s ≥ 1 carries the add kernel folding the staged
//! chunk received at step s-1 into the chunk step s sends — the
//! serialized dependence chain that makes reduce-scatter harder to
//! overlap than allgather's pure relay. After the loop a final fold
//! kernel adds the last staged chunk into the rank's owned chunk
//! `(rank+1) % n`; KT drains its queues first, because the fold rides a
//! bare stream kernel with no plan prologue to order it after the last
//! triggered receive. Validation is exact: the owned chunk must hold
//! `Σ_src payload(src, own, j)` (integer payloads keep f32 sums exact).

use anyhow::{bail, Context, Result};

use crate::coordinator::run_cluster;
use crate::gpu::{host_enqueue, stream_synchronize, KernelPayload, KernelSpec, StreamOp};
use crate::mpi::{SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::world::ComputeMode;

use super::scaffold::{check_exact, lease_world, scenario_run, RankComm, Timers};
use super::{comm_variant, payload, ScenarioCfg, ScenarioRun, Workload};

pub struct ReduceScatter;

/// Tag base; disjoint from the collectives' 1000/2000/3000 and
/// allgather's 4000 spaces.
const RS_TAG: i32 = 5000;

impl Workload for ReduceScatter {
    fn name(&self) -> &'static str {
        "reduce-scatter"
    }

    fn description(&self) -> &'static str {
        "ring reduce-scatter (the ring's reduce phase), add-kernel chain over persistent CommPlans"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "st", "st-shader", "kt", "gi"]
    }

    fn default_elems(&self) -> &'static [usize] {
        &[256, 4096, 65536]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        comm_variant("reduce-scatter", &cfg.variant)?;
        if cfg.world_size() < 2 {
            bail!("reduce-scatter needs at least two ranks");
        }
        if cfg.elems == 0 {
            bail!("reduce-scatter: chunks must carry at least one element");
        }
        if cfg.queues_per_rank == 0 {
            bail!("reduce-scatter: at least one queue per rank");
        }
        // Exact f32 validation: sums of n payloads (each < 8192) stay
        // exactly representable while n * 8191 < 2^24.
        if cfg.world_size() > 2048 {
            bail!("reduce-scatter: exact f32 validation bounds the world to 2048 ranks");
        }
        // Each ring step is one single-send plan; plans rotate over the
        // queue set, so multi-queue runs need at least as many steps as
        // queues or the extra queues would sit idle.
        if cfg.queues_per_rank > 1 && cfg.world_size() - 1 < cfg.queues_per_rank {
            bail!(
                "reduce-scatter: {} queues per rank need at least {} ranks (one ring step per queue)",
                cfg.queues_per_rank,
                cfg.queues_per_rank + 1
            );
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let variant = comm_variant("reduce-scatter", &cfg.variant)?;
        let n = cfg.world_size();
        let elems = cfg.elems;

        let mut world = lease_world("reduce-scatter", cfg);
        world.compute = ComputeMode::Real;
        // Per rank: the working vector (n chunks of running partial
        // sums) plus one staging slot per ring step for the incoming
        // chunk (the fold kernel reads it after the receive lands).
        let work: Vec<_> = (0..n).map(|_| world.bufs.alloc(n * elems)).collect();
        let stage: Vec<_> = (0..n).map(|_| world.bufs.alloc((n - 1) * elems)).collect();

        let times = Timers::new(n);
        let (iters, qpr) = (cfg.iters, cfg.queues_per_rank);
        let (work2, stage2, times2) = (work.clone(), stage.clone(), times.clone());
        let out = run_cluster(world, cfg.seed, move |rank, ctx| {
            let comm = RankComm::new(ctx, rank, variant, qpr);
            let (wbuf, sbuf) = (work2[rank], stage2[rank]);
            let next = (rank + 1) % n;
            let prev = (rank + n - 1) % n;
            // Build-once: one persistent plan per ring step. Step s
            // sends the partial sum of chunk (rank - s) onward and
            // lands chunk (rank - s - 1) in staging slot s.
            let steps: Vec<_> = (0..n - 1)
                .map(|s| {
                    let send_c = (rank + n - s) % n;
                    let tag = RS_TAG + s as i32;
                    let mut b = comm.builder();
                    b.send(next, BufSlice::new(wbuf, send_c * elems, elems), tag, COMM_WORLD);
                    b.recv_deferred(
                        SrcSel::Rank(prev),
                        TagSel::Tag(tag),
                        COMM_WORLD,
                        BufSlice::new(sbuf, s * elems, elems),
                    )
                    .expect("concrete selectors");
                    b.build(ctx).expect("reduce-scatter plan build")
                })
                .collect();

            let t0 = ctx.now();
            for _iter in 0..iters {
                for (s, plan) in steps.iter().enumerate() {
                    // Step 0 rides the init kernel (reset all chunks to
                    // this rank's own contribution); step s >= 1 rides
                    // the add kernel folding the chunk staged at step
                    // s-1 into the chunk this step sends.
                    let spec = if s == 0 {
                        KernelSpec {
                            name: "rs_init".into(),
                            flops: 0,
                            bytes: 2 * 4 * (n * elems) as u64,
                            payload: KernelPayload::Fn(Box::new(move |w, _| {
                                let b = w.bufs.get_mut(wbuf);
                                for c in 0..n {
                                    for j in 0..elems {
                                        b[c * elems + j] = payload(rank, c, j);
                                    }
                                }
                            })),
                        }
                    } else {
                        let fold_c = (rank + n - s) % n;
                        KernelSpec {
                            name: "rs_add".into(),
                            flops: elems as u64,
                            bytes: 3 * 4 * elems as u64,
                            payload: KernelPayload::Fn(Box::new(move |w, _| {
                                let (dst, src) =
                                    (fold_c * elems, (s - 1) * elems);
                                for j in 0..elems {
                                    let x = w.bufs.get(sbuf)[src + j];
                                    w.bufs.get_mut(wbuf)[dst + j] += x;
                                }
                            })),
                        }
                    };
                    let round = plan.round(ctx, vec![spec]).expect("reduce-scatter round");
                    plan.complete(ctx, round).expect("reduce-scatter complete");
                }
                // Final fold: the chunk staged by the last step is this
                // rank's owned chunk (rank + 1) % n. It rides a bare
                // stream kernel, so KT must drain its queues first —
                // Host/ST are already ordered (waitall / waitValue64).
                for plan in &steps {
                    comm.drain_if_kt(ctx, plan, "reduce-scatter");
                }
                let own = (rank + 1) % n;
                host_enqueue(
                    ctx,
                    comm.sid,
                    StreamOp::Kernel(KernelSpec {
                        name: "rs_fold".into(),
                        flops: elems as u64,
                        bytes: 3 * 4 * elems as u64,
                        payload: KernelPayload::Fn(Box::new(move |w, _| {
                            let (dst, src) = (own * elems, (n - 2) * elems);
                            for j in 0..elems {
                                let x = w.bufs.get(sbuf)[src + j];
                                w.bufs.get_mut(wbuf)[dst + j] += x;
                            }
                        })),
                    }),
                );
                stream_synchronize(ctx, comm.sid);
            }
            times2.record(rank, ctx.now() - t0);
            comm.finish(ctx, "reduce-scatter");
        })
        .context("reduce-scatter run failed")?;

        // Reference: rank r's owned chunk (r+1) % n holds the full sum
        // over ranks; the other chunks hold partial sums and are not
        // part of the reduce-scatter contract.
        let pairs = work.iter().enumerate().flat_map(|(r, wb)| {
            let got = out.world.bufs.get(*wb);
            let own = (r + 1) % n;
            (0..elems).map(move |j| {
                let expect: f32 = (0..n).map(|src| payload(src, own, j)).sum();
                (got[own * elems + j], expect)
            })
        });
        let validation = check_exact(pairs, |i| {
            let (r, j) = (i / elems, i % elems);
            format!("reduce-scatter rank {r} owned chunk elem {j}")
        });
        Ok(scenario_run("reduce-scatter", cfg, out, &times, validation))
    }
}
