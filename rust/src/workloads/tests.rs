//! Workload-subsystem tests: registry integrity, per-workload smoke runs
//! with exact validation, grid factorization, campaign determinism, and
//! the JSON validator.

use super::campaign::{json_parses, run_campaign, CampaignSpec};
use super::{by_name, grid_for, names, registry, ScenarioCfg, Validation};

#[test]
fn registry_has_nine_unique_workloads() {
    let names = names();
    assert_eq!(
        names,
        vec![
            "faces",
            "halo3d",
            "allreduce",
            "alltoall",
            "incast",
            "allgather",
            "halograph",
            "reduce-scatter",
            "broadcast"
        ]
    );
    for n in &names {
        let w = by_name(n).expect("by_name must resolve every registry name");
        assert_eq!(w.name(), *n);
        assert!(w.variants().len() >= 2, "{n}: campaigns need at least two variants");
        assert!(!w.default_elems().is_empty(), "{n}: needs default sizes");
        assert!(!w.description().is_empty());
    }
    assert!(by_name("no-such-workload").is_none());
}

/// Every workload × variant runs a tiny inter-node cell and validates.
#[test]
fn every_workload_variant_smoke_runs_and_validates() {
    for w in registry() {
        for v in w.variants() {
            let cfg = ScenarioCfg::smoke(v, 2, 1, 24);
            w.configure(&cfg)
                .unwrap_or_else(|e| panic!("{}::{v} infeasible on 2x1: {e}", w.name()));
            let r = w
                .run(&cfg)
                .unwrap_or_else(|e| panic!("{}::{v} failed: {e}", w.name()));
            assert!(
                r.validation.ok(),
                "{}::{v} validation: {}",
                w.name(),
                r.validation.label()
            );
            assert!(r.time_ns > 0, "{}::{v} must spend virtual time", w.name());
        }
    }
}

/// The validated workloads really compare against a reference (not
/// vacuously NotChecked), and mixed intra/inter-node topologies pass.
#[test]
fn validated_workloads_check_data_on_mixed_topology() {
    for (name, variant) in [
        ("halo3d", "st"),
        ("halo3d", "kt"),
        ("allreduce", "ring-st"),
        ("allreduce", "rdbl-st"),
        ("allreduce", "ring-kt"),
        ("alltoall", "st"),
        ("alltoall", "kt"),
        ("incast", "st"),
        ("incast", "kt"),
        ("allgather", "st"),
        ("allgather", "kt"),
        ("halograph", "st"),
        ("halograph", "kt"),
        ("reduce-scatter", "st"),
        ("reduce-scatter", "kt"),
        ("broadcast", "st"),
        ("broadcast", "kt"),
    ] {
        let w = by_name(name).unwrap();
        // broadcast's relay chain is sequential: it only admits one
        // queue per rank, so the mixed-topology leg keeps qpr=1 there.
        let qpr = if name == "broadcast" { 1 } else { 2 };
        let cfg = ScenarioCfg::smoke(variant, 2, qpr, 40);
        let r = w.run(&cfg).unwrap_or_else(|e| panic!("{name}::{variant}: {e}"));
        match r.validation {
            Validation::Passed { checked } => {
                assert!(checked > 0, "{name}::{variant} checked nothing")
            }
            other => panic!("{name}::{variant}: expected Passed, got {other:?}"),
        }
    }
}

/// ST variants must exercise the triggered path (deferred-work queues or
/// progress-thread emulation), the baseline must not.
#[test]
fn st_variants_use_triggered_ops() {
    let w = by_name("halo3d").unwrap();
    let st = w.run(&ScenarioCfg::smoke("st", 2, 1, 24)).unwrap();
    let base = w.run(&ScenarioCfg::smoke("baseline", 2, 1, 24)).unwrap();
    assert!(st.metrics.dwq_triggered > 0, "ST must trigger NIC deferred work");
    assert_eq!(base.metrics.dwq_triggered, 0, "baseline must not touch the DWQ");
    assert_eq!(st.metrics.bytes_wire, base.metrics.bytes_wire, "same traffic either way");
}

/// KT variants fire their triggers from inside kernels: no extra wire
/// traffic, mid-kernel trigger actions recorded, and a cheaper control
/// path than ST (same DWQ offload, fewer stream memops).
#[test]
fn kt_variants_use_kernel_triggers() {
    let w = by_name("halo3d").unwrap();
    let kt = w.run(&ScenarioCfg::smoke("kt", 2, 1, 24)).unwrap();
    let st = w.run(&ScenarioCfg::smoke("st", 2, 1, 24)).unwrap();
    assert!(kt.metrics.kt_triggers > 0, "KT must fire mid-kernel triggers");
    assert_eq!(st.metrics.kt_triggers, 0, "ST must not");
    assert_eq!(kt.metrics.dwq_triggered, st.metrics.dwq_triggered, "same NIC offload");
    assert_eq!(kt.metrics.bytes_wire, st.metrics.bytes_wire, "same traffic either way");
    assert!(
        kt.metrics.memops_executed < st.metrics.memops_executed,
        "KT must execute fewer stream memops than ST ({} vs {})",
        kt.metrics.memops_executed,
        st.metrics.memops_executed
    );
    assert!(
        kt.time_ns <= st.time_ns,
        "KT must not be slower than ST ({} vs {} ns)",
        kt.time_ns,
        st.time_ns
    );
}

/// Every ran campaign cell except the reference variant carries the
/// baseline-relative delta, readable from both report renderings.
#[test]
fn campaign_report_has_baseline_relative_deltas() {
    let mut spec = CampaignSpec::smoke();
    spec.threads = Some(1);
    let report = run_campaign(&spec).unwrap();
    for c in report.cells.iter().filter(|c| c.summary.is_some()) {
        if c.variant == "baseline" {
            assert!(
                c.delta_vs_ref_pct.is_none(),
                "{}: reference cell must carry no delta",
                c.workload
            );
        } else {
            assert!(
                c.delta_vs_ref_pct.is_some(),
                "{}/{}: missing baseline-relative delta",
                c.workload,
                c.variant
            );
        }
    }
    assert!(report.to_markdown().contains("vs ref"));
    assert!(report.to_json().contains("\"delta_vs_ref_pct\""));
    assert!(json_parses(&report.to_json()));
}

/// Infeasible cells are rejected by configure (and later skipped by the
/// campaign), not run to a panic.
#[test]
fn configure_gates_infeasible_cells() {
    let w = by_name("allreduce").unwrap();
    assert!(w.configure(&ScenarioCfg::smoke("rdbl-st", 3, 1, 16)).is_err());
    assert!(w.configure(&ScenarioCfg::smoke("rdbl-st", 4, 1, 16)).is_ok());
    let w = by_name("incast").unwrap();
    assert!(w.configure(&ScenarioCfg::smoke("st", 1, 1, 16)).is_err());
    for name in names() {
        let w = by_name(name).unwrap();
        assert!(w.configure(&ScenarioCfg::smoke("no-such-variant", 2, 1, 16)).is_err());
    }
}

#[test]
fn grid_factorization_is_exact_and_near_cubic() {
    for n in 1..=64 {
        let (px, py, pz) = grid_for(n);
        assert_eq!(px * py * pz, n, "grid_for({n})");
        assert!(px >= py && py >= pz, "grid_for({n}) ordering");
    }
    assert_eq!(grid_for(8), (2, 2, 2));
    assert_eq!(grid_for(4), (2, 2, 1));
    assert_eq!(grid_for(7), (7, 1, 1));
    assert_eq!(grid_for(12), (3, 2, 2));
}

#[test]
fn smoke_campaign_report_is_deterministic_and_parses() {
    let mut spec = CampaignSpec::smoke();
    spec.threads = Some(1);
    let a = run_campaign(&spec).unwrap();
    assert!(a.all_ok(), "smoke campaign must validate:\n{}", a.to_markdown());
    assert_eq!(a.workloads_covered(), 2);
    assert!(a.ran_cells() >= 4, "2 workloads x 2 variants expected");
    assert!(json_parses(&a.to_json()), "JSON report must parse:\n{}", a.to_json());
    // Byte-identical across reruns and across worker-thread counts.
    spec.threads = Some(4);
    let b = run_campaign(&spec).unwrap();
    assert_eq!(a.to_json(), b.to_json(), "thread count must not change the report");
    assert_eq!(a.to_markdown(), b.to_markdown());
}

/// Campaigns skip infeasible cells (rdbl-st on 3 nodes) instead of
/// failing, and say so in the report.
#[test]
fn campaign_skips_infeasible_cells() {
    let spec = CampaignSpec {
        workloads: vec!["allreduce".into()],
        variants: vec!["rdbl-st".into()],
        elems: vec![16],
        topos: vec![(3, 1), (2, 1)],
        seeds: vec![5],
        iters: 1,
        jitter: 0.0,
        threads: Some(1),
        ..CampaignSpec::default()
    };
    let r = run_campaign(&spec).unwrap();
    assert_eq!(r.cells.len(), 2);
    assert!(r.cells[0].validation.starts_with("skipped:"), "{}", r.cells[0].validation);
    assert!(r.cells[0].summary.is_none());
    assert!(r.cells[1].summary.is_some());
    assert!(r.all_ok());
    assert!(json_parses(&r.to_json()));
}

#[test]
fn campaign_rejects_unknown_workloads_and_empty_axes() {
    let mut spec = CampaignSpec::smoke();
    spec.workloads = vec!["bogus".into()];
    assert!(run_campaign(&spec).is_err());
    let mut spec = CampaignSpec::smoke();
    spec.seeds.clear();
    assert!(run_campaign(&spec).is_err());
    let mut spec = CampaignSpec::smoke();
    spec.iters = 0;
    assert!(run_campaign(&spec).is_err());
}

#[test]
fn json_validator_accepts_and_rejects() {
    for good in [
        "{}",
        "[]",
        "null",
        "-12.5e-3",
        "\"a \\\"quoted\\\" string\"",
        "{\"a\": [1, 2.5, {\"b\": null}], \"c\": \"x\"}",
        "  { \"k\" : true }  ",
    ] {
        assert!(json_parses(good), "should parse: {good}");
    }
    for bad in [
        "",
        "{",
        "{\"a\": }",
        "[1, ]",
        "{\"a\" 1}",
        "tru",
        "1.2.3",
        "\"unterminated",
        "{} extra",
        "{'a': 1}",
    ] {
        assert!(!json_parses(bad), "should NOT parse: {bad}");
    }
}

#[test]
fn payload_values_are_small_exact_integers() {
    for r in 0..8 {
        for lane in 0..30 {
            for j in 0..100 {
                let p = super::payload(r, lane, j);
                assert!((1.0..=8191.0).contains(&p));
                assert_eq!(p, p.trunc(), "payload must be integral");
            }
        }
    }
}

/// halograph is built to drive the unexpected-message path: every
/// variant — host, ST, and the KT path whose receives are NIC
/// triggered-receive descriptors — must see unexpected arrivals AND
/// still validate exactly.
#[test]
fn halograph_drives_the_unexpected_path_on_every_variant() {
    let w = by_name("halograph").unwrap();
    for variant in ["baseline", "st", "st-shader", "kt"] {
        let cfg = ScenarioCfg::smoke(variant, 2, 1, 24);
        let r = w.run(&cfg).unwrap_or_else(|e| panic!("halograph::{variant}: {e}"));
        match r.validation {
            Validation::Passed { checked } => assert!(checked > 0),
            other => panic!("halograph::{variant}: expected Passed, got {other:?}"),
        }
        assert!(
            r.metrics.unexpected_msgs > 0,
            "halograph::{variant}: the skewed arrival order must produce unexpected messages"
        );
    }
}

/// Under KT, halograph receives ride NIC triggered-receive descriptors
/// (no progress thread on the receive path); under ST they stay
/// progress-emulated — the paper-faithful contrast.
#[test]
fn halograph_kt_receives_are_nic_posted() {
    let w = by_name("halograph").unwrap();
    let kt = w.run(&ScenarioCfg::smoke("kt", 2, 1, 24)).unwrap();
    let st = w.run(&ScenarioCfg::smoke("st", 2, 1, 24)).unwrap();
    assert!(kt.metrics.triggered_recvs > 0, "KT receives must be NIC-posted");
    assert_eq!(st.metrics.triggered_recvs, 0, "ST receives stay progress-emulated");
    assert!(st.metrics.progress_ops > 0, "the ST emulation runs on the progress thread");
    assert_eq!(
        kt.metrics.bytes_wire, st.metrics.bytes_wire,
        "same traffic under either receive story"
    );
    assert!(
        kt.metrics.memops_executed < st.metrics.memops_executed,
        "KT executes fewer stream memops than ST"
    );
}

/// The per-queue report split is consistent: for every ran cell that
/// observes its queues, per-slot DWQ waits sum to the aggregated
/// metric, and the slot list matches the queues-per-rank axis.
#[test]
fn per_queue_split_sums_to_the_aggregate() {
    // ST only: a KT round arms every slot's ops before its carrying
    // kernel is enqueued, so KT cannot run with per-round demand above
    // the slot capacity (DESIGN.md §Triggered receives).
    let spec = CampaignSpec {
        workloads: vec!["halo3d".into()],
        variants: vec!["st".into()],
        elems: vec![32],
        topos: vec![(4, 1)],
        queues: vec![2],
        seeds: vec![5],
        iters: 2,
        jitter: 0.0,
        dwq_slots: Some(2),
        threads: Some(1),
        ..CampaignSpec::default()
    };
    let report = run_campaign(&spec).unwrap();
    assert!(report.all_ok(), "{}", report.to_markdown());
    let mut saw_waits = false;
    assert!(report.ran_cells() > 0);
    for c in report.cells.iter().filter(|c| c.summary.is_some()) {
        assert_eq!(c.per_queue.len(), 2, "{}/{}: one row per queue slot", c.workload, c.variant);
        let wait_sum: u64 = c.per_queue.iter().map(|q| q.dwq_slot_waits).sum();
        assert_eq!(
            wait_sum, c.dwq_slot_waits,
            "{}/{}: per-queue waits must sum to the aggregate",
            c.workload, c.variant
        );
        saw_waits |= wait_sum > 0;
        assert!(c.per_queue.iter().map(|q| q.dwq_posts).sum::<u64>() > 0);
    }
    assert!(saw_waits, "dwq_slots=2 must provoke at least one per-queue stall");
    assert!(report.to_json().contains("\"dwq_queues\""));
    assert!(json_parses(&report.to_json()));
}

/// The pinned KT tight-DWQ stress cell: kernel-triggered pre-armed
/// demand above `dwq_slots_per_nic` cannot make progress (a KT round
/// arms every descriptor before its carrying kernel enqueues, so no
/// trigger can ever free a slot). The campaign must fail fast with a
/// `stalled` row whose report names the exhausted pool — never a silent
/// hang, never a sweep abort.
#[test]
fn kt_tight_dwq_cell_stalls_with_a_report_naming_the_pool() {
    let mut spec = CampaignSpec::kt_tight_dwq();
    spec.threads = Some(1);
    let report = run_campaign(&spec).expect("a stalled cell is a row, not a sweep abort");
    let cell = report
        .cells
        .iter()
        .find(|c| c.stalls > 0)
        .expect("the tight-DWQ cell must record a stall");
    assert!(cell.validation.starts_with("STALLED:"), "{}", cell.validation);
    let rep = cell.stall_report.as_ref().expect("stalled cells carry the full report");
    assert!(
        rep.contains("stx DWQ slot") && rep.contains("exhausted"),
        "the report must name the exhausted slot pool:\n{rep}"
    );
    assert!(!report.all_ok(), "a stalled cell is not ok");
    assert!(report.to_json().contains("\"status\": \"stalled\""));
    assert!(json_parses(&report.to_json()), "{}", report.to_json());
    // Determinism: the stall diagnosis itself replays byte-identically.
    let rerun = run_campaign(&spec).unwrap();
    assert_eq!(report.to_json(), rerun.to_json());
}

/// broadcast propagates the root payload down a binomial tree: every
/// variant exact-validates on a non-power-of-two world (so some ranks
/// have no children and the last round is partial), and the sequential
/// relay chain rejects queue striping at configure time.
#[test]
fn broadcast_tree_validates_on_non_power_of_two_worlds() {
    let w = by_name("broadcast").unwrap();
    for variant in ["baseline", "st", "st-shader", "kt"] {
        // 3 nodes x 1 rank: rounds ⌈log2 3⌉ = 2, rank 2's receive edge
        // comes from the tree's second round.
        let cfg = ScenarioCfg::smoke(variant, 3, 1, 24);
        let r = w.run(&cfg).unwrap_or_else(|e| panic!("broadcast::{variant}: {e}"));
        match r.validation {
            Validation::Passed { checked } => {
                assert_eq!(checked, 3 * 24, "broadcast::{variant} must check every element")
            }
            other => panic!("broadcast::{variant}: expected Passed, got {other:?}"),
        }
        assert!(r.time_ns > 0);
    }
    assert!(w.configure(&ScenarioCfg::smoke("st", 2, 2, 24)).is_err(), "qpr>1 must be rejected");
    assert!(w.configure(&ScenarioCfg::smoke("st", 1, 1, 24)).is_err(), "needs two ranks");
}

/// The broadcast tree is latency-bound: ST offloads the relay to the
/// NIC (DWQ triggers fire), KT additionally fires from inside kernels,
/// and wire traffic is identical across variants (n-1 receive edges).
#[test]
fn broadcast_st_and_kt_ride_the_triggered_path() {
    let w = by_name("broadcast").unwrap();
    let base = w.run(&ScenarioCfg::smoke("baseline", 4, 1, 24)).unwrap();
    let st = w.run(&ScenarioCfg::smoke("st", 4, 1, 24)).unwrap();
    let kt = w.run(&ScenarioCfg::smoke("kt", 4, 1, 24)).unwrap();
    assert!(st.metrics.dwq_triggered > 0, "ST broadcast must trigger NIC deferred work");
    assert_eq!(base.metrics.dwq_triggered, 0, "baseline must not touch the DWQ");
    assert!(kt.metrics.kt_triggers > 0, "KT broadcast must fire mid-kernel triggers");
    assert_eq!(st.metrics.bytes_wire, base.metrics.bytes_wire, "same tree either way");
    assert_eq!(kt.metrics.bytes_wire, st.metrics.bytes_wire, "same tree either way");
}

/// The chaos smoke campaign ({drop, dup, delay, trigger-delay,
/// straggler} everywhere): every cell either exact-validates after
/// watchdog recovery or renders as a `stalled` row — and the chaos
/// report is byte-identical across reruns and thread counts.
#[test]
fn chaos_smoke_campaign_recovers_or_stalls_and_is_deterministic() {
    let mut spec = CampaignSpec::chaos_smoke(29);
    spec.threads = Some(1);
    let a = run_campaign(&spec).expect("chaos must not abort the sweep");
    assert!(a.ran_cells() > 0 || a.cells.iter().any(|c| c.stalls > 0));
    let mut saw_faults = false;
    for c in &a.cells {
        if c.stalls > 0 {
            assert!(c.stall_report.is_some(), "{}/{}: stalled without report", c.workload, c.variant);
            continue;
        }
        if c.summary.is_some() {
            assert!(
                c.ok,
                "{}/{}: chaos cells must exact-validate after recovery: {}",
                c.workload, c.variant, c.validation
            );
            saw_faults |= c.faults_injected > 0;
        }
    }
    assert!(saw_faults, "the chaos preset must actually inject faults:\n{}", a.to_markdown());
    assert!(json_parses(&a.to_json()));
    spec.threads = Some(4);
    let b = run_campaign(&spec).unwrap();
    assert_eq!(a.to_json(), b.to_json(), "chaos report must not depend on thread count");
    assert_eq!(a.to_markdown(), b.to_markdown());
}
