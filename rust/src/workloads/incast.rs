//! `incast` workload: N→1 hotspot stress — every non-root rank sends an
//! `elems`-sized message to rank 0 each iteration, hammering the root
//! node's NIC ingress port (the store-and-forward busy-until
//! serialization `fabric::transfer` models and the fabric contention
//! tests pin down).
//!
//! The campaign report surfaces the congestion directly through the
//! per-workload wire metrics: `max_ingress_wait_ns` grows with the
//! sender count while `max_egress_wait_ns` stays near zero — the
//! signature of an incast hotspot (vs the alltoall pattern, which loads
//! both port directions).
//!
//! Senders record their one-message pattern in a
//! [`crate::stx::CommPlan`] built once; the root runs a plain receive
//! loop. Validation is exact: the root's slot for sender `s` must hold
//! `payload(s, 0, j)` after the final iteration.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::run_cluster;
use crate::gpu::{stream_synchronize, KernelPayload, KernelSpec};
use crate::mpi::{self, SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::world::ComputeMode;

use super::scaffold::{check_exact, lease_world, scenario_run, RankComm, Timers};
use super::{comm_variant, payload, ScenarioCfg, ScenarioRun, Workload};

pub struct Incast;

const ROOT: usize = 0;
const INCAST_TAG: i32 = 900;

impl Workload for Incast {
    fn name(&self) -> &'static str {
        "incast"
    }

    fn description(&self) -> &'static str {
        "N->1 hotspot stressing the root NIC ingress port's busy-until serialization"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "st", "st-shader", "kt", "gi"]
    }

    fn default_elems(&self) -> &'static [usize] {
        &[256, 4096, 65536]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        comm_variant("incast", &cfg.variant)?;
        if cfg.world_size() < 2 {
            bail!("incast needs at least one sender besides the root");
        }
        if cfg.elems == 0 {
            bail!("incast: messages must carry at least one element");
        }
        // One message per sender per iteration: extra queues would sit
        // idle, so q>1 cells would be misleading — reject them (the
        // campaign reports the cells as skipped).
        if cfg.queues_per_rank != 1 {
            bail!("incast: senders post a single message, which cannot stripe over queues");
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let variant = comm_variant("incast", &cfg.variant)?;
        let n = cfg.world_size();
        let elems = cfg.elems;

        let mut world = lease_world("incast", cfg);
        world.compute = ComputeMode::Real;
        // Root sink: one slot per sender (senders 1..n land at slot s-1).
        let sink = world.bufs.alloc((n - 1) * elems);
        let send: Vec<_> = (0..n).map(|_| world.bufs.alloc(elems)).collect();
        let images: Arc<Vec<Vec<f32>>> =
            Arc::new((0..n).map(|r| (0..elems).map(|j| payload(r, 0, j)).collect()).collect());

        let times = Timers::new(n);
        let (iters, qpr) = (cfg.iters, cfg.queues_per_rank);
        let (send2, images2, times2) = (send.clone(), images.clone(), times.clone());
        let out = run_cluster(world, cfg.seed, move |rank, ctx| {
            if rank == ROOT {
                // The root only receives — no stream, no queue, no plan.
                let t0 = ctx.now();
                for _iter in 0..iters {
                    let mut rreqs = Vec::with_capacity(n - 1);
                    for s in 1..n {
                        rreqs.push(mpi::irecv(
                            ctx,
                            rank,
                            SrcSel::Rank(s),
                            TagSel::Tag(INCAST_TAG),
                            COMM_WORLD,
                            BufSlice::new(sink, (s - 1) * elems, elems),
                        ));
                    }
                    mpi::waitall(ctx, &rreqs);
                }
                times2.record(rank, ctx.now() - t0);
                return;
            }
            // Sender: stream/queue setup and the one-send plan, both
            // outside the timed region (matches halo3d and alltoall, so
            // the baseline-vs-ST contrast is not skewed by setup cost).
            let comm = RankComm::new(ctx, rank, variant, qpr);
            let sb = send2[rank];
            let mut b = comm.builder();
            b.send(ROOT, BufSlice::whole(sb, elems), INCAST_TAG, COMM_WORLD);
            let cplan = b.build(ctx).expect("incast plan build");

            let t0 = ctx.now();
            for _iter in 0..iters {
                // Pack kernel refreshes the outgoing message (image by
                // Arc, not by per-iteration clone).
                let images_k = images2.clone();
                let pack = KernelSpec {
                    name: "incast_pack".into(),
                    flops: 0,
                    bytes: 2 * 4 * elems as u64,
                    payload: KernelPayload::Fn(Box::new(move |w, _| {
                        w.bufs.get_mut(sb)[..elems].copy_from_slice(&images_k[rank]);
                    })),
                };
                let round = cplan.round(ctx, vec![pack]).expect("incast round");
                cplan.complete(ctx, round).expect("incast complete");
                // The host round already ended synchronized (Fig-1 sync
                // before its isend); ST/KT drain the stream here.
                if variant.uses_queue() {
                    stream_synchronize(ctx, comm.sid);
                }
            }
            // KT drains the final send completion inside the timed
            // region (ST already waited via the stream).
            comm.drain_if_kt(ctx, &cplan, "incast");
            // Stop the clock before queue teardown (outside the timed
            // region, like halo3d/alltoall).
            times2.record(rank, ctx.now() - t0);
            comm.finish(ctx, "incast");
        })
        .context("incast run failed")?;

        let got = out.world.bufs.get(sink);
        let pairs = (1..n)
            .flat_map(|s| (0..elems).map(move |j| (got[(s - 1) * elems + j], payload(s, 0, j))));
        let validation = check_exact(pairs, |i| {
            format!("incast root slot for sender {} elem {}", 1 + i / elems, i % elems)
        });
        Ok(scenario_run("incast", cfg, out, &times, validation))
    }
}
