//! `incast` workload: N→1 hotspot stress — every non-root rank sends an
//! `elems`-sized message to rank 0 each iteration, hammering the root
//! node's NIC ingress port (the store-and-forward busy-until
//! serialization `fabric::transfer` models and the fabric contention
//! tests pin down).
//!
//! The campaign report surfaces the congestion directly through the
//! per-workload wire metrics: `max_ingress_wait_ns` grows with the
//! sender count while `max_egress_wait_ns` stays near zero — the
//! signature of an incast hotspot (vs the alltoall pattern, which loads
//! both port directions).
//!
//! Validation is exact: the root's slot for sender `s` must hold
//! `payload(s, 0, j)` after the final iteration.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{build_world, run_cluster};
use crate::gpu::{self, host_enqueue, stream_synchronize, KernelPayload, KernelSpec, StreamOp};
use crate::mpi::{self, SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::stx::{self, Variant};
use crate::world::ComputeMode;

use super::{comm_variant, payload, ScenarioCfg, ScenarioRun, Validation, Workload};

pub struct Incast;

const ROOT: usize = 0;
const INCAST_TAG: i32 = 900;

impl Workload for Incast {
    fn name(&self) -> &'static str {
        "incast"
    }

    fn description(&self) -> &'static str {
        "N->1 hotspot stressing the root NIC ingress port's busy-until serialization"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "st", "st-shader", "kt"]
    }

    fn default_elems(&self) -> &'static [usize] {
        &[256, 4096, 65536]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        comm_variant("incast", &cfg.variant)?;
        if cfg.world_size() < 2 {
            bail!("incast needs at least one sender besides the root");
        }
        if cfg.elems == 0 {
            bail!("incast: messages must carry at least one element");
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let variant = comm_variant("incast", &cfg.variant)?;
        let n = cfg.world_size();
        let elems = cfg.elems;

        let mut world = build_world(cfg.cost.clone(), cfg.topology());
        world.compute = ComputeMode::Real;
        // Root sink: one slot per sender (senders 1..n land at slot s-1).
        let sink = world.bufs.alloc((n - 1) * elems);
        let send: Vec<_> = (0..n).map(|_| world.bufs.alloc(elems)).collect();
        let images: Arc<Vec<Vec<f32>>> =
            Arc::new((0..n).map(|r| (0..elems).map(|j| payload(r, 0, j)).collect()).collect());

        let times: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(vec![0; n]));
        let iters = cfg.iters;
        let (send2, images2, times2) = (send.clone(), images.clone(), times.clone());
        let out = run_cluster(world, cfg.seed, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            // Queue setup outside the timed region (matches halo3d and
            // alltoall, so the baseline-vs-ST contrast is not skewed by
            // one-time setup cost).
            let queue = if rank == ROOT {
                None
            } else {
                variant
                    .uses_queue()
                    .then(|| stx::create_queue(ctx, rank, sid, variant.flavor()))
            };
            let t0 = ctx.now();
            if rank == ROOT {
                for _iter in 0..iters {
                    let mut rreqs = Vec::with_capacity(n - 1);
                    for s in 1..n {
                        rreqs.push(mpi::irecv(
                            ctx,
                            rank,
                            SrcSel::Rank(s),
                            TagSel::Tag(INCAST_TAG),
                            COMM_WORLD,
                            BufSlice::new(sink, (s - 1) * elems, elems),
                        ));
                    }
                    mpi::waitall(ctx, &rreqs);
                }
            } else {
                let sb = send2[rank];
                for _iter in 0..iters {
                    // Pack kernel refreshes the outgoing message (image by
                    // Arc, not by per-iteration clone).
                    let images_k = images2.clone();
                    let pack = KernelSpec {
                        name: "incast_pack".into(),
                        flops: 0,
                        bytes: 2 * 4 * elems as u64,
                        payload: KernelPayload::Fn(Box::new(move |w, _| {
                            w.bufs.get_mut(sb)[..elems].copy_from_slice(&images_k[rank]);
                        })),
                    };
                    match variant {
                        Variant::Host => {
                            host_enqueue(ctx, sid, StreamOp::Kernel(pack));
                            stream_synchronize(ctx, sid);
                            let sr = mpi::isend(
                                ctx,
                                rank,
                                ROOT,
                                BufSlice::whole(sb, elems),
                                INCAST_TAG,
                                COMM_WORLD,
                            );
                            mpi::wait(ctx, sr);
                        }
                        Variant::KernelTriggered => {
                            // KT: the previous iteration's send completion
                            // rides the pack prologue; the trigger fires
                            // from inside the pack kernel.
                            let q = queue.unwrap();
                            let mut kt = gpu::KernelCtx::new();
                            stx::kt_wait(ctx, q, &mut kt).expect("incast kt_wait");
                            stx::enqueue_send(
                                ctx,
                                q,
                                ROOT,
                                BufSlice::whole(sb, elems),
                                INCAST_TAG,
                                COMM_WORLD,
                            )
                            .expect("incast enqueue_send");
                            stx::kt_start(ctx, q, &mut kt, stx::KT_TRIGGER_FRAC)
                                .expect("incast kt_start");
                            host_enqueue(ctx, sid, StreamOp::KtKernel(pack, kt));
                            stream_synchronize(ctx, sid);
                        }
                        _ => {
                            host_enqueue(ctx, sid, StreamOp::Kernel(pack));
                            let q = queue.unwrap();
                            stx::enqueue_send(
                                ctx,
                                q,
                                ROOT,
                                BufSlice::whole(sb, elems),
                                INCAST_TAG,
                                COMM_WORLD,
                            )
                            .expect("incast enqueue_send");
                            stx::enqueue_start(ctx, q).expect("incast enqueue_start");
                            stx::enqueue_wait(ctx, q).expect("incast enqueue_wait");
                            stream_synchronize(ctx, sid);
                        }
                    }
                }
                // KT drains the final send completion inside the timed
                // region (ST already waited via enqueue_wait).
                if variant == Variant::KernelTriggered {
                    stx::queue_drain(ctx, queue.unwrap()).expect("incast queue drain");
                }
            }
            // Stop the clock before queue teardown (outside the timed
            // region, like halo3d/alltoall).
            let dt = ctx.now() - t0;
            if let Some(q) = queue {
                stx::free_queue(ctx, q).expect("incast queue idle at teardown");
            }
            times2.lock().unwrap()[rank] = dt;
        })
        .map_err(|e| anyhow!("incast run failed: {e}"))?;

        let mut validation = Validation::Passed { checked: (n - 1) * elems };
        let got = out.world.bufs.get(sink);
        'outer: for s in 1..n {
            for j in 0..elems {
                let expect = payload(s, 0, j);
                if got[(s - 1) * elems + j] != expect {
                    validation = Validation::Failed {
                        detail: format!(
                            "root slot for sender {s} elem {j}: {} != {expect}",
                            got[(s - 1) * elems + j]
                        ),
                    };
                    break 'outer;
                }
            }
        }

        let rank_time = times.lock().unwrap().clone();
        Ok(ScenarioRun {
            time_ns: rank_time.iter().copied().max().unwrap_or(0),
            metrics: out.world.metrics.clone(),
            stats: out.stats,
            validation,
        })
    }
}
