//! `allreduce` workload: the ST ring collective wrapped as a sweepable
//! scenario, plus the recursive-doubling ST variant and a host-driven
//! baseline ring for contrast.
//!
//! Variants:
//! * `baseline` — host-driven ring: `MPI_Irecv`/`MPI_Isend`/`MPI_Waitall`
//!   per step with a `hipStreamSynchronize` at every kernel boundary
//!   (the Fig-1 control path).
//! * `ring-st` — [`crate::collectives::ring_allreduce_st`]: every step's
//!   send/recv is stream-triggered, the host never synchronizes inside
//!   the ring.
//! * `rdbl-st` — [`crate::collectives::recursive_doubling_allreduce_st`]:
//!   log2(n) full-vector exchanges; requires a power-of-two world (the
//!   campaign skips infeasible cells via `configure`).
//! * `ring-kt` — [`crate::collectives::ring_allreduce_kt`]: the same
//!   ring schedule, kernel-triggered — each step's trigger/wait pair
//!   rides the reduction kernels themselves, with no per-step stream
//!   memory ops (arXiv 2306.15773).
//! * `ring-gi` — [`crate::collectives::ring_allreduce_gi`]: the same
//!   ring schedule, GPU-initiated — the kernels build each step's
//!   command-ring descriptors outright, with no stream memory ops at
//!   all and no DWQ slots (arXiv 2503.24230).
//!
//! The collectives drive one typed [`crate::stx::Queue`] per rank.
//! Each of the `iters` repetitions re-initializes the vector (untimed),
//! barriers so repetitions never overlap across ranks, and times one
//! allreduce + drain. Validation is exact: element j of every rank must
//! equal `sum over ranks of payload(rank, 0, j)`.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::collectives::{
    chunks, recursive_doubling_allreduce_st, ring_ag_step, ring_allreduce_gi, ring_allreduce_kt,
    ring_allreduce_st, ring_rs_step,
};
use crate::coordinator::run_cluster;
use crate::gpu::{self, host_enqueue, stream_synchronize, KernelPayload, KernelSpec, StreamOp};
use crate::mpi::{self, SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::sim::HostCtx;
use crate::stx::{Queue, Variant};
use crate::world::{BufId, ComputeMode, World};

use super::scaffold::{check_exact, lease_world, scenario_run, Timers};
use super::{payload, ScenarioCfg, ScenarioRun, Workload};

pub struct Allreduce;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    HostRing,
    RingSt,
    RdblSt,
    RingKt,
    RingGi,
}

fn mode_of(variant: &str) -> Result<Mode> {
    Ok(match variant {
        "baseline" => Mode::HostRing,
        "ring-st" => Mode::RingSt,
        "rdbl-st" => Mode::RdblSt,
        "ring-kt" => Mode::RingKt,
        "ring-gi" => Mode::RingGi,
        other => bail!("allreduce: unknown variant '{other}'"),
    })
}

/// Host-driven baseline ring: the same schedule as the ST ring, but the
/// host drives every step and synchronizes at every kernel boundary.
#[allow(clippy::too_many_arguments)]
fn ring_allreduce_host(
    ctx: &mut HostCtx<World>,
    rank: usize,
    n: usize,
    sid: gpu::StreamId,
    data: BufId,
    len: usize,
    tmp: BufId,
    comm: u16,
) {
    if n <= 1 {
        return;
    }
    let next = (rank + 1) % n;
    let prev = (rank + n - 1) % n;
    let ch = chunks(len, n);

    // Phase 1: reduce-scatter (same schedule as the ST ring, by
    // construction: both call collectives::ring_rs_step).
    for s in 0..n - 1 {
        let (send_c, recv_c, tag) = ring_rs_step(rank, n, s);
        let (soff, slen) = ch[send_c];
        let (roff, rlen) = ch[recv_c];
        let rr = mpi::irecv(
            ctx,
            rank,
            SrcSel::Rank(prev),
            TagSel::Tag(tag),
            comm,
            BufSlice::new(tmp, 0, rlen),
        );
        let sr = mpi::isend(ctx, rank, next, BufSlice::new(data, soff, slen), tag, comm);
        mpi::waitall(ctx, &[rr, sr]);
        host_enqueue(
            ctx,
            sid,
            StreamOp::Kernel(KernelSpec {
                name: format!("host_ring_acc[{s}]"),
                flops: rlen as u64,
                bytes: 3 * 4 * rlen as u64,
                payload: KernelPayload::Fn(Box::new(move |w, _| {
                    let t = w.bufs.get(tmp)[..rlen].to_vec();
                    let d = w.bufs.get_mut(data);
                    for (dst, src) in d[roff..roff + rlen].iter_mut().zip(&t) {
                        *dst += src;
                    }
                })),
            }),
        );
        // Kernel-boundary sync before the next step may send this chunk.
        stream_synchronize(ctx, sid);
    }

    // Phase 2: allgather (received chunks land in place).
    for s in 0..n - 1 {
        let (send_c, recv_c, tag) = ring_ag_step(rank, n, s);
        let (soff, slen) = ch[send_c];
        let (roff, rlen) = ch[recv_c];
        let rr = mpi::irecv(
            ctx,
            rank,
            SrcSel::Rank(prev),
            TagSel::Tag(tag),
            comm,
            BufSlice::new(data, roff, rlen),
        );
        let sr = mpi::isend(ctx, rank, next, BufSlice::new(data, soff, slen), tag, comm);
        mpi::waitall(ctx, &[rr, sr]);
    }
}

impl Workload for Allreduce {
    fn name(&self) -> &'static str {
        "allreduce"
    }

    fn description(&self) -> &'static str {
        "allreduce(sum): host ring vs ST ring vs ST recursive doubling vs KT ring vs GI ring"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "ring-st", "rdbl-st", "ring-kt", "ring-gi"]
    }

    fn default_elems(&self) -> &'static [usize] {
        &[256, 4096, 65536]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        let mode = mode_of(&cfg.variant)?;
        let n = cfg.world_size();
        if n == 0 {
            bail!("allreduce: empty world");
        }
        if cfg.elems == 0 {
            bail!("allreduce: vector must carry at least one element");
        }
        if mode == Mode::RdblSt && !n.is_power_of_two() {
            bail!("allreduce/rdbl-st: world size {n} is not a power of two");
        }
        if cfg.queues_per_rank != 1 {
            bail!("allreduce: the ring collectives drive exactly one queue per rank");
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let mode = mode_of(&cfg.variant)?;
        let n = cfg.world_size();
        let len = cfg.elems;

        let mut world = lease_world("allreduce", cfg);
        world.compute = ComputeMode::Real;
        let data: Vec<BufId> = (0..n).map(|_| world.bufs.alloc(len)).collect();
        // `tmp` sized for the recursive-doubling full-vector exchange; the
        // ring only stages ceil(len/n) elements in it.
        let tmp: Vec<BufId> = (0..n).map(|_| world.bufs.alloc(len)).collect();
        let images: Arc<Vec<Vec<f32>>> =
            Arc::new((0..n).map(|r| (0..len).map(|j| payload(r, 0, j)).collect()).collect());
        let expect: Vec<f32> =
            (0..len).map(|j| (0..n).map(|r| payload(r, 0, j)).sum()).collect();

        let times = Timers::new(n);
        let iters = cfg.iters;
        let (data2, tmp2, images2, times2) =
            (data.clone(), tmp.clone(), images.clone(), times.clone());
        let out = run_cluster(world, cfg.seed, move |rank, ctx| {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let queue = match mode {
                Mode::HostRing => None,
                Mode::RingKt => Some(
                    Queue::create(ctx, rank, sid, Variant::KernelTriggered)
                        .expect("NIC counter pool exhausted"),
                ),
                Mode::RingGi => Some(
                    Queue::create(ctx, rank, sid, Variant::GpuInitiated)
                        .expect("NIC counter pool exhausted"),
                ),
                _ => Some(
                    Queue::create(ctx, rank, sid, Variant::StreamTriggered)
                        .expect("NIC counter pool exhausted"),
                ),
            };
            let (d, t) = (data2[rank], tmp2[rank]);
            let mut acc = 0u64;
            for rep in 0..iters {
                // (Re)initialize the vector — untimed, then barrier so
                // repetitions never overlap across ranks. The image
                // travels by Arc, not by per-repetition clone.
                let images_k = images2.clone();
                ctx.with(move |w, _| {
                    w.bufs.get_mut(d)[..len].copy_from_slice(&images_k[rank]);
                });
                mpi::barrier(ctx, rank, n, COMM_WORLD, rep as u32);
                let t0 = ctx.now();
                match (mode, &queue) {
                    (Mode::HostRing, _) => {
                        ring_allreduce_host(ctx, rank, n, sid, d, len, t, COMM_WORLD)
                    }
                    (Mode::RingSt, Some(q)) => {
                        ring_allreduce_st(ctx, rank, n, q, sid, d, len, t, COMM_WORLD)
                    }
                    (Mode::RingKt, Some(q)) => {
                        ring_allreduce_kt(ctx, rank, n, q, sid, d, len, t, COMM_WORLD)
                    }
                    (Mode::RingGi, Some(q)) => {
                        ring_allreduce_gi(ctx, rank, n, q, sid, d, len, t, COMM_WORLD)
                    }
                    (Mode::RdblSt, Some(q)) => {
                        recursive_doubling_allreduce_st(
                            ctx, rank, n, q, sid, d, len, t, COMM_WORLD,
                        )
                        .expect("configure() gates on power-of-two worlds")
                    }
                    _ => unreachable!("queue exists for every queue-using mode"),
                }
                stream_synchronize(ctx, sid);
                acc += ctx.now() - t0;
            }
            if let Some(q) = queue {
                q.free(ctx).expect("allreduce queue idle at teardown");
            }
            times2.record(rank, acc);
        })
        .context("allreduce run failed")?;

        let expect_ref = &expect;
        let pairs = data.iter().flat_map(|d| {
            let got = out.world.bufs.get(*d);
            got.iter().zip(expect_ref).map(|(&g, &e)| (g, e))
        });
        let validation =
            check_exact(pairs, |i| format!("allreduce rank {} elem {}", i / len, i % len));
        Ok(scenario_run("allreduce", cfg, out, &times, validation))
    }
}
