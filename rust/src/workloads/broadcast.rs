//! `broadcast` workload: root-to-all propagation over a binomial tree —
//! the latency-bound complement of the bandwidth patterns (ring
//! allgather / reduce-scatter): ⌈log2 n⌉ dependent rounds, each rank's
//! forwarding gated on its own receive landing first.
//!
//! Tree shape (root 0): in round `k` every rank `r < 2^k` holding the
//! data sends it to `r + 2^k` (when that target exists), so rank `r > 0`
//! receives exactly once, in round `⌊log2 r⌋`, from `r - 2^⌊log2 r⌋`.
//! Each participating round is one persistent [`crate::stx::CommPlan`]
//! — a recv-only plan for the incoming edge, a send-only plan per
//! outgoing edge — processed in round order with
//! [`crate::stx::CommPlan::complete`] between them: the receive-before-
//! forward relay idiom the allgather workload established, here forming
//! a tree instead of a ring. The root's first send plan carries the pack
//! kernel that refreshes the payload every iteration.
//!
//! Validation is exact: after the final iteration every rank's buffer
//! must hold `payload(0, 0, j)` for all `j`.

use anyhow::{bail, Context, Result};

use crate::coordinator::run_cluster;
use crate::gpu::{stream_synchronize, KernelPayload, KernelSpec};
use crate::mpi::{SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::world::ComputeMode;

use super::scaffold::{check_exact, lease_world, scenario_run, RankComm, Timers};
use super::{comm_variant, payload, ScenarioCfg, ScenarioRun, Workload};

pub struct Broadcast;

const ROOT: usize = 0;
/// Tag base; one tag per tree round, disjoint from the other workloads'
/// spaces that could share a run (each workload runs its own world, but
/// disjoint bases keep traces readable).
const BC_TAG: i32 = 6000;

/// Round in which rank `r > 0` receives: the index of its highest set
/// bit (`⌊log2 r⌋`).
fn recv_round(r: usize) -> u32 {
    debug_assert!(r > 0);
    usize::BITS - 1 - r.leading_zeros()
}

impl Workload for Broadcast {
    fn name(&self) -> &'static str {
        "broadcast"
    }

    fn description(&self) -> &'static str {
        "binomial-tree broadcast: log-depth relay over per-round persistent CommPlans"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "st", "st-shader", "kt", "gi"]
    }

    fn default_elems(&self) -> &'static [usize] {
        // 65536 elems = 256 KiB: well past the eager/rendezvous
        // threshold, so the tree's relay edges exercise the RTS/Get
        // path too.
        &[256, 4096, 65536]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        comm_variant("broadcast", &cfg.variant)?;
        if cfg.world_size() < 2 {
            bail!("broadcast needs at least two ranks");
        }
        if cfg.elems == 0 {
            bail!("broadcast: the payload must carry at least one element");
        }
        // The tree is one dependency chain per rank (receive, then
        // forward): extra queues cannot be striped without breaking the
        // receive-before-forward gate, so q>1 cells are rejected (the
        // campaign reports them as skipped).
        if cfg.queues_per_rank != 1 {
            bail!("broadcast: the relay chain is sequential and cannot stripe over queues");
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let variant = comm_variant("broadcast", &cfg.variant)?;
        let n = cfg.world_size();
        let elems = cfg.elems;
        let rounds = usize::BITS - (n - 1).leading_zeros(); // ⌈log2 n⌉

        let mut world = lease_world("broadcast", cfg);
        world.compute = ComputeMode::Real;
        let bufs: Vec<_> = (0..n).map(|_| world.bufs.alloc(elems)).collect();

        let times = Timers::new(n);
        let (iters, qpr) = (cfg.iters, cfg.queues_per_rank);
        let (bufs2, times2) = (bufs.clone(), times.clone());
        let out = run_cluster(world, cfg.seed, move |rank, ctx| {
            let comm = RankComm::new(ctx, rank, variant, qpr);
            let buf = bufs2[rank];
            // Build-once: the incoming edge (ranks > 0), then one plan
            // per outgoing edge, in round order. Rank r sends in round k
            // iff it already holds the data (r < 2^k) and the target
            // exists (r + 2^k < n).
            let first_send_round = if rank == ROOT { 0 } else { recv_round(rank) + 1 };
            let recv_plan = (rank != ROOT).then(|| {
                let k = recv_round(rank);
                let parent = rank - (1 << k);
                let mut b = comm.builder();
                b.recv_deferred(
                    SrcSel::Rank(parent),
                    TagSel::Tag(BC_TAG + k as i32),
                    COMM_WORLD,
                    BufSlice::whole(buf, elems),
                )
                .expect("concrete selectors");
                b.build(ctx).expect("broadcast recv plan build")
            });
            let send_plans: Vec<_> = (first_send_round..rounds)
                .filter(|&k| rank + (1usize << k) < n)
                .map(|k| {
                    let child = rank + (1usize << k);
                    let mut b = comm.builder();
                    b.send(child, BufSlice::whole(buf, elems), BC_TAG + k as i32, COMM_WORLD);
                    b.build(ctx).expect("broadcast send plan build")
                })
                .collect();

            let t0 = ctx.now();
            for _iter in 0..iters {
                if let Some(plan) = &recv_plan {
                    let round = plan.round(ctx, Vec::new()).expect("broadcast recv round");
                    // The relay gate: the forwarding sends below must
                    // not start until the payload has landed.
                    plan.complete(ctx, round).expect("broadcast recv complete");
                }
                for (s, plan) in send_plans.iter().enumerate() {
                    // The root's first outgoing edge rides the pack
                    // kernel that refreshes the payload; every other
                    // edge forwards in place.
                    let kernels = if rank == ROOT && s == 0 {
                        vec![KernelSpec {
                            name: "bc_pack".into(),
                            flops: 0,
                            bytes: 2 * 4 * elems as u64,
                            payload: KernelPayload::Fn(Box::new(move |w, _| {
                                let b = w.bufs.get_mut(buf);
                                for j in 0..elems {
                                    b[j] = payload(ROOT, 0, j);
                                }
                            })),
                        }]
                    } else {
                        Vec::new()
                    };
                    let round = plan.round(ctx, kernels).expect("broadcast send round");
                    plan.complete(ctx, round).expect("broadcast send complete");
                }
                stream_synchronize(ctx, comm.sid);
            }
            if let Some(plan) = &recv_plan {
                comm.drain_if_kt(ctx, plan, "broadcast");
            }
            for plan in &send_plans {
                comm.drain_if_kt(ctx, plan, "broadcast");
            }
            times2.record(rank, ctx.now() - t0);
            comm.finish(ctx, "broadcast");
        })
        .context("broadcast run failed")?;

        // Reference: every rank's buffer == the root's payload.
        let pairs = bufs.iter().flat_map(|b| {
            let got = out.world.bufs.get(*b);
            (0..elems).map(move |j| (got[j], payload(ROOT, 0, j)))
        });
        let validation = check_exact(pairs, |i| {
            format!("broadcast rank {} elem {}", i / elems, i % elems)
        });
        Ok(scenario_run("broadcast", cfg, out, &times, validation))
    }
}
