//! `allgather` workload: the ring's gather phase as a standalone,
//! sweepable scenario (ROADMAP backlog) — and the demonstration plug-in
//! for the stx v2 [`crate::stx::CommPlan`] build-once / start-many
//! shape: each of the n-1 ring steps is one persistent plan (send block
//! `rank-s` to `next`, deferred-receive block `rank-s-1` from `prev`,
//! landing in place) built before the timed region and re-armed every
//! iteration with zero enqueue calls.
//!
//! Per iteration: the pack kernel refreshes the rank's own block and
//! carries step 0's round; steps 1..n-1 ride device progress kernels
//! (KT) or bare trigger/wait pairs (ST) or per-step isend/waitall
//! (host). Validation is exact: slot `s` of every rank must hold
//! `payload(s, 0, j)` after the final iteration.

use anyhow::{bail, Context, Result};

use crate::coordinator::run_cluster;
use crate::gpu::{stream_synchronize, KernelPayload, KernelSpec};
use crate::mpi::{SrcSel, TagSel, COMM_WORLD};
use crate::nic::BufSlice;
use crate::world::ComputeMode;

use super::scaffold::{check_exact, lease_world, scenario_run, RankComm, Timers};
use super::{comm_variant, payload, ScenarioCfg, ScenarioRun, Workload};

pub struct Allgather;

/// Tag base; disjoint from the ring collective's 1000/2000/3000 spaces.
const AG_TAG: i32 = 4000;

impl Workload for Allgather {
    fn name(&self) -> &'static str {
        "allgather"
    }

    fn description(&self) -> &'static str {
        "ring allgather (the ring's gather phase), persistent per-step CommPlans"
    }

    fn variants(&self) -> &'static [&'static str] {
        &["baseline", "st", "st-shader", "kt", "gi"]
    }

    fn default_elems(&self) -> &'static [usize] {
        &[256, 4096, 65536]
    }

    fn configure(&self, cfg: &ScenarioCfg) -> Result<()> {
        comm_variant("allgather", &cfg.variant)?;
        if cfg.world_size() < 2 {
            bail!("allgather needs at least two ranks");
        }
        if cfg.elems == 0 {
            bail!("allgather: blocks must carry at least one element");
        }
        if cfg.queues_per_rank == 0 {
            bail!("allgather: at least one queue per rank");
        }
        // Each ring step is one single-send plan; plans rotate over the
        // queue set, so multi-queue runs need at least as many steps as
        // queues or the extra queues would sit idle.
        if cfg.queues_per_rank > 1 && cfg.world_size() - 1 < cfg.queues_per_rank {
            bail!(
                "allgather: {} queues per rank need at least {} ranks (one ring step per queue)",
                cfg.queues_per_rank,
                cfg.queues_per_rank + 1
            );
        }
        Ok(())
    }

    fn run(&self, cfg: &ScenarioCfg) -> Result<ScenarioRun> {
        self.configure(cfg)?;
        let variant = comm_variant("allgather", &cfg.variant)?;
        let n = cfg.world_size();
        let elems = cfg.elems;

        let mut world = lease_world("allgather", cfg);
        world.compute = ComputeMode::Real;
        // Per rank: the gathered vector (n blocks); block `rank` is its
        // own contribution, written by the pack kernel each iteration.
        let all: Vec<_> = (0..n).map(|_| world.bufs.alloc(n * elems)).collect();

        let times = Timers::new(n);
        let (iters, qpr) = (cfg.iters, cfg.queues_per_rank);
        let (all2, times2) = (all.clone(), times.clone());
        let out = run_cluster(world, cfg.seed, move |rank, ctx| {
            let comm = RankComm::new(ctx, rank, variant, qpr);
            let buf = all2[rank];
            let next = (rank + 1) % n;
            let prev = (rank + n - 1) % n;
            // Build-once: one persistent plan per ring step. Step s
            // relays block (rank - s) onward and lands block
            // (rank - s - 1) in place.
            let steps: Vec<_> = (0..n - 1)
                .map(|s| {
                    let send_b = (rank + n - s) % n;
                    let recv_b = (rank + n - s - 1) % n;
                    let tag = AG_TAG + s as i32;
                    let mut b = comm.builder();
                    b.send(next, BufSlice::new(buf, send_b * elems, elems), tag, COMM_WORLD);
                    b.recv_deferred(
                        SrcSel::Rank(prev),
                        TagSel::Tag(tag),
                        COMM_WORLD,
                        BufSlice::new(buf, recv_b * elems, elems),
                    )
                    .expect("concrete selectors");
                    b.build(ctx).expect("allgather plan build")
                })
                .collect();

            let t0 = ctx.now();
            for _iter in 0..iters {
                for (s, plan) in steps.iter().enumerate() {
                    // Step 0 rides the pack kernel that refreshes this
                    // rank's own block; later steps need no producer.
                    let kernels = if s == 0 {
                        vec![KernelSpec {
                            name: "ag_pack".into(),
                            flops: 0,
                            bytes: 2 * 4 * elems as u64,
                            payload: KernelPayload::Fn(Box::new(move |w, _| {
                                let b = w.bufs.get_mut(buf);
                                for j in 0..elems {
                                    b[rank * elems + j] = payload(rank, 0, j);
                                }
                            })),
                        }]
                    } else {
                        Vec::new()
                    };
                    let round = plan.round(ctx, kernels).expect("allgather round");
                    plan.complete(ctx, round).expect("allgather complete");
                }
                stream_synchronize(ctx, comm.sid);
            }
            for plan in &steps {
                comm.drain_if_kt(ctx, plan, "allgather");
            }
            times2.record(rank, ctx.now() - t0);
            comm.finish(ctx, "allgather");
        })
        .context("allgather run failed")?;

        // Reference: block s of every rank == payload(s, 0, j).
        let pairs = all.iter().flat_map(|b| {
            let got = out.world.bufs.get(*b);
            (0..n)
                .flat_map(move |s| (0..elems).map(move |j| (got[s * elems + j], payload(s, 0, j))))
        });
        let validation = check_exact(pairs, |i| {
            let (r, s, j) = (i / (n * elems), (i / elems) % n, i % elems);
            format!("allgather rank {r} block {s} elem {j}")
        });
        Ok(scenario_run("allgather", cfg, out, &times, validation))
    }
}
