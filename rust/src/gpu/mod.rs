//! Simulated GPU: streams, control processor, stream memory operations.
//!
//! Models the GPU contract the paper builds on (§II-B, §II-D):
//!
//! * a **stream** is a FIFO queue of device operations; operations on one
//!   stream execute in order, streams are asynchronous w.r.t. each other;
//! * the **GPU control processor (CP)** pops stream operations and
//!   executes them: compute kernels, `writeValue64` (write a 64-bit word
//!   visible to the NIC — the ST *trigger*), `waitValue64` (stall the
//!   stream until a 64-bit word reaches a value — the ST *completion
//!   wait*);
//! * stream memory ops come in two flavors ([`MemOpFlavor`]): the stock
//!   HIP implementation and the hand-coded shader variant of §V-F.
//!
//! Kernel *numerics* are real: a kernel's payload either runs an
//! AOT-compiled XLA executable (via [`crate::runtime`]) or a built-in
//! closure over simulated device buffers. Kernel *timing* always comes
//! from the cost model's roofline (`flops`, `bytes`).

use std::collections::VecDeque;

use crate::costmodel::MemOpFlavor;
use crate::sim::{CellId, Time};
use crate::world::{BufId, Callback, ComputeMode, Ctx, World};

/// Identifies one stream on one GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StreamId {
    pub gpu: usize,
    pub stream: usize,
}

/// How a `writeValue64` mutates the target word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteMode {
    Set,
    Add,
}

/// A kernel's executable payload.
pub enum KernelPayload {
    /// Timing-only kernel (used in sweeps after numerics are validated).
    None,
    /// Built-in device function over simulated buffers.
    Fn(Box<dyn FnOnce(&mut World, &mut Ctx) + Send>),
    /// AOT-compiled XLA executable from `artifacts/`, by manifest name.
    Hlo { entry: String, inputs: Vec<BufId>, outputs: Vec<BufId> },
}

impl std::fmt::Debug for KernelPayload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelPayload::None => write!(f, "None"),
            KernelPayload::Fn(_) => write!(f, "Fn(..)"),
            KernelPayload::Hlo { entry, .. } => write!(f, "Hlo({entry})"),
        }
    }
}

/// A compute kernel enqueued on a stream.
#[derive(Debug)]
pub struct KernelSpec {
    pub name: String,
    /// Roofline characteristics used for the modeled execution time.
    pub flops: u64,
    pub bytes: u64,
    pub payload: KernelPayload,
}

/// One device operation in a stream.
pub enum StreamOp {
    Kernel(KernelSpec),
    /// `hipStreamWriteValue64`-style: write `value` to a GPU-visible word
    /// (here: an engine cell — NIC counters are mapped to these).
    WriteValue64 { cell: CellId, value: u64, mode: WriteMode, flavor: MemOpFlavor },
    /// `hipStreamWaitValue64`-style: stall the stream until `cell >=
    /// threshold`.
    WaitValue64 { cell: CellId, threshold: u64, flavor: MemOpFlavor },
    /// Internal device-side action with an explicit cost (used by the
    /// intra-node data path to model DMA engine work bound to a stream).
    Run { cost: Time, f: Callback },
}

impl std::fmt::Debug for StreamOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamOp::Kernel(k) => write!(f, "Kernel({})", k.name),
            StreamOp::WriteValue64 { value, .. } => write!(f, "WriteValue64({value})"),
            StreamOp::WaitValue64 { threshold, .. } => write!(f, "WaitValue64(>={threshold})"),
            StreamOp::Run { .. } => write!(f, "Run(..)"),
        }
    }
}

/// A GPU stream: FIFO of pending ops + CP execution state.
pub struct Stream {
    pub ops: VecDeque<StreamOp>,
    /// True while the CP is executing (or blocked on) the current op.
    pub busy: bool,
    /// Total operations ever enqueued.
    pub enqueued: u64,
    /// Cell counting completed operations (target of stream synchronize).
    pub completed_cell: CellId,
}

/// A simulated GPU device.
pub struct Gpu {
    pub node: usize,
    pub streams: Vec<Stream>,
}

impl Gpu {
    pub fn new(node: usize) -> Self {
        Self { node, streams: Vec::new() }
    }
}

/// Create a stream on `gpu`; returns its id.
pub fn create_stream(w: &mut World, core: &mut Ctx, gpu: usize) -> StreamId {
    let idx = w.gpus[gpu].streams.len();
    let completed_cell = core.new_cell(format!("gpu{gpu}.s{idx}.completed"), 0);
    w.gpus[gpu].streams.push(Stream {
        ops: VecDeque::new(),
        busy: false,
        enqueued: 0,
        completed_cell,
    });
    StreamId { gpu, stream: idx }
}

/// Enqueue a device op. The *host-side* cost of enqueueing is charged by
/// the caller (host actors use `ctx.advance(cost.kernel_enqueue)`); this
/// function only mutates device state and kicks the CP if idle. The CP
/// step runs inline (same instant, same lock scope) instead of through a
/// scheduled zero-delay event — one less event per enqueue on the hot
/// path, with identical virtual timing.
pub fn enqueue(w: &mut World, core: &mut Ctx, sid: StreamId, op: StreamOp) {
    let s = &mut w.gpus[sid.gpu].streams[sid.stream];
    s.ops.push_back(op);
    s.enqueued += 1;
    if !s.busy {
        cp_step(w, core, sid);
    }
}

/// Total ops enqueued so far (snapshot for a later synchronize).
pub fn enqueued_count(w: &World, sid: StreamId) -> u64 {
    w.gpus[sid.gpu].streams[sid.stream].enqueued
}

/// The completion-counter cell of a stream.
pub fn completed_cell(w: &World, sid: StreamId) -> CellId {
    w.gpus[sid.gpu].streams[sid.stream].completed_cell
}

/// CP state machine: start executing the head-of-queue op if idle.
pub fn cp_step(w: &mut World, core: &mut Ctx, sid: StreamId) {
    let s = &mut w.gpus[sid.gpu].streams[sid.stream];
    if s.busy {
        return;
    }
    let Some(op) = s.ops.pop_front() else { return };
    s.busy = true;
    match op {
        StreamOp::Kernel(spec) => {
            w.metrics.kernels_launched += 1;
            let dur = w.cost.cp_dispatch + w.cost.kernel_time(spec.flops, spec.bytes);
            let dur = w.cost.jittered(dur, core.rng());
            core.schedule(
                dur,
                Box::new(move |w, c| {
                    run_kernel_payload(w, c, spec.payload);
                    complete_op(w, c, sid);
                }),
            );
        }
        StreamOp::WriteValue64 { cell, value, mode, flavor } => {
            w.metrics.memops_executed += 1;
            let dur = w.cost.jittered(w.cost.memop(flavor), core.rng());
            core.schedule(
                dur,
                Box::new(move |w, c| {
                    match mode {
                        WriteMode::Set => c.write_cell(cell, value),
                        WriteMode::Add => {
                            c.add_cell(cell, value);
                        }
                    }
                    complete_op(w, c, sid);
                }),
            );
        }
        StreamOp::WaitValue64 { cell, threshold, flavor } => {
            w.metrics.memops_executed += 1;
            let dur = w.cost.jittered(w.cost.memop(flavor), core.rng());
            // Charge the memop issue cost, then wait on the cell.
            core.schedule(
                dur,
                Box::new(move |_, c| {
                    c.on_ge(
                        cell,
                        threshold,
                        format!("gpu{}.s{} waitValue64", sid.gpu, sid.stream),
                        Box::new(move |w, c| complete_op(w, c, sid)),
                    );
                }),
            );
        }
        StreamOp::Run { cost, f } => {
            core.schedule(
                cost,
                Box::new(move |w, c| {
                    f(w, c);
                    complete_op(w, c, sid);
                }),
            );
        }
    }
}

/// Execute a kernel's payload (numerics) according to the compute mode.
fn run_kernel_payload(w: &mut World, core: &mut Ctx, payload: KernelPayload) {
    match payload {
        KernelPayload::None => {}
        KernelPayload::Fn(f) => {
            if w.compute == ComputeMode::Real {
                f(w, core);
            }
        }
        KernelPayload::Hlo { entry, inputs, outputs } => {
            if w.compute == ComputeMode::Real {
                let rt = w
                    .runtime
                    .clone()
                    .expect("ComputeMode::Real with Hlo payload requires a loaded runtime");
                let in_data: Vec<Vec<f32>> =
                    inputs.iter().map(|b| w.bufs.get(*b).to_vec()).collect();
                let results = rt
                    .execute_f32(&entry, &in_data)
                    .unwrap_or_else(|e| panic!("HLO kernel '{entry}' failed: {e}"));
                assert_eq!(
                    results.len(),
                    outputs.len(),
                    "HLO '{entry}' returned {} outputs, expected {}",
                    results.len(),
                    outputs.len()
                );
                for (out_buf, data) in outputs.iter().zip(results) {
                    let dst = w.bufs.get_mut(*out_buf);
                    assert_eq!(dst.len(), data.len(), "HLO '{entry}' output size mismatch");
                    dst.copy_from_slice(&data);
                }
            }
        }
    }
}

/// Mark the in-flight op of `sid` complete and continue with the next.
fn complete_op(w: &mut World, core: &mut Ctx, sid: StreamId) {
    let s = &mut w.gpus[sid.gpu].streams[sid.stream];
    debug_assert!(s.busy);
    s.busy = false;
    let cell = s.completed_cell;
    core.add_cell(cell, 1);
    cp_step(w, core, sid);
}

// ---------------------------------------------------------------------
// Host-facing helpers (called from host actors, charging host-side costs)
// ---------------------------------------------------------------------

/// Host-side enqueue of a device op (charges the HIP enqueue cost).
pub fn host_enqueue(hctx: &mut crate::sim::HostCtx<World>, sid: StreamId, op: StreamOp) {
    let cost = hctx.with(|w, _| w.cost.kernel_enqueue);
    hctx.advance(cost);
    hctx.with(move |w, core| enqueue(w, core, sid, op));
}

/// `hipStreamSynchronize`: block the host until every op enqueued on the
/// stream so far has completed. This is the expensive kernel-boundary
/// synchronization point the ST design removes (paper Fig. 1 vs Fig. 2).
pub fn stream_synchronize(hctx: &mut crate::sim::HostCtx<World>, sid: StreamId) {
    let (cell, target, sync_cost) = hctx.with(|w, _| {
        w.metrics.stream_syncs += 1;
        (completed_cell(w, sid), enqueued_count(w, sid), w.cost.stream_sync)
    });
    hctx.advance(sync_cost);
    hctx.wait_ge(cell, target, "hipStreamSynchronize");
}

/// Intra-node DMA copy between device buffers (ROCr-IPC/xGMI path): moves
/// the payload after the modeled transfer time, then runs `done`.
pub fn dma_copy(
    w: &mut World,
    core: &mut Ctx,
    src: BufId,
    src_off: usize,
    dst: BufId,
    dst_off: usize,
    elems: usize,
    done: Callback,
) {
    let bytes = elems * 4;
    w.metrics.bytes_ipc += bytes as u64;
    let dur = w.cost.jittered(w.cost.ipc_time(bytes), core.rng());
    core.schedule(
        dur,
        Box::new(move |w, c| {
            if w.is_real() {
                w.bufs.copy(src, src_off, dst, dst_off, elems);
            }
            done(w, c);
        }),
    );
}

#[cfg(test)]
mod tests;
