//! Simulated GPU: streams, control processor, stream memory operations.
//!
//! Models the GPU contract the paper builds on (§II-B, §II-D):
//!
//! * a **stream** is a FIFO queue of device operations; operations on one
//!   stream execute in order, streams are asynchronous w.r.t. each other;
//! * the **GPU control processor (CP)** pops stream operations and
//!   executes them: compute kernels, `writeValue64` (write a 64-bit word
//!   visible to the NIC — the ST *trigger*), `waitValue64` (stall the
//!   stream until a 64-bit word reaches a value — the ST *completion
//!   wait*);
//! * stream memory ops come in two flavors ([`MemOpFlavor`]): the stock
//!   HIP implementation and the hand-coded shader variant of §V-F.
//!
//! Beyond the paper's stream-op model, this module also implements the
//! **kernel-triggered (KT)** contract of the follow-on work (arXiv
//! 2306.15773): a [`StreamOp::KtKernel`] carries a [`KernelCtx`] whose
//! hooks fire NIC deferred-work entries from *inside* the kernel's
//! execution window ([`KernelCtx::kt_counter_inc`] /
//! [`KernelCtx::kt_put`] / [`KernelCtx::kt_recv`] — the last rings the
//! doorbell with a posted-*receive* descriptor, the receive half of the
//! offload story) and fold completion waits into the kernel
//! prologue ([`KernelCtx::wait_ge`]) — no `writeValue64`/`waitValue64`
//! stream ops at all. See `stx` for the MPIX-level wrappers and
//! DESIGN.md §Kernel-triggered communication / §Triggered receives for
//! the timelines.
//!
//! Kernel *numerics* are real: a kernel's payload either runs an
//! AOT-compiled XLA executable (via [`crate::runtime`]) or a built-in
//! closure over simulated device buffers. Kernel *timing* always comes
//! from the cost model's roofline (`flops`, `bytes`).

use std::collections::VecDeque;

use crate::costmodel::MemOpFlavor;
use crate::fault::PoisonedCounter;
use crate::nic::{BufSlice, Done, Envelope};
use crate::obs::{Event, KtKind};
use crate::sim::{CellId, Time};
use crate::world::{ArmedEntry, BufId, Callback, ComputeMode, Ctx, World};

/// Identifies one stream on one GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StreamId {
    pub gpu: usize,
    pub stream: usize,
}

/// How a `writeValue64` mutates the target word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteMode {
    Set,
    Add,
}

/// A kernel's executable payload.
pub enum KernelPayload {
    /// Timing-only kernel (used in sweeps after numerics are validated).
    None,
    /// Built-in device function over simulated buffers.
    Fn(Box<dyn FnOnce(&mut World, &mut Ctx) + Send>),
    /// AOT-compiled XLA executable from `artifacts/`, by manifest name.
    Hlo { entry: String, inputs: Vec<BufId>, outputs: Vec<BufId> },
}

impl std::fmt::Debug for KernelPayload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelPayload::None => write!(f, "None"),
            KernelPayload::Fn(_) => write!(f, "Fn(..)"),
            KernelPayload::Hlo { entry, .. } => write!(f, "Hlo({entry})"),
        }
    }
}

/// A compute kernel enqueued on a stream.
#[derive(Debug)]
pub struct KernelSpec {
    pub name: String,
    /// Roofline characteristics used for the modeled execution time.
    pub flops: u64,
    pub bytes: u64,
    pub payload: KernelPayload,
}

// ---------------------------------------------------------------------
// Kernel-triggered (KT) communication: triggers fired from inside kernels
// ---------------------------------------------------------------------

/// Completion wait folded into a kernel's prologue (the KT path): the
/// kernel's first wavefront spins on a GPU-visible word until it reaches
/// `threshold`, and only then does the kernel body — and its modeled
/// duration — begin. Unlike a `waitValue64` stream op, this costs no CP
/// memory operation and occupies no extra stream slot: completion rides
/// the kernel itself.
#[derive(Debug, Clone, Copy)]
pub struct KtWait {
    pub cell: CellId,
    pub threshold: u64,
}

/// One device-side trigger fired from inside a running kernel at `frac`
/// of the kernel's modeled duration (0.0 = body start, 1.0 = kernel
/// tail; out-of-range values are clamped).
pub struct KtTrigger {
    pub frac: f64,
    pub action: KtAction,
}

/// What a mid-kernel trigger does when it retires.
pub enum KtAction {
    /// Device-scope release write: bump a GPU-visible word by `value`.
    /// In practice the word is a NIC hardware counter, so the write
    /// releases every deferred-work entry queued against it — the KT
    /// equivalent of `MPIX_Enqueue_start`'s `writeValue64`.
    CounterInc { cell: CellId, value: u64 },
    /// Device-initiated one-sided put: the kernel writes the NIC
    /// doorbell directly (the fully offloaded path of arXiv
    /// 2306.15773); the NIC executes the descriptor like any
    /// host-posted command.
    Put(KtPut),
    /// Device-initiated posted receive: the kernel rings the NIC
    /// doorbell with a receive descriptor, and the NIC's list engine
    /// appends it to the matching engine ([`crate::nic::execute_recv_post`])
    /// — the receive-side counterpart of [`KtAction::Put`]. Fired at
    /// `frac == 1.0` this is the kernel-*epilogue* hook: the last
    /// wavefront posts the receive for the next iteration's inbound data.
    PostRecv(KtRecv),
}

impl std::fmt::Debug for KtAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KtAction::CounterInc { cell, value } => write!(f, "CounterInc({cell:?}, +{value})"),
            KtAction::Put(p) => write!(f, "Put({}->{})", p.src_rank, p.dst_rank),
            KtAction::PostRecv(r) => write!(f, "PostRecv(r{} from {})", r.rank, r.src_rank),
        }
    }
}

/// Descriptor of a device-initiated put (see [`KtAction::Put`]).
pub struct KtPut {
    pub src_rank: usize,
    pub dst_rank: usize,
    pub src: BufSlice,
    pub dst: BufSlice,
    /// Fired at the source when the payload has left its NIC.
    pub src_done: Done,
    /// Fired at the destination when the payload has landed.
    pub dst_done: Done,
}

/// Descriptor of a device-initiated posted receive (see
/// [`KtAction::PostRecv`]).
pub struct KtRecv {
    /// The receiving MPI rank (owns the matching engine).
    pub rank: usize,
    /// Concrete source selector (deferred descriptors reject wildcards).
    pub src_rank: usize,
    pub tag: i32,
    pub comm: u16,
    pub dst: BufSlice,
    /// Fired when the matched payload has landed in `dst`.
    pub done: Done,
}

/// The kernel-side trigger plan attached to a [`StreamOp::KtKernel`]:
/// the hooks through which a simulated kernel drives communication from
/// *inside* its execution window instead of at stream-op boundaries.
///
/// A KT kernel's numerics commit when its body starts (after the
/// prologue wait, before any trigger retires): the engine models timing
/// independently of data movement, and a kernel's stores must be
/// globally visible before its earliest mid-kernel trigger reaches the
/// NIC.
#[derive(Default)]
pub struct KernelCtx {
    /// Completion waits folded into the kernel prologue. All must be
    /// satisfied — in registration order — before the body runs; multiple
    /// waits let one kernel drain several queues (multi-queue ranks).
    pub waits: Vec<KtWait>,
    pub triggers: Vec<KtTrigger>,
}

impl KernelCtx {
    pub fn new() -> Self {
        Self::default()
    }

    /// True when the kernel carries no KT behavior at all.
    pub fn is_empty(&self) -> bool {
        self.waits.is_empty() && self.triggers.is_empty()
    }

    /// Fold a completion wait into the kernel prologue. May be called
    /// more than once (e.g. one wait per queue of a multi-queue plan);
    /// the prologue satisfies the waits in registration order.
    pub fn wait_ge(&mut self, cell: CellId, threshold: u64) {
        self.waits.push(KtWait { cell, threshold });
    }

    /// Bump a GPU-visible counter by `value` at `frac` of the kernel's
    /// duration (device-scope release write).
    pub fn kt_counter_inc(&mut self, frac: f64, cell: CellId, value: u64) {
        self.triggers.push(KtTrigger { frac, action: KtAction::CounterInc { cell, value } });
    }

    /// Issue a device-initiated one-sided put at `frac` of the kernel's
    /// duration.
    pub fn kt_put(&mut self, frac: f64, put: KtPut) {
        self.triggers.push(KtTrigger { frac, action: KtAction::Put(put) });
    }

    /// Ring the NIC doorbell with a posted-receive descriptor at `frac`
    /// of the kernel's duration (1.0 = the epilogue: the last wavefront
    /// posts the receive for the next iteration's inbound data).
    pub fn kt_recv(&mut self, frac: f64, recv: KtRecv) {
        self.triggers.push(KtTrigger { frac, action: KtAction::PostRecv(recv) });
    }
}

impl std::fmt::Debug for KernelCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KernelCtx(waits={}, triggers={})", self.waits.len(), self.triggers.len())
    }
}

// ---------------------------------------------------------------------
// GPU-initiated (GI) communication: device-built command rings
// ---------------------------------------------------------------------

/// Payload granule one GI ring descriptor covers: a command-ring entry
/// is a fixed-size work-queue element with a bounded scatter-gather
/// reach, so device threads emit one descriptor per `GI_CHUNK_BYTES` of
/// send payload. This is what makes GI's device overhead grow with
/// message size while KT's per-message host arming cost stays flat —
/// the mechanism behind the `figgi` crossover.
pub const GI_CHUNK_BYTES: u64 = 8192;

/// Descriptor slots in one per-thread-block command ring. A producer
/// wavefront that finds the ring full stalls until the NIC consumes the
/// oldest in-flight descriptor (`Metrics::gi_ring_full_waits`).
pub const GI_RING_SLOTS: usize = 16;

/// Number of ring descriptors a send of `bytes` payload needs (at least
/// one; receives are always a single fixed-size match entry).
pub fn gi_chunks(bytes: u64) -> u64 {
    1 + bytes.saturating_sub(1) / GI_CHUNK_BYTES
}

/// What a GI descriptor chain does once the NIC has consumed its final
/// chunk (see [`crate::nic::gi_consume`]).
pub enum GiAction {
    /// Tagged send: routed by locality exactly like a fired triggered
    /// send (eager/rendezvous over the wire, IPC intra-node).
    Send {
        /// Match envelope of the message.
        env: Envelope,
        /// Source payload slice.
        src: BufSlice,
        /// Completion actions (request cell + completion counter).
        done: Done,
    },
    /// Posted receive: a fixed-size match entry handed to the NIC list
    /// engine, completion-counted in hardware like a KT doorbell recv.
    Recv(KtRecv),
}

impl std::fmt::Debug for GiAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GiAction::Send { env, .. } => {
                write!(f, "Send({}->{})", env.src_rank, env.dst_rank)
            }
            GiAction::Recv(r) => write!(f, "Recv(r{} from {})", r.rank, r.src_rank),
        }
    }
}

/// One GI message: `chunks` ring descriptors built back-to-back by the
/// kernel's closing wavefronts, whose final chunk hands `action` to the
/// NIC.
pub struct GiPost {
    /// Ring descriptors this message occupies ([`gi_chunks`]; `>= 1`).
    pub chunks: u64,
    /// What the NIC does after consuming the last chunk.
    pub action: GiAction,
}

/// The device-side descriptor plan attached to a [`StreamOp::GiKernel`]:
/// prologue completion waits (shared shape with [`KernelCtx`]) plus the
/// ordered list of messages the kernel's threads post into their
/// command ring. Descriptor builds are serial, `cost.gi_descr_build_ns`
/// apart, starting at the end of the compute window — they *extend* the
/// kernel's modeled duration, which is exactly the per-message device
/// overhead GI pays for dodging host arming and pre-armed DWQ slots.
#[derive(Default)]
pub struct GiCtx {
    /// Completion waits folded into the kernel prologue (registration
    /// order), same contract as [`KernelCtx::waits`].
    pub waits: Vec<KtWait>,
    /// Messages posted through the command ring, in order.
    pub posts: Vec<GiPost>,
}

impl GiCtx {
    /// Empty plan.
    pub fn new() -> Self {
        Self::default()
    }

    /// True when the kernel carries no GI behavior at all.
    pub fn is_empty(&self) -> bool {
        self.waits.is_empty() && self.posts.is_empty()
    }

    /// Fold a completion wait into the kernel prologue.
    pub fn wait_ge(&mut self, cell: CellId, threshold: u64) {
        self.waits.push(KtWait { cell, threshold });
    }

    /// Append one message to the descriptor plan.
    pub fn post(&mut self, post: GiPost) {
        self.posts.push(post);
    }
}

impl std::fmt::Debug for GiCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "GiCtx(waits={}, posts={})", self.waits.len(), self.posts.len())
    }
}

/// One device operation in a stream.
pub enum StreamOp {
    Kernel(KernelSpec),
    /// A compute kernel participating in kernel-triggered communication:
    /// its [`KernelCtx`] folds an optional completion wait into the
    /// kernel prologue and fires trigger actions from inside the
    /// execution window — no separate stream memory ops (the KT variant
    /// axis).
    KtKernel(KernelSpec, KernelCtx),
    /// A compute kernel participating in GPU-initiated communication:
    /// its [`GiCtx`] folds completion waits into the prologue and makes
    /// the kernel's closing wavefronts build command-ring descriptors
    /// for every recorded message, extending the kernel window by the
    /// serial build time (the GI variant axis).
    GiKernel(KernelSpec, GiCtx),
    /// `hipStreamWriteValue64`-style: write `value` to a GPU-visible word
    /// (here: an engine cell — NIC counters are mapped to these).
    WriteValue64 { cell: CellId, value: u64, mode: WriteMode, flavor: MemOpFlavor },
    /// `hipStreamWaitValue64`-style: stall the stream until `cell >=
    /// threshold`.
    WaitValue64 { cell: CellId, threshold: u64, flavor: MemOpFlavor },
    /// Internal device-side action with an explicit cost (used by the
    /// intra-node data path to model DMA engine work bound to a stream).
    Run { cost: Time, f: Callback },
}

impl std::fmt::Debug for StreamOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamOp::Kernel(k) => write!(f, "Kernel({})", k.name),
            StreamOp::KtKernel(k, kt) => write!(f, "KtKernel({}, {kt:?})", k.name),
            StreamOp::GiKernel(k, gi) => write!(f, "GiKernel({}, {gi:?})", k.name),
            StreamOp::WriteValue64 { value, .. } => write!(f, "WriteValue64({value})"),
            StreamOp::WaitValue64 { threshold, .. } => write!(f, "WaitValue64(>={threshold})"),
            StreamOp::Run { .. } => write!(f, "Run(..)"),
        }
    }
}

/// A GPU stream: FIFO of pending ops + CP execution state.
pub struct Stream {
    pub ops: VecDeque<StreamOp>,
    /// True while the CP is executing (or blocked on) the current op.
    pub busy: bool,
    /// Total operations ever enqueued.
    pub enqueued: u64,
    /// Cell counting completed operations (target of stream synchronize).
    pub completed_cell: CellId,
}

/// A simulated GPU device.
pub struct Gpu {
    pub node: usize,
    pub streams: Vec<Stream>,
}

impl Gpu {
    pub fn new(node: usize) -> Self {
        Self { node, streams: Vec::new() }
    }

    /// Rewind to the just-built state (part of
    /// [`crate::world::World::reset`]): streams hold per-run cell ids
    /// and op deques, so they are dropped; the next run re-creates them
    /// with identical indices and cell ids.
    pub fn reset(&mut self) {
        self.streams.clear();
    }
}

/// Create a stream on `gpu`; returns its id.
pub fn create_stream(w: &mut World, core: &mut Ctx, gpu: usize) -> StreamId {
    let idx = w.gpus[gpu].streams.len();
    let completed_cell = core.new_cell(format!("gpu{gpu}.s{idx}.completed"), 0);
    w.gpus[gpu].streams.push(Stream {
        ops: VecDeque::new(),
        busy: false,
        enqueued: 0,
        completed_cell,
    });
    StreamId { gpu, stream: idx }
}

/// Enqueue a device op. The *host-side* cost of enqueueing is charged by
/// the caller (host actors use `ctx.advance(cost.kernel_enqueue)`); this
/// function only mutates device state and kicks the CP if idle. The CP
/// step runs inline (same instant, same lock scope) instead of through a
/// scheduled zero-delay event — one less event per enqueue on the hot
/// path, with identical virtual timing.
pub fn enqueue(w: &mut World, core: &mut Ctx, sid: StreamId, op: StreamOp) {
    let s = &mut w.gpus[sid.gpu].streams[sid.stream];
    s.ops.push_back(op);
    s.enqueued += 1;
    if !s.busy {
        cp_step(w, core, sid);
    }
}

/// Total ops enqueued so far (snapshot for a later synchronize).
pub fn enqueued_count(w: &World, sid: StreamId) -> u64 {
    w.gpus[sid.gpu].streams[sid.stream].enqueued
}

/// The completion-counter cell of a stream.
pub fn completed_cell(w: &World, sid: StreamId) -> CellId {
    w.gpus[sid.gpu].streams[sid.stream].completed_cell
}

/// Straggler perturbation from an active fault plan: a seeded subset of
/// ranks runs kernels slower by a fixed factor (gpu index == rank in
/// `build_world`). Identity on no-fault runs — the multiplication is
/// skipped entirely so the baseline timeline is bit-for-bit unchanged.
fn straggled(w: &World, gpu: usize, dur: Time) -> Time {
    match w.fault.as_ref() {
        Some(f) => {
            let factor = f.plan.straggler_factor(gpu);
            if factor > 1.0 {
                ((dur as f64) * factor).round() as Time
            } else {
                dur
            }
        }
        None => dur,
    }
}

/// CP state machine: start executing the head-of-queue op if idle.
pub fn cp_step(w: &mut World, core: &mut Ctx, sid: StreamId) {
    let s = &mut w.gpus[sid.gpu].streams[sid.stream];
    if s.busy {
        return;
    }
    let Some(op) = s.ops.pop_front() else { return };
    s.busy = true;
    match op {
        StreamOp::Kernel(spec) => {
            w.metrics.kernels_launched += 1;
            let dur = w.cost.cp_dispatch + w.cost.kernel_time(spec.flops, spec.bytes);
            let dur = straggled(w, sid.gpu, w.cost.jittered(dur, core.rng()));
            if core.trace_on() {
                let name = core.trace_intern(&spec.name);
                core.trace_push(Event::Kernel {
                    t0: core.now(),
                    dur,
                    gpu: sid.gpu as u32,
                    stream: sid.stream as u32,
                    name,
                });
            }
            core.schedule(
                dur,
                Box::new(move |w, c| {
                    run_kernel_payload(w, c, spec.payload);
                    complete_op(w, c, sid);
                }),
            );
        }
        StreamOp::KtKernel(spec, kt) => {
            w.metrics.kernels_launched += 1;
            let dur = w.cost.cp_dispatch + w.cost.kernel_time(spec.flops, spec.bytes);
            let dur = straggled(w, sid.gpu, w.cost.jittered(dur, core.rng()));
            let desc = format!("gpu{}.s{} {} kt-prologue", sid.gpu, sid.stream, spec.name);
            let KernelCtx { waits, triggers } = kt;
            let payload = spec.payload;
            let kname = spec.name;
            let body: Callback = Box::new(move |w, c| {
                // A KT kernel's numerics commit at body start: its stores
                // must be globally visible before the earliest mid-kernel
                // trigger reaches the NIC (timing is modeled separately).
                if c.trace_on() {
                    let name = c.trace_intern(&kname);
                    c.trace_push(Event::Kernel {
                        t0: c.now(),
                        dur,
                        gpu: sid.gpu as u32,
                        stream: sid.stream as u32,
                        name,
                    });
                }
                run_kernel_payload(w, c, payload);
                for t in triggers {
                    let off = ((dur as f64) * t.frac.clamp(0.0, 1.0)).round() as Time;
                    c.schedule(
                        off.min(dur),
                        Box::new(move |w, c| fire_kt_action(w, c, t.action, sid.gpu)),
                    );
                }
                c.schedule(dur, Box::new(move |w, c| complete_op(w, c, sid)));
            });
            // Fold the prologue waits around the body, innermost last:
            // the first wavefront satisfies them in registration order.
            // The spins keep the stream busy (the kernel occupies it) but
            // cost no CP memory operations.
            let mut entry = body;
            for kw in waits.into_iter().rev() {
                let d = desc.clone();
                let inner = entry;
                entry = Box::new(move |_w, c| c.on_ge(kw.cell, kw.threshold, d, inner));
            }
            entry(w, core);
        }
        StreamOp::GiKernel(spec, gi) => {
            w.metrics.kernels_launched += 1;
            let dur = w.cost.cp_dispatch + w.cost.kernel_time(spec.flops, spec.bytes);
            let dur = straggled(w, sid.gpu, w.cost.jittered(dur, core.rng()));
            let desc = format!("gpu{}.s{} {} gi-prologue", sid.gpu, sid.stream, spec.name);
            let GiCtx { waits, posts } = gi;
            let payload = spec.payload;
            let kname = spec.name;
            let body: Callback = Box::new(move |w, c| {
                // Like a KT kernel, numerics commit at body start: the
                // stores a descriptor's payload covers must be globally
                // visible before the NIC consumes it.
                run_kernel_payload(w, c, payload);
                // The closing wavefronts build one ring descriptor per
                // chunk, serially, starting at the end of the compute
                // window — the builds EXTEND the kernel's duration. The
                // NIC consumes each descriptor nic_cmd_post + nic_proc
                // after its post, freeing the ring slot; a producer that
                // finds all GI_RING_SLOTS occupied stalls until the
                // oldest in-flight descriptor is consumed.
                let build = w.cost.gi_descr_build_ns;
                let consume = w.cost.nic_cmd_post + w.cost.nic_proc;
                let mut ring: VecDeque<Time> = VecDeque::new();
                let mut t = dur;
                for p in posts {
                    for _ in 0..p.chunks.max(1) {
                        let mut at = t + build;
                        while ring.front().is_some_and(|&ct| ct <= at) {
                            ring.pop_front();
                        }
                        if ring.len() >= GI_RING_SLOTS {
                            w.metrics.gi_ring_full_waits += 1;
                            if let Some(&front) = ring.front() {
                                at = at.max(front);
                            }
                            while ring.front().is_some_and(|&ct| ct <= at) {
                                ring.pop_front();
                            }
                        }
                        ring.push_back(at + consume);
                        t = at;
                    }
                    // The NIC picks up the chain at the final chunk's
                    // post time (gi_consume charges its own fetch
                    // latency and bumps Metrics::gi_posts).
                    let chunks = p.chunks.max(1);
                    let action = p.action;
                    c.schedule(
                        t,
                        Box::new(move |w, c| crate::nic::gi_consume(w, c, chunks, action)),
                    );
                }
                if c.trace_on() {
                    let name = c.trace_intern(&kname);
                    c.trace_push(Event::Kernel {
                        t0: c.now(),
                        dur: t,
                        gpu: sid.gpu as u32,
                        stream: sid.stream as u32,
                        name,
                    });
                }
                c.schedule(t, Box::new(move |w, c| complete_op(w, c, sid)));
            });
            // Prologue waits fold around the body exactly like a KT
            // kernel's.
            let mut entry = body;
            for kw in waits.into_iter().rev() {
                let d = desc.clone();
                let inner = entry;
                entry = Box::new(move |_w, c| c.on_ge(kw.cell, kw.threshold, d, inner));
            }
            entry(w, core);
        }
        StreamOp::WriteValue64 { cell, value, mode, flavor } => {
            w.metrics.memops_executed += 1;
            let dur = w.cost.jittered(w.cost.memop(flavor), core.rng());
            core.schedule(
                dur,
                Box::new(move |w, c| {
                    doorbell_update(w, c, cell, value, mode, sid.gpu);
                    complete_op(w, c, sid);
                }),
            );
        }
        StreamOp::WaitValue64 { cell, threshold, flavor } => {
            w.metrics.memops_executed += 1;
            let dur = w.cost.jittered(w.cost.memop(flavor), core.rng());
            // Charge the memop issue cost, then wait on the cell.
            core.schedule(
                dur,
                Box::new(move |_, c| {
                    c.on_ge(
                        cell,
                        threshold,
                        format!("gpu{}.s{} waitValue64", sid.gpu, sid.stream),
                        Box::new(move |w, c| complete_op(w, c, sid)),
                    );
                }),
            );
        }
        StreamOp::Run { cost, f } => {
            core.schedule(
                cost,
                Box::new(move |w, c| {
                    f(w, c);
                    complete_op(w, c, sid);
                }),
            );
        }
    }
}

/// Land one doorbell update on a trigger-counter cell, possibly losing
/// its low bit to an injected counter flip (see [`crate::fault`]). On a
/// flip the update lands with the bit cleared — the counter
/// *under-counts*, so waiters hang rather than fire early; the
/// shortfall is recorded as a [`PoisonedCounter`] for the stx watchdog
/// to repair, and the poison is named in the armed registry so stall
/// reports point at the exact cell. Even-valued updates have no low
/// bit to lose and consume no fault draw.
fn doorbell_update(
    w: &mut World,
    core: &mut Ctx,
    cell: CellId,
    value: u64,
    mode: WriteMode,
    gpu: usize,
) {
    let flipped =
        value & 1 == 1 && w.fault.as_mut().is_some_and(|f| f.plan.counter_flip());
    if !flipped {
        match mode {
            WriteMode::Set => core.write_cell(cell, value),
            WriteMode::Add => {
                core.add_cell(cell, value);
            }
        }
        return;
    }
    // Set-mode poisons record the absolute repair target (`lost` = 0);
    // add-mode poisons record the lost delta, which stays a safe repair
    // no matter how far later increments advance the counter.
    let (intended, lost) = match mode {
        WriteMode::Set => {
            core.write_cell(cell, value & !1);
            (value, 0)
        }
        WriteMode::Add => {
            let intended = core.cell(cell) + value;
            core.add_cell(cell, value & !1);
            (intended, 1)
        }
    };
    let token = w.armed.register(ArmedEntry {
        node: w.topo.node_of(gpu),
        queue: None,
        desc: format!(
            "POISONED trigger counter {cell:?} (lost doorbell bit): \
             threshold {intended} unreachable without repair"
        ),
    });
    w.metrics.faults_injected += 1;
    if let Some(f) = w.fault.as_mut() {
        f.poisoned.push(PoisonedCounter { cell, intended, lost, token });
    }
}

/// Retire one mid-kernel trigger action (the KT data path).
fn fire_kt_action(w: &mut World, core: &mut Ctx, action: KtAction, gpu: usize) {
    w.metrics.kt_triggers += 1;
    if core.trace_on() {
        let kind = match &action {
            KtAction::CounterInc { .. } => KtKind::CounterInc,
            KtAction::Put(_) => KtKind::Put,
            KtAction::PostRecv(_) => KtKind::Recv,
        };
        core.trace_push(Event::KtDoorbell { t: core.now(), gpu: gpu as u32, kind });
    }
    match action {
        KtAction::CounterInc { cell, value } => {
            // Device-scope release write: lands on the same engine cell
            // the NIC's deferred-work waiters watch, so it releases them
            // exactly like a CP `writeValue64` or a NIC DWQ atomic.
            doorbell_update(w, core, cell, value, WriteMode::Add, gpu);
        }
        KtAction::Put(p) => {
            // The kernel rings the NIC doorbell; command validation and
            // descriptor fetch are charged like a host-posted command.
            let lat = w.cost.nic_cmd_post + w.cost.nic_proc;
            core.schedule(
                lat,
                Box::new(move |w, c| {
                    crate::nic::execute_put(
                        w, c, p.src_rank, p.dst_rank, p.src, p.dst, p.src_done, p.dst_done,
                    );
                }),
            );
        }
        KtAction::PostRecv(r) => {
            // Doorbell + list-engine append, charged like a host-posted
            // command plus the receive-descriptor processing.
            let lat = w.cost.nic_cmd_post + w.cost.nic_proc + w.cost.nic_recv_post;
            core.schedule(
                lat,
                Box::new(move |w, c| {
                    crate::nic::execute_recv_post(
                        w, c, r.rank, r.src_rank, r.tag, r.comm, r.dst, r.done,
                    );
                }),
            );
        }
    }
}

/// Execute a kernel's payload (numerics) according to the compute mode.
fn run_kernel_payload(w: &mut World, core: &mut Ctx, payload: KernelPayload) {
    match payload {
        KernelPayload::None => {}
        KernelPayload::Fn(f) => {
            if w.compute == ComputeMode::Real {
                f(w, core);
            }
        }
        KernelPayload::Hlo { entry, inputs, outputs } => {
            if w.compute == ComputeMode::Real {
                let rt = w
                    .runtime
                    .clone()
                    .expect("ComputeMode::Real with Hlo payload requires a loaded runtime");
                let in_data: Vec<Vec<f32>> =
                    inputs.iter().map(|b| w.bufs.get(*b).to_vec()).collect();
                let results = rt
                    .execute_f32(&entry, &in_data)
                    .unwrap_or_else(|e| panic!("HLO kernel '{entry}' failed: {e}"));
                assert_eq!(
                    results.len(),
                    outputs.len(),
                    "HLO '{entry}' returned {} outputs, expected {}",
                    results.len(),
                    outputs.len()
                );
                for (out_buf, data) in outputs.iter().zip(results) {
                    let dst = w.bufs.get_mut(*out_buf);
                    assert_eq!(dst.len(), data.len(), "HLO '{entry}' output size mismatch");
                    dst.copy_from_slice(&data);
                }
            }
        }
    }
}

/// Mark the in-flight op of `sid` complete and continue with the next.
fn complete_op(w: &mut World, core: &mut Ctx, sid: StreamId) {
    let s = &mut w.gpus[sid.gpu].streams[sid.stream];
    debug_assert!(s.busy);
    s.busy = false;
    let cell = s.completed_cell;
    core.add_cell(cell, 1);
    cp_step(w, core, sid);
}

// ---------------------------------------------------------------------
// Host-facing helpers (called from host actors, charging host-side costs)
// ---------------------------------------------------------------------

/// Host-side enqueue of a device op (charges the HIP enqueue cost).
pub fn host_enqueue(hctx: &mut crate::sim::HostCtx<World>, sid: StreamId, op: StreamOp) {
    let cost = hctx.with(|w, _| w.cost.kernel_enqueue);
    hctx.advance(cost);
    hctx.with(move |w, core| enqueue(w, core, sid, op));
}

/// `hipStreamSynchronize`: block the host until every op enqueued on the
/// stream so far has completed. This is the expensive kernel-boundary
/// synchronization point the ST design removes (paper Fig. 1 vs Fig. 2).
pub fn stream_synchronize(hctx: &mut crate::sim::HostCtx<World>, sid: StreamId) {
    let (cell, target, sync_cost) = hctx.with(|w, _| {
        w.metrics.stream_syncs += 1;
        (completed_cell(w, sid), enqueued_count(w, sid), w.cost.stream_sync)
    });
    hctx.advance(sync_cost);
    hctx.wait_ge(cell, target, "hipStreamSynchronize");
}

/// Intra-node DMA copy between device buffers (ROCr-IPC/xGMI path): moves
/// the payload after the modeled transfer time, then runs `done`.
pub fn dma_copy(
    w: &mut World,
    core: &mut Ctx,
    src: BufId,
    src_off: usize,
    dst: BufId,
    dst_off: usize,
    elems: usize,
    done: Callback,
) {
    let bytes = elems * 4;
    w.metrics.bytes_ipc += bytes as u64;
    let dur = w.cost.jittered(w.cost.ipc_time(bytes), core.rng());
    core.schedule(
        dur,
        Box::new(move |w, c| {
            if w.is_real() {
                w.bufs.copy(src, src_off, dst, dst_off, elems);
            }
            done(w, c);
        }),
    );
}

#[cfg(test)]
mod tests;
