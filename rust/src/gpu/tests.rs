//! GPU simulator unit tests.

use super::*;
use crate::coordinator::build_world;
use crate::costmodel::presets;
use crate::sim::Engine;
use crate::world::Topology;

fn engine1() -> Engine<World> {
    let mut cost = presets::frontier_like();
    cost.jitter_sigma = 0.0;
    Engine::new(build_world(cost, Topology::new(1, 1)), 1)
}

fn kernel(name: &str, f: impl FnOnce(&mut World, &mut Ctx) + Send + 'static) -> StreamOp {
    StreamOp::Kernel(KernelSpec {
        name: name.into(),
        flops: 0,
        bytes: 0,
        payload: KernelPayload::Fn(Box::new(f)),
    })
}

#[test]
fn stream_ops_execute_in_fifo_order() {
    let eng = engine1();
    let order = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        for i in 0..5 {
            let ord = order.clone();
            enqueue(w, core, sid, kernel(&format!("k{i}"), move |_, _| {
                ord.lock().unwrap().push(i);
            }));
        }
    });
    eng.run().unwrap();
    assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
}

#[test]
fn kernel_time_respects_roofline() {
    let eng = engine1();
    let t_done = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let td = t_done.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        // 24e6 flops at 24000 flops/ns = 1000 ns compute.
        enqueue(
            w,
            core,
            sid,
            StreamOp::Kernel(KernelSpec {
                name: "k".into(),
                flops: 24_000_000,
                bytes: 0,
                payload: KernelPayload::Fn(Box::new(move |_, c| {
                    *td.lock().unwrap() = c.now();
                })),
            }),
        );
    });
    let (w, _) = eng.run().unwrap();
    let done = *t_done.lock().unwrap();
    let expect = w.cost.cp_dispatch + w.cost.kernel_fixed + 1000;
    assert_eq!(done, expect);
}

#[test]
fn wait_value_blocks_stream_until_write() {
    let eng = engine1();
    let ran_at = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let ra = ran_at.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        let flag = core.new_cell("flag", 0);
        enqueue(
            w,
            core,
            sid,
            StreamOp::WaitValue64 { cell: flag, threshold: 1, flavor: MemOpFlavor::Hip },
        );
        enqueue(w, core, sid, kernel("after", move |_, c| {
            *ra.lock().unwrap() = c.now();
        }));
        // External write at t=10_000 unblocks the stream.
        core.schedule(10_000, Box::new(move |_, c| c.write_cell(flag, 1)));
    });
    eng.run().unwrap();
    let t = *ran_at.lock().unwrap();
    assert!(t >= 10_000, "kernel ran at {t} before waitValue64 satisfied");
}

#[test]
fn write_value_set_and_add_modes() {
    let eng = engine1();
    let seen = std::sync::Arc::new(std::sync::Mutex::new((0u64, 0u64)));
    let sn = seen.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        let c1 = core.new_cell("c1", 5);
        enqueue(
            w,
            core,
            sid,
            StreamOp::WriteValue64 { cell: c1, value: 9, mode: WriteMode::Set, flavor: MemOpFlavor::Hip },
        );
        enqueue(
            w,
            core,
            sid,
            StreamOp::WriteValue64 { cell: c1, value: 3, mode: WriteMode::Add, flavor: MemOpFlavor::Hip },
        );
        enqueue(w, core, sid, kernel("check", move |_, core| {
            sn.lock().unwrap().0 = core.cell(c1);
        }));
    });
    eng.run().unwrap();
    assert_eq!(seen.lock().unwrap().0, 12);
}

#[test]
fn shader_memops_are_faster_than_hip() {
    fn memop_finish(flavor: MemOpFlavor) -> u64 {
        let eng = engine1();
        let t = std::sync::Arc::new(std::sync::Mutex::new(0u64));
        let tc = t.clone();
        eng.setup(|w, core| {
            let sid = create_stream(w, core, 0);
            let c = core.new_cell("c", 0);
            enqueue(w, core, sid, StreamOp::WriteValue64 { cell: c, value: 1, mode: WriteMode::Set, flavor });
            enqueue(w, core, sid, kernel("after", move |_, core| {
                *tc.lock().unwrap() = core.now();
            }));
        });
        eng.run().unwrap();
        let v = *t.lock().unwrap();
        v
    }
    assert!(memop_finish(MemOpFlavor::Shader) < memop_finish(MemOpFlavor::Hip));
}

#[test]
fn streams_are_independent() {
    let eng = engine1();
    let log = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    eng.setup(|w, core| {
        let s1 = create_stream(w, core, 0);
        let s2 = create_stream(w, core, 0);
        let flag = core.new_cell("never", 0);
        // s1 blocks forever-ish; s2 proceeds.
        enqueue(w, core, s1, StreamOp::WaitValue64 { cell: flag, threshold: 1, flavor: MemOpFlavor::Hip });
        let lg = log.clone();
        enqueue(w, core, s2, kernel("s2k", move |_, _| lg.lock().unwrap().push("s2")));
        core.schedule(100_000, Box::new(move |_, c| c.write_cell(flag, 1)));
        let lg2 = log.clone();
        enqueue(w, core, s1, kernel("s1k", move |_, _| lg2.lock().unwrap().push("s1")));
    });
    eng.run().unwrap();
    assert_eq!(*log.lock().unwrap(), vec!["s2", "s1"]);
}

#[test]
fn completed_cell_counts_ops() {
    let eng = engine1();
    let counts = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let cc = counts.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        for i in 0..3 {
            enqueue(w, core, sid, kernel(&format!("k{i}"), |_, _| {}));
        }
        let cell = completed_cell(w, sid);
        core.on_ge(cell, 3, "all-done", Box::new(move |_, core| {
            *cc.lock().unwrap() = core.cell(cell);
        }));
    });
    eng.run().unwrap();
    assert_eq!(*counts.lock().unwrap(), 3);
}

#[test]
fn modeled_mode_skips_numerics() {
    let eng = engine1();
    eng.setup(|w, _| w.compute = crate::world::ComputeMode::Modeled);
    let ran = std::sync::Arc::new(std::sync::Mutex::new(false));
    let rc = ran.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        enqueue(w, core, sid, kernel("side-effect", move |_, _| {
            *rc.lock().unwrap() = true;
        }));
    });
    let (w, _) = eng.run().unwrap();
    assert!(!*ran.lock().unwrap(), "payload must not run in Modeled mode");
    assert_eq!(w.metrics.kernels_launched, 1, "timing still charged");
}

#[test]
fn dma_copy_moves_data_and_charges_time() {
    let eng = engine1();
    let t = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let tc = t.clone();
    eng.setup(|w, core| {
        let src = w.bufs.alloc_init(vec![1.0, 2.0, 3.0, 4.0]);
        let dst = w.bufs.alloc(4);
        dma_copy(w, core, src, 1, dst, 0, 3, Box::new(move |w, core| {
            assert_eq!(&w.bufs.get(crate::world::BufId(1))[..3], &[2.0, 3.0, 4.0]);
            *tc.lock().unwrap() = core.now();
        }));
    });
    eng.run().unwrap();
    assert!(*t.lock().unwrap() > 0);
}

#[test]
fn run_op_executes_with_cost() {
    let eng = engine1();
    let t = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let tc = t.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        enqueue(w, core, sid, StreamOp::Run {
            cost: 777,
            f: Box::new(move |_, core| *tc.lock().unwrap() = core.now()),
        });
    });
    eng.run().unwrap();
    assert_eq!(*t.lock().unwrap(), 777);
}
