//! GPU simulator unit tests.

use super::*;
use crate::coordinator::build_world;
use crate::costmodel::presets;
use crate::sim::Engine;
use crate::world::Topology;

fn engine1() -> Engine<World> {
    let mut cost = presets::frontier_like();
    cost.jitter_sigma = 0.0;
    Engine::new(build_world(cost, Topology::new(1, 1)), 1)
}

fn kernel(name: &str, f: impl FnOnce(&mut World, &mut Ctx) + Send + 'static) -> StreamOp {
    StreamOp::Kernel(KernelSpec {
        name: name.into(),
        flops: 0,
        bytes: 0,
        payload: KernelPayload::Fn(Box::new(f)),
    })
}

#[test]
fn stream_ops_execute_in_fifo_order() {
    let eng = engine1();
    let order = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        for i in 0..5 {
            let ord = order.clone();
            enqueue(w, core, sid, kernel(&format!("k{i}"), move |_, _| {
                ord.lock().unwrap().push(i);
            }));
        }
    });
    eng.run().unwrap();
    assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
}

#[test]
fn kernel_time_respects_roofline() {
    let eng = engine1();
    let t_done = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let td = t_done.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        // 24e6 flops at 24000 flops/ns = 1000 ns compute.
        enqueue(
            w,
            core,
            sid,
            StreamOp::Kernel(KernelSpec {
                name: "k".into(),
                flops: 24_000_000,
                bytes: 0,
                payload: KernelPayload::Fn(Box::new(move |_, c| {
                    *td.lock().unwrap() = c.now();
                })),
            }),
        );
    });
    let (w, _) = eng.run().unwrap();
    let done = *t_done.lock().unwrap();
    let expect = w.cost.cp_dispatch + w.cost.kernel_fixed + 1000;
    assert_eq!(done, expect);
}

#[test]
fn wait_value_blocks_stream_until_write() {
    let eng = engine1();
    let ran_at = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let ra = ran_at.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        let flag = core.new_cell("flag", 0);
        enqueue(
            w,
            core,
            sid,
            StreamOp::WaitValue64 { cell: flag, threshold: 1, flavor: MemOpFlavor::Hip },
        );
        enqueue(w, core, sid, kernel("after", move |_, c| {
            *ra.lock().unwrap() = c.now();
        }));
        // External write at t=10_000 unblocks the stream.
        core.schedule(10_000, Box::new(move |_, c| c.write_cell(flag, 1)));
    });
    eng.run().unwrap();
    let t = *ran_at.lock().unwrap();
    assert!(t >= 10_000, "kernel ran at {t} before waitValue64 satisfied");
}

#[test]
fn write_value_set_and_add_modes() {
    let eng = engine1();
    let seen = std::sync::Arc::new(std::sync::Mutex::new((0u64, 0u64)));
    let sn = seen.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        let c1 = core.new_cell("c1", 5);
        enqueue(
            w,
            core,
            sid,
            StreamOp::WriteValue64 { cell: c1, value: 9, mode: WriteMode::Set, flavor: MemOpFlavor::Hip },
        );
        enqueue(
            w,
            core,
            sid,
            StreamOp::WriteValue64 { cell: c1, value: 3, mode: WriteMode::Add, flavor: MemOpFlavor::Hip },
        );
        enqueue(w, core, sid, kernel("check", move |_, core| {
            sn.lock().unwrap().0 = core.cell(c1);
        }));
    });
    eng.run().unwrap();
    assert_eq!(seen.lock().unwrap().0, 12);
}

#[test]
fn shader_memops_are_faster_than_hip() {
    fn memop_finish(flavor: MemOpFlavor) -> u64 {
        let eng = engine1();
        let t = std::sync::Arc::new(std::sync::Mutex::new(0u64));
        let tc = t.clone();
        eng.setup(|w, core| {
            let sid = create_stream(w, core, 0);
            let c = core.new_cell("c", 0);
            enqueue(w, core, sid, StreamOp::WriteValue64 { cell: c, value: 1, mode: WriteMode::Set, flavor });
            enqueue(w, core, sid, kernel("after", move |_, core| {
                *tc.lock().unwrap() = core.now();
            }));
        });
        eng.run().unwrap();
        let v = *t.lock().unwrap();
        v
    }
    assert!(memop_finish(MemOpFlavor::Shader) < memop_finish(MemOpFlavor::Hip));
}

#[test]
fn streams_are_independent() {
    let eng = engine1();
    let log = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    eng.setup(|w, core| {
        let s1 = create_stream(w, core, 0);
        let s2 = create_stream(w, core, 0);
        let flag = core.new_cell("never", 0);
        // s1 blocks forever-ish; s2 proceeds.
        enqueue(w, core, s1, StreamOp::WaitValue64 { cell: flag, threshold: 1, flavor: MemOpFlavor::Hip });
        let lg = log.clone();
        enqueue(w, core, s2, kernel("s2k", move |_, _| lg.lock().unwrap().push("s2")));
        core.schedule(100_000, Box::new(move |_, c| c.write_cell(flag, 1)));
        let lg2 = log.clone();
        enqueue(w, core, s1, kernel("s1k", move |_, _| lg2.lock().unwrap().push("s1")));
    });
    eng.run().unwrap();
    assert_eq!(*log.lock().unwrap(), vec!["s2", "s1"]);
}

#[test]
fn completed_cell_counts_ops() {
    let eng = engine1();
    let counts = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let cc = counts.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        for i in 0..3 {
            enqueue(w, core, sid, kernel(&format!("k{i}"), |_, _| {}));
        }
        let cell = completed_cell(w, sid);
        core.on_ge(cell, 3, "all-done", Box::new(move |_, core| {
            *cc.lock().unwrap() = core.cell(cell);
        }));
    });
    eng.run().unwrap();
    assert_eq!(*counts.lock().unwrap(), 3);
}

#[test]
fn modeled_mode_skips_numerics() {
    let eng = engine1();
    eng.setup(|w, _| w.compute = crate::world::ComputeMode::Modeled);
    let ran = std::sync::Arc::new(std::sync::Mutex::new(false));
    let rc = ran.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        enqueue(w, core, sid, kernel("side-effect", move |_, _| {
            *rc.lock().unwrap() = true;
        }));
    });
    let (w, _) = eng.run().unwrap();
    assert!(!*ran.lock().unwrap(), "payload must not run in Modeled mode");
    assert_eq!(w.metrics.kernels_launched, 1, "timing still charged");
}

#[test]
fn dma_copy_moves_data_and_charges_time() {
    let eng = engine1();
    let t = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let tc = t.clone();
    eng.setup(|w, core| {
        let src = w.bufs.alloc_init(vec![1.0, 2.0, 3.0, 4.0]);
        let dst = w.bufs.alloc(4);
        dma_copy(w, core, src, 1, dst, 0, 3, Box::new(move |w, core| {
            assert_eq!(&w.bufs.get(crate::world::BufId(1))[..3], &[2.0, 3.0, 4.0]);
            *tc.lock().unwrap() = core.now();
        }));
    });
    eng.run().unwrap();
    assert!(*t.lock().unwrap() > 0);
}

#[test]
fn run_op_executes_with_cost() {
    let eng = engine1();
    let t = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let tc = t.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        enqueue(w, core, sid, StreamOp::Run {
            cost: 777,
            f: Box::new(move |_, core| *tc.lock().unwrap() = core.now()),
        });
    });
    eng.run().unwrap();
    assert_eq!(*t.lock().unwrap(), 777);
}

// ---------------------------------------------------------------------
// Kernel-triggered (KT) path
// ---------------------------------------------------------------------

/// KT trigger fire time is pinned strictly *inside* the kernel's
/// execution window (start < fire < end), and fires earlier than the ST
/// counterpart, which only writes the trigger via a memop executed
/// *after* the kernel completes.
#[test]
fn kt_trigger_fires_inside_kernel_window_and_before_st() {
    let eng = engine1();
    let times = std::sync::Arc::new(std::sync::Mutex::new((0u64, 0u64, 0u64, 0u64)));
    // (kt_fire, kt_end, st_fire, payload_at)
    let tm = times.clone();
    let tm2 = times.clone();
    let tm3 = times.clone();
    eng.setup(|w, core| {
        // KT stream: one kernel with a mid-execution trigger at 0.5.
        let s_kt = create_stream(w, core, 0);
        let kt_cell = core.new_cell("kt_trig", 0);
        core.on_ge(kt_cell, 1, "watch kt", Box::new(move |_, c| {
            tm.lock().unwrap().0 = c.now();
        }));
        let mut kt = KernelCtx::new();
        kt.kt_counter_inc(0.5, kt_cell, 1);
        let tp = tm3.clone();
        enqueue(
            w,
            core,
            s_kt,
            StreamOp::KtKernel(
                KernelSpec {
                    name: "kt_k".into(),
                    flops: 24_000_000, // 1000 ns compute
                    bytes: 0,
                    payload: KernelPayload::Fn(Box::new(move |_, c| {
                        tp.lock().unwrap().3 = c.now();
                    })),
                },
                kt,
            ),
        );
        let done = completed_cell(w, s_kt);
        let tme = tm2.clone();
        core.on_ge(done, 1, "kt end", Box::new(move |_, c| {
            tme.lock().unwrap().1 = c.now();
        }));
        // ST stream (same GPU, independent): same kernel, then the
        // trigger write as a memop.
        let s_st = create_stream(w, core, 0);
        let st_cell = core.new_cell("st_trig", 0);
        let tms = times.clone();
        core.on_ge(st_cell, 1, "watch st", Box::new(move |_, c| {
            tms.lock().unwrap().2 = c.now();
        }));
        enqueue(
            w,
            core,
            s_st,
            StreamOp::Kernel(KernelSpec {
                name: "st_k".into(),
                flops: 24_000_000,
                bytes: 0,
                payload: KernelPayload::None,
            }),
        );
        enqueue(
            w,
            core,
            s_st,
            StreamOp::WriteValue64 {
                cell: st_cell,
                value: 1,
                mode: WriteMode::Set,
                flavor: MemOpFlavor::Hip,
            },
        );
    });
    let (w, _) = eng.run().unwrap();
    let (kt_fire, kt_end, st_fire, payload_at) = *times.lock().unwrap();
    let dur = w.cost.cp_dispatch + w.cost.kernel_fixed + 1000;
    assert_eq!(kt_end, dur, "kernel window end");
    assert_eq!(kt_fire, dur / 2, "trigger at frac 0.5 of the window");
    assert!(kt_fire > 0 && kt_fire < kt_end, "fire strictly inside the kernel");
    // Numerics commit at body start, before the trigger retires.
    assert_eq!(payload_at, 0, "KT payload commits at body start");
    assert!(payload_at < kt_fire);
    // ST pays the kernel, then the memop: strictly later than KT.
    assert_eq!(st_fire, dur + w.cost.memop_hip);
    assert!(kt_fire < st_fire, "KT trigger must beat the ST memop ({kt_fire} vs {st_fire})");
    assert_eq!(w.metrics.kt_triggers, 1);
}

/// A KT prologue wait stalls the kernel body (and its whole duration)
/// until the watched cell reaches the threshold, with no memop charged.
#[test]
fn kt_prologue_wait_blocks_body_until_threshold() {
    let eng = engine1();
    let t = std::sync::Arc::new(std::sync::Mutex::new((0u64, 0u64)));
    let tb = t.clone();
    let te = t.clone();
    eng.setup(|w, core| {
        let sid = create_stream(w, core, 0);
        let gate = core.new_cell("gate", 0);
        let mut kt = KernelCtx::new();
        kt.wait_ge(gate, 1);
        enqueue(
            w,
            core,
            sid,
            StreamOp::KtKernel(
                KernelSpec {
                    name: "gated".into(),
                    flops: 24_000_000,
                    bytes: 0,
                    payload: KernelPayload::Fn(Box::new(move |_, c| {
                        tb.lock().unwrap().0 = c.now();
                    })),
                },
                kt,
            ),
        );
        let done = completed_cell(w, sid);
        core.on_ge(done, 1, "gated end", Box::new(move |_, c| {
            te.lock().unwrap().1 = c.now();
        }));
        core.schedule(5_000, Box::new(move |_, c| c.write_cell(gate, 1)));
    });
    let (w, _) = eng.run().unwrap();
    let (body_at, end_at) = *t.lock().unwrap();
    assert_eq!(body_at, 5_000, "body starts when the prologue wait is satisfied");
    let dur = w.cost.cp_dispatch + w.cost.kernel_fixed + 1000;
    assert_eq!(end_at, 5_000 + dur, "duration charged after the wait");
    assert_eq!(w.metrics.memops_executed, 0, "no memop on the KT path");
}

/// `kt_put` issues a device-initiated one-sided put mid-kernel: the
/// payload lands at the destination and both completion actions fire.
#[test]
fn kt_put_moves_data_mid_kernel() {
    let mut cost = presets::frontier_like();
    cost.jitter_sigma = 0.0;
    let eng = Engine::new(build_world(cost, Topology::new(2, 1)), 1);
    let done_at = std::sync::Arc::new(std::sync::Mutex::new((0u64, 0u64)));
    let da = done_at.clone();
    let db = done_at.clone();
    eng.setup(|w, core| {
        let src = w.bufs.alloc_init(vec![7.5; 16]);
        let dst = w.bufs.alloc(16);
        let sid = create_stream(w, core, 0);
        let mut kt = KernelCtx::new();
        kt.kt_put(
            0.25,
            KtPut {
                src_rank: 0,
                dst_rank: 1,
                src: BufSlice::whole(src, 16),
                dst: BufSlice::whole(dst, 16),
                src_done: Done::call(Box::new(move |_, c| da.lock().unwrap().0 = c.now())),
                dst_done: Done::call(Box::new(move |w, c| {
                    assert_eq!(w.bufs.get(crate::world::BufId(1)), &[7.5; 16]);
                    db.lock().unwrap().1 = c.now();
                })),
            },
        );
        enqueue(
            w,
            core,
            sid,
            StreamOp::KtKernel(
                KernelSpec {
                    name: "putter".into(),
                    flops: 24_000_000,
                    bytes: 0,
                    payload: KernelPayload::None,
                },
                kt,
            ),
        );
    });
    let (w, _) = eng.run().unwrap();
    let (src_done, dst_done) = *done_at.lock().unwrap();
    assert!(src_done > 0 && dst_done > 0, "both completions must fire");
    assert_eq!(w.metrics.kt_triggers, 1);
    assert!(w.metrics.bytes_wire >= 64, "the put crossed the fabric");
}

/// `kt_recv` rings the NIC doorbell with a posted-receive descriptor at
/// the chosen fraction of the kernel window (1.0 = epilogue): an
/// arrival that beat the post resolves through the unexpected queue and
/// lands once the kernel posts the descriptor.
#[test]
fn kt_recv_posts_receive_from_kernel_epilogue() {
    let mut cost = presets::frontier_like();
    cost.jitter_sigma = 0.0;
    let eng = Engine::new(build_world(cost, Topology::new(2, 1)), 1);
    let landed = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let la = landed.clone();
    eng.setup(|w, core| {
        let src = w.bufs.alloc_init(vec![4.5; 8]);
        let dst = w.bufs.alloc(8);
        // The message arrives long before the kernel posts the receive.
        let env =
            crate::nic::Envelope { src_rank: 0, dst_rank: 1, tag: 3, comm: 0, elems: 8 };
        crate::nic::execute_send(w, core, env, BufSlice::whole(src, 8), Done::none());
        let sid = create_stream(w, core, 1);
        let mut kt = KernelCtx::new();
        kt.kt_recv(
            1.0,
            KtRecv {
                rank: 1,
                src_rank: 0,
                tag: 3,
                comm: 0,
                dst: BufSlice::whole(dst, 8),
                done: Done::call(Box::new(move |w, c| {
                    assert_eq!(w.bufs.get(crate::world::BufId(1)), &[4.5; 8]);
                    *la.lock().unwrap() = c.now();
                })),
            },
        );
        core.schedule(
            50_000,
            Box::new(move |w, c| {
                enqueue(
                    w,
                    c,
                    sid,
                    StreamOp::KtKernel(
                        KernelSpec {
                            name: "epilogue_recv".into(),
                            flops: 24_000_000,
                            bytes: 0,
                            payload: KernelPayload::None,
                        },
                        kt,
                    ),
                );
            }),
        );
    });
    let (w, _) = eng.run().unwrap();
    let t = *landed.lock().unwrap();
    assert!(t > 50_000, "landed at {t}: only after the kernel posted the descriptor");
    assert_eq!(w.metrics.unexpected_msgs, 1, "the arrival beat the doorbell post");
    assert_eq!(w.metrics.triggered_recvs, 1);
    assert_eq!(w.metrics.kt_triggers, 1);
    assert_eq!(w.metrics.memops_executed, 0, "no CP memop anywhere on the path");
}
