//! Two-sided MPI messaging layer: matching engine, requests, progress.
//!
//! Implements the MPI subset the paper's workloads need — non-blocking
//! point-to-point (`isend`/`irecv`/`wait`/`waitall`) with full tag/source
//! matching semantics (posted-receive queue + unexpected-message queue,
//! pairwise FIFO per (source, tag, comm), wildcards on the standard path) —
//! plus the per-process **asynchronous progress thread** that emulates the
//! deferred-execution features the paper's ST path lacks hardware for
//! (ST receives, and all intra-node ST traffic; paper §IV). The
//! kernel-triggered variant's receives bypass the progress thread
//! entirely: the NIC posts them into this matching engine itself
//! ([`crate::nic::post_triggered_recv`]).
//!
//! Data paths (§II-A): inter-node transfers go through the simulated NIC
//! and fabric; intra-node transfers use ROCr-IPC-style P2P DMA for large
//! payloads and a non-temporal memcpy path for small ones (§V-D).
//!
//! Request completion is a counter cell reaching 1; single-cell
//! completions ride the engine's *typed* event path
//! ([`crate::nic::Done::schedule_fire_at`]) so the per-message completion
//! costs no closure allocation, and hosts blocked in [`wait`] are woken
//! through the engine's zero-delay microtask queue.

use std::collections::{HashSet, VecDeque};

use crate::gpu;
use crate::nic::{self, BufSlice, Done, Envelope, WireMsg};
use crate::obs::Event;
use crate::sim::{HostCtx, Time};
use crate::world::{Ctx, World};

/// MPI_COMM_WORLD.
pub const COMM_WORLD: u16 = 0;
/// The duplicated world communicator used by the paper's Fig. 7 example.
pub const COMM_WORLD_DUP: u16 = 1;

/// Source selector (MPI_ANY_SOURCE supported on the standard path only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SrcSel {
    Rank(usize),
    Any,
}

/// Tag selector (MPI_ANY_TAG supported on the standard path only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TagSel {
    Tag(i32),
    Any,
}

impl SrcSel {
    fn matches(&self, rank: usize) -> bool {
        match self {
            SrcSel::Rank(r) => *r == rank,
            SrcSel::Any => true,
        }
    }
}

impl TagSel {
    fn matches(&self, tag: i32) -> bool {
        match self {
            TagSel::Tag(t) => *t == tag,
            TagSel::Any => true,
        }
    }
}

/// A pending receive in the posted queue.
pub struct PostedRecv {
    pub src: SrcSel,
    pub tag: TagSel,
    pub comm: u16,
    pub dst: BufSlice,
    pub done: Done,
}

/// Body of an unexpected (arrived-before-posted) message.
pub enum UnexpBody {
    /// Inter-node eager payload, buffered by the receiving NIC/MPI.
    Eager(Vec<f32>),
    /// Inter-node rendezvous announcement; data still at the source.
    Rts { src: BufSlice, src_node: usize, src_done: Done },
    /// Intra-node small send, buffered through the shm bounce buffer.
    IntraEager(Vec<f32>),
    /// Intra-node large send, waiting zero-copy for the receiver.
    IntraZeroCopy { src: BufSlice, src_done: Done },
}

pub struct UnexpMsg {
    pub env: Envelope,
    pub body: UnexpBody,
}

/// The per-process asynchronous progress thread (paper §IV-A2, §IV-B).
/// It is a serial resource: emulated operations queue up behind each
/// other, which is exactly the software-emulation penalty the paper
/// measures against hardware offload.
#[derive(Debug, Default)]
pub struct ProgressThread {
    pub busy_until: Time,
    pub ops_handled: u64,
}

/// Per-rank MPI process state.
pub struct Proc {
    pub rank: usize,
    pub node: usize,
    pub gpu: usize,
    pub posted: VecDeque<PostedRecv>,
    pub unexpected: VecDeque<UnexpMsg>,
    pub progress: ProgressThread,
    /// Wire sequence numbers already delivered to this rank (idempotent
    /// duplicate resolution under fault injection; empty on no-fault
    /// runs, where every message carries seq 0 = unsequenced).
    pub seen_seqs: HashSet<u64>,
}

impl Proc {
    pub fn new(rank: usize, node: usize, gpu: usize) -> Self {
        Self {
            rank,
            node,
            gpu,
            posted: VecDeque::new(),
            unexpected: VecDeque::new(),
            progress: ProgressThread::default(),
            seen_seqs: HashSet::new(),
        }
    }

    /// Rewind to the just-built state, keeping the matching-engine
    /// deque/set allocations for the next run (part of
    /// [`crate::world::World::reset`]). `posted` entries hold cell ids
    /// of the previous run's core, so they must not survive; clearing
    /// keeps capacity, which is unobservable.
    pub fn reset(&mut self) {
        self.posted.clear();
        self.unexpected.clear();
        self.progress = ProgressThread::default();
        self.seen_seqs.clear();
    }
}

/// An MPI request: completion is a cell reaching 1.
pub struct Req {
    pub done: crate::sim::CellId,
    pub cancelled: bool,
}

// ---------------------------------------------------------------------
// Progress-thread accounting
// ---------------------------------------------------------------------

/// Charge `cost` ns of progress-thread time on `rank`, serialized behind
/// whatever the thread is already doing. Returns the completion instant.
pub fn progress_charge(w: &mut World, core: &mut Ctx, rank: usize, cost: Time) -> Time {
    let cost = w.cost.jittered(cost, core.rng());
    let p = &mut w.procs[rank].progress;
    let start = core.now().max(p.busy_until);
    let end = start + cost;
    p.busy_until = end;
    p.ops_handled += 1;
    w.metrics.progress_ops += 1;
    end
}

// ---------------------------------------------------------------------
// Matching engine
// ---------------------------------------------------------------------

fn env_matches(p: &PostedRecv, env: &Envelope) -> bool {
    p.comm == env.comm && p.src.matches(env.src_rank) && p.tag.matches(env.tag)
}

/// Find-and-remove the first posted receive matching `env` (FIFO).
fn take_matching_posted(
    w: &mut World,
    core: &mut Ctx,
    rank: usize,
    env: &Envelope,
) -> Option<PostedRecv> {
    let q = &mut w.procs[rank].posted;
    let idx = q.iter().position(|p| env_matches(p, env))?;
    w.metrics.matched_posted += 1;
    core.trace_push(Event::Match { t: core.now(), rank: rank as u32, tag: env.tag });
    q.remove(idx)
}

/// Find-and-remove the first unexpected message matching the selectors.
fn take_matching_unexpected(
    w: &mut World,
    rank: usize,
    src: SrcSel,
    tag: TagSel,
    comm: u16,
) -> Option<UnexpMsg> {
    let q = &mut w.procs[rank].unexpected;
    let idx = q
        .iter()
        .position(|m| m.env.comm == comm && src.matches(m.env.src_rank) && tag.matches(m.env.tag))?;
    q.remove(idx)
}

/// Deliver an inter-node message that has arrived (and been hardware
/// tag-matched) at the destination NIC.
pub fn deliver_from_wire(w: &mut World, core: &mut Ctx, msg: WireMsg) {
    let env = *msg.env();
    let rank = env.dst_rank;
    // Idempotent duplicate resolution: sequenced eager payloads (an
    // active fault plan assigns seq != 0 at the source NIC) deliver
    // exactly once — a duplicated wire copy or a redundant watchdog
    // retransmit of an already-delivered payload is discarded here,
    // before it can touch the matching queues.
    if let WireMsg::Eager { seq, .. } = &msg {
        if *seq != 0 && !w.procs[rank].seen_seqs.insert(*seq) {
            return;
        }
    }
    match take_matching_posted(w, core, rank, &env) {
        Some(posted) => match msg {
            WireMsg::Eager { payload, .. } => {
                if w.is_real() {
                    debug_assert_eq!(payload.len(), posted.dst.elems, "eager size mismatch");
                    let d = w.bufs.get_mut(posted.dst.buf);
                    d[posted.dst.off..posted.dst.off + posted.dst.elems]
                        .copy_from_slice(&payload);
                }
                posted.done.fire(w, core);
            }
            WireMsg::Rts { src, src_node, src_done, .. } => {
                let dst_node = w.procs[rank].node;
                nic::rendezvous_get(w, core, src_node, dst_node, src, posted.dst, src_done, posted.done);
            }
        },
        None => {
            w.metrics.unexpected_msgs += 1;
            core.trace_push(Event::Unexpected { t: core.now(), rank: rank as u32, tag: env.tag });
            let body = match msg {
                WireMsg::Eager { payload, .. } => UnexpBody::Eager(payload),
                WireMsg::Rts { src, src_node, src_done, .. } => {
                    UnexpBody::Rts { src, src_node, src_done }
                }
            };
            w.procs[rank].unexpected.push_back(UnexpMsg { env, body });
        }
    }
}

/// Post a receive into the matching engine; if a matching message already
/// arrived, consume it. This is the world-level operation shared by the
/// host `MPI_Irecv` wrapper and the progress thread's emulated ST recv.
pub fn post_recv(
    w: &mut World,
    core: &mut Ctx,
    rank: usize,
    src: SrcSel,
    tag: TagSel,
    comm: u16,
    dst: BufSlice,
    done: Done,
) {
    match take_matching_unexpected(w, rank, src, tag, comm) {
        None => {
            w.procs[rank].posted.push_back(PostedRecv { src, tag, comm, dst, done });
        }
        Some(unexp) => {
            debug_assert_eq!(unexp.env.elems, dst.elems, "recv size mismatch");
            core.trace_push(Event::Match {
                t: core.now(),
                rank: rank as u32,
                tag: unexp.env.tag,
            });
            match unexp.body {
                UnexpBody::Eager(payload) | UnexpBody::IntraEager(payload) => {
                    // Copy out of the bounce buffer.
                    let dur = w.cost.jittered(w.cost.memcpy_small, core.rng());
                    core.schedule(
                        dur,
                        Box::new(move |w, core| {
                            if w.is_real() {
                                let d = w.bufs.get_mut(dst.buf);
                                d[dst.off..dst.off + dst.elems].copy_from_slice(&payload);
                            }
                            done.fire(w, core);
                        }),
                    );
                }
                UnexpBody::Rts { src, src_node, src_done } => {
                    let dst_node = w.procs[rank].node;
                    nic::rendezvous_get(w, core, src_node, dst_node, src, dst, src_done, done);
                }
                UnexpBody::IntraZeroCopy { src, src_done } => {
                    intra_zero_copy(w, core, src, dst, src_done, done);
                }
            }
        }
    }
}

/// Zero-copy intra-node transfer through the GPU P2P DMA engine: fires
/// both completions when the copy lands.
fn intra_zero_copy(
    w: &mut World,
    core: &mut Ctx,
    src: BufSlice,
    dst: BufSlice,
    src_done: Done,
    recv_done: Done,
) {
    debug_assert_eq!(src.elems, dst.elems);
    gpu::dma_copy(
        w,
        core,
        src.buf,
        src.off,
        dst.buf,
        dst.off,
        src.elems,
        Box::new(move |w, core| {
            src_done.fire(w, core);
            recv_done.fire(w, core);
        }),
    );
}

/// World-level send: routes to the NIC (inter-node) or the intra-node
/// IPC/memcpy path. Shared by host `MPI_Isend` and ST emulation.
pub fn do_send(w: &mut World, core: &mut Ctx, env: Envelope, src: BufSlice, send_done: Done) {
    if w.topo.same_node(env.src_rank, env.dst_rank) {
        intra_send(w, core, env, src, send_done);
    } else {
        nic::execute_send(w, core, env, src, send_done);
    }
}

/// Intra-node send via ROCr IPC / non-temporal memcpy (paper §V-D).
fn intra_send(w: &mut World, core: &mut Ctx, env: Envelope, src: BufSlice, send_done: Done) {
    w.metrics.intra_sends += 1;
    let bytes = src.bytes();
    let rank = env.dst_rank;
    if bytes <= w.cost.memcpy_threshold {
        // Small payload: buffered copy; sender completes locally.
        let dur = w.cost.jittered(w.cost.ipc_time(bytes), core.rng());
        w.metrics.bytes_ipc += bytes as u64;
        core.schedule(
            dur,
            Box::new(move |w, core| {
                let payload = if w.is_real() {
                    w.bufs.get(src.buf)[src.off..src.off + src.elems].to_vec()
                } else {
                    Vec::new()
                };
                send_done.fire(w, core);
                match take_matching_posted(w, core, rank, &env) {
                    Some(posted) => {
                        if w.is_real() {
                            let d = w.bufs.get_mut(posted.dst.buf);
                            d[posted.dst.off..posted.dst.off + posted.dst.elems]
                                .copy_from_slice(&payload);
                        }
                        posted.done.fire(w, core);
                    }
                    None => {
                        w.metrics.unexpected_msgs += 1;
                        core.trace_push(Event::Unexpected {
                            t: core.now(),
                            rank: rank as u32,
                            tag: env.tag,
                        });
                        w.procs[rank]
                            .unexpected
                            .push_back(UnexpMsg { env, body: UnexpBody::IntraEager(payload) });
                    }
                }
            }),
        );
    } else {
        // Large payload: zero-copy P2P DMA once both sides are known.
        match take_matching_posted(w, core, rank, &env) {
            Some(posted) => intra_zero_copy(w, core, src, posted.dst, send_done, posted.done),
            None => {
                w.metrics.unexpected_msgs += 1;
                core.trace_push(Event::Unexpected {
                    t: core.now(),
                    rank: rank as u32,
                    tag: env.tag,
                });
                w.procs[rank].unexpected.push_back(UnexpMsg {
                    env,
                    body: UnexpBody::IntraZeroCopy { src, src_done: send_done },
                });
            }
        }
    }
}

// ---------------------------------------------------------------------
// Host-facing MPI API (used from host actors)
// ---------------------------------------------------------------------

/// `MPI_Isend`: post a non-blocking send; returns a request id.
pub fn isend(
    hctx: &mut HostCtx<World>,
    rank: usize,
    dst: usize,
    src: BufSlice,
    tag: i32,
    comm: u16,
) -> usize {
    let call = hctx.with(|w, _| {
        let mut c = w.cost.host_mpi_call;
        // Host-driven rendezvous progression (RTS/CTS handling) — the
        // standard path's hidden cost that NIC-offloaded ST avoids (§V-E).
        if !w.topo.same_node(rank, dst) && w.cost.is_rendezvous(src.bytes()) {
            c += w.cost.host_rendezvous_progression;
        }
        c
    });
    hctx.advance(call);
    hctx.with(|w, core| {
        let req = w.new_request(core, "isend");
        let env = Envelope { src_rank: rank, dst_rank: dst, tag, comm, elems: src.elems };
        let done = Done::cell(w.request_done_cell(req));
        // Host posts the command; NIC/shm path takes over after the post cost.
        let post = w.cost.nic_cmd_post;
        core.schedule(post, Box::new(move |w, core| do_send(w, core, env, src, done)));
        req
    })
}

/// `MPI_Irecv`: post a non-blocking receive; returns a request id.
/// Wildcards (`SrcSel::Any`, `TagSel::Any`) are allowed here — unlike the
/// ST path (§III-D).
pub fn irecv(
    hctx: &mut HostCtx<World>,
    rank: usize,
    src: SrcSel,
    tag: TagSel,
    comm: u16,
    dst: BufSlice,
) -> usize {
    let call = hctx.with(|w, _| w.cost.host_mpi_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        let req = w.new_request(core, "irecv");
        let done = Done::cell(w.request_done_cell(req));
        post_recv(w, core, rank, src, tag, comm, dst, done);
        req
    })
}

/// `MPI_Wait`: block the host until the request completes.
pub fn wait(hctx: &mut HostCtx<World>, req: usize) {
    let (cell, overhead) = hctx.with(|w, _| (w.request_done_cell(req), w.cost.host_wait_overhead));
    hctx.advance(overhead);
    hctx.wait_ge(cell, 1, "MPI_Wait");
}

/// `MPI_Waitall`.
pub fn waitall(hctx: &mut HostCtx<World>, reqs: &[usize]) {
    for &r in reqs {
        wait(hctx, r);
    }
}

/// Test (non-blocking probe) whether a request has completed.
pub fn test(hctx: &mut HostCtx<World>, req: usize) -> bool {
    hctx.with(|w, core| core.cell(w.request_done_cell(req)) >= 1)
}

/// Reusable tag space for [`barrier`]; chosen outside the range any
/// workload in this crate uses.
const BARRIER_TAG_BASE: i32 = 1 << 20;

/// `MPI_Barrier` (dissemination algorithm): ceil(log2 n) rounds of
/// point-to-point exchanges. `generation` must be the same monotonically
/// increasing value on every rank (it keys the tag space so back-to-back
/// barriers never cross-match).
pub fn barrier(hctx: &mut HostCtx<World>, rank: usize, n: usize, comm: u16, generation: u32) {
    if n <= 1 {
        return;
    }
    // Zero-length payloads still need a buffer id; use a 1-elem scratch.
    let scratch = hctx.with(|w, _| w.bufs.alloc(1));
    let mut round = 0u32;
    let mut dist = 1usize;
    while dist < n {
        let to = (rank + dist) % n;
        let from = (rank + n - dist) % n;
        let tag = BARRIER_TAG_BASE + (generation as i32) * 64 + round as i32;
        let r1 = isend(hctx, rank, to, BufSlice::whole(scratch, 1), tag, comm);
        let r2 = irecv(hctx, rank, SrcSel::Rank(from), TagSel::Tag(tag), comm, BufSlice::whole(scratch, 1));
        waitall(hctx, &[r1, r2]);
        dist <<= 1;
        round += 1;
    }
}

#[cfg(test)]
mod tests;
