//! MPI layer tests: matching semantics, intra/inter paths, host API.

use super::*;
use crate::coordinator::{build_world, run_cluster};
use crate::costmodel::presets;
use crate::sim::Engine;
use crate::world::{BufId, Topology};

fn cost() -> crate::costmodel::CostModel {
    let mut c = presets::frontier_like();
    c.jitter_sigma = 0.0;
    c
}

/// Two ranks on different nodes exchange one message via the host API.
#[test]
fn host_send_recv_inter_node() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init((0..64).map(|x| x as f32).collect());
    let dst = w.bufs.alloc(64);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let req = isend(ctx, 0, 1, BufSlice::whole(src, 64), 7, COMM_WORLD);
            wait(ctx, req);
        } else {
            let req = irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(7), COMM_WORLD, BufSlice::whole(dst, 64));
            wait(ctx, req);
            ctx.with(move |w, _| {
                assert_eq!(w.bufs.get(dst)[10], 10.0);
            });
        }
    })
    .unwrap();
    assert_eq!(out.world.metrics.eager_sends, 1);
    assert!(out.makespan > 0);
}

#[test]
fn host_send_recv_intra_node_small_uses_memcpy_path() {
    let mut w = build_world(cost(), Topology::new(1, 2));
    let src = w.bufs.alloc_init(vec![5.0; 16]);
    let dst = w.bufs.alloc(16);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let req = isend(ctx, 0, 1, BufSlice::whole(src, 16), 3, COMM_WORLD);
            wait(ctx, req);
        } else {
            let req = irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(3), COMM_WORLD, BufSlice::whole(dst, 16));
            wait(ctx, req);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[5.0; 16]));
        }
    })
    .unwrap();
    assert_eq!(out.world.metrics.intra_sends, 1);
    assert_eq!(out.world.metrics.eager_sends, 0, "no wire traffic intra-node");
    assert_eq!(out.world.metrics.bytes_wire, 0);
}

#[test]
fn host_send_recv_intra_node_large_zero_copy() {
    let elems = 64 * 1024;
    let mut w = build_world(cost(), Topology::new(1, 2));
    let src = w.bufs.alloc_init(vec![2.0; elems]);
    let dst = w.bufs.alloc(elems);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let req = isend(ctx, 0, 1, BufSlice::whole(src, elems), 3, COMM_WORLD);
            wait(ctx, req);
        } else {
            let req = irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(3), COMM_WORLD, BufSlice::whole(dst, elems));
            wait(ctx, req);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst)[elems - 1], 2.0));
        }
    })
    .unwrap();
    assert!(out.world.metrics.bytes_ipc >= (elems * 4) as u64);
}

/// Tag matching: messages with different tags go to the right receives
/// even when posted out of order.
#[test]
fn tag_matching_out_of_order() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let a = w.bufs.alloc_init(vec![1.0; 8]);
    let b = w.bufs.alloc_init(vec![2.0; 8]);
    let da = w.bufs.alloc(8);
    let db = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let r1 = isend(ctx, 0, 1, BufSlice::whole(a, 8), 100, COMM_WORLD);
            let r2 = isend(ctx, 0, 1, BufSlice::whole(b, 8), 200, COMM_WORLD);
            waitall(ctx, &[r1, r2]);
        } else {
            // Post tag 200 first, then tag 100 — must still match by tag.
            let r2 = irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(200), COMM_WORLD, BufSlice::whole(db, 8));
            let r1 = irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(100), COMM_WORLD, BufSlice::whole(da, 8));
            waitall(ctx, &[r1, r2]);
            ctx.with(move |w, _| {
                assert_eq!(w.bufs.get(da), &[1.0; 8]);
                assert_eq!(w.bufs.get(db), &[2.0; 8]);
            });
        }
    })
    .unwrap();
}

/// Same (src, tag): FIFO pairwise ordering must hold.
#[test]
fn same_tag_fifo_order() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let bufs: Vec<BufId> = (0..4).map(|i| w.bufs.alloc_init(vec![i as f32; 4])).collect();
    let dsts: Vec<BufId> = (0..4).map(|_| w.bufs.alloc(4)).collect();
    let bufs2 = bufs.clone();
    let dsts2 = dsts.clone();
    run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let reqs: Vec<usize> = bufs2
                .iter()
                .map(|&b| isend(ctx, 0, 1, BufSlice::whole(b, 4), 9, COMM_WORLD))
                .collect();
            waitall(ctx, &reqs);
        } else {
            let reqs: Vec<usize> = dsts2
                .iter()
                .map(|&d| irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(9), COMM_WORLD, BufSlice::whole(d, 4)))
                .collect();
            waitall(ctx, &reqs);
            let d = dsts2.clone();
            ctx.with(move |w, _| {
                for (i, dst) in d.iter().enumerate() {
                    assert_eq!(w.bufs.get(*dst), &[i as f32; 4], "message {i} out of order");
                }
            });
        }
    })
    .unwrap();
}

#[test]
fn wildcard_any_source_matches() {
    let mut w = build_world(cost(), Topology::new(3, 1));
    let s = w.bufs.alloc_init(vec![4.0; 8]);
    let d = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| match rank {
        2 => {
            let req = irecv(ctx, 2, SrcSel::Any, TagSel::Any, COMM_WORLD, BufSlice::whole(d, 8));
            wait(ctx, req);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(d), &[4.0; 8]));
        }
        1 => {
            ctx.advance(5_000);
            let req = isend(ctx, 1, 2, BufSlice::whole(s, 8), 77, COMM_WORLD);
            wait(ctx, req);
        }
        _ => {}
    })
    .unwrap();
}

#[test]
fn unexpected_messages_buffer_until_posted() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let s = w.bufs.alloc_init(vec![8.0; 8]);
    let d = w.bufs.alloc(8);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let req = isend(ctx, 0, 1, BufSlice::whole(s, 8), 1, COMM_WORLD);
            wait(ctx, req);
        } else {
            // Deliberately late post.
            ctx.advance(500_000);
            let req = irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(1), COMM_WORLD, BufSlice::whole(d, 8));
            wait(ctx, req);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(d), &[8.0; 8]));
        }
    })
    .unwrap();
    assert_eq!(out.world.metrics.unexpected_msgs, 1);
}

#[test]
fn comm_isolation() {
    // A message on comm A must not match a receive on comm B.
    let mut w = build_world(cost(), Topology::new(2, 1));
    let s1 = w.bufs.alloc_init(vec![1.0; 4]);
    let s2 = w.bufs.alloc_init(vec![2.0; 4]);
    let d1 = w.bufs.alloc(4);
    let d2 = w.bufs.alloc(4);
    run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let r1 = isend(ctx, 0, 1, BufSlice::whole(s1, 4), 5, COMM_WORLD);
            let r2 = isend(ctx, 0, 1, BufSlice::whole(s2, 4), 5, COMM_WORLD_DUP);
            waitall(ctx, &[r1, r2]);
        } else {
            let r2 = irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(5), COMM_WORLD_DUP, BufSlice::whole(d2, 4));
            let r1 = irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(5), COMM_WORLD, BufSlice::whole(d1, 4));
            waitall(ctx, &[r1, r2]);
            ctx.with(move |w, _| {
                assert_eq!(w.bufs.get(d1), &[1.0; 4]);
                assert_eq!(w.bufs.get(d2), &[2.0; 4]);
            });
        }
    })
    .unwrap();
}

#[test]
fn progress_thread_serializes_work() {
    let eng = Engine::new(build_world(cost(), Topology::new(1, 1)), 1);
    let times = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    eng.setup(|w, core| {
        for _ in 0..3 {
            let t = progress_charge(w, core, 0, 1000);
            times.lock().unwrap().push(t);
        }
    });
    let (w, _) = eng.run().unwrap();
    let t = times.lock().unwrap().clone();
    assert_eq!(t, vec![1000, 2000, 3000], "progress ops must serialize");
    assert_eq!(w.procs[0].progress.ops_handled, 3);
}

#[test]
fn deadlock_in_mpi_program_is_reported() {
    let w = build_world(cost(), Topology::new(2, 1));
    let result = run_cluster(w, 1, move |rank, ctx| {
        if rank == 1 {
            // Receive that never gets a send.
            let dst = ctx.with(|w, _| w.bufs.alloc(4));
            let req = irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(1), COMM_WORLD, BufSlice::whole(dst, 4));
            wait(ctx, req);
        }
    });
    let err = match result {
        Err(e) => e,
        Ok(_) => panic!("expected deadlock"),
    };
    let msg = format!("{err}");
    assert!(msg.contains("deadlock"), "got: {msg}");
}

#[test]
fn test_probe_nonblocking() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let s = w.bufs.alloc_init(vec![1.0; 4]);
    let d = w.bufs.alloc(4);
    run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            ctx.advance(100_000);
            let req = isend(ctx, 0, 1, BufSlice::whole(s, 4), 1, COMM_WORLD);
            wait(ctx, req);
        } else {
            let req = irecv(ctx, 1, SrcSel::Rank(0), TagSel::Tag(1), COMM_WORLD, BufSlice::whole(d, 4));
            assert!(!test(ctx, req), "request cannot be done yet");
            wait(ctx, req);
            assert!(test(ctx, req));
        }
    })
    .unwrap();
}

#[test]
fn many_to_one_fan_in() {
    let n = 8;
    let mut w = build_world(cost(), Topology::new(n, 1));
    let srcs: Vec<BufId> = (0..n).map(|r| w.bufs.alloc_init(vec![r as f32; 16])).collect();
    let dsts: Vec<BufId> = (0..n).map(|_| w.bufs.alloc(16)).collect();
    let srcs2 = srcs.clone();
    let dsts2 = dsts.clone();
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let reqs: Vec<usize> = (1..n)
                .map(|r| {
                    irecv(ctx, 0, SrcSel::Rank(r), TagSel::Tag(0), COMM_WORLD, BufSlice::whole(dsts2[r], 16))
                })
                .collect();
            waitall(ctx, &reqs);
            let d = dsts2.clone();
            ctx.with(move |w, _| {
                for r in 1..n {
                    assert_eq!(w.bufs.get(d[r]), &[r as f32; 16]);
                }
            });
        } else {
            let req = isend(ctx, rank, 0, BufSlice::whole(srcs2[rank], 16), 0, COMM_WORLD);
            wait(ctx, req);
        }
    })
    .unwrap();
    assert_eq!(out.world.metrics.eager_sends as usize, n - 1);
}

#[test]
fn barrier_synchronizes_skewed_ranks() {
    use std::sync::{Arc, Mutex};
    let n = 6;
    let w = build_world(cost(), Topology::new(3, 2));
    let exits: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(vec![0; n]));
    let entries: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(vec![0; n]));
    let (ex, en) = (exits.clone(), entries.clone());
    run_cluster(w, 1, move |rank, ctx| {
        // Heavily skewed arrival times.
        ctx.advance(10_000 * rank as u64);
        en.lock().unwrap()[rank] = ctx.now();
        barrier(ctx, rank, n, COMM_WORLD, 0);
        ex.lock().unwrap()[rank] = ctx.now();
    })
    .unwrap();
    let exits = exits.lock().unwrap().clone();
    let entries = entries.lock().unwrap().clone();
    let latest_entry = *entries.iter().max().unwrap();
    for r in 0..n {
        assert!(
            exits[r] >= latest_entry,
            "rank {r} left the barrier at {} before rank {} arrived at {latest_entry}",
            exits[r],
            n - 1
        );
    }
}

#[test]
fn back_to_back_barriers_do_not_cross_match() {
    let n = 4;
    let w = build_world(cost(), Topology::new(2, 2));
    run_cluster(w, 1, move |rank, ctx| {
        for generation in 0..3u32 {
            ctx.advance(1_000 * ((rank as u64 * 7 + generation as u64) % 5));
            barrier(ctx, rank, n, COMM_WORLD, generation);
        }
    })
    .unwrap();
}

/// Matching-engine accounting invariant: every delivered message bumps
/// exactly one of `matched_posted` (matched a posted receive on
/// arrival) or `unexpected_msgs` (buffered), for any interleaving of
/// posts and arrivals — including wildcard selectors.
#[test]
fn matching_conserves_message_accounting_with_wildcards() {
    use crate::nic::{Envelope, WireMsg};
    let eng = Engine::new(build_world(cost(), Topology::new(2, 1)), 1);
    eng.setup(|w, core| {
        let bufs: Vec<BufId> = (0..4).map(|_| w.bufs.alloc(1)).collect();
        // Two arrivals before any post, two after a wildcard post.
        let mk = |src: usize, tag: i32, id: f32| WireMsg::Eager {
            env: Envelope { src_rank: src, dst_rank: 1, tag, comm: 0, elems: 1 },
            payload: vec![id],
            seq: 0,
        };
        deliver_from_wire(w, core, mk(0, 7, 1.0));
        deliver_from_wire(w, core, mk(0, 8, 2.0));
        post_recv(w, core, 1, SrcSel::Any, TagSel::Any, 0, BufSlice::whole(bufs[0], 1), Done::none());
        post_recv(w, core, 1, SrcSel::Rank(0), TagSel::Tag(8), 0, BufSlice::whole(bufs[1], 1), Done::none());
        post_recv(w, core, 1, SrcSel::Any, TagSel::Tag(9), 0, BufSlice::whole(bufs[2], 1), Done::none());
        deliver_from_wire(w, core, mk(0, 9, 3.0));
        deliver_from_wire(w, core, mk(0, 5, 4.0));
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(w.metrics.matched_posted + w.metrics.unexpected_msgs, 4, "each message once");
    assert_eq!(w.metrics.matched_posted, 1, "only the tag-9 arrival found a posted match");
    assert_eq!(w.metrics.unexpected_msgs, 3);
    // FIFO from the unexpected queue: the Any/Any post takes the OLDEST
    // buffered message (tag 7), the (0, 8) post its exact match.
    assert_eq!(w.bufs.get(BufId(0)), &[1.0]);
    assert_eq!(w.bufs.get(BufId(1)), &[2.0]);
    assert_eq!(w.bufs.get(BufId(2)), &[3.0]);
    // The tag-5 arrival stays unexpected; nothing matches it.
    assert_eq!(w.procs[1].unexpected.len(), 1);
    assert_eq!(w.procs[1].unexpected[0].env.tag, 5);
    assert!(w.procs[1].posted.is_empty());
}

/// Wildcard receives drain the unexpected queue in arrival (FIFO)
/// order, and posted-queue scans run in posting order — the two rules
/// that make the match set independent of post-vs-arrival interleaving
/// (the property test in tests/properties.rs shuffles both).
#[test]
fn wildcard_matching_is_fifo_on_both_queues() {
    use crate::nic::{Envelope, WireMsg};
    let eng = Engine::new(build_world(cost(), Topology::new(3, 1)), 1);
    eng.setup(|w, core| {
        let bufs: Vec<BufId> = (0..2).map(|_| w.bufs.alloc(1)).collect();
        let mk = |src: usize, id: f32| WireMsg::Eager {
            env: Envelope { src_rank: src, dst_rank: 2, tag: 1, comm: 0, elems: 1 },
            payload: vec![id],
            seq: 0,
        };
        // Posted order: (src1) before (Any). The src0 arrival must skip
        // the src1-selector and land in the Any receive.
        post_recv(w, core, 2, SrcSel::Rank(1), TagSel::Tag(1), 0, BufSlice::whole(bufs[0], 1), Done::none());
        post_recv(w, core, 2, SrcSel::Any, TagSel::Any, 0, BufSlice::whole(bufs[1], 1), Done::none());
        deliver_from_wire(w, core, mk(0, 10.0));
        deliver_from_wire(w, core, mk(1, 20.0));
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(w.bufs.get(BufId(0)), &[20.0], "src1 selector got the src1 message");
    assert_eq!(w.bufs.get(BufId(1)), &[10.0], "the Any receive got the src0 message");
    assert_eq!(w.metrics.matched_posted, 2);
    assert_eq!(w.metrics.unexpected_msgs, 0);
}
