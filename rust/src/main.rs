//! stmpi launcher: run Faces experiments, the figure sweep, a workload
//! campaign, or the ST-allreduce trainer on the simulated cluster from
//! the command line.
//!
//! ```text
//! stmpi faces [--config faces.toml] [key=value ...]
//! stmpi sweep                      # regenerate Figs 8-12
//! stmpi campaign [key=value ...]   # workload-engine comparative report
//! stmpi train [key=value ...]
//! stmpi figures fig9 fig11         # selected figures
//! ```
//!
//! `faces` keys (TOML-subset config file and/or CLI overrides):
//!   faces.dist=2x2x2  faces.nodes=8  faces.rpn=1  faces.g=128
//!   faces.outer=1 faces.middle=2 faces.inner=25
//!   faces.variant=baseline|st|st-shader|kt  faces.real=true  faces.check=true
//!   seed=11  jitter=0.03
//! `campaign` keys (comma lists; empty = defaults):
//!   campaign.workloads=faces,halo3d,allreduce,alltoall,incast,allgather,halograph,reduce-scatter
//!   campaign.variants=baseline,st,kt,ring-st,rdbl-st,ring-kt
//!   campaign.sizes=256,4096  campaign.topos=2x1,4x1  campaign.seeds=11,23
//!   campaign.queues=1,2 (queues per rank)  campaign.dwq_slots=4
//!   campaign.iters=3  campaign.jitter=0.01  campaign.out=CAMPAIGN_report
//!   campaign.faults=off|drops|dups|delays|chaos  campaign.fault_seed=11
//!   (the chaos axis; `STMPI_FAULTS=1` in the environment is shorthand
//!   for campaign.faults=chaos — stalled cells render as `stalled` rows
//!   carrying their StallReport instead of aborting the sweep)
//!   campaign.trace=TRACE (Chrome-trace export: writes each cell's
//!   first-seed event trace as `TRACE_<cell>.json`, loadable in
//!   Perfetto / chrome://tracing; `STMPI_TRACE=1` in the environment is
//!   shorthand for campaign.trace=TRACE, `STMPI_TRACE=0` disables
//!   recording entirely and the overlap %/crit-path columns render `--`)
//! `train` keys: train.nodes, train.rpn, train.steps, seed.
//!
//! `sweep` regenerates Figs 8-12, the ST-vs-KT figure (figkt), and the
//! ST-vs-KT message-size sweep; `figures` takes fig8..fig12 or figkt.

use anyhow::{bail, Context, Result};

use stmpi::coordinator::config::Config;
use stmpi::costmodel::{presets, MemOpFlavor};
use stmpi::fault::FaultSpec;
use stmpi::faces::figures::{
    all_figures, render_kt_compare, run_figure, run_kt_compare, Loops, FIGURE_G, KT_COMPARE_GS,
    SEEDS,
};
use stmpi::faces::{run_faces, FacesConfig, Variant};
use stmpi::train::{train, TrainConfig};
use stmpi::workloads::{run_campaign, CampaignSpec};
use stmpi::world::ComputeMode;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("faces") => cmd_faces(&args[1..]),
        Some("sweep") => cmd_sweep(),
        Some("campaign") => cmd_campaign(&args[1..]),
        Some("figures") => cmd_figures(&args[1..]),
        Some("train") => cmd_train(&args[1..]),
        Some("--help") | Some("-h") | None => {
            println!(
                "usage: stmpi <faces|sweep|campaign|figures|train> [--config FILE] [key=value ...]"
            );
            println!("see module docs in rust/src/main.rs for the key list");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}'"),
    }
}

fn load_config(args: &[String]) -> Result<Config> {
    let mut cfg = Config::default();
    let mut overrides = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--config" {
            let path = it.next().ok_or_else(|| anyhow::anyhow!("--config needs a path"))?;
            cfg = Config::load(path)?;
        } else if a.contains('=') {
            overrides.push(a.clone());
        } else {
            bail!("unexpected argument '{a}' (expected key=value)");
        }
    }
    cfg.apply_overrides(&overrides)?;
    Ok(cfg)
}

fn parse_variant(s: &str) -> Result<Variant> {
    Variant::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown variant '{s}' (baseline|st|st-shader|kt)"))
}

fn cmd_faces(args: &[String]) -> Result<()> {
    let c = load_config(args)?;
    let mut cost = presets::frontier_like();
    cost.jitter_sigma = c.f64_or("jitter", 0.0)?;
    let real = c.bool_or("faces.real", false)?;
    let cfg = FacesConfig {
        dist: c.triple_or("faces.dist", (8, 1, 1))?,
        nodes: c.usize_or("faces.nodes", 8)?,
        ranks_per_node: c.usize_or("faces.rpn", 1)?,
        g: c.usize_or("faces.g", if real { 32 } else { FIGURE_G })?,
        outer: c.usize_or("faces.outer", 1)?,
        middle: c.usize_or("faces.middle", 2)?,
        inner: c.usize_or("faces.inner", 25)?,
        variant: parse_variant(c.str_or("faces.variant", "st"))?,
        compute: if real { ComputeMode::Real } else { ComputeMode::Modeled },
        check: c.bool_or("faces.check", real)?,
        seed: c.u64_or("seed", 11)?,
        cost,
        faults: None,
    };
    println!(
        "faces: {:?} dist={:?} nodes={} rpn={} G={} loops={}x{}x{} compute={:?}",
        cfg.variant, cfg.dist, cfg.nodes, cfg.ranks_per_node, cfg.g, cfg.outer, cfg.middle,
        cfg.inner, cfg.compute
    );
    let r = run_faces(&cfg)?;
    println!("time: {:.3} ms (virtual)", r.time_ns as f64 / 1e6);
    if let Some(err) = r.max_err {
        println!("max |field - reference| = {err:.3e} ({})", if err < 1e-3 { "OK" } else { "FAIL" });
    }
    println!("{:#?}", r.metrics);
    Ok(())
}

fn cmd_sweep() -> Result<()> {
    for spec in all_figures() {
        let report = run_figure(&spec, &SEEDS, Loops::default(), FIGURE_G);
        println!("{}", report.render());
    }
    let rows = run_kt_compare(&KT_COMPARE_GS, &SEEDS, Loops::default());
    println!("{}", render_kt_compare(&rows));
    Ok(())
}

fn comma_list(c: &Config, key: &str) -> Vec<String> {
    c.get(key)
        .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
        .unwrap_or_default()
}

fn cmd_campaign(args: &[String]) -> Result<()> {
    let c = load_config(args)?;
    let defaults = CampaignSpec::default();
    let elems = comma_list(&c, "campaign.sizes")
        .iter()
        .map(|s| s.parse::<usize>().with_context(|| format!("campaign.sizes entry '{s}'")))
        .collect::<Result<Vec<_>>>()?;
    let topo_list = comma_list(&c, "campaign.topos");
    let topos = if topo_list.is_empty() {
        defaults.topos.clone()
    } else {
        topo_list
            .iter()
            .map(|t| -> Result<(usize, usize)> {
                let (a, b) = t
                    .split_once('x')
                    .ok_or_else(|| anyhow::anyhow!("campaign.topos entry '{t}' (want NxR)"))?;
                Ok((a.trim().parse::<usize>()?, b.trim().parse::<usize>()?))
            })
            .collect::<Result<Vec<_>>>()?
    };
    let seed_list = comma_list(&c, "campaign.seeds");
    let seeds = if seed_list.is_empty() {
        defaults.seeds.clone()
    } else {
        seed_list
            .iter()
            .map(|s| s.parse::<u64>().with_context(|| format!("campaign.seeds entry '{s}'")))
            .collect::<Result<Vec<_>>>()?
    };
    let queue_list = comma_list(&c, "campaign.queues");
    let queues = if queue_list.is_empty() {
        defaults.queues.clone()
    } else {
        queue_list
            .iter()
            .map(|s| s.parse::<usize>().with_context(|| format!("campaign.queues entry '{s}'")))
            .collect::<Result<Vec<_>>>()?
    };
    let dwq_slots = match c.get("campaign.dwq_slots") {
        Some(v) => Some(v.parse::<usize>().context("campaign.dwq_slots")?),
        None => None,
    };
    let fault_seed = c.u64_or("campaign.fault_seed", seeds.first().copied().unwrap_or(11))?;
    let faults = match c.get("campaign.faults") {
        Some(name) => fault_preset(name, fault_seed)?,
        // `STMPI_FAULTS=1` is the CI chaos leg's shorthand for
        // campaign.faults=chaos.
        None if std::env::var("STMPI_FAULTS").is_ok_and(|v| v == "1") => {
            Some(FaultSpec::chaos(fault_seed))
        }
        None => None,
    };
    let trace = match c.get("campaign.trace") {
        Some(prefix) => Some(prefix.to_string()),
        // `STMPI_TRACE=1` is shorthand for campaign.trace=TRACE (any
        // other set value only toggles recording, handled in obs).
        None if std::env::var("STMPI_TRACE").is_ok_and(|v| v == "1") => {
            Some("TRACE".to_string())
        }
        None => None,
    };
    let spec = CampaignSpec {
        workloads: comma_list(&c, "campaign.workloads"),
        variants: comma_list(&c, "campaign.variants"),
        elems,
        topos,
        queues,
        seeds,
        iters: c.usize_or("campaign.iters", defaults.iters)?,
        jitter: c.f64_or("campaign.jitter", defaults.jitter)?,
        dwq_slots,
        threads: None,
        faults,
        trace,
    };
    let report = run_campaign(&spec)?;
    println!("{}", report.to_markdown());
    let out = c.str_or("campaign.out", "CAMPAIGN_report");
    std::fs::write(format!("{out}.json"), report.to_json())
        .with_context(|| format!("writing {out}.json"))?;
    std::fs::write(format!("{out}.md"), report.to_markdown())
        .with_context(|| format!("writing {out}.md"))?;
    println!("wrote {out}.json and {out}.md");
    if let Some(prefix) = &spec.trace {
        let mut wrote = 0usize;
        for cell in &report.cells {
            let Some(tj) = &cell.trace_json else { continue };
            // The export inherits the recorder's determinism contract;
            // a malformed trace is a bug, not an I/O condition.
            if !stmpi::workloads::campaign::json_parses(tj) {
                bail!(
                    "internal error: Chrome trace for {}/{} elems={} is not valid JSON",
                    cell.workload,
                    cell.variant,
                    cell.elems
                );
            }
            let path = format!(
                "{prefix}_{}_{}_{}_{}x{}_q{}.json",
                cell.workload,
                cell.variant,
                cell.elems,
                cell.nodes,
                cell.ranks_per_node,
                cell.queues_per_rank
            );
            std::fs::write(&path, tj).with_context(|| format!("writing {path}"))?;
            wrote += 1;
        }
        println!("wrote {wrote} Chrome trace file(s) with prefix {prefix}");
    }
    if !report.all_ok() {
        let stalled: u64 = report.cells.iter().map(|c| c.stalls).sum();
        if stalled > 0 {
            bail!("campaign recorded {stalled} stalled run(s) (see `stalls` column above)");
        }
        bail!("campaign validation failed (see report above)");
    }
    Ok(())
}

/// Parse the `campaign.faults` preset name into a [`FaultSpec`].
fn fault_preset(name: &str, seed: u64) -> Result<Option<FaultSpec>> {
    match name {
        "off" => Ok(None),
        "drops" => Ok(Some(FaultSpec::drops(seed))),
        "dups" => Ok(Some(FaultSpec::dups(seed))),
        "delays" => Ok(Some(FaultSpec::delays(seed))),
        "chaos" => Ok(Some(FaultSpec::chaos(seed))),
        other => bail!("unknown campaign.faults preset '{other}' (off|drops|dups|delays|chaos)"),
    }
}

fn cmd_figures(names: &[String]) -> Result<()> {
    if names.is_empty() {
        bail!("figures: name at least one of fig8..fig12");
    }
    for name in names {
        let spec = all_figures()
            .into_iter()
            .find(|s| s.id == name)
            .ok_or_else(|| anyhow::anyhow!("unknown figure '{name}'"))?;
        let report = run_figure(&spec, &SEEDS, Loops::default(), FIGURE_G);
        println!("{}", report.render());
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let c = load_config(args)?;
    let cfg = TrainConfig {
        nodes: c.usize_or("train.nodes", 4)?,
        ranks_per_node: c.usize_or("train.rpn", 1)?,
        steps: c.usize_or("train.steps", 50)?,
        seed: c.u64_or("seed", 3)?,
        cost: presets::frontier_like(),
        flavor: MemOpFlavor::Hip,
    };
    println!("train: {} ranks x {} steps", cfg.nodes * cfg.ranks_per_node, cfg.steps);
    let r = train(&cfg)?;
    for (i, l) in r.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == r.losses.len() {
            println!("step {i:>4}  loss {l:.4}");
        }
    }
    println!("virtual time: {:.3} ms", r.time_ns as f64 / 1e6);
    Ok(())
}
