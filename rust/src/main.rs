//! stmpi launcher: run Faces experiments, the figure sweep, a workload
//! campaign, or the ST-allreduce trainer on the simulated cluster from
//! the command line.
//!
//! ```text
//! stmpi faces [--config faces.toml] [key=value ...]
//! stmpi sweep                      # regenerate Figs 8-12
//! stmpi campaign [key=value ...]   # workload-engine comparative report
//! stmpi serve [key=value ...]      # campaign store as a TCP query service
//! stmpi diff [key=value ...]       # re-cost a campaign under overrides
//! stmpi train [key=value ...]
//! stmpi figures fig9 fig11         # selected figures
//! ```
//!
//! `faces` keys (TOML-subset config file and/or CLI overrides):
//!   faces.dist=2x2x2  faces.nodes=8  faces.rpn=1  faces.g=128
//!   faces.outer=1 faces.middle=2 faces.inner=25
//!   faces.variant=baseline|st|st-shader|kt|gi  faces.real=true  faces.check=true
//!   seed=11  jitter=0.03
//! `campaign` keys (comma lists; empty = defaults):
//!   campaign.workloads=faces,halo3d,allreduce,alltoall,incast,allgather,halograph,reduce-scatter,broadcast
//!   campaign.variants=baseline,st,kt,gi,ring-st,rdbl-st,ring-kt,ring-gi
//!   campaign.sizes=256,4096  campaign.topos=2x1,4x1  campaign.seeds=11,23
//!   campaign.queues=1,2 (queues per rank)  campaign.dwq_slots=4
//!   campaign.iters=3  campaign.jitter=0.01  campaign.out=CAMPAIGN_report
//!   campaign.faults=off|drops|dups|delays|rdv-drops|chaos  campaign.fault_seed=11
//!   (the chaos axis; `STMPI_FAULTS=1` in the environment is shorthand
//!   for campaign.faults=chaos — stalled cells render as `stalled` rows
//!   carrying their StallReport instead of aborting the sweep)
//!   campaign.trace=TRACE (Chrome-trace export: writes each cell's
//!   first-seed event trace as `TRACE_<cell>.json`, loadable in
//!   Perfetto / chrome://tracing; `STMPI_TRACE=1` in the environment is
//!   shorthand for campaign.trace=TRACE, `STMPI_TRACE=0` disables
//!   recording entirely and the overlap %/crit-path columns render `--`)
//!   campaign.store=STORE (content-addressed result store directory:
//!   per-(cell x seed) results persist to an append-only segment log and
//!   reruns serve fingerprint hits from cache instead of simulating —
//!   byte-identical report either way; cache stats land in
//!   `<out>_STORE_stats.json`; `STMPI_STORE=DIR` is the env shorthand)
//!   campaign.cost=field:value,... (cost-model overrides, applied before
//!   fingerprinting — changed costs re-simulate every affected cell)
//! `serve` keys: serve.addr=127.0.0.1:7878  serve.store=STORE — the
//!   line-oriented JSON protocol is documented in `store::server`.
//! `diff` keys: every campaign.* key plus the required
//!   diff.overrides=field:value,... — runs the same grid under the base
//!   and overridden cost models (both legs incremental when
//!   campaign.store is set) and writes DIFF_report.{json,md}.
//! `train` keys: train.nodes, train.rpn, train.steps, seed.
//!
//! `sweep` regenerates Figs 8-12, the ST-vs-KT figure (figkt), the
//! ST-vs-KT message-size sweep, and the KT-vs-GI crossover sweep
//! (figgi); `figures` takes fig8..fig12, figkt, or figgi.

use anyhow::{bail, Context, Result};

use stmpi::coordinator::config::Config;
use stmpi::costmodel::{presets, MemOpFlavor};
use stmpi::fault::FaultSpec;
use stmpi::faces::figures::{
    all_figures, render_gi_compare, render_kt_compare, run_figure, run_gi_compare, run_kt_compare,
    Loops, FIGURE_G, GI_COMPARE_GS, KT_COMPARE_GS, SEEDS,
};
use stmpi::faces::{run_faces, FacesConfig, Variant};
use stmpi::store::server::Server;
use stmpi::store::Store;
use stmpi::train::{train, TrainConfig};
use stmpi::workloads::{diff_cost_models, run_campaign, CampaignSpec};
use stmpi::world::ComputeMode;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("faces") => cmd_faces(&args[1..]),
        Some("sweep") => cmd_sweep(),
        Some("campaign") => cmd_campaign(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("diff") => cmd_diff(&args[1..]),
        Some("figures") => cmd_figures(&args[1..]),
        Some("train") => cmd_train(&args[1..]),
        Some("--help") | Some("-h") | None => {
            println!(
                "usage: stmpi <faces|sweep|campaign|serve|diff|figures|train> \
                 [--config FILE] [key=value ...]"
            );
            println!("see module docs in rust/src/main.rs for the key list");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}'"),
    }
}

fn load_config(args: &[String]) -> Result<Config> {
    let mut cfg = Config::default();
    let mut overrides = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--config" {
            let path = it.next().ok_or_else(|| anyhow::anyhow!("--config needs a path"))?;
            cfg = Config::load(path)?;
        } else if a.contains('=') {
            overrides.push(a.clone());
        } else {
            bail!("unexpected argument '{a}' (expected key=value)");
        }
    }
    cfg.apply_overrides(&overrides)?;
    Ok(cfg)
}

fn parse_variant(s: &str) -> Result<Variant> {
    Variant::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown variant '{s}' (baseline|st|st-shader|kt|gi)"))
}

fn cmd_faces(args: &[String]) -> Result<()> {
    let c = load_config(args)?;
    let mut cost = presets::frontier_like();
    cost.jitter_sigma = c.f64_or("jitter", 0.0)?;
    let real = c.bool_or("faces.real", false)?;
    let cfg = FacesConfig {
        dist: c.triple_or("faces.dist", (8, 1, 1))?,
        nodes: c.usize_or("faces.nodes", 8)?,
        ranks_per_node: c.usize_or("faces.rpn", 1)?,
        g: c.usize_or("faces.g", if real { 32 } else { FIGURE_G })?,
        outer: c.usize_or("faces.outer", 1)?,
        middle: c.usize_or("faces.middle", 2)?,
        inner: c.usize_or("faces.inner", 25)?,
        variant: parse_variant(c.str_or("faces.variant", "st"))?,
        compute: if real { ComputeMode::Real } else { ComputeMode::Modeled },
        check: c.bool_or("faces.check", real)?,
        seed: c.u64_or("seed", 11)?,
        cost,
        faults: None,
    };
    println!(
        "faces: {:?} dist={:?} nodes={} rpn={} G={} loops={}x{}x{} compute={:?}",
        cfg.variant, cfg.dist, cfg.nodes, cfg.ranks_per_node, cfg.g, cfg.outer, cfg.middle,
        cfg.inner, cfg.compute
    );
    let r = run_faces(&cfg)?;
    println!("time: {:.3} ms (virtual)", r.time_ns as f64 / 1e6);
    if let Some(err) = r.max_err {
        println!("max |field - reference| = {err:.3e} ({})", if err < 1e-3 { "OK" } else { "FAIL" });
    }
    println!("{:#?}", r.metrics);
    Ok(())
}

fn cmd_sweep() -> Result<()> {
    for spec in all_figures() {
        let report = run_figure(&spec, &SEEDS, Loops::default(), FIGURE_G);
        println!("{}", report.render());
    }
    let rows = run_kt_compare(&KT_COMPARE_GS, &SEEDS, Loops::default());
    println!("{}", render_kt_compare(&rows));
    let rows = run_gi_compare(&GI_COMPARE_GS, &SEEDS, Loops::default());
    println!("{}", render_gi_compare(&rows));
    Ok(())
}

fn comma_list(c: &Config, key: &str) -> Vec<String> {
    c.get(key)
        .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
        .unwrap_or_default()
}

/// Parse `field:value,...` cost-model override pairs (the value side of
/// `campaign.cost=` / `diff.overrides=`; `:` separates because `=` is
/// taken by the key=value CLI grammar).
fn parse_cost_pairs(list: &[String], key: &str) -> Result<Vec<(String, f64)>> {
    list.iter()
        .map(|pair| -> Result<(String, f64)> {
            let (field, value) = pair
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("{key} entry '{pair}' (want field:value)"))?;
            let value = value
                .trim()
                .parse::<f64>()
                .with_context(|| format!("{key} entry '{pair}'"))?;
            Ok((field.trim().to_string(), value))
        })
        .collect()
}

/// Build a [`CampaignSpec`] from the shared `campaign.*` key vocabulary
/// (used by both `stmpi campaign` and `stmpi diff`).
fn campaign_spec(c: &Config) -> Result<CampaignSpec> {
    let defaults = CampaignSpec::default();
    let elems = comma_list(c, "campaign.sizes")
        .iter()
        .map(|s| s.parse::<usize>().with_context(|| format!("campaign.sizes entry '{s}'")))
        .collect::<Result<Vec<_>>>()?;
    let topo_list = comma_list(c, "campaign.topos");
    let topos = if topo_list.is_empty() {
        defaults.topos.clone()
    } else {
        topo_list
            .iter()
            .map(|t| -> Result<(usize, usize)> {
                let (a, b) = t
                    .split_once('x')
                    .ok_or_else(|| anyhow::anyhow!("campaign.topos entry '{t}' (want NxR)"))?;
                Ok((a.trim().parse::<usize>()?, b.trim().parse::<usize>()?))
            })
            .collect::<Result<Vec<_>>>()?
    };
    let seed_list = comma_list(c, "campaign.seeds");
    let seeds = if seed_list.is_empty() {
        defaults.seeds.clone()
    } else {
        seed_list
            .iter()
            .map(|s| s.parse::<u64>().with_context(|| format!("campaign.seeds entry '{s}'")))
            .collect::<Result<Vec<_>>>()?
    };
    let queue_list = comma_list(c, "campaign.queues");
    let queues = if queue_list.is_empty() {
        defaults.queues.clone()
    } else {
        queue_list
            .iter()
            .map(|s| s.parse::<usize>().with_context(|| format!("campaign.queues entry '{s}'")))
            .collect::<Result<Vec<_>>>()?
    };
    let dwq_slots = match c.get("campaign.dwq_slots") {
        Some(v) => Some(v.parse::<usize>().context("campaign.dwq_slots")?),
        None => None,
    };
    let fault_seed = c.u64_or("campaign.fault_seed", seeds.first().copied().unwrap_or(11))?;
    let faults = match c.get("campaign.faults") {
        Some(name) => fault_preset(name, fault_seed)?,
        // `STMPI_FAULTS=1` is the CI chaos leg's shorthand for
        // campaign.faults=chaos.
        None if std::env::var("STMPI_FAULTS").is_ok_and(|v| v == "1") => {
            Some(FaultSpec::chaos(fault_seed))
        }
        None => None,
    };
    let trace = match c.get("campaign.trace") {
        Some(prefix) => Some(prefix.to_string()),
        // `STMPI_TRACE=1` is shorthand for campaign.trace=TRACE (any
        // other set value only toggles recording, handled in obs).
        None if std::env::var("STMPI_TRACE").is_ok_and(|v| v == "1") => {
            Some("TRACE".to_string())
        }
        None => None,
    };
    let store = match c.get("campaign.store") {
        Some(dir) => Some(dir.to_string()),
        // `STMPI_STORE=DIR` is the CI incremental leg's shorthand for
        // campaign.store=DIR.
        None => std::env::var("STMPI_STORE").ok().filter(|d| !d.is_empty()),
    };
    let cost_overrides = parse_cost_pairs(&comma_list(c, "campaign.cost"), "campaign.cost")?;
    Ok(CampaignSpec {
        workloads: comma_list(c, "campaign.workloads"),
        variants: comma_list(c, "campaign.variants"),
        elems,
        topos,
        queues,
        seeds,
        iters: c.usize_or("campaign.iters", defaults.iters)?,
        jitter: c.f64_or("campaign.jitter", defaults.jitter)?,
        dwq_slots,
        threads: None,
        faults,
        trace,
        store,
        cost_overrides,
    })
}

fn cmd_campaign(args: &[String]) -> Result<()> {
    let c = load_config(args)?;
    let spec = campaign_spec(&c)?;
    let report = run_campaign(&spec)?;
    println!("{}", report.to_markdown());
    let out = c.str_or("campaign.out", "CAMPAIGN_report");
    std::fs::write(format!("{out}.json"), report.to_json())
        .with_context(|| format!("writing {out}.json"))?;
    std::fs::write(format!("{out}.md"), report.to_markdown())
        .with_context(|| format!("writing {out}.md"))?;
    println!("wrote {out}.json and {out}.md");
    if let Some(prefix) = &spec.trace {
        let mut wrote = 0usize;
        for cell in &report.cells {
            let Some(tj) = &cell.trace_json else { continue };
            // The export inherits the recorder's determinism contract;
            // a malformed trace is a bug, not an I/O condition.
            if !stmpi::workloads::campaign::json_parses(tj) {
                bail!(
                    "internal error: Chrome trace for {}/{} elems={} is not valid JSON",
                    cell.workload,
                    cell.variant,
                    cell.elems
                );
            }
            let path = format!(
                "{prefix}_{}_{}_{}_{}x{}_q{}.json",
                cell.workload,
                cell.variant,
                cell.elems,
                cell.nodes,
                cell.ranks_per_node,
                cell.queues_per_rank
            );
            std::fs::write(&path, tj).with_context(|| format!("writing {path}"))?;
            wrote += 1;
        }
        println!("wrote {wrote} Chrome trace file(s) with prefix {prefix}");
    }
    if let Some(dir) = &spec.store {
        // Cache accounting stays out of the report bytes (warm and cold
        // runs must render identically); it lands in its own artifact.
        let store = Store::open(std::path::Path::new(dir))?;
        let stats = store.stats_json(&report.cache);
        let path = format!("{out}_STORE_stats.json");
        std::fs::write(&path, &stats).with_context(|| format!("writing {path}"))?;
        println!(
            "store {dir}: {} hit(s), {} simulated, {:.3} ms of virtual time served from cache \
             (stats in {path})",
            report.cache.hits,
            report.cache.misses,
            report.cache.simulated_ns_saved as f64 / 1e6
        );
    }
    if !report.all_ok() {
        let stalled: u64 = report.cells.iter().map(|c| c.stalls).sum();
        if stalled > 0 {
            bail!("campaign recorded {stalled} stalled run(s) (see `stalls` column above)");
        }
        bail!("campaign validation failed (see report above)");
    }
    Ok(())
}

/// Parse the `campaign.faults` preset name into a [`FaultSpec`].
fn fault_preset(name: &str, seed: u64) -> Result<Option<FaultSpec>> {
    if name == "off" {
        return Ok(None);
    }
    match FaultSpec::preset(name, seed) {
        Some(spec) => Ok(Some(spec)),
        None => bail!(
            "unknown campaign.faults preset '{name}' (off or one of {:?})",
            FaultSpec::preset_names()
        ),
    }
}

/// `stmpi serve`: run the campaign store as a line-oriented TCP query
/// service (see `store::server` for the protocol). Blocks until a
/// client sends `{"op":"shutdown"}`.
fn cmd_serve(args: &[String]) -> Result<()> {
    let c = load_config(args)?;
    let addr = c.str_or("serve.addr", "127.0.0.1:7878");
    let dir = c.str_or("serve.store", "STORE");
    let server = Server::bind(addr, std::path::Path::new(dir))?;
    println!("stmpi serve: store {dir} on {}", server.local_addr()?);
    server.serve()
}

/// `stmpi diff`: run the configured campaign grid under the base cost
/// model and under `diff.overrides`, and report the per-cell deltas.
fn cmd_diff(args: &[String]) -> Result<()> {
    let c = load_config(args)?;
    let spec = campaign_spec(&c)?;
    let pairs = comma_list(&c, "diff.overrides");
    if pairs.is_empty() {
        bail!("diff needs diff.overrides=field:value,... (cost-model fields to perturb)");
    }
    let overrides = parse_cost_pairs(&pairs, "diff.overrides")?;
    let diff = diff_cost_models(&spec, &overrides)?;
    println!("{}", diff.to_markdown());
    let out = c.str_or("diff.out", "DIFF_report");
    std::fs::write(format!("{out}.json"), diff.to_json())
        .with_context(|| format!("writing {out}.json"))?;
    std::fs::write(format!("{out}.md"), diff.to_markdown())
        .with_context(|| format!("writing {out}.md"))?;
    println!("wrote {out}.json and {out}.md");
    if let Some(dir) = &spec.store {
        println!(
            "store {dir}: {} hit(s), {} simulated across both cost legs",
            diff.cache.hits, diff.cache.misses
        );
    }
    Ok(())
}

fn cmd_figures(names: &[String]) -> Result<()> {
    if names.is_empty() {
        bail!("figures: name at least one of fig8..fig12, figkt, figgi");
    }
    for name in names {
        if name == "figgi" {
            // figgi is a message-size sweep, not a fixed-size figure.
            let rows = run_gi_compare(&GI_COMPARE_GS, &SEEDS, Loops::default());
            println!("{}", render_gi_compare(&rows));
            continue;
        }
        let spec = all_figures()
            .into_iter()
            .find(|s| s.id == name)
            .ok_or_else(|| anyhow::anyhow!("unknown figure '{name}'"))?;
        let report = run_figure(&spec, &SEEDS, Loops::default(), FIGURE_G);
        println!("{}", report.render());
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let c = load_config(args)?;
    let cfg = TrainConfig {
        nodes: c.usize_or("train.nodes", 4)?,
        ranks_per_node: c.usize_or("train.rpn", 1)?,
        steps: c.usize_or("train.steps", 50)?,
        seed: c.u64_or("seed", 3)?,
        cost: presets::frontier_like(),
        flavor: MemOpFlavor::Hip,
    };
    println!("train: {} ranks x {} steps", cfg.nodes * cfg.ranks_per_node, cfg.steps);
    let r = train(&cfg)?;
    for (i, l) in r.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == r.losses.len() {
            println!("step {i:>4}  loss {l:.4}");
        }
    }
    println!("virtual time: {:.3} ms", r.time_ns as f64 / 1e6);
    Ok(())
}
