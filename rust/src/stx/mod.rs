//! The paper's proposed interface: stream-triggered (ST) MPI operations.
//!
//! Implements §III's `MPIX_*` API over the simulated substrate:
//!
//! * [`create_queue`] / [`free_queue`] — `MPIX_Create_queue` /
//!   `MPIX_Free_queue`: bind a GPU stream to an MPI queue object and open
//!   two NIC hardware counters (one trigger, one completion), mapped into
//!   GPU-CP-visible memory (§IV-A);
//! * [`enqueue_send`] / [`enqueue_recv`] — `MPIX_Enqueue_send/recv`:
//!   create deferred communication descriptors, FIFO per queue,
//!   asynchronous w.r.t. the host;
//! * [`enqueue_start`] — `MPIX_Enqueue_start`: appends a stream-memory
//!   `writeValue64` to the GPU stream; when the GPU CP executes it, the
//!   write to the trigger counter fires **all** operations enqueued since
//!   the previous start (batching, §III-A footnote);
//! * [`enqueue_wait`] — `MPIX_Enqueue_wait`: appends a `waitValue64` on
//!   the completion counter, stalling the *stream* (never the host) until
//!   every started operation has completed.
//!
//! Routing mirrors §IV faithfully:
//! * inter-node sends → NIC DWQ triggered sends (full hardware offload);
//! * receives (any locality) and all intra-node traffic → emulated by the
//!   per-process progress thread, charged on its serial timeline;
//! * inter-node rendezvous sends get a small progress-thread assist for
//!   completion handling (§V-E).
//!
//! Wildcards are rejected (§III-D): ST operations require a concrete
//! source rank and tag.

use crate::costmodel::MemOpFlavor;
use crate::gpu::{self, StreamId, StreamOp, WriteMode};
use crate::mpi::{self, SrcSel, TagSel};
use crate::nic::{self, BufSlice, Done, Envelope};
use crate::sim::{CellId, HostCtx};
use crate::world::World;

/// Errors surfaced to the application (mirrors MPI error classes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StError {
    WildcardUnsupported,
    QueueFreed(usize),
    QueueBusy(u64),
}

impl std::fmt::Display for StError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StError::WildcardUnsupported => {
                write!(f, "ST operations do not support MPI_ANY_SOURCE/MPI_ANY_TAG (paper §III-D)")
            }
            StError::QueueFreed(q) => write!(f, "MPIX_Queue {q} was freed"),
            StError::QueueBusy(n) => {
                write!(f, "MPIX_Free_queue while {n} enqueued operations are incomplete")
            }
        }
    }
}

impl std::error::Error for StError {}

/// `MPIX_Queue`: maps a GPU stream to the MPI runtime and batches ST ops.
pub struct MpixQueue {
    pub rank: usize,
    pub stream: StreamId,
    /// NIC hardware trigger counter (GPU-CP visible).
    pub trig_ctr: CellId,
    /// NIC hardware completion counter (GPU-CP visible).
    pub comp_ctr: CellId,
    /// Stream memory op implementation used for this queue's
    /// start/wait operations (Hip or hand-coded Shader, §V-F).
    pub flavor: MemOpFlavor,
    /// Number of `enqueue_start` calls so far == the value the next
    /// trigger write stores.
    pub epoch: u64,
    /// Ops enqueued since the last start (they trigger at `epoch + 1`).
    pub pending_since_start: u64,
    /// Total ops covered by issued starts (the wait threshold).
    pub started_total: u64,
    pub freed: bool,
}

/// Create an `MPIX_Queue` bound to `stream` (local operation, §III-A).
pub fn create_queue(
    hctx: &mut HostCtx<World>,
    rank: usize,
    stream: StreamId,
    flavor: MemOpFlavor,
) -> usize {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        let node = w.topo.node_of(rank);
        let qid = w.queues.len();
        let trig_ctr = nic::alloc_counter(w, core, node, &format!("q{qid}.trig"));
        let comp_ctr = nic::alloc_counter(w, core, node, &format!("q{qid}.comp"));
        w.queues.push(MpixQueue {
            rank,
            stream,
            trig_ctr,
            comp_ctr,
            flavor,
            epoch: 0,
            pending_since_start: 0,
            started_total: 0,
            freed: false,
        });
        qid
    })
}

/// Release an `MPIX_Queue`'s internal resources. It is the caller's
/// responsibility to have waited for all associated ST operations
/// (§III-A); violating that is reported as an error.
pub fn free_queue(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        let q = &w.queues[queue];
        if q.freed {
            return Err(StError::QueueFreed(queue));
        }
        let completed = core.cell(q.comp_ctr);
        let outstanding = q.started_total.saturating_sub(completed);
        if outstanding > 0 {
            return Err(StError::QueueBusy(outstanding));
        }
        w.queues[queue].freed = true;
        Ok(())
    })
}

/// `MPIX_Enqueue_send`: deferred tagged send on `queue`. Returns a
/// request id usable with host-side `mpi::wait` (§III-B2 item 4).
pub fn enqueue_send(
    hctx: &mut HostCtx<World>,
    queue: usize,
    dst: usize,
    src: BufSlice,
    tag: i32,
    comm: u16,
) -> Result<usize, StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let rank = w.queues[queue].rank;
        let req = w.new_request(core, "st_send");
        let req_cell = w.request_done_cell(req);
        let q = &mut w.queues[queue];
        let threshold = q.epoch + 1;
        q.pending_since_start += 1;
        let trig = q.trig_ctr;
        let comp = q.comp_ctr;
        let env = Envelope { src_rank: rank, dst_rank: dst, tag, comm, elems: src.elems };

        if w.topo.same_node(rank, dst) {
            // No intra-node deferred-work hardware exists (§IV-B): the
            // progress thread watches the trigger counter and performs the
            // send itself.
            core.on_ge(
                trig,
                threshold,
                format!("progress r{rank} ST intra send"),
                Box::new(move |w, core| {
                    let cost = w.cost.progress_wakeup + w.cost.progress_per_op;
                    let at = mpi::progress_charge(w, core, rank, cost);
                    core.schedule_at(
                        at,
                        Box::new(move |w, core| {
                            // Completion counter updates also flow through
                            // the progress thread.
                            let done = Done {
                                cells: vec![req_cell],
                                cb: Some(Box::new(move |w, core| {
                                    let c = w.cost.progress_completion;
                                    let at = mpi::progress_charge(w, core, rank, c);
                                    // Typed event: the completion-counter
                                    // update needs no closure.
                                    core.schedule_cell_add_at(at, comp, 1);
                                })),
                            };
                            mpi::do_send(w, core, env, src, done);
                        }),
                    );
                }),
            );
        } else {
            // Full NIC offload via a DWQ triggered send (§IV-A1). The NIC
            // bumps the completion counter in hardware; rendezvous sends
            // need a small progress-thread assist (§V-E).
            let rendezvous = w.cost.is_rendezvous(src.bytes());
            let done = Done {
                cells: vec![req_cell, comp],
                cb: if rendezvous {
                    Some(Box::new(move |w, core| {
                        let c = w.cost.progress_rendezvous_assist;
                        let _ = mpi::progress_charge(w, core, rank, c);
                    }))
                } else {
                    None
                },
            };
            nic::post_triggered_send(w, core, trig, threshold, env, src, done);
        }
        Ok(req)
    })
}

/// `MPIX_Enqueue_recv`: deferred tagged receive on `queue`. The NIC has
/// no triggered receives (§IV-A2), so the progress thread emulates the
/// deferred semantics regardless of locality: it observes the trigger,
/// posts the receive into the matching engine, and mediates the
/// completion-counter update.
pub fn enqueue_recv(
    hctx: &mut HostCtx<World>,
    queue: usize,
    src_rank: usize,
    dst: BufSlice,
    tag: i32,
    comm: u16,
) -> Result<usize, StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let rank = w.queues[queue].rank;
        let req = w.new_request(core, "st_recv");
        let req_cell = w.request_done_cell(req);
        let q = &mut w.queues[queue];
        let threshold = q.epoch + 1;
        q.pending_since_start += 1;
        let trig = q.trig_ctr;
        let comp = q.comp_ctr;

        core.on_ge(
            trig,
            threshold,
            format!("progress r{rank} ST recv"),
            Box::new(move |w, core| {
                let cost = w.cost.progress_wakeup + w.cost.progress_per_op;
                let at = mpi::progress_charge(w, core, rank, cost);
                core.schedule_at(
                    at,
                    Box::new(move |w, core| {
                        let done = Done {
                            cells: vec![req_cell],
                            cb: Some(Box::new(move |w, core| {
                                let c = w.cost.progress_completion;
                                let at = mpi::progress_charge(w, core, rank, c);
                                // Typed event path, as in enqueue_send.
                                core.schedule_cell_add_at(at, comp, 1);
                            })),
                        };
                        mpi::post_recv(
                            w,
                            core,
                            rank,
                            SrcSel::Rank(src_rank),
                            TagSel::Tag(tag),
                            comm,
                            dst,
                            done,
                        );
                    }),
                );
            }),
        );
        Ok(req)
    })
}

/// Convenience guard: ST does not allow wildcards (§III-D). Callers that
/// accept user-provided selectors should validate through this.
pub fn validate_selectors(src: SrcSel, tag: TagSel) -> Result<(), StError> {
    if src == SrcSel::Any || tag == TagSel::Any {
        return Err(StError::WildcardUnsupported);
    }
    Ok(())
}

/// `MPIX_Enqueue_start`: appends a `writeValue64` to the queue's GPU
/// stream. When the CP executes it (in stream order), the trigger counter
/// advances to the new epoch and every operation enqueued since the last
/// start executes (batched trigger, §III-B item 3).
pub fn enqueue_start(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let (call, enq) = hctx.with(|w, _| (w.cost.host_enqueue_call, w.cost.kernel_enqueue));
    hctx.advance(call + enq);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &mut w.queues[queue];
        q.epoch += 1;
        q.started_total += q.pending_since_start;
        q.pending_since_start = 0;
        let op = StreamOp::WriteValue64 {
            cell: q.trig_ctr,
            value: q.epoch,
            mode: WriteMode::Set,
            flavor: q.flavor,
        };
        let sid = q.stream;
        gpu::enqueue(w, core, sid, op);
        Ok(())
    })
}

/// `MPIX_Enqueue_wait`: appends a `waitValue64` on the completion counter
/// to the queue's GPU stream; the *stream* stalls until all started
/// operations complete. Host-asynchronous (§III-B2 item 3).
pub fn enqueue_wait(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let (call, enq) = hctx.with(|w, _| (w.cost.host_enqueue_call, w.cost.kernel_enqueue));
    hctx.advance(call + enq);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &w.queues[queue];
        let op = StreamOp::WaitValue64 {
            cell: q.comp_ctr,
            threshold: q.started_total,
            flavor: q.flavor,
        };
        let sid = q.stream;
        gpu::enqueue(w, core, sid, op);
        Ok(())
    })
}

#[cfg(test)]
mod tests;
