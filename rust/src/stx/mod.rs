//! The paper's proposed interface: stream-triggered (ST) MPI operations.
//!
//! Implements §III's `MPIX_*` API over the simulated substrate, as the
//! **stx v2** typed surface:
//!
//! * [`Queue`] — a typed, owned handle to an `MPIX_Queue`
//!   (`MPIX_Create_queue` / `MPIX_Free_queue`): binds a GPU stream to an
//!   MPI queue object and holds two NIC hardware counters (one trigger,
//!   one completion) from the node's finite counter pool, mapped into
//!   GPU-CP-visible memory (§IV-A). Multiple queues per rank are legal
//!   and contend for NIC counters and DWQ descriptor slots.
//! * [`Queue::send`] / [`Queue::recv`] — `MPIX_Enqueue_send/recv`:
//!   deferred communication descriptors, FIFO per queue, asynchronous
//!   w.r.t. the host. Each inter-node send reserves a DWQ slot until its
//!   trigger fires; a full DWQ fails the call ([`StError::DwqFull`])
//!   without leaking any resource.
//! * [`Queue::start`] — `MPIX_Enqueue_start`: appends a stream-memory
//!   `writeValue64`; when the GPU CP executes it, the trigger-counter
//!   write fires **all** operations enqueued since the previous start
//!   (batching, §III-A footnote).
//! * [`Queue::wait`] — `MPIX_Enqueue_wait`: appends a `waitValue64` on
//!   the completion counter, stalling the *stream* (never the host).
//! * [`CommPlan`] — the persistent, build-once / start-many layer the
//!   MPI+X triggering-API surveys converge on: a [`CommPlanBuilder`]
//!   records a pattern of sends/receives (and KT hooks) once, validates
//!   selectors eagerly, allocates persistent requests, and then every
//!   iteration is [`CommPlan::round`] / [`CommPlan::complete`] /
//!   [`CommPlan::drain`] — no per-iteration descriptor allocation, and
//!   the host baseline, ST, ST-shader, KT, and GI variants all run
//!   through the same plan object.
//!
//! Routing mirrors §IV faithfully for the paper's ST variants:
//! * inter-node sends → NIC DWQ triggered sends (full hardware offload);
//! * ST receives (any locality) and all intra-node traffic → emulated by
//!   the per-process progress thread, charged on its serial timeline;
//! * inter-node rendezvous sends get a small progress-thread assist for
//!   completion handling (§V-E).
//!
//! The [`Variant::KernelTriggered`] path additionally completes the
//! *receive* half of the offload story (the follow-on work, arXiv
//! 2306.15773 / 2406.05594): receives on a KT queue ride NIC
//! **triggered-receive descriptors** ([`crate::nic::post_triggered_recv`])
//! — armed against the queue's trigger counter, posted into the matching
//! engine by the NIC's list engine when the kernel's mid-window trigger
//! fires, completion-counted in hardware. No `ResumeHost`, no progress
//! thread anywhere on a KT receive. [`Queue::kt_recv`] goes one step
//! further: the kernel itself rings the doorbell with the receive
//! descriptor at a chosen fraction of its window (1.0 = epilogue), the
//! device-side dual of the prologue completion wait
//! ([`Queue::kt_wait`]). See DESIGN.md §Triggered receives.
//!
//! The [`Variant::GpuInitiated`] path completes the taxonomy (GICC /
//! NVSHMEM-style, arXiv 2503.24230): [`Queue::gi_send`] /
//! [`Queue::gi_recv`] / [`Queue::gi_wait`] record the pattern into a
//! [`crate::gpu::GiCtx`] whose kernel builds per-thread-block
//! command-ring descriptors itself — zero host arming cost, no trigger
//! counters, no pre-armed DWQ slots, but `cost.gi_descr_build_ns` of
//! device time per descriptor inside the kernel window (one descriptor
//! per [`crate::gpu::GI_CHUNK_BYTES`] of send payload). The NIC drains
//! the ring directly ([`crate::nic::gi_consume`]). See DESIGN.md
//! §GPU-initiated communication.
//!
//! Wildcards are rejected (§III-D): deferred operations require a
//! concrete source rank and tag, checked eagerly at plan-build time.
//!
//! **Recovery contract under fault injection** (`World::fault` set, see
//! [`crate::fault`]): every host completion drain ([`Queue::drain`],
//! [`CommPlan::drain`]) and stream completion wait ([`Queue::wait`])
//! arms a recovery watchdog. On expiry the watchdog retransmits every
//! dropped payload in the lost ledger and re-arms with exponential
//! backoff; after [`crate::fault::FaultSpec::max_retries`] rounds the
//! run either surfaces [`StError::DrainTimeout`] to the blocked host
//! (opt-in `timeout_error` mode, enabling [`Queue::free_after_timeout`]
//! force-release) or parks so the engine's stall detector emits a
//! structured [`crate::sim::StallReport`] — never a silent hang. On
//! no-fault runs the watchdog is never armed and the timeline is
//! bit-for-bit identical to earlier releases.
//!
//! Beyond the paper's ST API this module also hosts the **kernel-
//! triggered (KT)** hooks of the follow-on work (arXiv 2306.15773):
//! [`Queue::kt_start`] folds the trigger write into a kernel's execution
//! window instead of appending a `writeValue64`, [`Queue::kt_wait`] folds
//! the completion wait into a kernel's prologue, and [`Queue::drain`] is
//! the one host-side wait a KT timed region performs (at its very end).
//! [`Variant`] names the resulting axis every experiment sweeps.
//!
//! The v1 free-function surface (`create_queue`, `enqueue_send`, …,
//! keyed by raw `usize` queue ids) completed its one-release
//! `#[deprecated]` migration window and has been removed; the typed
//! [`Queue`]/[`CommPlan`] API is the only surface (DESIGN.md §stx v2
//! keeps the migration table for reference).
#![deny(missing_docs)]

use crate::costmodel::MemOpFlavor;
use crate::gpu::{
    self, host_enqueue, stream_synchronize, GiCtx, KernelCtx, KernelPayload, KernelSpec, StreamId,
    StreamOp, WriteMode,
};
use crate::mpi::{self, SrcSel, TagSel};
use crate::nic::{self, BufSlice, Done, DwqOrigin, Envelope};
use crate::sim::{CellId, HostCtx};
use crate::world::World;

/// The communication-variant axis every experiment and workload sweeps:
/// *who drives the control path* of each communication step.
///
/// * [`Variant::Host`] — GPU-aware MPI baseline: the host synchronizes
///   at every kernel boundary and posts sends itself (paper Fig. 1).
/// * [`Variant::StreamTriggered`] / [`Variant::StreamTriggeredShader`]
///   — the paper's ST path: `MPIX_Enqueue_*` deferred operations whose
///   trigger and completion ride `writeValue64`/`waitValue64` stream
///   memory ops executed by the GPU CP between kernels (Fig. 2), with
///   the stock HIP or the hand-coded shader memop flavor (§V-F).
/// * [`Variant::KernelTriggered`] — the follow-on KT path (arXiv
///   2306.15773): triggers fire from *inside* running kernels
///   ([`crate::gpu::KernelCtx`]) and completion waits fold into the
///   next kernel's prologue, so an iteration pays no `enqueue_start`
///   memop and no `MPIX_Enqueue_waitall`-style stream stall at all —
///   completion rides the kernel's own tail.
/// * [`Variant::GpuInitiated`] — the taxonomy's fourth shape (GICC /
///   NVSHMEM-style, arXiv 2503.24230 §GPU-initiated): device threads
///   build and post the communication descriptors *themselves* into
///   per-thread-block command rings ([`crate::gpu::GiCtx`]). No host
///   arming, no trigger counters, no pre-armed DWQ slots — but every
///   message pays `cost.gi_descr_build_ns` per ring descriptor inside
///   the kernel window, so GI wins at small-message/high-rate and KT
///   at large-message/pre-plannable (the `figgi` crossover).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// GPU-aware MPI: host synchronizes at kernel boundaries.
    Host,
    /// Stream-triggered with HIP stream memory operations.
    StreamTriggered,
    /// ST with hand-coded shader stream memory operations (§V-F).
    StreamTriggeredShader,
    /// Kernel-triggered: triggers fire from inside running kernels.
    KernelTriggered,
    /// GPU-initiated: device threads build and post descriptors into
    /// command rings; the NIC consumes them without pre-armed DWQ slots.
    GpuInitiated,
}

impl Variant {
    /// Stable short name used by reports, campaign grids, and the CLI.
    pub fn name(self) -> &'static str {
        match self {
            Variant::Host => "baseline",
            Variant::StreamTriggered => "st",
            Variant::StreamTriggeredShader => "st-shader",
            Variant::KernelTriggered => "kt",
            Variant::GpuInitiated => "gi",
        }
    }

    /// Parse a report/CLI name — the inverse of [`Variant::name`]
    /// (accepts the legacy `shader` alias).
    pub fn parse(s: &str) -> Option<Variant> {
        Some(match s {
            "baseline" => Variant::Host,
            "st" => Variant::StreamTriggered,
            "st-shader" | "shader" => Variant::StreamTriggeredShader,
            "kt" => Variant::KernelTriggered,
            "gi" => Variant::GpuInitiated,
            _ => return None,
        })
    }

    /// Stream-memop flavor this variant binds its queue with (KT and GI
    /// queues keep the HIP flavor: their hot paths never execute a
    /// memop).
    pub fn flavor(self) -> MemOpFlavor {
        match self {
            Variant::StreamTriggeredShader => MemOpFlavor::Shader,
            _ => MemOpFlavor::Hip,
        }
    }

    /// True for every variant that needs an `MPIX_Queue` (all but
    /// [`Variant::Host`]).
    pub fn uses_queue(self) -> bool {
        self != Variant::Host
    }

    /// All variants, in report order.
    pub fn all() -> [Variant; 5] {
        [
            Variant::Host,
            Variant::StreamTriggered,
            Variant::StreamTriggeredShader,
            Variant::KernelTriggered,
            Variant::GpuInitiated,
        ]
    }
}

/// Default fraction of a kernel's execution window at which KT triggers
/// fire: late enough that the data the released sends cover has been
/// written (numerics commit at body start; 0.9 models firing from the
/// kernel's last wavefront), early enough to overlap the NIC trigger
/// handshake with the kernel tail.
pub const KT_TRIGGER_FRAC: f64 = 0.9;

/// Errors surfaced to the application (mirrors MPI error classes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StError {
    /// Deferred operations do not support `MPI_ANY_SOURCE`/`MPI_ANY_TAG`
    /// (paper §III-D).
    WildcardUnsupported,
    /// The `MPIX_Queue` with this id was already freed.
    QueueFreed(usize),
    /// `MPIX_Free_queue` while this many operations are incomplete.
    QueueBusy(u64),
    /// This node's NIC hardware-counter pool is exhausted
    /// (`cost.nic_counter_limit`); free a queue to reclaim capacity.
    CountersExhausted(usize),
    /// This node's deferred-work queue has no free descriptor slot
    /// (`cost.dwq_slots_per_nic`); the failed call released everything it
    /// had allocated. Plans absorb this by waiting for the next release.
    DwqFull(usize),
    /// A [`CommPlan`] recorded deferred operations but was built without
    /// any [`Queue`].
    PlanWithoutQueue,
    /// A [`CommPlan`] was built over a queue belonging to another rank.
    ForeignQueue(usize),
    /// A watchdog-supervised drain (fault runs with
    /// [`crate::fault::FaultSpec::timeout_error`] set) exhausted its
    /// retransmission budget with operations still incomplete. The queue
    /// is still live; [`Queue::free_after_timeout`] force-releases its
    /// resources.
    DrainTimeout {
        /// The queue whose drain timed out.
        queue: usize,
        /// Started-but-incomplete operations at the final check.
        outstanding: u64,
    },
}

impl std::fmt::Display for StError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StError::WildcardUnsupported => {
                write!(f, "ST operations do not support MPI_ANY_SOURCE/MPI_ANY_TAG (paper §III-D)")
            }
            StError::QueueFreed(q) => write!(f, "MPIX_Queue {q} was freed"),
            StError::QueueBusy(n) => {
                write!(f, "MPIX_Free_queue while {n} enqueued operations are incomplete")
            }
            StError::CountersExhausted(node) => {
                write!(f, "NIC {node}: hardware counter pool exhausted (free a queue first)")
            }
            StError::DwqFull(node) => {
                write!(f, "NIC {node}: deferred-work queue has no free descriptor slot")
            }
            StError::PlanWithoutQueue => {
                write!(f, "CommPlan records deferred operations but was built without a queue")
            }
            StError::ForeignQueue(q) => {
                write!(f, "CommPlan built over queue {q}, which belongs to another rank")
            }
            StError::DrainTimeout { queue, outstanding } => write!(
                f,
                "MPIX_Queue {queue} drain timed out with {outstanding} operation(s) \
                 incomplete after watchdog retries"
            ),
        }
    }
}

impl std::error::Error for StError {}

/// `MPIX_Queue`: maps a GPU stream to the MPI runtime and batches ST ops.
/// This is the world-side record; applications hold a typed [`Queue`]
/// handle over it.
pub struct MpixQueue {
    /// Owning MPI rank.
    pub rank: usize,
    /// The GPU stream this queue is bound to.
    pub stream: StreamId,
    /// The communication variant the queue was created for. Receives on
    /// [`Variant::KernelTriggered`] queues ride NIC triggered-receive
    /// descriptors; every other variant keeps the paper's
    /// progress-thread emulation (§IV-A2).
    pub variant: Variant,
    /// NIC hardware trigger counter (GPU-CP visible).
    pub trig_ctr: CellId,
    /// NIC hardware completion counter (GPU-CP visible).
    pub comp_ctr: CellId,
    /// Stream memory op implementation used for this queue's
    /// start/wait operations (derived from the variant, §V-F).
    pub flavor: MemOpFlavor,
    /// Number of `enqueue_start` calls so far == the value the next
    /// trigger write stores.
    pub epoch: u64,
    /// Ops enqueued since the last start (they trigger at `epoch + 1`).
    pub pending_since_start: u64,
    /// Total ops covered by issued starts (the wait threshold).
    pub started_total: u64,
    /// Deferred descriptors this queue posted to its NIC's DWQ.
    pub dwq_posts: u64,
    /// Times an op on this queue had to wait for a free DWQ slot
    /// (multi-queue contention signal, surfaced by campaign reports).
    pub dwq_slot_waits: u64,
    /// Set once the queue is freed; every later use is an error.
    pub freed: bool,
}

// ---------------------------------------------------------------------
// Internals shared by the typed API and the plan layer
// ---------------------------------------------------------------------

fn create_queue_impl(
    hctx: &mut HostCtx<World>,
    rank: usize,
    stream: StreamId,
    variant: Variant,
) -> Result<usize, StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        let node = w.topo.node_of(rank);
        let qid = w.queues.len();
        let trig_ctr = nic::alloc_counter(w, core, node, &format!("q{qid}.trig"))
            .ok_or(StError::CountersExhausted(node))?;
        let comp_ctr = match nic::alloc_counter(w, core, node, &format!("q{qid}.comp")) {
            Some(c) => c,
            None => {
                // Leak-free error path: return the trigger counter the
                // half-built queue already held.
                nic::release_counter(w, node);
                return Err(StError::CountersExhausted(node));
            }
        };
        w.queues.push(MpixQueue {
            rank,
            stream,
            variant,
            trig_ctr,
            comp_ctr,
            flavor: variant.flavor(),
            epoch: 0,
            pending_since_start: 0,
            started_total: 0,
            dwq_posts: 0,
            dwq_slot_waits: 0,
            freed: false,
        });
        Ok(qid)
    })
}

fn free_queue_impl(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        let q = &w.queues[queue];
        if q.freed {
            return Err(StError::QueueFreed(queue));
        }
        let completed = core.cell(q.comp_ctr);
        // Enqueued-but-unstarted ops count as incomplete too: they hold
        // armed waiters and (inter-node sends) DWQ descriptor slots that
        // only a fired trigger releases — freeing now would leak them.
        let outstanding = q.started_total.saturating_sub(completed) + q.pending_since_start;
        if outstanding > 0 {
            return Err(StError::QueueBusy(outstanding));
        }
        let node = w.topo.node_of(q.rank);
        w.queues[queue].freed = true;
        // Both hardware counters go back to the NIC's finite pool.
        nic::release_counter(w, node);
        nic::release_counter(w, node);
        Ok(())
    })
}

/// Freed-queue check plus DWQ slot reservation for one deferred send.
/// Once this returns `Ok`, arming the operation cannot fail — so error
/// paths never leave a request, counter bump, or slot behind.
fn reserve_send_slot(
    w: &mut World,
    core: &mut crate::world::Ctx,
    queue: usize,
    dst: usize,
) -> Result<(), StError> {
    if w.queues[queue].freed {
        return Err(StError::QueueFreed(queue));
    }
    let rank = w.queues[queue].rank;
    if !w.topo.same_node(rank, dst) {
        let node = w.topo.node_of(rank);
        nic::dwq_reserve(w, core, node).map_err(|f| StError::DwqFull(f.node))?;
        w.queues[queue].dwq_posts += 1;
    }
    Ok(())
}

/// Freed-queue check plus DWQ slot reservation for one deferred receive.
/// Hardware triggered-receive descriptors ([`Variant::KernelTriggered`]
/// queues) sit in the NIC's deferred-work queue exactly like triggered
/// sends, so they consume a slot until their trigger fires;
/// progress-emulated receives (every other variant) hold no NIC
/// resource. As with sends, once this returns `Ok` the arm cannot fail.
fn reserve_recv_slot(
    w: &mut World,
    core: &mut crate::world::Ctx,
    queue: usize,
) -> Result<(), StError> {
    if w.queues[queue].freed {
        return Err(StError::QueueFreed(queue));
    }
    if w.queues[queue].variant == Variant::KernelTriggered {
        let node = w.topo.node_of(w.queues[queue].rank);
        nic::dwq_reserve(w, core, node).map_err(|f| StError::DwqFull(f.node))?;
        w.queues[queue].dwq_posts += 1;
    }
    Ok(())
}

/// Arm one deferred send on `queue` for the next trigger epoch. The
/// caller has already passed [`reserve_send_slot`]; this cannot fail.
#[allow(clippy::too_many_arguments)]
fn arm_send(
    w: &mut World,
    core: &mut crate::world::Ctx,
    queue: usize,
    dst: usize,
    src: BufSlice,
    tag: i32,
    comm: u16,
    req_cell: CellId,
) {
    let rank = w.queues[queue].rank;
    let q = &mut w.queues[queue];
    let threshold = q.epoch + 1;
    q.pending_since_start += 1;
    let trig = q.trig_ctr;
    let comp = q.comp_ctr;
    let env = Envelope { src_rank: rank, dst_rank: dst, tag, comm, elems: src.elems };

    if w.topo.same_node(rank, dst) {
        // No intra-node deferred-work hardware exists (§IV-B): the
        // progress thread watches the trigger counter and performs the
        // send itself.
        core.on_ge(
            trig,
            threshold,
            format!("progress r{rank} ST intra send"),
            Box::new(move |w, core| {
                let cost = w.cost.progress_wakeup + w.cost.progress_per_op;
                let at = mpi::progress_charge(w, core, rank, cost);
                core.schedule_at(
                    at,
                    Box::new(move |w, core| {
                        // Completion counter updates also flow through
                        // the progress thread.
                        let done = Done {
                            cells: vec![req_cell],
                            cb: Some(Box::new(move |w, core| {
                                let c = w.cost.progress_completion;
                                let at = mpi::progress_charge(w, core, rank, c);
                                // Typed event: the completion-counter
                                // update needs no closure.
                                core.schedule_cell_add_at(at, comp, 1);
                            })),
                        };
                        mpi::do_send(w, core, env, src, done);
                    }),
                );
            }),
        );
    } else {
        // Full NIC offload via a DWQ triggered send (§IV-A1). The NIC
        // bumps the completion counter in hardware; rendezvous sends
        // need a small progress-thread assist (§V-E).
        let rendezvous = w.cost.is_rendezvous(src.bytes());
        let done = Done {
            cells: vec![req_cell, comp],
            cb: if rendezvous {
                Some(Box::new(move |w, core| {
                    let c = w.cost.progress_rendezvous_assist;
                    let _ = mpi::progress_charge(w, core, rank, c);
                }))
            } else {
                None
            },
        };
        let origin = DwqOrigin {
            queue: Some(queue),
            label: format!("q{queue} epoch {threshold} send r{rank}->r{dst} tag {tag}"),
        };
        nic::post_triggered_send(w, core, trig, threshold, env, src, done, Some(origin));
    }
}

/// Completion actions of a hardware-posted receive, shared by the
/// DWQ-triggered and kernel-doorbell paths: complete the request at
/// landing, and let the NIC bump the completion counter
/// `nic_completion` later (a typed event — no closure beyond this hop).
fn hw_recv_done(req_cell: CellId, comp: CellId) -> Done {
    Done {
        cells: vec![req_cell],
        cb: Some(Box::new(move |w, core| {
            let c = w.cost.nic_completion;
            core.schedule_cell_add(c, comp, 1);
        })),
    }
}

/// Arm one deferred receive on `queue` for the next trigger epoch.
///
/// Two hardware stories, keyed by the queue's variant:
///
/// * [`Variant::KernelTriggered`] — the NIC's triggered-receive path
///   ([`crate::nic::post_triggered_recv`], the receive half of the
///   offload story): the descriptor is armed in the deferred-work queue,
///   the trigger fire hands it to the NIC list engine, matched payloads
///   land without any host involvement, and the completion counter is
///   bumped in hardware. The caller has already passed
///   [`reserve_recv_slot`].
/// * everything else — the paper's testbed lacks triggered receives
///   (§IV-A2), so the progress thread emulates the deferred semantics
///   regardless of locality: it observes the trigger, posts the receive
///   into the matching engine, and mediates the completion-counter
///   update.
#[allow(clippy::too_many_arguments)]
fn arm_recv(
    w: &mut World,
    core: &mut crate::world::Ctx,
    queue: usize,
    src_rank: usize,
    dst: BufSlice,
    tag: i32,
    comm: u16,
    req_cell: CellId,
) {
    let rank = w.queues[queue].rank;
    let q = &mut w.queues[queue];
    let threshold = q.epoch + 1;
    q.pending_since_start += 1;
    let trig = q.trig_ctr;
    let comp = q.comp_ctr;

    if q.variant == Variant::KernelTriggered {
        // Hardware triggered receive: the NIC bumps the completion
        // counter itself once the matched payload has landed.
        let done = hw_recv_done(req_cell, comp);
        let origin = DwqOrigin {
            queue: Some(queue),
            label: format!("q{queue} epoch {threshold} recv r{rank}<-r{src_rank} tag {tag}"),
        };
        nic::post_triggered_recv(
            w, core, trig, threshold, rank, src_rank, tag, comm, dst, done,
            Some(origin),
        );
        return;
    }

    core.on_ge(
        trig,
        threshold,
        format!("progress r{rank} ST recv"),
        Box::new(move |w, core| {
            let cost = w.cost.progress_wakeup + w.cost.progress_per_op;
            let at = mpi::progress_charge(w, core, rank, cost);
            core.schedule_at(
                at,
                Box::new(move |w, core| {
                    let done = Done {
                        cells: vec![req_cell],
                        cb: Some(Box::new(move |w, core| {
                            let c = w.cost.progress_completion;
                            let at = mpi::progress_charge(w, core, rank, c);
                            // Typed event path, as in arm_send.
                            core.schedule_cell_add_at(at, comp, 1);
                        })),
                    };
                    mpi::post_recv(
                        w,
                        core,
                        rank,
                        SrcSel::Rank(src_rank),
                        TagSel::Tag(tag),
                        comm,
                        dst,
                        done,
                    );
                }),
            );
        }),
    );
}

fn send_impl(
    hctx: &mut HostCtx<World>,
    queue: usize,
    dst: usize,
    src: BufSlice,
    tag: i32,
    comm: u16,
) -> Result<usize, StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        reserve_send_slot(w, core, queue, dst)?;
        let req = w.new_request(core, "st_send");
        let req_cell = w.request_done_cell(req);
        arm_send(w, core, queue, dst, src, tag, comm, req_cell);
        Ok(req)
    })
}

fn recv_impl(
    hctx: &mut HostCtx<World>,
    queue: usize,
    src_rank: usize,
    dst: BufSlice,
    tag: i32,
    comm: u16,
) -> Result<usize, StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        reserve_recv_slot(w, core, queue)?;
        let req = w.new_request(core, "st_recv");
        let req_cell = w.request_done_cell(req);
        arm_recv(w, core, queue, src_rank, dst, tag, comm, req_cell);
        Ok(req)
    })
}

/// Fold a device-initiated posted receive into `kernel`: at `frac` of
/// its window (1.0 = the epilogue wavefront) the kernel rings the NIC
/// doorbell with the descriptor, the list engine appends it to the
/// matching engine, and the completion counter is bumped in hardware
/// when the matched payload lands. The op joins `started_total`
/// directly — no trigger covers it — so `kt_wait`/`drain` thresholds
/// taken after this call include it.
#[allow(clippy::too_many_arguments)]
fn kt_recv_impl(
    hctx: &mut HostCtx<World>,
    queue: usize,
    kernel: &mut KernelCtx,
    frac: f64,
    src_rank: usize,
    dst: BufSlice,
    tag: i32,
    comm: u16,
) -> Result<usize, StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let req = w.new_request(core, "kt_recv");
        let req_cell = w.request_done_cell(req);
        let q = &mut w.queues[queue];
        let rank = q.rank;
        let comp = q.comp_ctr;
        q.started_total += 1;
        let done = hw_recv_done(req_cell, comp);
        kernel.kt_recv(frac, gpu::KtRecv { rank, src_rank, tag, comm, dst, done });
        Ok(req)
    })
}

/// Record one GPU-initiated send into a kernel's descriptor plan: the
/// kernel's closing wavefronts build [`crate::gpu::gi_chunks`] command-
/// ring descriptors (one per [`crate::gpu::GI_CHUNK_BYTES`] of payload)
/// and the NIC executes the send on consuming the last one, routed by
/// locality exactly like a fired triggered send. The op joins
/// `started_total` directly — GI uses no trigger epochs — and charges
/// **zero host time**: the pattern ships as kernel arguments, which is
/// the host-side saving GI buys over KT's per-op arming calls.
/// Rendezvous inter-node sends keep the small progress-thread completion
/// assist (§V-E): descriptor *initiation* moved to the device, but the
/// NIC still cannot finish a rendezvous alone.
#[allow(clippy::too_many_arguments)]
fn gi_arm_send(
    w: &mut World,
    queue: usize,
    gi: &mut GiCtx,
    dst: usize,
    src: BufSlice,
    tag: i32,
    comm: u16,
    req_cell: CellId,
) {
    let rendezvous = w.cost.is_rendezvous(src.bytes());
    let inter = !w.topo.same_node(w.queues[queue].rank, dst);
    let q = &mut w.queues[queue];
    q.started_total += 1;
    let rank = q.rank;
    let comp = q.comp_ctr;
    let env = Envelope { src_rank: rank, dst_rank: dst, tag, comm, elems: src.elems };
    let done = Done {
        cells: vec![req_cell, comp],
        cb: if inter && rendezvous {
            Some(Box::new(move |w, core| {
                let c = w.cost.progress_rendezvous_assist;
                let _ = mpi::progress_charge(w, core, rank, c);
            }))
        } else {
            None
        },
    };
    gi.post(gpu::GiPost {
        chunks: gpu::gi_chunks(src.bytes() as u64),
        action: gpu::GiAction::Send { env, src, done },
    });
}

/// Record one GPU-initiated receive: a single fixed-size match entry in
/// the command ring (receives carry no payload, so they never chunk);
/// the NIC's list engine appends it to the matching engine on
/// consumption and the completion counter is bumped in hardware, like a
/// KT doorbell receive. Zero host time, joins `started_total` directly.
fn gi_arm_recv(
    w: &mut World,
    queue: usize,
    gi: &mut GiCtx,
    src_rank: usize,
    dst: BufSlice,
    tag: i32,
    comm: u16,
    req_cell: CellId,
) {
    let q = &mut w.queues[queue];
    q.started_total += 1;
    let rank = q.rank;
    let comp = q.comp_ctr;
    let done = hw_recv_done(req_cell, comp);
    gi.post(gpu::GiPost {
        chunks: 1,
        action: gpu::GiAction::Recv(gpu::KtRecv { rank, src_rank, tag, comm, dst, done }),
    });
}

fn gi_send_impl(
    hctx: &mut HostCtx<World>,
    queue: usize,
    gi: &mut GiCtx,
    dst: usize,
    src: BufSlice,
    tag: i32,
    comm: u16,
) -> Result<usize, StError> {
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let req = w.new_request(core, "gi_send");
        let req_cell = w.request_done_cell(req);
        gi_arm_send(w, queue, gi, dst, src, tag, comm, req_cell);
        Ok(req)
    })
}

fn gi_recv_impl(
    hctx: &mut HostCtx<World>,
    queue: usize,
    gi: &mut GiCtx,
    src_rank: usize,
    dst: BufSlice,
    tag: i32,
    comm: u16,
) -> Result<usize, StError> {
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let req = w.new_request(core, "gi_recv");
        let req_cell = w.request_done_cell(req);
        gi_arm_recv(w, queue, gi, src_rank, dst, tag, comm, req_cell);
        Ok(req)
    })
}

/// Fold this queue's completion wait into a GI kernel's prologue
/// (threshold snapshot at call time, like [`kt_wait_impl`]) — zero host
/// time, the threshold ships as a kernel argument.
fn gi_wait_impl(hctx: &mut HostCtx<World>, queue: usize, gi: &mut GiCtx) -> Result<(), StError> {
    hctx.with(|w, _| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &w.queues[queue];
        gi.wait_ge(q.comp_ctr, q.started_total);
        Ok(())
    })
}

fn start_impl(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let (call, enq) = hctx.with(|w, _| (w.cost.host_enqueue_call, w.cost.kernel_enqueue));
    hctx.advance(call + enq);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &mut w.queues[queue];
        q.epoch += 1;
        q.started_total += q.pending_since_start;
        q.pending_since_start = 0;
        let op = StreamOp::WriteValue64 {
            cell: q.trig_ctr,
            value: q.epoch,
            mode: WriteMode::Set,
            flavor: q.flavor,
        };
        let sid = q.stream;
        gpu::enqueue(w, core, sid, op);
        Ok(())
    })
}

fn wait_impl(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let (call, enq) = hctx.with(|w, _| (w.cost.host_enqueue_call, w.cost.kernel_enqueue));
    hctx.advance(call + enq);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &w.queues[queue];
        let (comp, target) = (q.comp_ctr, q.started_total);
        let op = StreamOp::WaitValue64 { cell: comp, threshold: target, flavor: q.flavor };
        let sid = q.stream;
        // Under fault injection the stream-side completion wait is
        // watchdog-supervised too: the *stream* parks on the counter
        // (never the host), so the watchdog contributes only its
        // retransmit half — no gate. A stream stall that outlives every
        // retry surfaces as a StallReport naming the waitValue64.
        if w.fault.is_some() {
            arm_watchdog(w, core, comp, target, None, 0);
        }
        gpu::enqueue(w, core, sid, op);
        Ok(())
    })
}

fn kt_start_impl(
    hctx: &mut HostCtx<World>,
    queue: usize,
    kernel: &mut KernelCtx,
    frac: f64,
) -> Result<(), StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, _| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &mut w.queues[queue];
        q.epoch += 1;
        q.started_total += q.pending_since_start;
        q.pending_since_start = 0;
        kernel.kt_counter_inc(frac, q.trig_ctr, 1);
        Ok(())
    })
}

fn kt_wait_impl(
    hctx: &mut HostCtx<World>,
    queue: usize,
    kernel: &mut KernelCtx,
) -> Result<(), StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, _| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &w.queues[queue];
        kernel.wait_ge(q.comp_ctr, q.started_total);
        Ok(())
    })
}

fn drain_impl(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let (cell, threshold, cost, fault) = hctx.with(|w, _| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &w.queues[queue];
        Ok((q.comp_ctr, q.started_total, w.cost.host_wait_overhead, w.fault.is_some()))
    })?;
    hctx.advance(cost);
    if !fault {
        hctx.wait_ge(cell, threshold, "MPIX queue drain");
        return Ok(());
    }
    // Watchdog-supervised drain (fault runs only): the host parks on a
    // gate that opens either when the completion counter reaches its
    // threshold or — in `timeout_error` mode — when the watchdog
    // exhausts its retransmission budget, so the host can observe
    // `StError::DrainTimeout` instead of parking forever.
    let gate = hctx.with(|w, core| {
        let gate = core.new_cell(format!("q{queue}.drain.gate"), 0);
        core.on_ge(
            cell,
            threshold,
            format!("q{queue} drain watchdog gate"),
            Box::new(move |_w, core| {
                core.add_cell(gate, 1);
            }),
        );
        arm_watchdog(w, core, cell, threshold, Some(gate), 0);
        gate
    });
    hctx.wait_ge(gate, 1, "MPIX queue drain (watchdog)");
    let outstanding = hctx.with(|_w, core| threshold.saturating_sub(core.cell(cell)));
    if outstanding > 0 {
        return Err(StError::DrainTimeout { queue, outstanding });
    }
    Ok(())
}

/// One arm of the recovery watchdog (fault runs only). After the spec's
/// timeout — doubled on every attempt, exponential backoff — check the
/// completion counter; if it is still short of `target`, retransmit
/// every payload in the lost ledger ([`crate::nic::retransmit`], which
/// bypasses injection), repair any poisoned trigger counters
/// ([`crate::fault::PoisonedCounter`] — lost doorbell bits replayed
/// without regressing the counter), and re-arm. After
/// [`crate::fault::FaultSpec::max_retries`] attempts the watchdog
/// records a timeout and either opens `gate` anyway (`timeout_error`
/// mode: the blocked drain observes [`StError::DrainTimeout`] and can
/// force-release resources) or goes quiet, in which case the event heap
/// drains and the engine reports a [`crate::sim::StallReport`] — never
/// a silent hang, never a panic.
fn arm_watchdog(
    w: &mut World,
    core: &mut crate::world::Ctx,
    comp: CellId,
    target: u64,
    gate: Option<CellId>,
    attempt: u32,
) {
    let Some(f) = w.fault.as_ref() else { return };
    let spec = f.plan.spec();
    let delay = spec.watchdog_ns.saturating_mul(1u64 << attempt.min(20));
    let max_retries = spec.max_retries;
    let timeout_error = spec.timeout_error;
    core.schedule(
        delay,
        Box::new(move |w, core| {
            if core.cell(comp) >= target {
                return; // completed while the watchdog slept
            }
            if attempt < max_retries {
                let lost = match w.fault.as_mut() {
                    Some(f) => std::mem::take(&mut f.lost),
                    None => Vec::new(),
                };
                for m in lost {
                    nic::retransmit(w, core, m);
                }
                // Repair poisoned trigger counters (lost doorbell bits).
                // Add-mode poisons replay the lost delta — always safe
                // for a monotonic counter. Set-mode poisons rewrite the
                // intended value, but only if the counter is still short
                // of it: a later set may already have advanced past the
                // poisoned epoch, and the repair must never regress it.
                let poisoned = match w.fault.as_mut() {
                    Some(f) => std::mem::take(&mut f.poisoned),
                    None => Vec::new(),
                };
                for p in poisoned {
                    w.armed.clear(p.token);
                    if p.lost > 0 {
                        core.add_cell(p.cell, p.lost);
                        w.metrics.retries += 1;
                    } else if core.cell(p.cell) < p.intended {
                        core.write_cell(p.cell, p.intended);
                        w.metrics.retries += 1;
                    }
                }
                arm_watchdog(w, core, comp, target, gate, attempt + 1);
            } else {
                w.metrics.timeouts += 1;
                if let (true, Some(g)) = (timeout_error, gate) {
                    core.add_cell(g, 1);
                }
            }
        }),
    );
}

/// Force-release a queue abandoned after a watchdog timeout: skip the
/// busy check, cancel every DWQ descriptor the queue still has armed
/// (crediting the released cell so producers blocked on a full DWQ see
/// the slots come back), and return both hardware counters to the NIC
/// pool. Returns the number of cancelled descriptors. Only sound for
/// queues whose triggers will never fire.
fn force_free_impl(hctx: &mut HostCtx<World>, queue: usize) -> Result<u64, StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let node = w.topo.node_of(w.queues[queue].rank);
        w.queues[queue].freed = true;
        let orphans = w.armed.drain_queue(queue);
        let n = orphans.len() as u64;
        for e in &orphans {
            nic::dwq_cancel(w, core, e.node);
        }
        nic::release_counter(w, node);
        nic::release_counter(w, node);
        Ok(n)
    })
}

/// Charge one enqueue call, then run `attempt` (a reserve-and-arm
/// closure) until it arms, absorbing DWQ backpressure: a full
/// deferred-work queue stalls the host until the NIC releases a
/// descriptor instead of failing. The stall is recorded once per
/// logical wait — on `qid` and globally — even if a freed slot is
/// snatched by a concurrent producer and the wait repeats. Shared by
/// the plan layer's send and receive arms so their stall semantics
/// cannot diverge.
fn arm_with_backpressure(
    hctx: &mut HostCtx<World>,
    qid: usize,
    mut attempt: impl FnMut(&mut World, &mut crate::world::Ctx) -> Result<(), StError>,
) -> Result<(), StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    let mut stalled = false;
    loop {
        match hctx.with(&mut attempt) {
            Err(StError::DwqFull(node)) => {
                let rank = if !stalled {
                    stalled = true;
                    hctx.with(|w, _| {
                        w.metrics.dwq_slot_waits += 1;
                        w.queues[qid].dwq_slot_waits += 1;
                        w.queues[qid].rank
                    })
                } else {
                    hctx.with(|w, _| w.queues[qid].rank)
                };
                wait_for_dwq_slot(hctx, node, rank);
            }
            other => return other,
        }
    }
}

/// Block the host until `node`'s deferred-work queue releases a
/// descriptor. The *caller* records the stall (once per logical wait,
/// even if a released slot is lost to a concurrent producer and the
/// wait repeats).
fn wait_for_dwq_slot(hctx: &mut HostCtx<World>, node: usize, rank: usize) {
    let (cell, threshold, cap) = hctx.with(|w, core| {
        let cell = nic::dwq_released_cell(w, core, node);
        let cap = w.cost.dwq_slots_per_nic as u64;
        // A slot frees once released >= posted - capacity + 1 (the DWQ
        // was full when we got here, so posted >= capacity).
        (cell, w.nics[node].dwq_posted + 1 - cap, cap)
    });
    // The wait description names the exhausted pool and its capacity so
    // a stall here (pre-armed demand exceeding dwq_slots_per_nic with no
    // fire in flight) yields a self-explanatory StallReport.
    let t0 = hctx.now();
    hctx.wait_ge(cell, threshold, &format!("stx DWQ slot on nic{node} (capacity {cap} exhausted)"));
    let dur = hctx.now() - t0;
    if dur > 0 {
        // Backpressure span for the trace: how long this rank's host sat
        // on the exhausted descriptor pool (the critical-path
        // `backpressure` bucket; see `crate::obs`).
        hctx.with(|_, core| {
            core.trace_push(crate::obs::Event::DwqWait {
                t0,
                dur,
                node: node as u32,
                rank: rank as u32,
            });
        });
    }
}

// ---------------------------------------------------------------------
// Queue: the typed, owned handle (stx v2)
// ---------------------------------------------------------------------

/// Typed, owned handle to an `MPIX_Queue` (stx v2). Carries its variant,
/// rank, and stream; the NIC resources it holds (two hardware counters)
/// return to the node's pool when the handle is [`Queue::free`]d. The
/// raw `usize` id behind the handle remains readable through
/// [`Queue::id`] for diagnostics.
#[derive(Debug)]
pub struct Queue {
    id: usize,
    rank: usize,
    stream: StreamId,
    variant: Variant,
}

/// Point-in-time per-queue statistics ([`Queue::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueStats {
    /// Deferred descriptors this queue posted to its NIC's DWQ.
    pub dwq_posts: u64,
    /// Times ops on this queue waited for a free DWQ descriptor slot.
    pub dwq_slot_waits: u64,
    /// Started-but-incomplete operations right now.
    pub outstanding: u64,
}

impl Queue {
    /// `MPIX_Create_queue`: bind `stream` to a new queue for `rank`,
    /// taking two hardware counters from the node's finite pool (the
    /// stream-memop flavor follows `variant`, §V-F). Fails with
    /// [`StError::CountersExhausted`] — leak-free — when the pool is dry.
    pub fn create(
        hctx: &mut HostCtx<World>,
        rank: usize,
        stream: StreamId,
        variant: Variant,
    ) -> Result<Queue, StError> {
        let id = create_queue_impl(hctx, rank, stream, variant)?;
        Ok(Queue { id, rank, stream, variant })
    }

    /// The raw world-side queue id (diagnostics and world-state
    /// inspection; the id indexes `World::queues`).
    pub fn id(&self) -> usize {
        self.id
    }

    /// The owning MPI rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The GPU stream this queue is bound to.
    pub fn stream(&self) -> StreamId {
        self.stream
    }

    /// The communication variant this queue was created for.
    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// `MPIX_Enqueue_send`: deferred tagged send. Returns a request id
    /// usable with host-side `mpi::wait` (§III-B2 item 4). Inter-node
    /// sends reserve a DWQ descriptor slot; a full DWQ fails with
    /// [`StError::DwqFull`] having released everything it allocated.
    pub fn send(
        &self,
        hctx: &mut HostCtx<World>,
        dst: usize,
        src: BufSlice,
        tag: i32,
        comm: u16,
    ) -> Result<usize, StError> {
        send_impl(hctx, self.id, dst, src, tag, comm)
    }

    /// `MPIX_Enqueue_recv`: deferred tagged receive. On a
    /// [`Variant::KernelTriggered`] queue this arms a NIC
    /// triggered-receive descriptor (hardware-posted into the matching
    /// engine when the trigger fires, hardware completion counting,
    /// no host or progress-thread involvement) and reserves a DWQ
    /// descriptor slot — a full DWQ fails with [`StError::DwqFull`],
    /// leak-free. On every other variant the receive is progress-thread
    /// emulated at any locality (§IV-A2), as on the paper's testbed.
    /// Returns a request id.
    pub fn recv(
        &self,
        hctx: &mut HostCtx<World>,
        src_rank: usize,
        dst: BufSlice,
        tag: i32,
        comm: u16,
    ) -> Result<usize, StError> {
        recv_impl(hctx, self.id, src_rank, dst, tag, comm)
    }

    /// `MPIX_Enqueue_start`: append the `writeValue64` trigger for every
    /// operation enqueued since the previous start (§III-B item 3).
    pub fn start(&self, hctx: &mut HostCtx<World>) -> Result<(), StError> {
        start_impl(hctx, self.id)
    }

    /// `MPIX_Enqueue_wait`: append a `waitValue64` on the completion
    /// counter; the *stream* stalls, never the host (§III-B2 item 3).
    pub fn wait(&self, hctx: &mut HostCtx<World>) -> Result<(), StError> {
        wait_impl(hctx, self.id)
    }

    /// Kernel-triggered start — the KT counterpart of [`Queue::start`]:
    /// the trigger-counter bump is folded into `kernel` and fires at
    /// `frac` of its execution window, so the NIC releases every
    /// operation enqueued since the previous start while the kernel is
    /// still running.
    ///
    /// The write is a device-scope atomic increment; CP starts write the
    /// absolute epoch. Both advance the counter to the same value, so ST
    /// and KT starts may be mixed on one queue.
    pub fn kt_start(
        &self,
        hctx: &mut HostCtx<World>,
        kernel: &mut KernelCtx,
        frac: f64,
    ) -> Result<(), StError> {
        kt_start_impl(hctx, self.id, kernel, frac)
    }

    /// Kernel-triggered wait — the KT counterpart of [`Queue::wait`]:
    /// the completion wait folds into `kernel`'s prologue (its first
    /// wavefront spins before the body runs), costing no CP memop.
    pub fn kt_wait(
        &self,
        hctx: &mut HostCtx<World>,
        kernel: &mut KernelCtx,
    ) -> Result<(), StError> {
        kt_wait_impl(hctx, self.id, kernel)
    }

    /// Kernel-triggered receive — the device-side dual of
    /// [`Queue::kt_wait`]'s prologue hook: at `frac` of `kernel`'s
    /// window (1.0 = the epilogue wavefront) the kernel itself rings
    /// the NIC doorbell with a posted-receive descriptor. The NIC's
    /// list engine appends it to the matching engine — early arrivals
    /// resolve through the unexpected-message queue — and bumps the
    /// completion counter in hardware when the payload lands. Counts
    /// toward `kt_wait`/[`Queue::drain`] thresholds taken after this
    /// call. Returns a request id usable with host-side `mpi::wait`.
    #[allow(clippy::too_many_arguments)]
    pub fn kt_recv(
        &self,
        hctx: &mut HostCtx<World>,
        kernel: &mut KernelCtx,
        frac: f64,
        src_rank: usize,
        dst: BufSlice,
        tag: i32,
        comm: u16,
    ) -> Result<usize, StError> {
        kt_recv_impl(hctx, self.id, kernel, frac, src_rank, dst, tag, comm)
    }

    /// GPU-initiated send — the GI counterpart of [`Queue::send`]: the
    /// message is recorded into `gi`'s descriptor plan, and the kernel
    /// the plan is attached to ([`crate::gpu::StreamOp::GiKernel`])
    /// builds its command-ring descriptors itself (one per
    /// [`crate::gpu::GI_CHUNK_BYTES`] of payload, each costing
    /// `cost.gi_descr_build_ns` inside the kernel window). No host
    /// arming cost, no trigger epoch, no DWQ slot. Returns a request id
    /// usable with host-side `mpi::wait`.
    pub fn gi_send(
        &self,
        hctx: &mut HostCtx<World>,
        gi: &mut GiCtx,
        dst: usize,
        src: BufSlice,
        tag: i32,
        comm: u16,
    ) -> Result<usize, StError> {
        gi_send_impl(hctx, self.id, gi, dst, src, tag, comm)
    }

    /// GPU-initiated receive — a single fixed-size match entry in the
    /// command ring; the NIC's list engine posts it into the matching
    /// engine on consumption, completion-counted in hardware. Zero host
    /// time, like [`Queue::gi_send`]. Returns a request id.
    pub fn gi_recv(
        &self,
        hctx: &mut HostCtx<World>,
        gi: &mut GiCtx,
        src_rank: usize,
        dst: BufSlice,
        tag: i32,
        comm: u16,
    ) -> Result<usize, StError> {
        gi_recv_impl(hctx, self.id, gi, src_rank, dst, tag, comm)
    }

    /// GPU-initiated completion wait — folds this queue's completion
    /// threshold (snapshot at call time) into a GI kernel's prologue,
    /// the GI counterpart of [`Queue::kt_wait`]. Zero host time: the
    /// threshold ships as a kernel argument.
    pub fn gi_wait(&self, hctx: &mut HostCtx<World>, gi: &mut GiCtx) -> Result<(), StError> {
        gi_wait_impl(hctx, self.id, gi)
    }

    /// Host-side completion drain: block the host until every started
    /// operation has completed. KT and GI timed regions call this once
    /// at their very end; it returns immediately on a quiet queue.
    pub fn drain(&self, hctx: &mut HostCtx<World>) -> Result<(), StError> {
        drain_impl(hctx, self.id)
    }

    /// Snapshot this queue's resource/contention counters.
    pub fn stats(&self, hctx: &mut HostCtx<World>) -> QueueStats {
        let id = self.id;
        hctx.with(|w, core| {
            let q = &w.queues[id];
            QueueStats {
                dwq_posts: q.dwq_posts,
                dwq_slot_waits: q.dwq_slot_waits,
                outstanding: q.started_total.saturating_sub(core.cell(q.comp_ctr)),
            }
        })
    }

    /// `MPIX_Free_queue`: release the queue and return its hardware
    /// counters to the NIC pool. It is the caller's responsibility to
    /// have waited for all associated operations — enqueued-but-unstarted
    /// ones included (§III-A); violating that reports
    /// [`StError::QueueBusy`] and hands the still-live handle back so
    /// the caller can [`Queue::drain`] and retry.
    pub fn free(self, hctx: &mut HostCtx<World>) -> Result<(), (Queue, StError)> {
        match free_queue_impl(hctx, self.id) {
            Ok(()) => Ok(()),
            Err(e) => Err((self, e)),
        }
    }

    /// Force-release this queue after a watchdog timeout
    /// ([`StError::DrainTimeout`]): skips the busy check, cancels every
    /// DWQ descriptor the queue still has armed (their slots return to
    /// the node's pool immediately), and frees both hardware counters.
    /// Returns the number of cancelled descriptors. Only sound when the
    /// queue's triggers will never fire — the recovery half of the
    /// fault-injection contract; on healthy queues use [`Queue::free`].
    pub fn free_after_timeout(self, hctx: &mut HostCtx<World>) -> Result<u64, (Queue, StError)> {
        match force_free_impl(hctx, self.id) {
            Ok(n) => Ok(n),
            Err(e) => Err((self, e)),
        }
    }
}

// ---------------------------------------------------------------------
// CommPlan: build-once / start-many persistent patterns (stx v2)
// ---------------------------------------------------------------------

struct SendRec {
    dst: usize,
    src: BufSlice,
    tag: i32,
    comm: u16,
    qslot: usize,
}

struct RecvRec {
    src: SrcSel,
    tag: TagSel,
    comm: u16,
    /// Parity-indexed destination buffers (equal unless double-buffered).
    bufs: [BufSlice; 2],
    deferred: bool,
    qslot: usize,
}

struct PlanSend {
    rec: SendRec,
    req_cell: CellId,
}

struct PlanRecv {
    rec: RecvRec,
    /// Persistent request cell (deferred receives only).
    req_cell: Option<CellId>,
}

/// Records a communication pattern for a [`CommPlan`]: sends, posted
/// (standard `MPI_Irecv`) receives, and queue-deferred receives.
/// Selector validation is eager — wildcards on deferred operations fail
/// at record time, not at start time (§III-D).
pub struct CommPlanBuilder {
    rank: usize,
    stream: StreamId,
    variant: Variant,
    queues: Vec<usize>,
    slot0: usize,
    sends: Vec<SendRec>,
    recvs: Vec<RecvRec>,
    kt_frac: f64,
}

impl CommPlanBuilder {
    fn next_send_slot(&self) -> usize {
        if self.queues.is_empty() {
            0
        } else {
            (self.slot0 + self.sends.len()) % self.queues.len()
        }
    }

    fn next_recv_slot(&self) -> usize {
        if self.queues.is_empty() {
            0
        } else {
            (self.slot0 + self.recvs.iter().filter(|r| r.deferred).count()) % self.queues.len()
        }
    }

    /// Start the round-robin striping at queue slot `slot` instead of 0.
    /// Lets a *sequence* of small plans (e.g. one per ring step) spread
    /// over the queue set even when each plan records a single send —
    /// otherwise every one-op plan would land on queue 0.
    pub fn stripe_from(&mut self, slot: usize) {
        self.slot0 = if self.queues.is_empty() { 0 } else { slot % self.queues.len() };
    }

    /// Record a deferred tagged send to `dst`. Sends stripe round-robin
    /// over the plan's queues.
    pub fn send(&mut self, dst: usize, src: BufSlice, tag: i32, comm: u16) {
        let qslot = self.next_send_slot();
        self.sends.push(SendRec { dst, src, tag, comm, qslot });
    }

    /// Record a *posted* receive: re-posted as a standard `MPI_Irecv` by
    /// [`CommPlan::post_recvs`] each iteration (the paper's deliberate
    /// receive-side choice on a testbed without triggered receives,
    /// §V-B). Wildcards are allowed here, as on any standard receive.
    pub fn recv(&mut self, src: SrcSel, tag: TagSel, comm: u16, dst: BufSlice) {
        self.recvs.push(RecvRec { src, tag, comm, bufs: [dst, dst], deferred: false, qslot: 0 });
    }

    /// Record a double-buffered posted receive: iteration parity selects
    /// which of the two destination slices the re-post lands in.
    pub fn recv_db(&mut self, src: SrcSel, tag: TagSel, comm: u16, dst: [BufSlice; 2]) {
        self.recvs.push(RecvRec { src, tag, comm, bufs: dst, deferred: false, qslot: 0 });
    }

    /// Record a *deferred* receive on the plan's queues (collective-style
    /// patterns): armed and triggered with the sends each round — as a
    /// NIC triggered-receive descriptor on [`Variant::KernelTriggered`]
    /// plans, progress-thread emulated otherwise, and a late host
    /// `MPI_Irecv` fallback in host-variant rounds.
    /// Wildcards are rejected eagerly (§III-D).
    pub fn recv_deferred(
        &mut self,
        src: SrcSel,
        tag: TagSel,
        comm: u16,
        dst: BufSlice,
    ) -> Result<(), StError> {
        validate_selectors(src, tag)?;
        let qslot = self.next_recv_slot();
        self.recvs.push(RecvRec { src, tag, comm, bufs: [dst, dst], deferred: true, qslot });
        Ok(())
    }

    /// Override the kernel-window fraction at which KT triggers fire
    /// (default [`KT_TRIGGER_FRAC`]).
    pub fn kt_frac(&mut self, frac: f64) {
        self.kt_frac = frac;
    }

    /// Finalize the plan: validate the queue set, allocate one persistent
    /// request per deferred operation (the build-once half of the
    /// build-once / start-many contract), and freeze the pattern.
    pub fn build(self, hctx: &mut HostCtx<World>) -> Result<CommPlan, StError> {
        let n_deferred = self.sends.len() + self.recvs.iter().filter(|r| r.deferred).count();
        if self.variant.uses_queue() && n_deferred > 0 && self.queues.is_empty() {
            return Err(StError::PlanWithoutQueue);
        }
        let call = hctx.with(|w, _| w.cost.host_enqueue_call);
        hctx.advance(call * n_deferred as u64);
        let rank = self.rank;
        let queues = self.queues;
        let (sends, recvs) = hctx.with(|w, core| {
            for &qid in &queues {
                if w.queues[qid].freed {
                    return Err(StError::QueueFreed(qid));
                }
                if w.queues[qid].rank != rank {
                    return Err(StError::ForeignQueue(qid));
                }
            }
            let sends: Vec<PlanSend> = self
                .sends
                .into_iter()
                .map(|rec| {
                    let req = w.new_request(core, "plan_send");
                    PlanSend { rec, req_cell: w.request_done_cell(req) }
                })
                .collect();
            let recvs: Vec<PlanRecv> = self
                .recvs
                .into_iter()
                .map(|rec| {
                    let req_cell = rec.deferred.then(|| {
                        let req = w.new_request(core, "plan_recv");
                        w.request_done_cell(req)
                    });
                    PlanRecv { rec, req_cell }
                })
                .collect();
            Ok((sends, recvs))
        })?;
        let mut active: Vec<usize> = sends
            .iter()
            .map(|s| s.rec.qslot)
            .chain(recvs.iter().filter(|r| r.rec.deferred).map(|r| r.rec.qslot))
            .collect();
        active.sort_unstable();
        active.dedup();
        if queues.is_empty() {
            active.clear();
        }
        Ok(CommPlan {
            rank,
            stream: self.stream,
            variant: self.variant,
            queues,
            active,
            sends,
            recvs,
            kt_frac: self.kt_frac,
        })
    }
}

/// A persistent communication pattern (stx v2): descriptors, selectors,
/// and requests are allocated **once** at build; every iteration re-arms
/// them with [`CommPlan::round`] / [`CommPlan::complete`] — the host
/// baseline, ST, ST-shader, KT, and GI variants all run through the
/// same plan object, so workload code contains no per-variant
/// communication branches and no per-iteration enqueue calls.
///
/// One iteration ("round") of a plan:
///
/// 1. [`CommPlan::post_recvs`] — re-post the plan's posted receives
///    (standard `MPI_Irecv`, double-buffered by `parity`);
/// 2. [`CommPlan::round`] — launch the producer kernels and run the
///    deferred set under the variant's protocol (see below);
/// 3. …overlap kernels, host work…;
/// 4. [`CommPlan::complete`] — the variant's send-completion wait;
/// 5. `mpi::waitall` on the posted-receive requests.
///
/// Per-variant `round`/`complete` behavior:
///
/// * **Host** — kernels, `hipStreamSynchronize`, `MPI_Isend` per send
///   (Fig. 1); `complete` = host `MPI_Waitall`.
/// * **ST / ST-shader** — kernels, then per queue: arm ops + one
///   `writeValue64` start; `complete` = one `waitValue64` per queue
///   (Fig. 2) — the stream stalls, never the host.
/// * **KT** — the completion wait for the *previous* round rides the
///   first kernel's prologue, ops are armed, and the trigger fires from
///   inside the last kernel at the plan's KT fraction; `complete` is a
///   no-op (the next round's prologue — or [`CommPlan::drain`] — covers
///   completion).
/// * **GI** — like KT for completion (previous round's wait in the
///   first kernel's prologue, `complete` a no-op), but the round's
///   messages are *built by the last kernel itself* as command-ring
///   descriptors: no host arming calls, no trigger counters, no DWQ
///   slots — per-descriptor device build time inside the kernel window
///   instead.
///
/// Multi-queue plans stripe operations round-robin over their queues;
/// each queue arms and triggers independently, contending for the NIC's
/// DWQ slots (stalls surface as `dwq_slot_waits`). A round's per-queue
/// slot demand must fit `cost.dwq_slots_per_nic`; otherwise the engine's
/// stall detector produces a [`crate::sim::StallReport`] naming the
/// blocked arm and the exhausted pool.
pub struct CommPlan {
    rank: usize,
    stream: StreamId,
    variant: Variant,
    queues: Vec<usize>,
    /// Indices into `queues` that own at least one deferred op.
    active: Vec<usize>,
    sends: Vec<PlanSend>,
    recvs: Vec<PlanRecv>,
    kt_frac: f64,
}

/// Token tying one [`CommPlan::round`] to its [`CommPlan::complete`]:
/// carries the host-variant request ids that `complete` waits on.
#[must_use = "a round must be completed (CommPlan::complete)"]
pub struct Round {
    host_reqs: Vec<usize>,
}

impl CommPlan {
    /// Start recording a plan for `rank` on `stream`, driven by `queues`
    /// (empty for [`Variant::Host`]; ops stripe round-robin otherwise).
    pub fn builder(
        rank: usize,
        stream: StreamId,
        variant: Variant,
        queues: &[Queue],
    ) -> CommPlanBuilder {
        CommPlanBuilder {
            rank,
            stream,
            variant,
            queues: queues.iter().map(|q| q.id).collect(),
            slot0: 0,
            sends: Vec::new(),
            recvs: Vec::new(),
            kt_frac: KT_TRIGGER_FRAC,
        }
    }

    /// The variant this plan runs under.
    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Re-post the plan's posted receives for this iteration (standard
    /// `MPI_Irecv`; `parity` selects the double-buffer half). Returns
    /// the request ids for the end-of-iteration `mpi::waitall`.
    pub fn post_recvs(&self, hctx: &mut HostCtx<World>, parity: usize) -> Vec<usize> {
        self.recvs
            .iter()
            .filter(|r| !r.rec.deferred)
            .map(|r| {
                let d = &r.rec;
                mpi::irecv(hctx, self.rank, d.src, d.tag, d.comm, d.bufs[parity % 2])
            })
            .collect()
    }

    /// Arm one plan send through the shared backpressure loop: a full
    /// deferred-work queue stalls the host until the NIC releases a
    /// descriptor (recorded as a `dwq_slot_waits` event) instead of
    /// failing.
    fn arm_plan_send(&self, hctx: &mut HostCtx<World>, s: &PlanSend) -> Result<(), StError> {
        let qid = self.queues[s.rec.qslot];
        let (dst, src, tag, comm) = (s.rec.dst, s.rec.src, s.rec.tag, s.rec.comm);
        let req_cell = s.req_cell;
        arm_with_backpressure(hctx, qid, move |w, core| {
            reserve_send_slot(w, core, qid, dst)?;
            arm_send(w, core, qid, dst, src, tag, comm, req_cell);
            Ok(())
        })
    }

    /// Arm one plan receive through the same backpressure loop as
    /// `arm_plan_send`: on KT queues the hardware triggered-receive
    /// descriptor needs a DWQ slot, and a full deferred-work queue
    /// stalls the host until the NIC releases one (a `dwq_slot_waits`
    /// event) instead of failing.
    fn arm_plan_recv(&self, hctx: &mut HostCtx<World>, r: &PlanRecv) -> Result<(), StError> {
        let qid = self.queues[r.rec.qslot];
        let (src, tag) = match (r.rec.src, r.rec.tag) {
            (SrcSel::Rank(s), TagSel::Tag(t)) => (s, t),
            // Unreachable: recv_deferred validated the selectors.
            _ => return Err(StError::WildcardUnsupported),
        };
        let (dst, comm) = (r.rec.bufs[0], r.rec.comm);
        let req_cell = r.req_cell.expect("deferred recv carries a persistent request");
        arm_with_backpressure(hctx, qid, move |w, core| {
            reserve_recv_slot(w, core, qid)?;
            arm_recv(w, core, qid, src, dst, tag, comm, req_cell);
            Ok(())
        })
    }

    /// Arm every deferred op owned by queue slot `slot`, in record order
    /// (sends, then deferred receives).
    fn arm_slot(&self, hctx: &mut HostCtx<World>, slot: usize) -> Result<(), StError> {
        for s in self.sends.iter().filter(|s| s.rec.qslot == slot) {
            self.arm_plan_send(hctx, s)?;
        }
        for r in self.recvs.iter().filter(|r| r.rec.deferred && r.rec.qslot == slot) {
            self.arm_plan_recv(hctx, r)?;
        }
        Ok(())
    }

    /// Run one round of the plan: launch `kernels` (the producer/pack
    /// phase) and drive the deferred set under the plan's variant
    /// protocol (see the type-level docs for the per-variant timeline).
    /// KT rounds with no kernels get a zero-cost device progress kernel
    /// to carry their hooks.
    pub fn round(
        &self,
        hctx: &mut HostCtx<World>,
        kernels: Vec<KernelSpec>,
    ) -> Result<Round, StError> {
        match self.variant {
            Variant::Host => {
                let had_kernels = !kernels.is_empty();
                for k in kernels {
                    host_enqueue(hctx, self.stream, StreamOp::Kernel(k));
                }
                if had_kernels {
                    // The Fig-1 kernel-boundary sync the ST path removes.
                    stream_synchronize(hctx, self.stream);
                }
                let mut reqs = Vec::with_capacity(self.sends.len());
                // Deferred-recorded receives fall back to standard
                // irecvs on the host path (pre-posted before the sends).
                for r in self.recvs.iter().filter(|r| r.rec.deferred) {
                    let d = &r.rec;
                    reqs.push(mpi::irecv(hctx, self.rank, d.src, d.tag, d.comm, d.bufs[0]));
                }
                for s in &self.sends {
                    let d = &s.rec;
                    reqs.push(mpi::isend(hctx, self.rank, d.dst, d.src, d.tag, d.comm));
                }
                Ok(Round { host_reqs: reqs })
            }
            Variant::KernelTriggered => {
                let mut kernels = kernels;
                if kernels.is_empty() {
                    // Device-side progress kernel carrying the hooks.
                    kernels.push(KernelSpec {
                        name: "plan_progress".into(),
                        flops: 0,
                        bytes: 0,
                        payload: KernelPayload::None,
                    });
                }
                let mut kts: Vec<KernelCtx> = kernels.iter().map(|_| KernelCtx::new()).collect();
                // Previous rounds' completion rides the first kernel's
                // prologue (thresholds snapshot *before* this round's
                // ops are armed). The wait covers the plan's WHOLE queue
                // set, not just the slots this plan arms: chained small
                // plans (one per collective step) rotate over the
                // queues, and step s+1's trigger must not fire before
                // step s's ops — possibly on a different queue — have
                // completed.
                for slot in 0..self.queues.len() {
                    kt_wait_impl(hctx, self.queues[slot], &mut kts[0])?;
                }
                for &slot in &self.active {
                    self.arm_slot(hctx, slot)?;
                    let last = kts.last_mut().expect("at least one kernel");
                    kt_start_impl(hctx, self.queues[slot], last, self.kt_frac)?;
                }
                for (k, kt) in kernels.into_iter().zip(kts) {
                    let op = if kt.is_empty() {
                        StreamOp::Kernel(k)
                    } else {
                        StreamOp::KtKernel(k, kt)
                    };
                    host_enqueue(hctx, self.stream, op);
                }
                Ok(Round { host_reqs: Vec::new() })
            }
            Variant::GpuInitiated => {
                let mut kernels = kernels;
                if kernels.is_empty() {
                    // Device-side progress kernel carrying the ring work.
                    kernels.push(KernelSpec {
                        name: "plan_progress".into(),
                        flops: 0,
                        bytes: 0,
                        payload: KernelPayload::None,
                    });
                }
                let mut gis: Vec<GiCtx> = kernels.iter().map(|_| GiCtx::new()).collect();
                // Previous rounds' completion rides the first kernel's
                // prologue, over the plan's WHOLE queue set (same chained
                // small-plan reasoning as the KT arm above).
                for slot in 0..self.queues.len() {
                    gi_wait_impl(hctx, self.queues[slot], &mut gis[0])?;
                }
                // The round's messages all land in the LAST kernel's
                // descriptor plan: its closing wavefronts build the
                // command-ring entries after the producers have run.
                // No host arming, no DWQ slots, no trigger epochs —
                // and no host time charged: the pattern ships as kernel
                // arguments.
                for &slot in &self.active {
                    let qid = self.queues[slot];
                    let last = gis.last_mut().expect("at least one kernel");
                    hctx.with(|w, _| {
                        if w.queues[qid].freed {
                            return Err(StError::QueueFreed(qid));
                        }
                        for s in self.sends.iter().filter(|s| s.rec.qslot == slot) {
                            let d = &s.rec;
                            gi_arm_send(w, qid, last, d.dst, d.src, d.tag, d.comm, s.req_cell);
                        }
                        for r in
                            self.recvs.iter().filter(|r| r.rec.deferred && r.rec.qslot == slot)
                        {
                            let (src, tag) = match (r.rec.src, r.rec.tag) {
                                (SrcSel::Rank(s), TagSel::Tag(t)) => (s, t),
                                // Unreachable: recv_deferred validated.
                                _ => return Err(StError::WildcardUnsupported),
                            };
                            let req_cell =
                                r.req_cell.expect("deferred recv carries a persistent request");
                            gi_arm_recv(w, qid, last, src, r.rec.bufs[0], tag, r.rec.comm, req_cell);
                        }
                        Ok(())
                    })?;
                }
                for (k, gi) in kernels.into_iter().zip(gis) {
                    let op = if gi.is_empty() {
                        StreamOp::Kernel(k)
                    } else {
                        StreamOp::GiKernel(k, gi)
                    };
                    host_enqueue(hctx, self.stream, op);
                }
                Ok(Round { host_reqs: Vec::new() })
            }
            _ => {
                for k in kernels {
                    host_enqueue(hctx, self.stream, StreamOp::Kernel(k));
                }
                // Per queue: arm its ops, then its writeValue64 start —
                // grouping per queue keeps DWQ backpressure resolvable
                // (an earlier queue's trigger is already in the stream
                // when a later queue stalls for a slot).
                for &slot in &self.active {
                    self.arm_slot(hctx, slot)?;
                    start_impl(hctx, self.queues[slot])?;
                }
                Ok(Round { host_reqs: Vec::new() })
            }
        }
    }

    /// The variant's send-completion wait for a [`CommPlan::round`]:
    /// host `MPI_Waitall` (Host), one `waitValue64` per queue (ST —
    /// stalls the stream, not the host), or nothing (KT — completion
    /// rides the next round's kernel prologue or [`CommPlan::drain`]).
    pub fn complete(&self, hctx: &mut HostCtx<World>, round: Round) -> Result<(), StError> {
        match self.variant {
            Variant::Host => {
                mpi::waitall(hctx, &round.host_reqs);
                Ok(())
            }
            // KT and GI completion rides the next round's kernel
            // prologue (or CommPlan::drain): nothing to do here.
            Variant::KernelTriggered | Variant::GpuInitiated => Ok(()),
            _ => {
                for &slot in &self.active {
                    wait_impl(hctx, self.queues[slot])?;
                }
                Ok(())
            }
        }
    }

    /// Host-side drain of every queue the plan drives: blocks until all
    /// started operations completed. The one host wait a KT timed region
    /// performs (at its very end); immediate on quiet queues.
    pub fn drain(&self, hctx: &mut HostCtx<World>) -> Result<(), StError> {
        for &qid in &self.queues {
            drain_impl(hctx, qid)?;
        }
        Ok(())
    }
}

/// Convenience guard: deferred operations do not allow wildcards
/// (§III-D). [`CommPlanBuilder::recv_deferred`] validates through this
/// eagerly; callers that accept user-provided selectors should too.
pub fn validate_selectors(src: SrcSel, tag: TagSel) -> Result<(), StError> {
    if src == SrcSel::Any || tag == TagSel::Any {
        return Err(StError::WildcardUnsupported);
    }
    Ok(())
}

#[cfg(test)]
mod tests;
