//! The paper's proposed interface: stream-triggered (ST) MPI operations.
//!
//! Implements §III's `MPIX_*` API over the simulated substrate:
//!
//! * [`create_queue`] / [`free_queue`] — `MPIX_Create_queue` /
//!   `MPIX_Free_queue`: bind a GPU stream to an MPI queue object and open
//!   two NIC hardware counters (one trigger, one completion), mapped into
//!   GPU-CP-visible memory (§IV-A);
//! * [`enqueue_send`] / [`enqueue_recv`] — `MPIX_Enqueue_send/recv`:
//!   create deferred communication descriptors, FIFO per queue,
//!   asynchronous w.r.t. the host;
//! * [`enqueue_start`] — `MPIX_Enqueue_start`: appends a stream-memory
//!   `writeValue64` to the GPU stream; when the GPU CP executes it, the
//!   write to the trigger counter fires **all** operations enqueued since
//!   the previous start (batching, §III-A footnote);
//! * [`enqueue_wait`] — `MPIX_Enqueue_wait`: appends a `waitValue64` on
//!   the completion counter, stalling the *stream* (never the host) until
//!   every started operation has completed.
//!
//! Routing mirrors §IV faithfully:
//! * inter-node sends → NIC DWQ triggered sends (full hardware offload);
//! * receives (any locality) and all intra-node traffic → emulated by the
//!   per-process progress thread, charged on its serial timeline;
//! * inter-node rendezvous sends get a small progress-thread assist for
//!   completion handling (§V-E).
//!
//! Wildcards are rejected (§III-D): ST operations require a concrete
//! source rank and tag.
//!
//! Beyond the paper's ST API this module also hosts the **kernel-
//! triggered (KT)** wrappers of the follow-on work (arXiv 2306.15773):
//! [`kt_start`] folds the trigger write into a kernel's execution window
//! instead of appending a `writeValue64`, [`kt_wait`] folds the
//! completion wait into a kernel's prologue instead of appending a
//! `waitValue64`, and [`queue_drain`] is the one host-side wait a KT
//! timed region performs (at its very end). The deferred operations
//! themselves ([`enqueue_send`] / [`enqueue_recv`]) are shared verbatim:
//! the NIC's deferred-work entries do not care *what* advances the
//! trigger counter. [`Variant`] names the resulting axis every
//! experiment sweeps.

use crate::costmodel::MemOpFlavor;
use crate::gpu::{self, StreamId, StreamOp, WriteMode};
use crate::mpi::{self, SrcSel, TagSel};
use crate::nic::{self, BufSlice, Done, Envelope};
use crate::sim::{CellId, HostCtx};
use crate::world::World;

/// The communication-variant axis every experiment and workload sweeps:
/// *who drives the control path* of each communication step.
///
/// * [`Variant::Host`] — GPU-aware MPI baseline: the host synchronizes
///   at every kernel boundary and posts sends itself (paper Fig. 1).
/// * [`Variant::StreamTriggered`] / [`Variant::StreamTriggeredShader`]
///   — the paper's ST path: `MPIX_Enqueue_*` deferred operations whose
///   trigger and completion ride `writeValue64`/`waitValue64` stream
///   memory ops executed by the GPU CP between kernels (Fig. 2), with
///   the stock HIP or the hand-coded shader memop flavor (§V-F).
/// * [`Variant::KernelTriggered`] — the follow-on KT path (arXiv
///   2306.15773): triggers fire from *inside* running kernels
///   ([`crate::gpu::KernelCtx`]) and completion waits fold into the
///   next kernel's prologue, so an iteration pays no `enqueue_start`
///   memop and no `MPIX_Enqueue_waitall`-style stream stall at all —
///   completion rides the kernel's own tail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// GPU-aware MPI: host synchronizes at kernel boundaries.
    Host,
    /// Stream-triggered with HIP stream memory operations.
    StreamTriggered,
    /// ST with hand-coded shader stream memory operations (§V-F).
    StreamTriggeredShader,
    /// Kernel-triggered: triggers fire from inside running kernels.
    KernelTriggered,
}

impl Variant {
    /// Stable short name used by reports, campaign grids, and the CLI.
    pub fn name(self) -> &'static str {
        match self {
            Variant::Host => "baseline",
            Variant::StreamTriggered => "st",
            Variant::StreamTriggeredShader => "st-shader",
            Variant::KernelTriggered => "kt",
        }
    }

    /// Parse a report/CLI name — the inverse of [`Variant::name`]
    /// (accepts the legacy `shader` alias).
    pub fn parse(s: &str) -> Option<Variant> {
        Some(match s {
            "baseline" => Variant::Host,
            "st" => Variant::StreamTriggered,
            "st-shader" | "shader" => Variant::StreamTriggeredShader,
            "kt" => Variant::KernelTriggered,
            _ => return None,
        })
    }

    /// Stream-memop flavor this variant binds its queue with (KT queues
    /// keep the HIP flavor: their hot path never executes a memop).
    pub fn flavor(self) -> MemOpFlavor {
        match self {
            Variant::StreamTriggeredShader => MemOpFlavor::Shader,
            _ => MemOpFlavor::Hip,
        }
    }

    /// True for every variant that needs an `MPIX_Queue` (all but
    /// [`Variant::Host`]).
    pub fn uses_queue(self) -> bool {
        self != Variant::Host
    }

    /// All variants, in report order.
    pub fn all() -> [Variant; 4] {
        [
            Variant::Host,
            Variant::StreamTriggered,
            Variant::StreamTriggeredShader,
            Variant::KernelTriggered,
        ]
    }
}

/// Default fraction of a kernel's execution window at which KT triggers
/// fire: late enough that the data the released sends cover has been
/// written (numerics commit at body start; 0.9 models firing from the
/// kernel's last wavefront), early enough to overlap the NIC trigger
/// handshake with the kernel tail.
pub const KT_TRIGGER_FRAC: f64 = 0.9;

/// Errors surfaced to the application (mirrors MPI error classes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StError {
    WildcardUnsupported,
    QueueFreed(usize),
    QueueBusy(u64),
}

impl std::fmt::Display for StError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StError::WildcardUnsupported => {
                write!(f, "ST operations do not support MPI_ANY_SOURCE/MPI_ANY_TAG (paper §III-D)")
            }
            StError::QueueFreed(q) => write!(f, "MPIX_Queue {q} was freed"),
            StError::QueueBusy(n) => {
                write!(f, "MPIX_Free_queue while {n} enqueued operations are incomplete")
            }
        }
    }
}

impl std::error::Error for StError {}

/// `MPIX_Queue`: maps a GPU stream to the MPI runtime and batches ST ops.
pub struct MpixQueue {
    pub rank: usize,
    pub stream: StreamId,
    /// NIC hardware trigger counter (GPU-CP visible).
    pub trig_ctr: CellId,
    /// NIC hardware completion counter (GPU-CP visible).
    pub comp_ctr: CellId,
    /// Stream memory op implementation used for this queue's
    /// start/wait operations (Hip or hand-coded Shader, §V-F).
    pub flavor: MemOpFlavor,
    /// Number of `enqueue_start` calls so far == the value the next
    /// trigger write stores.
    pub epoch: u64,
    /// Ops enqueued since the last start (they trigger at `epoch + 1`).
    pub pending_since_start: u64,
    /// Total ops covered by issued starts (the wait threshold).
    pub started_total: u64,
    pub freed: bool,
}

/// Create an `MPIX_Queue` bound to `stream` (local operation, §III-A).
pub fn create_queue(
    hctx: &mut HostCtx<World>,
    rank: usize,
    stream: StreamId,
    flavor: MemOpFlavor,
) -> usize {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        let node = w.topo.node_of(rank);
        let qid = w.queues.len();
        let trig_ctr = nic::alloc_counter(w, core, node, &format!("q{qid}.trig"));
        let comp_ctr = nic::alloc_counter(w, core, node, &format!("q{qid}.comp"));
        w.queues.push(MpixQueue {
            rank,
            stream,
            trig_ctr,
            comp_ctr,
            flavor,
            epoch: 0,
            pending_since_start: 0,
            started_total: 0,
            freed: false,
        });
        qid
    })
}

/// Release an `MPIX_Queue`'s internal resources. It is the caller's
/// responsibility to have waited for all associated ST operations
/// (§III-A); violating that is reported as an error.
pub fn free_queue(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        let q = &w.queues[queue];
        if q.freed {
            return Err(StError::QueueFreed(queue));
        }
        let completed = core.cell(q.comp_ctr);
        let outstanding = q.started_total.saturating_sub(completed);
        if outstanding > 0 {
            return Err(StError::QueueBusy(outstanding));
        }
        w.queues[queue].freed = true;
        Ok(())
    })
}

/// `MPIX_Enqueue_send`: deferred tagged send on `queue`. Returns a
/// request id usable with host-side `mpi::wait` (§III-B2 item 4).
pub fn enqueue_send(
    hctx: &mut HostCtx<World>,
    queue: usize,
    dst: usize,
    src: BufSlice,
    tag: i32,
    comm: u16,
) -> Result<usize, StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let rank = w.queues[queue].rank;
        let req = w.new_request(core, "st_send");
        let req_cell = w.request_done_cell(req);
        let q = &mut w.queues[queue];
        let threshold = q.epoch + 1;
        q.pending_since_start += 1;
        let trig = q.trig_ctr;
        let comp = q.comp_ctr;
        let env = Envelope { src_rank: rank, dst_rank: dst, tag, comm, elems: src.elems };

        if w.topo.same_node(rank, dst) {
            // No intra-node deferred-work hardware exists (§IV-B): the
            // progress thread watches the trigger counter and performs the
            // send itself.
            core.on_ge(
                trig,
                threshold,
                format!("progress r{rank} ST intra send"),
                Box::new(move |w, core| {
                    let cost = w.cost.progress_wakeup + w.cost.progress_per_op;
                    let at = mpi::progress_charge(w, core, rank, cost);
                    core.schedule_at(
                        at,
                        Box::new(move |w, core| {
                            // Completion counter updates also flow through
                            // the progress thread.
                            let done = Done {
                                cells: vec![req_cell],
                                cb: Some(Box::new(move |w, core| {
                                    let c = w.cost.progress_completion;
                                    let at = mpi::progress_charge(w, core, rank, c);
                                    // Typed event: the completion-counter
                                    // update needs no closure.
                                    core.schedule_cell_add_at(at, comp, 1);
                                })),
                            };
                            mpi::do_send(w, core, env, src, done);
                        }),
                    );
                }),
            );
        } else {
            // Full NIC offload via a DWQ triggered send (§IV-A1). The NIC
            // bumps the completion counter in hardware; rendezvous sends
            // need a small progress-thread assist (§V-E).
            let rendezvous = w.cost.is_rendezvous(src.bytes());
            let done = Done {
                cells: vec![req_cell, comp],
                cb: if rendezvous {
                    Some(Box::new(move |w, core| {
                        let c = w.cost.progress_rendezvous_assist;
                        let _ = mpi::progress_charge(w, core, rank, c);
                    }))
                } else {
                    None
                },
            };
            nic::post_triggered_send(w, core, trig, threshold, env, src, done);
        }
        Ok(req)
    })
}

/// `MPIX_Enqueue_recv`: deferred tagged receive on `queue`. The NIC has
/// no triggered receives (§IV-A2), so the progress thread emulates the
/// deferred semantics regardless of locality: it observes the trigger,
/// posts the receive into the matching engine, and mediates the
/// completion-counter update.
pub fn enqueue_recv(
    hctx: &mut HostCtx<World>,
    queue: usize,
    src_rank: usize,
    dst: BufSlice,
    tag: i32,
    comm: u16,
) -> Result<usize, StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let rank = w.queues[queue].rank;
        let req = w.new_request(core, "st_recv");
        let req_cell = w.request_done_cell(req);
        let q = &mut w.queues[queue];
        let threshold = q.epoch + 1;
        q.pending_since_start += 1;
        let trig = q.trig_ctr;
        let comp = q.comp_ctr;

        core.on_ge(
            trig,
            threshold,
            format!("progress r{rank} ST recv"),
            Box::new(move |w, core| {
                let cost = w.cost.progress_wakeup + w.cost.progress_per_op;
                let at = mpi::progress_charge(w, core, rank, cost);
                core.schedule_at(
                    at,
                    Box::new(move |w, core| {
                        let done = Done {
                            cells: vec![req_cell],
                            cb: Some(Box::new(move |w, core| {
                                let c = w.cost.progress_completion;
                                let at = mpi::progress_charge(w, core, rank, c);
                                // Typed event path, as in enqueue_send.
                                core.schedule_cell_add_at(at, comp, 1);
                            })),
                        };
                        mpi::post_recv(
                            w,
                            core,
                            rank,
                            SrcSel::Rank(src_rank),
                            TagSel::Tag(tag),
                            comm,
                            dst,
                            done,
                        );
                    }),
                );
            }),
        );
        Ok(req)
    })
}

/// Convenience guard: ST does not allow wildcards (§III-D). Callers that
/// accept user-provided selectors should validate through this.
pub fn validate_selectors(src: SrcSel, tag: TagSel) -> Result<(), StError> {
    if src == SrcSel::Any || tag == TagSel::Any {
        return Err(StError::WildcardUnsupported);
    }
    Ok(())
}

/// `MPIX_Enqueue_start`: appends a `writeValue64` to the queue's GPU
/// stream. When the CP executes it (in stream order), the trigger counter
/// advances to the new epoch and every operation enqueued since the last
/// start executes (batched trigger, §III-B item 3).
pub fn enqueue_start(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let (call, enq) = hctx.with(|w, _| (w.cost.host_enqueue_call, w.cost.kernel_enqueue));
    hctx.advance(call + enq);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &mut w.queues[queue];
        q.epoch += 1;
        q.started_total += q.pending_since_start;
        q.pending_since_start = 0;
        let op = StreamOp::WriteValue64 {
            cell: q.trig_ctr,
            value: q.epoch,
            mode: WriteMode::Set,
            flavor: q.flavor,
        };
        let sid = q.stream;
        gpu::enqueue(w, core, sid, op);
        Ok(())
    })
}

/// `MPIX_Enqueue_wait`: appends a `waitValue64` on the completion counter
/// to the queue's GPU stream; the *stream* stalls until all started
/// operations complete. Host-asynchronous (§III-B2 item 3).
pub fn enqueue_wait(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let (call, enq) = hctx.with(|w, _| (w.cost.host_enqueue_call, w.cost.kernel_enqueue));
    hctx.advance(call + enq);
    hctx.with(|w, core| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &w.queues[queue];
        let op = StreamOp::WaitValue64 {
            cell: q.comp_ctr,
            threshold: q.started_total,
            flavor: q.flavor,
        };
        let sid = q.stream;
        gpu::enqueue(w, core, sid, op);
        Ok(())
    })
}

/// Kernel-triggered start — the KT counterpart of [`enqueue_start`].
/// Instead of appending a `writeValue64` stream op, the trigger-counter
/// bump is folded into `kernel` (a [`gpu::KernelCtx`] later attached to
/// a [`gpu::StreamOp::KtKernel`]) and fires at `frac` of the kernel's
/// execution window: the NIC releases every operation enqueued since the
/// previous start while the kernel is still running, removing the
/// per-iteration CP memop handshake the ST path pays.
///
/// The write is a device-scope atomic increment; CP `enqueue_start`
/// writes the absolute epoch. Both advance the counter to the same
/// value, so ST and KT starts may be mixed on one queue.
pub fn kt_start(
    hctx: &mut HostCtx<World>,
    queue: usize,
    kernel: &mut gpu::KernelCtx,
    frac: f64,
) -> Result<(), StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, _| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &mut w.queues[queue];
        q.epoch += 1;
        q.started_total += q.pending_since_start;
        q.pending_since_start = 0;
        kernel.kt_counter_inc(frac, q.trig_ctr, 1);
        Ok(())
    })
}

/// Kernel-triggered wait — the KT counterpart of [`enqueue_wait`]. The
/// completion wait folds into `kernel`'s prologue (its first wavefront
/// spins on the completion counter before the body runs), so the stream
/// never stalls on a separate `waitValue64` op and no CP memop is
/// executed: completion rides the kernel itself.
pub fn kt_wait(
    hctx: &mut HostCtx<World>,
    queue: usize,
    kernel: &mut gpu::KernelCtx,
) -> Result<(), StError> {
    let call = hctx.with(|w, _| w.cost.host_enqueue_call);
    hctx.advance(call);
    hctx.with(|w, _| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &w.queues[queue];
        kernel.wait_ge(q.comp_ctr, q.started_total);
        Ok(())
    })
}

/// Host-side completion drain: block the host until every started
/// operation on `queue` has completed. KT timed regions call this once
/// at the very end (per-iteration completion rides kernel prologues);
/// it returns immediately on an already-quiet queue, so ST callers may
/// use it as a cheap teardown guard too.
pub fn queue_drain(hctx: &mut HostCtx<World>, queue: usize) -> Result<(), StError> {
    let (cell, threshold, cost) = hctx.with(|w, _| {
        if w.queues[queue].freed {
            return Err(StError::QueueFreed(queue));
        }
        let q = &w.queues[queue];
        Ok((q.comp_ctr, q.started_total, w.cost.host_wait_overhead))
    })?;
    hctx.advance(cost);
    hctx.wait_ge(cell, threshold, "MPIX queue drain");
    Ok(())
}

#[cfg(test)]
mod tests;
