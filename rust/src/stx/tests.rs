//! ST extension tests: the paper's §III semantics through the stx v2
//! typed API (`Queue` / `CommPlan`), NIC resource-pool regression
//! tests, and the triggered-receive path (hardware receives on
//! KernelTriggered queues, doorbell `kt_recv`, plan equivalence).

use super::*;
use crate::coordinator::{build_world, run_cluster};
use crate::costmodel::presets;
use crate::gpu::{host_enqueue, stream_synchronize, KernelPayload, KernelSpec};
use crate::sim::SimStats;
use crate::world::{BufId, Metrics, Topology, World};

fn cost() -> crate::costmodel::CostModel {
    let mut c = presets::frontier_like();
    c.jitter_sigma = 0.0;
    c
}

fn fill_kernel(buf: BufId, val: f32) -> StreamOp {
    StreamOp::Kernel(KernelSpec {
        name: format!("fill{val}"),
        flops: 1000,
        bytes: 1000,
        payload: KernelPayload::Fn(Box::new(move |w, _| w.bufs.get_mut(buf).fill(val))),
    })
}

/// Create a stream + typed queue for `rank` from inside a host actor.
fn make_queue(
    ctx: &mut crate::sim::HostCtx<World>,
    rank: usize,
    variant: Variant,
) -> (StreamId, Queue) {
    let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
    let q = Queue::create(ctx, rank, sid, variant).expect("counter pool");
    (sid, q)
}

/// The paper's core scenario (Fig. 2): kernel K1, triggered send, wait,
/// kernel K2 — all driven by the GPU CP, host never blocks on comm.
#[test]
fn st_send_recv_inter_node_end_to_end() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc(64);
    let dst = w.bufs.alloc(64);
    let out = run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            // K1 writes the data that the ST send must pick up.
            host_enqueue(ctx, sid, fill_kernel(src, 3.25));
            q.send(ctx, 1, BufSlice::whole(src, 64), 11, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
            stream_synchronize(ctx, sid);
        } else {
            q.recv(ctx, 0, BufSlice::whole(dst, 64), 11, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
            // K2 consumes the received data, in stream order after the wait.
            host_enqueue(
                ctx,
                sid,
                StreamOp::Kernel(KernelSpec {
                    name: "consume".into(),
                    flops: 0,
                    bytes: 0,
                    payload: KernelPayload::Fn(Box::new(move |w, _| {
                        assert_eq!(w.bufs.get(dst), &[3.25; 64], "K2 must see received data");
                    })),
                }),
            );
            stream_synchronize(ctx, sid);
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
    assert_eq!(out.world.metrics.dwq_triggered, 1, "send offloaded to NIC DWQ");
    assert!(out.world.metrics.progress_ops > 0, "recv emulated by progress thread");
}

/// Fig. 7: one start triggers a batch of four sends.
#[test]
fn batched_start_triggers_all_enqueued_ops() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let srcs: Vec<BufId> = (0..4).map(|i| w.bufs.alloc_init(vec![i as f32; 32])).collect();
    let dsts: Vec<BufId> = (0..4).map(|_| w.bufs.alloc(32)).collect();
    let srcs2 = srcs.clone();
    let dsts2 = dsts.clone();
    let tags = [123, 126, 125, 124];
    let out = run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            for (i, &b) in srcs2.iter().enumerate() {
                q.send(ctx, 1, BufSlice::whole(b, 32), tags[i], crate::mpi::COMM_WORLD_DUP)
                    .unwrap();
            }
            q.start(ctx).unwrap(); // single start for all four
            q.wait(ctx).unwrap();
        } else {
            for (i, &b) in dsts2.iter().enumerate() {
                q.recv(ctx, 0, BufSlice::whole(b, 32), tags[i], crate::mpi::COMM_WORLD_DUP)
                    .unwrap();
            }
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
        }
        stream_synchronize(ctx, sid);
        if rank == 1 {
            let d = dsts2.clone();
            ctx.with(move |w, _| {
                for (i, &b) in d.iter().enumerate() {
                    assert_eq!(w.bufs.get(b), &[i as f32; 32], "batched msg {i}");
                }
            });
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
    assert_eq!(out.world.metrics.dwq_triggered, 4);
    // Exactly one trigger write + one completion wait per rank => 4 memops
    // total (2 ranks x (start + wait)).
    assert_eq!(out.world.metrics.memops_executed, 4);
}

/// §III-B2 item 2: buffers may be mutated by kernels enqueued before the
/// start; the send must transmit the post-kernel contents.
#[test]
fn deferred_send_sees_kernel_writes() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![-1.0; 16]);
    let dst = w.bufs.alloc(16);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            // Enqueue the send FIRST, kernel writes after host-enqueue but
            // before the start in stream order.
            q.send(ctx, 1, BufSlice::whole(src, 16), 1, crate::mpi::COMM_WORLD).unwrap();
            host_enqueue(ctx, sid, fill_kernel(src, 9.5));
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
        } else {
            q.recv(ctx, 0, BufSlice::whole(dst, 16), 1, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
        }
        stream_synchronize(ctx, sid);
        if rank == 1 {
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[9.5; 16]));
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
}

/// Intra-node ST traffic must flow through the progress thread (§IV-B).
#[test]
fn intra_node_st_uses_progress_thread() {
    let mut w = build_world(cost(), Topology::new(1, 2));
    let src = w.bufs.alloc_init(vec![6.0; 32]);
    let dst = w.bufs.alloc(32);
    let out = run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            q.send(ctx, 1, BufSlice::whole(src, 32), 2, crate::mpi::COMM_WORLD).unwrap();
        } else {
            q.recv(ctx, 0, BufSlice::whole(dst, 32), 2, crate::mpi::COMM_WORLD).unwrap();
        }
        q.start(ctx).unwrap();
        q.wait(ctx).unwrap();
        stream_synchronize(ctx, sid);
        if rank == 1 {
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[6.0; 32]));
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
    assert_eq!(out.world.metrics.dwq_triggered, 0, "no NIC offload intra-node");
    assert!(
        out.world.metrics.progress_ops >= 2,
        "both the emulated send and recv go through the progress thread"
    );
    assert_eq!(out.world.metrics.intra_sends, 1);
    assert_eq!(out.world.metrics.dwq_peak, 0, "intra-node ops take no DWQ slot");
}

/// The wait op stalls the *stream*: a kernel enqueued after
/// `Queue::wait` must not run before the data has landed, but the host
/// returns immediately (non-blocking semantics, §III-B2).
#[test]
fn enqueue_wait_is_host_asynchronous() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![1.0; 8]);
    let dst = w.bufs.alloc(8);
    let host_return_time = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let hrt = host_return_time.clone();
    let out = run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            // Rank 0 delays its send by doing host work first.
            ctx.advance(300_000);
            q.send(ctx, 1, BufSlice::whole(src, 8), 3, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
            stream_synchronize(ctx, sid);
        } else {
            q.recv(ctx, 0, BufSlice::whole(dst, 8), 3, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
            // All four calls return without blocking on the (still
            // far-away) sender:
            *hrt.lock().unwrap() = ctx.now();
            stream_synchronize(ctx, sid); // ... this one blocks.
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
    let t = *host_return_time.lock().unwrap();
    assert!(
        t < 300_000,
        "enqueue calls must return immediately (host returned at {t})"
    );
    assert!(out.rank_finish[1] > 300_000, "but the stream finished after the send");
}

#[test]
fn wildcards_rejected() {
    assert_eq!(
        validate_selectors(SrcSel::Any, TagSel::Tag(1)),
        Err(StError::WildcardUnsupported)
    );
    assert_eq!(
        validate_selectors(SrcSel::Rank(0), TagSel::Any),
        Err(StError::WildcardUnsupported)
    );
    assert!(validate_selectors(SrcSel::Rank(0), TagSel::Tag(1)).is_ok());
}

/// §III-D: a deferred send interoperates with standard MPI_Irecv.
#[test]
fn st_send_matches_standard_irecv() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![4.5; 16]);
    let dst = w.bufs.alloc(16);
    run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
            q.send(ctx, 1, BufSlice::whole(src, 16), 8, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
            stream_synchronize(ctx, sid);
            q.free(ctx).unwrap();
        } else {
            // Plain MPI_Irecv + MPI_Wait on the receiving side.
            let req = crate::mpi::irecv(
                ctx,
                1,
                SrcSel::Rank(0),
                TagSel::Tag(8),
                crate::mpi::COMM_WORLD,
                BufSlice::whole(dst, 16),
            );
            crate::mpi::wait(ctx, req);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[4.5; 16]));
        }
    })
    .unwrap();
}

/// Host-side MPI_Wait on an ST request (§III-B2 item 4).
#[test]
fn host_wait_on_st_request() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![2.0; 8]);
    let dst = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        let (_sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            let req = q.send(ctx, 1, BufSlice::whole(src, 8), 4, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            crate::mpi::wait(ctx, req); // host blocks until the ST send completes
        } else {
            let req = q.recv(ctx, 0, BufSlice::whole(dst, 8), 4, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            crate::mpi::wait(ctx, req);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[2.0; 8]));
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
}

/// Two epochs: ops after a start belong to the next trigger epoch (Fig 6:
/// T1 triggers S1/R1, T2 triggers S2/R2).
#[test]
fn multiple_start_epochs() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let s1 = w.bufs.alloc_init(vec![1.0; 8]);
    let s2 = w.bufs.alloc_init(vec![2.0; 8]);
    let d1 = w.bufs.alloc(8);
    let d2 = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            q.send(ctx, 1, BufSlice::whole(s1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap(); // T1
            q.send(ctx, 1, BufSlice::whole(s2, 8), 2, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap(); // T2
            q.wait(ctx).unwrap(); // W: waits for both epochs
        } else {
            q.recv(ctx, 0, BufSlice::whole(d1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.recv(ctx, 0, BufSlice::whole(d2, 8), 2, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
        }
        stream_synchronize(ctx, sid);
        if rank == 1 {
            ctx.with(move |w, _| {
                assert_eq!(w.bufs.get(d1), &[1.0; 8]);
                assert_eq!(w.bufs.get(d2), &[2.0; 8]);
            });
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
}

/// The shader-flavored queue completes faster than the HIP one on an
/// identical workload (the Fig 12 mechanism).
#[test]
fn shader_flavor_is_faster() {
    fn run_variant(variant: Variant) -> u64 {
        let mut w = build_world(cost(), Topology::new(2, 1));
        let src = w.bufs.alloc_init(vec![1.0; 64]);
        let dst = w.bufs.alloc(64);
        let out = run_cluster(w, 1, move |rank, ctx| {
            let (sid, q) = make_queue(ctx, rank, variant);
            for e in 0..4 {
                if rank == 0 {
                    q.send(ctx, 1, BufSlice::whole(src, 64), e, crate::mpi::COMM_WORLD).unwrap();
                } else {
                    q.recv(ctx, 0, BufSlice::whole(dst, 64), e, crate::mpi::COMM_WORLD).unwrap();
                }
                q.start(ctx).unwrap();
                q.wait(ctx).unwrap();
            }
            stream_synchronize(ctx, sid);
            q.free(ctx).unwrap();
        })
        .unwrap();
        out.makespan
    }
    let hip = run_variant(Variant::StreamTriggered);
    let shader = run_variant(Variant::StreamTriggeredShader);
    assert!(shader < hip, "shader {shader} must beat hip {hip}");
}

// ---------------------------------------------------------------------
// Kernel-triggered (KT) hooks
// ---------------------------------------------------------------------

/// The KT core scenario: the pack kernel itself fires the trigger
/// mid-execution and a later kernel's prologue carries the completion
/// wait — end to end with zero stream memory ops on the sender.
#[test]
fn kt_send_recv_inter_node_end_to_end() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc(64);
    let dst = w.bufs.alloc(64);
    let out = run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            // The deferred send is enqueued first; the pack kernel that
            // produces the data also releases it (stream-ordering: data
            // commits at body start, trigger fires later in the window).
            q.send(ctx, 1, BufSlice::whole(src, 64), 11, crate::mpi::COMM_WORLD).unwrap();
            let mut kt = gpu::KernelCtx::new();
            q.kt_start(ctx, &mut kt, KT_TRIGGER_FRAC).unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "kt_pack".into(),
                        flops: 1000,
                        bytes: 1000,
                        payload: KernelPayload::Fn(Box::new(move |w, _| {
                            w.bufs.get_mut(src).fill(3.25)
                        })),
                    },
                    kt,
                ),
            );
            // A trailing kernel's prologue waits out the completion.
            let mut tail = gpu::KernelCtx::new();
            q.kt_wait(ctx, &mut tail).unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "kt_tail".into(),
                        flops: 0,
                        bytes: 0,
                        payload: KernelPayload::None,
                    },
                    tail,
                ),
            );
            stream_synchronize(ctx, sid);
        } else {
            q.recv(ctx, 0, BufSlice::whole(dst, 64), 11, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[3.25; 64], "KT payload"));
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
    assert_eq!(out.world.metrics.dwq_triggered, 1, "send offloaded to NIC DWQ");
    assert_eq!(out.world.metrics.kt_triggers, 1, "trigger fired from inside the kernel");
    // Only the *receiver* executed memops (its ST start+wait): the KT
    // sender paid none.
    assert_eq!(out.world.metrics.memops_executed, 2);
}

/// ST and KT starts may be mixed on one queue: the absolute-epoch
/// `writeValue64` and the device-scope increment advance the trigger
/// counter to the same values.
#[test]
fn st_and_kt_starts_interoperate_on_one_queue() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let s1 = w.bufs.alloc_init(vec![1.5; 8]);
    let s2 = w.bufs.alloc_init(vec![2.5; 8]);
    let d1 = w.bufs.alloc(8);
    let d2 = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            // Epoch 1: classic ST start.
            q.send(ctx, 1, BufSlice::whole(s1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            // Epoch 2: KT start riding a kernel.
            q.send(ctx, 1, BufSlice::whole(s2, 8), 2, crate::mpi::COMM_WORLD).unwrap();
            let mut kt = gpu::KernelCtx::new();
            q.kt_start(ctx, &mut kt, 1.0).unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "epoch2".into(),
                        flops: 0,
                        bytes: 0,
                        payload: KernelPayload::None,
                    },
                    kt,
                ),
            );
            q.wait(ctx).unwrap();
            stream_synchronize(ctx, sid);
        } else {
            q.recv(ctx, 0, BufSlice::whole(d1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            q.recv(ctx, 0, BufSlice::whole(d2, 8), 2, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| {
                assert_eq!(w.bufs.get(d1), &[1.5; 8], "ST epoch");
                assert_eq!(w.bufs.get(d2), &[2.5; 8], "KT epoch");
            });
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
}

/// `Queue::drain` blocks the host until every started op completed, and
/// returns immediately on a quiet queue.
#[test]
fn queue_drain_waits_out_kt_sends() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![8.0; 16]);
    let dst = w.bufs.alloc(16);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            q.send(ctx, 1, BufSlice::whole(src, 16), 5, crate::mpi::COMM_WORLD).unwrap();
            let mut kt = gpu::KernelCtx::new();
            q.kt_start(ctx, &mut kt, KT_TRIGGER_FRAC).unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "kt_send".into(),
                        flops: 0,
                        bytes: 0,
                        payload: KernelPayload::None,
                    },
                    kt,
                ),
            );
            // No stream wait, no tail kernel: the host drain is the only
            // completion wait — Queue::free must then succeed.
            q.drain(ctx).unwrap();
            q.drain(ctx).unwrap(); // idempotent on a quiet queue
            stream_synchronize(ctx, sid);
            assert_eq!(q.stats(ctx).outstanding, 0);
        } else {
            q.recv(ctx, 0, BufSlice::whole(dst, 16), 5, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[8.0; 16]));
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
}

/// Freeing a busy queue fails — counting enqueued-but-unstarted ops as
/// busy too (they hold armed waiters and DWQ slots) — and hands the
/// still-live handle back so the caller can start, drain, and retry.
#[test]
fn busy_free_returns_the_handle_for_retry() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![3.0; 8]);
    let dst = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
        if rank == 0 {
            // Enqueued but NOT started: the send holds a DWQ slot that
            // only its trigger can release — free must refuse.
            q.send(ctx, 1, BufSlice::whole(src, 8), 7, crate::mpi::COMM_WORLD).unwrap();
            let q = match q.free(ctx) {
                Err((q, StError::QueueBusy(n))) => {
                    assert_eq!(n, 1, "the unstarted send counts as incomplete");
                    q
                }
                other => panic!("expected QueueBusy with the handle back, got {other:?}"),
            };
            q.start(ctx).unwrap();
            q.drain(ctx).unwrap();
            stream_synchronize(ctx, sid);
            q.free(ctx).expect("drained queue frees cleanly on retry");
        } else {
            q.recv(ctx, 0, BufSlice::whole(dst, 8), 7, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.wait(ctx).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[3.0; 8]));
            q.free(ctx).unwrap();
        }
    })
    .unwrap();
}

// ---------------------------------------------------------------------
// NIC resource pools: leak-free error paths, exhaustion, reuse
// ---------------------------------------------------------------------

/// Counter-pool exhaustion fails `Queue::create` cleanly: the trigger
/// counter a half-built queue grabbed is returned (repeated failures do
/// not leak), and freeing a queue makes creation succeed again.
#[test]
fn queue_create_counter_exhaustion_is_leak_free() {
    let mut c = cost();
    c.nic_counter_limit = 3;
    let w = build_world(c, Topology::new(1, 1));
    run_cluster(w, 1, move |rank, ctx| {
        let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
        let q1 = Queue::create(ctx, rank, sid, Variant::StreamTriggered).unwrap();
        // Only one counter left: each attempt grabs it as the trigger
        // counter, fails on the completion counter, and must roll back.
        for _ in 0..3 {
            match Queue::create(ctx, rank, sid, Variant::StreamTriggered) {
                Err(StError::CountersExhausted(node)) => assert_eq!(node, 0),
                other => panic!("expected CountersExhausted, got {other:?}"),
            }
        }
        ctx.with(|w, _| {
            assert_eq!(w.nics[0].counters_in_use, 2, "failed creates must not leak counters");
        });
        q1.free(ctx).unwrap();
        ctx.with(|w, _| assert_eq!(w.nics[0].counters_in_use, 0, "free returns both counters"));
        let q2 = Queue::create(ctx, rank, sid, Variant::StreamTriggered)
            .expect("capacity reclaimed after free");
        q2.free(ctx).unwrap();
    })
    .unwrap();
}

/// A full DWQ fails `Queue::send` with `DwqFull` — leak-free: nothing is
/// armed, no request or slot is held — and once the queue's started ops
/// drain, the same queue is reusable and the send succeeds.
#[test]
fn full_dwq_fails_send_then_queue_is_reusable() {
    let mut c = cost();
    c.dwq_slots_per_nic = 1;
    let mut w = build_world(c, Topology::new(2, 1));
    let s1 = w.bufs.alloc_init(vec![1.0; 8]);
    let s2 = w.bufs.alloc_init(vec![2.0; 8]);
    let d1 = w.bufs.alloc(8);
    let d2 = w.bufs.alloc(8);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let (_sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
            q.send(ctx, 1, BufSlice::whole(s1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            // The single DWQ slot is held by the deferred send above.
            match q.send(ctx, 1, BufSlice::whole(s2, 8), 2, crate::mpi::COMM_WORLD) {
                Err(StError::DwqFull(node)) => assert_eq!(node, 0),
                other => panic!("expected DwqFull, got {other:?}"),
            }
            // Trigger + drain the first send; its descriptor leaves the
            // DWQ, so the exhausted queue becomes reusable.
            q.start(ctx).unwrap();
            q.drain(ctx).unwrap();
            q.send(ctx, 1, BufSlice::whole(s2, 8), 2, crate::mpi::COMM_WORLD)
                .expect("slot reclaimed after the trigger fired");
            q.start(ctx).unwrap();
            q.drain(ctx).unwrap();
            assert_eq!(q.stats(ctx).dwq_posts, 2, "only armed sends count");
            q.free(ctx).unwrap();
        } else {
            for (buf, tag) in [(d1, 1), (d2, 2)] {
                let req = crate::mpi::irecv(
                    ctx,
                    rank,
                    SrcSel::Rank(0),
                    TagSel::Tag(tag),
                    crate::mpi::COMM_WORLD,
                    BufSlice::whole(buf, 8),
                );
                crate::mpi::wait(ctx, req);
            }
            ctx.with(move |w, _| {
                assert_eq!(w.bufs.get(d1), &[1.0; 8]);
                assert_eq!(w.bufs.get(d2), &[2.0; 8]);
            });
        }
    })
    .unwrap();
    assert_eq!(out.world.metrics.dwq_peak, 1);
    assert_eq!(out.world.metrics.dwq_slot_waits, 0, "the raw path fails instead of waiting");
}

// ---------------------------------------------------------------------
// CommPlan: build-once / start-many
// ---------------------------------------------------------------------

/// A plan started N times is event-for-event identical to N hand-driven
/// iterations over the same queue: byte-identical `SimStats` and
/// metrics. (Both sides build the plan so setup costs align; the hand
/// side then ignores it and re-enqueues every descriptor per iteration —
/// exactly what the plan makes unnecessary.)
#[test]
fn plan_rounds_match_hand_enqueued_iterations() {
    fn run(use_plan: bool) -> (SimStats, Metrics) {
        let mut w = build_world(cost(), Topology::new(2, 1));
        let sa = w.bufs.alloc_init(vec![1.0; 16]);
        let sb = w.bufs.alloc_init(vec![2.0; 16]);
        let da = w.bufs.alloc(16);
        let db = w.bufs.alloc(16);
        let out = run_cluster(w, 1, move |rank, ctx| {
            let (sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
            if rank == 0 {
                let qs = std::slice::from_ref(&q);
                let mut b = CommPlan::builder(rank, sid, Variant::StreamTriggered, qs);
                b.send(1, BufSlice::whole(sa, 16), 1, crate::mpi::COMM_WORLD);
                b.send(1, BufSlice::whole(sb, 16), 2, crate::mpi::COMM_WORLD);
                let plan = b.build(ctx).unwrap();
                crate::mpi::barrier(ctx, rank, 2, crate::mpi::COMM_WORLD, 0);
                for _iter in 0..4 {
                    if use_plan {
                        let r = plan.round(ctx, Vec::new()).unwrap();
                        plan.complete(ctx, r).unwrap();
                    } else {
                        q.send(ctx, 1, BufSlice::whole(sa, 16), 1, crate::mpi::COMM_WORLD)
                            .unwrap();
                        q.send(ctx, 1, BufSlice::whole(sb, 16), 2, crate::mpi::COMM_WORLD)
                            .unwrap();
                        q.start(ctx).unwrap();
                        q.wait(ctx).unwrap();
                    }
                    stream_synchronize(ctx, sid);
                }
            } else {
                crate::mpi::barrier(ctx, rank, 2, crate::mpi::COMM_WORLD, 0);
                for _iter in 0..4 {
                    let mut reqs = Vec::new();
                    for (buf, tag) in [(da, 1), (db, 2)] {
                        reqs.push(crate::mpi::irecv(
                            ctx,
                            rank,
                            SrcSel::Rank(0),
                            TagSel::Tag(tag),
                            crate::mpi::COMM_WORLD,
                            BufSlice::whole(buf, 16),
                        ));
                    }
                    crate::mpi::waitall(ctx, &reqs);
                }
                ctx.with(move |w, _| {
                    assert_eq!(w.bufs.get(da), &[1.0; 16]);
                    assert_eq!(w.bufs.get(db), &[2.0; 16]);
                });
            }
            q.free(ctx).unwrap();
        })
        .unwrap();
        (out.stats, out.world.metrics.clone())
    }
    let (hand_stats, hand_metrics) = run(false);
    let (plan_stats, plan_metrics) = run(true);
    assert_eq!(hand_stats, plan_stats, "plan rounds must replay the hand event structure");
    assert_eq!(hand_metrics, plan_metrics, "and move identical traffic");
}

/// Two queues on one rank: a plan stripes its ops round-robin, both
/// queues trigger independently, and with a single-slot DWQ the second
/// queue's arm must wait for the first queue's trigger — the
/// `dwq_slot_waits` contention signal, with correct payloads throughout.
#[test]
fn multi_queue_plan_contends_for_dwq_slots() {
    let mut c = cost();
    c.dwq_slots_per_nic = 1;
    let mut w = build_world(c, Topology::new(2, 1));
    let sa = w.bufs.alloc_init(vec![7.0; 16]);
    let sb = w.bufs.alloc_init(vec![8.0; 16]);
    let da = w.bufs.alloc(16);
    let db = w.bufs.alloc(16);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
            let queues: Vec<Queue> = (0..2)
                .map(|_| Queue::create(ctx, rank, sid, Variant::StreamTriggered).unwrap())
                .collect();
            let mut b = CommPlan::builder(rank, sid, Variant::StreamTriggered, &queues);
            b.send(1, BufSlice::whole(sa, 16), 1, crate::mpi::COMM_WORLD);
            b.send(1, BufSlice::whole(sb, 16), 2, crate::mpi::COMM_WORLD);
            let plan = b.build(ctx).unwrap();
            for _iter in 0..2 {
                let r = plan.round(ctx, Vec::new()).unwrap();
                plan.complete(ctx, r).unwrap();
            }
            plan.drain(ctx).unwrap();
            stream_synchronize(ctx, sid);
            let waits: u64 = queues.iter().map(|q| q.stats(ctx).dwq_slot_waits).sum();
            assert!(waits > 0, "a single-slot DWQ must stall the second queue");
            for q in queues {
                q.free(ctx).unwrap();
            }
        } else {
            for _iter in 0..2 {
                let mut reqs = Vec::new();
                for (buf, tag) in [(da, 1), (db, 2)] {
                    reqs.push(crate::mpi::irecv(
                        ctx,
                        rank,
                        SrcSel::Rank(0),
                        TagSel::Tag(tag),
                        crate::mpi::COMM_WORLD,
                        BufSlice::whole(buf, 16),
                    ));
                }
                crate::mpi::waitall(ctx, &reqs);
            }
            ctx.with(move |w, _| {
                assert_eq!(w.bufs.get(da), &[7.0; 16]);
                assert_eq!(w.bufs.get(db), &[8.0; 16]);
            });
        }
    })
    .unwrap();
    assert!(out.world.metrics.dwq_slot_waits > 0);
    assert_eq!(out.world.metrics.dwq_peak, 1, "occupancy can never exceed the slot count");
}

/// The same plan object drives the KT protocol: hooks ride a synthesized
/// progress kernel when a round has no producer kernels, and `drain` is
/// the region's one host-side wait.
#[test]
fn kt_plan_round_end_to_end() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![4.0; 16]);
    let dst = w.bufs.alloc(16);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let (sid, q) = make_queue(ctx, rank, Variant::KernelTriggered);
            let qs = std::slice::from_ref(&q);
            let mut b = CommPlan::builder(rank, sid, Variant::KernelTriggered, qs);
            b.send(1, BufSlice::whole(src, 16), 3, crate::mpi::COMM_WORLD);
            let plan = b.build(ctx).unwrap();
            for _iter in 0..2 {
                let r = plan.round(ctx, Vec::new()).unwrap();
                plan.complete(ctx, r).unwrap(); // no-op under KT
            }
            plan.drain(ctx).unwrap();
            stream_synchronize(ctx, sid);
            q.free(ctx).unwrap();
        } else {
            for _iter in 0..2 {
                let req = crate::mpi::irecv(
                    ctx,
                    rank,
                    SrcSel::Rank(0),
                    TagSel::Tag(3),
                    crate::mpi::COMM_WORLD,
                    BufSlice::whole(dst, 16),
                );
                crate::mpi::wait(ctx, req);
            }
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[4.0; 16]));
        }
    })
    .unwrap();
    assert_eq!(out.world.metrics.kt_triggers, 2, "one mid-kernel trigger per round");
    assert_eq!(out.world.metrics.memops_executed, 0, "KT plans execute no stream memops");
}

/// Builder validation is eager: wildcards on deferred receives and
/// missing queues fail at build/record time, not at start time.
#[test]
fn plan_builder_validates_eagerly() {
    let w = build_world(cost(), Topology::new(2, 1));
    run_cluster(w, 1, move |rank, ctx| {
        if rank != 0 {
            return;
        }
        let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
        let buf = ctx.with(|w, _| w.bufs.alloc(8));
        // Wildcard deferred receive: rejected at record time.
        let q = Queue::create(ctx, rank, sid, Variant::StreamTriggered).unwrap();
        let qs = std::slice::from_ref(&q);
        let mut b = CommPlan::builder(rank, sid, Variant::StreamTriggered, qs);
        let slice = BufSlice::whole(buf, 8);
        assert_eq!(
            b.recv_deferred(SrcSel::Any, TagSel::Tag(1), crate::mpi::COMM_WORLD, slice),
            Err(StError::WildcardUnsupported)
        );
        // Deferred ops without any queue: rejected at build time.
        let mut b2 = CommPlan::builder(rank, sid, Variant::StreamTriggered, &[]);
        b2.send(1, BufSlice::whole(buf, 8), 1, crate::mpi::COMM_WORLD);
        match b2.build(ctx) {
            Err(StError::PlanWithoutQueue) => {}
            other => panic!("expected PlanWithoutQueue, got {other:?}"),
        }
        q.free(ctx).unwrap();
    })
    .unwrap();
}

// ---------------------------------------------------------------------
// Triggered receives: the receive half of the offload story
// ---------------------------------------------------------------------

/// A receive on a KernelTriggered queue rides a NIC triggered-receive
/// descriptor: the payload lands with ZERO progress-thread involvement
/// on either side's receive path, and the hardware bumps the completion
/// counter. (Compare `st_send_recv_inter_node_end_to_end`, which pins
/// `progress_ops > 0` for the ST emulation.)
#[test]
fn kt_queue_recv_rides_nic_triggered_recv() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![6.5; 32]);
    let dst = w.bufs.alloc(32);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            // Plain host send: keeps the receive path the only deferred op.
            let req =
                crate::mpi::isend(ctx, 0, 1, BufSlice::whole(src, 32), 3, crate::mpi::COMM_WORLD);
            crate::mpi::wait(ctx, req);
        } else {
            let (sid, q) = make_queue(ctx, rank, Variant::KernelTriggered);
            q.recv(ctx, 0, BufSlice::whole(dst, 32), 3, crate::mpi::COMM_WORLD).unwrap();
            let mut kt = gpu::KernelCtx::new();
            q.kt_start(ctx, &mut kt, KT_TRIGGER_FRAC).unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "kt_recv_arm".into(),
                        flops: 500,
                        bytes: 500,
                        payload: KernelPayload::None,
                    },
                    kt,
                ),
            );
            q.drain(ctx).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[6.5; 32], "hw-recv payload"));
            q.free(ctx).expect("completion counter reached started_total");
        }
    })
    .unwrap();
    let m = &out.world.metrics;
    assert_eq!(m.triggered_recvs, 1, "the NIC posted the receive itself");
    assert_eq!(m.dwq_triggered, 1, "the recv descriptor fired from the DWQ");
    assert_eq!(m.progress_ops, 0, "no progress thread anywhere on the KT receive path");
}

/// The unexpected-message interleaving resolves inside the NIC: the
/// payload arrives long before the triggered-receive descriptor fires,
/// waits in the unexpected queue, and is consumed at hardware post time.
#[test]
fn kt_triggered_recv_resolves_unexpected_arrival() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![9.25; 16]);
    let dst = w.bufs.alloc(16);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let req =
                crate::mpi::isend(ctx, 0, 1, BufSlice::whole(src, 16), 4, crate::mpi::COMM_WORLD);
            crate::mpi::wait(ctx, req);
        } else {
            // Arm late: the message has been sitting in the unexpected
            // queue for ~1 ms when the descriptor fires.
            ctx.advance(1_000_000);
            let (sid, q) = make_queue(ctx, rank, Variant::KernelTriggered);
            q.recv(ctx, 0, BufSlice::whole(dst, 16), 4, crate::mpi::COMM_WORLD).unwrap();
            let mut kt = gpu::KernelCtx::new();
            q.kt_start(ctx, &mut kt, 1.0).unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "late_arm".into(),
                        flops: 0,
                        bytes: 0,
                        payload: KernelPayload::None,
                    },
                    kt,
                ),
            );
            q.drain(ctx).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[9.25; 16]));
            q.free(ctx).unwrap();
        }
    })
    .unwrap();
    assert_eq!(out.world.metrics.unexpected_msgs, 1, "the payload beat the descriptor");
    assert_eq!(out.world.metrics.triggered_recvs, 1);
}

/// `Queue::kt_recv` — the doorbell path: the kernel itself posts the
/// receive from its epilogue wavefront, and a trailing prologue wait
/// covers its completion.
#[test]
fn kt_recv_doorbell_posts_from_kernel_epilogue() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![1.75; 8]);
    let dst = w.bufs.alloc(8);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            ctx.advance(50_000);
            let req =
                crate::mpi::isend(ctx, 0, 1, BufSlice::whole(src, 8), 6, crate::mpi::COMM_WORLD);
            crate::mpi::wait(ctx, req);
        } else {
            let (sid, q) = make_queue(ctx, rank, Variant::KernelTriggered);
            let mut kt = gpu::KernelCtx::new();
            let req = q
                .kt_recv(ctx, &mut kt, 1.0, 0, BufSlice::whole(dst, 8), 6, crate::mpi::COMM_WORLD)
                .unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "epilogue_recv".into(),
                        flops: 800,
                        bytes: 800,
                        payload: KernelPayload::None,
                    },
                    kt,
                ),
            );
            // Host-side wait interop: the doorbell recv returned a
            // standard request id.
            crate::mpi::wait(ctx, req);
            q.drain(ctx).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[1.75; 8]));
            q.free(ctx).expect("doorbell recv joined the completion accounting");
        }
    })
    .unwrap();
    let m = &out.world.metrics;
    assert_eq!(m.triggered_recvs, 1);
    assert_eq!(m.kt_triggers, 1, "the doorbell rang from inside the kernel");
    assert_eq!(m.dwq_triggered, 0, "doorbell posts bypass the deferred-work queue");
}

/// A full DWQ fails `Queue::recv` on a KT queue with `DwqFull` —
/// hardware recv descriptors occupy slots like triggered sends — and
/// the failure is leak-free: once the armed descriptor fires, the queue
/// is reusable.
#[test]
fn full_dwq_fails_kt_recv_then_queue_is_reusable() {
    let mut c = cost();
    c.dwq_slots_per_nic = 1;
    let mut w = build_world(c, Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![4.0; 8]);
    let d1 = w.bufs.alloc(8);
    let d2 = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            for tag in [1, 2] {
                let req = crate::mpi::isend(
                    ctx,
                    0,
                    1,
                    BufSlice::whole(src, 8),
                    tag,
                    crate::mpi::COMM_WORLD,
                );
                crate::mpi::wait(ctx, req);
            }
        } else {
            let (sid, q) = make_queue(ctx, rank, Variant::KernelTriggered);
            q.recv(ctx, 0, BufSlice::whole(d1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            match q.recv(ctx, 0, BufSlice::whole(d2, 8), 2, crate::mpi::COMM_WORLD) {
                Err(StError::DwqFull(node)) => assert_eq!(node, 1),
                other => panic!("expected DwqFull, got {other:?}"),
            }
            q.start(ctx).unwrap();
            q.drain(ctx).unwrap();
            q.recv(ctx, 0, BufSlice::whole(d2, 8), 2, crate::mpi::COMM_WORLD)
                .expect("slot reclaimed after the recv descriptor fired");
            q.start(ctx).unwrap();
            q.drain(ctx).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| {
                assert_eq!(w.bufs.get(d1), &[4.0; 8]);
                assert_eq!(w.bufs.get(d2), &[4.0; 8]);
            });
            q.free(ctx).unwrap();
        }
    })
    .unwrap();
}

/// Plan-vs-hand equivalence for KT receives: a KT-variant plan with a
/// deferred receive replays the exact event/cost structure of the
/// hand-driven kt_wait / recv / send / kt_start sequence — byte-identical
/// `SimStats` (the stx v2 event-equivalence contract extended to the
/// triggered-receive path).
#[test]
fn kt_plan_deferred_recvs_match_hand_kt_iterations() {
    fn run(use_plan: bool) -> SimStats {
        let mut w = build_world(cost(), Topology::new(2, 1));
        let sa = w.bufs.alloc_init(vec![1.0; 16]);
        let sb = w.bufs.alloc_init(vec![2.0; 16]);
        let da = w.bufs.alloc(16);
        let db = w.bufs.alloc(16);
        let out = run_cluster(w, 1, move |rank, ctx| {
            let (sid, q) = make_queue(ctx, rank, Variant::KernelTriggered);
            let (my_send, my_recv, peer) = if rank == 0 { (sa, da, 1) } else { (sb, db, 1 - rank) };
            let (tag_out, tag_in) = if rank == 0 { (10, 11) } else { (11, 10) };
            // Both sides build the identical plan, so setup costs align;
            // the hand side then ignores it (cf.
            // plan_rounds_match_hand_enqueued_iterations).
            let qs = std::slice::from_ref(&q);
            let mut b = CommPlan::builder(rank, sid, Variant::KernelTriggered, qs);
            b.send(peer, BufSlice::whole(my_send, 16), tag_out, crate::mpi::COMM_WORLD);
            b.recv_deferred(
                SrcSel::Rank(peer),
                TagSel::Tag(tag_in),
                crate::mpi::COMM_WORLD,
                BufSlice::whole(my_recv, 16),
            )
            .unwrap();
            let plan = b.build(ctx).unwrap();
            for _iter in 0..3 {
                if use_plan {
                    let r = plan.round(ctx, Vec::new()).unwrap();
                    plan.complete(ctx, r).unwrap();
                } else {
                    // The hand-rolled shape of CommPlan::round's KT arm:
                    // prologue wait, arm send then recv, trigger on the
                    // (single) progress kernel.
                    let mut kt = gpu::KernelCtx::new();
                    q.kt_wait(ctx, &mut kt).unwrap();
                    q.send(ctx, peer, BufSlice::whole(my_send, 16), tag_out, crate::mpi::COMM_WORLD)
                        .unwrap();
                    q.recv(ctx, peer, BufSlice::whole(my_recv, 16), tag_in, crate::mpi::COMM_WORLD)
                        .unwrap();
                    q.kt_start(ctx, &mut kt, KT_TRIGGER_FRAC).unwrap();
                    host_enqueue(
                        ctx,
                        sid,
                        StreamOp::KtKernel(
                            KernelSpec {
                                name: "plan_progress".into(),
                                flops: 0,
                                bytes: 0,
                                payload: KernelPayload::None,
                            },
                            kt,
                        ),
                    );
                }
            }
            if use_plan {
                plan.drain(ctx).unwrap();
            } else {
                q.drain(ctx).unwrap();
            }
            stream_synchronize(ctx, sid);
            q.free(ctx).unwrap();
        })
        .unwrap();
        out.stats
    }
    assert_eq!(run(true), run(false), "plan vs hand SimStats (KT deferred recvs)");
}

// ---------------------------------------------------------------------
// Fault injection: watchdog timeout, force-free recovery, leak audit
// ---------------------------------------------------------------------

/// Exhaust-then-reuse leak audit for the recovery path: a queue
/// abandoned with an armed-but-never-triggered send holds one DWQ slot
/// and two NIC counters; `free_after_timeout` must cancel the orphaned
/// descriptor (crediting the released cell so the pool is reusable) and
/// return both counters — after which the exhausted resources can be
/// re-acquired in the same run.
#[test]
fn force_free_reclaims_dwq_slots_and_counters() {
    let mut c = cost();
    c.dwq_slots_per_nic = 1;
    let mut w = build_world(c, Topology::new(2, 1));
    let s1 = w.bufs.alloc_init(vec![1.0; 8]);
    let s2 = w.bufs.alloc_init(vec![2.0; 8]);
    let d2 = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let (_sid, q1) = make_queue(ctx, rank, Variant::StreamTriggered);
            let (_sid2, q2) = make_queue(ctx, rank, Variant::StreamTriggered);
            // q1's deferred send takes the single DWQ slot and is never
            // started: its trigger will never fire.
            q1.send(ctx, 1, BufSlice::whole(s1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            match q2.send(ctx, 1, BufSlice::whole(s2, 8), 2, crate::mpi::COMM_WORLD) {
                Err(StError::DwqFull(node)) => assert_eq!(node, 0),
                other => panic!("expected DwqFull, got {other:?}"),
            }
            let before = ctx.with(|w, _| w.nics[0].counters_in_use);
            let cancelled = q1.free_after_timeout(ctx).expect("force-free");
            assert_eq!(cancelled, 1, "the armed-but-never-triggered send is cancelled");
            ctx.with(move |w, _| {
                assert_eq!(
                    w.nics[0].counters_in_use,
                    before - 2,
                    "force-free returns both hardware counters"
                );
            });
            // Exhaust-then-reuse: the cancelled descriptor's slot is
            // observable as free, so the blocked send now arms, fires,
            // and completes.
            q2.send(ctx, 1, BufSlice::whole(s2, 8), 2, crate::mpi::COMM_WORLD)
                .expect("slot reclaimed by dwq_cancel");
            q2.start(ctx).unwrap();
            q2.drain(ctx).unwrap();
            q2.free(ctx).unwrap();
        } else {
            let req = crate::mpi::irecv(
                ctx,
                rank,
                SrcSel::Rank(0),
                TagSel::Tag(2),
                crate::mpi::COMM_WORLD,
                BufSlice::whole(d2, 8),
            );
            crate::mpi::wait(ctx, req);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(d2), &[2.0; 8]));
        }
    })
    .unwrap();
}

/// `timeout_error` mode end to end: every wire payload is dropped and
/// the watchdog has no retry budget, so the receiver's drain surfaces
/// `StError::DrainTimeout` (instead of parking forever or stalling the
/// engine), the abandoned queue force-frees, and the NIC pool is
/// immediately reusable — all within one run, with the fault counters
/// visible in `Metrics`.
#[test]
fn drain_timeout_error_mode_reports_and_recovers() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let spec = crate::fault::FaultSpec {
        drop_prob: 1.0,
        max_retries: 0,
        timeout_error: true,
        ..Default::default()
    };
    let fp = crate::fault::fingerprint(spec.seed, "stx/drain-timeout");
    w.fault = Some(crate::fault::FaultState::new(crate::fault::FaultPlan::new(spec, fp, 2)));
    let src = w.bufs.alloc_init(vec![4.0; 8]);
    let dst = w.bufs.alloc(8);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            // Plain eager send; the plan drops it on the wire (the
            // sender still completes locally, so this host finishes).
            let req =
                crate::mpi::isend(ctx, rank, 1, BufSlice::whole(src, 8), 9, crate::mpi::COMM_WORLD);
            crate::mpi::wait(ctx, req);
        } else {
            let (_sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
            q.recv(ctx, 0, BufSlice::whole(dst, 8), 9, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            match q.drain(ctx) {
                Err(StError::DrainTimeout { queue: _, outstanding }) => {
                    assert_eq!(outstanding, 1, "the dropped payload never completed the recv")
                }
                other => panic!("expected DrainTimeout, got {other:?}"),
            }
            let before = ctx.with(|w, _| w.nics[1].counters_in_use);
            let cancelled = q.free_after_timeout(ctx).expect("abandoned queue force-frees");
            assert_eq!(cancelled, 0, "an ST recv rides the progress thread, not the DWQ");
            ctx.with(move |w, _| assert_eq!(w.nics[1].counters_in_use, before - 2));
            // The pool is reusable in the same run.
            let (_sid2, q2) = make_queue(ctx, rank, Variant::StreamTriggered);
            q2.free(ctx).unwrap();
        }
    })
    .unwrap();
    assert_eq!(out.world.metrics.faults_injected, 1, "exactly one drop was injected");
    assert_eq!(out.world.metrics.timeouts, 1, "the watchdog gave up once");
    assert_eq!(out.world.metrics.retries, 0, "no retry budget in this spec");
}

/// The recovery half under a budget: the same dropped payload, but the
/// watchdog may retransmit — the receiver's drain then completes with
/// the replayed data and validates, no timeout surfaced.
#[test]
fn watchdog_retransmit_recovers_a_dropped_payload() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let spec = crate::fault::FaultSpec { drop_prob: 1.0, ..Default::default() };
    let fp = crate::fault::fingerprint(spec.seed, "stx/retransmit");
    w.fault = Some(crate::fault::FaultState::new(crate::fault::FaultPlan::new(spec, fp, 2)));
    let src = w.bufs.alloc_init(vec![6.5; 8]);
    let dst = w.bufs.alloc(8);
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let req =
                crate::mpi::isend(ctx, rank, 1, BufSlice::whole(src, 8), 9, crate::mpi::COMM_WORLD);
            crate::mpi::wait(ctx, req);
        } else {
            let (_sid, q) = make_queue(ctx, rank, Variant::StreamTriggered);
            q.recv(ctx, 0, BufSlice::whole(dst, 8), 9, crate::mpi::COMM_WORLD).unwrap();
            q.start(ctx).unwrap();
            q.drain(ctx).expect("the retransmitted payload completes the drain");
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[6.5; 8]));
            q.free(ctx).unwrap();
        }
    })
    .unwrap();
    assert_eq!(out.world.metrics.faults_injected, 1);
    assert_eq!(out.world.metrics.retries, 1, "one watchdog retransmit recovered the payload");
    assert_eq!(out.world.metrics.timeouts, 0);
}

/// Snapshot-and-reset leak audit for the recovery paths: one run
/// force-frees a timed-out queue, a second run abandons an
/// armed-but-never-triggered send outright (hosts exit with the
/// descriptor still armed, so the run completes holding a DWQ slot, two
/// counters, and an armed-registry entry). `World::reset` must return
/// every slot and counter and empty the armed registry — and the reused
/// world must then drive a full send/recv exchange with the whole pool
/// available again (exhaust → reset → reuse).
#[test]
fn reset_reclaims_abandoned_queue_resources_for_reuse() {
    let mut c = cost();
    c.dwq_slots_per_nic = 1;
    let mut w = build_world(c, Topology::new(2, 1));
    let s1 = w.bufs.alloc_init(vec![1.0; 8]);

    // Run 1: arm a deferred send on the only DWQ slot and never start
    // the queue. Nobody waits on it, so the run completes "leaking" the
    // slot, both hardware counters, and the armed descriptor.
    let out = run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let (_sid, q1) = make_queue(ctx, rank, Variant::StreamTriggered);
            q1.send(ctx, 1, BufSlice::whole(s1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
        }
    })
    .unwrap();
    let mut w = out.world;
    assert_eq!(w.nics[0].counters_in_use, 2, "abandoned queue still holds its counters");
    assert_eq!(w.nics[0].dwq_posted, 1, "the armed send holds the only DWQ slot");
    assert_eq!(w.armed.len(), 1, "the descriptor is still registered as armed");

    let snap = w.snapshot();
    w.reset(&snap);
    assert_eq!(w.nics[0].counters_in_use, 0, "reset returns the hardware counters");
    assert_eq!(w.nics[0].dwq_posted, 0, "reset returns the DWQ slot");
    assert!(w.armed.is_empty(), "reset empties the armed registry");

    // Run 2 on the SAME world: a timed-out queue force-frees (the other
    // recovery path), then the full pool carries a complete exchange.
    let s2 = w.bufs.alloc_init(vec![2.0; 8]);
    let s3 = w.bufs.alloc_init(vec![3.0; 8]);
    let d3 = w.bufs.alloc(8);
    let out = run_cluster(w, 2, move |rank, ctx| {
        if rank == 0 {
            let (_sid, q1) = make_queue(ctx, rank, Variant::StreamTriggered);
            q1.send(ctx, 1, BufSlice::whole(s2, 8), 1, crate::mpi::COMM_WORLD)
                .expect("the reset world's DWQ slot is free");
            let cancelled = q1.free_after_timeout(ctx).expect("force-free");
            assert_eq!(cancelled, 1, "the armed send is cancelled, crediting the slot");
            let (_sid2, q2) = make_queue(ctx, rank, Variant::StreamTriggered);
            q2.send(ctx, 1, BufSlice::whole(s3, 8), 2, crate::mpi::COMM_WORLD)
                .expect("slot credited by the force-free");
            q2.start(ctx).unwrap();
            q2.drain(ctx).unwrap();
            q2.free(ctx).unwrap();
        } else {
            let req = crate::mpi::irecv(
                ctx,
                rank,
                SrcSel::Rank(0),
                TagSel::Tag(2),
                crate::mpi::COMM_WORLD,
                BufSlice::whole(d3, 8),
            );
            crate::mpi::wait(ctx, req);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(d3), &[3.0; 8]));
        }
    })
    .unwrap();
    assert_eq!(out.world.nics[0].dwq_posted, 2, "run 2 posted the cancelled and replayed sends");
}
