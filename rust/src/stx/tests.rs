//! ST extension tests: the paper's §III semantics.

use super::*;
use crate::coordinator::{build_world, run_cluster};
use crate::costmodel::presets;
use crate::gpu::{host_enqueue, stream_synchronize, KernelPayload, KernelSpec};
use crate::world::{BufId, Topology, World};

fn cost() -> crate::costmodel::CostModel {
    let mut c = presets::frontier_like();
    c.jitter_sigma = 0.0;
    c
}

fn fill_kernel(buf: BufId, val: f32) -> StreamOp {
    StreamOp::Kernel(KernelSpec {
        name: format!("fill{val}"),
        flops: 1000,
        bytes: 1000,
        payload: KernelPayload::Fn(Box::new(move |w, _| w.bufs.get_mut(buf).fill(val))),
    })
}

/// Create a stream + queue for `rank` from inside a host actor.
fn make_queue(ctx: &mut crate::sim::HostCtx<World>, rank: usize, flavor: MemOpFlavor) -> (StreamId, usize) {
    let sid = ctx.with(move |w, core| gpu::create_stream(w, core, rank));
    let q = create_queue(ctx, rank, sid, flavor);
    (sid, q)
}

/// The paper's core scenario (Fig. 2): kernel K1, triggered send, wait,
/// kernel K2 — all driven by the GPU CP, host never blocks on comm.
#[test]
fn st_send_recv_inter_node_end_to_end() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc(64);
    let dst = w.bufs.alloc(64);
    let out = run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            // K1 writes the data that the ST send must pick up.
            host_enqueue(ctx, sid, fill_kernel(src, 3.25));
            enqueue_send(ctx, q, 1, BufSlice::whole(src, 64), 11, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
            stream_synchronize(ctx, sid);
        } else {
            enqueue_recv(ctx, q, 0, BufSlice::whole(dst, 64), 11, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
            // K2 consumes the received data, in stream order after the wait.
            host_enqueue(
                ctx,
                sid,
                StreamOp::Kernel(KernelSpec {
                    name: "consume".into(),
                    flops: 0,
                    bytes: 0,
                    payload: KernelPayload::Fn(Box::new(move |w, _| {
                        assert_eq!(w.bufs.get(dst), &[3.25; 64], "K2 must see received data");
                    })),
                }),
            );
            stream_synchronize(ctx, sid);
        }
        free_queue(ctx, q).unwrap();
    })
    .unwrap();
    assert_eq!(out.world.metrics.dwq_triggered, 1, "send offloaded to NIC DWQ");
    assert!(out.world.metrics.progress_ops > 0, "recv emulated by progress thread");
}

/// Fig. 7: one start triggers a batch of four sends.
#[test]
fn batched_start_triggers_all_enqueued_ops() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let srcs: Vec<BufId> = (0..4).map(|i| w.bufs.alloc_init(vec![i as f32; 32])).collect();
    let dsts: Vec<BufId> = (0..4).map(|_| w.bufs.alloc(32)).collect();
    let srcs2 = srcs.clone();
    let dsts2 = dsts.clone();
    let tags = [123, 126, 125, 124];
    let out = run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            for (i, &b) in srcs2.iter().enumerate() {
                enqueue_send(ctx, q, 1, BufSlice::whole(b, 32), tags[i], crate::mpi::COMM_WORLD_DUP)
                    .unwrap();
            }
            enqueue_start(ctx, q).unwrap(); // single start for all four
            enqueue_wait(ctx, q).unwrap();
        } else {
            for (i, &b) in dsts2.iter().enumerate() {
                enqueue_recv(ctx, q, 0, BufSlice::whole(b, 32), tags[i], crate::mpi::COMM_WORLD_DUP)
                    .unwrap();
            }
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
        }
        stream_synchronize(ctx, sid);
        if rank == 1 {
            let d = dsts2.clone();
            ctx.with(move |w, _| {
                for (i, &b) in d.iter().enumerate() {
                    assert_eq!(w.bufs.get(b), &[i as f32; 32], "batched msg {i}");
                }
            });
        }
        free_queue(ctx, q).unwrap();
    })
    .unwrap();
    assert_eq!(out.world.metrics.dwq_triggered, 4);
    // Exactly one trigger write + one completion wait per rank => 4 memops
    // total (2 ranks x (start + wait)).
    assert_eq!(out.world.metrics.memops_executed, 4);
}

/// §III-B2 item 2: buffers may be mutated by kernels enqueued before the
/// start; the send must transmit the post-kernel contents.
#[test]
fn deferred_send_sees_kernel_writes() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![-1.0; 16]);
    let dst = w.bufs.alloc(16);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            // Enqueue the send FIRST, kernel writes after host-enqueue but
            // before the start in stream order.
            enqueue_send(ctx, q, 1, BufSlice::whole(src, 16), 1, crate::mpi::COMM_WORLD).unwrap();
            host_enqueue(ctx, sid, fill_kernel(src, 9.5));
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
        } else {
            enqueue_recv(ctx, q, 0, BufSlice::whole(dst, 16), 1, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
        }
        stream_synchronize(ctx, sid);
        if rank == 1 {
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[9.5; 16]));
        }
        free_queue(ctx, q).unwrap();
    })
    .unwrap();
}

/// Intra-node ST traffic must flow through the progress thread (§IV-B).
#[test]
fn intra_node_st_uses_progress_thread() {
    let mut w = build_world(cost(), Topology::new(1, 2));
    let src = w.bufs.alloc_init(vec![6.0; 32]);
    let dst = w.bufs.alloc(32);
    let out = run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            enqueue_send(ctx, q, 1, BufSlice::whole(src, 32), 2, crate::mpi::COMM_WORLD).unwrap();
        } else {
            enqueue_recv(ctx, q, 0, BufSlice::whole(dst, 32), 2, crate::mpi::COMM_WORLD).unwrap();
        }
        enqueue_start(ctx, q).unwrap();
        enqueue_wait(ctx, q).unwrap();
        stream_synchronize(ctx, sid);
        if rank == 1 {
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[6.0; 32]));
        }
        free_queue(ctx, q).unwrap();
    })
    .unwrap();
    assert_eq!(out.world.metrics.dwq_triggered, 0, "no NIC offload intra-node");
    assert!(
        out.world.metrics.progress_ops >= 2,
        "both the emulated send and recv go through the progress thread"
    );
    assert_eq!(out.world.metrics.intra_sends, 1);
}

/// The wait op stalls the *stream*: a kernel enqueued after
/// `enqueue_wait` must not run before the data has landed, but the host
/// returns immediately (non-blocking semantics, §III-B2).
#[test]
fn enqueue_wait_is_host_asynchronous() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![1.0; 8]);
    let dst = w.bufs.alloc(8);
    let host_return_time = std::sync::Arc::new(std::sync::Mutex::new(0u64));
    let hrt = host_return_time.clone();
    let out = run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            // Rank 0 delays its send by doing host work first.
            ctx.advance(300_000);
            enqueue_send(ctx, q, 1, BufSlice::whole(src, 8), 3, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
            stream_synchronize(ctx, sid);
        } else {
            enqueue_recv(ctx, q, 0, BufSlice::whole(dst, 8), 3, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
            // All four calls return without blocking on the (still
            // far-away) sender:
            *hrt.lock().unwrap() = ctx.now();
            stream_synchronize(ctx, sid); // ... this one blocks.
            free_queue(ctx, q).unwrap();
            return;
        }
        free_queue(ctx, q).unwrap();
    })
    .unwrap();
    let t = *host_return_time.lock().unwrap();
    assert!(
        t < 300_000,
        "enqueue calls must return immediately (host returned at {t})"
    );
    assert!(out.rank_finish[1] > 300_000, "but the stream finished after the send");
}

#[test]
fn free_busy_queue_is_an_error() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![1.0; 8]);
    let dst = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            enqueue_send(ctx, q, 1, BufSlice::whole(src, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            // Freeing before completion must fail with QueueBusy.
            match free_queue(ctx, q) {
                Err(StError::QueueBusy(n)) => assert_eq!(n, 1),
                other => panic!("expected QueueBusy, got {other:?}"),
            }
            enqueue_wait(ctx, q).unwrap();
            stream_synchronize(ctx, sid);
            free_queue(ctx, q).unwrap();
            // Double-free reports QueueFreed.
            assert_eq!(free_queue(ctx, q), Err(StError::QueueFreed(q)));
        } else {
            enqueue_recv(ctx, q, 0, BufSlice::whole(dst, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
            stream_synchronize(ctx, sid);
            free_queue(ctx, q).unwrap();
        }
    })
    .unwrap();
}

#[test]
fn wildcards_rejected() {
    assert_eq!(
        validate_selectors(SrcSel::Any, TagSel::Tag(1)),
        Err(StError::WildcardUnsupported)
    );
    assert_eq!(
        validate_selectors(SrcSel::Rank(0), TagSel::Any),
        Err(StError::WildcardUnsupported)
    );
    assert!(validate_selectors(SrcSel::Rank(0), TagSel::Tag(1)).is_ok());
}

/// §III-D: MPIX_Enqueue_send interoperates with standard MPI_Irecv.
#[test]
fn st_send_matches_standard_irecv() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![4.5; 16]);
    let dst = w.bufs.alloc(16);
    run_cluster(w, 1, move |rank, ctx| {
        if rank == 0 {
            let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
            enqueue_send(ctx, q, 1, BufSlice::whole(src, 16), 8, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
            stream_synchronize(ctx, sid);
            free_queue(ctx, q).unwrap();
        } else {
            // Plain MPI_Irecv + MPI_Wait on the receiving side.
            let req = crate::mpi::irecv(
                ctx,
                1,
                SrcSel::Rank(0),
                TagSel::Tag(8),
                crate::mpi::COMM_WORLD,
                BufSlice::whole(dst, 16),
            );
            crate::mpi::wait(ctx, req);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[4.5; 16]));
        }
    })
    .unwrap();
}

/// Host-side MPI_Wait on an ST request (§III-B2 item 4).
#[test]
fn host_wait_on_st_request() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![2.0; 8]);
    let dst = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            let req =
                enqueue_send(ctx, q, 1, BufSlice::whole(src, 8), 4, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            crate::mpi::wait(ctx, req); // host blocks until the ST send completes
        } else {
            let req =
                enqueue_recv(ctx, q, 0, BufSlice::whole(dst, 8), 4, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            crate::mpi::wait(ctx, req);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[2.0; 8]));
        }
        let _ = sid;
    })
    .unwrap();
}

/// Two epochs: ops after a start belong to the next trigger epoch (Fig 6:
/// T1 triggers S1/R1, T2 triggers S2/R2).
#[test]
fn multiple_start_epochs() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let s1 = w.bufs.alloc_init(vec![1.0; 8]);
    let s2 = w.bufs.alloc_init(vec![2.0; 8]);
    let d1 = w.bufs.alloc(8);
    let d2 = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            enqueue_send(ctx, q, 1, BufSlice::whole(s1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap(); // T1
            enqueue_send(ctx, q, 1, BufSlice::whole(s2, 8), 2, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap(); // T2
            enqueue_wait(ctx, q).unwrap(); // W: waits for both epochs
        } else {
            enqueue_recv(ctx, q, 0, BufSlice::whole(d1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_recv(ctx, q, 0, BufSlice::whole(d2, 8), 2, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
        }
        stream_synchronize(ctx, sid);
        if rank == 1 {
            ctx.with(move |w, _| {
                assert_eq!(w.bufs.get(d1), &[1.0; 8]);
                assert_eq!(w.bufs.get(d2), &[2.0; 8]);
            });
        }
        free_queue(ctx, q).unwrap();
    })
    .unwrap();
}

/// The shader-flavored queue completes faster than the HIP one on an
/// identical workload (the Fig 12 mechanism).
#[test]
fn shader_flavor_is_faster() {
    fn run_flavor(flavor: MemOpFlavor) -> u64 {
        let mut w = build_world(cost(), Topology::new(2, 1));
        let src = w.bufs.alloc_init(vec![1.0; 64]);
        let dst = w.bufs.alloc(64);
        let out = run_cluster(w, 1, move |rank, ctx| {
            let (sid, q) = make_queue(ctx, rank, flavor);
            for e in 0..4 {
                if rank == 0 {
                    enqueue_send(ctx, q, 1, BufSlice::whole(src, 64), e, crate::mpi::COMM_WORLD)
                        .unwrap();
                } else {
                    enqueue_recv(ctx, q, 0, BufSlice::whole(dst, 64), e, crate::mpi::COMM_WORLD)
                        .unwrap();
                }
                enqueue_start(ctx, q).unwrap();
                enqueue_wait(ctx, q).unwrap();
            }
            stream_synchronize(ctx, sid);
            free_queue(ctx, q).unwrap();
        })
        .unwrap();
        out.makespan
    }
    let hip = run_flavor(MemOpFlavor::Hip);
    let shader = run_flavor(MemOpFlavor::Shader);
    assert!(shader < hip, "shader {shader} must beat hip {hip}");
}

// ---------------------------------------------------------------------
// Kernel-triggered (KT) wrappers
// ---------------------------------------------------------------------

/// The KT core scenario: the pack kernel itself fires the trigger
/// mid-execution and a later kernel's prologue carries the completion
/// wait — end to end with zero stream memory ops on the sender.
#[test]
fn kt_send_recv_inter_node_end_to_end() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc(64);
    let dst = w.bufs.alloc(64);
    let out = run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            // The deferred send is enqueued first; the pack kernel that
            // produces the data also releases it (stream-ordering: data
            // commits at body start, trigger fires later in the window).
            enqueue_send(ctx, q, 1, BufSlice::whole(src, 64), 11, crate::mpi::COMM_WORLD).unwrap();
            let mut kt = gpu::KernelCtx::new();
            kt_start(ctx, q, &mut kt, KT_TRIGGER_FRAC).unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "kt_pack".into(),
                        flops: 1000,
                        bytes: 1000,
                        payload: KernelPayload::Fn(Box::new(move |w, _| {
                            w.bufs.get_mut(src).fill(3.25)
                        })),
                    },
                    kt,
                ),
            );
            // A trailing kernel's prologue waits out the completion.
            let mut tail = gpu::KernelCtx::new();
            kt_wait(ctx, q, &mut tail).unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "kt_tail".into(),
                        flops: 0,
                        bytes: 0,
                        payload: KernelPayload::None,
                    },
                    tail,
                ),
            );
            stream_synchronize(ctx, sid);
        } else {
            enqueue_recv(ctx, q, 0, BufSlice::whole(dst, 64), 11, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[3.25; 64], "KT payload"));
        }
        free_queue(ctx, q).unwrap();
    })
    .unwrap();
    assert_eq!(out.world.metrics.dwq_triggered, 1, "send offloaded to NIC DWQ");
    assert_eq!(out.world.metrics.kt_triggers, 1, "trigger fired from inside the kernel");
    // Only the *receiver* executed memops (its ST start+wait): the KT
    // sender paid none.
    assert_eq!(out.world.metrics.memops_executed, 2);
}

/// ST and KT starts may be mixed on one queue: the absolute-epoch
/// `writeValue64` and the device-scope increment advance the trigger
/// counter to the same values.
#[test]
fn st_and_kt_starts_interoperate_on_one_queue() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let s1 = w.bufs.alloc_init(vec![1.5; 8]);
    let s2 = w.bufs.alloc_init(vec![2.5; 8]);
    let d1 = w.bufs.alloc(8);
    let d2 = w.bufs.alloc(8);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            // Epoch 1: classic ST start.
            enqueue_send(ctx, q, 1, BufSlice::whole(s1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            // Epoch 2: KT start riding a kernel.
            enqueue_send(ctx, q, 1, BufSlice::whole(s2, 8), 2, crate::mpi::COMM_WORLD).unwrap();
            let mut kt = gpu::KernelCtx::new();
            kt_start(ctx, q, &mut kt, 1.0).unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "epoch2".into(),
                        flops: 0,
                        bytes: 0,
                        payload: KernelPayload::None,
                    },
                    kt,
                ),
            );
            enqueue_wait(ctx, q).unwrap();
            stream_synchronize(ctx, sid);
        } else {
            enqueue_recv(ctx, q, 0, BufSlice::whole(d1, 8), 1, crate::mpi::COMM_WORLD).unwrap();
            enqueue_recv(ctx, q, 0, BufSlice::whole(d2, 8), 2, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| {
                assert_eq!(w.bufs.get(d1), &[1.5; 8], "ST epoch");
                assert_eq!(w.bufs.get(d2), &[2.5; 8], "KT epoch");
            });
        }
        free_queue(ctx, q).unwrap();
    })
    .unwrap();
}

/// `queue_drain` blocks the host until every started op completed, and
/// returns immediately on a quiet queue; freed queues are an error.
#[test]
fn queue_drain_waits_out_kt_sends() {
    let mut w = build_world(cost(), Topology::new(2, 1));
    let src = w.bufs.alloc_init(vec![8.0; 16]);
    let dst = w.bufs.alloc(16);
    run_cluster(w, 1, move |rank, ctx| {
        let (sid, q) = make_queue(ctx, rank, MemOpFlavor::Hip);
        if rank == 0 {
            enqueue_send(ctx, q, 1, BufSlice::whole(src, 16), 5, crate::mpi::COMM_WORLD).unwrap();
            let mut kt = gpu::KernelCtx::new();
            kt_start(ctx, q, &mut kt, KT_TRIGGER_FRAC).unwrap();
            host_enqueue(
                ctx,
                sid,
                StreamOp::KtKernel(
                    KernelSpec {
                        name: "kt_send".into(),
                        flops: 0,
                        bytes: 0,
                        payload: KernelPayload::None,
                    },
                    kt,
                ),
            );
            // No enqueue_wait, no tail kernel: the host drain is the only
            // completion wait — free_queue must then succeed.
            queue_drain(ctx, q).unwrap();
            queue_drain(ctx, q).unwrap(); // idempotent on a quiet queue
            stream_synchronize(ctx, sid);
        } else {
            enqueue_recv(ctx, q, 0, BufSlice::whole(dst, 16), 5, crate::mpi::COMM_WORLD).unwrap();
            enqueue_start(ctx, q).unwrap();
            enqueue_wait(ctx, q).unwrap();
            stream_synchronize(ctx, sid);
            ctx.with(move |w, _| assert_eq!(w.bufs.get(dst), &[8.0; 16]));
        }
        free_queue(ctx, q).unwrap();
        assert_eq!(queue_drain(ctx, q), Err(StError::QueueFreed(q)));
    })
    .unwrap();
}
