//! Deterministic fault injection: seeded chaos for triggered operations.
//!
//! The premise of stream-triggered communication is that the host steps
//! out of the loop — which means a dropped wire message, a NIC counter
//! that never reaches its threshold, or a DWQ descriptor armed against a
//! doorbell that never rings is a *silent hang* with no CPU thread
//! watching. This module supplies the chaos half of the robustness
//! contract (the diagnosis half is [`crate::sim::StallReport`]):
//!
//! * [`FaultSpec`] — the knob set: message drop / duplication / extra
//!   delay probabilities on the wire path, delayed NIC trigger fire,
//!   straggler ranks (cost-model perturbation of kernel durations), and
//!   the recovery watchdog (timeout, bounded retries with exponential
//!   backoff).
//! * [`FaultPlan`] — a *per-run* decision stream: one [`SplitMix64`]
//!   seeded from a campaign-cell [`fingerprint`], consumed in event
//!   order. Because each simulation run is single-threaded and
//!   event-ordered deterministically, the same `(spec, fingerprint)`
//!   yields byte-identical fault decisions on every rerun and at any
//!   `STMPI_SWEEP_THREADS`.
//! * [`FaultState`] — the per-world runtime state: the plan, the ledger
//!   of dropped payloads awaiting retransmission ([`LostMsg`]), and the
//!   wire sequence counter used for idempotent duplicate resolution in
//!   the matching engine.
//!
//! Injection sites (all inert when `World::fault` is `None` — the
//! no-fault timeline is bit-for-bit unchanged):
//!
//! | fault            | site                                   | effect |
//! |------------------|----------------------------------------|--------|
//! | drop             | `nic::execute_send` (eager payload)    | remote delivery skipped; payload recorded in the lost ledger for watchdog retransmit |
//! | duplicate        | `nic::execute_send` (eager payload)    | payload transferred twice with one sequence number; receiver discards the second copy |
//! | delay            | `nic::execute_send` → `fabric::transfer_delayed` | wire transfer starts `delay` ns late |
//! | rendezvous drop  | `nic::execute_send` (rendezvous RTS)   | the RTS control message occupies the wire but never reaches matching; the send descriptor (not the payload — that only moves on the Get pull) is recorded in the lost ledger for watchdog replay |
//! | trigger delay    | `nic` DWQ fire path                    | descriptor executes late after its counter trips |
//! | straggler        | `gpu::cp_step` kernel duration         | a seeded subset of ranks runs kernels slower by a fixed factor |
//! | counter flip     | `gpu` doorbell writes (`writeValue64` set, KT counter inc) | the low bit of a trigger-counter update is lost (the edge never lands), so the counter under-counts; recorded as a [`PoisonedCounter`] and named in the armed registry so a stall report identifies it |
//!
//! Recovery: `stx` arms a host watchdog (see `stx::arm_watchdog`) on
//! `Queue::wait` / `CommPlan::complete` / drain whenever a fault plan is
//! active; on expiry it retransmits everything in the lost ledger,
//! repairs every [`PoisonedCounter`] (rewriting the intended doorbell
//! value, or adding back a lost increment), and re-arms with exponential
//! backoff, up to [`FaultSpec::max_retries`]. After the last retry the
//! run either completes (counters reached) or the event heap drains and
//! the engine emits a [`crate::sim::StallReport`] — never a hang, never
//! a panic. A poisoned counter no watchdog repairs (e.g. a KT run whose
//! host never parks on a supervised wait) stalls with the report naming
//! it; a flip can never make a run validate wrong data silently, because
//! it only ever *under*-counts — data movement waits longer, it does not
//! start early. GPU-initiated (GI) traffic is immune by construction:
//! command-ring descriptors carry no trigger counters at all.

use crate::nic::Envelope;
use crate::sim::rng::SplitMix64;
use crate::sim::CellId;

/// Fault-injection configuration: probabilities, magnitudes, and the
/// recovery-watchdog contract. All probabilities are per-message (wire
/// faults), per-fire (trigger delay), or per-rank (stragglers).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Probability an eager payload message is dropped on the wire.
    pub drop_prob: f64,
    /// Probability an eager payload message is duplicated.
    pub dup_prob: f64,
    /// Probability an eager payload message starts its transfer late.
    pub delay_prob: f64,
    /// Mean extra delay (ns) for delayed messages; the actual delay is
    /// uniform in `[delay_ns/2, delay_ns*3/2)`.
    pub delay_ns: u64,
    /// Probability a rendezvous RTS control message is dropped on the
    /// wire (the rendezvous-path fault: the receiver never learns the
    /// payload exists, so without the watchdog replay the send side
    /// would hang silently). Drawn from the shared decision stream, but
    /// *only* when non-zero — eager-only specs keep their exact
    /// historical decision sequences.
    pub rdv_drop_prob: f64,
    /// Probability a tripped DWQ descriptor fires late.
    pub trigger_delay_prob: f64,
    /// Extra ns added to a delayed trigger fire.
    pub trigger_delay_ns: u64,
    /// Probability a trigger-counter doorbell update loses its low bit
    /// (a flipped doorbell edge): the counter under-counts and every
    /// descriptor armed against the intended threshold hangs until the
    /// watchdog repairs it. Drawn from the shared decision stream, but
    /// *only* when non-zero — pre-existing specs keep their exact
    /// historical decision sequences.
    pub counter_flip_prob: f64,
    /// Fraction of ranks perturbed into stragglers.
    pub straggler_frac: f64,
    /// Kernel-duration multiplier applied to straggler ranks.
    pub straggler_factor: f64,
    /// Watchdog timeout (ns) armed by `stx` completion waits; doubles on
    /// every retry (exponential backoff).
    pub watchdog_ns: u64,
    /// Retransmission rounds before the watchdog gives up. After the
    /// last round the run either completes or stalls with a report.
    pub max_retries: u32,
    /// Opt-in escape hatch: after the last retry, complete the blocked
    /// drain gate anyway so the host can observe `StError::DrainTimeout`
    /// and force-release queue resources (used by the leak-audit tests).
    /// Default `false`: the run parks and the stall detector reports it.
    pub timeout_error: bool,
    /// Base seed mixed into the per-cell fingerprint.
    pub seed: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self {
            drop_prob: 0.0,
            dup_prob: 0.0,
            delay_prob: 0.0,
            delay_ns: 4_000,
            rdv_drop_prob: 0.0,
            trigger_delay_prob: 0.0,
            trigger_delay_ns: 2_000,
            counter_flip_prob: 0.0,
            straggler_frac: 0.0,
            straggler_factor: 3.0,
            watchdog_ns: 2_000_000,
            max_retries: 4,
            timeout_error: false,
            seed: 1,
        }
    }
}

impl FaultSpec {
    /// True when any injection knob is non-zero (a plan built from an
    /// inactive spec injects nothing, but still arms watchdogs).
    pub fn injects(&self) -> bool {
        self.drop_prob > 0.0
            || self.dup_prob > 0.0
            || self.delay_prob > 0.0
            || self.rdv_drop_prob > 0.0
            || self.trigger_delay_prob > 0.0
            || self.counter_flip_prob > 0.0
            || self.straggler_frac > 0.0
    }

    /// Drop-only plan (exercises the retransmit path).
    pub fn drops(seed: u64) -> Self {
        Self { drop_prob: 0.12, seed, ..Self::default() }
    }

    /// Duplication-only plan (exercises idempotent matching).
    pub fn dups(seed: u64) -> Self {
        Self { dup_prob: 0.15, seed, ..Self::default() }
    }

    /// Delay-only plan (wire + trigger-fire jitter; timing-only, no loss).
    pub fn delays(seed: u64) -> Self {
        Self {
            delay_prob: 0.2,
            trigger_delay_prob: 0.15,
            straggler_frac: 0.25,
            seed,
            ..Self::default()
        }
    }

    /// Rendezvous-drop-only plan (exercises the RTS replay path; only
    /// messages above the eager threshold are at risk).
    pub fn rdv_drops(seed: u64) -> Self {
        Self { rdv_drop_prob: 0.25, seed, ..Self::default() }
    }

    /// Counter-flip-only plan (exercises the poisoned-counter repair
    /// path: lost doorbell edges on ST/KT trigger counters). GI traffic
    /// is immune by construction — command-ring descriptors carry no
    /// trigger counters.
    pub fn counter_flips(seed: u64) -> Self {
        Self { counter_flip_prob: 0.3, seed, ..Self::default() }
    }

    /// Everything at once — the chaos-campaign default. Deliberately
    /// leaves `rdv_drop_prob` at zero so the chaos decision streams
    /// pinned by earlier releases stay byte-identical; rendezvous
    /// chaos is opted into via [`FaultSpec::rdv_drops`] or an explicit
    /// spec.
    pub fn chaos(seed: u64) -> Self {
        Self {
            drop_prob: 0.06,
            dup_prob: 0.06,
            delay_prob: 0.10,
            trigger_delay_prob: 0.08,
            straggler_frac: 0.25,
            seed,
            ..Self::default()
        }
    }

    /// Look up a named preset — the vocabulary of the
    /// `campaign.faults=`/`STMPI_FAULTS=` CLI shorthands and of fault
    /// fields in store-server campaign specs. `None` for unknown names;
    /// [`FaultSpec::preset_names`] lists the valid ones.
    pub fn preset(name: &str, seed: u64) -> Option<Self> {
        match name {
            "drops" => Some(Self::drops(seed)),
            "dups" => Some(Self::dups(seed)),
            "delays" => Some(Self::delays(seed)),
            "rdv-drops" | "rdv_drops" => Some(Self::rdv_drops(seed)),
            "flips" => Some(Self::counter_flips(seed)),
            "chaos" => Some(Self::chaos(seed)),
            _ => None,
        }
    }

    /// The names [`FaultSpec::preset`] accepts (for error messages).
    pub fn preset_names() -> &'static [&'static str] {
        &["drops", "dups", "delays", "rdv-drops", "flips", "chaos"]
    }

    /// Stable FNV-1a fingerprint of the full spec, by field name and
    /// IEEE bit pattern — the fault component of the campaign store's
    /// cell keys. Two cells share it iff their specs are semantically
    /// identical. Extending the spec extends this fold, which shifts
    /// every hash — that is the correct invalidation behavior, since a
    /// new knob means the old decision streams are no longer
    /// reproducible guarantees.
    pub fn stable_hash(&self) -> u64 {
        let mut h = crate::sim::rng::Fnv64::new();
        h.write_str("drop_prob").write_f64(self.drop_prob);
        h.write_str("dup_prob").write_f64(self.dup_prob);
        h.write_str("delay_prob").write_f64(self.delay_prob);
        h.write_str("delay_ns").write_u64(self.delay_ns);
        h.write_str("rdv_drop_prob").write_f64(self.rdv_drop_prob);
        h.write_str("trigger_delay_prob").write_f64(self.trigger_delay_prob);
        h.write_str("trigger_delay_ns").write_u64(self.trigger_delay_ns);
        h.write_str("counter_flip_prob").write_f64(self.counter_flip_prob);
        h.write_str("straggler_frac").write_f64(self.straggler_frac);
        h.write_str("straggler_factor").write_f64(self.straggler_factor);
        h.write_str("watchdog_ns").write_u64(self.watchdog_ns);
        h.write_str("max_retries").write_u64(u64::from(self.max_retries));
        h.write_str("timeout_error").write_u64(u64::from(self.timeout_error));
        h.write_str("seed").write_u64(self.seed);
        h.finish()
    }
}

/// Decision for one eager payload message on the wire path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFault {
    /// Deliver normally.
    None,
    /// Skip remote delivery; record in the lost ledger.
    Drop,
    /// Deliver twice with the same sequence number.
    Dup,
    /// Start the wire transfer this many ns late.
    Delay(u64),
}

/// Stable 64-bit fingerprint of a campaign cell: FNV-1a over the label,
/// mixed with the spec seed. Keys the per-cell decision stream so chaos
/// campaigns are byte-identical across reruns and thread counts.
pub fn fingerprint(seed: u64, label: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for b in label.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// The per-run fault decision stream plus precomputed per-rank straggler
/// factors. Decisions are drawn in event order from a dedicated RNG —
/// never from the simulation's shared RNG, so an *inactive* plan leaves
/// the no-fault timeline untouched.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    spec: FaultSpec,
    rng: SplitMix64,
    /// Kernel-duration multiplier per rank (1.0 = unperturbed).
    stragglers: Vec<f64>,
}

impl FaultPlan {
    /// Build the plan for one campaign cell: `fp` from [`fingerprint`],
    /// `world_size` fixes the straggler assignment.
    pub fn new(spec: FaultSpec, fp: u64, world_size: usize) -> Self {
        // Straggler assignment uses its own derived stream so wire-fault
        // draws do not depend on world size.
        let mut srng = SplitMix64::new(fp ^ 0xA5A5_5A5A_DEAD_BEEF);
        let stragglers = (0..world_size)
            .map(|_| {
                if spec.straggler_frac > 0.0 && srng.next_f64() < spec.straggler_frac {
                    spec.straggler_factor
                } else {
                    1.0
                }
            })
            .collect();
        Self { spec, rng: SplitMix64::new(fp), stragglers }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Draw the fault decision for the next eager payload message.
    pub fn wire_fault(&mut self) -> WireFault {
        let p = self.rng.next_f64();
        let s = &self.spec;
        if p < s.drop_prob {
            WireFault::Drop
        } else if p < s.drop_prob + s.dup_prob {
            WireFault::Dup
        } else if p < s.drop_prob + s.dup_prob + s.delay_prob {
            let d = s.delay_ns / 2 + self.rng.below(s.delay_ns.max(1));
            WireFault::Delay(d)
        } else {
            WireFault::None
        }
    }

    /// Decide whether the next rendezvous RTS is dropped. Consumes a
    /// decision draw *only* when `rdv_drop_prob` is set, so the eager
    /// decision sequences of pre-existing (eager-only) specs replay
    /// bit-identically.
    pub fn rdv_drop(&mut self) -> bool {
        self.spec.rdv_drop_prob > 0.0 && self.rng.next_f64() < self.spec.rdv_drop_prob
    }

    /// Decide whether the next trigger-counter doorbell update loses
    /// its low bit. Consumes a decision draw *only* when
    /// `counter_flip_prob` is set, so pre-existing specs replay their
    /// exact historical decision sequences.
    pub fn counter_flip(&mut self) -> bool {
        self.spec.counter_flip_prob > 0.0 && self.rng.next_f64() < self.spec.counter_flip_prob
    }

    /// Extra ns before a tripped DWQ descriptor fires (0 = on time).
    pub fn trigger_extra(&mut self) -> u64 {
        if self.spec.trigger_delay_prob > 0.0 && self.rng.next_f64() < self.spec.trigger_delay_prob
        {
            self.spec.trigger_delay_ns
        } else {
            0
        }
    }

    /// Kernel-duration multiplier for `rank` (1.0 when unperturbed or
    /// out of range).
    pub fn straggler_factor(&self, rank: usize) -> f64 {
        self.stragglers.get(rank).copied().unwrap_or(1.0)
    }
}

/// A dropped wire message awaiting watchdog replay: everything
/// `nic::retransmit` needs to put the identical traffic back on the
/// wire.
#[derive(Debug)]
pub enum LostMsg {
    /// A dropped eager payload (same envelope, same payload snapshot,
    /// same sequence number — the receiver-side dedup set makes a
    /// redundant retransmit harmless).
    Eager {
        env: Envelope,
        payload: Vec<f32>,
        seq: u64,
        src_node: usize,
        dst_node: usize,
        /// Wire size of the original message (the retransmit pays it
        /// again).
        bytes: usize,
    },
    /// A dropped rendezvous RTS. The payload never left the source (it
    /// only moves on the Get pull), so the ledger holds the send
    /// *descriptor*: the source slice the matched receiver will pull
    /// from, and the source-side completion (`src_done`) that fires
    /// once that pull drains — which is also why this variant (and thus
    /// the ledger) is not `Clone`: a completion must fire exactly once.
    Rts {
        env: Envelope,
        src: crate::nic::BufSlice,
        src_node: usize,
        dst_node: usize,
        src_done: crate::nic::Done,
    },
}

/// A trigger counter that lost a doorbell bit and now *under-counts*:
/// every descriptor armed against `intended` hangs until the watchdog
/// repairs the cell. Under-counting is the sound direction — a poisoned
/// counter can delay validation but can never validate wrong data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoisonedCounter {
    /// The trigger-counter cell whose update lost its low bit.
    pub cell: CellId,
    /// The value the counter *should* hold after the poisoned update
    /// (repair target for set-mode doorbells, where `lost` is 0).
    pub intended: u64,
    /// The increment amount that was lost (repair delta for add-mode
    /// doorbells; 0 for set-mode poisons).
    pub lost: u64,
    /// Armed-registry token naming the poison in stall reports; the
    /// watchdog clears it on repair.
    pub token: usize,
}

/// Per-world fault runtime state (lives at `World::fault`; `None` means
/// the fault layer is fully inert). Not `Clone`: the lost ledger can
/// hold single-fire completions (see [`LostMsg::Rts`]), and
/// `World::reset`/`snapshot` drop fault state rather than copy it.
#[derive(Debug)]
pub struct FaultState {
    pub plan: FaultPlan,
    /// Dropped payloads awaiting retransmission by the stx watchdog.
    pub lost: Vec<LostMsg>,
    /// Trigger counters that lost a doorbell bit, awaiting watchdog
    /// repair (see [`PoisonedCounter`]).
    pub poisoned: Vec<PoisonedCounter>,
    /// Next wire sequence number (0 is reserved for "unsequenced").
    seq_next: u64,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> Self {
        Self { plan, lost: Vec::new(), poisoned: Vec::new(), seq_next: 0 }
    }

    /// Allocate the next wire sequence number (starts at 1; 0 means
    /// "unsequenced" on messages sent while no plan is active).
    pub fn next_seq(&mut self) -> u64 {
        self.seq_next += 1;
        self.seq_next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_stable_and_label_sensitive() {
        let a = fingerprint(7, "halo3d/st/48/2x1/q1/s5");
        let b = fingerprint(7, "halo3d/st/48/2x1/q1/s5");
        let c = fingerprint(7, "halo3d/kt/48/2x1/q1/s5");
        let d = fingerprint(8, "halo3d/st/48/2x1/q1/s5");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }

    #[test]
    fn plan_decisions_replay_identically() {
        let spec = FaultSpec::chaos(3);
        let fp = fingerprint(spec.seed, "cell");
        let mut p1 = FaultPlan::new(spec.clone(), fp, 8);
        let mut p2 = FaultPlan::new(spec, fp, 8);
        for _ in 0..256 {
            assert_eq!(p1.wire_fault(), p2.wire_fault());
            assert_eq!(p1.trigger_extra(), p2.trigger_extra());
        }
        for r in 0..8 {
            let f1 = p1.straggler_factor(r);
            let f2 = p2.straggler_factor(r);
            assert_eq!(f1.to_bits(), f2.to_bits());
        }
    }

    #[test]
    fn inactive_spec_injects_nothing() {
        let spec = FaultSpec::default();
        assert!(!spec.injects());
        let mut p = FaultPlan::new(spec, 99, 4);
        for _ in 0..64 {
            assert_eq!(p.wire_fault(), WireFault::None);
            assert_eq!(p.trigger_extra(), 0);
        }
        for r in 0..4 {
            let f = p.straggler_factor(r);
            assert_eq!(f.to_bits(), 1.0f64.to_bits());
        }
    }

    #[test]
    fn chaos_spec_draws_every_fault_kind() {
        let spec = FaultSpec::chaos(5);
        assert!(spec.injects());
        let mut p = FaultPlan::new(spec, fingerprint(5, "mix"), 16);
        let mut drops = 0;
        let mut dups = 0;
        let mut delays = 0;
        let mut clean = 0;
        for _ in 0..2000 {
            match p.wire_fault() {
                WireFault::Drop => drops += 1,
                WireFault::Dup => dups += 1,
                WireFault::Delay(d) => {
                    assert!(d >= 2_000 && d < 6_000, "delay {d} outside [ns/2, 3ns/2)");
                    delays += 1;
                }
                WireFault::None => clean += 1,
            }
        }
        assert!(drops > 0 && dups > 0 && delays > 0 && clean > 0);
        let stragglers = (0..16).filter(|&r| p.straggler_factor(r) > 1.0).count();
        assert!(stragglers > 0 && stragglers < 16);
    }

    #[test]
    fn rdv_drop_gate_consumes_no_draws_when_inactive() {
        // An eager-only spec must keep its exact decision sequence even
        // if the rendezvous site polls the plan between eager draws.
        let spec = FaultSpec::chaos(9);
        assert_eq!(spec.rdv_drop_prob, 0.0, "chaos stays eager-only by design");
        let fp = fingerprint(spec.seed, "gate");
        let mut with_polls = FaultPlan::new(spec.clone(), fp, 4);
        let mut without = FaultPlan::new(spec, fp, 4);
        for _ in 0..256 {
            assert!(!with_polls.rdv_drop(), "inactive knob must never drop");
            assert_eq!(with_polls.wire_fault(), without.wire_fault());
        }
    }

    #[test]
    fn rdv_drops_preset_injects_on_the_rendezvous_path() {
        let spec = FaultSpec::rdv_drops(4);
        assert!(spec.injects());
        assert_eq!(spec.drop_prob, 0.0, "rdv preset leaves eager traffic clean");
        let mut p = FaultPlan::new(spec, fingerprint(4, "rdv"), 4);
        let drops = (0..400).filter(|_| p.rdv_drop()).count();
        assert!(drops > 0 && drops < 400, "rdv_drop_prob=0.25 must drop some, not all: {drops}");
    }

    #[test]
    fn preset_lookup_covers_the_published_names() {
        for name in FaultSpec::preset_names() {
            let spec = FaultSpec::preset(name, 3);
            assert!(spec.is_some_and(|s| s.injects() && s.seed == 3), "preset {name}");
        }
        assert!(FaultSpec::preset("no-such", 3).is_none());
    }

    #[test]
    fn stable_hash_is_deterministic_and_field_sensitive() {
        let base = FaultSpec::chaos(7);
        assert_eq!(base.stable_hash(), FaultSpec::chaos(7).stable_hash());
        let mut tweaked = base.clone();
        tweaked.rdv_drop_prob = 0.01;
        assert_ne!(base.stable_hash(), tweaked.stable_hash());
        assert_ne!(base.stable_hash(), FaultSpec::chaos(8).stable_hash());
        assert_ne!(FaultSpec::drops(7).stable_hash(), FaultSpec::dups(7).stable_hash());
        let mut wd = base.clone();
        wd.watchdog_ns += 1;
        assert_ne!(base.stable_hash(), wd.stable_hash());
        let mut flip = base.clone();
        flip.counter_flip_prob = 0.3;
        assert_ne!(base.stable_hash(), flip.stable_hash());
    }

    #[test]
    fn counter_flip_gate_consumes_no_draws_when_inactive() {
        // A spec without the flip knob must keep its exact decision
        // sequence even if the doorbell sites poll the plan between
        // wire draws.
        let spec = FaultSpec::chaos(11);
        assert_eq!(spec.counter_flip_prob, 0.0, "chaos leaves doorbells clean by design");
        let fp = fingerprint(spec.seed, "flip-gate");
        let mut with_polls = FaultPlan::new(spec.clone(), fp, 4);
        let mut without = FaultPlan::new(spec, fp, 4);
        for _ in 0..256 {
            assert!(!with_polls.counter_flip(), "inactive knob must never flip");
            assert_eq!(with_polls.wire_fault(), without.wire_fault());
        }
    }

    #[test]
    fn counter_flips_preset_injects_on_the_doorbell_path() {
        let spec = FaultSpec::counter_flips(6);
        assert!(spec.injects());
        assert_eq!(spec.drop_prob, 0.0, "flip preset leaves the wire clean");
        let mut p = FaultPlan::new(spec, fingerprint(6, "flips"), 4);
        let flips = (0..400).filter(|_| p.counter_flip()).count();
        assert!(flips > 0 && flips < 400, "counter_flip_prob=0.3 must flip some, not all: {flips}");
    }

    #[test]
    fn poisoned_ledger_starts_empty() {
        let st = FaultState::new(FaultPlan::new(FaultSpec::counter_flips(1), 1, 2));
        assert!(st.poisoned.is_empty());
    }

    #[test]
    fn sequence_numbers_start_at_one() {
        let mut st = FaultState::new(FaultPlan::new(FaultSpec::drops(1), 1, 2));
        assert_eq!(st.next_seq(), 1);
        assert_eq!(st.next_seq(), 2);
        assert!(st.lost.is_empty());
    }
}
