//! Deterministic fault injection: seeded chaos for triggered operations.
//!
//! The premise of stream-triggered communication is that the host steps
//! out of the loop — which means a dropped wire message, a NIC counter
//! that never reaches its threshold, or a DWQ descriptor armed against a
//! doorbell that never rings is a *silent hang* with no CPU thread
//! watching. This module supplies the chaos half of the robustness
//! contract (the diagnosis half is [`crate::sim::StallReport`]):
//!
//! * [`FaultSpec`] — the knob set: message drop / duplication / extra
//!   delay probabilities on the wire path, delayed NIC trigger fire,
//!   straggler ranks (cost-model perturbation of kernel durations), and
//!   the recovery watchdog (timeout, bounded retries with exponential
//!   backoff).
//! * [`FaultPlan`] — a *per-run* decision stream: one [`SplitMix64`]
//!   seeded from a campaign-cell [`fingerprint`], consumed in event
//!   order. Because each simulation run is single-threaded and
//!   event-ordered deterministically, the same `(spec, fingerprint)`
//!   yields byte-identical fault decisions on every rerun and at any
//!   `STMPI_SWEEP_THREADS`.
//! * [`FaultState`] — the per-world runtime state: the plan, the ledger
//!   of dropped payloads awaiting retransmission ([`LostMsg`]), and the
//!   wire sequence counter used for idempotent duplicate resolution in
//!   the matching engine.
//!
//! Injection sites (all inert when `World::fault` is `None` — the
//! no-fault timeline is bit-for-bit unchanged):
//!
//! | fault            | site                                   | effect |
//! |------------------|----------------------------------------|--------|
//! | drop             | `nic::execute_send` (eager payload)    | remote delivery skipped; payload recorded in the lost ledger for watchdog retransmit |
//! | duplicate        | `nic::execute_send` (eager payload)    | payload transferred twice with one sequence number; receiver discards the second copy |
//! | delay            | `nic::execute_send` → `fabric::transfer_delayed` | wire transfer starts `delay` ns late |
//! | trigger delay    | `nic` DWQ fire path                    | descriptor executes late after its counter trips |
//! | straggler        | `gpu::cp_step` kernel duration         | a seeded subset of ranks runs kernels slower by a fixed factor |
//!
//! Recovery: `stx` arms a host watchdog (see `stx::arm_watchdog`) on
//! `Queue::wait` / `CommPlan::complete` / drain whenever a fault plan is
//! active; on expiry it retransmits everything in the lost ledger and
//! re-arms with exponential backoff, up to [`FaultSpec::max_retries`].
//! After the last retry the run either completes (counters reached) or
//! the event heap drains and the engine emits a [`crate::sim::StallReport`]
//! — never a hang, never a panic.

use crate::nic::Envelope;
use crate::sim::rng::SplitMix64;

/// Fault-injection configuration: probabilities, magnitudes, and the
/// recovery-watchdog contract. All probabilities are per-message (wire
/// faults), per-fire (trigger delay), or per-rank (stragglers).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Probability an eager payload message is dropped on the wire.
    pub drop_prob: f64,
    /// Probability an eager payload message is duplicated.
    pub dup_prob: f64,
    /// Probability an eager payload message starts its transfer late.
    pub delay_prob: f64,
    /// Mean extra delay (ns) for delayed messages; the actual delay is
    /// uniform in `[delay_ns/2, delay_ns*3/2)`.
    pub delay_ns: u64,
    /// Probability a tripped DWQ descriptor fires late.
    pub trigger_delay_prob: f64,
    /// Extra ns added to a delayed trigger fire.
    pub trigger_delay_ns: u64,
    /// Fraction of ranks perturbed into stragglers.
    pub straggler_frac: f64,
    /// Kernel-duration multiplier applied to straggler ranks.
    pub straggler_factor: f64,
    /// Watchdog timeout (ns) armed by `stx` completion waits; doubles on
    /// every retry (exponential backoff).
    pub watchdog_ns: u64,
    /// Retransmission rounds before the watchdog gives up. After the
    /// last round the run either completes or stalls with a report.
    pub max_retries: u32,
    /// Opt-in escape hatch: after the last retry, complete the blocked
    /// drain gate anyway so the host can observe `StError::DrainTimeout`
    /// and force-release queue resources (used by the leak-audit tests).
    /// Default `false`: the run parks and the stall detector reports it.
    pub timeout_error: bool,
    /// Base seed mixed into the per-cell fingerprint.
    pub seed: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self {
            drop_prob: 0.0,
            dup_prob: 0.0,
            delay_prob: 0.0,
            delay_ns: 4_000,
            trigger_delay_prob: 0.0,
            trigger_delay_ns: 2_000,
            straggler_frac: 0.0,
            straggler_factor: 3.0,
            watchdog_ns: 2_000_000,
            max_retries: 4,
            timeout_error: false,
            seed: 1,
        }
    }
}

impl FaultSpec {
    /// True when any injection knob is non-zero (a plan built from an
    /// inactive spec injects nothing, but still arms watchdogs).
    pub fn injects(&self) -> bool {
        self.drop_prob > 0.0
            || self.dup_prob > 0.0
            || self.delay_prob > 0.0
            || self.trigger_delay_prob > 0.0
            || self.straggler_frac > 0.0
    }

    /// Drop-only plan (exercises the retransmit path).
    pub fn drops(seed: u64) -> Self {
        Self { drop_prob: 0.12, seed, ..Self::default() }
    }

    /// Duplication-only plan (exercises idempotent matching).
    pub fn dups(seed: u64) -> Self {
        Self { dup_prob: 0.15, seed, ..Self::default() }
    }

    /// Delay-only plan (wire + trigger-fire jitter; timing-only, no loss).
    pub fn delays(seed: u64) -> Self {
        Self {
            delay_prob: 0.2,
            trigger_delay_prob: 0.15,
            straggler_frac: 0.25,
            seed,
            ..Self::default()
        }
    }

    /// Everything at once — the chaos-campaign default.
    pub fn chaos(seed: u64) -> Self {
        Self {
            drop_prob: 0.06,
            dup_prob: 0.06,
            delay_prob: 0.10,
            trigger_delay_prob: 0.08,
            straggler_frac: 0.25,
            seed,
            ..Self::default()
        }
    }
}

/// Decision for one eager payload message on the wire path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFault {
    /// Deliver normally.
    None,
    /// Skip remote delivery; record in the lost ledger.
    Drop,
    /// Deliver twice with the same sequence number.
    Dup,
    /// Start the wire transfer this many ns late.
    Delay(u64),
}

/// Stable 64-bit fingerprint of a campaign cell: FNV-1a over the label,
/// mixed with the spec seed. Keys the per-cell decision stream so chaos
/// campaigns are byte-identical across reruns and thread counts.
pub fn fingerprint(seed: u64, label: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for b in label.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// The per-run fault decision stream plus precomputed per-rank straggler
/// factors. Decisions are drawn in event order from a dedicated RNG —
/// never from the simulation's shared RNG, so an *inactive* plan leaves
/// the no-fault timeline untouched.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    spec: FaultSpec,
    rng: SplitMix64,
    /// Kernel-duration multiplier per rank (1.0 = unperturbed).
    stragglers: Vec<f64>,
}

impl FaultPlan {
    /// Build the plan for one campaign cell: `fp` from [`fingerprint`],
    /// `world_size` fixes the straggler assignment.
    pub fn new(spec: FaultSpec, fp: u64, world_size: usize) -> Self {
        // Straggler assignment uses its own derived stream so wire-fault
        // draws do not depend on world size.
        let mut srng = SplitMix64::new(fp ^ 0xA5A5_5A5A_DEAD_BEEF);
        let stragglers = (0..world_size)
            .map(|_| {
                if spec.straggler_frac > 0.0 && srng.next_f64() < spec.straggler_frac {
                    spec.straggler_factor
                } else {
                    1.0
                }
            })
            .collect();
        Self { spec, rng: SplitMix64::new(fp), stragglers }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Draw the fault decision for the next eager payload message.
    pub fn wire_fault(&mut self) -> WireFault {
        let p = self.rng.next_f64();
        let s = &self.spec;
        if p < s.drop_prob {
            WireFault::Drop
        } else if p < s.drop_prob + s.dup_prob {
            WireFault::Dup
        } else if p < s.drop_prob + s.dup_prob + s.delay_prob {
            let d = s.delay_ns / 2 + self.rng.below(s.delay_ns.max(1));
            WireFault::Delay(d)
        } else {
            WireFault::None
        }
    }

    /// Extra ns before a tripped DWQ descriptor fires (0 = on time).
    pub fn trigger_extra(&mut self) -> u64 {
        if self.spec.trigger_delay_prob > 0.0 && self.rng.next_f64() < self.spec.trigger_delay_prob
        {
            self.spec.trigger_delay_ns
        } else {
            0
        }
    }

    /// Kernel-duration multiplier for `rank` (1.0 when unperturbed or
    /// out of range).
    pub fn straggler_factor(&self, rank: usize) -> f64 {
        self.stragglers.get(rank).copied().unwrap_or(1.0)
    }
}

/// A dropped eager payload awaiting watchdog retransmission: everything
/// `nic::retransmit` needs to put the identical message back on the wire
/// (same envelope, same payload snapshot, same sequence number — the
/// receiver-side dedup set makes a redundant retransmit harmless).
#[derive(Debug, Clone)]
pub struct LostMsg {
    pub env: Envelope,
    pub payload: Vec<f32>,
    pub seq: u64,
    pub src_node: usize,
    pub dst_node: usize,
    /// Wire size of the original message (the retransmit pays it again).
    pub bytes: usize,
}

/// Per-world fault runtime state (lives at `World::fault`; `None` means
/// the fault layer is fully inert).
#[derive(Debug, Clone)]
pub struct FaultState {
    pub plan: FaultPlan,
    /// Dropped payloads awaiting retransmission by the stx watchdog.
    pub lost: Vec<LostMsg>,
    /// Next wire sequence number (0 is reserved for "unsequenced").
    seq_next: u64,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> Self {
        Self { plan, lost: Vec::new(), seq_next: 0 }
    }

    /// Allocate the next wire sequence number (starts at 1; 0 means
    /// "unsequenced" on messages sent while no plan is active).
    pub fn next_seq(&mut self) -> u64 {
        self.seq_next += 1;
        self.seq_next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_stable_and_label_sensitive() {
        let a = fingerprint(7, "halo3d/st/48/2x1/q1/s5");
        let b = fingerprint(7, "halo3d/st/48/2x1/q1/s5");
        let c = fingerprint(7, "halo3d/kt/48/2x1/q1/s5");
        let d = fingerprint(8, "halo3d/st/48/2x1/q1/s5");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }

    #[test]
    fn plan_decisions_replay_identically() {
        let spec = FaultSpec::chaos(3);
        let fp = fingerprint(spec.seed, "cell");
        let mut p1 = FaultPlan::new(spec.clone(), fp, 8);
        let mut p2 = FaultPlan::new(spec, fp, 8);
        for _ in 0..256 {
            assert_eq!(p1.wire_fault(), p2.wire_fault());
            assert_eq!(p1.trigger_extra(), p2.trigger_extra());
        }
        for r in 0..8 {
            let f1 = p1.straggler_factor(r);
            let f2 = p2.straggler_factor(r);
            assert_eq!(f1.to_bits(), f2.to_bits());
        }
    }

    #[test]
    fn inactive_spec_injects_nothing() {
        let spec = FaultSpec::default();
        assert!(!spec.injects());
        let mut p = FaultPlan::new(spec, 99, 4);
        for _ in 0..64 {
            assert_eq!(p.wire_fault(), WireFault::None);
            assert_eq!(p.trigger_extra(), 0);
        }
        for r in 0..4 {
            let f = p.straggler_factor(r);
            assert_eq!(f.to_bits(), 1.0f64.to_bits());
        }
    }

    #[test]
    fn chaos_spec_draws_every_fault_kind() {
        let spec = FaultSpec::chaos(5);
        assert!(spec.injects());
        let mut p = FaultPlan::new(spec, fingerprint(5, "mix"), 16);
        let mut drops = 0;
        let mut dups = 0;
        let mut delays = 0;
        let mut clean = 0;
        for _ in 0..2000 {
            match p.wire_fault() {
                WireFault::Drop => drops += 1,
                WireFault::Dup => dups += 1,
                WireFault::Delay(d) => {
                    assert!(d >= 2_000 && d < 6_000, "delay {d} outside [ns/2, 3ns/2)");
                    delays += 1;
                }
                WireFault::None => clean += 1,
            }
        }
        assert!(drops > 0 && dups > 0 && delays > 0 && clean > 0);
        let stragglers = (0..16).filter(|&r| p.straggler_factor(r) > 1.0).count();
        assert!(stragglers > 0 && stragglers < 16);
    }

    #[test]
    fn sequence_numbers_start_at_one() {
        let mut st = FaultState::new(FaultPlan::new(FaultSpec::drops(1), 1, 2));
        assert_eq!(st.next_seq(), 1);
        assert_eq!(st.next_seq(), 2);
        assert!(st.lost.is_empty());
    }
}
