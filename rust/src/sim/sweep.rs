//! Parallel sweep executor: run many independent simulations across OS
//! threads with deterministic results.
//!
//! Every figure and ablation is a sweep of full cluster simulations —
//! (variant × seed × parameter) grids of [`crate::faces::run_faces`]
//! calls. Each simulation is self-contained (its own `Engine`, its own
//! seeded RNG), so the sweep is embarrassingly parallel; this module
//! provides the work-stealing-free, deterministic harness the figure and
//! ablation drivers run on.
//!
//! Determinism: job `i` always computes `f(i, &items[i])`, results are
//! written to slot `i`, and every simulation draws randomness only from
//! its own config's seed — so the output vector is byte-identical no
//! matter how many worker threads run or how the OS schedules them
//! (pinned by `rust/tests/determinism.rs`).

use std::any::{Any, TypeId};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

std::thread_local! {
    /// Per-worker-thread recycling bin, keyed by concrete type. Holds at
    /// most one spare value per type — enough to carry a [`crate::sim`]
    /// event arena (or any other allocation-heavy scratch structure)
    /// from one sweep cell to the next on the same worker without any
    /// cross-thread traffic or locking.
    static RECYCLER: RefCell<HashMap<TypeId, Box<dyn Any>>> = RefCell::new(HashMap::new());
}

/// Take the recycled spare of type `T` stashed on this thread by a prior
/// [`recycle_put`], or `T::default()` if none is stashed. Recycled values
/// must be observationally identical to fresh ones — callers are expected
/// to clear them on the put or take side (determinism depends on it).
pub fn recycle_take<T: Default + Any>() -> T {
    RECYCLER.with(|r| {
        r.borrow_mut()
            .remove(&TypeId::of::<T>())
            .and_then(|b| b.downcast::<T>().ok().map(|b| *b))
            .unwrap_or_default()
    })
}

/// Stash `v` as this thread's spare of type `T` for a later
/// [`recycle_take`]. An already-stashed spare of the same type is
/// replaced (the older one is dropped).
pub fn recycle_put<T: Any>(v: T) {
    RECYCLER.with(|r| {
        r.borrow_mut().insert(TypeId::of::<T>(), Box::new(v));
    });
}

/// Number of worker threads to use by default: the `STMPI_SWEEP_THREADS`
/// environment variable if set (>= 1), else the machine's available
/// parallelism.
pub fn default_threads() -> usize {
    std::env::var("STMPI_SWEEP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Map `f` over `items` on up to `threads` OS threads, returning results
/// in item order. Jobs are claimed through a shared atomic cursor, so
/// long jobs do not convoy behind short ones. A panicking job poisons
/// the cursor: other workers stop claiming new jobs and the panic
/// propagates to the caller once in-flight jobs finish.
pub fn map<I, T, F>(items: &[I], threads: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(usize, &I) -> T + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, &items[i]))) {
                    Ok(r) => *out[i].lock().unwrap() = Some(r),
                    Err(payload) => {
                        stop.store(true, Ordering::Relaxed);
                        std::panic::resume_unwind(payload);
                    }
                }
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("sweep job did not complete"))
        .collect()
}

/// Convenience: [`map`] with [`default_threads`].
pub fn map_default<I, T, F>(items: &[I], f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(usize, &I) -> T + Sync,
{
    map(items, default_threads(), f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn results_are_in_item_order() {
        let items: Vec<u64> = (0..97).collect();
        let out = map(&items, 8, |i, &x| {
            assert_eq!(i as u64, x);
            x * 2
        });
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input_yields_empty_output() {
        let out: Vec<u64> = map(&[] as &[u64], 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items_is_fine() {
        let out = map(&[1u64, 2, 3], 64, |_, &x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn single_thread_matches_multi_thread() {
        let items: Vec<u64> = (0..40).collect();
        let job = |_: usize, &x: &u64| {
            // A deterministic per-item computation with its own "seed".
            let mut s = x.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
            for _ in 0..100 {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
            }
            s
        };
        let a = map(&items, 1, job);
        let b = map(&items, 7, job);
        assert_eq!(a, b, "thread count must not change results");
    }

    #[test]
    fn panics_propagate_to_caller() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            map(&[1u64, 2, 3, 4], 2, |_, &x| {
                if x == 2 {
                    panic!("boom");
                }
                x
            })
        }));
        assert!(result.is_err(), "a panicking job must fail the sweep");
    }

    #[test]
    fn work_actually_spreads_across_threads() {
        let items: Vec<usize> = (0..64).collect();
        let ids = map(&items, 4, |_, _| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            format!("{:?}", std::thread::current().id())
        });
        let distinct: HashSet<&String> = ids.iter().collect();
        assert!(distinct.len() > 1, "expected more than one worker thread");
    }
}
