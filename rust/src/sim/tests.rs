//! Engine unit tests: scheduling order, cells, host handshake, deadlock.

use super::*;

#[derive(Default)]
struct TestWorld {
    log: Vec<(Time, String)>,
}

fn log_ev(w: &mut TestWorld, core: &Core<TestWorld>, msg: &str) {
    w.log.push((core.now(), msg.to_string()));
}

#[test]
fn events_run_in_time_order() {
    let eng = Engine::new(TestWorld::default(), 1);
    eng.setup(|_, core| {
        core.schedule(30, Box::new(|w, c| log_ev(w, c, "c")));
        core.schedule(10, Box::new(|w, c| log_ev(w, c, "a")));
        core.schedule(20, Box::new(|w, c| log_ev(w, c, "b")));
    });
    let (w, stats) = eng.run().unwrap();
    assert_eq!(
        w.log,
        vec![(10, "a".into()), (20, "b".into()), (30, "c".into())]
    );
    assert_eq!(stats.events, 3);
}

#[test]
fn same_time_events_run_in_insertion_order() {
    let eng = Engine::new(TestWorld::default(), 1);
    eng.setup(|_, core| {
        for i in 0..10 {
            core.schedule(5, Box::new(move |w, c| log_ev(w, c, &format!("e{i}"))));
        }
    });
    let (w, _) = eng.run().unwrap();
    let msgs: Vec<_> = w.log.iter().map(|(_, m)| m.clone()).collect();
    assert_eq!(msgs, (0..10).map(|i| format!("e{i}")).collect::<Vec<_>>());
}

#[test]
fn nested_scheduling_works() {
    let eng = Engine::new(TestWorld::default(), 1);
    eng.setup(|_, core| {
        core.schedule(
            10,
            Box::new(|w, c| {
                log_ev(w, c, "outer");
                c.schedule(5, Box::new(|w, c| log_ev(w, c, "inner")));
            }),
        );
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(w.log, vec![(10, "outer".into()), (15, "inner".into())]);
}

#[test]
fn cell_write_fires_waiter() {
    let eng = Engine::new(TestWorld::default(), 1);
    eng.setup(|_, core| {
        let c = core.new_cell("ctr", 0);
        core.on_ge(c, 3, "test-waiter", Box::new(|w, core| log_ev(w, core, "fired")));
        core.schedule(100, Box::new(move |_, core| {
            core.write_cell(c, 2); // below threshold: no fire
        }));
        core.schedule(200, Box::new(move |_, core| {
            core.add_cell(c, 1); // reaches 3
        }));
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(w.log, vec![(200, "fired".into())]);
}

#[test]
fn on_ge_already_satisfied_fires_immediately() {
    let eng = Engine::new(TestWorld::default(), 1);
    eng.setup(|_, core| {
        let c = core.new_cell("ctr", 5);
        core.on_ge(c, 3, "sat", Box::new(|w, core| log_ev(w, core, "sat")));
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(w.log, vec![(0, "sat".into())]);
}

#[test]
fn multiple_waiters_fire_in_registration_order() {
    let eng = Engine::new(TestWorld::default(), 1);
    eng.setup(|_, core| {
        let c = core.new_cell("ctr", 0);
        for i in 0..5 {
            core.on_ge(c, 1, "w", Box::new(move |w, core| log_ev(w, core, &format!("w{i}"))));
        }
        core.schedule(7, Box::new(move |_, core| core.write_cell(c, 1)));
    });
    let (w, _) = eng.run().unwrap();
    let msgs: Vec<_> = w.log.iter().map(|(_, m)| m.clone()).collect();
    assert_eq!(msgs, vec!["w0", "w1", "w2", "w3", "w4"]);
}

#[test]
fn host_advance_accumulates_time() {
    let mut eng = Engine::new(TestWorld::default(), 1);
    eng.spawn_host("h", |ctx| {
        assert_eq!(ctx.now(), 0);
        ctx.advance(100);
        assert_eq!(ctx.now(), 100);
        ctx.advance(50);
        assert_eq!(ctx.now(), 150);
        ctx.with(|w, c| w.log.push((c.now(), "done".into())));
    });
    let (w, stats) = eng.run().unwrap();
    assert_eq!(w.log, vec![(150, "done".into())]);
    assert!(stats.host_switches >= 3);
}

#[test]
fn host_wait_ge_blocks_until_write() {
    let mut eng = Engine::new(TestWorld::default(), 1);
    let cell = eng.setup(|_, core| {
        let c = core.new_cell("flag", 0);
        core.schedule(500, Box::new(move |_, core| core.write_cell(c, 1)));
        c
    });
    eng.spawn_host("waiter", move |ctx| {
        ctx.wait_ge(cell, 1, "flag>=1");
        assert_eq!(ctx.now(), 500);
        ctx.with(|w, c| w.log.push((c.now(), "woke".into())));
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(w.log, vec![(500, "woke".into())]);
}

#[test]
fn host_wait_ge_satisfied_is_instant() {
    let mut eng = Engine::new(TestWorld::default(), 1);
    let cell = eng.setup(|_, core| core.new_cell("flag", 9));
    eng.spawn_host("h", move |ctx| {
        ctx.advance(10);
        ctx.wait_ge(cell, 5, "flag>=5");
        assert_eq!(ctx.now(), 10); // no time passed
    });
    eng.run().unwrap();
}

#[test]
fn two_hosts_ping_pong() {
    let mut eng = Engine::new(TestWorld::default(), 1);
    let (a2b, b2a) = eng.setup(|_, core| (core.new_cell("a2b", 0), core.new_cell("b2a", 0)));
    eng.spawn_host("a", move |ctx| {
        for i in 1..=3u64 {
            ctx.advance(10);
            ctx.with(|_, core| core.write_cell(a2b, i));
            ctx.wait_ge(b2a, i, "b2a");
        }
        ctx.with(|w, c| w.log.push((c.now(), "a-done".into())));
    });
    eng.spawn_host("b", move |ctx| {
        for i in 1..=3u64 {
            ctx.wait_ge(a2b, i, "a2b");
            ctx.advance(5);
            ctx.with(|_, core| core.write_cell(b2a, i));
        }
    });
    let (w, _) = eng.run().unwrap();
    // Each round: a advances 10, writes; b wakes, advances 5, writes; so
    // rounds complete at 15, 30, 45.
    assert_eq!(w.log, vec![(45, "a-done".into())]);
}

#[test]
fn deadlock_detected_and_reported() {
    let mut eng = Engine::new(TestWorld::default(), 1);
    let cell = eng.setup(|_, core| core.new_cell("never", 0));
    eng.spawn_host("stuck", move |ctx| {
        ctx.wait_ge(cell, 1, "never>=1");
    });
    match eng.run() {
        Err(SimError::Stall { report }) => {
            // Structured fields: the parked host and the armed waiter.
            assert_eq!(report.hosts.len(), 1);
            assert_eq!(report.hosts[0].host, "stuck");
            assert_eq!(report.hosts[0].site, "never>=1");
            assert_eq!(report.waiters.len(), 1);
            assert_eq!(report.waiters[0].cell_name, "never");
            assert_eq!(report.waiters[0].value, 0);
            assert_eq!(report.waiters[0].threshold, 1);
            // Rendered form still names every blocked entity.
            let text = report.to_string();
            assert!(text.contains("stuck"), "report: {text}");
            assert!(text.contains("never"), "report: {text}");
            assert!(report.headline().contains("stuck"), "headline: {}", report.headline());
        }
        other => panic!("expected stall, got {other:?}", other = other.map(|_| ())),
    }
}

/// The stall inspector hook contributes world-level detail to the report.
#[test]
fn stall_inspector_detail_lands_in_report() {
    let mut eng = Engine::new(TestWorld::default(), 1);
    let cell = eng.setup(|_, core| core.new_cell("armed.ctr", 0));
    eng.set_stall_inspector(|w, core| StallDetail {
        armed: vec![format!("dwq descriptor on cell '{}'", core.cell_name(CellId(0)))],
        notes: vec![format!("world log entries: {}", w.log.len())],
    });
    eng.spawn_host("parked", move |ctx| {
        ctx.wait_ge(cell, 2, "armed.ctr>=2");
    });
    match eng.run() {
        Err(SimError::Stall { report }) => {
            assert_eq!(report.armed, vec!["dwq descriptor on cell 'armed.ctr'".to_string()]);
            assert_eq!(report.notes, vec!["world log entries: 0".to_string()]);
            let text = format!("{}", SimError::Stall { report });
            assert!(text.contains("deadlock"), "display keeps the deadlock keyword: {text}");
        }
        other => panic!("expected stall, got {other:?}", other = other.map(|_| ())),
    }
}

#[test]
fn host_panic_is_reported() {
    let mut eng = Engine::new(TestWorld::default(), 1);
    eng.spawn_host("bad", |ctx| {
        ctx.advance(1);
        panic!("boom-{}", 42);
    });
    match eng.run() {
        Err(SimError::HostPanic { message }) => {
            assert!(message.contains("boom-42"), "message: {message}");
            assert!(message.contains("bad"), "message: {message}");
        }
        other => panic!("expected host panic, got {other:?}", other = other.map(|_| ())),
    }
}

#[test]
fn determinism_same_seed_same_timeline() {
    fn run_once(seed: u64) -> Vec<(Time, String)> {
        let mut eng = Engine::new(TestWorld::default(), seed);
        let cell = eng.setup(|_, core| core.new_cell("c", 0));
        for h in 0..4u64 {
            eng.spawn_host(format!("h{h}"), move |ctx| {
                for i in 0..5u64 {
                    let dt = ctx.with(|_, core| core.rng().jitter(100, 0.2));
                    ctx.advance(dt);
                    ctx.with(|w, core| {
                        let v = core.add_cell(cell, 1);
                        w.log.push((core.now(), format!("h{h}.{i}={v}")));
                    });
                }
            });
        }
        eng.run().unwrap().0.log
    }
    let a = run_once(77);
    let b = run_once(77);
    let c = run_once(78);
    assert_eq!(a, b);
    assert_ne!(a, c);
}

#[test]
fn many_hosts_scale() {
    let mut eng = Engine::new(TestWorld::default(), 1);
    let cell = eng.setup(|_, core| core.new_cell("sum", 0));
    let n = 64u64;
    for h in 0..n {
        eng.spawn_host(format!("h{h}"), move |ctx| {
            for _ in 0..10 {
                ctx.advance(7);
                ctx.with(|_, core| {
                    core.add_cell(cell, 1);
                });
            }
        });
    }
    let mut eng2_cell = None;
    eng.setup(|_, core| eng2_cell = Some(core.cell(cell)));
    let (_, stats) = eng.run().unwrap();
    assert!(stats.host_switches >= n * 10);
}

#[test]
fn world_returned_after_run() {
    let eng = Engine::new(TestWorld { log: vec![(0, "pre".into())] }, 1);
    let (w, _) = eng.run().unwrap();
    assert_eq!(w.log, vec![(0, "pre".into())]);
}

// ---------------------------------------------------------------------
// PR 1 (sim hot-path rework): typed events, microtasks, waiter ordering
// ---------------------------------------------------------------------

/// Pins the waiter fire-order contract: satisfied waiters fire in
/// ascending threshold order, and REGISTRATION ORDER among waiters with
/// the same threshold (the ordered-waiter refactor must never silently
/// change this).
#[test]
fn same_threshold_waiters_fire_in_registration_order() {
    let eng = Engine::new(TestWorld::default(), 1);
    eng.setup(|_, core| {
        let c = core.new_cell("ctr", 0);
        // Registered: a(5), b(3), c(5), d(3), e(4).
        for (name, th) in [("a", 5u64), ("b", 3), ("c", 5), ("d", 3), ("e", 4)] {
            core.on_ge(c, th, "w", Box::new(move |w, core| log_ev(w, core, name)));
        }
        core.schedule(10, Box::new(move |_, core| core.write_cell(c, 5)));
    });
    let (w, _) = eng.run().unwrap();
    let msgs: Vec<_> = w.log.iter().map(|(_, m)| m.as_str()).collect();
    // Ascending threshold; b before d (both 3), a before c (both 5).
    assert_eq!(msgs, vec!["b", "d", "e", "a", "c"]);
}

/// Partially satisfied cells fire only the satisfied prefix, keeping the
/// rest ordered.
#[test]
fn partial_fire_drains_only_satisfied_thresholds() {
    let eng = Engine::new(TestWorld::default(), 1);
    eng.setup(|_, core| {
        let c = core.new_cell("ctr", 0);
        for (name, th) in [("t5", 5u64), ("t2", 2), ("t9", 9)] {
            core.on_ge(c, th, "w", Box::new(move |w, core| log_ev(w, core, name)));
        }
        core.schedule(10, Box::new(move |_, core| core.write_cell(c, 4)));
        core.schedule(20, Box::new(move |_, core| core.write_cell(c, 9)));
    });
    let (w, _) = eng.run().unwrap();
    assert_eq!(
        w.log,
        vec![(10, "t2".into()), (20, "t5".into()), (20, "t9".into())]
    );
}

/// Microtasks (zero-delay continuations) run at the current instant,
/// FIFO, before any pending heap event that shares the timestamp.
#[test]
fn microtasks_run_before_same_time_heap_events() {
    let eng = Engine::new(TestWorld::default(), 1);
    eng.setup(|_, core| {
        core.schedule(
            10,
            Box::new(|w, c| {
                log_ev(w, c, "e1");
                c.defer(Box::new(|w, c| {
                    log_ev(w, c, "m1");
                    c.defer(Box::new(|w, c| log_ev(w, c, "m2")));
                }));
            }),
        );
        core.schedule(10, Box::new(|w, c| log_ev(w, c, "e2")));
    });
    let (w, stats) = eng.run().unwrap();
    let msgs: Vec<_> = w.log.iter().map(|(_, m)| m.as_str()).collect();
    assert_eq!(msgs, vec!["e1", "m1", "m2", "e2"]);
    assert_eq!(stats.microtasks, 2);
    assert_eq!(stats.events, 4, "microtasks count as events");
}

/// Typed cell-add events behave exactly like a scheduled closure that
/// calls `add_cell`, including waiter firing.
#[test]
fn typed_cell_add_fires_waiters() {
    let eng = Engine::new(TestWorld::default(), 1);
    eng.setup(|_, core| {
        let c = core.new_cell("ctr", 0);
        core.on_ge(c, 3, "w", Box::new(|w, core| log_ev(w, core, "fired")));
        core.schedule_cell_add(5, c, 2); // below threshold
        core.schedule_cell_add(9, c, 1); // reaches 3
    });
    let (w, stats) = eng.run().unwrap();
    assert_eq!(w.log, vec![(9, "fired".into())]);
    assert_eq!(stats.cell_writes, 2);
}

/// `advance(0)` keeps the token: no host switch, no time passes.
#[test]
fn advance_zero_is_free() {
    let mut eng = Engine::new(TestWorld::default(), 1);
    eng.spawn_host("h", |ctx| {
        ctx.advance(0);
        assert_eq!(ctx.now(), 0);
        ctx.advance(10);
        ctx.advance(0);
        assert_eq!(ctx.now(), 10);
    });
    let (_, stats) = eng.run().unwrap();
    // Initial resume + one real advance — the advance(0)s cost nothing.
    assert_eq!(stats.host_switches, 2);
}

/// A waiter-woken host resumes at the exact write instant through the
/// microtask path.
#[test]
fn waiter_wakeup_carries_resume_time() {
    let mut eng = Engine::new(TestWorld::default(), 1);
    let cell = eng.setup(|_, core| {
        let c = core.new_cell("flag", 0);
        core.schedule_cell_add(777, c, 1);
        c
    });
    eng.spawn_host("h", move |ctx| {
        ctx.wait_ge(cell, 1, "flag");
        assert_eq!(ctx.now(), 777);
    });
    eng.run().unwrap();
}
